"""Mesh-placement quality (DESIGN.md §2.2): the paper's scheduler applied to
expert placement on the multi-pod mesh.

With skewed (Zipf) expert load — the realistic case — R-Storm's soft CPU
constraint balances hot experts across pods while round-robin placement
concentrates them; the hard HBM constraint is never violated.  Also reports
the planner's escalation decisions per architecture."""

from __future__ import annotations

import numpy as np

from repro import configs
from repro.configs.base import SHAPES
from repro.models import build, cell_skip_reason
from repro.placement import (
    MeshShape,
    ResourceAwarePlanner,
    plan_expert_placement,
    round_robin_expert_placement,
)

from .common import emit_csv_row


def run() -> list:
    rows = []
    mesh = MeshShape({"pod": 2, "data": 16, "model": 16})
    rng = np.random.default_rng(0)
    for arch in ("olmoe-1b-7b", "mixtral-8x7b"):
        cfg = configs.get(arch)
        E = cfg.n_experts
        # Bimodal load (a handful of hot experts at random indices) averaged
        # over 20 draws: the regime where *which group gets which expert*
        # matters.  (A single ultra-hot expert is an irreducible floor no
        # placement can split — both schedulers tie there.)
        n_hot = max(E // 8, 2)
        rs_max, rr_max, floor = [], [], []
        for seed in range(20):
            r = np.random.default_rng(seed)
            load = np.full(E, 1.0)
            hot = r.choice(E, n_hot, replace=False)
            load[hot] = E / n_hot  # hot experts carry ~50% of traffic
            rs = plan_expert_placement(cfg, mesh, load)
            rr = round_robin_expert_placement(cfg, mesh, load)
            rs_max.append(rs["max_load_share"])
            rr_max.append(rr["max_load_share"])
            floor.append(load.max() / load.sum())
            assert not rs["unassigned"]
        ideal = 1.0 / min(mesh.size("model") * mesh.size("pod"), E)
        emit_csv_row(
            f"placement_experts/{arch}_bimodal",
            0.0,
            f"rstorm_mean_max_load={np.mean(rs_max):.4f};"
            f"rr_mean_max_load={np.mean(rr_max):.4f};"
            f"single_expert_floor={np.mean(floor):.4f};ideal={ideal:.4f};n=20",
        )
        rows.append((arch, "bimodal", np.mean(rs_max), np.mean(rr_max)))
    # Planner escalation report (hard-constraint ladder) per train cell.
    planner = ResourceAwarePlanner()
    for arch in configs.ARCHS:
        m = build(arch)
        shape = SHAPES[0]  # train_4k
        plan = planner.plan(m, shape, mesh)
        emit_csv_row(
            f"placement_plan/{arch}_train4k",
            0.0,
            f"fsdp={plan.fsdp};n_micro={plan.n_micro};"
            f"mem_total={plan.memory.total / 2**30:.2f}GiB;"
            f"fits={plan.memory.fits}",
        )
        rows.append((arch, "plan", plan, None))
    return rows


if __name__ == "__main__":
    run()
