"""Reconfiguration-plane quality benchmark: greedy vs search-mode rebalance.

Two deterministic timelines, replayed once per reconfig mode through the
same ``ScenarioRunner``:

* ``rebalance_failover`` — PageLoad on emulab_12 loses two workers, then
  rebalances; the paper's §3 recovery path.  ``sim_tp`` is the final
  steady-state sink throughput, ``moved_count`` the number of migrated
  tasks (search pays extra moves only when the simulated-never-worse guard
  says they buy throughput).
* ``rebalance_hotspot`` — a ``LoadChangeEvent`` makes one PageLoad
  component 4x more expensive mid-run; greedy has nothing orphaned to
  patch (the placement is stale, not broken), search re-optimizes under
  the migration penalty.

Both ``sim_tp`` (higher is better) and ``moved_count`` (lower is better)
are pure functions of fixed seeds and feed the bench-regression gate;
wall-clock timing is reported but exempt.
"""

from __future__ import annotations

from typing import Dict

from repro.api import (
    LoadChangeEvent,
    NodeFailEvent,
    RebalanceEvent,
    ScenarioRunner,
    ScenarioSpec,
    SchedulerSpec,
    SubmitEvent,
)
from repro.stream import topologies

from .common import EMULAB_12, EMULAB_24, emit_csv_row, timed

#: (label, reconfig mode, reconfig kwargs) — the rebalance comparison matrix.
MODES = [
    ("greedy", "greedy", None),
    (
        "search",
        "search",
        {"seed": 0, "n_chains": 16, "steps": 600, "move_cost": 0.25},
    ),
    ("search_budget", "search", {"seed": 0, "budget_s": 0.1}),
]


def failover_scenario() -> ScenarioSpec:
    return ScenarioSpec(
        name="rebalance_failover",
        cluster=EMULAB_12,
        timeline=(
            SubmitEvent(
                topology=topologies.spec("pageload"),
                scheduler=SchedulerSpec("rstorm", {}),
            ),
            NodeFailEvent(node_id="r0n0"),
            NodeFailEvent(node_id="r0n1"),
            RebalanceEvent(),
        ),
    )


def hotspot_scenario() -> ScenarioSpec:
    return ScenarioSpec(
        name="rebalance_hotspot",
        cluster=EMULAB_24,
        timeline=(
            SubmitEvent(
                topology=topologies.spec("pageload"),
                scheduler=SchedulerSpec("rstorm", {}),
            ),
            LoadChangeEvent(
                topology_id="pageload", component_id="geo_enrich", factor=4.0
            ),
            RebalanceEvent(),
        ),
    )


def _final_tp(trace) -> float:
    return trace.final().topologies["pageload"]["sink_throughput"]


def _moved(trace) -> int:
    return sum(
        len(v) for v in trace.final().outcome.get("moved", {}).values()
    )


def run(smoke: bool = False) -> Dict[str, object]:
    out: Dict[str, object] = {}
    for scenario_fn in (failover_scenario, hotspot_scenario):
        spec = scenario_fn()
        for label, mode, kwargs in MODES:
            trace, secs = timed(
                ScenarioRunner(
                    spec, reconfig=mode, reconfig_kwargs=kwargs
                ).run,
                repeat=1,
            )
            out[f"{spec.name}/{label}"] = trace
            emit_csv_row(
                f"{spec.name}/{label}",
                secs * 1e6,
                f"sim_tp={_final_tp(trace):.1f}tuples/s;"
                f"moved_count={_moved(trace)}",
            )
    return out


if __name__ == "__main__":
    run()
