"""Paper Fig 9 + Fig 10 — computation-time-bound micro-benchmarks.

Claims reproduced:
  * R-Storm matches default throughput using ~half the machines
    (Linear 6 vs 12, Diamond 7 vs 12);
  * CPU utilization is 69–350% higher under R-Storm;
  * Star: default Storm over-utilizes one machine (node-major slot order)
    creating a bottleneck that throttles throughput.
"""

from __future__ import annotations

from repro.stream import topologies

from .common import compare_schedulers, emit_csv_row

PAPER_UTIL_GAINS = {"linear": 69.0, "diamond": 91.0, "star": 350.0}


def run() -> list:
    rows = []
    for name, maker in topologies.ALL_MICRO.items():
        schedulers = [
            ("default", "round_robin", {"seed": 1}),
            ("rstorm", "rstorm", {}),
        ]
        if name == "star":
            # The paper's Star bottleneck arises from slot-ordered round robin
            # stacking heavy centre tasks on one machine.
            schedulers.insert(
                1,
                ("default_node_major", "round_robin", {"seed": 1, "slot_mode": "node_major"}),
            )
        res = compare_schedulers(lambda: maker(network_bound=False), schedulers)
        baseline = res["default_node_major"] if name == "star" else res["default"]
        rs = res["rstorm"]
        tp_gain = (rs.sink_throughput / max(baseline.sink_throughput, 1e-9) - 1) * 100
        util_gain = (
            rs.avg_cpu_utilization / max(baseline.avg_cpu_utilization, 1e-9) - 1
        ) * 100
        for label, r in res.items():
            emit_csv_row(
                f"fig9_{name}_cpu/{label}",
                0.0,
                f"tp={r.sink_throughput:.0f}tuples/s;machines={r.machines_used};"
                f"util={r.avg_cpu_utilization:.3f};binding={r.binding}",
            )
        emit_csv_row(
            f"fig10_{name}_cpu/util_gain",
            0.0,
            f"gain={util_gain:+.1f}%;paper={PAPER_UTIL_GAINS[name]:+.0f}%;"
            f"tp_gain={tp_gain:+.1f}%;machines={rs.machines_used}vs{baseline.machines_used}",
        )
        rows.append((name, tp_gain, util_gain, res))
    return rows


if __name__ == "__main__":
    run()
