"""Paper Fig 12 — Yahoo PageLoad and Processing production topologies
(single-topology runs on the 12-node Emulab cluster).

Paper: R-Storm outperforms default Storm by ~50% (PageLoad) and ~47%
(Processing) in overall throughput."""

from __future__ import annotations

from repro.stream import topologies

from .common import DEFAULT_MATRIX, compare_schedulers, emit_csv_row

PAPER_GAINS = {"pageload": 50.0, "processing": 47.0}


def run() -> list:
    rows = []
    for name, maker in topologies.ALL_YAHOO.items():
        res = compare_schedulers(maker, DEFAULT_MATRIX)
        base = res["default"].sink_throughput
        for label, r in res.items():
            gain = (r.sink_throughput / max(base, 1e-9) - 1.0) * 100.0
            emit_csv_row(
                f"fig12_{name}/{label}",
                0.0,
                f"tp={r.sink_throughput:.1f}tuples/s;gain={gain:+.1f}%;"
                f"paper={PAPER_GAINS[name]:+.0f}%;binding={r.binding};"
                f"machines={r.machines_used}",
            )
        rows.append((name, res))
    return rows


if __name__ == "__main__":
    run()
