"""CI hot-path regression gate: the 1000-task / 256-node R-Storm schedule
must complete within a fixed wall-clock budget, and fully place the topology.

The arena engine does this in ~0.06 s on a laptop (the legacy dict path
takes ~2 s); the budget leaves generous headroom for slow CI runners while
still failing hard if the vectorized hot path regresses to per-task Python
dict churn.

Usage: PYTHONPATH=src python -m benchmarks.check_overhead_budget [budget_s]
"""

from __future__ import annotations

import sys
import time

from repro.core import Cluster, get_scheduler

from .bench_scheduler_overhead import SIZES, chain_topology

DEFAULT_BUDGET_S = 1.5


def main() -> int:
    budget_s = float(sys.argv[1]) if len(sys.argv) > 1 else DEFAULT_BUDGET_S
    # The gate always enforces the bench's flagship (largest) case.
    comps, par, racks, nodes_per_rack = SIZES[-1]
    topo = chain_topology(comps, par)
    cluster = Cluster.homogeneous(
        racks=racks, nodes_per_rack=nodes_per_rack, memory_mb=65536.0, cpu=6400.0
    )
    sched = get_scheduler("rstorm")
    best = float("inf")
    for _ in range(3):
        cluster.reset()
        t0 = time.perf_counter()
        assignment = sched.schedule(topo, cluster, commit=False)
        best = min(best, time.perf_counter() - t0)
    ok = best <= budget_s and assignment.is_complete(topo)
    print(
        f"scheduler-overhead budget: {topo.task_count()} tasks / "
        f"{len(cluster.nodes)} nodes in {best:.3f}s "
        f"(budget {budget_s:.1f}s, complete={assignment.is_complete(topo)}) "
        f"-> {'OK' if ok else 'FAIL'}"
    )
    return 0 if ok else 1


if __name__ == "__main__":
    sys.exit(main())
