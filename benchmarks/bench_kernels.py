"""Kernel micro-benchmarks.

On this CPU-only container the Pallas kernels execute in interpret mode
(correctness, not speed), so the timings reported here are for the jnp
oracle paths (the XLA-compiled baselines the kernels must beat on real
TPUs); the derived column carries the analytic FLOPs so TPU-side MFU can be
projected.  Correctness (kernel == oracle) is asserted per call."""

from __future__ import annotations

import numpy as np

import jax
import jax.numpy as jnp

from repro.kernels.decode_attn import decode_attention, decode_attention_ref
from repro.kernels.flash import attention_ref, flash_attention
from repro.kernels.mlstm import mlstm_chunk, mlstm_ref
from repro.kernels.moe_gemm import grouped_gemm, grouped_gemm_ref
from repro.kernels.rglru import rglru_scan, rglru_scan_ref

from .common import emit_csv_row, timed

KEY = jax.random.PRNGKey(0)


def run() -> list:
    rows = []
    # flash attention
    B, H, Kv, S, hd = 1, 8, 4, 1024, 64
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (B, H, S, hd))
    k = jax.random.normal(ks[1], (B, Kv, S, hd))
    v = jax.random.normal(ks[2], (B, Kv, S, hd))
    ref_fn = jax.jit(lambda a, b, c: attention_ref(a, b, c, causal=True))
    ref_fn(q, k, v).block_until_ready()
    _, secs = timed(lambda: ref_fn(q, k, v).block_until_ready())
    got = flash_attention(q, k, v, causal=True, interpret=True)
    err = float(jnp.max(jnp.abs(got - ref_fn(q, k, v))))
    flops = 2 * 2 * B * H * S * S * hd
    emit_csv_row(
        "kernel_flash/oracle_b1h8s1024d64",
        secs * 1e6,
        f"flops={flops:.3e};kernel_vs_oracle_maxerr={err:.1e}",
    )
    rows.append(("flash", secs, err))

    # decode attention
    S = 4096
    q1 = jax.random.normal(ks[0], (B, H, hd))
    kc = jax.random.normal(ks[1], (B, Kv, S, hd))
    vc = jax.random.normal(ks[2], (B, Kv, S, hd))
    ref_fn = jax.jit(lambda a, b, c: decode_attention_ref(a, b, c, S))
    ref_fn(q1, kc, vc).block_until_ready()
    _, secs = timed(lambda: ref_fn(q1, kc, vc).block_until_ready())
    got = decode_attention(q1, kc, vc, S, interpret=True)
    err = float(jnp.max(jnp.abs(got - ref_fn(q1, kc, vc))))
    emit_csv_row(
        "kernel_decode/oracle_b1h8s4096",
        secs * 1e6,
        f"bytes={2 * B * Kv * S * hd * 4:.3e};kernel_vs_oracle_maxerr={err:.1e}",
    )
    rows.append(("decode", secs, err))

    # rglru
    B2, S2, D2 = 2, 1024, 512
    a = jax.nn.sigmoid(jax.random.normal(ks[0], (B2, S2, D2)))
    x = jax.random.normal(ks[1], (B2, S2, D2))
    h0 = jnp.zeros((B2, D2))
    ref_fn = jax.jit(rglru_scan_ref)
    ref_fn(a, x, h0).block_until_ready()
    _, secs = timed(lambda: ref_fn(a, x, h0).block_until_ready())
    got = rglru_scan(a, x, h0, interpret=True)
    err = float(jnp.max(jnp.abs(got - ref_fn(a, x, h0))))
    emit_csv_row(
        "kernel_rglru/oracle_b2s1024d512",
        secs * 1e6,
        f"elements={B2 * S2 * D2:.3e};kernel_vs_oracle_maxerr={err:.1e}",
    )
    rows.append(("rglru", secs, err))

    # mlstm
    B3, H3, S3, hd3 = 1, 4, 512, 64
    ks5 = jax.random.split(KEY, 5)
    q3 = jax.random.normal(ks5[0], (B3, H3, S3, hd3))
    k3 = jax.random.normal(ks5[1], (B3, H3, S3, hd3)) / np.sqrt(hd3)
    v3 = jax.random.normal(ks5[2], (B3, H3, S3, hd3))
    li = jax.random.normal(ks5[3], (B3, H3, S3))
    lf = jax.nn.log_sigmoid(jax.random.normal(ks5[4], (B3, H3, S3)) + 2.0)
    ref_fn = jax.jit(mlstm_ref)
    ref_fn(q3, k3, v3, li, lf).block_until_ready()
    _, secs = timed(lambda: ref_fn(q3, k3, v3, li, lf).block_until_ready())
    got = mlstm_chunk(q3, k3, v3, li, lf, chunk=128, interpret=True)
    err = float(jnp.max(jnp.abs(got - ref_fn(q3, k3, v3, li, lf))))
    emit_csv_row(
        "kernel_mlstm/oracle_b1h4s512d64",
        secs * 1e6,
        f"kernel_vs_oracle_maxerr={err:.1e}",
    )
    rows.append(("mlstm", secs, err))

    # grouped gemm
    E, C, D4, F = 8, 256, 512, 1024
    x4 = jax.random.normal(ks[0], (E, C, D4), jnp.bfloat16)
    w4 = jax.random.normal(ks[1], (E, D4, F), jnp.bfloat16) * 0.05
    ref_fn = jax.jit(grouped_gemm_ref)
    ref_fn(x4, w4).block_until_ready()
    _, secs = timed(lambda: ref_fn(x4, w4).block_until_ready())
    got = grouped_gemm(x4, w4, interpret=True)
    err = float(
        jnp.max(jnp.abs(got.astype(jnp.float32) - ref_fn(x4, w4).astype(jnp.float32)))
    )
    emit_csv_row(
        "kernel_moe_gemm/oracle_e8c256d512f1024",
        secs * 1e6,
        f"flops={2 * E * C * D4 * F:.3e};kernel_vs_oracle_maxerr={err:.1e}",
    )
    rows.append(("moe_gemm", secs, err))
    return rows


if __name__ == "__main__":
    run()
