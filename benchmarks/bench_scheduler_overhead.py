"""Scheduling latency vs topology size (paper §3: "scheduling decisions need
to be made in a snappy manner" — Nimbus invokes the scheduler every 10 s).

R-Storm is O(tasks × nodes); we verify the absolute cost stays far below the
10 s scheduling round even for 1000-task topologies on 256-node clusters.
"""

from __future__ import annotations

from repro.core import Cluster, Component, Topology, get_scheduler

from .common import emit_csv_row, timed


def chain_topology(components: int, parallelism: int) -> Topology:
    t = Topology(f"chain{components}x{parallelism}")
    prev = None
    for i in range(components):
        c = Component(f"c{i}", is_spout=(i == 0), parallelism=parallelism)
        c.set_memory_load(128.0).set_cpu_load(10.0)
        t.add_component(c)
        if prev:
            t.add_edge(prev, c.id)
        prev = c.id
    return t


def run() -> list:
    rows = []
    for comps, par, racks, nodes_per_rack in (
        (4, 4, 2, 6),
        (8, 8, 2, 12),
        (16, 16, 4, 16),
        (25, 40, 8, 32),  # 1000 tasks, 256 nodes
    ):
        topo = chain_topology(comps, par)
        cluster = Cluster.homogeneous(
            racks=racks, nodes_per_rack=nodes_per_rack, memory_mb=65536.0, cpu=6400.0
        )
        for label, name in (("rstorm", "rstorm"), ("default", "round_robin")):
            sched = get_scheduler(name)
            cluster.reset()
            a, secs = timed(lambda: sched.schedule(topo, cluster, commit=False), repeat=2)
            emit_csv_row(
                f"sched_overhead/{label}_t{comps * par}_n{racks * nodes_per_rack}",
                secs * 1e6,
                f"tasks={comps * par};nodes={racks * nodes_per_rack};"
                f"complete={a.is_complete(topo)}",
            )
            rows.append((label, comps * par, racks * nodes_per_rack, secs))
    return rows


if __name__ == "__main__":
    run()
