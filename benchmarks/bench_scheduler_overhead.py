"""Scheduling latency vs topology size (paper §3: "scheduling decisions need
to be made in a snappy manner" — Nimbus invokes the scheduler every 10 s).

R-Storm is O(tasks × nodes); we verify the absolute cost stays far below the
10 s scheduling round even for 1000-task topologies on 256-node clusters,
and measure the array-backed engine against the dict-based legacy path
(`engine="legacy"`), emitting the speedup per case.
"""

from __future__ import annotations

from repro.core import Cluster, Component, Topology, get_scheduler

from .common import emit_csv_row, timed


def chain_topology(components: int, parallelism: int) -> Topology:
    t = Topology(f"chain{components}x{parallelism}")
    prev = None
    for i in range(components):
        c = Component(f"c{i}", is_spout=(i == 0), parallelism=parallelism)
        c.set_memory_load(128.0).set_cpu_load(10.0)
        t.add_component(c)
        if prev:
            t.add_edge(prev, c.id)
        prev = c.id
    return t


#: (components, parallelism, racks, nodes_per_rack)
SIZES = (
    (4, 4, 2, 6),
    (8, 8, 2, 12),
    (16, 16, 4, 16),
    (25, 40, 8, 32),  # 1000 tasks, 256 nodes
)

#: (label, registry name, extra kwargs)
MATRIX = (
    ("rstorm", "rstorm", {}),
    ("default", "round_robin", {}),
    ("rstorm_annealed", "rstorm_annealed", {"iters": 400}),
)


def run() -> list:
    rows = []
    for comps, par, racks, nodes_per_rack in SIZES:
        topo = chain_topology(comps, par)
        cluster = Cluster.homogeneous(
            racks=racks, nodes_per_rack=nodes_per_rack, memory_mb=65536.0, cpu=6400.0
        )
        tasks, nodes = comps * par, racks * nodes_per_rack
        for label, name, kwargs in MATRIX:
            # Legacy full-recompute annealer swaps are O(E) per iteration —
            # minutes at the flagship size; time only the arena engine there.
            engines = (
                ("arena",)
                if label == "rstorm_annealed" and tasks > 256
                else ("arena", "legacy")
            )
            per_engine = {}
            for engine in engines:
                sched = get_scheduler(name, engine=engine, **kwargs)
                cluster.reset()
                a, secs = timed(
                    lambda: sched.schedule(topo, cluster, commit=False), repeat=2
                )
                per_engine[engine] = secs
                emit_csv_row(
                    f"sched_overhead/{label}_{engine}_t{tasks}_n{nodes}",
                    secs * 1e6,
                    f"tasks={tasks};nodes={nodes};complete={a.is_complete(topo)}",
                )
            if "legacy" in per_engine:
                speedup = per_engine["legacy"] / max(per_engine["arena"], 1e-12)
                emit_csv_row(
                    f"sched_overhead/{label}_speedup_t{tasks}_n{nodes}",
                    speedup,
                    f"tasks={tasks};nodes={nodes};arena_s={per_engine['arena']:.4f};"
                    f"legacy_s={per_engine['legacy']:.4f}",
                )
            rows.append(
                (label, tasks, nodes, per_engine["arena"], per_engine.get("legacy"))
            )
    return rows


if __name__ == "__main__":
    run()
