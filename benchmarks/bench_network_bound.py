"""Paper Fig 8 — network-bound micro-benchmark topologies.

Reports R-Storm vs default-Storm throughput on Linear/Diamond/Star (paper:
+50% / +30% / +47%)."""

from __future__ import annotations

from repro.stream import topologies

from .common import DEFAULT_MATRIX, compare_schedulers, emit_csv_row

PAPER_GAINS = {"linear": 50.0, "diamond": 30.0, "star": 47.0}


def run() -> list:
    rows = []
    for name, maker in topologies.ALL_MICRO.items():
        res = compare_schedulers(lambda: maker(network_bound=True), DEFAULT_MATRIX)
        base = res["default"].sink_throughput
        for label in ("rstorm", "rstorm_plus", "rstorm_annealed"):
            gain = (res[label].sink_throughput / max(base, 1e-9) - 1.0) * 100.0
            derived = (
                f"tp={res[label].sink_throughput:.0f}tuples/s;"
                f"gain={gain:+.1f}%;paper={PAPER_GAINS[name]:+.0f}%;"
                f"binding={res[label].binding};machines={res[label].machines_used}"
            )
            emit_csv_row(f"fig8_{name}_net/{label}", 0.0, derived)
            rows.append((name, label, gain, res[label]))
        emit_csv_row(
            f"fig8_{name}_net/default",
            0.0,
            f"tp={base:.0f}tuples/s;binding={res['default'].binding};"
            f"machines={res['default'].machines_used}",
        )
    return rows


if __name__ == "__main__":
    run()
