"""Paper Fig 13 + §6.5 — scheduling multiple topologies on a 24-node cluster.

Paper numbers: PageLoad 25496 vs 16695 tuples/10s (R-Storm +53%); Processing
67115 tuples/10s vs ~10 tuples/s under default Storm ("grinded to a near
halt" — memory over-subscription thrashes machines).

We report three rows:
  * rstorm            — both topologies healthy (memory is a hard constraint);
  * default           — port-major slot order: both degrade via contention;
  * default_node_major— the paper's catastrophic outcome: heavy Processing
    tasks stack on shared nodes, over-subscribing 2 GB RAM → thrash →
    Processing collapses while PageLoad (whose tasks avoid the thrashed
    nodes in this run) merely degrades.  Default Storm's placement is
    pseudo-random, so the exact damage is seed-dependent; the seed scan
    statistics are reported alongside.
"""

from __future__ import annotations

import math
import statistics
from typing import Dict, Tuple

from repro.api import Nimbus
from repro.stream import topologies

from .common import EMULAB_24, emit_csv_row, payload_for

# Representative node-major seed pair (found by scan; reproduces the paper's
# asymmetry: PageLoad ~66% of R-Storm — paper: 65% — Processing ~zero).
NODE_MAJOR_SEEDS = (10, 2)


def run_pair(mode: str, seeds: Tuple[int, int] = (1, 7)):
    nimbus = Nimbus(EMULAB_24)
    pl, pr = topologies.pageload(), topologies.processing()
    if mode == "rstorm":
        specs = [(pl, "rstorm", {}), (pr, "rstorm", {})]
    else:
        specs = [
            (pl, "round_robin", {"seed": seeds[0], "slot_mode": mode}),
            (pr, "round_robin", {"seed": seeds[1], "slot_mode": mode}),
        ]
    for topo, name, kwargs in specs:
        nimbus.submit(payload_for(topo, name, kwargs, EMULAB_24, simulate=False))
    res = nimbus.simulate_all()
    return res["pageload"], res["processing"]


def run() -> Dict[str, object]:
    out = {}
    pl_rs, pr_rs = run_pair("rstorm")
    out["rstorm"] = (pl_rs, pr_rs)
    emit_csv_row(
        "fig13_multi/rstorm",
        0.0,
        f"pageload={pl_rs.sink_throughput:.1f}tuples/s;"
        f"processing={pr_rs.sink_throughput:.1f}tuples/s;thrashed=0",
    )
    pl_d, pr_d = run_pair("port_major")
    out["default"] = (pl_d, pr_d)
    emit_csv_row(
        "fig13_multi/default_port_major",
        0.0,
        f"pageload={pl_d.sink_throughput:.1f}tuples/s;"
        f"processing={pr_d.sink_throughput:.1f}tuples/s",
    )
    pl_n, pr_n = run_pair("node_major", NODE_MAJOR_SEEDS)
    out["default_node_major"] = (pl_n, pr_n)
    emit_csv_row(
        "fig13_multi/default_node_major",
        0.0,
        f"pageload={pl_n.sink_throughput:.1f}tuples/s"
        f"({pl_n.sink_throughput / max(pl_rs.sink_throughput, 1e-9):.0%}of_rstorm;paper=65%);"
        f"processing={pr_n.sink_throughput:.1f}tuples/s(paper~1/s);"
        f"thrashed={len(pr_n.thrashed_nodes)}",
    )
    # Seed-scan statistics for the stochastic default scheduler.
    pr_ratios, pl_ratios = [], []
    for s1 in range(6):
        for s2 in range(6):
            pl_x, pr_x = run_pair("node_major", (s1, s2))
            pl_ratios.append(pl_x.sink_throughput / max(pl_rs.sink_throughput, 1e-9))
            pr_ratios.append(pr_x.sink_throughput / max(pr_rs.sink_throughput, 1e-9))
    emit_csv_row(
        "fig13_multi/default_node_major_seedscan",
        0.0,
        f"processing_median={statistics.median(pr_ratios):.3f}of_rstorm;"
        f"processing_max={max(pr_ratios):.3f};"
        f"pageload_median={statistics.median(pl_ratios):.3f}of_rstorm;n=36",
    )
    return out


if __name__ == "__main__":
    run()
