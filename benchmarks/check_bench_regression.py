"""CI quality-regression gate over the benchmark trajectory.

Compares a fresh ``BENCH_smoke.json`` (written by ``benchmarks.run --smoke``)
against the committed ``BENCH_baseline.json`` and fails on a >20% regression
in any *deterministic quality* metric parsed from the rows' ``derived``
fields:

* lower-is-better: ``netcost``, ``moved_count``;
* higher-is-better: ``sink_tp``, ``sim_tp``, ``tp``, ``spearman``,
  ``greedy_tp``, ``tp_initial``, ``tp_final``, ``tp_recovered``.

Wall-clock columns (``us_per_call``, ``cand_per_s``) are deliberately NOT
gated — they are machine-dependent; the scheduler-overhead budget gate owns
latency.  The quality metrics are pure functions of fixed seeds, so both CI
legs (jax and nojax) compare against the same baseline (the search subsystem
is golden-equal across backends).

A baseline row missing from the fresh run fails the gate too (silent loss of
coverage reads as "no regression").  After an *intentional* change in
benchmark output, regenerate with::

    PYTHONPATH=src python -m benchmarks.run --smoke
    cp BENCH_smoke.json BENCH_baseline.json

Usage: python -m benchmarks.check_bench_regression [fresh] [baseline] [tol]
"""

from __future__ import annotations

import json
import re
import sys

TOLERANCE = 0.20

LOWER_IS_BETTER = ("netcost", "moved_count")
HIGHER_IS_BETTER = (
    "sink_tp",
    "sim_tp",
    "tp",
    "spearman",
    "greedy_tp",
    "tp_initial",
    "tp_final",
    "tp_recovered",
)

_FLOAT = r"([-+]?\d+(?:\.\d+)?(?:[eE][-+]?\d+)?)"


def parse_metrics(derived: str) -> dict:
    """``key=<float><junk>;...`` pairs for the gated keys only."""
    out = {}
    for key in LOWER_IS_BETTER + HIGHER_IS_BETTER:
        m = re.search(rf"(?:^|;){key}={_FLOAT}", derived)
        if m:
            out[key] = float(m.group(1))
    return out


def load_rows(path: str) -> dict:
    with open(path) as fh:
        data = json.load(fh)
    return {row["name"]: parse_metrics(row.get("derived", "")) for row in data["rows"]}


def main() -> int:
    fresh_path = sys.argv[1] if len(sys.argv) > 1 else "BENCH_smoke.json"
    base_path = sys.argv[2] if len(sys.argv) > 2 else "BENCH_baseline.json"
    tol = float(sys.argv[3]) if len(sys.argv) > 3 else TOLERANCE
    fresh, base = load_rows(fresh_path), load_rows(base_path)
    failures = []
    checked = 0
    for name, metrics in sorted(base.items()):
        if not metrics:
            continue
        if name not in fresh:
            failures.append(f"{name}: row missing from {fresh_path}")
            continue
        for key, old in metrics.items():
            if key not in fresh[name]:
                failures.append(f"{name}: metric {key} missing from fresh run")
                continue
            new = fresh[name][key]
            checked += 1
            if key in LOWER_IS_BETTER:
                bad = old > 0 and new > old * (1.0 + tol)
                arrow = f"{old:g} -> {new:g} (+{(new / old - 1) * 100:.1f}%)" if old else ""
            else:
                bad = old > 0 and new < old * (1.0 - tol)
                arrow = f"{old:g} -> {new:g} ({(new / old - 1) * 100:+.1f}%)" if old else ""
            if bad:
                failures.append(f"{name}: {key} regressed {arrow}")
    print(
        f"bench-regression gate: {checked} metrics checked against "
        f"{base_path} (tolerance {tol:.0%}) -> "
        f"{'FAIL' if failures else 'OK'}"
    )
    for f in failures:
        print(f"  REGRESSION: {f}")
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
