"""Batched placement search vs greedy R-Storm on the flagship overhead case
(1000 tasks / 256 nodes — the same topology/cluster the scheduler-overhead
budget gate enforces).

Three views:

* ``search/eval_bXXXX``   — raw batched-evaluator throughput: candidates/s
  for scoring B complete placements (feasibility + network cost) in one
  vmapped/jit reduction (numpy fallback when jax is absent);
* ``search/anneal_*``     — the chains×steps sweep: network-cost improvement
  over greedy and wall-clock for the full ``rstorm-search`` schedule call;
* ``search/sequential_*`` — the sequential ``SwapAnnealer`` at a comparable
  swap budget, pinning what batching buys over one-chain annealing.

Smoke mode (CI) runs one tiny 8-chain × 50-step budget plus a B=1024
evaluator scaling row.
"""

from __future__ import annotations

import numpy as np

from repro.core import Assignment, BatchArena, Cluster, PlacementArena, get_scheduler
from repro.core.search import resolve_backend
from repro.core.search.objective import evaluate_batch

from .bench_scheduler_overhead import chain_topology
from .common import emit_csv_row, timed

#: (n_chains, steps) sweep for the full run: breadth scaling at fixed depth
#: (64→1024 chains), then depth scaling at fixed breadth (200→20000 steps) —
#: on big topologies depth closes the gap to the sequential annealer while
#: breadth buys start diversity and the never-worse guarantee.
SWEEP = ((64, 200), (1024, 200), (64, 5000), (64, 20000))
SMOKE_SWEEP = ((8, 50),)

#: Evaluator-scaling batch sizes (acceptance: ≥1024 concurrent candidates).
EVAL_BATCHES = (256, 1024)


def flagship():
    topo = chain_topology(25, 40)
    cluster = Cluster.homogeneous(
        racks=8, nodes_per_rack=32, memory_mb=65536.0, cpu=6400.0
    )
    return topo, cluster


def run(smoke: bool = False) -> list:
    topo, cluster = flagship()
    backend = resolve_backend("auto")
    tasks, nodes = topo.task_count(), len(cluster.nodes)
    rows = []

    greedy, greedy_s = timed(
        lambda: get_scheduler("rstorm").schedule(topo, cluster, commit=False),
        repeat=1 if smoke else 2,
    )
    greedy_net = greedy.network_cost(topo, cluster)
    emit_csv_row(
        f"search/greedy_t{tasks}_n{nodes}",
        greedy_s * 1e6,
        f"netcost={greedy_net};backend={backend}",
    )

    # Raw batched-evaluator throughput on seeded random candidates.
    arena = PlacementArena(cluster, topo)
    avail0 = arena.snapshot()
    seed_assignment = Assignment(topology_id=topo.id)
    get_scheduler("rstorm")._place_on_arena(arena, topo, seed_assignment)
    ba = BatchArena.from_arena(
        arena, topo, dict(seed_assignment.placements), avail0=avail0
    )
    rng = np.random.Generator(np.random.Philox(0))
    alive = np.flatnonzero(ba.alive)
    for b in EVAL_BATCHES:
        P = alive[rng.integers(0, alive.size, size=(b, ba.n_tasks))]
        result, secs = timed(
            lambda: evaluate_batch(ba, P, backend=backend), repeat=1 if smoke else 2
        )
        emit_csv_row(
            f"search/eval_b{b}_t{tasks}",
            secs * 1e6,
            f"cand_per_s={b / max(secs, 1e-9):.0f};backend={backend};"
            f"feasible={int(result.feasible.sum())}",
        )
        rows.append(("eval", b, secs))

    # chains × steps sweep of the full scheduler call.
    for n_chains, steps in SMOKE_SWEEP if smoke else SWEEP:
        sched = get_scheduler(
            "rstorm-search", n_chains=n_chains, steps=steps, seed=0
        )
        cluster.reset()
        a, secs = timed(
            lambda: sched.schedule(topo, cluster, commit=False), repeat=1
        )
        net = a.network_cost(topo, cluster)
        emit_csv_row(
            f"search/anneal_c{n_chains}_s{steps}_t{tasks}",
            secs * 1e6,
            f"netcost={net};improvement_pct={100.0 * (greedy_net - net) / greedy_net:.2f};"
            f"backend={backend};complete={a.is_complete(topo)}",
        )
        rows.append(("anneal", n_chains, steps, net, secs))

    # Sequential one-chain annealer at a comparable swap budget.
    seq_iters = 400 if smoke else 50_000
    seq = get_scheduler("rstorm_annealed", iters=seq_iters)
    cluster.reset()
    a, secs = timed(lambda: seq.schedule(topo, cluster, commit=False), repeat=1)
    net = a.network_cost(topo, cluster)
    emit_csv_row(
        f"search/sequential_i{seq_iters}_t{tasks}",
        secs * 1e6,
        f"netcost={net};improvement_pct={100.0 * (greedy_net - net) / greedy_net:.2f}",
    )
    rows.append(("sequential", seq_iters, net, secs))
    return rows


if __name__ == "__main__":
    run()
