"""Batched placement search vs greedy R-Storm on the flagship overhead case
(1000 tasks / 256 nodes — the same topology/cluster the scheduler-overhead
budget gate enforces), plus the throughput-proxy fidelity sweep on the §6
benchmark topology suite.

Five views:

* ``search/eval_bXXXX``   — raw batched-evaluator throughput: candidates/s
  for scoring B complete placements (feasibility + network cost) in one
  vmapped/jit reduction (numpy fallback when jax is absent);
* ``search/anneal_*``     — the chains×steps sweep: network-cost improvement
  over greedy and wall-clock for the full ``rstorm-search`` schedule call;
* ``search/sequential_*`` — the sequential ``SwapAnnealer`` at a comparable
  swap budget, pinning what batching buys over one-chain annealing;
* ``search/score_*``      — amortized per-candidate cost of the *fused*
  scoring call (netcost + violation + dead + throughput proxy in one
  pass), per backend: numpy, jax-vmap, and the Pallas fused kernel
  (interpret mode off-TPU — the smoke leg doubles as the kernel's CI
  smoke row).  Timing-only derived keys: the three backends are
  bit-identical by contract, so there is no quality metric to gate;
* ``search/multiswap_*``  — the multi-swap annealer: k proposals fused
  per ``lax.scan`` element, same final placements (``identical_to_k1``),
  k× fewer scan launches;
* ``search/fidelity_*``   — proxy fidelity: Spearman rank correlation of the
  throughput proxy against ``simulator.run`` sink throughput over a mixed
  candidate set (greedy + annealed under both objectives + random), per
  suite topology (acceptance bar: ≥ 0.8);
* ``search/tp_*``         — end-to-end: simulated sink throughput of the
  ``objective="throughput"`` search's placement vs the greedy R-Storm seed
  (never lower, by the scheduler's simulated guarantee).

Smoke mode (CI) runs one tiny 8-chain × 50-step budget plus a B=1024
evaluator scaling row and a three-case fidelity subset.
"""

from __future__ import annotations

import numpy as np

from repro.core import Assignment, BatchArena, Cluster, PlacementArena, get_scheduler
from repro.core.search import HAS_JAX, resolve_backend
from repro.core.search.anneal import BatchAnnealer
from repro.core.search.objective import evaluate_batch
from repro.core.search.throughput import compile_throughput, throughput_batch
from repro.stream import Simulator
from repro.stream import topologies as T

from .bench_scheduler_overhead import chain_topology
from .common import emit_csv_row, spearman, timed

#: (n_chains, steps) sweep for the full run: breadth scaling at fixed depth
#: (64→1024 chains), then depth scaling at fixed breadth (200→20000 steps) —
#: on big topologies depth closes the gap to the sequential annealer while
#: breadth buys start diversity and the never-worse guarantee.
SWEEP = ((64, 200), (1024, 200), (64, 5000), (64, 20000))
SMOKE_SWEEP = ((8, 50),)

#: Evaluator-scaling batch sizes (acceptance: ≥1024 concurrent candidates).
EVAL_BATCHES = (256, 1024)


def flagship():
    topo = chain_topology(25, 40)
    cluster = Cluster.homogeneous(
        racks=8, nodes_per_rack=32, memory_mb=65536.0, cpu=6400.0
    )
    return topo, cluster


#: §6 suite for the proxy-fidelity and end-to-end throughput sweeps.
SUITE = (
    ("linear_net", lambda: T.linear(True)),
    ("diamond_net", lambda: T.diamond(True)),
    ("star_net", lambda: T.star(True)),
    ("linear_cpu", lambda: T.linear(False)),
    ("diamond_cpu", lambda: T.diamond(False)),
    ("star_cpu", lambda: T.star(False)),
    ("pageload", T.pageload),
    ("processing", T.processing),
)
SMOKE_SUITE = ("linear_net", "star_cpu", "pageload")


def _suite_cluster(name):
    from repro.core import emulab_cluster

    return emulab_cluster()


def _candidate_mix(ba, tm, assignment, backend, n_random=12):
    """Deterministic candidate set spanning the quality range: the greedy
    seed, short netcost- and throughput-annealed chains from it, and seeded
    random placements."""
    greedy_row = ba.encode(dict(assignment.placements))
    netc = BatchAnnealer(ba, backend=backend).run(
        np.tile(greedy_row, (5, 1)), steps=60, seed=3
    )
    tpc = BatchAnnealer(ba, backend=backend).run(
        np.tile(greedy_row, (4, 1)), steps=60, seed=5,
        objective="throughput", tm=tm,
    )
    rng = np.random.Generator(np.random.Philox(0))
    alive = np.flatnonzero(ba.alive)
    rand = alive[rng.integers(0, alive.size, size=(n_random, ba.n_tasks))]
    return np.concatenate([greedy_row[None, :], netc, tpc, rand], axis=0)


def run_fidelity(smoke: bool = False) -> list:
    """Proxy-vs-simulator sweep: rank fidelity + end-to-end throughput."""
    backend = resolve_backend("auto")
    rows = []
    for name, maker in SUITE:
        if smoke and name not in SMOKE_SUITE:
            continue
        topo, cluster = maker(), _suite_cluster(name)
        arena = PlacementArena(cluster, topo)
        avail0 = arena.snapshot()
        seed_assignment = Assignment(topology_id=topo.id)
        get_scheduler("rstorm")._place_on_arena(arena, topo, seed_assignment)
        ba = BatchArena.from_arena(
            arena, topo, dict(seed_assignment.placements), avail0=avail0
        )
        tm = compile_throughput(ba, topo, cluster)
        P = _candidate_mix(ba, tm, seed_assignment, backend)
        (proxy, secs) = timed(
            lambda: throughput_batch(ba, tm, P, backend=backend), repeat=1
        )
        sim = Simulator(cluster)
        sim_tp = np.array(
            [
                sim.run(
                    topo, Assignment(topo.id, placements=ba.decode(P[b]))
                ).sink_throughput
                for b in range(P.shape[0])
            ]
        )
        rho = spearman(proxy, sim_tp)
        emit_csv_row(
            f"search/fidelity_{name}",
            secs * 1e6 / P.shape[0],
            f"spearman={rho:.3f};candidates={P.shape[0]};backend={backend}",
        )
        rows.append(("fidelity", name, rho))

        # End-to-end: the throughput-objective search vs the greedy seed,
        # both measured by the simulator.
        cluster.reset()
        sched = get_scheduler(
            "rstorm-search",
            n_chains=8 if smoke else 16,
            steps=100 if smoke else 600,
            seed=0,
            objective="throughput",
        )
        a, secs = timed(lambda: sched.schedule(topo, cluster, commit=False), repeat=1)
        cluster.reset()
        tp_s = sim.run(topo, a).sink_throughput
        tp_g = sim.run(
            topo,
            Assignment(topo.id, placements=dict(seed_assignment.placements)),
        ).sink_throughput
        gain = (tp_s / tp_g - 1.0) * 100.0 if tp_g > 0 else 0.0
        emit_csv_row(
            f"search/tp_{name}",
            secs * 1e6,
            f"sink_tp={tp_s:.1f};greedy_tp={tp_g:.1f};gain_pct={gain:+.2f};"
            f"never_worse={tp_s >= tp_g};backend={backend}",
        )
        rows.append(("tp", name, tp_s, tp_g))
    return rows


def run(smoke: bool = False) -> list:
    topo, cluster = flagship()
    backend = resolve_backend("auto")
    tasks, nodes = topo.task_count(), len(cluster.nodes)
    rows = []

    greedy, greedy_s = timed(
        lambda: get_scheduler("rstorm").schedule(topo, cluster, commit=False),
        repeat=1 if smoke else 2,
    )
    greedy_net = greedy.network_cost(topo, cluster)
    emit_csv_row(
        f"search/greedy_t{tasks}_n{nodes}",
        greedy_s * 1e6,
        f"netcost={greedy_net};backend={backend}",
    )

    # Raw batched-evaluator throughput on seeded random candidates.
    arena = PlacementArena(cluster, topo)
    avail0 = arena.snapshot()
    seed_assignment = Assignment(topology_id=topo.id)
    get_scheduler("rstorm")._place_on_arena(arena, topo, seed_assignment)
    ba = BatchArena.from_arena(
        arena, topo, dict(seed_assignment.placements), avail0=avail0
    )
    rng = np.random.Generator(np.random.Philox(0))
    alive = np.flatnonzero(ba.alive)
    for b in EVAL_BATCHES:
        P = alive[rng.integers(0, alive.size, size=(b, ba.n_tasks))]
        result, secs = timed(
            lambda: evaluate_batch(ba, P, backend=backend), repeat=1 if smoke else 2
        )
        emit_csv_row(
            f"search/eval_b{b}_t{tasks}",
            secs * 1e6,
            f"cand_per_s={b / max(secs, 1e-9):.0f};backend={backend};"
            f"feasible={int(result.feasible.sum())}",
        )
        rows.append(("eval", b, secs))

    # Amortized per-candidate cost of the fused scoring call (every
    # objective term, throughput included) per backend, at equal chunking.
    # Timing-only derived keys by design: jax/pallas rows exist only on
    # the jax leg, and the regression gate skips rows with no quality
    # metrics, so the nojax CI leg stays green.
    tm_flagship = compile_throughput(ba, topo, cluster)
    score_b = 1024 if smoke else 10240
    score_chunk = 1024
    P = alive[rng.integers(0, alive.size, size=(score_b, ba.n_tasks))]
    for be in ("numpy",) + (("jax", "pallas") if HAS_JAX else ()):
        _, secs = timed(
            lambda: evaluate_batch(
                ba, P, backend=be, chunk=score_chunk,
                throughput_model=tm_flagship,
            ),
            repeat=1 if smoke else 2,
        )
        extra = ""
        if be == "pallas":
            from repro.core.search.kernels import default_interpret

            extra = f";interpret={default_interpret()}"
        emit_csv_row(
            f"search/score_{be}_b{score_b}_t{tasks}",
            secs * 1e6,
            f"us_per_cand={secs * 1e6 / score_b:.2f};"
            f"cand_per_s={score_b / max(secs, 1e-9):.0f};backend={be}" + extra,
        )
        rows.append(("score", be, secs))

    # Multi-swap annealing: k fused proposals per scan launch must walk a
    # bit-identical chain (identical_to_k1, and netcost= is gated across
    # legs — the numpy fallback walks the same chain by construction).
    ms_steps = 64 if smoke else 1024
    ms_chains = 8 if smoke else 32
    P0 = np.tile(ba.encode(dict(seed_assignment.placements)), (ms_chains, 1))
    ref = None
    for k in (1, 8):
        ann = BatchAnnealer(ba, backend=backend)
        # Warm the scan compile cache first (the k-unrolled body traces in
        # O(k)); the row measures the steady-state step rate that fused
        # proposals exist to raise, not one-off trace time.
        ann.run(P0, ms_steps, seed=0, multi_swap=k)
        Pk, secs = timed(
            lambda: ann.run(P0, ms_steps, seed=0, multi_swap=k), repeat=1
        )
        if ref is None:
            ref = Pk
        net = float(evaluate_batch(ba, Pk, backend=backend).net.min())
        launches = ms_steps // k + ms_steps % k
        emit_csv_row(
            f"search/multiswap_k{k}_s{ms_steps}_t{tasks}",
            secs * 1e6,
            f"netcost={net};scan_launches={launches};"
            f"swaps_per_s={ms_steps * ms_chains / max(secs, 1e-9):.0f};"
            f"identical_to_k1={bool(np.array_equal(ref, Pk))};backend={backend}",
        )
        rows.append(("multiswap", k, net, secs))

    # chains × steps sweep of the full scheduler call.
    for n_chains, steps in SMOKE_SWEEP if smoke else SWEEP:
        sched = get_scheduler(
            "rstorm-search", n_chains=n_chains, steps=steps, seed=0
        )
        cluster.reset()
        a, secs = timed(
            lambda: sched.schedule(topo, cluster, commit=False), repeat=1
        )
        net = a.network_cost(topo, cluster)
        emit_csv_row(
            f"search/anneal_c{n_chains}_s{steps}_t{tasks}",
            secs * 1e6,
            f"netcost={net};improvement_pct={100.0 * (greedy_net - net) / greedy_net:.2f};"
            f"backend={backend};complete={a.is_complete(topo)}",
        )
        rows.append(("anneal", n_chains, steps, net, secs))

    # Sequential one-chain annealer at a comparable swap budget.
    seq_iters = 400 if smoke else 50_000
    seq = get_scheduler("rstorm_annealed", iters=seq_iters)
    cluster.reset()
    a, secs = timed(lambda: seq.schedule(topo, cluster, commit=False), repeat=1)
    net = a.network_cost(topo, cluster)
    emit_csv_row(
        f"search/sequential_i{seq_iters}_t{tasks}",
        secs * 1e6,
        f"netcost={net};improvement_pct={100.0 * (greedy_net - net) / greedy_net:.2f}",
    )
    rows.append(("sequential", seq_iters, net, secs))

    # Proxy fidelity + end-to-end throughput over the §6 suite.
    rows.extend(run_fidelity(smoke=smoke))

    # Flagship end-to-end: throughput objective on the 1000×256 case (the
    # chain topology is acked, so the ack term carries the ranking there).
    if not smoke:
        cluster.reset()
        sched = get_scheduler(
            "rstorm-search", n_chains=16, steps=2000, seed=0,
            objective="throughput",
        )
        a, secs = timed(lambda: sched.schedule(topo, cluster, commit=False), repeat=1)
        cluster.reset()
        sim = Simulator(cluster)
        tp_s = sim.run(topo, a).sink_throughput
        tp_g = sim.run(topo, greedy).sink_throughput
        emit_csv_row(
            f"search/tp_flagship_t{tasks}",
            secs * 1e6,
            f"sink_tp={tp_s:.1f};greedy_tp={tp_g:.1f};"
            f"gain_pct={(tp_s / tp_g - 1.0) * 100.0:+.2f};never_worse={tp_s >= tp_g}",
        )
        rows.append(("tp_flagship", tp_s, tp_g))
    return rows


if __name__ == "__main__":
    run()
