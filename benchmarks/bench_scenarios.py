"""Dynamic-scenario sweeps (§3 failure recovery + §6.5 multi-tenant churn),
each expressed as one declarative ``ScenarioSpec`` timeline and replayed
through ``ScenarioRunner`` for every registered scheduler:

* ``failover``     — submit PageLoad, kill two workers, rebalance; how much
  throughput survives the failure and comes back after re-placement;
* ``elastic``      — submit onto a too-small cluster (tasks stay unplaced),
  then join a fresh rack; elastic scale-up must land every task;
* ``multi_tenant`` — the paper's §6.5 experiment as a timeline: PageLoad and
  Processing share a 24-node cluster, then survive node churn.  The
  ``default_node_major`` row reproduces the paper's catastrophic outcome
  (memory over-subscription thrashes machines; Processing "grinded to a
  near halt") with the representative seeds from bench_multi_topology.
"""

from __future__ import annotations

from typing import Dict, Tuple

from repro.api import (
    ClusterSpec,
    NodeEntry,
    NodeFailEvent,
    NodeJoinEvent,
    RebalanceEvent,
    ScenarioRunner,
    ScenarioSpec,
    SchedulerSpec,
    SubmitEvent,
)
from repro.stream import topologies

from .bench_multi_topology import NODE_MAJOR_SEEDS
from .common import DEFAULT_MATRIX, EMULAB_12, EMULAB_24, emit_csv_row

#: The §6.5 sweep: the standard matrix plus the paper's collapse row.
MULTI_TENANT_MATRIX = DEFAULT_MATRIX + [
    (
        "default_node_major",
        "round_robin",
        {"seed": NODE_MAJOR_SEEDS[0], "slot_mode": "node_major"},
    ),
]


def _tp(entry, topo_id: str) -> float:
    return entry.topologies.get(topo_id, {}).get("sink_throughput", 0.0)


def failover_scenario(name: str, kwargs: dict) -> ScenarioSpec:
    return ScenarioSpec(
        name=f"failover_{name}",
        cluster=EMULAB_12,
        timeline=(
            SubmitEvent(
                topology=topologies.spec("pageload"),
                scheduler=SchedulerSpec(name, dict(kwargs)),
            ),
            NodeFailEvent(node_id="r0n0"),
            NodeFailEvent(node_id="r0n1"),
            RebalanceEvent(),
        ),
    )


def elastic_scenario(name: str, kwargs: dict) -> ScenarioSpec:
    # 3 x 2 GB nodes cannot hold PageLoad (~8.4 GB): tasks stay unplaced
    # until the fresh rack joins.
    return ScenarioSpec(
        name=f"elastic_{name}",
        cluster=ClusterSpec(racks=1, nodes_per_rack=3),
        timeline=(
            SubmitEvent(
                topology=topologies.spec("pageload"),
                scheduler=SchedulerSpec(name, dict(kwargs)),
            ),
            NodeJoinEvent(
                nodes=tuple(NodeEntry(f"fresh{i}", "rack_fresh") for i in range(4))
            ),
        ),
    )


def multi_tenant_scenario(name: str, kwargs: dict) -> ScenarioSpec:
    kw_pl, kw_pr = dict(kwargs), dict(kwargs)
    if "seed" in kw_pr:  # two independent pseudo-random placements (§6.5)
        seeds = (
            NODE_MAJOR_SEEDS if kw_pr.get("slot_mode") == "node_major" else (1, 7)
        )
        kw_pl["seed"], kw_pr["seed"] = seeds
    return ScenarioSpec(
        name=f"multi_tenant_{name}",
        cluster=EMULAB_24,
        timeline=(
            SubmitEvent(
                topology=topologies.spec("pageload"),
                scheduler=SchedulerSpec(name, kw_pl),
            ),
            SubmitEvent(
                topology=topologies.spec("processing"),
                scheduler=SchedulerSpec(name, kw_pr),
            ),
            NodeFailEvent(node_id="r0n0"),
            RebalanceEvent(),
        ),
    )


def run() -> Dict[str, object]:
    out: Dict[str, object] = {}

    for label, name, kwargs in DEFAULT_MATRIX:
        trace = ScenarioRunner(failover_scenario(name, kwargs)).run()
        out[f"failover/{label}"] = trace
        submit, fail2, rebal = trace.entries[0], trace.entries[2], trace.entries[3]
        orphans = sum(
            len(e.outcome.get("orphaned", ())) for e in trace.entries[1:3]
        )
        emit_csv_row(
            f"scenario_failover/{label}",
            0.0,
            f"tp_initial={_tp(submit, 'pageload'):.1f}tuples/s;"
            f"tp_degraded={_tp(fail2, 'pageload'):.1f};"
            f"tp_recovered={_tp(rebal, 'pageload'):.1f};"
            f"orphans={orphans};"
            f"moved={sum(len(v) for v in rebal.outcome.get('moved', {}).values())};"
            f"unplaced={sum(len(v) for v in rebal.unplaced.values())}",
        )

    for label, name, kwargs in DEFAULT_MATRIX:
        trace = ScenarioRunner(elastic_scenario(name, kwargs)).run()
        out[f"elastic/{label}"] = trace
        submit, join = trace.entries[0], trace.entries[1]
        emit_csv_row(
            f"scenario_elastic/{label}",
            0.0,
            f"unplaced_initial={sum(len(v) for v in submit.unplaced.values())};"
            f"unplaced_final={sum(len(v) for v in join.unplaced.values())};"
            f"tp_initial={_tp(submit, 'pageload'):.1f}tuples/s;"
            f"tp_final={_tp(join, 'pageload'):.1f}",
        )

    for label, name, kwargs in MULTI_TENANT_MATRIX:
        trace = ScenarioRunner(multi_tenant_scenario(name, kwargs)).run()
        out[f"multi_tenant/{label}"] = trace
        both, churned = trace.entries[1], trace.entries[3]
        emit_csv_row(
            f"scenario_multitenant/{label}",
            0.0,
            f"pageload={_tp(both, 'pageload'):.1f}tuples/s;"
            f"processing={_tp(both, 'processing'):.1f};"
            f"thrashed={len(both.topologies.get('processing', {}).get('thrashed_nodes', ()))};"
            f"after_churn_pageload={_tp(churned, 'pageload'):.1f};"
            f"after_churn_processing={_tp(churned, 'processing'):.1f}",
        )

    return out


if __name__ == "__main__":
    run()
