# One function per paper table. Print ``name,us_per_call,derived`` CSV.
from __future__ import annotations

import importlib
import sys
import traceback

BENCHES = [
    "benchmarks.bench_network_bound",    # Fig 8
    "benchmarks.bench_cpu_bound",        # Fig 9 + 10
    "benchmarks.bench_yahoo",            # Fig 12
    "benchmarks.bench_multi_topology",   # Fig 13
    "benchmarks.bench_scheduler_overhead",
    "benchmarks.bench_placement",        # mesh-placement quality (DESIGN §2.2)
    "benchmarks.bench_kernels",          # Pallas kernel oracles
]


def main() -> None:
    print("name,us_per_call,derived")
    failed = []
    for mod_name in BENCHES:
        try:
            mod = importlib.import_module(mod_name)
        except Exception:
            traceback.print_exc()
            failed.append(mod_name)
            continue
        try:
            mod.run()
        except Exception:
            traceback.print_exc()
            failed.append(mod_name)
    if failed:
        print(f"FAILED benches: {failed}", file=sys.stderr)
        sys.exit(1)


if __name__ == "__main__":
    main()
