# One function per paper table. Print ``name,us_per_call,derived`` CSV.
# ``--smoke`` runs the fast pure-Python subset (no jax/kernels, no seed
# scans) — what CI uses as a quick end-to-end pass over the control plane.
from __future__ import annotations

import importlib
import sys
import traceback

BENCHES = [
    "benchmarks.bench_network_bound",    # Fig 8
    "benchmarks.bench_cpu_bound",        # Fig 9 + 10
    "benchmarks.bench_yahoo",            # Fig 12
    "benchmarks.bench_multi_topology",   # Fig 13
    "benchmarks.bench_scenarios",        # §3/§6.5 dynamic scenario timelines
    "benchmarks.bench_scheduler_overhead",
    "benchmarks.bench_placement",        # mesh-placement quality (DESIGN §2.2)
    "benchmarks.bench_kernels",          # Pallas kernel oracles
]

SMOKE_BENCHES = [
    "benchmarks.bench_network_bound",
    "benchmarks.bench_yahoo",
    "benchmarks.bench_scenarios",   # failure/churn/scale-up timelines (~3 s)
]


def main() -> None:
    args = sys.argv[1:]
    smoke = "--smoke" in args
    unknown = [a for a in args if a != "--smoke"]
    if unknown:
        print(f"usage: python -m benchmarks.run [--smoke] (unknown: {unknown})", file=sys.stderr)
        sys.exit(2)
    print("name,us_per_call,derived")
    failed = []
    for mod_name in SMOKE_BENCHES if smoke else BENCHES:
        try:
            mod = importlib.import_module(mod_name)
        except Exception:
            traceback.print_exc()
            failed.append(mod_name)
            continue
        try:
            mod.run()
        except Exception:
            traceback.print_exc()
            failed.append(mod_name)
    if failed:
        print(f"FAILED benches: {failed}", file=sys.stderr)
        sys.exit(1)


if __name__ == "__main__":
    main()
