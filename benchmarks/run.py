# One function per paper table. Print ``name,us_per_call,derived`` CSV.
# ``--smoke`` runs the fast pure-Python subset (no jax/kernels, no seed
# scans) — what CI uses as a quick end-to-end pass over the control plane.
#
# Every run also writes a machine-readable artifact (BENCH_smoke.json /
# BENCH_full.json) with one record per emitted row, so the perf trajectory
# is tracked across PRs; CI uploads it as a build artifact.
from __future__ import annotations

import importlib
import inspect
import json
import sys
import traceback

from . import common

BENCHES = [
    "benchmarks.bench_network_bound",    # Fig 8
    "benchmarks.bench_cpu_bound",        # Fig 9 + 10
    "benchmarks.bench_yahoo",            # Fig 12
    "benchmarks.bench_multi_topology",   # Fig 13
    "benchmarks.bench_scenarios",        # §3/§6.5 dynamic scenario timelines
    "benchmarks.bench_rebalance",        # greedy vs search reconfiguration
    "benchmarks.bench_des",              # packet-level referee fidelity+scale
    "benchmarks.bench_scheduler_overhead",
    "benchmarks.bench_search",           # batched placement search vs greedy
    "benchmarks.bench_placement",        # mesh-placement quality (DESIGN §2.2)
    "benchmarks.bench_kernels",          # Pallas kernel oracles
]

SMOKE_BENCHES = [
    "benchmarks.bench_network_bound",
    "benchmarks.bench_yahoo",
    "benchmarks.bench_scenarios",   # failure/churn/scale-up timelines (~3 s)
    "benchmarks.bench_rebalance",   # greedy vs search reconfiguration
    "benchmarks.bench_des",         # DES fidelity vs solver (~2 s)
    "benchmarks.bench_search",      # tiny budget: 8 chains × 50 steps
]


def _invoke(mod, smoke: bool) -> None:
    """Call ``mod.run()``, passing ``smoke=`` to benches that take it."""
    if smoke and "smoke" in inspect.signature(mod.run).parameters:
        mod.run(smoke=True)
    else:
        mod.run()


def main() -> None:
    args = sys.argv[1:]
    smoke = "--smoke" in args
    unknown = [a for a in args if a != "--smoke"]
    if unknown:
        print(f"usage: python -m benchmarks.run [--smoke] (unknown: {unknown})", file=sys.stderr)
        sys.exit(2)
    print("name,us_per_call,derived")
    common.ROWS.clear()
    failed = []
    for mod_name in SMOKE_BENCHES if smoke else BENCHES:
        common.CURRENT_BENCH = mod_name.rsplit(".", 1)[-1]
        try:
            mod = importlib.import_module(mod_name)
        except Exception:
            traceback.print_exc()
            failed.append(mod_name)
            continue
        try:
            _invoke(mod, smoke)
        except Exception:
            traceback.print_exc()
            failed.append(mod_name)
    artifact = f"BENCH_{'smoke' if smoke else 'full'}.json"
    with open(artifact, "w") as fh:
        json.dump(
            {"mode": "smoke" if smoke else "full", "failed": failed, "rows": common.ROWS},
            fh,
            indent=2,
        )
        fh.write("\n")
    print(f"wrote {artifact} ({len(common.ROWS)} rows)", file=sys.stderr)
    if failed:
        print(f"FAILED benches: {failed}", file=sys.stderr)
        sys.exit(1)


if __name__ == "__main__":
    main()
