"""DES fidelity + scale benchmark — the packet-level referee's scorecard.

Fidelity rows: measured DES sink throughput vs the fixed-point solver's
prediction on the §6 micros and the Yahoo pipelines (``sink_tp`` is a pure
function of the fixed seed, so it is gated by the 20% regression check;
``solver_tp``/``relerr_pct`` are context columns).  The scale row reports
raw event-loop throughput (events simulated per wall-second), which is
machine-dependent and deliberately not gated.
"""

from __future__ import annotations

from repro.core import RStormScheduler, emulab_cluster
from repro.obs import MetricsHub
from repro.stream import DesConfig, DesExecutor, Simulator, topologies

from .common import emit_csv_row, timed

#: (row name, topology factory, DES horizon seconds).
FIDELITY_CASES = [
    ("linear_net", lambda: topologies.linear(True), 0.25),
    ("linear_cpu", lambda: topologies.linear(False), 0.5),
    ("diamond_net", lambda: topologies.diamond(True), 0.25),
    ("star_cpu", lambda: topologies.star(False), 0.5),
    ("pageload", lambda: topologies.pageload(), 0.5),
    ("processing", lambda: topologies.processing(), 0.5),
]

SMOKE_CASES = [
    ("linear_cpu", lambda: topologies.linear(False), 0.2),
    ("pageload", lambda: topologies.pageload(), 0.2),
    ("processing", lambda: topologies.processing(), 0.2),
]


def _place(topo):
    cl = emulab_cluster()
    a = RStormScheduler().schedule(topo, cl, commit=False)
    cl.reset()
    return cl, a


def run(smoke: bool = False) -> list:
    rows = []
    for name, maker, duration in SMOKE_CASES if smoke else FIDELITY_CASES:
        topo = maker()
        cl, a = _place(topo)
        sol = Simulator(cl).run(topo, a)
        ex = DesExecutor(cl, config=DesConfig(duration_s=duration))
        rep, wall = timed(ex.run, topo, a, repeat=1)
        relerr = (rep.sink_throughput / max(sol.sink_throughput, 1e-9) - 1.0) * 100.0
        emit_csv_row(
            f"des_fidelity/{name}",
            wall * 1e6,
            f"sink_tp={rep.sink_throughput:.1f}tuples/s;"
            f"solver_tp={sol.sink_throughput:.1f};relerr={relerr:+.1f}%;"
            f"p99_ms={rep.p99_latency_s * 1e3 if rep.p99_latency_s else 0.0:.2f};"
            f"events={rep.events_processed}",
        )
        rows.append((name, rep, sol))
    # Scale row: the busiest micro, reported as raw event throughput.
    topo = topologies.star(True)
    cl, a = _place(topo)
    ex = DesExecutor(
        cl, config=DesConfig(duration_s=0.05 if smoke else 0.2)
    )
    rep, wall = timed(ex.run, topo, a, repeat=1)
    emit_csv_row(
        "des_scale/star_net",
        wall * 1e6,
        f"events={rep.events_processed};"
        f"events_per_s={rep.events_processed / max(wall, 1e-9):.0f}",
    )
    rows.append(("scale", rep, None))
    # Instrumentation-overhead row: the same scale case re-run under an
    # enabled MetricsHub.  ``sink_tp`` is gated — telemetry is contractually
    # invisible to the physics, so it must match the bare run exactly;
    # ``events_per_s``/``overhead_pct`` are wall-clock context (not gated,
    # budget: instrumented stays within ~5% of bare).  The hub's JSONL goes
    # to OBS_bench_des.jsonl for the report-CLI smoke + CI artifact.
    hub = MetricsHub()
    ex = DesExecutor(cl, config=DesConfig(duration_s=0.05 if smoke else 0.2))

    def _run_instrumented():
        with hub.activate():
            return ex.run(topo, a)

    rep_obs, wall_obs = timed(_run_instrumented, repeat=1)
    hub.export("OBS_bench_des.jsonl")
    overhead = (wall_obs / max(wall, 1e-9) - 1.0) * 100.0
    emit_csv_row(
        "des_obs/star_net_instrumented",
        wall_obs * 1e6,
        f"sink_tp={rep_obs.sink_throughput:.1f}tuples/s;"
        f"events_per_s={rep_obs.events_processed / max(wall_obs, 1e-9):.0f};"
        f"overhead_pct={overhead:+.1f}%;"
        f"identical_to_bare={rep_obs.to_dict() == rep.to_dict()};"
        f"records={len(hub.records())}",
    )
    rows.append(("scale_obs", rep_obs, None))
    return rows


if __name__ == "__main__":
    run()
