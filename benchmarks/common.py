"""Shared helpers for the paper-figure benchmarks."""

from __future__ import annotations

import time
from typing import Callable, Dict, List, Tuple

from repro.core import (
    Cluster,
    RoundRobinScheduler,
    RStormScheduler,
    Scheduler,
    emulab_cluster,
)
from repro.stream import Simulator
from repro.core.topology import Topology


def schedule_and_simulate(
    topology: Topology,
    scheduler: Scheduler,
    cluster: Cluster,
):
    cluster.reset()
    assignment = scheduler.schedule(topology, cluster, commit=False)
    cluster.reset()
    sim = Simulator(cluster)
    return assignment, sim.run(topology, assignment)


def compare_schedulers(
    topology_factory: Callable[[], Topology],
    schedulers: List[Tuple[str, Scheduler]],
    cluster: Cluster | None = None,
) -> Dict[str, object]:
    cluster = cluster or emulab_cluster()
    out = {}
    for label, sched in schedulers:
        topo = topology_factory()
        _, res = schedule_and_simulate(topo, sched, cluster)
        out[label] = res
    return out


def timed(fn: Callable, *args, repeat: int = 3, **kwargs) -> Tuple[object, float]:
    """Run fn; return (result, best wall-time seconds)."""
    best = float("inf")
    result = None
    for _ in range(repeat):
        t0 = time.perf_counter()
        result = fn(*args, **kwargs)
        best = min(best, time.perf_counter() - t0)
    return result, best


def emit_csv_row(name: str, us_per_call: float, derived: str) -> None:
    print(f"{name},{us_per_call:.2f},{derived}")
