"""Shared helpers for the paper-figure benchmarks.

All benchmarks now go through the declarative control plane: a scheduler is
named by ``(label, registry_name, kwargs)`` rows (scenario-table style) and
each run is one ``SchedulingPayload`` planned via the ``Nimbus`` facade.
"""

from __future__ import annotations

import time
from typing import Callable, Dict, List, Tuple

from repro.api import (
    ClusterSpec,
    Nimbus,
    RunSettings,
    SchedulerSpec,
    SchedulingPayload,
    TopologySpec,
)
from repro.core.topology import Topology

#: (label, scheduler registry name, kwargs) — the default comparison matrix.
DEFAULT_MATRIX: List[Tuple[str, str, dict]] = [
    ("default", "round_robin", {"seed": 1}),
    ("rstorm", "rstorm", {}),
    ("rstorm_plus", "rstorm_plus", {}),
    ("rstorm_annealed", "rstorm_annealed", {"iters": 300}),
]

EMULAB_12 = ClusterSpec(preset="emulab_12")
EMULAB_24 = ClusterSpec(preset="emulab_24")


def payload_for(
    topology: Topology,
    scheduler_name: str,
    kwargs: dict | None = None,
    cluster: ClusterSpec = EMULAB_12,
    simulate: bool = True,
) -> SchedulingPayload:
    return SchedulingPayload(
        topology=TopologySpec.from_topology(topology),
        cluster=cluster,
        scheduler=SchedulerSpec(scheduler_name, dict(kwargs or {})),
        settings=RunSettings(simulate=simulate),
    )


def schedule_and_simulate(
    topology: Topology,
    scheduler_name: str,
    kwargs: dict | None = None,
    cluster: ClusterSpec = EMULAB_12,
):
    """Plan (dry-run) one payload and return (plan, plan.sim)."""
    plan = Nimbus().plan(payload_for(topology, scheduler_name, kwargs, cluster))
    return plan, plan.sim


def compare_schedulers(
    topology_factory: Callable[[], Topology],
    schedulers: List[Tuple[str, str, dict]] | None = None,
    cluster: ClusterSpec = EMULAB_12,
) -> Dict[str, object]:
    """Run the scheduler matrix over one topology; label -> SimResult."""
    out = {}
    for label, name, kwargs in schedulers or DEFAULT_MATRIX:
        _, res = schedule_and_simulate(topology_factory(), name, kwargs, cluster)
        out[label] = res
    return out


#: Machine-readable sink for every emitted row: ``benchmarks.run`` resets
#: this, stamps ``bench`` per module, and writes it out as BENCH_*.json so
#: the perf trajectory is tracked across PRs (CI uploads the artifact).
ROWS: List[Dict[str, object]] = []
CURRENT_BENCH: str = ""


def spearman(x, y) -> float:
    """Spearman rank correlation with average ranks for ties (no scipy in
    the container) — the proxy-fidelity statistic ``bench_search`` reports."""
    import numpy as np

    def rank(v):
        v = np.asarray(v, dtype=np.float64)
        order = np.argsort(v, kind="stable")
        r = np.empty(len(v))
        r[order] = np.arange(len(v), dtype=np.float64)
        out = r.copy()
        for val in np.unique(v):
            m = v == val
            out[m] = r[m].mean()
        return out

    rx, ry = rank(x), rank(y)
    rx -= rx.mean()
    ry -= ry.mean()
    denom = float(np.sqrt((rx**2).sum() * (ry**2).sum()))
    # A constant input carries zero ranking information — report nan (the
    # regression gate then flags the metric as missing) rather than a
    # vacuous 1.0 that would mask total fidelity collapse.
    return float((rx * ry).sum() / denom) if denom > 0 else float("nan")


def timed(fn: Callable, *args, repeat: int = 3, **kwargs) -> Tuple[object, float]:
    """Run fn; return (result, best wall-time seconds)."""
    best = float("inf")
    result = None
    for _ in range(repeat):
        t0 = time.perf_counter()
        result = fn(*args, **kwargs)
        best = min(best, time.perf_counter() - t0)
    return result, best


def emit_csv_row(name: str, us_per_call: float, derived: str) -> None:
    print(f"{name},{us_per_call:.2f},{derived}")
    ROWS.append(
        {
            "bench": CURRENT_BENCH,
            "name": name,
            "us_per_call": round(float(us_per_call), 2),
            "derived": derived,
        }
    )
