"""Grouped expert GEMM Pallas kernel (MoE dispatch buffers).

Tiled (bc, bf) output blocks per expert with a sequential contraction
dimension accumulated in VMEM scratch; expert index is an outer parallel
grid dimension, so each expert's tiles stream through the MXU back-to-back
(MegaBlocks-style grouped GEMM, adapted to TPU tiling).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _gg_kernel(x_ref, w_ref, o_ref, acc_scr, *, n_d: int):
    di = pl.program_id(3)

    @pl.when(di == 0)
    def _init():
        acc_scr[...] = jnp.zeros_like(acc_scr)

    x = x_ref[0].astype(jnp.float32)   # (bc, bd)
    w = w_ref[0].astype(jnp.float32)   # (bd, bf)
    acc_scr[...] += jax.lax.dot_general(
        x, w, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )

    @pl.when(di == n_d - 1)
    def _fin():
        o_ref[0] = acc_scr[...].astype(o_ref.dtype)


def grouped_gemm(x, w, *, block_c: int = 128, block_f: int = 128,
                 block_d: int = 256, interpret: bool = False):
    """x (E,C,D) @ w (E,D,F) -> (E,C,F), expert-wise."""
    E, C, D = x.shape
    F = w.shape[2]
    bc, bf, bd = min(block_c, C), min(block_f, F), min(block_d, D)
    assert C % bc == 0 and F % bf == 0 and D % bd == 0
    grid = (E, C // bc, F // bf, D // bd)
    kernel = functools.partial(_gg_kernel, n_d=D // bd)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bc, bd), lambda e, i, j, d: (e, i, d)),
            pl.BlockSpec((1, bd, bf), lambda e, i, j, d: (e, d, j)),
        ],
        out_specs=pl.BlockSpec((1, bc, bf), lambda e, i, j, d: (e, i, j)),
        out_shape=jax.ShapeDtypeStruct((E, C, F), x.dtype),
        scratch_shapes=[pltpu.VMEM((bc, bf), jnp.float32)],
        interpret=interpret,
    )(x, w)
