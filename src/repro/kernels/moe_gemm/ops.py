"""Jitted wrapper for the grouped expert GEMM."""

from __future__ import annotations

import functools

import jax

from .grouped_gemm import grouped_gemm


@functools.partial(
    jax.jit, static_argnames=("block_c", "block_f", "block_d", "interpret")
)
def grouped_gemm_op(x, w, *, block_c: int = 128, block_f: int = 128,
                    block_d: int = 256, interpret: bool = False):
    return grouped_gemm(
        x, w, block_c=block_c, block_f=block_f, block_d=block_d, interpret=interpret
    )
