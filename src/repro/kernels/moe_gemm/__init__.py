from .grouped_gemm import grouped_gemm
from .ops import grouped_gemm_op
from .ref import grouped_gemm_ref

__all__ = ["grouped_gemm", "grouped_gemm_op", "grouped_gemm_ref"]
