"""Oracle for the grouped expert GEMM: (E,C,D) x (E,D,F) -> (E,C,F)."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def grouped_gemm_ref(x: jax.Array, w: jax.Array) -> jax.Array:
    return jnp.einsum(
        "ecd,edf->ecf", x.astype(jnp.float32), w.astype(jnp.float32)
    ).astype(x.dtype)
