"""RG-LRU linear-recurrence Pallas kernel.

TPU adaptation (DESIGN.md §2.3): the per-channel gated recurrence
h_t = a_t*h_{t-1} + x_t has no MXU form (the gate is diagonal), so within
each VMEM time-block the kernel runs a log-depth doubling scan on the VPU
(log2(T_blk) shifted multiply-adds over the whole (T_blk, D) tile), and time
blocks are chained through a VMEM carry on the sequential grid dimension —
HBM traffic is exactly one read of (a,x) and one write of h.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_BLOCK_T = 256


def _rglru_kernel(a_ref, x_ref, h0_ref, o_ref, carry_scr, *, blk_t: int):
    ti = pl.program_id(1)

    @pl.when(ti == 0)
    def _init():
        carry_scr[...] = h0_ref[...].astype(jnp.float32)

    a = a_ref[0].astype(jnp.float32)      # (blk_t, D)
    x = x_ref[0].astype(jnp.float32)
    # In-block inclusive scan by doubling: after k rounds, h_t aggregates
    # inputs from t-2^k+1..t and prod_t the gate product over that span.
    h = x
    prod = a
    step = 1
    while step < blk_t:
        h_shift = jnp.pad(h, ((step, 0), (0, 0)))[:blk_t]
        p_shift = jnp.pad(prod, ((step, 0), (0, 0)), constant_values=1.0)[:blk_t]
        h = h + prod * h_shift
        prod = prod * p_shift
        step *= 2
    # Chain the carry from previous blocks.
    h = h + prod * carry_scr[...]          # carry (1, D) broadcasts over time
    o_ref[0] = h.astype(o_ref.dtype)
    carry_scr[...] = h[-1:]


def rglru_scan(a, x, h0, *, block_t: int = DEFAULT_BLOCK_T, interpret: bool = False):
    """a, x: (B, S, D); h0 (B, D) -> h (B, S, D)."""
    B, S, D = x.shape
    blk_t = min(block_t, S)
    assert S % blk_t == 0
    n_t = S // blk_t
    kernel = functools.partial(_rglru_kernel, blk_t=blk_t)
    return pl.pallas_call(
        kernel,
        grid=(B, n_t),
        in_specs=[
            pl.BlockSpec((1, blk_t, D), lambda b, t: (b, t, 0)),
            pl.BlockSpec((1, blk_t, D), lambda b, t: (b, t, 0)),
            pl.BlockSpec((1, D), lambda b, t: (b, 0)),
        ],
        out_specs=pl.BlockSpec((1, blk_t, D), lambda b, t: (b, t, 0)),
        out_shape=jax.ShapeDtypeStruct((B, S, D), x.dtype),
        scratch_shapes=[pltpu.VMEM((1, D), jnp.float32)],
        interpret=interpret,
    )(a, x, h0)
