"""Jitted wrapper for the RG-LRU scan kernel."""

from __future__ import annotations

import functools

import jax

from .rglru_scan import rglru_scan


@functools.partial(jax.jit, static_argnames=("block_t", "interpret"))
def rglru_scan_op(a, x, h0, *, block_t: int = 256, interpret: bool = False):
    return rglru_scan(a, x, h0, block_t=block_t, interpret=interpret)
