"""Oracle for the RG-LRU diagonal linear recurrence:
h_t = a_t * h_{t-1} + x_t,   a in (0,1), per-channel.

Inputs a, x: (B, S, D); initial state h0 (B, D).  Output h (B, S, D).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def rglru_scan_ref(a: jax.Array, x: jax.Array, h0: jax.Array) -> jax.Array:
    def body(h, inp):
        a_t, x_t = inp
        h = a_t * h + x_t
        return h, h

    _, hs = jax.lax.scan(
        body,
        h0.astype(jnp.float32),
        (a.astype(jnp.float32).transpose(1, 0, 2), x.astype(jnp.float32).transpose(1, 0, 2)),
    )
    return hs.transpose(1, 0, 2).astype(x.dtype)
