from .ops import rglru_scan_op
from .ref import rglru_scan_ref
from .rglru_scan import rglru_scan

__all__ = ["rglru_scan", "rglru_scan_op", "rglru_scan_ref"]
