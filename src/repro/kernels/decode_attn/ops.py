"""Jitted wrapper for decode attention (model layout)."""

from __future__ import annotations

import functools

import jax

from .decode_attention import decode_attention


@functools.partial(jax.jit, static_argnames=("block_k", "interpret"))
def decode_attention_op(q, k_cache, v_cache, length, *, block_k: int = 512,
                        interpret: bool = False):
    """q (B,1,H,hd); caches (B,S,Kv,hd); length scalar."""
    qt = q[:, 0].transpose(0, 1, 2) if q.ndim == 4 else q
    qt = q[:, 0]                       # (B,H,hd)
    kt = k_cache.transpose(0, 2, 1, 3)  # (B,Kv,S,hd)
    vt = v_cache.transpose(0, 2, 1, 3)
    out = decode_attention(qt, kt, vt, length, block_k=block_k, interpret=interpret)
    return out[:, None]                # (B,1,H,hd)
