from .decode_attention import decode_attention
from .ops import decode_attention_op
from .ref import decode_attention_ref

__all__ = ["decode_attention", "decode_attention_op", "decode_attention_ref"]
