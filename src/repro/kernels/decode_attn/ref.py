"""Oracle for single-token decode attention over a KV cache.

q (B, H, hd); cache k/v (B, Kv, S, hd); valid length `length` (attend to
positions < length).  Output (B, H, hd).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def decode_attention_ref(q, k, v, length) -> jax.Array:
    B, H, hd = q.shape
    Kv, S = k.shape[1], k.shape[2]
    G = H // Kv
    qg = q.reshape(B, Kv, G, hd).astype(jnp.float32)
    s = jnp.einsum("bkgh,bksh->bkgs", qg, k.astype(jnp.float32)) / jnp.sqrt(
        jnp.array(hd, jnp.float32)
    )
    mask = jnp.arange(S)[None] < length
    s = jnp.where(mask[:, None, None, :], s, NEG_INF)
    w = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bkgs,bksh->bkgh", w, v.astype(jnp.float32))
    return out.reshape(B, H, hd).astype(q.dtype)
