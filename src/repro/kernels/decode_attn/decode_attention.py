"""Flash-decoding Pallas kernel: one query token vs a long KV cache.

The KV sequence is tiled into VMEM blocks iterated on the innermost
(sequential) grid dimension with online-softmax accumulators in scratch —
the TPU analogue of GPU split-K flash decoding (partials per K-split merged
by rescaling; here the merge happens in-order in scratch, which on TPU keeps
the MXU busy without a separate reduction kernel).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30
DEFAULT_BLOCK_K = 512


def _decode_kernel(len_ref, q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr,
                   *, scale: float, blk_k: int, n_k: int):
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    length = len_ref[0]
    k_start = ki * blk_k

    @pl.when(k_start < length)
    def _body():
        q = q_ref[0, 0].astype(jnp.float32)          # (1, hd) row
        k = k_ref[0, 0].astype(jnp.float32)          # (blk_k, hd)
        v = v_ref[0, 0].astype(jnp.float32)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
        ) * scale                                     # (1, blk_k)
        j = k_start + jax.lax.broadcasted_iota(jnp.int32, (1, blk_k), 1)
        s = jnp.where(j < length, s, NEG_INF)
        m_prev = m_scr[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
        p = jnp.exp(s - m_new)
        alpha = jnp.exp(m_prev - m_new)
        l_scr[...] = l_scr[...] * alpha + jnp.sum(p, axis=1, keepdims=True)
        acc_scr[...] = acc_scr[...] * alpha + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
        )
        m_scr[...] = m_new

    @pl.when(ki == n_k - 1)
    def _fin():
        o_ref[0, 0] = (acc_scr[...] / jnp.maximum(l_scr[...], 1e-30)).astype(o_ref.dtype)


def decode_attention(q, k, v, length, *, block_k: int = DEFAULT_BLOCK_K,
                     interpret: bool = False) -> jax.Array:
    """q (B,H,hd); k/v (B,Kv,S,hd); length scalar int32."""
    B, H, hd = q.shape
    Kv, S = k.shape[1], k.shape[2]
    G = H // Kv
    blk_k = min(block_k, S)
    assert S % blk_k == 0
    n_k = S // blk_k
    q4 = q[:, :, None, :]  # (B,H,1,hd)
    length = jnp.asarray(length, jnp.int32).reshape(1)
    kernel = functools.partial(
        _decode_kernel, scale=1.0 / (hd ** 0.5), blk_k=blk_k, n_k=n_k
    )
    out = pl.pallas_call(
        kernel,
        grid=(B, H, n_k),
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.SMEM),
            pl.BlockSpec((1, 1, 1, hd), lambda b, h, j: (b, h, 0, 0)),
            pl.BlockSpec((1, 1, blk_k, hd), lambda b, h, j: (b, h // G, j, 0)),
            pl.BlockSpec((1, 1, blk_k, hd), lambda b, h, j: (b, h // G, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, 1, hd), lambda b, h, j: (b, h, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((B, H, 1, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((1, 1), jnp.float32),
            pltpu.VMEM((1, 1), jnp.float32),
            pltpu.VMEM((1, hd), jnp.float32),
        ],
        interpret=interpret,
    )(length, q4, k, v)
    return out[:, :, 0, :]
