"""Jitted wrapper for the chunkwise mLSTM kernel."""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from .mlstm_chunk import mlstm_chunk


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def mlstm_chunk_op(q, k, v, log_i, log_f, *, chunk: int = 128, interpret: bool = False):
    """k is scaled by 1/sqrt(hd) here (matching the model convention)."""
    hd = q.shape[-1]
    k = k / jnp.sqrt(jnp.array(hd, k.dtype))
    return mlstm_chunk(q, k, v, log_i, log_f, chunk=chunk, interpret=interpret)
