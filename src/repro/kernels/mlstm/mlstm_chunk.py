"""Chunkwise mLSTM Pallas kernel (xLSTM matrix memory).

TPU adaptation (DESIGN.md §2.3): within a VMEM chunk the (L,L) decay-gated
score matrix and the (L,hd) outputs are MXU matmuls; the running matrix
memory C (hd,hd), normalizer n (hd,) and stabilizer m (scalar) live in VMEM
scratch across the sequential chunk dimension.  This is the mLSTM analogue
of flash attention's online accumulation.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_CHUNK = 128
_NEG = -1e30


def _mlstm_kernel(q_ref, k_ref, v_ref, li_ref, lf_ref, o_ref,
                  c_scr, n_scr, m_scr, *, L: int):
    ci = pl.program_id(2)

    @pl.when(ci == 0)
    def _init():
        c_scr[...] = jnp.zeros_like(c_scr)
        n_scr[...] = jnp.zeros_like(n_scr)
        m_scr[...] = jnp.full_like(m_scr, _NEG)

    q = q_ref[0, 0].astype(jnp.float32)            # (L, hd)
    k = k_ref[0, 0].astype(jnp.float32)
    v = v_ref[0, 0].astype(jnp.float32)
    li = li_ref[0, 0, :, 0].astype(jnp.float32)    # (L,)
    lf = lf_ref[0, 0, :, 0].astype(jnp.float32)

    b = jnp.cumsum(lf)                             # (L,) within-chunk cum log f
    total = b[-1]
    m_prev = m_scr[0, 0]
    m_inter = m_prev + b                           # (L,)
    dmat = b[:, None] - b[None, :] + li[None, :]   # (L, L)
    tri = jax.lax.broadcasted_iota(jnp.int32, (L, L), 0) >= jax.lax.broadcasted_iota(
        jnp.int32, (L, L), 1
    )
    dmat = jnp.where(tri, dmat, _NEG)
    m_intra = jnp.max(dmat, axis=1)                # (L,)
    m_new = jnp.maximum(m_inter, m_intra)          # (L,)
    w_intra = jnp.exp(dmat - m_new[:, None])
    scale_inter = jnp.exp(m_inter - m_new)         # (L,)

    scores = jax.lax.dot_general(
        q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    ) * w_intra                                    # (L, L)
    num = jax.lax.dot_general(
        scores, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
    ) + scale_inter[:, None] * jax.lax.dot_general(
        q, c_scr[...], (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )
    den = jnp.sum(scores, axis=1) + scale_inter * jnp.sum(q * n_scr[...], axis=1)
    h = num / jnp.maximum(jnp.abs(den), jnp.exp(-m_new))[:, None]
    o_ref[0, 0] = h.astype(o_ref.dtype)

    # State to end of chunk.
    m_state_intra = jnp.max(total - b + li)
    m_next = jnp.maximum(m_prev + total, m_state_intra)
    decay_old = jnp.exp(m_prev + total - m_next)
    w_state = jnp.exp(total - b + li - m_next)     # (L,)
    c_scr[...] = decay_old * c_scr[...] + jax.lax.dot_general(
        k * w_state[:, None], v, (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )
    n_scr[...] = decay_old * n_scr[...] + jnp.sum(k * w_state[:, None], axis=0)[None, :]
    m_scr[...] = jnp.full_like(m_scr, m_next)


def mlstm_chunk(q, k, v, log_i, log_f, *, chunk: int = DEFAULT_CHUNK,
                interpret: bool = False):
    """q,k,v (B,H,S,hd) (k pre-scaled); log_i/log_f (B,H,S) -> h (B,H,S,hd)."""
    B, H, S, hd = q.shape
    L = min(chunk, S)
    assert S % L == 0
    n_c = S // L
    li4 = log_i[..., None]
    lf4 = log_f[..., None]
    kernel = functools.partial(_mlstm_kernel, L=L)
    return pl.pallas_call(
        kernel,
        grid=(B, H, n_c),
        in_specs=[
            pl.BlockSpec((1, 1, L, hd), lambda b, h, c: (b, h, c, 0)),
            pl.BlockSpec((1, 1, L, hd), lambda b, h, c: (b, h, c, 0)),
            pl.BlockSpec((1, 1, L, hd), lambda b, h, c: (b, h, c, 0)),
            pl.BlockSpec((1, 1, L, 1), lambda b, h, c: (b, h, c, 0)),
            pl.BlockSpec((1, 1, L, 1), lambda b, h, c: (b, h, c, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, L, hd), lambda b, h, c: (b, h, c, 0)),
        out_shape=jax.ShapeDtypeStruct((B, H, S, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((hd, hd), jnp.float32),
            pltpu.VMEM((1, hd), jnp.float32),
            pltpu.VMEM((1, 1), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v, li4, lf4)
