"""Oracle for the mLSTM: strictly-sequential per-token recurrence (a
different algorithm from the kernel's chunkwise form — a genuine oracle).

Inputs: q,k,v (B,H,S,hd) (k pre-scaled by 1/sqrt(hd)); log_i, log_f (B,H,S).
Output h (B,H,S,hd).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def mlstm_ref(q, k, v, log_i, log_f):
    B, H, S, hd = q.shape
    qf = q.astype(jnp.float32)
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)
    li = log_i.astype(jnp.float32)
    lf = log_f.astype(jnp.float32)

    def body(carry, t):
        C, n, m = carry
        m_new = jnp.maximum(lf[:, :, t] + m, li[:, :, t])
        fw = jnp.exp(lf[:, :, t] + m - m_new)
        iw = jnp.exp(li[:, :, t] - m_new)
        C = fw[..., None, None] * C + iw[..., None, None] * jnp.einsum(
            "bhd,bhe->bhde", kf[:, :, t], vf[:, :, t]
        )
        n = fw[..., None] * n + iw[..., None] * kf[:, :, t]
        num = jnp.einsum("bhd,bhde->bhe", qf[:, :, t], C)
        den = jnp.einsum("bhd,bhd->bh", qf[:, :, t], n)
        h = num / jnp.maximum(jnp.abs(den), jnp.exp(-m_new))[..., None]
        return (C, n, m_new), h

    C0 = jnp.zeros((B, H, hd, hd), jnp.float32)
    n0 = jnp.zeros((B, H, hd), jnp.float32)
    m0 = jnp.full((B, H), -1e30, jnp.float32)
    _, hs = jax.lax.scan(body, (C0, n0, m0), jnp.arange(S))
    return hs.transpose(1, 2, 0, 3).astype(q.dtype)
