from .mlstm_chunk import mlstm_chunk
from .ops import mlstm_chunk_op
from .ref import mlstm_ref

__all__ = ["mlstm_chunk", "mlstm_chunk_op", "mlstm_ref"]
