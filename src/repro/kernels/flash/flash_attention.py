"""Flash attention Pallas-TPU kernel: online-softmax tiling with explicit
BlockSpec VMEM blocks, causal + sliding-window masking, GQA via kv-head
index mapping.

TPU adaptation (DESIGN.md §2.3): block shapes are MXU-aligned (q/k blocks a
multiple of 128 on the sequence dims, head_dim padded to 128 by the caller
when needed); the k-loop is the innermost *sequential* grid dimension with
f32 accumulators held in VMEM scratch across iterations — the TPU-native
reformulation of the GPU warp-level flash loop.
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = -1e30
DEFAULT_BLOCK_Q = 128
DEFAULT_BLOCK_K = 128


def _flash_kernel(
    q_ref, k_ref, v_ref,          # VMEM blocks
    o_ref,                        # output block
    m_scr, l_scr, acc_scr,        # scratch: (blk_q,1), (blk_q,1), (blk_q,hd)
    *,
    scale: float,
    blk_q: int,
    blk_k: int,
    n_k: int,
    causal: bool,
    window: Optional[int],
):
    qi = pl.program_id(2)
    ki = pl.program_id(3)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q_start = qi * blk_q
    k_start = ki * blk_k

    # Block-level relevance (causal: k block must not be entirely in the
    # future; windowed: nor entirely older than the window).
    relevant = True
    if causal:
        relevant = k_start <= q_start + blk_q - 1
        if window is not None:
            relevant = jnp.logical_and(
                relevant, k_start + blk_k - 1 > q_start - window
            )

    @pl.when(relevant)
    def _body():
        q = q_ref[0, 0].astype(jnp.float32)          # (blk_q, hd)
        k = k_ref[0, 0].astype(jnp.float32)          # (blk_k, hd)
        v = v_ref[0, 0].astype(jnp.float32)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
        ) * scale                                     # (blk_q, blk_k)
        if causal:
            i = q_start + jax.lax.broadcasted_iota(jnp.int32, (blk_q, blk_k), 0)
            j = k_start + jax.lax.broadcasted_iota(jnp.int32, (blk_q, blk_k), 1)
            mask = j <= i
            if window is not None:
                mask = jnp.logical_and(mask, j > i - window)
            s = jnp.where(mask, s, NEG_INF)
        m_prev = m_scr[...]                           # (blk_q, 1)
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
        p = jnp.exp(s - m_new)                        # (blk_q, blk_k)
        alpha = jnp.exp(m_prev - m_new)               # (blk_q, 1)
        l_scr[...] = l_scr[...] * alpha + jnp.sum(p, axis=1, keepdims=True)
        acc_scr[...] = acc_scr[...] * alpha + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
        )
        m_scr[...] = m_new

    @pl.when(ki == n_k - 1)
    def _fin():
        l = jnp.maximum(l_scr[...], 1e-30)
        o_ref[0, 0] = (acc_scr[...] / l).astype(o_ref.dtype)


def flash_attention(
    q: jax.Array,                  # (B, H, Sq, hd)
    k: jax.Array,                  # (B, Kv, Sk, hd)
    v: jax.Array,
    *,
    causal: bool = True,
    window: Optional[int] = None,
    block_q: int = DEFAULT_BLOCK_Q,
    block_k: int = DEFAULT_BLOCK_K,
    interpret: bool = False,
) -> jax.Array:
    B, H, Sq, hd = q.shape
    Kv, Sk = k.shape[1], k.shape[2]
    assert H % Kv == 0, (H, Kv)
    G = H // Kv
    blk_q = min(block_q, Sq)
    blk_k = min(block_k, Sk)
    assert Sq % blk_q == 0 and Sk % blk_k == 0, (Sq, blk_q, Sk, blk_k)
    n_q, n_k = Sq // blk_q, Sk // blk_k
    scale = 1.0 / (hd ** 0.5)

    kernel = functools.partial(
        _flash_kernel,
        scale=scale,
        blk_q=blk_q,
        blk_k=blk_k,
        n_k=n_k,
        causal=causal,
        window=window,
    )
    grid = (B, H, n_q, n_k)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, blk_q, hd), lambda b, h, i, j: (b, h, i, 0)),
            pl.BlockSpec((1, 1, blk_k, hd), lambda b, h, i, j: (b, h // G, j, 0)),
            pl.BlockSpec((1, 1, blk_k, hd), lambda b, h, i, j: (b, h // G, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, blk_q, hd), lambda b, h, i, j: (b, h, i, 0)),
        out_shape=jax.ShapeDtypeStruct((B, H, Sq, hd), q.dtype),
        scratch_shapes=[
            _vmem((blk_q, 1)),
            _vmem((blk_q, 1)),
            _vmem((blk_q, hd)),
        ],
        interpret=interpret,
    )(q, k, v)


def _vmem(shape):
    from jax.experimental.pallas import tpu as pltpu

    return pltpu.VMEM(shape, jnp.float32)
