"""Jitted wrapper: model layout (B,S,H,hd) <-> kernel layout (B,H,S,hd)."""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from .flash_attention import flash_attention


@functools.partial(
    jax.jit, static_argnames=("causal", "window", "block_q", "block_k", "interpret")
)
def flash_attention_op(
    q: jax.Array,                  # (B, S, H, hd) — model layout
    k: jax.Array,                  # (B, S, Kv, hd)
    v: jax.Array,
    *,
    causal: bool = True,
    window: Optional[int] = None,
    block_q: int = 128,
    block_k: int = 128,
    interpret: bool = False,
) -> jax.Array:
    qt = q.transpose(0, 2, 1, 3)
    kt = k.transpose(0, 2, 1, 3)
    vt = v.transpose(0, 2, 1, 3)
    out = flash_attention(
        qt, kt, vt,
        causal=causal, window=window,
        block_q=block_q, block_k=block_k, interpret=interpret,
    )
    return out.transpose(0, 2, 1, 3)
