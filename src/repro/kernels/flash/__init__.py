from .flash_attention import flash_attention
from .ops import flash_attention_op
from .ref import attention_ref

__all__ = ["flash_attention", "flash_attention_op", "attention_ref"]
