"""Pure-jnp oracle for flash attention (causal / sliding-window / bidir GQA).

Layout convention for the kernels package: q (B, H, S, hd); k, v
(B, Kv, S, hd); output (B, H, S, hd).
"""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def attention_ref(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    *,
    causal: bool = True,
    window: Optional[int] = None,
) -> jax.Array:
    B, H, Sq, hd = q.shape
    Kv, Sk = k.shape[1], k.shape[2]
    G = H // Kv
    qg = q.reshape(B, Kv, G, Sq, hd).astype(jnp.float32)
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)
    scores = jnp.einsum("bkgqh,bksh->bkgqs", qg, kf) / jnp.sqrt(
        jnp.array(hd, jnp.float32)
    )
    if causal:
        i = jnp.arange(Sq)[:, None]
        j = jnp.arange(Sk)[None, :]
        m = j <= i
        if window is not None:
            m = m & (j > i - window)
        scores = jnp.where(m[None, None, None], scores, NEG_INF)
    w = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bkgqs,bksh->bkgqh", w, vf)
    return out.reshape(B, H, Sq, hd).astype(q.dtype)
