# Pallas TPU kernels for the perf-critical compute layers, each with an
# ops.py jit wrapper and a ref.py pure-jnp oracle (validated interpret=True):
#   flash/       — causal/sliding-window GQA flash attention
#   decode_attn/ — flash-decoding (single token vs long KV cache)
#   rglru/       — RG-LRU diagonal linear recurrence (doubling scan)
#   mlstm/       — chunkwise mLSTM (matrix memory)
#   moe_gemm/    — grouped expert GEMM (MoE dispatch buffers)
from . import decode_attn, flash, mlstm, moe_gemm, rglru

__all__ = ["decode_attn", "flash", "mlstm", "moe_gemm", "rglru"]
