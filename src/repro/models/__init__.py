from .lm import Model
from .registry import build, build_from_config, cell_skip_reason, extend_cache, input_specs

__all__ = [
    "Model",
    "build",
    "build_from_config",
    "cell_skip_reason",
    "extend_cache",
    "input_specs",
]
