"""The language-model assembly: embeddings → scanned layer groups → head.

Layers are stacked per *pattern position* and iterated with ``jax.lax.scan``
(MaxText-style), so the HLO contains each distinct block kind once regardless
of depth — essential for fast multi-pod lowering.  Patterns that do not
divide n_layers get an explicit unscanned tail.

Supports: decoder-only LMs (dense/MoE/SSM/hybrid), a vision-prefix variant
(phi-3-vision: precomputed patch embeddings prepended), and encoder-decoder
(whisper: precomputed mel-frame embeddings through a bidirectional encoder,
causal decoder with per-layer cross-attention).
"""

from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig
from . import attention as attn_mod
from . import blocks
from ..placement.constraints import maybe_constrain
from .common import (
    ParamSpec,
    axes_from_spec,
    cross_entropy,
    dtype_of,
    init_from_spec,
    maybe_unrolled_scan,
    rms_norm,
    stack_spec,
)


def _remat(cfg: ModelConfig, fn):
    if cfg.remat == "none":
        return fn
    if cfg.remat == "dots":
        return jax.checkpoint(
            fn, policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable
        )
    return jax.checkpoint(fn)  # "full": save nothing, recompute in backward


class Model:
    """Functional model bound to a ModelConfig.  Params are plain pytrees."""

    def __init__(self, cfg: ModelConfig):
        self.cfg = cfg
        self.dtype = dtype_of(cfg.dtype)
        self.pdtype = dtype_of(cfg.param_dtype)
        P = len(cfg.pattern)
        self.n_groups = cfg.n_layers // P
        self.tail_kinds = cfg.layer_kinds()[self.n_groups * P :]

    # -- parameter construction ----------------------------------------------------
    def _group_specs(self) -> Dict[str, ParamSpec]:
        cfg = self.cfg
        out = {}
        for i, kind in enumerate(cfg.pattern):
            out[f"blk{i}_{kind}"] = blocks.block_spec(cfg, kind, cross=cfg.enc_dec)
        return out

    def param_specs(self) -> Dict[str, Any]:
        cfg = self.cfg
        D, V = cfg.d_model, cfg.vocab
        specs: Dict[str, Any] = {
            "embed": {"table": ((V, D), ("vocab", "embed"), "normal")},
            "final_norm": {"w": ((D,), ("embed",), "ones")},
        }
        if not cfg.tie_embeddings:
            specs["unembed"] = {"w": ((D, V), ("embed", "vocab"), "normal")}
        specs["groups"] = {
            name: stack_spec(spec, self.n_groups)
            for name, spec in self._group_specs().items()
        }
        specs["tail"] = {
            f"tail{i}_{kind}": blocks.block_spec(cfg, kind, cross=cfg.enc_dec)
            for i, kind in enumerate(self.tail_kinds)
        }
        if cfg.enc_dec:
            specs["enc_groups"] = {
                "enc_attn": stack_spec(
                    blocks.block_spec(cfg, "attn"), cfg.n_enc_layers
                )
            }
            specs["enc_norm"] = {"w": ((D,), ("embed",), "ones")}
            specs["frontend"] = {"w": ((D, D), ("embed", "embed"), "normal")}
        if cfg.vision_prefix > 0:
            specs["vision_adapter"] = {"w": ((D, D), ("embed", "embed"), "normal")}
        return specs

    def init_params(self, key: jax.Array):
        def init_tree(spec_tree, key):
            if isinstance(spec_tree, dict) and spec_tree and isinstance(
                next(iter(spec_tree.values())), tuple
            ):
                return init_from_spec(spec_tree, key, self.pdtype)
            keys = jax.random.split(key, max(len(spec_tree), 1))
            return {
                name: init_tree(sub, k)
                for (name, sub), k in zip(sorted(spec_tree.items()), keys)
            }

        return init_tree(self.param_specs(), key)

    def param_axes(self):
        def axes_tree(spec_tree):
            if isinstance(spec_tree, dict) and spec_tree and isinstance(
                next(iter(spec_tree.values())), tuple
            ):
                return axes_from_spec(spec_tree)
            return {name: axes_tree(sub) for name, sub in spec_tree.items()}

        return axes_tree(self.param_specs())

    # -- embedding / head -----------------------------------------------------------
    def embed(self, params, tokens: jax.Array) -> jax.Array:
        return params["embed"]["table"].astype(self.dtype)[tokens]

    def unembed(self, params, x: jax.Array) -> jax.Array:
        if self.cfg.tie_embeddings:
            w = params["embed"]["table"].astype(self.dtype).T
        else:
            w = params["unembed"]["w"].astype(self.dtype)
        return x @ w

    # -- encoder (whisper) ------------------------------------------------------------
    def encode(self, params, frames: jax.Array) -> jax.Array:
        """frames: precomputed mel-frame embeddings (B, enc_seq, D) — the conv
        frontend is a stub per the assignment; a linear adapter stands in."""
        cfg = self.cfg
        x = frames.astype(self.dtype) @ params["frontend"]["w"].astype(self.dtype)
        B, S, _ = x.shape
        positions = jnp.broadcast_to(jnp.arange(S)[None], (B, S))

        def body(x, p):
            x, _, _ = blocks.block_forward(cfg, "attn", p, x, positions, causal=False)
            return x, None

        x, _ = maybe_unrolled_scan(_remat(cfg, body), x, params["enc_groups"]["enc_attn"])
        return rms_norm(x, params["enc_norm"]["w"])

    # -- full forward (train / prefill) -------------------------------------------------
    def forward(
        self, params, batch: Dict[str, jax.Array], collect_cache: bool = False
    ) -> Tuple[jax.Array, jax.Array, Optional[Dict]]:
        """Returns (logits, aux_loss, cache-or-None)."""
        return self._forward_impl(params, batch, collect_cache, unembed=True)

    def _forward_impl(
        self,
        params,
        batch: Dict[str, jax.Array],
        collect_cache: bool = False,
        unembed: bool = True,
    ) -> Tuple[jax.Array, jax.Array, Optional[Dict]]:
        cfg = self.cfg
        tokens = batch["tokens"]
        B, S = tokens.shape
        x = self.embed(params, tokens)
        prefix = 0
        if cfg.vision_prefix > 0:
            patches = batch["patches"].astype(self.dtype)
            patches = patches @ params["vision_adapter"]["w"].astype(self.dtype)
            x = jnp.concatenate([patches, x], axis=1)
            prefix = patches.shape[1]
        total = prefix + S
        positions = jnp.broadcast_to(jnp.arange(total)[None], (B, total))
        enc_out = None
        if cfg.enc_dec:
            enc_out = self.encode(params, batch["frames"])

        def group_body(carry, gp):
            x, aux = carry
            x = maybe_constrain("residual", x)
            caches = {}
            for i, kind in enumerate(cfg.pattern):
                p = gp[f"blk{i}_{kind}"]
                ckv = (
                    attn_mod.encode_cross_kv(cfg, p, enc_out)
                    if enc_out is not None
                    else None
                )
                x, cache, a = blocks.block_forward(
                    cfg, kind, p, x, positions, cross_kv=ckv
                )
                caches[f"blk{i}_{kind}"] = cache
                aux = aux + a
            return (x, aux), caches if collect_cache else None

        (x, aux), group_caches = maybe_unrolled_scan(
            _remat(cfg, group_body),
            (x, jnp.zeros((), jnp.float32)),
            params["groups"],
        )
        tail_caches = {}
        for i, kind in enumerate(self.tail_kinds):
            p = params["tail"][f"tail{i}_{kind}"]
            ckv = attn_mod.encode_cross_kv(cfg, p, enc_out) if enc_out is not None else None
            x, cache, a = blocks.block_forward(cfg, kind, p, x, positions, cross_kv=ckv)
            tail_caches[f"tail{i}_{kind}"] = cache
            aux = aux + a
        x = rms_norm(x, params["final_norm"]["w"])
        if prefix:
            x = x[:, prefix:]
        out = self.unembed(params, x) if unembed else x
        cache = None
        if collect_cache:
            cache = {"groups": group_caches, "tail": tail_caches}
            if enc_out is not None:
                cache["enc_out"] = enc_out
        return out, aux, cache

    # -- hidden-state forward (for the chunked-CE loss path) -------------------------------
    def forward_hidden(self, params, batch: Dict[str, jax.Array]) -> Tuple[jax.Array, jax.Array]:
        """Like forward() but stops before unembedding: (hidden (B,S,D), aux)."""
        hidden, aux, _ = self._forward_impl(params, batch, collect_cache=False, unembed=False)
        return hidden, aux

    # -- losses ---------------------------------------------------------------------------
    CE_CHUNK = 512

    def loss_fn(self, params, batch: Dict[str, jax.Array]) -> Tuple[jax.Array, Dict]:
        tokens = batch["tokens"]
        B, S = tokens.shape
        if S < 2 * self.CE_CHUNK:
            logits, aux, _ = self.forward(params, batch)
            ce = cross_entropy(logits, batch["labels"], batch.get("mask"))
        else:
            # Chunked cross-entropy: never materialize the full (B,S,V) f32
            # logits — each S-chunk's logits are (re)computed under remat.
            hidden, aux = self.forward_hidden(params, batch)
            if self.cfg.tie_embeddings:
                w = params["embed"]["table"].astype(self.dtype).T
            else:
                w = params["unembed"]["w"].astype(self.dtype)
            n_chunks = S // self.CE_CHUNK
            hs = hidden.reshape(B, n_chunks, self.CE_CHUNK, -1).transpose(1, 0, 2, 3)
            ls = batch["labels"].reshape(B, n_chunks, self.CE_CHUNK).transpose(1, 0, 2)

            @jax.checkpoint
            def chunk_ce(carry, xs):
                h, lab = xs
                logits = maybe_constrain("logits", h @ w)
                return carry + cross_entropy(logits, lab) * lab.size, None

            total, _ = maybe_unrolled_scan(chunk_ce, jnp.zeros((), jnp.float32), (hs, ls))
            ce = total / (B * n_chunks * self.CE_CHUNK)
        loss = ce + 0.01 * aux
        return loss, {"ce": ce, "aux": aux}

    # -- decode -----------------------------------------------------------------------------
    def init_cache(self, batch: int, max_seq: int) -> Dict:
        cfg = self.cfg
        grp = {}
        for i, kind in enumerate(cfg.pattern):
            one = blocks.block_init_cache(cfg, kind, batch, max_seq, self.dtype)
            grp[f"blk{i}_{kind}"] = jax.tree_util.tree_map(
                lambda a: jnp.broadcast_to(a[None], (self.n_groups,) + a.shape), one
            )
        tail = {
            f"tail{i}_{kind}": blocks.block_init_cache(cfg, kind, batch, max_seq, self.dtype)
            for i, kind in enumerate(self.tail_kinds)
        }
        cache: Dict[str, Any] = {"groups": grp, "tail": tail}
        if cfg.enc_dec:
            cache["enc_out"] = jnp.zeros((batch, cfg.enc_seq, cfg.d_model), self.dtype)
        return cache

    def decode_step(
        self, params, cache: Dict, token: jax.Array, pos: jax.Array
    ) -> Tuple[jax.Array, Dict]:
        """One token for the whole batch.  token (B,1) int32, pos scalar."""
        cfg = self.cfg
        x = self.embed(params, token)
        enc_out = cache.get("enc_out")

        def group_body(x, scanned):
            gp, gcache = scanned
            new_caches = {}
            for i, kind in enumerate(cfg.pattern):
                key = f"blk{i}_{kind}"
                p = gp[key]
                ckv = (
                    attn_mod.encode_cross_kv(cfg, p, enc_out)
                    if enc_out is not None
                    else None
                )
                x, nc = blocks.block_decode(cfg, kind, p, x, gcache[key], pos, cross_kv=ckv)
                new_caches[key] = nc
            return x, new_caches

        x, new_group_caches = maybe_unrolled_scan(
            group_body, x, (params["groups"], cache["groups"])
        )
        new_tail = {}
        for i, kind in enumerate(self.tail_kinds):
            key = f"tail{i}_{kind}"
            p = params["tail"][key]
            ckv = attn_mod.encode_cross_kv(cfg, p, enc_out) if enc_out is not None else None
            x, nc = blocks.block_decode(cfg, kind, p, x, cache["tail"][key], pos, cross_kv=ckv)
            new_tail[key] = nc
        x = rms_norm(x, params["final_norm"]["w"])
        logits = self.unembed(params, x)
        new_cache: Dict[str, Any] = {"groups": new_group_caches, "tail": new_tail}
        if enc_out is not None:
            new_cache["enc_out"] = enc_out
        return logits, new_cache

    # -- prefill -------------------------------------------------------------------------------
    def prefill(self, params, batch: Dict[str, jax.Array]) -> Tuple[jax.Array, Dict]:
        """Returns (last-position logits (B,1,V), decode cache).  Only the
        final position is unembedded — the full (B,S,V) logits tensor is
        never materialized."""
        hidden, _aux, cache = self._forward_impl(
            params, batch, collect_cache=True, unembed=False
        )
        logits = self.unembed(params, hidden[:, -1:])
        return logits, cache
