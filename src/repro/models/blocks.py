"""Residual blocks assembled from the mixers: one init-spec + forward +
decode-step per block kind ("attn", "local", "rglru", "mlstm", "slstm"),
plus whisper's encoder/decoder blocks."""

from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig
from . import attention as attn
from . import moe as moe_mod
from . import recurrent as rec
from .common import ParamSpec, rms_norm


def ffn_spec(cfg: ModelConfig) -> ParamSpec:
    D, F = cfg.d_model, cfg.d_ff
    return {
        "wi": ((D, F), ("embed", "ffn"), "normal"),
        "wu": ((D, F), ("embed", "ffn"), "normal"),
        "wd": ((F, D), ("ffn", "embed"), "normal"),
    }


def ffn_forward(p: Dict, x: jax.Array) -> jax.Array:
    dt = x.dtype
    h = x @ p["wi"].astype(dt)
    u = x @ p["wu"].astype(dt)
    return (jax.nn.silu(h.astype(jnp.float32)).astype(dt) * u) @ p["wd"].astype(dt)


# -- block specs -----------------------------------------------------------------
def block_spec(cfg: ModelConfig, kind: str, cross: bool = False) -> ParamSpec:
    D = cfg.d_model
    spec: ParamSpec = {"ln1": ((D,), ("embed",), "ones")}
    if kind in ("attn", "local"):
        spec.update(attn.attn_spec(cfg))
        if cfg.n_experts > 0:
            spec["ln2"] = ((D,), ("embed",), "ones")
            spec.update(moe_mod.moe_spec(cfg))
        elif cfg.d_ff > 0:
            spec["ln2"] = ((D,), ("embed",), "ones")
            spec.update(ffn_spec(cfg))
    elif kind == "rglru":
        spec.update(rec.rglru_spec(cfg))
        if cfg.d_ff > 0:
            spec["ln2"] = ((D,), ("embed",), "ones")
            spec.update(ffn_spec(cfg))
    elif kind == "mlstm":
        spec.update(rec.mlstm_spec(cfg))
    elif kind == "slstm":
        spec.update(rec.slstm_spec(cfg))
    else:
        raise ValueError(f"unknown block kind {kind!r}")
    if cross:
        spec["ln_x"] = ((D,), ("embed",), "ones")
        spec.update(attn.attn_spec(cfg, cross=True))
    return spec


def _mix_ffn(cfg: ModelConfig, p: Dict, x: jax.Array, mixed: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """Residual-add mixer output, then (Mo)FFN if present.  Returns (x, aux)."""
    aux = jnp.zeros((), jnp.float32)
    x = x + mixed
    if "ln2" in p:
        h = rms_norm(x, p["ln2"])
        if cfg.n_experts > 0 and "router" in p:
            f, aux = moe_mod.moe_forward(cfg, p, h)
        else:
            f = ffn_forward(p, h)
        x = x + f
    return x, aux


def block_forward(
    cfg: ModelConfig,
    kind: str,
    p: Dict,
    x: jax.Array,
    positions: jax.Array,
    cross_kv: Optional[Tuple[jax.Array, jax.Array]] = None,
    causal: bool = True,
) -> Tuple[jax.Array, Dict, jax.Array]:
    """Full-sequence pass.  Returns (x, decode_cache, aux_loss)."""
    h = rms_norm(x, p["ln1"])
    if kind in ("attn", "local"):
        window = cfg.window if kind == "local" else None
        mixed, cache = attn.attention_forward(
            cfg, p, h, positions, window=window, causal=causal
        )
    elif kind == "rglru":
        mixed, cache = rec.rglru_forward(cfg, p, h)
    elif kind == "mlstm":
        mixed, cache = rec.mlstm_forward(cfg, p, h)
    elif kind == "slstm":
        mixed, cache = rec.slstm_forward(cfg, p, h)
    else:
        raise ValueError(kind)
    if cross_kv is not None:
        x = x + mixed
        xh = rms_norm(x, p["ln_x"])
        mixed = attn.cross_attention_forward(cfg, p, xh, cross_kv)
    x, aux = _mix_ffn(cfg, p, x, mixed)
    return x, cache, aux


def block_init_cache(cfg: ModelConfig, kind: str, batch: int, max_seq: int, dtype) -> Dict:
    if kind == "attn":
        return attn.init_kv_cache(cfg, batch, max_seq, None, dtype)
    if kind == "local":
        return attn.init_kv_cache(cfg, batch, max_seq, cfg.window, dtype)
    if kind == "rglru":
        return rec.rglru_init_state(cfg, batch)
    if kind == "mlstm":
        return rec.mlstm_init_state(cfg, batch)
    if kind == "slstm":
        return rec.slstm_init_state(cfg, batch)
    raise ValueError(kind)


def block_decode(
    cfg: ModelConfig,
    kind: str,
    p: Dict,
    x: jax.Array,           # (B,1,D)
    cache: Dict,
    pos: jax.Array,
    cross_kv: Optional[Tuple[jax.Array, jax.Array]] = None,
) -> Tuple[jax.Array, Dict]:
    h = rms_norm(x, p["ln1"])
    if kind in ("attn", "local"):
        window = cfg.window if kind == "local" else None
        mixed, cache = attn.attention_decode(cfg, p, h, cache, pos, window=window)
    elif kind == "rglru":
        mixed, cache = rec.rglru_step(cfg, p, h, cache)
    elif kind == "mlstm":
        mixed, cache = rec.mlstm_step(cfg, p, h, cache)
    elif kind == "slstm":
        mixed, cache = rec.slstm_step(cfg, p, h, cache)
    else:
        raise ValueError(kind)
    if cross_kv is not None:
        x = x + mixed
        xh = rms_norm(x, p["ln_x"])
        mixed = attn.cross_attention_decode(cfg, p, xh, cross_kv)
    x, _ = _mix_ffn(cfg, p, x, mixed)
    return x, cache
