"""Recurrent sequence mixers: RG-LRU (Griffin/RecurrentGemma), chunkwise
mLSTM and sLSTM (xLSTM).  Each provides a forward (full-sequence, training/
prefill) and a step (single-token decode) path plus state initializers.

TPU adaptation notes (DESIGN.md §2.3): RG-LRU's diagonal linear recurrence is
computed with ``jax.lax.associative_scan`` (log-depth on the MXU-adjacent
VPU), mLSTM uses the chunkwise formulation (intra-chunk quadratic on the MXU,
inter-chunk state passing) rather than a step loop, and sLSTM — strictly
sequential by construction — is a ``lax.scan``.
"""

from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig
from .common import ParamSpec, maybe_unrolled_scan, rms_norm

# =====================================================================================
# RG-LRU (Real-Gated Linear Recurrent Unit) — arXiv:2402.19427 §2.4
# =====================================================================================
_RGLRU_C = 8.0


def rglru_spec(cfg: ModelConfig) -> ParamSpec:
    D = cfg.d_model
    return {
        "w_in_x": ((D, D), ("embed", "ffn_in"), "normal"),
        "w_in_gate": ((D, D), ("embed", "ffn_in"), "normal"),
        "conv_w": ((4, D), (None, "ffn_in"), "normal"),
        "conv_b": ((D,), ("ffn_in",), "zeros"),
        "w_rec_gate": ((D, D), ("embed", "ffn_in"), "normal"),
        "b_rec_gate": ((D,), ("ffn_in",), "zeros"),
        "w_inp_gate": ((D, D), ("embed", "ffn_in"), "normal"),
        "b_inp_gate": ((D,), ("ffn_in",), "zeros"),
        "lambda_p": ((D,), ("ffn_in",), 1.0),
        "w_out": ((D, D), ("ffn_in", "embed"), "normal"),
    }


def _rglru_gates(p: Dict, xb: jax.Array, x_raw: jax.Array):
    """a (recurrence weight in (0,1)) and gated input, per channel."""
    dt = xb.dtype
    r = jax.nn.sigmoid(
        (x_raw @ p["w_rec_gate"].astype(dt)).astype(jnp.float32)
        + p["b_rec_gate"].astype(jnp.float32)
    )
    i = jax.nn.sigmoid(
        (x_raw @ p["w_inp_gate"].astype(dt)).astype(jnp.float32)
        + p["b_inp_gate"].astype(jnp.float32)
    )
    log_a = -_RGLRU_C * jax.nn.softplus(p["lambda_p"].astype(jnp.float32)) * r
    a = jnp.exp(log_a)
    gated = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-12)) * (
        i * xb.astype(jnp.float32)
    )
    return a, gated


def _causal_conv4(p: Dict, x: jax.Array, state: jax.Array | None = None):
    """Depthwise causal conv, width 4.  x (B,S,D); state (B,3,D) carries the
    last 3 inputs for decode."""
    if state is not None:
        x_ext = jnp.concatenate([state.astype(x.dtype), x], axis=1)
    else:
        x_ext = jnp.pad(x, ((0, 0), (3, 0), (0, 0)))
    w = p["conv_w"].astype(x.dtype)
    out = sum(x_ext[:, i : i + x.shape[1]] * w[i] for i in range(4))
    return out + p["conv_b"].astype(x.dtype), x_ext[:, -3:]


def rglru_forward(cfg: ModelConfig, p: Dict, x: jax.Array) -> Tuple[jax.Array, Dict]:
    """Griffin recurrent block: in-proj pair, conv4, RG-LRU scan, GeLU gate,
    out-proj.  Returns (out, decode_state)."""
    dt = x.dtype
    xb = x @ p["w_in_x"].astype(dt)
    gate = jax.nn.gelu((x @ p["w_in_gate"].astype(dt)).astype(jnp.float32))
    xb, conv_state = _causal_conv4(p, xb)
    a, gated = _rglru_gates(p, xb, x)
    # h_t = a_t * h_{t-1} + gated_t — associative scan over time.
    def combine(c1, c2):
        a1, b1 = c1
        a2, b2 = c2
        return a1 * a2, b1 * a2 + b2

    a_cum, h = jax.lax.associative_scan(combine, (a, gated), axis=1)
    out = (h * gate).astype(dt) @ p["w_out"].astype(dt)
    state = {"h": h[:, -1], "conv": conv_state}
    return out, state


def rglru_init_state(cfg: ModelConfig, batch: int) -> Dict:
    D = cfg.d_model
    return {
        "h": jnp.zeros((batch, D), jnp.float32),
        "conv": jnp.zeros((batch, 3, D), jnp.float32),
    }


def rglru_step(cfg: ModelConfig, p: Dict, x: jax.Array, state: Dict) -> Tuple[jax.Array, Dict]:
    """x (B,1,D) single token."""
    dt = x.dtype
    xb = x @ p["w_in_x"].astype(dt)
    gate = jax.nn.gelu((x @ p["w_in_gate"].astype(dt)).astype(jnp.float32))
    xb, conv_state = _causal_conv4(p, xb, state["conv"])
    a, gated = _rglru_gates(p, xb, x)
    h = a[:, 0] * state["h"] + gated[:, 0]
    out = (h[:, None] * gate).astype(dt) @ p["w_out"].astype(dt)
    return out, {"h": h, "conv": conv_state}


# =====================================================================================
# mLSTM (matrix-memory LSTM) — arXiv:2405.04517 §2.3, chunkwise form
# =====================================================================================
def mlstm_spec(cfg: ModelConfig) -> ParamSpec:
    D, H, hd = cfg.d_model, cfg.n_heads, cfg.head_dim
    return {
        "wq": ((D, H * hd), ("embed", "q_heads"), "normal"),
        "wk": ((D, H * hd), ("embed", "q_heads"), "normal"),
        "wv": ((D, H * hd), ("embed", "q_heads"), "normal"),
        "w_igate": ((D, H), ("embed", None), "normal"),
        "b_igate": ((H,), (None,), "zeros"),
        "w_fgate": ((D, H), ("embed", None), "normal"),
        "b_fgate": ((H,), (None,), "zeros"),
        "out_norm": ((H * hd,), ("q_heads",), "ones"),
        "wo": ((H * hd, D), ("q_heads", "embed"), "normal"),
    }


MLSTM_CHUNK = 256


def _mlstm_qkv_gates(cfg: ModelConfig, p: Dict, x: jax.Array):
    B, S, _ = x.shape
    H, hd = cfg.n_heads, cfg.head_dim
    dt = x.dtype
    q = (x @ p["wq"].astype(dt)).reshape(B, S, H, hd).transpose(0, 2, 1, 3)
    k = (x @ p["wk"].astype(dt)).reshape(B, S, H, hd).transpose(0, 2, 1, 3)
    v = (x @ p["wv"].astype(dt)).reshape(B, S, H, hd).transpose(0, 2, 1, 3)
    k = k / jnp.sqrt(jnp.array(hd, dt))
    log_i = (x @ p["w_igate"].astype(dt)).astype(jnp.float32) + p["b_igate"].astype(jnp.float32)
    log_f = jax.nn.log_sigmoid(
        (x @ p["w_fgate"].astype(dt)).astype(jnp.float32) + p["b_fgate"].astype(jnp.float32)
    )
    # (B,H,S)
    return q, k, v, log_i.transpose(0, 2, 1), log_f.transpose(0, 2, 1)


def mlstm_forward(cfg: ModelConfig, p: Dict, x: jax.Array) -> Tuple[jax.Array, Dict]:
    """Chunkwise-parallel mLSTM.  Returns (out, decode_state)."""
    B, S, D = x.shape
    H, hd = cfg.n_heads, cfg.head_dim
    q, k, v, log_i, log_f = _mlstm_qkv_gates(cfg, p, x)
    L = min(MLSTM_CHUNK, S)
    assert S % L == 0, f"seq {S} not divisible by chunk {L}"
    N = S // L

    def resh(t):  # (B,H,S,...) -> (N,B,H,L,...)
        return t.reshape(B, H, N, L, *t.shape[3:]).transpose(2, 0, 1, 3, *range(4, t.ndim + 1))

    qs, ks, vs = resh(q), resh(k), resh(v)
    lis = log_i.reshape(B, H, N, L).transpose(2, 0, 1, 3)
    lfs = log_f.reshape(B, H, N, L).transpose(2, 0, 1, 3)

    C0 = jnp.zeros((B, H, hd, hd), jnp.float32)
    n0 = jnp.zeros((B, H, hd), jnp.float32)
    m0 = jnp.full((B, H), -1e30, jnp.float32)

    def chunk_body(carry, inp):
        C, n, m = carry  # C,n stored relative to exp(m)
        qc, kc, vc, li, lf = inp  # (B,H,L,hd)... li/lf (B,H,L)
        b = jnp.cumsum(lf, axis=-1)  # (B,H,L) within-chunk cumulative log f
        total = b[..., -1:]
        # decay from chunk start to position t (inclusive of gates ≤ t).
        m_inter = m[..., None] + b  # (B,H,L)
        # intra-chunk weights: D_ts = b_t − b_s + li_s for s ≤ t
        dmat = b[..., :, None] - b[..., None, :] + li[..., None, :]  # (B,H,L,L)
        tri = jnp.tril(jnp.ones((L, L), bool))
        dmat = jnp.where(tri, dmat, -jnp.inf)
        m_intra = jnp.max(dmat, axis=-1)  # (B,H,L)
        m_new = jnp.maximum(m_inter, m_intra)
        w_intra = jnp.exp(dmat - m_new[..., None])  # (B,H,L,L)
        scale_inter = jnp.exp(m_inter - m_new)  # (B,H,L)
        qf = qc.astype(jnp.float32)
        kf = kc.astype(jnp.float32)
        vf = vc.astype(jnp.float32)
        scores = jnp.einsum("bhld,bhsd->bhls", qf, kf) * w_intra
        num = jnp.einsum("bhls,bhsd->bhld", scores, vf) + scale_inter[..., None] * jnp.einsum(
            "bhld,bhde->bhle", qf, C
        )
        den = jnp.sum(scores, axis=-1) + scale_inter * jnp.einsum("bhld,bhd->bhl", qf, n)
        h = num / jnp.maximum(jnp.abs(den), jnp.exp(-m_new))[..., None]
        # state update to end of chunk
        m_state_intra = jnp.max(total - b + li, axis=-1)  # (B,H)
        m_next = jnp.maximum(m + total[..., 0], m_state_intra)
        decay_old = jnp.exp(m + total[..., 0] - m_next)  # (B,H)
        w_state = jnp.exp(total - b + li - m_next[..., None])  # (B,H,L)
        C_next = decay_old[..., None, None] * C + jnp.einsum(
            "bhl,bhld,bhle->bhde", w_state, kf, vf
        )
        n_next = decay_old[..., None] * n + jnp.einsum("bhl,bhld->bhd", w_state, kf)
        return (C_next, n_next, m_next), h

    (C, n, m), hs = maybe_unrolled_scan(chunk_body, (C0, n0, m0), (qs, ks, vs, lis, lfs))
    h = hs.transpose(1, 2, 0, 3, 4).reshape(B, H, S, hd).transpose(0, 2, 1, 3).reshape(B, S, H * hd)
    h = rms_norm(h.astype(x.dtype), p["out_norm"])
    out = h @ p["wo"].astype(x.dtype)
    return out, {"C": C, "n": n, "m": m}


def mlstm_init_state(cfg: ModelConfig, batch: int) -> Dict:
    H, hd = cfg.n_heads, cfg.head_dim
    return {
        "C": jnp.zeros((batch, H, hd, hd), jnp.float32),
        "n": jnp.zeros((batch, H, hd), jnp.float32),
        "m": jnp.full((batch, H), -1e30, jnp.float32),
    }


def mlstm_step(cfg: ModelConfig, p: Dict, x: jax.Array, state: Dict) -> Tuple[jax.Array, Dict]:
    """x (B,1,D)."""
    B = x.shape[0]
    H, hd = cfg.n_heads, cfg.head_dim
    q, k, v, log_i, log_f = _mlstm_qkv_gates(cfg, p, x)
    q, k, v = q[:, :, 0], k[:, :, 0], v[:, :, 0]  # (B,H,hd)
    li, lf = log_i[..., 0], log_f[..., 0]  # (B,H)
    C, n, m = state["C"], state["n"], state["m"]
    m_new = jnp.maximum(lf + m, li)
    f_w = jnp.exp(lf + m - m_new)
    i_w = jnp.exp(li - m_new)
    kf, vf, qf = k.astype(jnp.float32), v.astype(jnp.float32), q.astype(jnp.float32)
    C = f_w[..., None, None] * C + i_w[..., None, None] * jnp.einsum("bhd,bhe->bhde", kf, vf)
    n = f_w[..., None] * n + i_w[..., None] * kf
    num = jnp.einsum("bhd,bhde->bhe", qf, C)
    den = jnp.einsum("bhd,bhd->bh", qf, n)
    h = num / jnp.maximum(jnp.abs(den), jnp.exp(-m_new))[..., None]
    h = h.reshape(B, 1, H * hd).astype(x.dtype)
    h = rms_norm(h, p["out_norm"])
    out = h @ p["wo"].astype(x.dtype)
    return out, {"C": C, "n": n, "m": m_new}


# =====================================================================================
# sLSTM (scalar-memory, exponential gating) — arXiv:2405.04517 §2.2
# =====================================================================================
def slstm_spec(cfg: ModelConfig) -> ParamSpec:
    D = cfg.d_model
    return {
        "w_gates": ((D, 4 * D), ("embed", "ffn_in"), "normal"),
        "r_gates": ((D, 4 * D), ("embed", "ffn_in"), 0.02),
        "b_gates": ((4 * D,), ("ffn_in",), "zeros"),
        "wo": ((D, D), ("ffn_in", "embed"), "normal"),
    }


def _slstm_cell(p, xg, h, c, n, m):
    """One step.  xg (B,4D) precomputed input contribution."""
    D = h.shape[-1]
    g = xg + h @ p["r_gates"].astype(jnp.float32)
    zi, ii, fi, oi = jnp.split(g, 4, axis=-1)
    z = jnp.tanh(zi)
    o = jax.nn.sigmoid(oi)
    m_new = jnp.maximum(fi + m, ii)
    i_w = jnp.exp(ii - m_new)
    f_w = jnp.exp(fi + m - m_new)
    c_new = f_w * c + i_w * z
    n_new = f_w * n + i_w
    h_new = o * c_new / jnp.maximum(n_new, 1e-6)
    return h_new, c_new, n_new, m_new


def slstm_forward(cfg: ModelConfig, p: Dict, x: jax.Array) -> Tuple[jax.Array, Dict]:
    B, S, D = x.shape
    xg = (x.astype(jnp.float32) @ p["w_gates"].astype(jnp.float32)) + p["b_gates"].astype(
        jnp.float32
    )
    h0 = jnp.zeros((B, D), jnp.float32)
    c0 = jnp.zeros((B, D), jnp.float32)
    n0 = jnp.zeros((B, D), jnp.float32)
    m0 = jnp.full((B, D), -1e30, jnp.float32)

    def body(carry, xg_t):
        h, c, n, m = carry
        h, c, n, m = _slstm_cell(p, xg_t, h, c, n, m)
        return (h, c, n, m), h

    (h, c, n, m), hs = jax.lax.scan(body, (h0, c0, n0, m0), xg.transpose(1, 0, 2))
    out = hs.transpose(1, 0, 2).astype(x.dtype) @ p["wo"].astype(x.dtype)
    return out, {"h": h, "c": c, "n": n, "m": m}


def slstm_init_state(cfg: ModelConfig, batch: int) -> Dict:
    D = cfg.d_model
    z = lambda: jnp.zeros((batch, D), jnp.float32)  # noqa: E731
    return {"h": z(), "c": z(), "n": z(), "m": jnp.full((batch, D), -1e30, jnp.float32)}


def slstm_step(cfg: ModelConfig, p: Dict, x: jax.Array, state: Dict) -> Tuple[jax.Array, Dict]:
    B = x.shape[0]
    xg = (x[:, 0].astype(jnp.float32) @ p["w_gates"].astype(jnp.float32)) + p[
        "b_gates"
    ].astype(jnp.float32)
    h, c, n, m = _slstm_cell(p, xg, state["h"], state["c"], state["n"], state["m"])
    out = h[:, None].astype(x.dtype) @ p["wo"].astype(x.dtype)
    return out, {"h": h, "c": c, "n": n, "m": m}
