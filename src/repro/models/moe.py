"""Mixture-of-Experts FFN: top-k routing with sort-based dispatch into
capacity-bounded grouped GEMMs (GShard-style with token dropping).

TPU adaptation: tokens are sorted by expert id and packed into an
(E, capacity, D) buffer so the expert FFN is a single grouped einsum on the
MXU; with experts sharded over the "model" axis the gather/scatter lowers to
the expected all-to-all pattern.  The Pallas grouped-GEMM kernel in
``repro.kernels`` accelerates the (E,C,D)x(E,D,F) contraction on real TPUs.
"""

from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig
from .common import ParamSpec
from ..placement.constraints import maybe_constrain


def moe_spec(cfg: ModelConfig) -> ParamSpec:
    D, F, E = cfg.d_model, cfg.d_ff, cfg.n_experts
    return {
        "router": ((D, E), ("embed", "experts"), "normal"),
        "wi": ((E, D, F), ("experts", "embed", "ffn"), "normal"),
        "wu": ((E, D, F), ("experts", "embed", "ffn"), "normal"),
        "wd": ((E, F, D), ("experts", "ffn", "embed"), "normal"),
    }


def moe_capacity(cfg: ModelConfig, n_tokens: int) -> int:
    cap = int(n_tokens * cfg.top_k * cfg.capacity_factor / cfg.n_experts)
    return max(8, ((cap + 7) // 8) * 8)  # pad to a multiple of 8 lanes


def grouped_dispatch_enabled() -> bool:
    """Beyond-paper optimization (EXPERIMENTS.md §Perf): dispatch per batch
    row (GShard 'groups') so the token sort/scatter is local to each data
    shard — the global-argsort path forces GSPMD to all-gather the full
    (T·K, D) dispatch tensor onto every device.  Off by default (baseline)."""
    import os

    return os.environ.get("REPRO_OPT_MOE_GROUPED", "0") == "1"


def moe_forward(cfg: ModelConfig, p: Dict, x: jax.Array) -> Tuple[jax.Array, jax.Array]:
    if grouped_dispatch_enabled() and x.shape[0] > 1:
        return moe_forward_grouped(cfg, p, x)
    return moe_forward_global(cfg, p, x)


def moe_forward_global(cfg: ModelConfig, p: Dict, x: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """x (B,S,D) -> (out (B,S,D), aux_loss scalar).

    Sort-based dispatch: flatten (T = B*S) tokens, expand to T*K slots,
    sort slots by expert, keep the first `capacity` per expert.
    """
    B, S, D = x.shape
    E, K = cfg.n_experts, cfg.top_k
    T = B * S
    C = moe_capacity(cfg, T)
    dt = x.dtype
    xf = x.reshape(T, D)

    logits = (xf @ p["router"].astype(dt)).astype(jnp.float32)  # (T,E)
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, expert_idx = jax.lax.top_k(probs, K)  # (T,K)
    gate_vals = gate_vals / jnp.maximum(gate_vals.sum(-1, keepdims=True), 1e-9)

    # Load-balancing auxiliary loss (Switch-style).
    me = probs.mean(axis=0)  # (E,)
    ce = jnp.zeros((E,), jnp.float32).at[expert_idx.reshape(-1)].add(1.0) / (T * K)
    aux = E * jnp.sum(me * ce)

    # Sort T*K slots by expert id; position within expert via cumsum.
    flat_expert = expert_idx.reshape(-1)                      # (T*K,)
    flat_gate = gate_vals.reshape(-1)
    flat_token = jnp.repeat(jnp.arange(T), K)
    order = jnp.argsort(flat_expert, stable=True)
    sorted_expert = flat_expert[order]
    sorted_token = flat_token[order]
    sorted_gate = flat_gate[order]
    # rank within expert = index - start offset of that expert's run
    counts = jnp.zeros((E,), jnp.int32).at[sorted_expert].add(1)
    starts = jnp.concatenate([jnp.zeros((1,), jnp.int32), jnp.cumsum(counts)[:-1]])
    rank = jnp.arange(T * K) - starts[sorted_expert]
    keep = rank < C  # token-dropping beyond capacity
    slot = sorted_expert * C + jnp.where(keep, rank, 0)

    # Dispatch: (E*C, D) buffer.
    buf = jnp.zeros((E * C, D), dt)
    buf = buf.at[slot].add(jnp.where(keep[:, None], xf[sorted_token], 0).astype(dt))
    xe = maybe_constrain("moe_buffer", buf.reshape(E, C, D))

    # Grouped expert FFN (SwiGLU).
    h = jnp.einsum("ecd,edf->ecf", xe, p["wi"].astype(dt))
    u = jnp.einsum("ecd,edf->ecf", xe, p["wu"].astype(dt))
    y = (jax.nn.silu(h.astype(jnp.float32)).astype(dt) * u)
    ye = jnp.einsum("ecf,efd->ecd", y, p["wd"].astype(dt)).reshape(E * C, D)

    # Combine: gather back and weight by gates.
    gathered = ye[slot] * jnp.where(keep, sorted_gate, 0.0)[:, None].astype(dt)
    out = jnp.zeros((T, D), dt).at[sorted_token].add(gathered)
    return out.reshape(B, S, D), aux


def moe_forward_grouped(cfg: ModelConfig, p: Dict, x: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """Grouped (per-batch-row) dispatch: sort/scatter stays local to the data
    shard holding the row, so no global gather of the dispatch tensor; the
    only cross-device traffic left is the expert-sharded GEMM's gather of
    (E/model_shards) buffer slices — the GShard group-local pattern."""
    B, S, D = x.shape
    E, K = cfg.n_experts, cfg.top_k
    C = moe_capacity(cfg, S)  # capacity per row
    dt = x.dtype

    logits = jnp.einsum("bsd,de->bse", x, p["router"].astype(dt)).astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, expert_idx = jax.lax.top_k(probs, K)            # (B,S,K)
    gate_vals = gate_vals / jnp.maximum(gate_vals.sum(-1, keepdims=True), 1e-9)

    me = probs.mean(axis=(0, 1))
    ce = (
        jnp.zeros((E,), jnp.float32).at[expert_idx.reshape(-1)].add(1.0)
        / (B * S * K)
    )
    aux = E * jnp.sum(me * ce)

    flat_expert = expert_idx.reshape(B, S * K)                  # per-row slots
    flat_gate = gate_vals.reshape(B, S * K)
    flat_token = jnp.broadcast_to(jnp.repeat(jnp.arange(S), K)[None], (B, S * K))

    def row_dispatch(xf, fe, ft, fg):
        order = jnp.argsort(fe, stable=True)
        se, st, sg = fe[order], ft[order], fg[order]
        counts = jnp.zeros((E,), jnp.int32).at[se].add(1)
        starts = jnp.concatenate([jnp.zeros((1,), jnp.int32), jnp.cumsum(counts)[:-1]])
        rank = jnp.arange(S * K) - starts[se]
        keep = rank < C
        slot = se * C + jnp.where(keep, rank, 0)
        buf = jnp.zeros((E * C, D), dt)
        buf = buf.at[slot].add(jnp.where(keep[:, None], xf[st], 0).astype(dt))
        return buf.reshape(E, C, D), (slot, st, sg, keep)

    xe, (slot, st, sg, keep) = jax.vmap(row_dispatch)(
        x, flat_expert, flat_token, flat_gate
    )                                                            # (B,E,C,D)
    # §Perf MoE iter 4: stage the shardings — keep the scatter local to the
    # row's data shard (batch-only sharding), then *slice* to the expert-
    # sharded layout for the GEMM (no communication), instead of letting the
    # E-sharding propagate backward into the scatter (which GSPMD resolves
    # by replicating the whole buffer).
    xe = maybe_constrain("moe_buffer_local", xe)
    xe = maybe_constrain("moe_buffer_grouped", xe)

    h = jnp.einsum("becd,edf->becf", xe, p["wi"].astype(dt))
    u = jnp.einsum("becd,edf->becf", xe, p["wu"].astype(dt))
    y = jax.nn.silu(h.astype(jnp.float32)).astype(dt) * u
    ye = jnp.einsum("becf,efd->becd", y, p["wd"].astype(dt)).reshape(B, E * C, D)
    # §Perf MoE iter 4 (second half): bring expert outputs back to batch-only
    # sharding ONCE (one all-gather over the model axis), so the combine
    # gather below is row-local.  (Iter 2's fully-token-sharded variant is
    # REFUTED — it made GSPMD replicate upstream tensors.)
    ye = maybe_constrain("moe_ye_local", ye)

    def row_combine(ye_row, slot_row, st_row, sg_row, keep_row):
        gathered = ye_row[slot_row] * jnp.where(keep_row, sg_row, 0.0)[:, None].astype(dt)
        return jnp.zeros((S, D), dt).at[st_row].add(gathered)

    out = jax.vmap(row_combine)(ye, slot, st, sg, keep)
    return out, aux
