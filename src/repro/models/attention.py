"""GQA attention: full-causal, sliding-window, bidirectional, and cross
variants; forward (train/prefill) and single-token decode paths.

Pure-jnp reference path (lowered for the dry-run); the Pallas flash kernels in
``repro.kernels`` are drop-in replacements on real TPUs and are validated
against this math in tests.
"""

from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig
from .common import ParamSpec, apply_rope, maybe_unrolled_scan, rms_norm

NEG_INF = -1e30


def attn_spec(cfg: ModelConfig, cross: bool = False) -> ParamSpec:
    D, H, Kv, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    prefix = "x" if cross else ""
    spec: ParamSpec = {
        f"{prefix}wq": ((D, H * hd), ("embed", "q_heads"), "normal"),
        f"{prefix}wk": ((D, Kv * hd), ("embed", "kv_heads"), "normal"),
        f"{prefix}wv": ((D, Kv * hd), ("embed", "kv_heads"), "normal"),
        f"{prefix}wo": ((H * hd, D), ("q_heads", "embed"), "normal"),
    }
    if cfg.qk_norm and not cross:
        spec["q_norm"] = ((hd,), (None,), "ones")
        spec["k_norm"] = ((hd,), (None,), "ones")
    return spec


def _project_qkv(cfg: ModelConfig, p: Dict, x: jax.Array, prefix: str = ""):
    B, S, _ = x.shape
    H, Kv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    dt = x.dtype
    q = (x @ p[f"{prefix}wq"].astype(dt)).reshape(B, S, H, hd)
    k = (x @ p[f"{prefix}wk"].astype(dt)).reshape(B, S, Kv, hd)
    v = (x @ p[f"{prefix}wv"].astype(dt)).reshape(B, S, Kv, hd)
    if cfg.qk_norm and not prefix:
        q = rms_norm(q, p["q_norm"])
        k = rms_norm(k, p["k_norm"])
    return q, k, v


def _sdpa(cfg: ModelConfig, q: jax.Array, k: jax.Array, v: jax.Array, mask: Optional[jax.Array]):
    """q (B,Sq,H,hd), k/v (B,Sk,Kv,hd) -> (B,Sq,H*hd).  GQA via reshape."""
    B, Sq, H, hd = q.shape
    Kv = k.shape[2]
    G = H // Kv
    q = q.reshape(B, Sq, Kv, G, hd)
    scores = jnp.einsum("bqkgh,bskh->bkgqs", q, k).astype(jnp.float32) / jnp.sqrt(
        jnp.array(hd, jnp.float32)
    )
    if mask is not None:
        scores = jnp.where(mask[:, None, None, :, :], scores, NEG_INF)
    w = jax.nn.softmax(scores, axis=-1).astype(q.dtype)
    out = jnp.einsum("bkgqs,bskh->bqkgh", w, v)
    return out.reshape(B, Sq, H * hd)


SDPA_BLOCK_Q = 512


def _swa_block_skip_enabled() -> bool:
    """Beyond-paper optimization (EXPERIMENTS.md §Perf): restrict each query
    block's keys to its sliding window instead of scoring the full masked
    row.  Off by default so baseline dry-runs stay paper-faithful."""
    import os

    return os.environ.get("REPRO_OPT_SWA", "0") == "1"


def _sdpa_blocked(
    cfg: ModelConfig,
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    *,
    causal: bool,
    window: Optional[int],
):
    """Query-blocked attention: scores for one q-block at a time, so the peak
    intermediate is (B,Kv,G,blk,Sk) instead of (B,Kv,G,Sq,Sk).  Exact (the
    full key row fits, so no online-softmax rescaling is required) — this is
    the jnp oracle the Pallas flash kernel is checked against."""
    B, Sq, H, hd = q.shape
    Sk, Kv = k.shape[1], k.shape[2]
    G = H // Kv
    blk = SDPA_BLOCK_Q
    assert Sq % blk == 0, (Sq, blk)
    n = Sq // blk
    qb = q.reshape(B, n, blk, Kv, G, hd).transpose(1, 0, 2, 3, 4, 5)
    offsets = jnp.arange(n) * blk
    scale = 1.0 / jnp.sqrt(jnp.array(hd, jnp.float32))

    skip = (
        causal
        and window is not None
        and _swa_block_skip_enabled()
        and Sk > window + blk
        and Sq == Sk
    )
    kv_span = window + blk if skip else Sk

    def body(carry, xs):
        qblk, off = xs  # (B,blk,Kv,G,hd), scalar
        if skip:
            # Only keys in (q_start - window, q_start + blk) can be visible.
            start = jnp.clip(off - window, 0, Sk - kv_span)
            kw = jax.lax.dynamic_slice_in_dim(k, start, kv_span, axis=1)
            vw = jax.lax.dynamic_slice_in_dim(v, start, kv_span, axis=1)
            j = start + jnp.arange(kv_span)
        else:
            kw, vw = k, v
            j = jnp.arange(Sk)
        scores = jnp.einsum("bqkgh,bskh->bkgqs", qblk, kw).astype(jnp.float32) * scale
        if causal:
            i = off + jnp.arange(blk)
            m = j[None, :] <= i[:, None]
            if window is not None:
                m = m & (j[None, :] > i[:, None] - window)
            scores = jnp.where(m[None, None, None], scores, NEG_INF)
        w = jax.nn.softmax(scores, axis=-1).astype(qblk.dtype)
        out = jnp.einsum("bkgqs,bskh->bqkgh", w, vw)
        return carry, out

    _, outs = maybe_unrolled_scan(body, None, (qb, offsets))
    out = outs.transpose(1, 0, 2, 3, 4, 5).reshape(B, Sq, H * hd)
    return out


def causal_mask(Sq: int, Sk: int, window: Optional[int] = None) -> jax.Array:
    """(1, Sq, Sk) boolean; True = attend.  Offset assumes Sq == Sk."""
    i = jnp.arange(Sq)[:, None]
    j = jnp.arange(Sk)[None, :]
    m = j <= i
    if window is not None:
        m = m & (j > i - window)
    return m[None]


def attention_forward(
    cfg: ModelConfig,
    p: Dict,
    x: jax.Array,
    positions: jax.Array,
    *,
    window: Optional[int] = None,
    causal: bool = True,
) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    """Full-sequence attention.  Returns (out (B,S,D), kv_cache pieces)."""
    B, S, _ = x.shape
    q, k, v = _project_qkv(cfg, p, x)
    q = apply_rope(q, positions, cfg.rope_theta)
    k = apply_rope(k, positions, cfg.rope_theta)
    if S > SDPA_BLOCK_Q and S % SDPA_BLOCK_Q == 0:
        out = _sdpa_blocked(cfg, q, k, v, causal=causal, window=window)
    else:
        mask = causal_mask(S, S, window) if causal else None
        out = _sdpa(cfg, q, k, v, mask)
    out = out @ p["wo"].astype(x.dtype)
    # Cache for decode continuation: ring-buffered if windowed.
    if window is not None and S > window:
        k_c, v_c = k[:, -window:], v[:, -window:]
        # Roll so that slot (pos % window) matches the ring-buffer layout.
        shift = S % window
        k_c = jnp.roll(k_c, shift, axis=1)
        v_c = jnp.roll(v_c, shift, axis=1)
    else:
        k_c, v_c = k, v
    return out, {"k": k_c, "v": v_c}


def cross_attention_forward(cfg: ModelConfig, p: Dict, x: jax.Array, ctx_kv: Tuple[jax.Array, jax.Array]) -> jax.Array:
    B, S, _ = x.shape
    H, hd = cfg.n_heads, cfg.head_dim
    q = (x @ p["xwq"].astype(x.dtype)).reshape(B, S, H, hd)
    k, v = ctx_kv
    out = _sdpa(cfg, q, k, v, None)
    return out @ p["xwo"].astype(x.dtype)


def encode_cross_kv(cfg: ModelConfig, p: Dict, ctx: jax.Array) -> Tuple[jax.Array, jax.Array]:
    B, S, _ = ctx.shape
    Kv, hd = cfg.n_kv_heads, cfg.head_dim
    k = (ctx @ p["xwk"].astype(ctx.dtype)).reshape(B, S, Kv, hd)
    v = (ctx @ p["xwv"].astype(ctx.dtype)).reshape(B, S, Kv, hd)
    return k, v


# -- decode (single new token against a cache) ----------------------------------------
def init_kv_cache(cfg: ModelConfig, batch: int, max_seq: int, window: Optional[int], dtype) -> Dict[str, jax.Array]:
    S = min(window, max_seq) if window else max_seq
    Kv, hd = cfg.n_kv_heads, cfg.head_dim
    return {
        "k": jnp.zeros((batch, S, Kv, hd), dtype),
        "v": jnp.zeros((batch, S, Kv, hd), dtype),
    }


def attention_decode(
    cfg: ModelConfig,
    p: Dict,
    x: jax.Array,            # (B, 1, D)
    cache: Dict[str, jax.Array],
    pos: jax.Array,          # scalar int32: index of the new token
    *,
    window: Optional[int] = None,
) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    B = x.shape[0]
    q, k, v = _project_qkv(cfg, p, x)
    pos_arr = jnp.full((B, 1), pos, jnp.int32)
    q = apply_rope(q, pos_arr, cfg.rope_theta)
    k = apply_rope(k, pos_arr, cfg.rope_theta)
    S = cache["k"].shape[1]
    slot = jnp.mod(pos, S) if window else jnp.minimum(pos, S - 1)
    k_cache = jax.lax.dynamic_update_slice(cache["k"], k, (0, slot, 0, 0))
    v_cache = jax.lax.dynamic_update_slice(cache["v"], v, (0, slot, 0, 0))
    j = jnp.arange(S)
    if window:
        # Ring buffer: once pos >= S every slot holds one of the last S
        # positions; before that only slots 0..pos are populated.
        mask = jnp.where(pos < S, j[None, :] <= pos, jnp.ones((1, S), bool))
    else:
        mask = j[None, :] <= pos
    mask = jnp.broadcast_to(mask[:, None, :], (B, 1, S))
    out = _sdpa(cfg, q, k_cache, v_cache, mask)
    out = out @ p["wo"].astype(x.dtype)
    return out, {"k": k_cache, "v": v_cache}


def cross_attention_decode(cfg: ModelConfig, p: Dict, x: jax.Array, ctx_kv: Tuple[jax.Array, jax.Array]) -> jax.Array:
    return cross_attention_forward(cfg, p, x, ctx_kv)
