"""Arch registry: build models, input specs per (arch × shape) cell, and the
skip rules for cells that are undefined for a family (DESIGN.md §4)."""

from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from .. import configs
from ..configs.base import ModelConfig, ShapeCell, shape_by_name
from .common import dtype_of
from .lm import Model


def build(arch: str, smoke: bool = False) -> Model:
    cfg = configs.get_smoke(arch) if smoke else configs.get(arch)
    return Model(cfg)


def build_from_config(cfg: ModelConfig) -> Model:
    return Model(cfg)


# -- cell applicability ---------------------------------------------------------------
def cell_skip_reason(cfg: ModelConfig, shape: ShapeCell) -> Optional[str]:
    """None if the (arch, shape) cell runs; otherwise the documented reason."""
    if shape.name == "long_500k":
        kinds = set(cfg.layer_kinds())
        sub_quadratic = kinds <= {"local", "rglru", "mlstm", "slstm"} or (
            "attn" not in kinds
        )
        if not sub_quadratic:
            return (
                "long_500k skipped: pure full-attention arch cannot hold a "
                "524k dense KV cache sub-quadratically (DESIGN.md §4)"
            )
    return None


# -- input specs (ShapeDtypeStruct stand-ins; no allocation) ------------------------------
def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(tuple(shape), dtype)


def input_specs(cfg: ModelConfig, shape: ShapeCell) -> Dict[str, Any]:
    """Model inputs for one cell.  For decode cells this includes the KV/state
    cache stand-ins (built via eval_shape of init_cache — no allocation)."""
    model = Model(cfg)
    B, S = shape.global_batch, shape.seq_len
    dt = dtype_of(cfg.dtype)
    if shape.kind == "train":
        batch: Dict[str, Any] = {
            "tokens": _sds((B, S), jnp.int32),
            "labels": _sds((B, S), jnp.int32),
        }
        _add_frontends(cfg, batch, B)
        return {"batch": batch}
    if shape.kind == "prefill":
        batch = {"tokens": _sds((B, S), jnp.int32)}
        _add_frontends(cfg, batch, B)
        return {"batch": batch}
    # decode: one new token against a cache of S positions.
    cache_shapes = jax.eval_shape(lambda: model.init_cache(B, S))
    return {
        "cache": cache_shapes,
        "token": _sds((B, 1), jnp.int32),
        "pos": _sds((), jnp.int32),
    }


def _add_frontends(cfg: ModelConfig, batch: Dict[str, Any], B: int) -> None:
    dt = dtype_of(cfg.dtype)
    if cfg.vision_prefix > 0:
        batch["patches"] = _sds((B, cfg.vision_prefix, cfg.d_model), dt)
    if cfg.enc_dec:
        batch["frames"] = _sds((B, cfg.enc_seq, cfg.d_model), dt)


# -- decode-cache continuation helper (prefill cache -> larger decode buffer) --------------
def extend_cache(model: Model, cache: Dict, max_seq: int) -> Dict:
    """Pad attention KV buffers (length-S) up to ``max_seq`` so decoding can
    continue past the prefill length.  Recurrent states are size-invariant."""
    cfg = model.cfg

    def pad_kv(leaf, axis, target):
        if leaf.shape[axis] >= target:
            return leaf
        pad = [(0, 0)] * leaf.ndim
        pad[axis] = (0, target - leaf.shape[axis])
        return jnp.pad(leaf, pad)

    out = {"groups": {}, "tail": {}}
    for i, kind in enumerate(cfg.pattern):
        key = f"blk{i}_{kind}"
        sub = cache["groups"][key]
        if kind == "attn":
            out["groups"][key] = {k: pad_kv(v, 2, max_seq) for k, v in sub.items()}
        elif kind == "local":
            out["groups"][key] = {
                k: pad_kv(v, 2, min(cfg.window, max_seq)) for k, v in sub.items()
            }
        else:
            out["groups"][key] = sub
    for i, kind in enumerate(model.tail_kinds):
        key = f"tail{i}_{kind}"
        sub = cache["tail"][key]
        if kind == "attn":
            out["tail"][key] = {k: pad_kv(v, 1, max_seq) for k, v in sub.items()}
        elif kind == "local":
            out["tail"][key] = {
                k: pad_kv(v, 1, min(cfg.window, max_seq)) for k, v in sub.items()
            }
        else:
            out["tail"][key] = sub
    if "enc_out" in cache:
        out["enc_out"] = cache["enc_out"]
    return out
