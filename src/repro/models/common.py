"""Shared model utilities: param specs (single source of truth for shapes,
logical sharding axes, and init), norms, RoPE, losses, and the scan-unroll
switch used by the dry-run's cost probes."""

from __future__ import annotations

import contextlib
import dataclasses
import math
import threading
from typing import Any, Callable, Dict, Mapping, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

# -- scan unrolling (dry-run cost probes) -----------------------------------------
# XLA's cost_analysis counts a While body ONCE regardless of trip count, so
# the roofline probes lower small models with every layer/chunk scan fully
# unrolled (sLSTM's per-token scan excepted — corrected analytically).
_UNROLL = threading.local()


def force_unroll() -> bool:
    return getattr(_UNROLL, "on", False)


@contextlib.contextmanager
def unrolled_scans():
    prev = force_unroll()
    _UNROLL.on = True
    try:
        yield
    finally:
        _UNROLL.on = prev


def maybe_unrolled_scan(body, carry, xs, length: Optional[int] = None):
    """lax.scan that fully unrolls under the probe context."""
    if force_unroll():
        n = length
        if n is None:
            n = jax.tree_util.tree_leaves(xs)[0].shape[0]
        return jax.lax.scan(body, carry, xs, length=length, unroll=max(int(n), 1))
    return jax.lax.scan(body, carry, xs, length=length)

# -- parameter specs --------------------------------------------------------------
# A ParamSpec maps param name -> (shape, logical_axes, init).
# init: "normal" (trunc-normal 0.02), "zeros", "ones", or a float std.
ParamSpec = Dict[str, Tuple[Tuple[int, ...], Tuple[Optional[str], ...], Any]]


def init_from_spec(spec: ParamSpec, key: jax.Array, dtype=jnp.float32) -> Dict[str, jax.Array]:
    out = {}
    names = sorted(spec)
    keys = jax.random.split(key, max(len(names), 1))
    for k, name in zip(keys, names):
        shape, _axes, init = spec[name]
        if init == "zeros":
            out[name] = jnp.zeros(shape, dtype)
        elif init == "ones":
            out[name] = jnp.ones(shape, dtype)
        else:
            std = 0.02 if init == "normal" else float(init)
            out[name] = (jax.random.truncated_normal(k, -2.0, 2.0, shape, jnp.float32) * std).astype(dtype)
    return out


def axes_from_spec(spec: ParamSpec) -> Dict[str, Tuple[Optional[str], ...]]:
    return {name: spec[name][1] for name in spec}


def stack_spec(spec: ParamSpec, n: int) -> ParamSpec:
    """Prepend a scanned 'layers' axis of size n to every param."""
    return {
        name: ((n,) + shape, ("layers",) + axes, init)
        for name, (shape, axes, init) in spec.items()
    }


# -- norms ------------------------------------------------------------------------
def rms_norm(x: jax.Array, weight: jax.Array, eps: float = 1e-6) -> jax.Array:
    dtype = x.dtype
    x32 = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x32), axis=-1, keepdims=True)
    y = x32 * jax.lax.rsqrt(var + eps)
    return (y * weight.astype(jnp.float32)).astype(dtype)


def layer_norm(x: jax.Array, weight: jax.Array, bias: jax.Array, eps: float = 1e-5) -> jax.Array:
    dtype = x.dtype
    x32 = x.astype(jnp.float32)
    mu = jnp.mean(x32, axis=-1, keepdims=True)
    var = jnp.var(x32, axis=-1, keepdims=True)
    y = (x32 - mu) * jax.lax.rsqrt(var + eps)
    return (y * weight.astype(jnp.float32) + bias.astype(jnp.float32)).astype(dtype)


# -- rotary embeddings ---------------------------------------------------------------
def rope_frequencies(head_dim: int, theta: float) -> jax.Array:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float = 10000.0) -> jax.Array:
    """x: (..., S, n_heads, head_dim); positions: (..., S) int32."""
    head_dim = x.shape[-1]
    freqs = rope_frequencies(head_dim, theta)  # (hd/2,)
    angles = positions[..., None].astype(jnp.float32) * freqs  # (..., S, hd/2)
    sin = jnp.sin(angles)[..., None, :]  # (..., S, 1, hd/2)
    cos = jnp.cos(angles)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# -- loss ----------------------------------------------------------------------------
def cross_entropy(logits: jax.Array, labels: jax.Array, mask: Optional[jax.Array] = None) -> jax.Array:
    """Mean next-token CE.  logits (B,S,V) any float dtype; labels (B,S) int."""
    logits = logits.astype(jnp.float32)
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    nll = logz - gold
    if mask is not None:
        return jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)
    return jnp.mean(nll)


# -- misc ------------------------------------------------------------------------------
def dtype_of(name: str):
    return {"bfloat16": jnp.bfloat16, "float32": jnp.float32, "float16": jnp.float16}[name]


def tree_size_bytes(tree) -> int:
    return sum(
        int(np.prod(x.shape)) * x.dtype.itemsize for x in jax.tree_util.tree_leaves(tree)
    )
