"""Batched serving engine: continuous-batching-lite decode loop over a jitted
decode_step, with per-slot request lifecycle (admit → decode → finish)."""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..models.lm import Model
from ..models.registry import extend_cache


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray               # (S,) int32
    max_new_tokens: int = 16
    output: List[int] = dataclasses.field(default_factory=list)
    done: bool = False


class ServingEngine:
    """Fixed-slot batched decoding.  Admission fills empty slots; every step
    decodes one token for all active slots (padding-token for idle ones)."""

    def __init__(self, model: Model, params, batch_slots: int = 4, max_seq: int = 256):
        self.model = model
        self.params = params
        self.slots = batch_slots
        self.max_seq = max_seq
        self._decode = jax.jit(model.decode_step)
        self._requests: List[Optional[Request]] = [None] * batch_slots
        self._pos = np.zeros(batch_slots, np.int32)
        self.cache = model.init_cache(batch_slots, max_seq)
        self.steps = 0

    # Greedy sampling (temperature 0) keeps the engine deterministic for tests.
    def _sample(self, logits: jax.Array) -> np.ndarray:
        return np.asarray(jnp.argmax(logits[:, -1], axis=-1), np.int32)

    def admit(self, req: Request) -> bool:
        for i, slot in enumerate(self._requests):
            if slot is None:
                self._requests[i] = req
                # Prefill the slot by feeding prompt tokens one at a time
                # (keeps a single compiled decode fn; a production engine
                # would use the batched prefill path per slot).
                for j, tok in enumerate(req.prompt):
                    t = jnp.zeros((self.slots, 1), jnp.int32).at[i, 0].set(int(tok))
                    logits, self.cache = self._decode(
                        self.params, self.cache, t, jnp.int32(j)
                    )
                self._pos[i] = len(req.prompt)
                return True
        return False

    def step(self) -> None:
        active = [i for i, r in enumerate(self._requests) if r is not None]
        if not active:
            return
        toks = np.zeros((self.slots, 1), np.int32)
        for i in active:
            r = self._requests[i]
            toks[i, 0] = r.output[-1] if r.output else (r.prompt[-1] if len(r.prompt) else 1)
        pos = int(max(self._pos[i] for i in active))
        logits, self.cache = self._decode(
            self.params, self.cache, jnp.asarray(toks), jnp.int32(pos)
        )
        nxt = self._sample(logits)
        for i in active:
            r = self._requests[i]
            r.output.append(int(nxt[i]))
            self._pos[i] += 1
            if len(r.output) >= r.max_new_tokens or self._pos[i] >= self.max_seq - 1:
                r.done = True
                self._requests[i] = None
        self.steps += 1

    def run(self, requests: List[Request], max_steps: int = 512) -> List[Request]:
        pending = list(requests)
        finished: List[Request] = []
        while (pending or any(r is not None for r in self._requests)) and self.steps < max_steps:
            while pending and self.admit(pending[0]):
                pending.pop(0)
            self.step()
            finished = [r for r in requests if r.done]
            if len(finished) == len(requests):
                break
        return requests
