from .engine import Request, ServingEngine

__all__ = ["Request", "ServingEngine"]
