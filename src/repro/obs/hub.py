"""``MetricsHub`` -- the shared registry the three planes report through.

One hub holds every metric of a run, keyed on ``(kind, name, labels)``,
plus span-style trace events ordered by a hub-assigned monotone ``seq``
counter (the deterministic clock; wall durations are profiling-only
side data).  Export is deterministically sorted JSONL: fixed seed ==
byte-identical telemetry.

Ambient activation
------------------
Instrumented subsystems (DES executor, ``BatchAnnealer``,
``SearchScheduler``) resolve their hub via :func:`get_hub` at run time,
so the control plane can instrument everything it constructs with one
``with hub.activate():`` block and zero parameter plumbing.  The default
ambient hub is :data:`NULL_HUB`, a disabled hub whose accessors hand out
inert singletons and retain **zero** state -- the disabled path is a
couple of attribute checks, so hot loops keep their benchmarked numbers.
"""

from __future__ import annotations

import json
from typing import Dict, Iterable, List, Optional, Tuple

from . import clock
from .metrics import (
    DEFAULT_BUCKETS,
    KIND_OF,
    Counter,
    Gauge,
    Histogram,
    Series,
)

#: Registry key: (kind, name, sorted label items).
Key = Tuple[str, str, Tuple[Tuple[str, object], ...]]


def _key(kind: str, name: str, labels: Dict[str, object]) -> Key:
    return (kind, name, tuple(sorted(labels.items())))


def _sort_key(key: Key):
    # Label *values* may mix int and str across metrics sharing a label
    # name; stringify so the export order is total (and deterministic).
    kind, name, labels = key
    return (kind, name, tuple((lk, str(lv)) for lk, lv in labels))


class _NullMetric:
    """Inert sink a disabled hub hands out -- every mutator is a no-op."""

    __slots__ = ()

    def inc(self, n: int = 1) -> None:
        pass

    def set(self, value: float) -> None:
        pass

    def append(self, t: float, value: float) -> None:
        pass

    def observe(self, value: float) -> None:
        pass


NULL_METRIC = _NullMetric()


class _NullSpan:
    """Inert context manager a disabled hub hands out for spans."""

    __slots__ = ()

    def __enter__(self) -> "_NullSpan":
        return self

    def __exit__(self, *exc) -> bool:
        return False

    def set(self, **meta) -> "_NullSpan":
        return self


NULL_SPAN = _NullSpan()


class Span:
    """One trace event: hub-assigned ``seq`` + parent link + typed meta.

    ``seq`` and ``parent`` (the enclosing span's seq, via the hub's
    open-span stack) are the deterministic clock; ``wall_s`` is measured
    through ``obs.clock`` for profiling and excluded from export unless
    ``include_wall=True``.
    """

    __slots__ = ("name", "labels", "seq", "parent", "meta", "wall_s", "_hub", "_t0")

    def __init__(self, hub: "MetricsHub", name: str, labels: Dict[str, object]):
        self._hub = hub
        self.name = name
        self.labels = labels
        self.seq: Optional[int] = None
        self.parent: Optional[int] = None
        self.meta: Dict[str, object] = {}
        self.wall_s: float = 0.0
        self._t0 = 0.0

    def __enter__(self) -> "Span":
        hub = self._hub
        self.seq = hub._seq
        hub._seq += 1
        self.parent = hub._stack[-1].seq if hub._stack else None
        hub._stack.append(self)
        hub._spans.append(self)
        self._t0 = clock.perf_counter()
        return self

    def __exit__(self, *exc) -> bool:
        self.wall_s = clock.perf_counter() - self._t0
        self._hub._stack.pop()
        return False

    def set(self, **meta) -> "Span":
        """Attach deterministic metadata (counts, sizes -- never wall time)."""
        self.meta.update(meta)
        return self


class MetricsHub:
    """Typed metric registry + trace-span collector with JSONL export.

    Accessors are create-or-get on ``(kind, name, labels)``; a disabled
    hub (``enabled=False``) returns shared inert singletons and retains
    zero state, which is what makes ambient instrumentation free when no
    observer asked for telemetry.
    """

    def __init__(self, enabled: bool = True) -> None:
        self.enabled = enabled
        self._metrics: Dict[Key, object] = {}
        self._spans: List[Span] = []
        self._stack: List[Span] = []
        self._seq = 0

    # -- registry -----------------------------------------------------------
    def counter(self, name: str, **labels):
        if not self.enabled:
            return NULL_METRIC
        key = _key("counter", name, labels)
        m = self._metrics.get(key)
        if m is None:
            m = self._metrics[key] = Counter()
        return m

    def gauge(self, name: str, **labels):
        if not self.enabled:
            return NULL_METRIC
        key = _key("gauge", name, labels)
        m = self._metrics.get(key)
        if m is None:
            m = self._metrics[key] = Gauge()
        return m

    def series(self, name: str, **labels):
        if not self.enabled:
            return NULL_METRIC
        key = _key("series", name, labels)
        m = self._metrics.get(key)
        if m is None:
            m = self._metrics[key] = Series()
        return m

    def histogram(self, name: str, buckets=DEFAULT_BUCKETS, **labels):
        if not self.enabled:
            return NULL_METRIC
        key = _key("histogram", name, labels)
        m = self._metrics.get(key)
        if m is None:
            m = self._metrics[key] = Histogram(buckets)
        return m

    def attach(self, name: str, metric, **labels):
        """Register an externally-created metric under this hub's registry.

        The DES executor always builds its latency/queue-depth histograms
        (``DesReport`` percentiles come from them); when a hub is active
        they are attached so the export shows the identical objects.
        Re-attaching the same key replaces the previous metric (the most
        recent run wins -- scenario timelines capture per-interval data
        through dedicated series instead).
        """
        if not self.enabled:
            return metric
        self._metrics[_key(KIND_OF[type(metric)], name, labels)] = metric
        return metric

    def find(self, kind: str, name: str) -> List[Tuple[Dict[str, object], object]]:
        """All ``(labels, metric)`` for one (kind, name), in export order."""
        out = []
        for key in sorted(self._metrics, key=_sort_key):
            k, n, labels = key
            if k == kind and n == name:
                out.append((dict(labels), self._metrics[key]))
        return out

    # -- spans --------------------------------------------------------------
    def span(self, name: str, **labels):
        if not self.enabled:
            return NULL_SPAN
        return Span(self, name, labels)

    # -- export -------------------------------------------------------------
    def records(self, include_wall: bool = False) -> List[Dict[str, object]]:
        """Deterministically ordered plain dicts: sorted metrics, then
        spans in ``seq`` order.  Wall durations only with ``include_wall``."""
        out: List[Dict[str, object]] = []
        for key in sorted(self._metrics, key=_sort_key):
            kind, name, labels = key
            rec: Dict[str, object] = {"kind": kind, "name": name, "labels": dict(labels)}
            rec.update(self._metrics[key].record())
            out.append(rec)
        for sp in self._spans:
            rec = {
                "kind": "span",
                "name": sp.name,
                "labels": dict(sp.labels),
                "seq": sp.seq,
                "parent": sp.parent,
                "meta": dict(sp.meta),
            }
            if include_wall:
                rec["wall_s"] = sp.wall_s
            out.append(rec)
        return out

    def to_jsonl(self, include_wall: bool = False) -> str:
        lines = [
            json.dumps(rec, sort_keys=True, separators=(",", ":"))
            for rec in self.records(include_wall)
        ]
        return "".join(line + "\n" for line in lines)

    def export(self, path: str, include_wall: bool = False) -> str:
        with open(path, "w") as fh:
            fh.write(self.to_jsonl(include_wall))
        return path

    # -- ambient activation -------------------------------------------------
    def activate(self) -> "_Activation":
        """Make this hub the ambient :func:`get_hub` target for a block."""
        return _Activation(self)


class _Activation:
    __slots__ = ("_hub", "_prev")

    def __init__(self, hub: MetricsHub) -> None:
        self._hub = hub
        self._prev: Optional[MetricsHub] = None

    def __enter__(self) -> MetricsHub:
        global _CURRENT
        self._prev = _CURRENT
        _CURRENT = self._hub
        return self._hub

    def __exit__(self, *exc) -> bool:
        global _CURRENT
        _CURRENT = self._prev
        return False


#: The disabled ambient default: zero-state, inert accessors.
NULL_HUB = MetricsHub(enabled=False)

_CURRENT: MetricsHub = NULL_HUB


def get_hub() -> MetricsHub:
    """The ambient hub (``NULL_HUB`` unless an ``activate()`` is open)."""
    return _CURRENT
