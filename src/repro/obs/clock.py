"""The observability plane's single wall-clock shim.

Everything exported by ``repro.obs`` is clocked on sim-time or explicit
step counters so a fixed seed yields byte-identical telemetry.  The one
legitimate consumer of wall time is the profiling hooks -- span
``wall_s`` durations and the swaps/s rates derived from them -- and
those route exclusively through this module so repro-lint's wall-clock
rule can confine ``time.perf_counter`` to exactly one justified site in
the instrumented tree.  Wall fields are excluded from JSONL export
unless ``include_wall=True`` is passed, mirroring how scenario traces
scrub ``schedule_time_s`` to keep goldens stable.
"""

from __future__ import annotations

import time


def perf_counter() -> float:
    """Monotonic wall clock for span durations and scheduler timing.

    Never feeds an exported golden: hub export drops wall fields by
    default, and ``Assignment.schedule_time_s`` is scrubbed on replay.
    """
    return time.perf_counter()  # repro-lint: allow(hot-loop) the tree's one justified wall-clock site; profiling-only, excluded from exported goldens
