# Deterministic observability plane: a typed metric registry + span traces
# shared by the DES executor, the batched search, and the Nimbus control
# plane.  Everything is clocked on sim-time or explicit step counters so a
# fixed seed yields byte-identical JSONL telemetry; ``obs.clock`` is the one
# justified wall-clock shim (span durations, profiling only).
from .hub import NULL_HUB, NULL_METRIC, NULL_SPAN, MetricsHub, Span, get_hub
from .metrics import (
    DEFAULT_BUCKETS,
    QUEUE_DEPTH_BUCKETS,
    Counter,
    Gauge,
    Histogram,
    Series,
)

__all__ = [
    "MetricsHub",
    "Span",
    "get_hub",
    "NULL_HUB",
    "NULL_METRIC",
    "NULL_SPAN",
    "Counter",
    "Gauge",
    "Series",
    "Histogram",
    "DEFAULT_BUCKETS",
    "QUEUE_DEPTH_BUCKETS",
]
