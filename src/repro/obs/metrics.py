"""Typed metric primitives for the deterministic observability plane.

Four metric kinds, all clocked on **sim-time or explicit step counters**
-- never wall clock -- so a fixed seed yields byte-identical telemetry:

- ``Counter``   monotone integer event counts (acks, drops, replays).
- ``Gauge``     last-write-wins scalar (acceptance rate, final node util).
- ``Series``    ``(t, value)`` points where ``t`` is sim-time seconds or a
  step/swap index -- the time-series shape DRS-style reactive control
  consumes.
- ``Histogram`` raw-sample distribution with fixed bucket upper bounds.
  Percentiles are **exact** -- ``np.percentile`` over the retained
  samples, the identical code path ``DesReport`` uses -- and the fixed
  ``le``-style buckets only shape the exported coarse view (they are
  computed lazily, so ``observe`` stays a bare list append on the DES
  hot path).

Every metric renders itself to a plain JSON-safe dict via ``record()``;
the ``MetricsHub`` (``repro.obs.hub``) owns naming, labels, and export.
"""

from __future__ import annotations

import math
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

#: Generic latency-style upper bounds (seconds), roughly 1-2-5 per decade.
DEFAULT_BUCKETS: Tuple[float, ...] = (
    0.0005, 0.001, 0.002, 0.005, 0.01, 0.02, 0.05,
    0.1, 0.2, 0.5, 1.0, 2.0, 5.0,
)

#: Dyadic upper bounds for queue-depth style integer samples.
QUEUE_DEPTH_BUCKETS: Tuple[float, ...] = (
    0.0, 1.0, 2.0, 4.0, 8.0, 16.0, 32.0, 64.0, 128.0, 256.0, 512.0, 1024.0,
)


class Counter:
    """Monotone integer event counter."""

    __slots__ = ("value",)

    def __init__(self) -> None:
        self.value: int = 0

    def inc(self, n: int = 1) -> None:
        self.value += n

    def record(self) -> Dict[str, object]:
        return {"value": self.value}


class Gauge:
    """Last-write-wins scalar; ``value`` is ``None`` until first set."""

    __slots__ = ("value",)

    def __init__(self) -> None:
        self.value: Optional[float] = None

    def set(self, value: float) -> None:
        self.value = value

    def record(self) -> Dict[str, object]:
        return {"value": self.value}


class Series:
    """Ordered ``(t, value)`` points; ``t`` is sim-time or a step index."""

    __slots__ = ("points",)

    def __init__(self) -> None:
        self.points: List[List[float]] = []

    def append(self, t: float, value: float) -> None:
        self.points.append([t, value])

    def record(self) -> Dict[str, object]:
        return {"points": self.points}


class Histogram:
    """Fixed-bucket histogram with exact percentile extraction.

    Raw samples are retained (``observe`` is a bare append -- the DES
    latency path budget is <5% overhead), so ``percentiles`` can return
    the *exact* p50/p95/p99 rather than bucket-interpolated estimates;
    ``bucket_counts`` bins the same samples against the fixed ``le``
    upper bounds lazily at export time.
    """

    __slots__ = ("buckets", "values")

    def __init__(self, buckets: Sequence[float] = DEFAULT_BUCKETS) -> None:
        self.buckets: Tuple[float, ...] = tuple(float(b) for b in buckets)
        self.values: List[float] = []

    def observe(self, value: float) -> None:
        self.values.append(value)

    def __len__(self) -> int:
        return len(self.values)

    def percentiles(
        self, qs: Sequence[float] = (50.0, 95.0, 99.0)
    ) -> Tuple[Optional[float], ...]:
        """Exact percentiles over the retained samples (``None`` when empty).

        This is *the* percentile code path: ``DesReport`` latency and
        queue-depth percentiles call it, and the JSONL export re-renders
        the same values -- one implementation, pinned equal by test.
        """
        if not self.values:
            return tuple(None for _ in qs)
        arr = np.asarray(self.values, dtype=np.float64)
        return tuple(float(v) for v in np.percentile(arr, list(qs)))

    def mean(self) -> float:
        if not self.values:
            return 0.0
        return math.fsum(self.values) / len(self.values)

    def bucket_counts(self) -> List[int]:
        """Per-bucket sample counts; the last slot is the +Inf overflow."""
        if not self.values:
            return [0] * (len(self.buckets) + 1)
        arr = np.asarray(self.values, dtype=np.float64)
        ub = np.asarray(self.buckets, dtype=np.float64)
        idx = np.searchsorted(ub, arr, side="left")
        counts = np.bincount(idx, minlength=len(self.buckets) + 1)
        return [int(c) for c in counts]

    def record(self) -> Dict[str, object]:
        p50, p95, p99 = self.percentiles()
        return {
            "count": len(self.values),
            "mean": self.mean(),
            "p50": p50,
            "p95": p95,
            "p99": p99,
            "buckets": list(self.buckets),
            "bucket_counts": self.bucket_counts(),
        }


#: kind tag used in registry keys and JSONL records, per metric class.
KIND_OF = {
    Counter: "counter",
    Gauge: "gauge",
    Series: "series",
    Histogram: "histogram",
}
