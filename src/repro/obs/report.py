"""Telemetry report CLI: ``python -m repro.obs.report``.

Stdlib-only (runs on the nojax CI leg) because the JSONL already carries
computed values -- exact percentiles, bucket counts, final gauges -- so
reporting is pure formatting:

    python -m repro.obs.report summarize run.jsonl [--top N]
    python -m repro.obs.report diff a.jsonl b.jsonl

``summarize`` prints counter/gauge tables, histogram percentile tables,
the top-k hot nodes by DES utilization, and the span tree (with wall
timings and derived swaps/s when the export included wall fields).
``diff`` aligns two runs on ``(kind, name, labels)`` and prints value
deltas plus added/removed metrics -- byte-identical runs diff empty.
"""

from __future__ import annotations

import argparse
import json
import os
import sys
from typing import Dict, List, Optional, Tuple


def load(path: str) -> List[dict]:
    records = []
    with open(path) as fh:
        for line in fh:
            line = line.strip()
            if line:
                records.append(json.loads(line))
    return records


def _label_str(labels: Dict[str, object]) -> str:
    if not labels:
        return ""
    inner = ",".join(f"{k}={labels[k]}" for k in sorted(labels))
    return "{" + inner + "}"


def _ident(rec: dict) -> str:
    return f"{rec['name']}{_label_str(rec.get('labels', {}))}"


def _fmt(v: Optional[float]) -> str:
    if v is None:
        return "-"
    if isinstance(v, float):
        return f"{v:.6g}"
    return str(v)


def _by_kind(records: List[dict]) -> Dict[str, List[dict]]:
    out: Dict[str, List[dict]] = {}
    for rec in records:
        out.setdefault(rec.get("kind", "?"), []).append(rec)
    return out


def _span_depth(rec: dict, by_seq: Dict[int, dict]) -> int:
    depth = 0
    parent = rec.get("parent")
    while parent is not None and parent in by_seq:
        depth += 1
        parent = by_seq[parent].get("parent")
    return depth


def summarize(path: str, top: int = 5, out=None) -> None:
    out = sys.stdout if out is None else out  # resolve at call, not import
    records = load(path)
    kinds = _by_kind(records)
    print(f"# {path}: {len(records)} records", file=out)

    for kind in ("counter", "gauge"):
        recs = kinds.get(kind, [])
        if recs:
            print(f"\n## {kind}s ({len(recs)})", file=out)
            for rec in recs:
                print(f"  {_ident(rec)} = {_fmt(rec.get('value'))}", file=out)

    hists = kinds.get("histogram", [])
    if hists:
        print(f"\n## histograms ({len(hists)})", file=out)
        print("  name count mean p50 p95 p99", file=out)
        for rec in hists:
            cells = " ".join(
                _fmt(rec.get(c)) for c in ("count", "mean", "p50", "p95", "p99")
            )
            print(f"  {_ident(rec)} {cells}", file=out)

    series = kinds.get("series", [])
    if series:
        print(f"\n## series ({len(series)})", file=out)
        for rec in series:
            pts = rec.get("points", [])
            last = _fmt(pts[-1][1]) if pts else "-"
            print(f"  {_ident(rec)}: {len(pts)} points, last={last}", file=out)

    # Top-k hot nodes: final DES per-node utilization gauges, hottest first.
    utils = [
        rec
        for rec in kinds.get("gauge", [])
        if rec["name"] == "des.node_utilization" and rec.get("value") is not None
    ]
    if utils:
        utils.sort(key=lambda rec: (-rec["value"], _ident(rec)))
        print(f"\n## top-{top} hot nodes", file=out)
        for rec in utils[:top]:
            print(f"  {_ident(rec)} util={_fmt(rec['value'])}", file=out)

    spans = kinds.get("span", [])
    if spans:
        print(f"\n## spans ({len(spans)})", file=out)
        by_seq = {rec["seq"]: rec for rec in spans}
        for rec in spans:
            indent = "  " * _span_depth(rec, by_seq)
            meta = rec.get("meta", {})
            parts = [f"{indent}[{rec['seq']}] {_ident(rec)}"]
            if meta:
                parts.append(
                    " ".join(f"{k}={_fmt(meta[k])}" for k in sorted(meta))
                )
            wall = rec.get("wall_s")
            if wall is not None:
                parts.append(f"wall={wall * 1e3:.2f}ms")
                # swaps/s: the annealer span carries its proposal count.
                if isinstance(meta.get("proposals"), (int, float)) and wall > 0:
                    parts.append(f"swaps_per_s={meta['proposals'] / wall:.3g}")
            print("  " + " ".join(parts), file=out)


def _scalar_fields(rec: dict) -> Dict[str, object]:
    kind = rec.get("kind")
    if kind in ("counter", "gauge"):
        return {"value": rec.get("value")}
    if kind == "histogram":
        return {c: rec.get(c) for c in ("count", "mean", "p50", "p95", "p99")}
    if kind == "series":
        pts = rec.get("points", [])
        return {"n_points": len(pts), "last": pts[-1][1] if pts else None}
    return {}


def diff(path_a: str, path_b: str, out=None) -> int:
    """Print per-metric deltas; return the number of differing records."""
    out = sys.stdout if out is None else out  # resolve at call, not import

    def index(path: str) -> Dict[Tuple[str, str, str], dict]:
        out_idx = {}
        for rec in load(path):
            if rec.get("kind") == "span":
                key = ("span", str(rec.get("seq")), rec.get("name", ""))
            else:
                key = (
                    rec.get("kind", "?"),
                    rec.get("name", ""),
                    json.dumps(rec.get("labels", {}), sort_keys=True),
                )
            out_idx[key] = rec
        return out_idx

    a, b = index(path_a), index(path_b)
    n_diff = 0
    for key in sorted(set(a) | set(b), key=str):
        ra, rb = a.get(key), b.get(key)
        if ra is None:
            print(f"+ only in {path_b}: {_ident(rb)} ({rb['kind']})", file=out)
            n_diff += 1
            continue
        if rb is None:
            print(f"- only in {path_a}: {_ident(ra)} ({ra['kind']})", file=out)
            n_diff += 1
            continue
        if ra.get("kind") == "span":
            if ra.get("meta") != rb.get("meta") or ra.get("parent") != rb.get("parent"):
                print(f"~ span [{ra['seq']}] {_ident(ra)}: meta/parent differ", file=out)
                n_diff += 1
            continue
        fa, fb = _scalar_fields(ra), _scalar_fields(rb)
        changed = {c for c in fa if fa[c] != fb.get(c)}
        if changed:
            n_diff += 1
            deltas = []
            for c in sorted(changed):
                va, vb = fa[c], fb.get(c)
                if isinstance(va, (int, float)) and isinstance(vb, (int, float)):
                    deltas.append(f"{c}: {_fmt(va)} -> {_fmt(vb)} ({vb - va:+.6g})")
                else:
                    deltas.append(f"{c}: {_fmt(va)} -> {_fmt(vb)}")
            print(f"~ {ra['kind']} {_ident(ra)}: " + "; ".join(deltas), file=out)
    if n_diff == 0:
        print("identical telemetry", file=out)
    return n_diff


def main(argv: Optional[List[str]] = None) -> int:
    parser = argparse.ArgumentParser(
        prog="python -m repro.obs.report",
        description="Summarize or diff deterministic telemetry JSONL exports.",
    )
    sub = parser.add_subparsers(dest="cmd", required=True)
    p_sum = sub.add_parser("summarize", help="one-run summary tables")
    p_sum.add_argument("path")
    p_sum.add_argument("--top", type=int, default=5, help="top-k hot nodes")
    p_diff = sub.add_parser("diff", help="align two runs and print deltas")
    p_diff.add_argument("path_a")
    p_diff.add_argument("path_b")
    args = parser.parse_args(argv)
    try:
        if args.cmd == "summarize":
            summarize(args.path, top=args.top)
            return 0
        return 1 if diff(args.path_a, args.path_b) else 0
    except BrokenPipeError:
        # Piping into `head` closes stdout early; exit quietly instead of
        # tracebacking (dup /dev/null over stdout so interpreter shutdown
        # does not raise again on flush).
        os.dup2(os.open(os.devnull, os.O_WRONLY), sys.stdout.fileno())
        return 0


if __name__ == "__main__":
    sys.exit(main())
