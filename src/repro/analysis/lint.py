"""repro-lint engine + CLI.

Usage::

    python -m repro.analysis.lint src benchmarks examples
    python -m repro.analysis.lint --list-rules
    python -m repro.analysis.lint --show-suppressed src

Walks the given files/directories, runs every zone-active rule
(:mod:`.zones`) over each Python file, and prints one ``path:line:col:
rule: message`` diagnostic per unsuppressed violation.  Exit status: 0
clean, 1 violations found, 2 usage/parse trouble.

Suppressions are in-place annotations::

    t0 = time.perf_counter()  # repro-lint: allow(hot-loop) schedule_time_s

``allow(rule-a, rule-b)`` lists rules; ``allow(*)`` suppresses everything on
the line.  A suppression comment on its own line covers the next code line
(intervening comment lines are skipped), so constructs can be annotated
above with a multi-line justification.  Everything after the closing paren
is the justification — it is required reading for reviewers, not for the
tool.
"""

from __future__ import annotations

import argparse
import ast
import re
import sys
from pathlib import Path
from typing import Dict, Iterable, List, Sequence, Set, Tuple

from .rules import RULES, RuleContext, Violation
from .zones import rules_for_path, set_attrs_for_path, x64_exempt

_ALLOW_RE = re.compile(r"#\s*repro-lint:\s*allow\(([^)]*)\)")


def _collect_allows(source: str) -> Dict[int, Set[str]]:
    """line number -> set of rule names allowed there (``*`` = all).

    A comment-only allow covers the next non-comment line, so annotations
    (and their multi-line justifications) can sit above the construct.
    """
    allows: Dict[int, Set[str]] = {}
    lines = source.splitlines()
    for i, line in enumerate(lines, start=1):
        m = _ALLOW_RE.search(line)
        if not m:
            continue
        names = {n.strip() for n in m.group(1).split(",") if n.strip()}
        allows.setdefault(i, set()).update(names)
        if line.lstrip().startswith("#"):
            j = i + 1
            while j <= len(lines) and lines[j - 1].lstrip().startswith("#"):
                j += 1
            allows.setdefault(j, set()).update(names)
    return allows


def lint_source(
    source: str, path: str, active_rules: Sequence[str] | None = None
) -> Tuple[List[Violation], List[Violation]]:
    """Lint one file's text; returns ``(violations, suppressed)``.

    ``active_rules`` overrides the zone lookup (used by the rule fixtures);
    by default the path decides which rules run — a file outside every zone
    produces nothing.
    """
    rules = rules_for_path(path) if active_rules is None else tuple(active_rules)
    if not rules:
        return [], []
    try:
        tree = ast.parse(source, filename=path)
    except SyntaxError as exc:
        v = Violation(
            path=path,
            line=exc.lineno or 1,
            col=exc.offset or 0,
            rule="parse-error",
            message=f"cannot parse: {exc.msg}",
        )
        return [v], []
    ctx = RuleContext(
        path=path,
        set_attrs=set_attrs_for_path(path),
        x64_exempt=x64_exempt(path),
    )
    found: List[Violation] = []
    for name in rules:
        found.extend(RULES[name](tree, ctx))
    allows = _collect_allows(source)
    kept: List[Violation] = []
    suppressed: List[Violation] = []
    for v in sorted(found):
        allowed = allows.get(v.line, set())
        (suppressed if (v.rule in allowed or "*" in allowed) else kept).append(v)
    return kept, suppressed


def _iter_py_files(paths: Iterable[str]) -> List[Path]:
    out: List[Path] = []
    for p in paths:
        path = Path(p)
        if path.is_dir():
            out.extend(
                f
                for f in sorted(path.rglob("*.py"))
                if "__pycache__" not in f.parts
            )
        elif path.suffix == ".py":
            out.append(path)
        elif not path.exists():
            raise FileNotFoundError(p)
    return out


def lint_paths(
    paths: Iterable[str],
) -> Tuple[List[Violation], List[Violation], int]:
    """Lint files/trees; returns ``(violations, suppressed, files_in_zone)``."""
    violations: List[Violation] = []
    suppressed: List[Violation] = []
    n_zone = 0
    for f in _iter_py_files(paths):
        rel = f.as_posix()
        if not rules_for_path(rel):
            continue
        n_zone += 1
        kept, supp = lint_source(f.read_text(encoding="utf-8"), rel)
        violations.extend(kept)
        suppressed.extend(supp)
    return sorted(violations), sorted(suppressed), n_zone


def main(argv: Sequence[str] | None = None) -> int:
    parser = argparse.ArgumentParser(
        prog="repro.analysis.lint",
        description="determinism & jax-purity static analysis for this repo",
    )
    parser.add_argument(
        "paths", nargs="*", default=["src", "benchmarks", "examples"],
        help="files or directories to lint (default: src benchmarks examples)",
    )
    parser.add_argument(
        "--list-rules", action="store_true", help="print rule names and exit"
    )
    parser.add_argument(
        "--show-suppressed", action="store_true",
        help="also print violations silenced by repro-lint: allow(...)",
    )
    args = parser.parse_args(argv)

    if args.list_rules:
        for name in sorted(RULES):
            print(name)
        return 0

    try:
        violations, suppressed, n_zone = lint_paths(args.paths)
    except FileNotFoundError as exc:
        print(f"repro-lint: no such path: {exc}", file=sys.stderr)
        return 2

    for v in violations:
        print(v.render())
    if args.show_suppressed:
        for v in suppressed:
            print(f"{v.render()} [suppressed]")
    print(
        f"repro-lint: {len(violations)} violation(s), "
        f"{len(suppressed)} suppressed, {n_zone} file(s) in zones",
        file=sys.stderr,
    )
    if any(v.rule == "parse-error" for v in violations):
        return 2
    return 1 if violations else 0


if __name__ == "__main__":
    sys.exit(main())
