"""repro-lint — static enforcement of the repo's determinism contracts.

The scheduling/scoring stack promises bit-reproducible results: golden
numpy/jax backend equality, deterministic scenario replays, and a CI
quality-regression gate all depend on it.  Those contracts used to live in
comments ("FMA-contraction-safe", "dyadic grid", "no exp in the hot loop")
and after-the-fact golden tests; this package rejects determinism-breaking
*code* before it ships.

Entry points:

* ``python -m repro.analysis.lint src benchmarks examples`` — CLI;
* :func:`repro.analysis.lint.lint_paths` — programmatic API;
* ``tests/test_analysis_lint.py`` — tier-1 test pinning the tree clean.

See :mod:`repro.analysis.zones` for which rules run where and
:mod:`repro.analysis.rules` for what each rule rejects.  Deliberate
violations are annotated in place with ``# repro-lint: allow(<rule>)``.
"""

from .rules import RULES, Violation  # noqa: F401
from .zones import ZONES, rules_for_path, set_attrs_for_path  # noqa: F401

_LINT_EXPORTS = ("lint_paths", "lint_source", "main")


def __getattr__(name):
    # Lazy so `python -m repro.analysis.lint` does not import .lint twice
    # (runpy would warn about the module already being in sys.modules).
    if name in _LINT_EXPORTS:
        from . import lint

        return getattr(lint, name)
    raise AttributeError(name)
