"""Deterministic-zone configuration: which lint rules apply to which files.

A *zone* is a set of path anchors plus the rule names enforced there.  Zones
overlap — a file's active rule set is the union over every zone that matches
it (``repro/core/search/anneal.py`` picks up both the core determinism rules
and the stricter hot-loop rules).

Matching is purely textual on posix path segments, so the linter works the
same whether it is handed ``src`` from the repo root, absolute paths, or a
single file.

Zone knowledge is also where repo-specific type facts live: the ``iter-order``
rule cannot infer that ``ResourceVector.dims`` returns a ``frozenset`` from a
different module, so the attribute names that are known set-valued across the
codebase are declared here (``SET_ATTRS``).
"""

from __future__ import annotations

import dataclasses
from pathlib import PurePosixPath
from typing import Tuple

#: Attribute names that return ``set``/``frozenset`` across the repo
#: (``ResourceVector.dims``/``.soft_dims``/``.hard``).  Iterating them
#: unsorted is exactly the hazard the iter-order rule exists to catch.
SET_ATTRS: Tuple[str, ...] = ("dims", "soft_dims", "hard")

#: The one module allowed to touch jax float64 config: the scoped
#: ``enable_x64`` helper.  Everything else must use ``backend.x64()``.
X64_ALLOWED: Tuple[str, ...] = ("repro/core/search/backend.py",)


@dataclasses.dataclass(frozen=True)
class Zone:
    """One deterministic zone: where it applies and what it enforces."""

    name: str
    anchors: Tuple[str, ...]  # path-segment anchors, e.g. "repro/core"
    rules: Tuple[str, ...]
    set_attrs: Tuple[str, ...] = ()


ZONES: Tuple[Zone, ...] = (
    # The scheduling core, the control-plane API, and the discrete-event
    # executor: everything that decides placements, serializes results, or
    # referees a placement's measured performance must be replay-
    # deterministic (the DES's bit-identical-trace contract hangs on it:
    # every random draw flows from one seeded Philox root).
    Zone(
        name="core",
        anchors=("repro/core", "repro/api", "repro/stream/des"),
        rules=(
            "unseeded-random",
            "iter-order",
            "float-sum",
            "np-reduce-dtype",
            "jax-purity",
            "x64-scope",
        ),
        set_attrs=SET_ATTRS,
    ),
    # The annealer step paths: beyond determinism, the hot-loop contract
    # (no deepcopy, no libm transcendentals, no wall-clock reads) and the
    # float64-only exactness contract apply.
    Zone(
        name="hot-loop",
        anchors=(
            "repro/core/engine",
            "repro/core/search",
            "repro/core/reconfig",
        ),
        rules=("hot-loop", "float32-literal"),
        set_attrs=SET_ATTRS,
    ),
    # The observability plane: telemetry must itself be deterministic (a
    # fixed seed exports byte-identical JSONL), so the registry/hub/report
    # code carries the core determinism rules plus hot-loop — wall-clock
    # reads are confined to the one allow-listed shim in ``obs/clock.py``.
    Zone(
        name="obs",
        anchors=("repro/obs",),
        rules=(
            "unseeded-random",
            "iter-order",
            "float-sum",
            "np-reduce-dtype",
            "hot-loop",
        ),
        set_attrs=SET_ATTRS,
    ),
    # Benchmarks and examples feed the committed quality baselines and the
    # documented replays — their numbers must be as reproducible as the
    # core's (timing columns are exempt by design, so no hot-loop rules).
    Zone(
        name="harness",
        anchors=("benchmarks", "examples"),
        rules=("unseeded-random", "iter-order", "jax-purity", "x64-scope"),
        set_attrs=SET_ATTRS,
    ),
    # The Pallas kernel layer (accelerator kernels and the fused search
    # scorer): no interpret=True left on at committed call sites, no
    # program_id-dependent accumulation order, no silently-truncating
    # grids.
    Zone(
        name="kernels",
        anchors=("repro/kernels", "repro/core/search/kernels"),
        rules=(
            "pallas-interpret",
            "pallas-accum-order",
            "pallas-grid-truncate",
        ),
        set_attrs=SET_ATTRS,
    ),
    # The *search* kernels additionally carry the three-backend golden-
    # equality contract (kernel == jax-vmap == numpy, bit-identical), so
    # their accumulators must be float64/exact-int.  The float32 flash
    # kernels under repro/kernels are deliberately outside this subzone.
    Zone(
        name="kernel-exactness",
        anchors=("repro/core/search/kernels",),
        rules=("pallas-accum-dtype",),
        set_attrs=SET_ATTRS,
    ),
)


def _norm(path: str) -> str:
    """Posix form with a leading slash so anchor matches are segment-exact."""
    return "/" + PurePosixPath(str(path).replace("\\", "/")).as_posix().lstrip("/")


def _matches(path: str, anchor: str) -> bool:
    p = _norm(path)
    a = "/" + anchor.strip("/")
    return (a + "/") in p or p.endswith(a)


def zones_for_path(path: str) -> Tuple[Zone, ...]:
    return tuple(
        z for z in ZONES if any(_matches(path, a) for a in z.anchors)
    )


def rules_for_path(path: str) -> Tuple[str, ...]:
    """Union of rule names active for ``path`` (empty → file not in a zone)."""
    out = []
    for z in zones_for_path(path):
        for r in z.rules:
            if r not in out:
                out.append(r)
    return tuple(out)


def set_attrs_for_path(path: str) -> Tuple[str, ...]:
    out = []
    for z in zones_for_path(path):
        for a in z.set_attrs:
            if a not in out:
                out.append(a)
    return tuple(out)


def x64_exempt(path: str) -> bool:
    """True for the scoped-x64 helper module itself."""
    return any(_matches(path, a) for a in X64_ALLOWED)
