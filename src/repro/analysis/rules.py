"""AST rules enforcing the repo's determinism & jax-purity contracts.

Each rule is a function ``(tree, ctx) -> list[Violation]`` registered in
``RULES``.  Rules are deliberately *syntactic*: they encode the repo's own
coding contracts (sorted iteration, seeded PRNGs, pure jit bodies, the
hot-loop ban list) rather than attempting whole-program dataflow.  Where a
construct is deliberate, the author annotates it in place with
``# repro-lint: allow(<rule>)`` and the justification survives review.

Rule inventory
--------------

``unseeded-random``
    Module-level global-PRNG calls (``np.random.rand``, ``random.choice``)
    and seedless generator construction (``default_rng()``, ``Philox()``,
    ``random.Random()``).  Replays are only deterministic if every stream
    has an explicit seed.

``iter-order``
    Iterating a ``set``/``frozenset`` (or a dict-of-sets entry) where the
    order can leak into results: ``for`` loops, comprehensions, and
    order-sensitive reductions (``sum``/``min``/``max``/``list``/``tuple``).
    String hashing is salted per process (PYTHONHASHSEED), so set order is
    *not* reproducible across runs — float accumulation or placement order
    fed from it silently breaks bit-equality.  ``sorted(...)`` launders;
    order-free reductions (``len``/``any``/``all``/set algebra) are exempt.

``float-sum``
    Builtin ``sum()`` applied directly to an array-like value.  Builtin sum
    accumulates left-to-right in object space; zone code must use
    ``ndarray.sum()``/``math.fsum`` so accumulation dtype and order are
    explicit (and match the jax path).

``np-reduce-dtype``
    ``np.sum``/``np.dot``/``np.mean``/... function-form reductions without a
    pinned ``dtype``.  The accumulator dtype must be explicit (float64) in
    zone files — backend golden-equality rests on both paths reducing in
    float64.

``float32-literal``
    float32/float16/bfloat16 dtypes in arena/search array constructors.  The
    search stack's exactness arguments (dyadic grids, exact segment-sums)
    are float64-only.

``jax-purity``
    Python side effects inside traced code: ``print``, ``np.*`` calls, and
    mutation of closed-over state inside functions that are jit/vmap/scan
    bodies.  Tracing executes such code once at trace time — silent
    wrong-results territory.

``x64-scope``
    ``jax.config.update`` / ``enable_x64`` outside the one scoped helper
    (``search/backend.py``).  A process-wide x64 flip would poison the
    float32 Pallas kernels; the scoped context is the only sanctioned way.

``hot-loop``
    ``copy.deepcopy``, libm transcendentals (``exp``/``log``/trig — not
    correctly rounded, platform-varying), and wall-clock reads inside the
    engine/search step paths.  The annealer's accept decisions must compare
    exact quantities, bit-identical across backends and platforms.

``pallas-interpret``
    ``interpret=True`` hardcoded at a call site in the kernel zone.  The
    interpreter is the golden-oracle *test* harness; committed call sites
    must plumb the flag (``default_interpret()`` / a parameter) so the
    compiled kernel actually runs on TPU.

``pallas-accum-order``
    Augmented assignment onto a ``Ref`` slot whose statement depends on
    ``pl.program_id`` — cross-program float accumulation order is a grid
    execution detail, not IEEE semantics.  Kernels must accumulate into
    their own output block (or carry exact grid-quantized values, where
    order provably cannot matter).

``pallas-accum-dtype``
    ``zeros``/``ones``/``empty``/``full`` accumulator constructors in the
    golden-oracle kernel zone without an explicit wide dtype.  ``jnp``
    defaults to float32 outside an x64 scope, silently breaking the
    bit-equality contract with the float64 oracles.

``pallas-grid-truncate``
    ``pallas_call`` grids computed with floor division (``B // block``) —
    a batch that is not a block multiple silently drops its tail.  Use
    ``pl.cdiv`` with host-side padding (and slice the outputs) instead.
"""

from __future__ import annotations

import ast
import dataclasses
from typing import Callable, Dict, List, Optional, Sequence, Set, Tuple


@dataclasses.dataclass(frozen=True, order=True)
class Violation:
    path: str
    line: int
    col: int
    rule: str
    message: str

    def render(self) -> str:
        return f"{self.path}:{self.line}:{self.col}: {self.rule}: {self.message}"


@dataclasses.dataclass
class RuleContext:
    """Per-file facts rules need: path, zone knowledge, source lines."""

    path: str
    set_attrs: Tuple[str, ...] = ()
    x64_exempt: bool = False


RULES: Dict[str, Callable[[ast.AST, RuleContext], List[Violation]]] = {}


def _rule(name: str):
    def wrap(fn):
        RULES[name] = fn
        return fn

    return wrap


def _v(ctx: RuleContext, node: ast.AST, rule: str, message: str) -> Violation:
    return Violation(
        path=ctx.path,
        line=getattr(node, "lineno", 1),
        col=getattr(node, "col_offset", 0),
        rule=rule,
        message=message,
    )


def _dotted(node: ast.AST) -> Optional[str]:
    """'a.b.c' for a Name/Attribute chain, else None."""
    parts: List[str] = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return ".".join(reversed(parts))
    return None


# --------------------------------------------------------------------------
# unseeded-random
# --------------------------------------------------------------------------

#: numpy module-level convenience functions that draw from the hidden
#: global RandomState.
_NP_GLOBAL_FNS = {
    "rand", "randn", "randint", "random", "random_sample", "ranf", "sample",
    "choice", "shuffle", "permutation", "uniform", "normal",
    "standard_normal", "exponential", "poisson", "beta", "gamma", "seed",
    "bytes", "random_integers",
}

#: stdlib ``random`` module-level functions (the hidden global Random()).
_PY_GLOBAL_FNS = {
    "random", "randint", "randrange", "choice", "choices", "shuffle",
    "sample", "uniform", "gauss", "normalvariate", "betavariate",
    "expovariate", "triangular", "getrandbits", "seed", "vonmisesvariate",
    "paretovariate", "weibullvariate", "lognormvariate",
}

#: Constructors that take the seed as their first argument.
_SEEDED_CTORS = {
    "default_rng", "Philox", "PCG64", "PCG64DXSM", "MT19937", "SFC64",
    "SeedSequence", "RandomState", "Random",
}


@_rule("unseeded-random")
def _check_unseeded_random(tree: ast.AST, ctx: RuleContext) -> List[Violation]:
    out: List[Violation] = []
    for node in ast.walk(tree):
        if not isinstance(node, ast.Call):
            continue
        dotted = _dotted(node.func)
        if dotted is None:
            continue
        parts = dotted.split(".")
        head, tail = parts[0], parts[-1]
        # np.random.rand(...) / numpy.random.shuffle(...)
        if (
            len(parts) == 3
            and head in ("np", "numpy")
            and parts[1] == "random"
            and tail in _NP_GLOBAL_FNS
        ):
            out.append(
                _v(
                    ctx, node, "unseeded-random",
                    f"`{dotted}` draws from numpy's hidden global RandomState; "
                    "construct a seeded Generator "
                    "(np.random.Generator(np.random.Philox(seed)))",
                )
            )
            continue
        # random.choice(...) — the stdlib hidden global Random().
        if len(parts) == 2 and head == "random" and tail in _PY_GLOBAL_FNS:
            out.append(
                _v(
                    ctx, node, "unseeded-random",
                    f"`{dotted}` uses the process-global random.Random(); "
                    "pass an explicitly seeded random.Random(seed) instead",
                )
            )
            continue
        # default_rng() / np.random.Philox() / random.Random() without a seed.
        if tail in _SEEDED_CTORS and not node.args:
            seed_kw = {"seed", "x", "entropy"}
            if not any(kw.arg in seed_kw for kw in node.keywords):
                out.append(
                    _v(
                        ctx, node, "unseeded-random",
                        f"`{dotted}()` without a seed is entropy-seeded; "
                        "every PRNG in a deterministic zone takes an explicit "
                        "seed",
                    )
                )
    return out


# --------------------------------------------------------------------------
# iter-order
# --------------------------------------------------------------------------

_SET_RETURNING_METHODS = {
    "union", "intersection", "difference", "symmetric_difference", "copy",
}
_SET_OPS = (ast.BitOr, ast.BitAnd, ast.Sub, ast.BitXor)
_ORDER_SENSITIVE_BUILTINS = {"sum", "list", "tuple", "max", "min", "next", "iter"}
#: Consumers that launder iteration order: sorting imposes one, set/frozenset
#: construction erases it, any/all/len never expose it.
_ORDER_FREE_CONSUMERS = {"sorted", "set", "frozenset", "any", "all", "len"}


class _SetTracker(ast.NodeVisitor):
    """Scope-aware tracking of set-typed names and dict-of-set names.

    Intentionally simple: statement-order single pass per scope, names
    resolved through the lexical scope stack.  ``kind`` is ``"set"`` or
    ``"dictofsets"``.
    """

    def __init__(self, ctx: RuleContext):
        self.ctx = ctx
        self.scopes: List[Dict[str, str]] = [{}]
        self.out: List[Violation] = []
        # Comprehension nodes consumed directly by an order-free builtin
        # (sorted/set/frozenset/any/all/len) — their generators may iterate
        # sets freely, the consumer erases or imposes the order.
        self._laundered: Set[int] = set()
        # Attribute names from ctx.set_attrs that this module assigns a
        # non-set value to on `self` (e.g. PlacementArena's sorted-list
        # `self.dims` vs ResourceVector's frozenset property of the same
        # name).  Local assignment evidence beats the zone-wide default.
        self._self_nonset: Set[str] = set()

    def preanalyze(self, tree: ast.AST) -> None:
        """Collect module-level `self.<attr> = ...` typing evidence."""
        set_assigned: Set[str] = set()
        nonset_assigned: Set[str] = set()
        for node in ast.walk(tree):
            if isinstance(node, ast.Assign):
                targets, value = node.targets, node.value
            elif isinstance(node, ast.AnnAssign) and node.value is not None:
                targets, value = [node.target], node.value
            else:
                continue
            for t in targets:
                if (
                    isinstance(t, ast.Attribute)
                    and isinstance(t.value, ast.Name)
                    and t.value.id == "self"
                    and t.attr in self.ctx.set_attrs
                ):
                    bucket = (
                        set_assigned
                        if self._kind(value) == "set"
                        else nonset_assigned
                    )
                    bucket.add(t.attr)
        self._self_nonset = nonset_assigned - set_assigned

    # -- type inference ----------------------------------------------------
    def _lookup(self, name: str) -> Optional[str]:
        for scope in reversed(self.scopes):
            if name in scope:
                return scope[name]
        return None

    def _kind(self, node: ast.AST) -> Optional[str]:
        """'set' / 'dictofsets' / None for an expression."""
        if isinstance(node, (ast.Set, ast.SetComp)):
            return "set"
        if isinstance(node, ast.Name):
            return self._lookup(node.id)
        if isinstance(node, ast.Attribute):
            if node.attr in self.ctx.set_attrs:
                if (
                    isinstance(node.value, ast.Name)
                    and node.value.id == "self"
                    and node.attr in self._self_nonset
                ):
                    return None
                return "set"
            return None
        if isinstance(node, ast.IfExp):
            return self._kind(node.body) or self._kind(node.orelse)
        if isinstance(node, ast.BinOp) and isinstance(node.op, _SET_OPS):
            if self._kind(node.left) == "set" or self._kind(node.right) == "set":
                return "set"
            return None
        if isinstance(node, ast.Subscript):
            if self._kind(node.value) == "dictofsets":
                return "set"
            return None
        if isinstance(node, ast.Call):
            f = node.func
            if isinstance(f, ast.Name) and f.id in ("set", "frozenset"):
                return "set"
            if isinstance(f, ast.Attribute):
                base = self._kind(f.value)
                if base == "set" and f.attr in _SET_RETURNING_METHODS:
                    return "set"
                if base == "dictofsets" and f.attr == "get":
                    return "set"
            return None
        if isinstance(node, ast.DictComp):
            if self._kind(node.value) == "set":
                return "dictofsets"
            return None
        if isinstance(node, ast.Dict):
            if node.values and all(self._kind(v) == "set" for v in node.values):
                return "dictofsets"
            return None
        return None

    def _bind(self, target: ast.AST, kind: Optional[str]) -> None:
        if isinstance(target, ast.Name):
            if kind is not None:
                self.scopes[-1][target.id] = kind
            else:
                self.scopes[-1].pop(target.id, None)

    # -- scope plumbing ----------------------------------------------------
    def _visit_function(self, node) -> None:
        self.scopes.append({})
        for stmt in node.body:
            self.visit(stmt)
        self.scopes.pop()

    visit_FunctionDef = _visit_function
    visit_AsyncFunctionDef = _visit_function
    visit_ClassDef = _visit_function
    visit_Lambda = lambda self, node: self.generic_visit(node)  # noqa: E731

    # -- assignments -------------------------------------------------------
    def visit_Assign(self, node: ast.Assign) -> None:
        self.generic_visit(node)
        kind = self._kind(node.value)
        for t in node.targets:
            self._bind(t, kind)

    def visit_AnnAssign(self, node: ast.AnnAssign) -> None:
        self.generic_visit(node)
        if node.value is not None:
            self._bind(node.target, self._kind(node.value))

    def visit_AugAssign(self, node: ast.AugAssign) -> None:
        self.generic_visit(node)
        # `s |= set(...)` keeps s a set; anything else drops tracking.
        if isinstance(node.target, ast.Name):
            cur = self._lookup(node.target.id)
            if cur == "set" and not isinstance(node.op, _SET_OPS):
                self._bind(node.target, None)

    # -- flag sites --------------------------------------------------------
    def _flag(self, node: ast.AST, what: str) -> None:
        self.out.append(
            _v(
                self.ctx, node, "iter-order",
                f"{what} iterates a set — iteration order depends on "
                "PYTHONHASHSEED; wrap in sorted(...) or restructure",
            )
        )

    def visit_For(self, node: ast.For) -> None:
        if self._kind(node.iter) == "set":
            self._flag(node.iter, "for-loop")
        self.generic_visit(node)

    def _visit_comp(self, node) -> None:
        if id(node) not in self._laundered:
            for gen in node.generators:
                if self._kind(gen.iter) == "set":
                    self._flag(gen.iter, "comprehension")
        self.generic_visit(node)

    visit_ListComp = _visit_comp
    visit_DictComp = _visit_comp
    visit_GeneratorExp = _visit_comp

    def visit_SetComp(self, node: ast.SetComp) -> None:
        # Building a set erases iteration order — never a hazard by itself.
        self.generic_visit(node)

    def visit_Call(self, node: ast.Call) -> None:
        f = node.func
        if isinstance(f, ast.Name) and f.id in _ORDER_FREE_CONSUMERS:
            for arg in node.args:
                if isinstance(
                    arg, (ast.GeneratorExp, ast.ListComp, ast.SetComp)
                ):
                    self._laundered.add(id(arg))
        if (
            isinstance(f, ast.Name)
            and f.id in _ORDER_SENSITIVE_BUILTINS
            and node.args
            and self._kind(node.args[0]) == "set"
        ):
            self._flag(node, f"{f.id}()")
        self.generic_visit(node)


@_rule("iter-order")
def _check_iter_order(tree: ast.AST, ctx: RuleContext) -> List[Violation]:
    tracker = _SetTracker(ctx)
    tracker.preanalyze(tree)
    tracker.visit(tree)
    return tracker.out


# --------------------------------------------------------------------------
# float-sum / np-reduce-dtype / float32-literal
# --------------------------------------------------------------------------


@_rule("float-sum")
def _check_float_sum(tree: ast.AST, ctx: RuleContext) -> List[Violation]:
    out: List[Violation] = []
    for node in ast.walk(tree):
        if (
            isinstance(node, ast.Call)
            and isinstance(node.func, ast.Name)
            and node.func.id == "sum"
            and node.args
            and isinstance(
                node.args[0], (ast.Name, ast.Attribute, ast.Subscript)
            )
        ):
            out.append(
                _v(
                    ctx, node, "float-sum",
                    "builtin sum() over an array-like accumulates "
                    "left-to-right in object space; use ndarray.sum() "
                    "(explicit dtype) or math.fsum",
                )
            )
    return out


_NP_REDUCTIONS = {"sum", "dot", "matmul", "mean", "cumsum", "prod", "average"}


@_rule("np-reduce-dtype")
def _check_np_reduce_dtype(tree: ast.AST, ctx: RuleContext) -> List[Violation]:
    out: List[Violation] = []
    for node in ast.walk(tree):
        if not isinstance(node, ast.Call):
            continue
        dotted = _dotted(node.func)
        if dotted is None:
            continue
        parts = dotted.split(".")
        if (
            len(parts) == 2
            and parts[0] in ("np", "numpy")
            and parts[1] in _NP_REDUCTIONS
            and not any(kw.arg == "dtype" for kw in node.keywords)
        ):
            out.append(
                _v(
                    ctx, node, "np-reduce-dtype",
                    f"`{dotted}` without a pinned dtype — zone reductions "
                    "must accumulate in float64 (pass dtype=np.float64 or "
                    "cast the operands)",
                )
            )
    return out


_NARROW_DTYPES = {"float32", "float16", "bfloat16"}


@_rule("float32-literal")
def _check_float32_literal(tree: ast.AST, ctx: RuleContext) -> List[Violation]:
    out: List[Violation] = []
    for node in ast.walk(tree):
        if isinstance(node, ast.Attribute) and node.attr in _NARROW_DTYPES:
            root = _dotted(node)
            if root and root.split(".")[0] in ("np", "numpy", "jnp", "jax"):
                out.append(
                    _v(
                        ctx, node, "float32-literal",
                        f"`{root}` in an exactness zone — the search stack's "
                        "bit-equality arguments are float64-only",
                    )
                )
        elif (
            isinstance(node, ast.Call)
            and any(
                kw.arg == "dtype"
                and isinstance(kw.value, ast.Constant)
                and kw.value.value in _NARROW_DTYPES
                for kw in node.keywords
            )
        ):
            out.append(
                _v(
                    ctx, node, "float32-literal",
                    "narrow dtype string in an exactness zone — the search "
                    "stack's bit-equality arguments are float64-only",
                )
            )
    return out


# --------------------------------------------------------------------------
# jax-purity / x64-scope
# --------------------------------------------------------------------------

_TRACERS = {"jit", "vmap", "pmap", "grad", "value_and_grad", "scan", "checkpoint"}
_MUTATING_METHODS = {
    "append", "extend", "insert", "add", "update", "setdefault", "pop",
    "popitem", "remove", "discard", "clear", "sort", "reverse",
}


def _is_tracer_expr(node: ast.AST) -> bool:
    """True for `jit`, `jax.jit`, `jax.lax.scan`, `functools.partial(jax.jit, ...)`."""
    dotted = _dotted(node)
    if dotted is not None:
        return dotted.split(".")[-1] in _TRACERS
    if isinstance(node, ast.Call):  # partial(jax.jit, ...) decorator form
        f = _dotted(node.func)
        if f and f.split(".")[-1] == "partial" and node.args:
            return _is_tracer_expr(node.args[0])
    return False


class _TracedCollector(ast.NodeVisitor):
    """Find FunctionDefs that are (or are nested in) jit/vmap/scan bodies."""

    def __init__(self):
        self.traced: List[ast.FunctionDef] = []
        self._defs: List[ast.FunctionDef] = []  # all defs, for name lookup

    def visit_FunctionDef(self, node: ast.FunctionDef) -> None:
        self._defs.append(node)
        if any(_is_tracer_expr(d) for d in node.decorator_list):
            self.traced.append(node)
        self.generic_visit(node)

    visit_AsyncFunctionDef = visit_FunctionDef

    def visit_Call(self, node: ast.Call) -> None:
        # jax.jit(f) / jax.vmap(f) / jax.lax.scan(f, ...) with a local f.
        if _is_tracer_expr(node.func) and node.args:
            arg = node.args[0]
            if isinstance(arg, ast.Name):
                for d in self._defs:
                    if d.name == arg.id and d not in self.traced:
                        self.traced.append(d)
        self.generic_visit(node)


def _local_names(fn: ast.FunctionDef) -> set:
    """Names bound inside ``fn`` (params + any Name store), nested defs
    included — good enough to tell closed-over state from locals."""
    bound = set()
    a = fn.args
    for p in (
        list(a.posonlyargs) + list(a.args) + list(a.kwonlyargs)
        + ([a.vararg] if a.vararg else []) + ([a.kwarg] if a.kwarg else [])
    ):
        bound.add(p.arg)
    for node in ast.walk(fn):
        if isinstance(node, ast.Name) and isinstance(node.ctx, ast.Store):
            bound.add(node.id)
        elif isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            bound.add(node.name)
    return bound


@_rule("jax-purity")
def _check_jax_purity(tree: ast.AST, ctx: RuleContext) -> List[Violation]:
    collector = _TracedCollector()
    collector.visit(tree)
    out: List[Violation] = []
    seen: set = set()
    for fn in collector.traced:
        bound = _local_names(fn)
        for node in ast.walk(fn):
            key = (id(node),)
            if key in seen:
                continue
            if isinstance(node, ast.Call):
                dotted = _dotted(node.func)
                if dotted == "print" or (
                    isinstance(node.func, ast.Name) and node.func.id == "print"
                ):
                    seen.add(key)
                    out.append(
                        _v(
                            ctx, node, "jax-purity",
                            "print() inside a traced function runs once at "
                            "trace time; use jax.debug.print or hoist it",
                        )
                    )
                elif dotted and dotted.split(".")[0] in ("np", "numpy"):
                    seen.add(key)
                    out.append(
                        _v(
                            ctx, node, "jax-purity",
                            f"`{dotted}` inside a traced function executes at "
                            "trace time on abstract values; use jnp/lax "
                            "equivalents",
                        )
                    )
                elif (
                    isinstance(node.func, ast.Attribute)
                    and node.func.attr in _MUTATING_METHODS
                    and isinstance(node.func.value, ast.Name)
                    and node.func.value.id not in bound
                ):
                    seen.add(key)
                    out.append(
                        _v(
                            ctx, node, "jax-purity",
                            f"`{node.func.value.id}.{node.func.attr}(...)` "
                            "mutates closed-over state inside a traced "
                            "function — a trace-time side effect",
                        )
                    )
            elif isinstance(node, (ast.Assign, ast.AugAssign)):
                targets = (
                    node.targets if isinstance(node, ast.Assign) else [node.target]
                )
                for t in targets:
                    if (
                        isinstance(t, ast.Subscript)
                        and isinstance(t.value, ast.Name)
                        and t.value.id not in bound
                    ):
                        seen.add(key)
                        out.append(
                            _v(
                                ctx, node, "jax-purity",
                                f"subscript-assign to closed-over "
                                f"`{t.value.id}` inside a traced function — "
                                "a trace-time side effect",
                            )
                        )
    return out


@_rule("x64-scope")
def _check_x64_scope(tree: ast.AST, ctx: RuleContext) -> List[Violation]:
    if ctx.x64_exempt:
        return []
    out: List[Violation] = []
    for node in ast.walk(tree):
        if isinstance(node, ast.Call):
            dotted = _dotted(node.func)
            if dotted in ("jax.config.update", "config.update"):
                out.append(
                    _v(
                        ctx, node, "x64-scope",
                        "`jax.config.update` outside search/backend.py — "
                        "process-wide config flips poison the float32 "
                        "kernels; use backend.x64()",
                    )
                )
        elif isinstance(node, ast.ImportFrom):
            for alias in node.names:
                if alias.name == "enable_x64":
                    out.append(
                        _v(
                            ctx, node, "x64-scope",
                            "`enable_x64` imported outside search/backend.py; "
                            "use the scoped backend.x64() helper",
                        )
                    )
    return out


# --------------------------------------------------------------------------
# hot-loop
# --------------------------------------------------------------------------

_TRANSCENDENTALS = {
    "exp", "expm1", "exp2", "log", "log1p", "log2", "log10", "power", "pow",
    "sin", "cos", "tan", "sinh", "cosh", "tanh", "arcsin", "arccos",
    "arctan", "arctan2", "asin", "acos", "atan", "atan2",
}
_CLOCK_FNS = {
    "time.time", "time.perf_counter", "time.monotonic", "time.process_time",
    "time.time_ns", "time.perf_counter_ns", "time.monotonic_ns",
    "datetime.now", "datetime.utcnow", "datetime.today",
    "datetime.datetime.now", "datetime.datetime.utcnow",
    "datetime.datetime.today", "date.today", "datetime.date.today",
}


@_rule("hot-loop")
def _check_hot_loop(tree: ast.AST, ctx: RuleContext) -> List[Violation]:
    out: List[Violation] = []
    for node in ast.walk(tree):
        if not isinstance(node, ast.Call):
            continue
        dotted = _dotted(node.func)
        if dotted is None:
            continue
        parts = dotted.split(".")
        if dotted in ("copy.deepcopy", "deepcopy"):
            out.append(
                _v(
                    ctx, node, "hot-loop",
                    "copy.deepcopy in an engine/search path — use the "
                    "arena's snapshot/rollback ledger",
                )
            )
        elif (
            len(parts) == 2
            and parts[0] in ("math", "np", "numpy", "jnp")
            and parts[1] in _TRANSCENDENTALS
        ):
            out.append(
                _v(
                    ctx, node, "hot-loop",
                    f"`{dotted}` in an engine/search path — libm "
                    "transcendentals are not correctly rounded and vary by "
                    "platform; hot-loop decisions must compare exact "
                    "quantities (threshold accepting, not Metropolis)",
                )
            )
        elif dotted in _CLOCK_FNS:
            out.append(
                _v(
                    ctx, node, "hot-loop",
                    f"`{dotted}` in an engine/search path — wall-clock reads "
                    "make replays timing-dependent",
                )
            )
    return out


# --------------------------------------------------------------------------
# pallas-interpret / pallas-accum-order / pallas-accum-dtype /
# pallas-grid-truncate
# --------------------------------------------------------------------------


@_rule("pallas-interpret")
def _check_pallas_interpret(tree: ast.AST, ctx: RuleContext) -> List[Violation]:
    out: List[Violation] = []
    for node in ast.walk(tree):
        if not isinstance(node, ast.Call):
            continue
        for kw in node.keywords:
            if (
                kw.arg == "interpret"
                and isinstance(kw.value, ast.Constant)
                and kw.value.value is True
            ):
                out.append(
                    _v(
                        ctx, node, "pallas-interpret",
                        "`interpret=True` hardcoded at a committed call site "
                        "— the interpreter is the golden-oracle test path; "
                        "plumb the flag (default_interpret() / a parameter) "
                        "so the compiled kernel runs on TPU",
                    )
                )
    return out


def _is_program_id_call(node: ast.AST) -> bool:
    if isinstance(node, ast.Call):
        dotted = _dotted(node.func)
        return bool(dotted) and dotted.split(".")[-1] == "program_id"
    return False


def _program_id_names(tree: ast.AST) -> Set[str]:
    """Names bound (directly or via arithmetic) to a pl.program_id result."""
    names: Set[str] = set()
    changed = True

    def tainted(expr: ast.AST) -> bool:
        return any(
            _is_program_id_call(sub)
            or (isinstance(sub, ast.Name) and sub.id in names)
            for sub in ast.walk(expr)
        )

    while changed:  # tiny fixpoint: `i = pl.program_id(0)`, `row = i * blk`
        changed = False
        for node in ast.walk(tree):
            if isinstance(node, ast.Assign) and tainted(node.value):
                for t in node.targets:
                    if isinstance(t, ast.Name) and t.id not in names:
                        names.add(t.id)
                        changed = True
    return names


@_rule("pallas-accum-order")
def _check_pallas_accum_order(
    tree: ast.AST, ctx: RuleContext
) -> List[Violation]:
    out: List[Violation] = []
    names = _program_id_names(tree)
    for node in ast.walk(tree):
        if not (
            isinstance(node, ast.AugAssign)
            and isinstance(node.target, ast.Subscript)
        ):
            continue
        if any(
            _is_program_id_call(sub)
            or (isinstance(sub, ast.Name) and sub.id in names)
            for sub in ast.walk(node)
        ):
            out.append(
                _v(
                    ctx, node, "pallas-accum-order",
                    "accumulation depends on pl.program_id — cross-program "
                    "float accumulation order is a grid execution detail; "
                    "accumulate into the program's own output block, or "
                    "carry exact grid-quantized values",
                )
            )
    return out


#: Accumulator constructors whose positional dtype slot varies.
_ACCUM_CTORS = {"zeros": 1, "ones": 1, "empty": 1, "full": 2}
#: Wide dtypes the exactness contract allows accumulating in.
_WIDE_DTYPES = {"float64", "int32", "int64", "bool_", "bool", "intp", "uint32"}


def _dtype_name(node: ast.AST) -> Optional[str]:
    """'float64' for `np.float64` / `jnp.float64` / 'float64', else None."""
    if isinstance(node, ast.Attribute):
        dotted = _dotted(node)
        if dotted and dotted.split(".")[0] in ("np", "numpy", "jnp", "jax"):
            return dotted.split(".")[-1]
        return None
    if isinstance(node, ast.Constant) and isinstance(node.value, str):
        return node.value
    return None


@_rule("pallas-accum-dtype")
def _check_pallas_accum_dtype(
    tree: ast.AST, ctx: RuleContext
) -> List[Violation]:
    out: List[Violation] = []
    for node in ast.walk(tree):
        if not isinstance(node, ast.Call):
            continue
        dotted = _dotted(node.func)
        if dotted is None:
            continue
        parts = dotted.split(".")
        if len(parts) != 2 or parts[0] not in ("np", "numpy", "jnp"):
            continue
        if parts[1] not in _ACCUM_CTORS:
            continue
        dtype_node = None
        for kw in node.keywords:
            if kw.arg == "dtype":
                dtype_node = kw.value
        if dtype_node is None:
            slot = _ACCUM_CTORS[parts[1]]
            if len(node.args) > slot:
                dtype_node = node.args[slot]
        if dtype_node is None:
            out.append(
                _v(
                    ctx, node, "pallas-accum-dtype",
                    f"`{dotted}` without an explicit dtype in the "
                    "golden-oracle kernel zone — jnp defaults to float32 "
                    "outside an x64 scope; pin dtype=jnp.float64 (or an "
                    "exact integer dtype)",
                )
            )
            continue
        name = _dtype_name(dtype_node)
        if name is not None and name not in _WIDE_DTYPES:
            out.append(
                _v(
                    ctx, node, "pallas-accum-dtype",
                    f"`{dotted}` accumulator pinned to `{name}` — the "
                    "golden-oracle comparison contract is float64/exact-int "
                    "only",
                )
            )
    return out


@_rule("pallas-grid-truncate")
def _check_pallas_grid_truncate(
    tree: ast.AST, ctx: RuleContext
) -> List[Violation]:
    out: List[Violation] = []
    for node in ast.walk(tree):
        if not isinstance(node, ast.Call):
            continue
        dotted = _dotted(node.func)
        if dotted is None or dotted.split(".")[-1] != "pallas_call":
            continue
        for kw in node.keywords:
            if kw.arg != "grid":
                continue
            for sub in ast.walk(kw.value):
                if isinstance(sub, ast.BinOp) and isinstance(
                    sub.op, ast.FloorDiv
                ):
                    out.append(
                        _v(
                            ctx, sub, "pallas-grid-truncate",
                            "floor division in a pallas_call grid silently "
                            "drops the tail block when the batch is not a "
                            "block multiple; use pl.cdiv and pad/mask the "
                            "boundary",
                        )
                    )
    return out
