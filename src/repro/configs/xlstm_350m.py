"""xlstm-350m [ssm] — alternating sLSTM + mLSTM blocks, no FFN
[arXiv:2405.04517; unverified]."""
from .base import ModelConfig


def full() -> ModelConfig:
    return ModelConfig(
        arch="xlstm-350m", family="ssm",
        n_layers=24, d_model=1024, n_heads=4, n_kv_heads=4,
        d_ff=0, vocab=50304,
        pattern=("mlstm", "slstm"),
        source="arXiv:2405.04517",
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        arch="xlstm-smoke", family="ssm",
        n_layers=2, d_model=64, n_heads=2, n_kv_heads=2,
        d_ff=0, vocab=256,
        pattern=("mlstm", "slstm"),
    )
