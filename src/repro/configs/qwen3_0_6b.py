"""qwen3-0.6b [dense] — qk_norm, GQA, wide head_dim [hf:Qwen/Qwen3-0.6B; hf]."""
from .base import ModelConfig


def full() -> ModelConfig:
    return ModelConfig(
        arch="qwen3-0.6b", family="dense",
        n_layers=28, d_model=1024, n_heads=16, n_kv_heads=8,
        d_ff=3072, vocab=151936,
        head_dim=128, qk_norm=True,
        pattern=("attn",),
        tie_embeddings=True,
        source="hf:Qwen/Qwen3-0.6B",
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        arch="qwen3-smoke", family="dense",
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
        d_ff=128, vocab=256,
        head_dim=32, qk_norm=True,
        pattern=("attn",),
        tie_embeddings=True,
    )
