"""smollm-360m [dense] — llama-arch small [hf:HuggingFaceTB/SmolLM-360M; hf]."""
from .base import ModelConfig


def full() -> ModelConfig:
    return ModelConfig(
        arch="smollm-360m", family="dense",
        n_layers=32, d_model=960, n_heads=15, n_kv_heads=5,
        d_ff=2560, vocab=49152,
        pattern=("attn",),
        tie_embeddings=True,
        source="hf:HuggingFaceTB/SmolLM-360M",
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        arch="smollm-smoke", family="dense",
        n_layers=2, d_model=60, n_heads=3, n_kv_heads=1,
        d_ff=128, vocab=256,
        pattern=("attn",),
        tie_embeddings=True,
    )
