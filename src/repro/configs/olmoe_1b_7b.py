"""olmoe-1b-7b [moe] — 64 experts, top-8 [arXiv:2409.02060; hf]."""
from .base import ModelConfig


def full() -> ModelConfig:
    return ModelConfig(
        arch="olmoe-1b-7b", family="moe",
        n_layers=16, d_model=2048, n_heads=16, n_kv_heads=16,
        d_ff=1024, vocab=50304,
        n_experts=64, top_k=8,
        pattern=("attn",),
        source="arXiv:2409.02060",
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        arch="olmoe-smoke", family="moe",
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=4,
        d_ff=32, vocab=256,
        n_experts=8, top_k=2,
        pattern=("attn",),
    )
