"""Assigned-architecture configs: ``get(arch_id)`` -> (full, smoke) builders."""
from __future__ import annotations

import importlib
from typing import Callable, Dict, Tuple

from .base import ModelConfig, SHAPES, ShapeCell, shape_by_name

_MODULES: Dict[str, str] = {
    "olmoe-1b-7b": "olmoe_1b_7b",
    "mixtral-8x7b": "mixtral_8x7b",
    "xlstm-350m": "xlstm_350m",
    "phi-3-vision-4.2b": "phi_3_vision_4_2b",
    "recurrentgemma-9b": "recurrentgemma_9b",
    "deepseek-7b": "deepseek_7b",
    "smollm-360m": "smollm_360m",
    "internlm2-1.8b": "internlm2_1_8b",
    "qwen3-0.6b": "qwen3_0_6b",
    "whisper-large-v3": "whisper_large_v3",
}

ARCHS = tuple(_MODULES)


def get(arch: str) -> ModelConfig:
    return _module(arch).full()


def get_smoke(arch: str) -> ModelConfig:
    return _module(arch).smoke()


def _module(arch: str):
    try:
        mod_name = _MODULES[arch]
    except KeyError:
        raise KeyError(f"unknown arch {arch!r}; have {sorted(_MODULES)}") from None
    return importlib.import_module(f".{mod_name}", __package__)


__all__ = ["ModelConfig", "ShapeCell", "SHAPES", "ARCHS", "get", "get_smoke", "shape_by_name"]
