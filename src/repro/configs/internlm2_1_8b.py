"""internlm2-1.8b [dense] — GQA [arXiv:2403.17297; hf]."""
from .base import ModelConfig


def full() -> ModelConfig:
    return ModelConfig(
        arch="internlm2-1.8b", family="dense",
        n_layers=24, d_model=2048, n_heads=16, n_kv_heads=8,
        d_ff=8192, vocab=92544,
        pattern=("attn",),
        source="arXiv:2403.17297",
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        arch="internlm2-smoke", family="dense",
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
        d_ff=128, vocab=256,
        pattern=("attn",),
    )
