"""recurrentgemma-9b [hybrid] — RG-LRU + local attention, 1 attention per
2 recurrent blocks [arXiv:2402.19427; unverified]."""
from .base import ModelConfig


def full() -> ModelConfig:
    return ModelConfig(
        arch="recurrentgemma-9b", family="hybrid",
        n_layers=38, d_model=4096, n_heads=16, n_kv_heads=1,
        d_ff=12288, vocab=256000,
        window=2048, pattern=("rglru", "rglru", "local"),
        source="arXiv:2402.19427",
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        arch="recurrentgemma-smoke", family="hybrid",
        n_layers=3, d_model=64, n_heads=4, n_kv_heads=1,
        d_ff=128, vocab=256,
        window=16, pattern=("rglru", "rglru", "local"),
    )
