"""mixtral-8x7b [moe] — 8 experts top-2, sliding-window attention
[arXiv:2401.04088; hf]."""
from .base import ModelConfig


def full() -> ModelConfig:
    return ModelConfig(
        arch="mixtral-8x7b", family="moe",
        n_layers=32, d_model=4096, n_heads=32, n_kv_heads=8,
        d_ff=14336, vocab=32000,
        n_experts=8, top_k=2,
        window=4096, pattern=("local",),
        source="arXiv:2401.04088",
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        arch="mixtral-smoke", family="moe",
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
        d_ff=128, vocab=256,
        n_experts=4, top_k=2,
        window=16, pattern=("local",),
    )
