"""whisper-large-v3 [audio] — encoder-decoder; conv frontend is a STUB
(input_specs provides precomputed mel-frame embeddings) [arXiv:2212.04356]."""
from .base import ModelConfig


def full() -> ModelConfig:
    return ModelConfig(
        arch="whisper-large-v3", family="audio",
        n_layers=32, d_model=1280, n_heads=20, n_kv_heads=20,
        d_ff=5120, vocab=51866,
        enc_dec=True, n_enc_layers=32, enc_seq=1500,
        audio_frontend=True,
        pattern=("attn",),
        source="arXiv:2212.04356",
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        arch="whisper-smoke", family="audio",
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=4,
        d_ff=128, vocab=256,
        enc_dec=True, n_enc_layers=2, enc_seq=32,
        audio_frontend=True,
        pattern=("attn",),
    )
