"""phi-3-vision-4.2b [vlm] — phi3-mini backbone + CLIP patch-embedding stub
[hf:microsoft/Phi-3-vision-128k-instruct; hf]."""
from .base import ModelConfig


def full() -> ModelConfig:
    return ModelConfig(
        arch="phi-3-vision-4.2b", family="vlm",
        n_layers=32, d_model=3072, n_heads=32, n_kv_heads=32,
        d_ff=8192, vocab=32064,
        vision_prefix=576,
        pattern=("attn",),
        source="hf:microsoft/Phi-3-vision-128k-instruct",
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        arch="phi3v-smoke", family="vlm",
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=4,
        d_ff=128, vocab=256,
        vision_prefix=16,
        pattern=("attn",),
    )
