"""Model configuration schema shared by all assigned architectures."""

from __future__ import annotations

import dataclasses
from typing import Optional, Tuple


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    # identity
    arch: str
    family: str                      # dense | moe | ssm | hybrid | vlm | audio

    # transformer backbone
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: Optional[int] = None   # default d_model // n_heads

    # attention flavour
    qk_norm: bool = False
    window: Optional[int] = None     # sliding-window size (None = full attn)
    rope_theta: float = 10000.0

    # MoE
    n_experts: int = 0
    top_k: int = 0
    capacity_factor: float = 1.25

    # hybrid / ssm layer pattern: tuple of block kinds repeated to n_layers.
    # kinds: "attn" (global), "local" (windowed attn), "rglru", "mlstm", "slstm"
    pattern: Tuple[str, ...] = ("attn",)

    # encoder-decoder (whisper)
    enc_dec: bool = False
    n_enc_layers: int = 0
    enc_seq: int = 1500              # encoder frames after the conv-stub

    # modality frontend stubs
    vision_prefix: int = 0           # patch-embedding prefix length (phi-3-v)
    audio_frontend: bool = False     # whisper conv stub

    # numerics / training
    dtype: str = "bfloat16"          # activation/compute dtype
    param_dtype: str = "float32"
    remat: str = "full"              # "none" | "full" | "dots"
    tie_embeddings: bool = False

    # notes for DESIGN/roofline bookkeeping
    source: str = ""

    def __post_init__(self):
        if self.head_dim is None:
            object.__setattr__(self, "head_dim", self.d_model // self.n_heads)
        assert self.n_heads % max(self.n_kv_heads, 1) == 0, (
            f"{self.arch}: n_heads={self.n_heads} not divisible by kv={self.n_kv_heads}"
        )

    # -- derived quantities -----------------------------------------------------
    @property
    def q_per_kv(self) -> int:
        return self.n_heads // self.n_kv_heads

    def layer_kinds(self) -> Tuple[str, ...]:
        """Expand the repeating pattern to n_layers entries."""
        kinds = []
        while len(kinds) < self.n_layers:
            kinds.extend(self.pattern)
        return tuple(kinds[: self.n_layers])

    def param_count(self) -> int:
        """Approximate parameter count (embedding + blocks + head)."""
        D, H, Kv, hd, F, V = (
            self.d_model,
            self.n_heads,
            self.n_kv_heads,
            self.head_dim,
            self.d_ff,
            self.vocab,
        )
        total = V * D  # embed
        if not self.tie_embeddings:
            total += V * D
        for kind in self.layer_kinds():
            if kind in ("attn", "local"):
                total += D * (H * hd) + 2 * D * (Kv * hd) + (H * hd) * D  # qkvo
                if self.n_experts > 0:
                    total += self.n_experts * 3 * D * F + D * self.n_experts
                elif F > 0:
                    total += 3 * D * F  # swiglu
                total += 2 * D
            elif kind == "rglru":
                # conv4 + in/out proj + gates (Griffin recurrent block) + mlp
                total += 2 * D * D + 4 * D + 3 * D + 2 * D
                if F > 0:
                    total += 3 * D * F + 2 * D
            elif kind == "mlstm":
                total += D * (H * hd) * 3 + (H * hd) * D + 2 * (H * hd) + 2 * D
            elif kind == "slstm":
                total += 4 * D * D + 4 * D + 2 * D
        if self.enc_dec:
            # encoder blocks (attn + mlp) + decoder cross-attention
            enc_block = D * (H * hd) + 2 * D * (Kv * hd) + (H * hd) * D + 3 * D * F + 2 * D
            total += self.n_enc_layers * enc_block
            total += self.n_layers * (D * (H * hd) + 2 * D * (Kv * hd) + (H * hd) * D + D)
        return total

    def active_param_count(self) -> int:
        """Parameters touched per token (MoE: top_k of n_experts)."""
        if self.n_experts == 0:
            return self.param_count()
        D, F = self.d_model, self.d_ff
        dense_moe = self.n_experts * 3 * D * F
        active_moe = self.top_k * 3 * D * F
        n_moe_layers = sum(1 for k in self.layer_kinds() if k in ("attn", "local"))
        return self.param_count() - n_moe_layers * (dense_moe - active_moe)


@dataclasses.dataclass(frozen=True)
class ShapeCell:
    """One assigned (arch × input-shape) cell."""

    name: str                        # train_4k | prefill_32k | decode_32k | long_500k
    seq_len: int
    global_batch: int
    kind: str                        # "train" | "prefill" | "decode"


SHAPES = (
    ShapeCell("train_4k", 4096, 256, "train"),
    ShapeCell("prefill_32k", 32768, 32, "prefill"),
    ShapeCell("decode_32k", 32768, 128, "decode"),
    ShapeCell("long_500k", 524288, 1, "decode"),
)


def shape_by_name(name: str) -> ShapeCell:
    for s in SHAPES:
        if s.name == name:
            return s
    raise KeyError(f"unknown shape {name!r}; have {[s.name for s in SHAPES]}")
