"""Distributed training launcher: planner → mesh → sharded train loop with
async checkpointing and restart-on-failure semantics.

On real hardware this runs under `jax.distributed` with one process per host
and the production mesh; on this container pass ``--devices N`` to force N
host devices (the code path — planner, NamedShardings, donation, checkpoint
resume — is identical).

    PYTHONPATH=src python -m repro.launch.train --arch qwen3-0.6b --smoke \
        --devices 8 --steps 30 --batch 16 --seq-len 64
"""

import argparse
import os
import sys
import time


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-0.6b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--devices", type=int, default=0, help="force N host devices")
    ap.add_argument("--steps", type=int, default=30)
    ap.add_argument("--batch", type=int, default=16)
    ap.add_argument("--seq-len", type=int, default=64)
    ap.add_argument("--n-micro", type=int, default=1)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_launch_ckpt")
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--compress-grads", action="store_true")
    args = ap.parse_args()

    if args.devices:
        os.environ["XLA_FLAGS"] = (
            f"--xla_force_host_platform_device_count={args.devices}"
        )

    # jax import AFTER the device-count flag.
    import jax
    import jax.numpy as jnp
    from jax.sharding import NamedSharding, PartitionSpec as P

    from .. import configs
    from ..configs.base import ShapeCell
    from ..data import LMDataset, Prefetcher
    from ..models import build
    from ..placement import MeshShape, ResourceAwarePlanner, activation_rules
    from ..train import (
        AdamWConfig,
        AsyncCheckpointer,
        TrainOptions,
        init_train_state,
        latest_step,
        make_train_step,
        restore_checkpoint,
    )
    from .mesh import make_smoke_mesh

    model = build(args.arch, smoke=args.smoke)
    cfg = model.cfg
    mesh = make_smoke_mesh()
    axes = dict(zip(mesh.axis_names, mesh.devices.shape))
    mshape = MeshShape(axes)
    shape = ShapeCell("launch", args.seq_len, args.batch, "train")
    planner = ResourceAwarePlanner()
    plan = planner.plan(model, shape, mshape)
    print(
        f"[train] arch={cfg.arch} devices={mesh.devices.size} mesh={axes} "
        f"fsdp={plan.fsdp} n_micro={plan.n_micro} "
        f"est={plan.memory.total / 2**30:.2f} GiB/dev"
    )

    opts = TrainOptions(
        opt=AdamWConfig(lr=3e-4, warmup_steps=10, total_steps=args.steps),
        n_micro=max(args.n_micro, plan.n_micro),
        compress_grads=args.compress_grads,
    )

    def shardings(tree_spec):
        return jax.tree_util.tree_map(
            lambda s: NamedSharding(mesh, s),
            tree_spec,
            is_leaf=lambda x: isinstance(x, P),
        )

    params_sh = shardings(plan.param_specs)
    state_sh = {
        "params": params_sh,
        "opt": {"m": params_sh, "v": params_sh, "step": NamedSharding(mesh, P())},
    }
    if opts.compress_grads:
        state_sh["err"] = params_sh
    batch_sh = shardings(plan.batch_specs)

    with mesh:
        with activation_rules(plan.activation_rules):
            state = init_train_state(model, jax.random.PRNGKey(0), opts)
            state = jax.device_put(state, state_sh)
            start = 0
            if args.resume and latest_step(args.ckpt_dir) is not None:
                like = jax.tree_util.tree_map(
                    lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), state
                )
                host_state, start = restore_checkpoint(args.ckpt_dir, like)
                state = jax.device_put(host_state, state_sh)
                print(f"[train] resumed from step {start}")
            step_fn = jax.jit(
                make_train_step(model, opts),
                in_shardings=(state_sh, batch_sh),
                donate_argnums=(0,),
            )
            ds = Prefetcher(
                iter(
                    LMDataset(
                        seq_len=args.seq_len,
                        batch_size=args.batch,
                        vocab_size=cfg.vocab,
                    )
                )
            )
            ckpt = AsyncCheckpointer(args.ckpt_dir, keep=2)
            t0 = time.time()
            for i in range(start, args.steps):
                batch = next(ds)
                batch = {k: jnp.asarray(v) for k, v in batch.items()}
                state, metrics = step_fn(state, batch)
                if (i + 1) % 10 == 0 or i + 1 == args.steps:
                    print(
                        f"[train] step {i + 1:4d} loss={float(metrics['loss']):.4f} "
                        f"({(time.time() - t0) / max(i + 1 - start, 1):.2f}s/step)"
                    )
                if (i + 1) % 20 == 0:
                    ckpt.save(i + 1, state)
            ckpt.close()
    print(f"[train] done ({args.steps} steps); checkpoints in {args.ckpt_dir}")


if __name__ == "__main__":
    main()
