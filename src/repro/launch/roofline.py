"""Roofline analysis over the dry-run records (EXPERIMENTS.md §Roofline).

Per (arch × shape × mesh) cell, three terms in seconds-per-step on the
TPU-v5e target:

  compute    = flops_per_device / peak_bf16
  memory     = hbm_traffic_per_device / hbm_bw, where traffic is derived
               from the *compiled* buffer assignment (arguments read +
               outputs written + 2x temporaries) — the raw cost_analysis
               byte count on the CPU backend counts unfused op operands and
               is reported alongside for reference;
  collective = Σ_op bytes_op × ring_multiplier / ici_bw (all-reduce moves
               ~2x its payload on a ring; gather/scatter/permute ~1x).

flops_per_device comes from the unrolled cost probes (see dryrun.probe_costs
— XLA counts While bodies once, so the scanned production program cannot be
costed directly).  MODEL_FLOPS = factor·N_active·tokens (6 train / 2
inference) and its ratio to compiled FLOPs measures how much of the compute
is "useful" (catching remat and replicated-attention waste).
"""

from __future__ import annotations

import argparse
import glob
import json
import os
from typing import Any, Dict, List, Optional

from .. import configs
from ..configs.base import shape_by_name
from ..placement.hardware import V5E

RING_MULT = {
    "all-reduce": 2.0,
    "all-gather": 1.0,
    "reduce-scatter": 1.0,
    "all-to-all": 1.0,
    "collective-permute": 1.0,
}


def model_flops_per_device(arch: str, shape_name: str, devices: int) -> float:
    cfg = configs.get(arch)
    shape = shape_by_name(shape_name)
    n_active = cfg.active_param_count()
    if shape.kind == "train":
        factor, tokens = 6.0, shape.global_batch * shape.seq_len
    elif shape.kind == "prefill":
        factor, tokens = 2.0, shape.global_batch * shape.seq_len
    else:  # decode: one token per sequence per step
        factor, tokens = 2.0, shape.global_batch
    return factor * n_active * tokens / devices


def analyze_record(rec: Dict[str, Any]) -> Optional[Dict[str, Any]]:
    if "skip" in rec or "error" in rec:
        return None
    chip = V5E
    flops = rec.get("flops_per_device", 0.0)
    mem = rec.get("memory_analysis", {})
    traffic = (
        mem.get("argument_size_in_bytes", 0.0)
        + mem.get("output_size_in_bytes", 0.0)
        + 2.0 * mem.get("temp_size_in_bytes", 0.0)
    )
    coll = dict(rec.get("collective_bytes_per_device", {}))
    # ZeRO weight all-gathers recur once per gradient-accumulation microbatch
    # (probes run n_micro=1; all-reduce/reduce-scatter were already scaled at
    # record time — see dryrun collective_note).
    n_micro = rec.get("plan", {}).get("n_micro", 1)
    if rec.get("plan", {}).get("fsdp") and n_micro > 1 and "all-gather" in coll:
        coll["all-gather"] = coll["all-gather"] * n_micro
    t_compute = flops / chip.peak_flops_bf16
    t_memory = traffic / chip.hbm_bw
    t_coll = sum(RING_MULT.get(op, 1.0) * b for op, b in coll.items()) / chip.ici_bw_per_link
    terms = {"compute": t_compute, "memory": t_memory, "collective": t_coll}
    dominant = max(terms, key=lambda k: terms[k])
    mf = model_flops_per_device(rec["arch"], rec["shape"], rec["devices"])
    ratio = mf / flops if flops else 0.0
    frac_roofline = terms["compute"] * (min(ratio, 1.0)) / max(sum(terms.values()), 1e-30)
    return {
        "arch": rec["arch"],
        "shape": rec["shape"],
        "mesh": "2x16x16" if rec["multi_pod"] else "16x16",
        "t_compute_s": t_compute,
        "t_memory_s": t_memory,
        "t_collective_s": t_coll,
        "dominant": dominant,
        "model_flops_per_dev": mf,
        "hlo_flops_per_dev": flops,
        "useful_ratio": ratio,
        "hbm_traffic_bytes": traffic,
        "raw_hlo_bytes": rec.get("bytes_per_device", 0.0),
        "collective_bytes": coll,
        "lever": lever_sentence(rec, dominant, ratio),
    }


def lever_sentence(rec: Dict[str, Any], dominant: str, ratio: float) -> str:
    cfg = configs.get(rec["arch"])
    if dominant == "compute" and ratio < 0.5:
        if cfg.n_heads % 16 != 0:
            return (
                "compute is mostly redundant: attention heads not divisible by the "
                "model axis replicate per-token work — pad heads / shard on head_dim "
                "or sequence instead"
            )
        if cfg.window and rec["shape"] in ("prefill_32k", "train_4k"):
            return (
                "masked-out sliding-window blocks are still computed — skip "
                "out-of-window key blocks (flash-style block skipping)"
            )
        return "reduce recompute (remat policy) or pick shardings XLA partitions fully"
    if dominant == "compute":
        return "compute-bound at high useful ratio — good; next win is kernel-level (flash/MXU util)"
    if dominant == "memory":
        if rec["shape"].startswith("decode") or rec["shape"].startswith("long"):
            return "decode is HBM-bound on weights+KV: quantize KV (int8) and/or batch more requests"
        return "cut activation traffic: fuse norms/gates, bigger microbatch, better remat policy"
    return (
        "collective-bound: overlap grad reduce with backward, compress cross-pod "
        "gradients, or re-balance TP axes to cut all-gather volume"
    )


def build_table(records: List[Dict[str, Any]]) -> str:
    rows = [r for r in (analyze_record(x) for x in records) if r]
    rows.sort(key=lambda r: (r["mesh"], r["arch"], r["shape"]))
    out = [
        "| arch | shape | mesh | compute(s) | memory(s) | collective(s) | dominant | MODEL/HLO flops | lever |",
        "|---|---|---|---|---|---|---|---|---|",
    ]
    for r in rows:
        out.append(
            f"| {r['arch']} | {r['shape']} | {r['mesh']} | "
            f"{r['t_compute_s']:.4f} | {r['t_memory_s']:.4f} | {r['t_collective_s']:.4f} | "
            f"**{r['dominant']}** | {r['useful_ratio']:.2f} | {r['lever']} |"
        )
    return "\n".join(out)


def skips_table(records: List[Dict[str, Any]]) -> str:
    out = ["| arch | shape | mesh | reason |", "|---|---|---|---|"]
    for rec in records:
        if "skip" in rec:
            mesh = "2x16x16" if rec["multi_pod"] else "16x16"
            out.append(f"| {rec['arch']} | {rec['shape']} | {mesh} | {rec['skip']} |")
    return "\n".join(out)


def load_records(dirname: str) -> List[Dict[str, Any]]:
    recs = []
    for path in sorted(glob.glob(os.path.join(dirname, "*.json"))):
        with open(path) as f:
            recs.append(json.load(f))
    return recs


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--dryrun-dir", default="results/dryrun")
    ap.add_argument("--out", default="results/roofline.md")
    args = ap.parse_args()
    recs = load_records(args.dryrun_dir)
    analyzed = [r for r in (analyze_record(x) for x in recs) if r]
    table = build_table(recs)
    skips = skips_table(recs)
    errors = [r for r in recs if "error" in r]
    text = (
        "# Roofline (generated by repro.launch.roofline)\n\n"
        f"Cells analyzed: {len(analyzed)}; skips: "
        f"{sum(1 for r in recs if 'skip' in r)}; errors: {len(errors)}\n\n"
        "## Terms\n\n" + table + "\n\n## Documented skips\n\n" + skips + "\n"
    )
    if errors:
        text += "\n## Errors\n\n" + "\n".join(
            f"- {r['arch']}/{r['shape']} mp={r['multi_pod']}: {r['error']}" for r in errors
        )
    with open(args.out, "w") as f:
        f.write(text)
    print(text)


if __name__ == "__main__":
    main()
