"""Production mesh construction.

A FUNCTION, not a module-level constant — importing this module never touches
jax device state (the dry-run sets XLA_FLAGS before any jax import)."""

from __future__ import annotations

from typing import Tuple

import jax

from ..placement.sharding_rules import MeshShape


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def mesh_shape(*, multi_pod: bool = False) -> MeshShape:
    """Planner-side description matching make_production_mesh."""
    if multi_pod:
        return MeshShape({"pod": 2, "data": 16, "model": 16})
    return MeshShape({"data": 16, "model": 16})


def make_smoke_mesh(devices=None):
    """Tiny mesh over however many devices exist (tests)."""
    devices = devices if devices is not None else jax.devices()
    n = len(devices)
    model = 2 if n % 2 == 0 and n > 1 else 1
    data = n // model
    return jax.make_mesh((data, model), ("data", "model"))
