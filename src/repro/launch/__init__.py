# NOTE: dryrun is intentionally NOT imported here — it sets XLA_FLAGS at
# import time and must only ever be the first jax-touching import of a
# dedicated process (python -m repro.launch.dryrun).
from .mesh import make_production_mesh, make_smoke_mesh, mesh_shape

__all__ = ["make_production_mesh", "make_smoke_mesh", "mesh_shape"]
