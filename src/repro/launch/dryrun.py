import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

# Multi-pod dry-run: lower + compile every (architecture × input-shape) cell
# on the production meshes and record memory/cost/collective analysis.
#
# The two lines above MUST stay first: jax locks the device count on first
# initialization.  512 placeholder host devices back both the 16x16
# single-pod mesh and the 2x16x16 multi-pod mesh.
#
# Usage:
#   PYTHONPATH=src python -m repro.launch.dryrun --arch deepseek-7b --shape train_4k
#   PYTHONPATH=src python -m repro.launch.dryrun --all [--multi-pod] [--out results/dryrun]

import argparse
import dataclasses
import json
import re
import time
import traceback
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from .. import configs
from ..configs.base import SHAPES, ShapeCell, shape_by_name
from ..models import build, build_from_config, cell_skip_reason, input_specs
from ..models.common import unrolled_scans
from ..placement import ResourceAwarePlanner, activation_rules
from ..train import AdamWConfig, TrainOptions, make_train_step
from .mesh import make_production_mesh, mesh_shape

COLLECTIVE_RE = re.compile(
    r"=\s*(?:\()?\s*((?:bf16|f16|f32|f64|s8|u8|s32|u32|s64|u64|pred|c64)"
    r"\[[0-9,]*\][^)]*?)\)?\s+"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
)
SHAPE_RE = re.compile(r"(bf16|f16|f32|f64|s8|u8|s32|u32|s64|u64|pred|c64)\[([0-9,]*)\]")
DTYPE_BYTES = {
    "bf16": 2, "f16": 2, "f32": 4, "f64": 8, "s8": 1, "u8": 1,
    "s32": 4, "u32": 4, "s64": 8, "u64": 8, "pred": 1, "c64": 8,
}


_COLLECTIVE_CALL_RE = re.compile(
    r"(?<!%)\b(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(-start)?\("
)


def collective_bytes(hlo_text: str) -> Dict[str, float]:
    """Per-device bytes moved by collectives, from the partitioned HLO.

    Sums the *result* shapes of every collective instruction (post-SPMD
    shapes are per-device); tuple results contribute every element.  Only
    genuine call sites count: the op name must be the instruction (followed
    by '('), not an operand reference like ``get-tuple-element(%all-reduce.1)``
    (preceded by '%'), and '-done' halves of async pairs are skipped so
    traffic is not double-counted.
    """
    out: Dict[str, float] = {}
    for line in hlo_text.splitlines():
        if "=" not in line:
            continue
        m = _COLLECTIVE_CALL_RE.search(line)
        if not m:
            continue
        op = m.group(1)
        lhs = line.split("=", 1)[0] + "=" + line.split("=", 1)[1].split(m.group(0))[0]
        nbytes = 0.0
        for dm in SHAPE_RE.finditer(lhs):
            dims = dm.group(2)
            n = 1
            if dims:
                for d in dims.split(","):
                    n *= int(d)
            nbytes += n * DTYPE_BYTES[dm.group(1)]
        if nbytes:
            out[op] = out.get(op, 0.0) + nbytes
    return out


def _shardings(mesh, spec_tree):
    return jax.tree_util.tree_map(
        lambda s: NamedSharding(mesh, s),
        spec_tree,
        is_leaf=lambda x: isinstance(x, P),
    )


def _lower_cell(model, cfg, shape, mesh, mshape, plan, specs, n_micro, compress):
    """Build + lower the cell's program; returns the jax Lowered object."""
    if shape.kind == "train":
        opts = TrainOptions(opt=AdamWConfig(), n_micro=n_micro, compress_grads=compress)
        step_fn = make_train_step(model, opts)
        params_sh = _shardings(mesh, plan.param_specs)
        state_sh = {
            "params": params_sh,
            "opt": {"m": params_sh, "v": params_sh, "step": NamedSharding(mesh, P())},
        }
        if opts.compress_grads:
            state_sh["err"] = params_sh
        batch_sh = _shardings(mesh, plan.batch_specs)
        state_shapes = jax.eval_shape(lambda: _train_state_shapes(model, opts))
        fn = jax.jit(step_fn, in_shardings=(state_sh, batch_sh), donate_argnums=(0,))
        return fn.lower(state_shapes, specs["batch"])
    if shape.kind == "prefill":
        params_sh = _shardings(mesh, plan.param_specs)
        batch_sh = _shardings(mesh, plan.batch_specs)
        fn = jax.jit(model.prefill, in_shardings=(params_sh, batch_sh))
        params_shapes = jax.eval_shape(lambda: model.init_params(jax.random.PRNGKey(0)))
        return fn.lower(params_shapes, specs["batch"])
    # decode
    params_sh = _shardings(mesh, plan.param_specs)
    cache_sh = _shardings(mesh, plan.cache_specs)
    B = shape.global_batch
    dp = 1
    for a in mshape.data_axes:
        dp *= mshape.size(a)
    if B % max(dp, 1) == 0 and dp > 1:
        tok_spec = P(
            mshape.data_axes if len(mshape.data_axes) > 1 else mshape.data_axes[0],
            None,
        )
    else:
        tok_spec = P(None, None)
    fn = jax.jit(
        model.decode_step,
        in_shardings=(
            params_sh,
            cache_sh,
            NamedSharding(mesh, tok_spec),
            NamedSharding(mesh, P()),
        ),
        donate_argnums=(1,),
    )
    params_shapes = jax.eval_shape(lambda: model.init_params(jax.random.PRNGKey(0)))
    return fn.lower(params_shapes, specs["cache"], specs["token"], specs["pos"])


def _slstm_correction(cfg, shape, mshape) -> Dict[str, float]:
    """Analytic while-body correction for sLSTM's per-token recurrence (the
    only scan the probes cannot unroll).  Per sLSTM layer."""
    if "slstm" not in cfg.pattern:
        return {"flops": 0.0, "bytes": 0.0}
    T = shape.seq_len if shape.kind != "decode" else 1
    if T <= 1:
        return {"flops": 0.0, "bytes": 0.0}
    dp = 1
    for a in mshape.data_axes:
        dp *= mshape.size(a)
    B_dev = max(shape.global_batch // max(dp, 1), 1)
    D = cfg.d_model
    shards = mshape.size("model") if (4 * D) % mshape.size("model") == 0 else 1
    flops_step = 2.0 * B_dev * D * (4 * D) / shards + 40.0 * B_dev * D
    bytes_step = (B_dev * D * 4 * 8) + (D * 4 * D * 4 / shards)
    mult = 3.0 if shape.kind == "train" else 1.0  # fwd+bwd recompute
    return {
        "flops": (T - 1) * flops_step * mult,
        "bytes": (T - 1) * bytes_step * mult,
    }


def probe_costs(
    arch: str,
    shape: ShapeCell,
    mesh,
    mshape,
    fsdp: bool,
    planner: ResourceAwarePlanner,
) -> Dict[str, Any]:
    """Exact per-device flops/bytes/collectives via two fully-unrolled probe
    compiles (1-group and 2-group models), scaled to the full depth.

    XLA's cost_analysis counts a While body once regardless of trip count, so
    the production (scanned) program cannot be costed directly; the probes
    contain no While loops (sLSTM's token recurrence excepted — corrected
    analytically)."""
    cfg = configs.get(arch)
    P_len = len(cfg.pattern)
    G = cfg.n_layers // P_len
    tail = len(cfg.layer_kinds()) - G * P_len

    results = []
    for k in (1, 2):
        kw = {"n_layers": k * P_len}
        if cfg.enc_dec:
            kw["n_enc_layers"] = k
        probe_cfg = dataclasses.replace(cfg, **kw)
        probe_model = build_from_config(probe_cfg)
        plan = planner.plan(probe_model, shape, mshape)
        # Match the full plan's fsdp decision for collective consistency.
        specs_p, _ = planner._param_specs(probe_model, mshape, fsdp)
        plan = dataclasses.replace(plan, param_specs=specs_p, n_micro=1)
        pspecs = input_specs(probe_cfg, shape)
        with mesh:
            with activation_rules(plan.activation_rules):
                with unrolled_scans():
                    lowered = _lower_cell(
                        probe_model, probe_cfg, shape, mesh, mshape, plan, pspecs,
                        n_micro=1, compress=False,
                    )
                    compiled = lowered.compile()
        cost = compiled.cost_analysis()
        if isinstance(cost, (list, tuple)):
            cost = cost[0]
        results.append(
            {
                "flops": float(cost.get("flops", 0.0)),
                "bytes": float(cost.get("bytes accessed", 0.0)),
                "coll": collective_bytes(compiled.as_text()),
            }
        )
    x1, x2 = results
    corr = _slstm_correction(cfg, shape, mshape)
    slstm_per_group = sum(1 for kind in cfg.pattern if kind == "slstm")

    def scale(a: float, b: float, c_per_group: float = 0.0) -> float:
        # Clamp: GSPMD occasionally shards the two probes differently, which
        # can make a per-group delta slightly negative; treat such costs as
        # depth-independent rather than extrapolating below zero.
        per_group = max(b - a, 0.0) + c_per_group
        return a + c_per_group + (G - 1) * per_group + (tail / P_len) * per_group

    flops = scale(x1["flops"], x2["flops"], corr["flops"] * slstm_per_group)
    nbytes = scale(x1["bytes"], x2["bytes"], corr["bytes"] * slstm_per_group)
    coll: Dict[str, float] = {}
    for op in set(x1["coll"]) | set(x2["coll"]):
        coll[op] = scale(x1["coll"].get(op, 0.0), x2["coll"].get(op, 0.0))
    return {
        "flops_per_device": flops,
        "bytes_per_device": nbytes,
        "collective_bytes_per_device": coll,
        "probe_raw": results,
        "n_groups": G,
        "tail_layers": tail,
    }


def dryrun_cell(
    arch: str,
    shape_name: str,
    *,
    multi_pod: bool = False,
    extra_flags: Optional[Dict[str, Any]] = None,
) -> Dict[str, Any]:
    """Lower + compile one (arch, shape, mesh) cell; return the record."""
    t0 = time.time()
    cfg = configs.get(arch)
    shape = shape_by_name(shape_name)
    skip = cell_skip_reason(cfg, shape)
    if skip:
        return {"arch": arch, "shape": shape_name, "multi_pod": multi_pod, "skip": skip}
    model = build(arch)
    mesh = make_production_mesh(multi_pod=multi_pod)
    mshape = mesh_shape(multi_pod=multi_pod)
    planner = ResourceAwarePlanner()
    plan = planner.plan(model, shape, mshape)
    specs = input_specs(cfg, shape)

    with mesh:
        with activation_rules(plan.activation_rules):
            lowered = _lower_cell(
                model, cfg, shape, mesh, mshape, plan, specs,
                n_micro=plan.n_micro, compress=multi_pod and shape.kind == "train",
            )
            compiled = lowered.compile()

    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis()
    if isinstance(cost, (list, tuple)):
        cost = cost[0]
    coll = collective_bytes(compiled.as_text())
    record = {
        "arch": arch,
        "shape": shape_name,
        "multi_pod": multi_pod,
        "devices": int(np.prod(list(mshape.axes.values()))),
        "plan": {
            "fsdp": plan.fsdp,
            "n_micro": plan.n_micro,
            "notes": plan.notes,
            "memory_estimate_gib": {
                k: v / 2**30 for k, v in plan.memory.as_dict().items()
            },
        },
        "memory_analysis": _mem_dict(mem),
        # Raw cost_analysis of the scanned program (While bodies counted
        # once — see probe_costs for the roofline-grade numbers).
        "raw_flops_scanned": float(cost.get("flops", 0.0)),
        "raw_bytes_scanned": float(cost.get("bytes accessed", 0.0)),
        "collective_ops_present": sorted(coll),
        "lower_compile_seconds": time.time() - t0,
    }
    if (extra_flags or {}).get("probes", True):
        t1 = time.time()
        probes = probe_costs(arch, shape, mesh, mshape, plan.fsdp, planner)
        record.update(probes)
        # Grad-accumulation correction: each microbatch reduces a full-size
        # gradient, so DP grad collectives scale with n_micro (probes run
        # n_micro=1).  Applied analytically to all-reduce/reduce-scatter.
        if shape.kind == "train" and plan.n_micro > 1:
            coll_p = record["collective_bytes_per_device"]
            for op in ("all-reduce", "reduce-scatter"):
                if op in coll_p:
                    coll_p[op] = coll_p[op] * plan.n_micro
            record["collective_note"] = (
                f"all-reduce/reduce-scatter scaled x{plan.n_micro} for grad accumulation"
            )
        record["probe_seconds"] = time.time() - t1
    print(
        f"[dryrun] {arch}/{shape_name} multi_pod={multi_pod} OK "
        f"({record['lower_compile_seconds']:.1f}s+{record.get('probe_seconds', 0):.1f}s, "
        f"flops/dev={record.get('flops_per_device', 0):.3e}, "
        f"coll/dev={sum(record.get('collective_bytes_per_device', {}).values()):.3e}B)"
    )
    return record


def _train_state_shapes(model, opts):
    from ..train import init_train_state

    return init_train_state(model, jax.random.PRNGKey(0), opts)


def _mem_dict(mem) -> Dict[str, float]:
    out = {}
    for attr in (
        "argument_size_in_bytes",
        "output_size_in_bytes",
        "temp_size_in_bytes",
        "generated_code_size_in_bytes",
    ):
        try:
            out[attr] = float(getattr(mem, attr))
        except Exception:
            pass
    if not out:
        out["repr"] = str(mem)
    return out


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--out", default="results/dryrun")
    ap.add_argument("--force", action="store_true")
    ap.add_argument(
        "--env",
        action="append",
        default=[],
        help="KEY=VAL optimization flags (e.g. REPRO_OPT_SWA=1), recorded per cell",
    )
    args = ap.parse_args()

    for kv in args.env:
        key, _, val = kv.partition("=")
        os.environ[key] = val
    os.makedirs(args.out, exist_ok=True)
    cells = []
    archs = configs.ARCHS if (args.all or args.arch is None) else [args.arch]
    shapes = [s.name for s in SHAPES] if (args.all or args.shape is None) else [args.shape]
    meshes = [False, True] if args.both_meshes else [args.multi_pod]
    for arch in archs:
        for shape_name in shapes:
            for mp in meshes:
                cells.append((arch, shape_name, mp))

    failures = []
    for arch, shape_name, mp in cells:
        tag = f"{arch}__{shape_name}__{'mp' if mp else 'sp'}"
        path = os.path.join(args.out, tag + ".json")
        if os.path.exists(path) and not args.force:
            print(f"[dryrun] {tag} cached")
            continue
        try:
            record = dryrun_cell(arch, shape_name, multi_pod=mp)
            if args.env:
                record["opt_env"] = args.env
        except Exception as e:  # noqa: BLE001
            traceback.print_exc()
            failures.append(tag)
            record = {
                "arch": arch,
                "shape": shape_name,
                "multi_pod": mp,
                "error": f"{type(e).__name__}: {e}",
            }
        with open(path, "w") as f:
            json.dump(record, f, indent=2)
    if failures:
        print(f"[dryrun] FAILURES: {failures}")
        raise SystemExit(1)
    print("[dryrun] all cells done")


if __name__ == "__main__":
    main()
