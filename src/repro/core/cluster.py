"""Cluster model: racks of worker nodes with resource budgets (paper §3, §4).

Mirrors the paper's Emulab environment (§6.1): racks connected by a
top-of-rack switch, nodes with CPU-point / memory-MB budgets, and the
network-distance hierarchy the scheduling insight is built on:

    intra-process < inter-process < inter-node (intra-rack) < inter-rack
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Iterable, List, Mapping, Optional, Tuple

from .resources import BANDWIDTH, CPU, MEMORY, ResourceVector, demand

# Network-distance constants (dimensionless hop weights used by Alg 4's
# distance term; latency seconds used by the simulator live on NetworkModel).
D_INTRA_PROCESS = 0.0
D_INTER_PROCESS = 0.5
D_INTER_NODE = 1.0
D_INTER_RACK = 2.0


@dataclasses.dataclass
class NodeSpec:
    """Static description of one worker node (paper §5.2 storm.yaml)."""

    node_id: str
    rack_id: str
    cpu_capacity: float = 100.0       # supervisor.cpu.capacity (points)
    memory_capacity_mb: float = 2048.0  # supervisor.memory.capacity.mb
    bandwidth_capacity: float = 100.0   # NIC, arbitrary units (Mbps in paper)
    num_worker_slots: int = 4


class Node:
    """A worker node with mutable remaining availability A_θ."""

    def __init__(self, spec: NodeSpec):
        self.spec = spec
        self.available = demand(
            spec.memory_capacity_mb, spec.cpu_capacity, spec.bandwidth_capacity
        )
        self.assigned_tasks: List = []
        self.alive = True

    @property
    def id(self) -> str:  # noqa: A003
        return self.spec.node_id

    @property
    def rack_id(self) -> str:
        return self.spec.rack_id

    @property
    def capacity(self) -> ResourceVector:
        return demand(
            self.spec.memory_capacity_mb,
            self.spec.cpu_capacity,
            self.spec.bandwidth_capacity,
        )

    def can_fit_hard(self, task_demand: ResourceVector) -> bool:
        return self.available.satisfies_hard(task_demand)

    def assign(self, task, task_demand: ResourceVector) -> None:
        self.assigned_tasks.append(task)
        self.available = self.available - task_demand

    def unassign(self, task, task_demand: ResourceVector) -> None:
        self.assigned_tasks.remove(task)
        self.available = self.available + task_demand

    def used(self) -> ResourceVector:
        return self.capacity - self.available

    def __repr__(self) -> str:
        return f"Node({self.id}@{self.rack_id}, avail={dict(self.available.values)})"


class Cluster:
    """A set of racks, each holding worker nodes."""

    def __init__(self, nodes: Iterable[NodeSpec]):
        self.nodes: Dict[str, Node] = {}
        self.racks: Dict[str, List[str]] = {}
        for spec in nodes:
            if spec.node_id in self.nodes:
                raise ValueError(f"duplicate node id {spec.node_id!r}")
            self.nodes[spec.node_id] = Node(spec)
            self.racks.setdefault(spec.rack_id, []).append(spec.node_id)
        if not self.nodes:
            raise ValueError("cluster must have at least one node")

    # -- construction helpers -------------------------------------------------
    @classmethod
    def homogeneous(
        cls,
        *,
        racks: int,
        nodes_per_rack: int,
        cpu: float = 100.0,
        memory_mb: float = 2048.0,
        bandwidth: float = 100.0,
        slots: int = 4,
    ) -> "Cluster":
        """The paper's Emulab layout: e.g. racks=2, nodes_per_rack=6."""
        specs = [
            NodeSpec(
                node_id=f"r{r}n{n}",
                rack_id=f"rack{r}",
                cpu_capacity=cpu,
                memory_capacity_mb=memory_mb,
                bandwidth_capacity=bandwidth,
                num_worker_slots=slots,
            )
            for r in range(racks)
            for n in range(nodes_per_rack)
        ]
        return cls(specs)

    # -- queries ---------------------------------------------------------------
    def live_nodes(self) -> List[Node]:
        return [n for n in self.nodes.values() if n.alive]

    def network_distance(self, a: str, b: str) -> float:
        """Hop-weight distance between two nodes (Alg 4's netDist term)."""
        if a == b:
            return D_INTER_PROCESS  # same node, different worker process
        na, nb = self.nodes[a], self.nodes[b]
        if na.rack_id == nb.rack_id:
            return D_INTER_NODE
        return D_INTER_RACK

    def rack_available(self, rack_id: str) -> ResourceVector:
        acc = demand()
        for nid in self.racks[rack_id]:
            node = self.nodes[nid]
            if node.alive:
                acc = acc + node.available
        return acc

    def rack_with_most_resources(self) -> str:
        """Alg 4 line 7 — rack with max total availability.

        'Most resources' is the sum over soft+hard dims of availability,
        normalized per-dim by cluster-wide capacity so that no single unit
        (MB vs points) dominates.
        """
        totals: Dict[str, float] = {}
        cap = self.total_capacity()
        for rid in self.racks:
            avail = self.rack_available(rid)
            # Sorted dims: the accumulation order of this float sum feeds
            # Ref-Node choice, so it must not depend on PYTHONHASHSEED.
            totals[rid] = sum(
                avail[d] / cap[d] for d in sorted(avail.dims) if cap[d] > 0
            )
        # Deterministic tie-break by rack id.
        return max(sorted(totals), key=lambda r: totals[r])

    def node_with_most_resources(self, rack_id: str) -> Node:
        """Alg 4 line 8 — node in the rack with max availability."""
        cap = self.total_capacity()

        def score(nid: str) -> float:
            avail = self.nodes[nid].available
            return sum(
                avail[d] / cap[d] for d in sorted(avail.dims) if cap[d] > 0
            )

        live = [nid for nid in self.racks[rack_id] if self.nodes[nid].alive]
        if not live:
            raise RuntimeError(f"no live nodes in rack {rack_id}")
        best = max(sorted(live), key=score)
        return self.nodes[best]

    def total_capacity(self) -> ResourceVector:
        acc = demand()
        for node in self.nodes.values():
            acc = acc + node.capacity
        return acc

    def total_available(self) -> ResourceVector:
        acc = demand()
        for node in self.live_nodes():
            acc = acc + node.available
        return acc

    # -- failure injection (fault-tolerance path) ------------------------------
    def fail_node(self, node_id: str) -> List:
        """Mark a node dead; return the tasks that were running on it."""
        node = self.nodes[node_id]
        node.alive = False
        orphans = list(node.assigned_tasks)
        node.assigned_tasks.clear()
        node.available = node.capacity  # resources are gone with the node
        return orphans

    def restore_node(self, node_id: str) -> None:
        node = self.nodes[node_id]
        node.alive = True
        node.available = node.capacity
        node.assigned_tasks.clear()

    def reset(self) -> None:
        for node in self.nodes.values():
            node.available = node.capacity
            node.assigned_tasks.clear()
            node.alive = True

    def __repr__(self) -> str:
        return f"Cluster({len(self.racks)} racks, {len(self.nodes)} nodes)"


def emulab_cluster() -> Cluster:
    """The paper's §6.1 experimental cluster: 12 workers in 2 racks,
    1 core (100 points) and 2 GB per node, 100 Mbps NICs."""
    return Cluster.homogeneous(racks=2, nodes_per_rack=6)


def emulab_cluster_24() -> Cluster:
    """The paper's §6.5 multi-topology cluster: 24 machines in two 12-node
    sub-clusters."""
    return Cluster.homogeneous(racks=2, nodes_per_rack=12)
