# Reconfiguration plane: rebalance-after-failure as a first-class search
# problem.  The greedy orphan patch-up (core.rescheduler) stays the default
# and the bit-identical baseline; `mode="search"` seeds the batch annealer
# from the current assignment and searches (migration set × placement)
# jointly, trading throughput/netcost gains against per-task migration
# penalties, with a simulated never-worse-than-greedy guarantee.  The
# DRS-style ReconfigPolicy turns observed queue/utilization series into
# reactive rebalance triggers.
from .engine import (
    DEFAULT_MOVE_COST,
    RECONFIG_MODES,
    RECONFIG_SCHEMAS,
    ReconfigEngine,
    validate_reconfig,
)
from .policy import ReconfigPolicy

__all__ = [
    "DEFAULT_MOVE_COST",
    "RECONFIG_MODES",
    "RECONFIG_SCHEMAS",
    "ReconfigEngine",
    "ReconfigPolicy",
    "validate_reconfig",
]
