"""DRS-style reactive reconfiguration policy (Fu et al., PAPERS.md).

Lifecycle events (fail/join) are not the only reason to rebalance: a load
shift can leave the placement stale while every node stays alive.  The
policy turns the observability plane's measured signals — the DES
executor's ``des.node_utilization`` and ``des.task_queue_depth`` series —
into rebalance triggers: when per-node utilization imbalance (max − mean)
or queue depth stays above threshold for ``sustain`` consecutive
intervals, the scenario runner fires one budgeted search rebalance, then
holds off for ``cooldown`` intervals so a slow-draining backlog doesn't
re-trigger on its own echo.

The decision is a pure function of hub state and the policy's counters —
no clocks, no randomness — so a replay triggers on exactly the same steps
every time.
"""

from __future__ import annotations

from typing import Optional

import numpy as np


class ReconfigPolicy:
    """Sustained-imbalance trigger over the obs hub's DES series."""

    def __init__(
        self,
        util_imbalance: float = 0.25,
        queue_depth: Optional[float] = None,
        sustain: int = 1,
        cooldown: int = 1,
    ):
        if util_imbalance < 0:
            raise ValueError(
                f"util_imbalance must be >= 0, got {util_imbalance!r}"
            )
        if queue_depth is not None and queue_depth < 0:
            raise ValueError(f"queue_depth must be >= 0, got {queue_depth!r}")
        if sustain < 1:
            raise ValueError(f"sustain must be >= 1, got {sustain!r}")
        if cooldown < 0:
            raise ValueError(f"cooldown must be >= 0, got {cooldown!r}")
        self.util_imbalance = util_imbalance
        self.queue_depth = queue_depth
        self.sustain = sustain
        self.cooldown = cooldown
        #: Most recent (max − mean) node utilization, for introspection.
        self.last_imbalance: Optional[float] = None
        self._hot = 0
        self._cooldown_left = 0
        self.triggers = 0

    def observe(self, hub) -> bool:
        """Read the latest interval's signals; True ⇔ fire a rebalance now.

        ``hub.find`` returns metrics in export (sorted-key) order, so the
        reduction order — and therefore the decision — is deterministic.
        """
        if not getattr(hub, "enabled", False):
            return False
        utils = [
            float(series.points[-1][1])
            for _, series in hub.find("series", "des.node_utilization")
            if series.points
        ]
        hot = False
        if len(utils) >= 2:
            arr = np.array(utils, dtype=np.float64)
            self.last_imbalance = float(arr.max() - arr.mean())
            hot = self.last_imbalance > self.util_imbalance
        if not hot and self.queue_depth is not None:
            depths = [
                float(series.points[-1][1])
                for _, series in hub.find("series", "des.task_queue_depth")
                if series.points
            ]
            if depths and max(depths) >= self.queue_depth:
                hot = True
        if self._cooldown_left > 0:
            self._cooldown_left -= 1
            self._hot = 0
            return False
        self._hot = self._hot + 1 if hot else 0
        if self._hot >= self.sustain:
            self._hot = 0
            self._cooldown_left = self.cooldown
            self.triggers += 1
            return True
        return False
