"""Joint (migration set × placement) reconfiguration engine.

The paper's §3 asks that "if there are failures … the scheduler must be
able to produce another scheduling quickly"; the greedy ``Rescheduler``
answers with an O(orphans × nodes) patch-up that never reconsiders healthy
placements.  This engine makes reconfiguration a search problem instead:

* ``mode="greedy"`` delegates verbatim to :class:`~repro.core.rescheduler.
  Rescheduler` — same objects, same call order — so existing scenario
  traces replay bit-identically (pinned by the golden-equivalence tests).
* ``mode="search"`` first runs the greedy pass (a complete feasible
  baseline, and the fallback when search finds nothing better), then — per
  topology — seeds the batch annealer from the *current* assignment and
  searches migrations and orphan placements jointly.  Each surviving task
  carries a ``move_cost`` penalty on the netcost term (threaded through
  all three evaluator backends), so the search only relocates a healthy
  task when the throughput/netcost gain pays for the disruption; orphan
  moves are sunk (zero cost).  A candidate is committed only if the full
  multi-topology simulation (``stream.simulator.run_many``) shows **no
  topology** losing sink throughput versus the greedy baseline — the
  never-worse guarantee measured in what §6 measures.

Budgeted calls (``budget_s``) resolve chains×steps through the portfolio's
deterministic tier plan — no wall-clock read anywhere in the decision
path, so a control loop gets a latency contract without losing replay
determinism.
"""

from __future__ import annotations

from typing import Any, Dict, List, Mapping, Optional, Tuple

import numpy as np

from ..assignment import Assignment
from ..engine import PlacementArena
from ..multitopology import GlobalState
from ..registry import KwargField
from ..rescheduler import RebalanceResult, Rescheduler
from ..search.anneal import BatchAnnealer, OBJECTIVES
from ..search.backend import BACKENDS, resolve_backend
from ..search.batch import BatchArena
from ..search.objective import evaluate_batch
from ..search.portfolio import PERTURB_SWAPS, _perturb, budget_plan
from ..search.throughput import compile_throughput, quantize

#: Reconfiguration modes the control plane validates against.
RECONFIG_MODES = ("greedy", "search")

#: Per-task migration penalty, in net-distance hops: relocating a healthy
#: task must buy at least this much netcost reduction (or a throughput
#: gain) to be accepted.  Dyadic, so the summed move term stays exact.
DEFAULT_MOVE_COST = 0.5

#: Per-mode kwargs schemas (the scheduler-registry validation idiom, so
#: ``reconfig_kwargs`` are validated data, not code).
RECONFIG_SCHEMAS: Dict[str, Dict[str, KwargField]] = {
    "greedy": {},
    "search": {
        "n_chains": KwargField(
            types=(int,), default=16, minimum=1, doc="parallel search chains"
        ),
        "steps": KwargField(
            types=(int,), default=600, minimum=1, doc="swap proposals per chain"
        ),
        "seed": KwargField(types=(int,), default=0, minimum=0, doc="PRNG seed"),
        "objective": KwargField(
            types=(str,),
            default="throughput",
            choices=OBJECTIVES,
            doc="what the rebalance search optimizes (throughput sees a CPU "
            "hotspot; netcost is the QM3DKP quadratic term)",
        ),
        "backend": KwargField(
            types=(str,),
            default="auto",
            choices=BACKENDS,
            doc="batch evaluator backend (golden-equal across all three)",
        ),
        "multi_swap": KwargField(
            types=(int,),
            default=8,
            minimum=1,
            doc="swap proposals fused per lax.scan element (jax/pallas)",
        ),
        "move_cost": KwargField(
            types=(int, float),
            default=DEFAULT_MOVE_COST,
            minimum=0,
            doc="per-task migration penalty in net-distance hops (grid-"
            "quantized; orphans move free — their move is sunk)",
        ),
        "budget_s": KwargField(
            types=(int, float, type(None)),
            default=None,
            doc="latency budget (seconds): chains×steps from the portfolio's "
            "deterministic tier plan instead of the explicit kwargs",
        ),
    },
}


def validate_reconfig(
    mode: Any, kwargs: Optional[Mapping[str, Any]] = None, path: str = "reconfig"
) -> List[str]:
    """Validate a (mode, kwargs) pair; returns all error strings at once."""
    if mode not in RECONFIG_MODES:
        return [
            f"{path}: unknown mode {mode!r}; choose from {sorted(RECONFIG_MODES)}"
        ]
    schema = RECONFIG_SCHEMAS[mode]
    errors: List[str] = []
    for key in sorted(kwargs or {}):
        if key not in schema:
            errors.append(
                f"{path}.{key}: unknown kwarg for mode {mode!r}; "
                f"allowed: {sorted(schema)}"
            )
            continue
        err = schema[key].check(f"{path}.{key}", kwargs[key])
        if err:
            errors.append(err)
            continue
        # budget_s is strictly positive when given (KwargField minimums are
        # inclusive and skip None, so the exclusive bound is checked here).
        if (
            key == "budget_s"
            and kwargs[key] is not None
            and kwargs[key] <= 0
        ):
            errors.append(
                f"{path}.budget_s: must be > 0 (seconds), got {kwargs[key]!r}"
            )
    return errors


class ReconfigEngine:
    """One reconfiguration plane over a :class:`GlobalState`.

    The lifecycle verbs mirror the greedy ``Rescheduler``'s: ``fail_node``
    (lazy, Storm-like — orphans wait for a rebalance), ``handle_scale_up``
    and ``rebalance``.
    """

    def __init__(
        self,
        state: GlobalState,
        weights=None,
        mode: str = "greedy",
        kwargs: Optional[Mapping[str, Any]] = None,
    ):
        errors = validate_reconfig(mode, kwargs)
        if errors:
            raise ValueError("; ".join(errors))
        self.state = state
        self.weights = weights
        self.mode = mode
        merged = {k: f.default for k, f in RECONFIG_SCHEMAS[mode].items()}
        merged.update(kwargs or {})
        self.kwargs = merged
        if mode == "search":
            merged["backend"] = resolve_backend(merged["backend"])
        self._greedy = Rescheduler(state, weights)

    # -- lifecycle verbs -------------------------------------------------------
    def fail_node(self, node_id: str) -> List[Tuple[str, str]]:
        """Mark a node dead; orphans stay recorded until a rebalance (the
        assignment outlives the worker, as in Storm's ZooKeeper state)."""
        return self.state.fail_node(node_id)

    def handle_scale_up(self, node_specs) -> RebalanceResult:
        """Join fresh nodes, then re-place (and in search mode, re-search)."""
        if self.mode == "greedy":
            return self._greedy.handle_scale_up(node_specs)
        pre = self._snapshot()
        self._greedy.handle_scale_up(node_specs)
        return self._search_pass(pre)

    def rebalance(self) -> RebalanceResult:
        """Re-place orphaned and unassigned tasks; in search mode, also
        search (migration × placement) jointly from the greedy baseline."""
        if self.mode == "greedy":
            return self._greedy.rebalance()
        pre = self._snapshot()
        self._greedy.rebalance()
        return self._search_pass(pre)

    # -- search mode -----------------------------------------------------------
    def _snapshot(self) -> Dict[str, Dict[str, str]]:
        """Pre-rebalance placements (dead-node entries included): the
        reference frame migration penalties and ``moved`` are charged in."""
        return {
            topo_id: dict(a.placements)
            for topo_id, a in self.state.assignments.items()
        }

    def _search_pass(self, pre: Dict[str, Dict[str, str]]) -> RebalanceResult:
        state = self.state
        for topo_id in sorted(state.assignments):
            if len(state.assignments[topo_id].placements) >= 2:
                self._search_topology(topo_id, pre.get(topo_id, {}))
        # The result is recomputed against the pre-rebalance frame, so a
        # task greedy placed and search then relocated counts once.
        result = RebalanceResult()
        for topo_id in sorted(state.assignments):
            a = state.assignments[topo_id]
            p0 = pre.get(topo_id, {})
            moved = sorted(
                tid for tid, nid in a.placements.items() if p0.get(tid) != nid
            )
            if moved:
                result.moved[topo_id] = moved
            if a.unassigned:
                result.unplaced[topo_id] = sorted(a.unassigned)
        return result

    def _plan(self, n_tasks: int) -> Tuple[int, int]:
        if self.kwargs["budget_s"] is not None:
            return budget_plan(float(self.kwargs["budget_s"]), n_tasks)
        return self.kwargs["n_chains"], self.kwargs["steps"]

    def _search_topology(self, topo_id: str, pre: Dict[str, str]) -> None:
        """Anneal one topology's placements from the greedy baseline and
        commit the winner iff no topology loses simulated throughput."""
        state, cluster = self.state, self.state.cluster
        topology = state.topologies[topo_id]
        assignment = state.assignments[topo_id]
        placements = dict(assignment.placements)
        tasks = {t.id: t for t in topology.all_tasks()}

        # The arena ledger reflects every committed topology; virtually
        # unassigning *this* topology's tasks yields the capacity budget
        # its candidates are scored against (other tenants stay charged).
        arena = PlacementArena(cluster, topology, self.weights)
        rows: Dict[str, np.ndarray] = {}

        def row_of(tid: str) -> np.ndarray:
            cid = tasks[tid].component_id
            if cid not in rows:
                rows[cid] = arena.compile_demand(
                    topology.components[cid].resource_demand
                )[0]
            return rows[cid]

        for tid in sorted(placements):
            arena.unassign(arena.index[placements[tid]], row_of(tid))
        avail0 = arena.snapshot()
        ba = BatchArena.from_arena(arena, topology, placements, avail0=avail0)

        # Migration term: surviving tasks pay move_cost off their pre-
        # rebalance node; orphans and previously-unassigned tasks move free.
        node_index = {nid: i for i, nid in enumerate(ba.node_ids)}
        mb = np.zeros(ba.n_tasks, dtype=np.intp)
        mc = np.zeros(ba.n_tasks, dtype=np.float64)
        cost = float(quantize(np.float64(self.kwargs["move_cost"])))
        for i, tid in enumerate(ba.tids):
            prev = pre.get(tid)
            if prev is not None and cluster.nodes[prev].alive:
                mb[i] = node_index[prev]
                mc[i] = cost
            else:
                mb[i] = node_index[placements[tid]]
        ba.move_base, ba.move_cost = mb, mc

        greedy_row = ba.encode(placements)
        n_chains, steps = self._plan(ba.n_tasks)
        objective = self.kwargs["objective"]
        backend = self.kwargs["backend"]
        seed = self.kwargs["seed"]
        tm = (
            compile_throughput(ba, topology, cluster)
            if objective == "throughput"
            else None
        )
        P0 = np.tile(greedy_row, (n_chains, 1))
        # Chain 0 stays the greedy baseline; the rest explore perturbations.
        _perturb(P0, np.arange(1, n_chains), PERTURB_SWAPS, seed ^ 0x5EED)
        P = BatchAnnealer(ba, backend=backend).run(
            P0, steps, seed, objective=objective, tm=tm,
            multi_swap=self.kwargs["multi_swap"],
        )
        result = evaluate_batch(ba, P, backend=backend, throughput_model=tm)
        base = evaluate_batch(ba, greedy_row, backend=backend, throughput_model=tm)
        candidate = self._pick(ba, P, result, base, objective)
        if candidate is None:
            return
        if not self._simulated_no_worse(topo_id, candidate):
            return
        # Commit the diff through the node ledger (the same unassign/assign
        # bookkeeping every other lifecycle verb uses).
        for tid in sorted(candidate):
            new_nid = candidate[tid]
            old_nid = placements[tid]
            if new_nid == old_nid:
                continue
            task = tasks[tid]
            d = topology.demand_of(task)
            old_node = cluster.nodes[old_nid]
            if task in old_node.assigned_tasks:
                old_node.unassign(task, d)
            cluster.nodes[new_nid].assign(task, d)
            assignment.placements[tid] = new_nid

    def _pick(
        self, ba, P, result, base, objective
    ) -> Optional[Dict[str, str]]:
        """Best feasible chain strictly better than the greedy baseline.
        ``net`` already carries the move penalty (the baseline's is 0.0 —
        it never relocates a surviving task), so "better" means the gain
        outweighs the disruption."""
        if objective == "throughput":
            tp = np.where(result.feasible, result.throughput, -np.inf)
            best_tp = tp.max()
            if not np.isfinite(best_tp):
                return None
            tie = tp == best_tp
            net = np.where(tie, result.net, np.inf)
            best = int(np.argmin(net))  # ties → lowest chain index
            g_tp, g_net = float(base.throughput[0]), float(base.net[0])
            if (tp[best], -net[best]) <= (g_tp, -g_net):
                return None
        else:
            cand = np.where(result.feasible, result.net, np.inf)
            best = int(np.argmin(cand))
            if not np.isfinite(cand[best]) or cand[best] >= base.net[0]:
                return None
        return ba.decode(P[best])

    def _simulated_no_worse(
        self, topo_id: str, candidate: Dict[str, str]
    ) -> bool:
        """Joint never-worse guard: simulate all tenants together with the
        candidate swapped in; every topology must hold its sink throughput
        versus the greedy baseline (a strictly-better proxy keeps a tie)."""
        from ...stream.simulator import Simulator  # lazy: stream imports core

        state = self.state
        sim = Simulator(state.cluster)

        def run_all(trial: Optional[Dict[str, str]]) -> Dict[str, float]:
            pairs = []
            for tid in sorted(state.assignments):
                p = (
                    trial
                    if trial is not None and tid == topo_id
                    else state.assignments[tid].placements
                )
                pairs.append(
                    (state.topologies[tid], Assignment(tid, placements=dict(p)))
                )
            return {
                tid: r.sink_throughput
                for tid, r in sim.run_many(pairs).items()
            }

        base = run_all(None)
        with_candidate = run_all(candidate)
        return all(
            with_candidate[tid] >= base[tid] for tid in sorted(base)
        )
