"""Algorithm 2 — BFS topology traversal, and Algorithm 3 — task selection.

The BFS starts from the spouts ("the performance of spout(s) impacts the
performance of the whole topology", §4.1.1) and yields a partial ordering of
components in which adjacent components sit in close succession.  Task
selection then round-robins one task per component over that ordering until
every task is ordered — so tasks of adjacent components are scheduled as
close together (in time, hence by the greedy node selection in space) as
possible.
"""

from __future__ import annotations

from collections import deque
from typing import Dict, List, Optional, Sequence

from .topology import Component, Task, Topology


def bfs_topology_traversal(topology: Topology, roots: Optional[Sequence[str]] = None) -> List[str]:
    """Alg 2, generalized to multiple roots (all spouts enqueue first).

    Returns component ids in BFS order.  Neighbour expansion follows
    ``Topology.neighbors`` (downstream first, then upstream), which makes the
    traversal well-defined on DAGs with joins and on (the paper's claim of
    support for) cyclic topologies alike — visited-set bookkeeping terminates
    cycles.
    """
    if roots is None:
        roots = [c.id for c in topology.spouts]
    if not roots:
        return []
    queue: deque = deque()
    visited: List[str] = []
    seen = set()
    for root in roots:
        if root not in topology.components:
            raise KeyError(f"unknown root component {root!r}")
        if root not in seen:
            queue.append(root)
            seen.add(root)
            visited.append(root)
    while queue:
        com = queue.popleft()
        for nbr in topology.neighbors(com):
            if nbr not in seen:
                seen.add(nbr)
                visited.append(nbr)
                queue.append(nbr)
    # Isolated components (none in valid topologies, but keep total).
    for cid in topology.components:
        if cid not in seen:
            visited.append(cid)
    return visited


def task_selection(topology: Topology) -> List[Task]:
    """Alg 3 — interleave one task per component over the BFS ordering."""
    order = bfs_topology_traversal(topology)
    remaining: Dict[str, List[Task]] = {
        cid: list(topology.components[cid].tasks(topology.id)) for cid in order
    }
    task_ordering: List[Task] = []
    total = topology.task_count()
    while len(task_ordering) < total:
        progressed = False
        for cid in order:
            bucket = remaining[cid]
            if bucket:
                task_ordering.append(bucket.pop(0))
                progressed = True
        if not progressed:  # pragma: no cover - defensive
            break
    return task_ordering
