"""Schedulers: R-Storm (Alg 1) and the default-Storm round-robin baseline,
plus beyond-paper variants (DESIGN.md §6).

Every scheduler is a pure function of (topology, cluster-state): it never
mutates the cluster it is given unless ``commit=True`` — matching Nimbus
statelessness (paper §5) and enabling deterministic elastic re-planning.
"""

from __future__ import annotations

import copy
import itertools
import random
import time
from typing import Dict, List, Mapping, Optional, Sequence, Tuple

from .assignment import Assignment
from .cluster import Cluster
from .node_selection import DEFAULT_SOFT_WEIGHTS, NodeSelector
from .registry import (
    KwargField,
    REGISTRY,
    SCHEDULERS,
    get_scheduler,
    register_scheduler,
    scheduler_names,
    validate_scheduler_kwargs,
)
from .resources import ResourceVector
from .topology import Task, Topology
from .traversal import bfs_topology_traversal, task_selection

# Shared kwarg schemas.
_WEIGHTS = KwargField(
    types=(dict, type(None)),
    default=None,
    doc="soft-dimension distance weights (Alg 4), e.g. {'cpu_points': 4e-4}",
)
_SEED = KwargField(types=(int,), default=0, minimum=0, doc="PRNG seed")


class Scheduler:
    """Interface mirroring Storm's IScheduler (paper §5)."""

    name = "base"

    def schedule(self, topology: Topology, cluster: Cluster, *, commit: bool = True) -> Assignment:
        raise NotImplementedError

    # Shared plumbing ----------------------------------------------------------
    def _finish(
        self,
        topology: Topology,
        cluster: Cluster,
        work: Cluster,
        assignment: Assignment,
        commit: bool,
        t0: float,
    ) -> Assignment:
        assignment.scheduler_name = self.name
        assignment.schedule_time_s = time.perf_counter() - t0
        if commit:
            # Atomic apply onto the real cluster (paper §4.1).
            assignment.apply(topology, cluster)
        return assignment


@register_scheduler("rstorm", kwargs_schema={"weights": _WEIGHTS})
class RStormScheduler(Scheduler):
    """Algorithm 1: taskOrdering = TaskSelection(); for each task, NodeSelection."""

    def __init__(self, weights: Optional[Mapping[str, float]] = None):
        self.weights = weights

    def schedule(self, topology: Topology, cluster: Cluster, *, commit: bool = True) -> Assignment:
        t0 = time.perf_counter()
        topology.validate()
        # Plan against a scratch copy so planning is side-effect free.
        work = copy.deepcopy(cluster)
        selector = NodeSelector(work, self.weights)
        assignment = Assignment(topology_id=topology.id)
        for task in task_selection(topology):
            d = topology.demand_of(task)
            node = selector.select(d)
            if node is None:
                assignment.unassigned.append(task.id)
                continue
            node.assign(task, d)
            assignment.placements[task.id] = node.id
        return self._finish(topology, cluster, work, assignment, commit, t0)


@register_scheduler(
    "round_robin",
    kwargs_schema={
        "seed": _SEED,
        "slot_mode": KwargField(
            types=(str,),
            default="port_major",
            choices=("port_major", "node_major"),
            doc="worker-slot ordering; node_major reproduces the §6.3.2 Star bottleneck",
        ),
    },
)
class RoundRobinScheduler(Scheduler):
    """Default Storm: pseudo-random round-robin over worker slots (§2).

    Resource demand and availability are ignored entirely (that is the
    paper's point).  Only liveness is respected.  Two slot orderings exist in
    deployed Storm versions:

    * ``port_major`` (default): slots interleave across nodes, so tasks of a
      single component land on different machines — the behaviour the paper
      describes in §2;
    * ``node_major``: a node's worker slots are consecutive, so consecutive
      tasks (often of the *same* component) stack onto one machine — the
      behaviour behind the paper's §6.3.2 Star bottleneck ("one of the
      machines ... gets over utilized ... and creates a bottleneck").
    """

    def __init__(self, seed: int = 0, slot_mode: str = "port_major"):
        if slot_mode not in ("port_major", "node_major"):
            raise ValueError(f"unknown slot_mode {slot_mode!r}")
        self.seed = seed
        self.slot_mode = slot_mode

    def schedule(self, topology: Topology, cluster: Cluster, *, commit: bool = True) -> Assignment:
        t0 = time.perf_counter()
        topology.validate()
        work = copy.deepcopy(cluster)
        rng = random.Random(self.seed)
        nodes = sorted(n.id for n in work.live_nodes())
        if not nodes:
            raise RuntimeError("no live nodes")
        rng.shuffle(nodes)  # 'pseudo-random' starting permutation
        # Build the slot list in the configured order.
        if self.slot_mode == "port_major":
            slots = []
            max_slots = max(work.nodes[n].spec.num_worker_slots for n in nodes)
            for port in range(max_slots):
                for n in nodes:
                    if port < work.nodes[n].spec.num_worker_slots:
                        slots.append(n)
        else:  # node_major
            slots = [
                n for n in nodes for _ in range(work.nodes[n].spec.num_worker_slots)
            ]
        assignment = Assignment(topology_id=topology.id)
        cursor = itertools.cycle(slots)
        for task in topology.all_tasks():
            nid = next(cursor)
            assignment.placements[task.id] = nid
            work.nodes[nid].assign(task, topology.demand_of(task))
        return self._finish(topology, cluster, work, assignment, commit, t0)


@register_scheduler("rstorm_plus", kwargs_schema={"weights": _WEIGHTS})
class RStormPlusScheduler(RStormScheduler):
    """Beyond-paper variant (DESIGN.md §6.1):

    (a) the Ref Node follows the last successfully used node per *component*,
        so wide topologies anchor each branch locally instead of pulling every
        branch toward one global anchor;
    (b) among equidistant candidates, prefers the node already hosting an
        upstream peer of the task (explicit quadratic-term credit).
    """

    def schedule(self, topology: Topology, cluster: Cluster, *, commit: bool = True) -> Assignment:
        t0 = time.perf_counter()
        topology.validate()
        work = copy.deepcopy(cluster)
        selector = NodeSelector(work, self.weights)
        assignment = Assignment(topology_id=topology.id)
        upstream_of = {cid: set(topology.upstream(cid)) for cid in topology.components}
        placed_by_component: Dict[str, List[str]] = {}
        for task in task_selection(topology):
            d = topology.demand_of(task)
            # (b) credit: nodes hosting upstream peers get a distance discount.
            peers = set()
            for up in upstream_of[task.component_id]:
                peers.update(placed_by_component.get(up, []))
            node = self._select_with_credit(selector, work, d, peers)
            if node is None:
                assignment.unassigned.append(task.id)
                continue
            node.assign(task, d)
            assignment.placements[task.id] = node.id
            placed_by_component.setdefault(task.component_id, []).append(node.id)
            # (a) per-branch anchoring.
            selector.ref_node = node.id
        return self._finish(topology, cluster, work, assignment, commit, t0)

    @staticmethod
    def _select_with_credit(selector: NodeSelector, work: Cluster, d: ResourceVector, peers) -> Optional[object]:
        import math

        if selector.ref_node is None or not work.nodes[selector.ref_node].alive:
            selector._establish_ref_node()
        best, best_d = None, math.inf
        for nid in sorted(work.nodes):
            node = work.nodes[nid]
            if not node.alive or not node.can_fit_hard(d):
                continue
            dist = selector.distance(d, node)
            if nid in peers:
                dist *= 0.75  # colocate-with-upstream credit
            if dist < best_d - 1e-12:
                best, best_d = node, dist
        return best


@register_scheduler(
    "rstorm_annealed",
    kwargs_schema={
        "iters": KwargField(
            types=(int,), default=400, minimum=1, doc="local-search swap budget"
        ),
        "seed": _SEED,
        "weights": _WEIGHTS,
    },
)
class AnnealedScheduler(Scheduler):
    """Beyond-paper (DESIGN.md §6.2): R-Storm seed + pairwise-swap local search
    minimizing (network cost, soft overload) lexicographically.

    Deliberately budgeted (``iters``) to stay within the paper's "snappy
    scheduling" requirement.
    """

    def __init__(self, iters: int = 400, seed: int = 0, weights=None):
        self.iters = iters
        self.seed = seed
        self.weights = weights

    def schedule(self, topology: Topology, cluster: Cluster, *, commit: bool = True) -> Assignment:
        t0 = time.perf_counter()
        seed_assignment = RStormScheduler(self.weights).schedule(
            topology, cluster, commit=False
        )
        rng = random.Random(self.seed)
        placements = dict(seed_assignment.placements)
        tasks = {t.id: t for t in topology.all_tasks()}
        demands = {tid: topology.demand_of(t) for tid, t in tasks.items()}
        tids = sorted(placements)

        def mem_overload(pl: Dict[str, str]) -> float:
            used: Dict[str, float] = {}
            for tid, nid in pl.items():
                used[nid] = used.get(nid, 0.0) + demands[tid]["memory_mb"]
            over = 0.0
            for nid, u in used.items():
                cap = cluster.nodes[nid].spec.memory_capacity_mb
                over += max(0.0, u - cap)
            return over

        def cost(pl: Dict[str, str]) -> float:
            a = Assignment(topology.id, placements=pl)
            return a.network_cost(topology, cluster) + 1e6 * mem_overload(pl)

        cur = cost(placements)
        if len(tids) >= 2:
            for _ in range(self.iters):
                a, b = rng.sample(tids, 2)
                if placements[a] == placements[b]:
                    continue
                placements[a], placements[b] = placements[b], placements[a]
                new = cost(placements)
                if new <= cur:
                    cur = new
                else:
                    placements[a], placements[b] = placements[b], placements[a]
        out = Assignment(
            topology_id=topology.id,
            placements=placements,
            unassigned=list(seed_assignment.unassigned),
        )
        return self._finish(topology, cluster, copy.deepcopy(cluster), out, commit, t0)


# ``SCHEDULERS`` and ``get_scheduler`` now live on the registry and are
# re-exported here (populated above via @register_scheduler).
__all__ = [
    "AnnealedScheduler",
    "KwargField",
    "REGISTRY",
    "RoundRobinScheduler",
    "RStormPlusScheduler",
    "RStormScheduler",
    "SCHEDULERS",
    "Scheduler",
    "get_scheduler",
    "register_scheduler",
    "scheduler_names",
    "validate_scheduler_kwargs",
]
