"""Schedulers: R-Storm (Alg 1) and the default-Storm round-robin baseline,
plus beyond-paper variants (DESIGN.md §6).

Every scheduler is a pure function of (topology, cluster-state): it never
mutates the cluster it is given unless ``commit=True`` — matching Nimbus
statelessness (paper §5) and enabling deterministic elastic re-planning.

All schedulers run on the array-backed placement engine
(:mod:`repro.core.engine`) by default: the cluster is compiled into dense
arrays once per ``schedule()`` call, node selection is a vectorized masked
reduction, and planning needs no ``copy.deepcopy(cluster)``.  The dict-based
``NodeSelector`` path is retained as the reference implementation behind
``engine="legacy"`` and is pinned bit-identical by the golden-equivalence
suite.
"""

from __future__ import annotations

import copy
import itertools
import random
from typing import Dict, List, Mapping, Optional

import numpy as np

from ..obs import clock as obs_clock
from .assignment import Assignment
from .cluster import Cluster
from .engine import ArenaSelector, PlacementArena, SwapAnnealer
from .node_selection import NodeSelector, PEER_CREDIT
from .registry import (
    KwargField,
    REGISTRY,
    SCHEDULERS,
    get_scheduler,
    register_scheduler,
    scheduler_names,
    validate_scheduler_kwargs,
)
from .topology import Topology
from .traversal import task_selection

# Shared kwarg schemas.
_WEIGHTS = KwargField(
    types=(dict, type(None)),
    default=None,
    doc="soft-dimension distance weights (Alg 4), e.g. {'cpu_points': 4e-4}",
)
_SEED = KwargField(types=(int,), default=0, minimum=0, doc="PRNG seed")
_ENGINE = KwargField(
    types=(str,),
    default="arena",
    choices=("arena", "legacy"),
    doc="placement engine: 'arena' (vectorized array core) or 'legacy' "
    "(dict-based reference path)",
)

def _check_engine(engine: str) -> str:
    if engine not in ("arena", "legacy"):
        raise ValueError(f"unknown engine {engine!r}")
    return engine


class Scheduler:
    """Interface mirroring Storm's IScheduler (paper §5)."""

    name = "base"

    def schedule(self, topology: Topology, cluster: Cluster, *, commit: bool = True) -> Assignment:
        raise NotImplementedError

    # Shared plumbing ----------------------------------------------------------
    def _finish(
        self,
        topology: Topology,
        cluster: Cluster,
        assignment: Assignment,
        commit: bool,
        t0: float,
    ) -> Assignment:
        assignment.scheduler_name = self.name
        assignment.schedule_time_s = obs_clock.perf_counter() - t0
        if commit:
            # Atomic apply onto the real cluster (paper §4.1).
            assignment.apply(topology, cluster)
        return assignment


@register_scheduler("rstorm", kwargs_schema={"weights": _WEIGHTS, "engine": _ENGINE})
class RStormScheduler(Scheduler):
    """Algorithm 1: taskOrdering = TaskSelection(); for each task, NodeSelection."""

    #: R-Storm+ flips this: upstream-peer colocation credit + per-branch
    #: Ref-Node anchoring in the shared arena placement loop.
    _upstream_credit = False

    def __init__(self, weights: Optional[Mapping[str, float]] = None, engine: str = "arena"):
        self.weights = weights
        self.engine = _check_engine(engine)

    def schedule(self, topology: Topology, cluster: Cluster, *, commit: bool = True) -> Assignment:
        t0 = obs_clock.perf_counter()
        topology.validate()
        assignment = Assignment(topology_id=topology.id)
        if self.engine == "legacy":
            self._legacy_place(topology, cluster, assignment)
        else:
            # Arena path: compile once, then one vectorized reduction per task.
            # The arena's availability ledger is the scratch state — the real
            # cluster is never touched until commit.
            arena = PlacementArena(cluster, topology, self.weights)
            self._place_on_arena(arena, topology, assignment)
        return self._finish(topology, cluster, assignment, commit, t0)

    def _legacy_place(self, topology: Topology, cluster: Cluster, assignment: Assignment) -> None:
        """Reference path: plan against a deep scratch copy."""
        work = copy.deepcopy(cluster)
        selector = NodeSelector(work, self.weights)
        for task in task_selection(topology):
            d = topology.demand_of(task)
            node = selector.select(d)
            if node is None:
                assignment.unassigned.append(task.id)
                continue
            node.assign(task, d)
            assignment.placements[task.id] = node.id

    def _place_on_arena(
        self,
        arena: PlacementArena,
        topology: Topology,
        assignment: Assignment,
        order=None,
    ) -> None:
        """The one placement loop both R-Storm and R-Storm+ run on the arena
        (and that the search subsystem re-runs under randomized task orders
        via ``order``; default is Alg 3's task selection)."""
        selector = ArenaSelector(arena)
        rows: Dict[str, tuple] = {}
        hosts: Dict[str, np.ndarray] = {}
        upstream_of = (
            {cid: set(topology.upstream(cid)) for cid in topology.components}
            if self._upstream_credit
            else {}
        )
        for task in task_selection(topology) if order is None else order:
            cid = task.component_id
            if cid not in rows:
                rows[cid] = arena.compile_demand(
                    topology.components[cid].resource_demand
                )
            row, hard = rows[cid]
            credit_mask = None
            # Sorted for replayability; OR-ing host masks is commutative, but
            # the iteration must not depend on set hash order regardless.
            for up in sorted(upstream_of.get(cid, ())):
                if up in hosts:
                    credit_mask = (
                        hosts[up] if credit_mask is None else credit_mask | hosts[up]
                    )
            i = selector.select(row, hard, credit_mask=credit_mask)
            if i is None:
                assignment.unassigned.append(task.id)
                continue
            arena.assign(i, row)
            assignment.placements[task.id] = arena.node_ids[i]
            if self._upstream_credit:
                if cid not in hosts:
                    hosts[cid] = np.zeros(len(arena.node_ids), dtype=bool)
                hosts[cid][i] = True
                # Per-branch anchoring (DESIGN.md §6.1a).
                selector.ref_node = i

    def _arena_seed(self, topology: Topology, cluster: Cluster):
        """(arena, assignment) for callers that keep working on the arena —
        the annealer reuses the compiled net matrix instead of recompiling."""
        arena = PlacementArena(cluster, topology, self.weights)
        assignment = Assignment(topology_id=topology.id)
        self._place_on_arena(arena, topology, assignment)
        return arena, assignment


@register_scheduler(
    "round_robin",
    kwargs_schema={
        "seed": _SEED,
        "slot_mode": KwargField(
            types=(str,),
            default="port_major",
            choices=("port_major", "node_major"),
            doc="worker-slot ordering; node_major reproduces the §6.3.2 Star bottleneck",
        ),
        "engine": _ENGINE,
    },
)
class RoundRobinScheduler(Scheduler):
    """Default Storm: pseudo-random round-robin over worker slots (§2).

    Resource demand and availability are ignored entirely (that is the
    paper's point).  Only liveness is respected.  Two slot orderings exist in
    deployed Storm versions:

    * ``port_major`` (default): slots interleave across nodes, so tasks of a
      single component land on different machines — the behaviour the paper
      describes in §2;
    * ``node_major``: a node's worker slots are consecutive, so consecutive
      tasks (often of the *same* component) stack onto one machine — the
      behaviour behind the paper's §6.3.2 Star bottleneck ("one of the
      machines ... gets over utilized ... and creates a bottleneck").
    """

    def __init__(self, seed: int = 0, slot_mode: str = "port_major", engine: str = "arena"):
        if slot_mode not in ("port_major", "node_major"):
            raise ValueError(f"unknown slot_mode {slot_mode!r}")
        self.seed = seed
        self.slot_mode = slot_mode
        self.engine = _check_engine(engine)

    def _slot_order(self, cluster: Cluster, nodes: List[str]) -> List[str]:
        """Worker-slot node sequence in the configured order."""
        if self.slot_mode == "port_major":
            slots = []
            max_slots = max(cluster.nodes[n].spec.num_worker_slots for n in nodes)
            for port in range(max_slots):
                for n in nodes:
                    if port < cluster.nodes[n].spec.num_worker_slots:
                        slots.append(n)
            return slots
        return [n for n in nodes for _ in range(cluster.nodes[n].spec.num_worker_slots)]

    def schedule(self, topology: Topology, cluster: Cluster, *, commit: bool = True) -> Assignment:
        t0 = obs_clock.perf_counter()
        topology.validate()
        assignment = Assignment(topology_id=topology.id)
        # Placements depend only on specs and liveness, so both engines share
        # one loop with no scratch copy (``engine`` kept for API uniformity).
        rng = random.Random(self.seed)
        nodes = sorted(n.id for n in cluster.live_nodes())
        if not nodes:
            raise RuntimeError("no live nodes")
        rng.shuffle(nodes)  # 'pseudo-random' starting permutation
        cursor = itertools.cycle(self._slot_order(cluster, nodes))
        for task in topology.all_tasks():
            assignment.placements[task.id] = next(cursor)
        return self._finish(topology, cluster, assignment, commit, t0)


@register_scheduler("rstorm_plus", kwargs_schema={"weights": _WEIGHTS, "engine": _ENGINE})
class RStormPlusScheduler(RStormScheduler):
    """Beyond-paper variant (DESIGN.md §6.1):

    (a) the Ref Node follows the last successfully used node per *component*,
        so wide topologies anchor each branch locally instead of pulling every
        branch toward one global anchor;
    (b) among equidistant candidates, prefers the node already hosting an
        upstream peer of the task (explicit quadratic-term credit — the
        ``credit_nodes`` option of node selection).

    The arena path is the shared ``_place_on_arena`` loop with
    ``_upstream_credit`` on (per-component host masks OR-ed over upstream
    components as the vector discount).
    """

    _upstream_credit = True

    def _legacy_place(self, topology: Topology, cluster: Cluster, assignment: Assignment) -> None:
        work = copy.deepcopy(cluster)
        selector = NodeSelector(work, self.weights)
        upstream_of = {cid: set(topology.upstream(cid)) for cid in topology.components}
        placed_by_component: Dict[str, List[str]] = {}
        for task in task_selection(topology):
            d = topology.demand_of(task)
            # (b) credit: nodes hosting upstream peers get a discount.
            peers = set()
            for up in sorted(upstream_of[task.component_id]):
                peers.update(placed_by_component.get(up, []))
            node = selector.select(d, credit_nodes=peers, credit=PEER_CREDIT)
            if node is None:
                assignment.unassigned.append(task.id)
                continue
            node.assign(task, d)
            assignment.placements[task.id] = node.id
            placed_by_component.setdefault(task.component_id, []).append(node.id)
            # (a) per-branch anchoring.
            selector.ref_node = node.id


@register_scheduler(
    "rstorm_annealed",
    kwargs_schema={
        "iters": KwargField(
            types=(int,), default=400, minimum=1, doc="local-search swap budget"
        ),
        "seed": _SEED,
        "weights": _WEIGHTS,
        "engine": _ENGINE,
    },
)
class AnnealedScheduler(Scheduler):
    """Beyond-paper (DESIGN.md §6.2): R-Storm seed + pairwise-swap local search
    minimizing (network cost, soft overload) lexicographically.

    Deliberately budgeted (``iters``) to stay within the paper's "snappy
    scheduling" requirement.  The arena engine evaluates each candidate swap
    incrementally in O(degree) instead of recomputing the full O(E) network
    cost, so swap budgets 10-100× larger fit the same wall-clock budget.
    """

    def __init__(self, iters: int = 400, seed: int = 0, weights=None, engine: str = "arena"):
        self.iters = iters
        self.seed = seed
        self.weights = weights
        self.engine = _check_engine(engine)

    def schedule(self, topology: Topology, cluster: Cluster, *, commit: bool = True) -> Assignment:
        t0 = obs_clock.perf_counter()
        rng = random.Random(self.seed)
        if self.engine == "legacy":
            seed_assignment = RStormScheduler(self.weights, engine="legacy").schedule(
                topology, cluster, commit=False
            )
            placements = self._legacy_swap_loop(
                topology, cluster, dict(seed_assignment.placements), rng
            )
        else:
            # Seed and anneal on one arena: the swap loop only reads the net
            # matrix and node index, so the seed's compile is reused.
            topology.validate()
            arena, seed_assignment = RStormScheduler(self.weights)._arena_seed(
                topology, cluster
            )
            placements = SwapAnnealer(
                arena, topology, dict(seed_assignment.placements)
            ).run(self.iters, rng)
        out = Assignment(
            topology_id=topology.id,
            placements=placements,
            unassigned=list(seed_assignment.unassigned),
        )
        # The swap loop never mutates the cluster, so no scratch copy is
        # needed — commit applies onto the real cluster as usual.
        return self._finish(topology, cluster, out, commit, t0)

    def _legacy_swap_loop(
        self,
        topology: Topology,
        cluster: Cluster,
        placements: Dict[str, str],
        rng: random.Random,
    ) -> Dict[str, str]:
        """Reference implementation: full O(E) cost recomputation per swap."""
        tasks = {t.id: t for t in topology.all_tasks()}
        demands = {tid: topology.demand_of(t) for tid, t in tasks.items()}
        tids = sorted(placements)

        def mem_overload(pl: Dict[str, str]) -> float:
            used: Dict[str, float] = {}
            for tid, nid in pl.items():
                used[nid] = used.get(nid, 0.0) + demands[tid]["memory_mb"]
            over = 0.0
            for nid, u in used.items():
                cap = cluster.nodes[nid].spec.memory_capacity_mb
                over += max(0.0, u - cap)
            return over

        def cost(pl: Dict[str, str]) -> float:
            a = Assignment(topology.id, placements=pl)
            return a.network_cost(topology, cluster) + 1e6 * mem_overload(pl)

        cur = cost(placements)
        if len(tids) >= 2:
            for _ in range(self.iters):
                a, b = rng.sample(tids, 2)
                if placements[a] == placements[b]:
                    continue
                placements[a], placements[b] = placements[b], placements[a]
                new = cost(placements)
                if new <= cur:
                    cur = new
                else:
                    placements[a], placements[b] = placements[b], placements[a]
        return placements


# ``SCHEDULERS`` and ``get_scheduler`` now live on the registry and are
# re-exported here (populated above via @register_scheduler).
__all__ = [
    "AnnealedScheduler",
    "KwargField",
    "PEER_CREDIT",
    "REGISTRY",
    "RoundRobinScheduler",
    "RStormPlusScheduler",
    "RStormScheduler",
    "SCHEDULERS",
    "Scheduler",
    "get_scheduler",
    "register_scheduler",
    "scheduler_names",
    "validate_scheduler_kwargs",
]
