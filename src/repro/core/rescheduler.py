"""Failure handling and elastic rescaling (paper §3: "if there are failures
... the scheduler must be able to produce another scheduling quickly").

Only the orphaned tasks are re-placed (NodeSelection over surviving nodes —
the same code path as initial placement); healthy placements are untouched,
so a reschedule is O(orphans × nodes), not a full re-plan.
"""

from __future__ import annotations

import dataclasses
import math
import statistics
import time
from typing import Dict, List, Optional, Tuple

from .assignment import Assignment
from .cluster import Cluster
from .multitopology import GlobalState
from .node_selection import NodeSelector
from .topology import Task, Topology


@dataclasses.dataclass
class RebalanceResult:
    """Outcome of one rebalancing pass, per topology.

    ``moved`` — tasks that landed on a (new) live node; ``unplaced`` — tasks
    the pass could not place without violating a hard constraint (they stay
    in their assignment's ``unassigned`` list awaiting capacity).  The two
    are disjoint: a task that ends up unassigned is *not* reported as moved.
    """

    moved: Dict[str, List[str]] = dataclasses.field(default_factory=dict)
    unplaced: Dict[str, List[str]] = dataclasses.field(default_factory=dict)

    def __bool__(self) -> bool:
        return bool(self.moved or self.unplaced)

    def moved_count(self) -> int:
        return sum(len(v) for v in self.moved.values())

    def unplaced_count(self) -> int:
        return sum(len(v) for v in self.unplaced.values())

    def to_dict(self) -> Dict[str, Dict[str, List[str]]]:
        return {
            "moved": {tid: list(v) for tid, v in sorted(self.moved.items())},
            "unplaced": {tid: list(v) for tid, v in sorted(self.unplaced.items())},
        }


class Rescheduler:
    def __init__(self, state: GlobalState, weights=None):
        self.state = state
        self.weights = weights

    def handle_node_failure(self, node_id: str) -> RebalanceResult:
        """Fail ``node_id`` and re-place its tasks.  Tasks that cannot be
        placed on the survivors are reported in ``result.unplaced``."""
        self.state.fail_node(node_id)
        return self._replace_orphans()

    def handle_scale_up(self, node_specs) -> RebalanceResult:
        """Elastic scale-up: add nodes, then re-place any unassigned tasks."""
        self.state.add_nodes(node_specs)
        return self._replace_orphans(include_unassigned=True)

    def rebalance(self) -> RebalanceResult:
        """Re-place orphaned *and* unassigned tasks on the current cluster."""
        return self._replace_orphans(include_unassigned=True)

    def _replace_orphans(self, include_unassigned: bool = False) -> RebalanceResult:
        cluster = self.state.cluster
        result = RebalanceResult()
        orphans_by_topo: Dict[str, List[str]] = {}
        for topo_id, tid in self.state.orphaned_tasks():
            orphans_by_topo.setdefault(topo_id, []).append(tid)
        for topo_id, assignment in self.state.assignments.items():
            topology = self.state.topologies[topo_id]
            tasks = {t.id: t for t in topology.all_tasks()}
            orphans = list(orphans_by_topo.get(topo_id, []))
            if include_unassigned:
                orphans += [t for t in assignment.unassigned if t in tasks]
            if not orphans:
                continue
            selector = NodeSelector(cluster, self.weights)
            # Anchor near the surviving mass of this topology: use the node
            # hosting most of its tasks as the ref node.
            counts: Dict[str, int] = {}
            for tid, nid in assignment.placements.items():
                if cluster.nodes[nid].alive:
                    counts[nid] = counts.get(nid, 0) + 1
            if counts:
                selector.ref_node = max(sorted(counts), key=lambda n: counts[n])
            for tid in orphans:
                task = tasks[tid]
                d = topology.demand_of(task)
                node = selector.select(d)
                if tid in assignment.placements:
                    del assignment.placements[tid]
                if tid in assignment.unassigned:
                    assignment.unassigned.remove(tid)
                if node is None:
                    assignment.unassigned.append(tid)
                    result.unplaced.setdefault(topo_id, []).append(tid)
                else:
                    node.assign(task, d)
                    assignment.placements[tid] = node.id
                    result.moved.setdefault(topo_id, []).append(tid)
        return result


class StragglerMitigator:
    """Migrate tasks whose observed service time exceeds ``factor`` × the
    component median (DESIGN.md §5).  Observation feed comes from the stream
    executor's StatisticServer."""

    def __init__(self, state: GlobalState, factor: float = 3.0, weights=None):
        self.state = state
        self.factor = factor
        self.weights = weights

    def _task_components(self) -> Dict[str, Tuple[str, str]]:
        """task id -> (topology_id, component_id), resolved through the live
        Topology objects rather than parsing the id string (task-id formats
        are a rendering detail, and bare ids collide across topologies)."""
        out: Dict[str, Tuple[str, str]] = {}
        for topo in self.state.topologies.values():
            for task in topo.all_tasks():
                out[task.id] = (topo.id, task.component_id)
        return out

    def find_stragglers(self, service_times: Dict[str, float]) -> List[str]:
        """service_times: task id -> EWMA seconds/tuple.  Ids not belonging
        to any submitted topology are ignored (nothing to migrate)."""
        components = self._task_components()
        by_component: Dict[Tuple[str, str], List[float]] = {}
        for tid, s in service_times.items():
            comp = components.get(tid)
            if comp is not None:
                by_component.setdefault(comp, []).append(s)
        medians = {c: statistics.median(v) for c, v in by_component.items()}
        out = []
        for tid, s in service_times.items():
            comp = components.get(tid)
            if comp is None:
                continue
            med = medians[comp]
            if med > 0 and s > self.factor * med:
                out.append(tid)
        return sorted(out)

    def migrate(self, task_ids: List[str]) -> Dict[str, str]:
        """Move straggling tasks to the closest feasible *other* node.

        One ``_task_components`` resolution up front, then a single walk of
        ``task_ids`` — O(task_ids × nodes), not O(task_ids × topologies):
        the same map ``find_stragglers`` already resolves collisions with.
        """
        cluster = self.state.cluster
        moves: Dict[str, str] = {}
        components = self._task_components()
        selector = NodeSelector(cluster, self.weights)
        tasks_by_topo: Dict[str, Dict[str, Task]] = {}
        for tid in task_ids:
            comp = components.get(tid)
            if comp is None:
                continue
            topo_id = comp[0]
            assignment = self.state.assignments.get(topo_id)
            if assignment is None or tid not in assignment.placements:
                continue
            topology = self.state.topologies[topo_id]
            tasks = tasks_by_topo.get(topo_id)
            if tasks is None:
                tasks = tasks_by_topo[topo_id] = {
                    t.id: t for t in topology.all_tasks()
                }
            old_nid = assignment.placements[tid]
            task = tasks[tid]
            d = topology.demand_of(task)
            old_node = cluster.nodes[old_nid]
            if task in old_node.assigned_tasks:
                old_node.unassign(task, d)
            selector.ref_node = old_nid  # stay close to prior placement
            best = None
            best_d = math.inf
            for nid in sorted(cluster.nodes):
                node = cluster.nodes[nid]
                if nid == old_nid or not node.alive or not node.can_fit_hard(d):
                    continue
                dist = selector.distance(d, node)
                if dist < best_d:
                    best, best_d = node, dist
            if best is None:  # nowhere better — put it back
                old_node.assign(task, d)
                continue
            best.assign(task, d)
            assignment.placements[tid] = best.id
            moves[tid] = best.id
        return moves
