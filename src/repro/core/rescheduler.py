"""Failure handling and elastic rescaling (paper §3: "if there are failures
... the scheduler must be able to produce another scheduling quickly").

Only the orphaned tasks are re-placed (NodeSelection over surviving nodes —
the same code path as initial placement); healthy placements are untouched,
so a reschedule is O(orphans × nodes), not a full re-plan.
"""

from __future__ import annotations

import time
from typing import Dict, List, Optional

from .assignment import Assignment
from .cluster import Cluster
from .multitopology import GlobalState
from .node_selection import NodeSelector
from .topology import Task, Topology


class Rescheduler:
    def __init__(self, state: GlobalState, weights=None):
        self.state = state
        self.weights = weights

    def handle_node_failure(self, node_id: str) -> Dict[str, List[str]]:
        """Fail ``node_id`` and re-place its tasks.  Returns per-topology lists
        of task ids that were migrated (or left unassigned if infeasible)."""
        cluster = self.state.cluster
        cluster.fail_node(node_id)
        return self._replace_orphans()

    def handle_scale_up(self, node_specs) -> Dict[str, List[str]]:
        """Elastic scale-up: add nodes, then re-place any unassigned tasks."""
        from .cluster import Node

        for spec in node_specs:
            if spec.node_id in self.state.cluster.nodes:
                raise ValueError(f"node {spec.node_id!r} already exists")
            self.state.cluster.nodes[spec.node_id] = Node(spec)
            self.state.cluster.racks.setdefault(spec.rack_id, []).append(spec.node_id)
        return self._replace_orphans(include_unassigned=True)

    def rebalance(self) -> Dict[str, List[str]]:
        """Re-place orphaned *and* unassigned tasks on the current cluster."""
        return self._replace_orphans(include_unassigned=True)

    def _replace_orphans(self, include_unassigned: bool = False) -> Dict[str, List[str]]:
        cluster = self.state.cluster
        moved: Dict[str, List[str]] = {}
        orphans_by_topo: Dict[str, List[str]] = {}
        for topo_id, tid in self.state.orphaned_tasks():
            orphans_by_topo.setdefault(topo_id, []).append(tid)
        for topo_id, assignment in self.state.assignments.items():
            topology = self.state.topologies[topo_id]
            tasks = {t.id: t for t in topology.all_tasks()}
            orphans = list(orphans_by_topo.get(topo_id, []))
            if include_unassigned:
                orphans += [t for t in assignment.unassigned if t in tasks]
            if not orphans:
                continue
            selector = NodeSelector(cluster, self.weights)
            # Anchor near the surviving mass of this topology: use the node
            # hosting most of its tasks as the ref node.
            counts: Dict[str, int] = {}
            for tid, nid in assignment.placements.items():
                if cluster.nodes[nid].alive:
                    counts[nid] = counts.get(nid, 0) + 1
            if counts:
                selector.ref_node = max(sorted(counts), key=lambda n: counts[n])
            for tid in orphans:
                task = tasks[tid]
                d = topology.demand_of(task)
                node = selector.select(d)
                if tid in assignment.placements:
                    del assignment.placements[tid]
                if tid in assignment.unassigned:
                    assignment.unassigned.remove(tid)
                if node is None:
                    assignment.unassigned.append(tid)
                else:
                    node.assign(task, d)
                    assignment.placements[tid] = node.id
                moved.setdefault(topo_id, []).append(tid)
        return moved


class StragglerMitigator:
    """Migrate tasks whose observed service time exceeds ``factor`` × the
    component median (DESIGN.md §5).  Observation feed comes from the stream
    executor's StatisticServer."""

    def __init__(self, state: GlobalState, factor: float = 3.0, weights=None):
        self.state = state
        self.factor = factor
        self.weights = weights

    def find_stragglers(self, service_times: Dict[str, float]) -> List[str]:
        """service_times: task id -> EWMA seconds/tuple."""
        import statistics

        by_component: Dict[str, List[float]] = {}
        for tid, s in service_times.items():
            comp = tid.split("[")[0]
            by_component.setdefault(comp, []).append(s)
        medians = {c: statistics.median(v) for c, v in by_component.items()}
        out = []
        for tid, s in service_times.items():
            comp = tid.split("[")[0]
            med = medians[comp]
            if med > 0 and s > self.factor * med:
                out.append(tid)
        return sorted(out)

    def migrate(self, task_ids: List[str]) -> Dict[str, str]:
        """Move straggling tasks to the closest feasible *other* node."""
        cluster = self.state.cluster
        moves: Dict[str, str] = {}
        for topo_id, assignment in self.state.assignments.items():
            topology = self.state.topologies[topo_id]
            tasks = {t.id: t for t in topology.all_tasks()}
            for tid in task_ids:
                if tid not in assignment.placements or tid not in tasks:
                    continue
                old_nid = assignment.placements[tid]
                task = tasks[tid]
                d = topology.demand_of(task)
                old_node = cluster.nodes[old_nid]
                if task in old_node.assigned_tasks:
                    old_node.unassign(task, d)
                selector = NodeSelector(cluster, self.weights)
                selector.ref_node = old_nid  # stay close to prior placement
                best = None
                import math

                best_d = math.inf
                for nid in sorted(cluster.nodes):
                    node = cluster.nodes[nid]
                    if nid == old_nid or not node.alive or not node.can_fit_hard(d):
                        continue
                    dist = selector.distance(d, node)
                    if dist < best_d:
                        best, best_d = node, dist
                if best is None:  # nowhere better — put it back
                    old_node.assign(task, d)
                    continue
                best.assign(task, d)
                assignment.placements[tid] = best.id
                moves[tid] = best.id
        return moves
