# The paper's primary contribution: R-Storm resource-aware scheduling
# (Alg 1-4), the round-robin default-Storm baseline, multi-topology global
# state, and failure/elastic rescheduling.
from .resources import (
    BANDWIDTH,
    CPU,
    MEMORY,
    ResourceVector,
    demand,
    weighted_distance,
)
from .topology import Component, Task, Topology
from .cluster import Cluster, Node, NodeSpec, emulab_cluster, emulab_cluster_24
from .traversal import bfs_topology_traversal, task_selection
from .node_selection import NodeSelector
from .engine import ArenaSelector, PlacementArena, SwapAnnealer
from .assignment import Assignment
from .schedulers import (
    AnnealedScheduler,
    RoundRobinScheduler,
    RStormPlusScheduler,
    RStormScheduler,
    SCHEDULERS,
    Scheduler,
    get_scheduler,
)
from .registry import (
    REGISTRY,
    KwargField,
    SchedulerEntry,
    register_scheduler,
    scheduler_names,
    validate_scheduler_kwargs,
)
from .multitopology import GlobalState
from .rescheduler import RebalanceResult, Rescheduler, StragglerMitigator

# The batched placement-search subsystem; importing registers the
# "rstorm-search" scheduler alongside the greedy/annealed ones.
from .search import (
    BatchAnnealer,
    BatchArena,
    SearchScheduler,
    ThroughputModel,
    compile_throughput,
    evaluate_batch,
    throughput_batch,
)

__all__ = [
    "BANDWIDTH",
    "CPU",
    "MEMORY",
    "ResourceVector",
    "demand",
    "weighted_distance",
    "Component",
    "Task",
    "Topology",
    "Cluster",
    "Node",
    "NodeSpec",
    "emulab_cluster",
    "emulab_cluster_24",
    "bfs_topology_traversal",
    "task_selection",
    "NodeSelector",
    "ArenaSelector",
    "PlacementArena",
    "SwapAnnealer",
    "BatchAnnealer",
    "BatchArena",
    "SearchScheduler",
    "ThroughputModel",
    "compile_throughput",
    "evaluate_batch",
    "throughput_batch",
    "Assignment",
    "Scheduler",
    "RStormScheduler",
    "RoundRobinScheduler",
    "RStormPlusScheduler",
    "AnnealedScheduler",
    "SCHEDULERS",
    "REGISTRY",
    "KwargField",
    "SchedulerEntry",
    "register_scheduler",
    "scheduler_names",
    "validate_scheduler_kwargs",
    "get_scheduler",
    "GlobalState",
    "RebalanceResult",
    "Rescheduler",
    "StragglerMitigator",
]
