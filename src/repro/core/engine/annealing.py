"""Incremental swap evaluation for the annealed scheduler.

The dict path recomputes the full O(E) ``Assignment.network_cost`` plus a
full per-node memory-overload pass on *every* candidate swap.  Here a swap is
evaluated in O(degree(a) + degree(b)) with precompiled adjacency arrays and
the arena's N×N net-distance matrix.

Exactness: every netDist value is a small multiple of 0.5, so sums and
differences of hop weights are exact in float64 — the incrementally-tracked
cost equals the full recomputation bit-for-bit, and accept/reject decisions
(hence placements) match the legacy annealer.  (Memory terms are exact for
any demand whose running per-node sums are representable, which holds for
the benchmark topologies; the golden suite pins this.)
"""

from __future__ import annotations

import random
from typing import Dict, List

import numpy as np

from .arena import PlacementArena, swap_network_delta, swap_overload_delta
from ..topology import Topology

#: Same soft-overload penalty weight as the legacy annealer cost.
OVERLOAD_PENALTY = 1e6


class SwapAnnealer:
    """Pairwise-swap local search over placed tasks of one topology.

    Minimizes ``network_cost + 1e6 × memory_overload`` with the same PRNG
    stream, acceptance rule (``new <= cur``) and iteration semantics as the
    legacy annealer — only the cost evaluation is incremental.
    """

    def __init__(
        self,
        arena: PlacementArena,
        topology: Topology,
        placements: Dict[str, str],
    ):
        self.arena = arena
        self.topology = topology
        # Sorted task ids: the legacy swap loop samples from sorted(placements),
        # so the PRNG stream is identical.
        self.tids: List[str] = sorted(placements)
        tindex = {tid: i for i, tid in enumerate(self.tids)}
        self._tindex = tindex
        self.p = np.array(
            [arena.index[placements[tid]] for tid in self.tids], dtype=np.intp
        )
        # Per-task hard-memory demand and per-node capacity (the legacy cost
        # checks placed-task memory against raw node capacity).
        demands = {t.id: topology.demand_of(t) for t in topology.all_tasks()}
        self.mem = np.array(
            [demands[tid]["memory_mb"] for tid in self.tids], dtype=np.float64
        )
        self.cap_mem = np.array(
            [
                arena.cluster.nodes[nid].spec.memory_capacity_mb
                for nid in arena.node_ids
            ],
            dtype=np.float64,
        )
        # Adjacency over placed tasks: one entry per directed task edge per
        # endpoint (edges with an unassigned endpoint never enter the cost).
        adj: List[List[int]] = [[] for _ in self.tids]
        edge_pairs: List[List[int]] = []
        for src, dst in topology.task_edges():
            a, b = tindex.get(src.id), tindex.get(dst.id)
            if a is None or b is None:
                continue
            edge_pairs.append([a, b])
            adj[a].append(b)
            adj[b].append(a)
        self.adj = [np.array(x, dtype=np.intp) for x in adj]
        self.edges = (
            np.array(edge_pairs, dtype=np.intp)
            if edge_pairs
            else np.zeros((0, 2), dtype=np.intp)
        )
        self.used_mem = np.zeros(len(arena.node_ids), dtype=np.float64)
        np.add.at(self.used_mem, self.p, self.mem)

    def _overload(self) -> float:
        return float(np.maximum(0.0, self.used_mem - self.cap_mem).sum())

    def cost(self) -> float:
        return self.arena.network_cost(self.p, self.edges) + OVERLOAD_PENALTY * self._overload()

    def run(self, iters: int, rng: random.Random) -> Dict[str, str]:
        """Budgeted swap loop; returns the improved task→node-id mapping."""
        arena, net = self.arena, self.arena.net
        cur = self.cost()
        if len(self.tids) >= 2:
            for _ in range(iters):
                a_id, b_id = rng.sample(self.tids, 2)
                ia, ib = self._tindex[a_id], self._tindex[b_id]
                na, nb = self.p[ia], self.p[ib]
                if na == nb:
                    continue
                # O(degree) network delta for swapping nodes of a and b
                # (shared with the batched search engine).
                pa, pb = self.p[self.adj[ia]], self.p[self.adj[ib]]
                m_ab = int((self.adj[ia] == ib).sum())
                delta = swap_network_delta(net, na, nb, pa, pb, m_ab)
                # O(2) memory-overload delta.
                ma, mb = self.mem[ia], self.mem[ib]
                ua, ub = self.used_mem[na], self.used_mem[nb]
                ua2, ub2 = ua - ma + mb, ub - mb + ma
                delta += OVERLOAD_PENALTY * swap_overload_delta(
                    self.cap_mem[na], self.cap_mem[nb], ua, ub, ma, mb
                )
                new = cur + delta
                if new <= cur:
                    self.p[ia], self.p[ib] = nb, na
                    self.used_mem[na], self.used_mem[nb] = ua2, ub2
                    cur = new
        return {tid: arena.node_ids[self.p[i]] for i, tid in enumerate(self.tids)}
