# Array-backed placement engine: the vectorized scheduling core every
# registered scheduler runs on (the dict-based NodeSelector path remains
# available as the reference implementation via ``engine="legacy"``).
from .arena import PlacementArena
from .selection import ArenaSelector
from .annealing import SwapAnnealer

__all__ = ["ArenaSelector", "PlacementArena", "SwapAnnealer"]
