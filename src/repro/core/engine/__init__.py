# Array-backed placement engine: the vectorized scheduling core every
# registered scheduler runs on (the dict-based NodeSelector path remains
# available as the reference implementation via ``engine="legacy"``).
from .arena import PlacementArena, swap_network_delta, swap_overload_delta
from .selection import ArenaSelector
from .annealing import SwapAnnealer

__all__ = [
    "ArenaSelector",
    "PlacementArena",
    "SwapAnnealer",
    "swap_network_delta",
    "swap_overload_delta",
]
