"""Arena-backed node selection — the vectorized twin of ``NodeSelector``.

Holds the Ref Node across calls (Alg 4's ``global refNode``) exactly like the
dict path, including re-establishment when the anchor dies, and supports the
upstream-peer credit discount as a first-class option (mirroring
``NodeSelector.select(..., credit_nodes=...)``).  Distance weights live on
the arena (passed at compile time).
"""

from __future__ import annotations

from typing import Optional

import numpy as np

from .arena import PlacementArena


class ArenaSelector:
    def __init__(self, arena: PlacementArena):
        self.arena = arena
        self.ref_node: Optional[int] = None

    def _ensure_ref(self) -> int:
        if self.ref_node is None or not self.arena.alive[self.ref_node]:
            self.ref_node = self.arena.establish_ref_node()
        return self.ref_node

    def select(
        self,
        demand_row: np.ndarray,
        hard_cols: np.ndarray,
        credit_mask: Optional[np.ndarray] = None,
        credit: Optional[float] = None,
    ) -> Optional[int]:
        """Argmin-distance feasible node index, or None (task unassigned)."""
        ref = self._ensure_ref()
        return self.arena.select(
            demand_row, hard_cols, ref, credit_mask=credit_mask, credit=credit
        )
