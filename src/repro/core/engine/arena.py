"""PlacementArena — the array-backed substrate under every scheduler.

A ``(Topology, Cluster)`` pair is compiled into dense numpy arrays once per
``schedule()`` call:

* an N×D node-availability matrix (D = union of resource dims),
* an N×N network-distance matrix precomputed from the rack topology,
* per-component demand rows and hard-constraint column masks,
* an alive mask.

On these, Alg 4's argmin-distance node selection is one masked vectorized
reduction, hard-constraint filtering is a boolean mask, and "plan on a
scratch copy" is a cheap availability snapshot/rollback instead of
``copy.deepcopy(cluster)``.  The arena never mutates the cluster it was
compiled from — commit still happens at the ``Assignment.apply`` boundary.

Numerical contract: for the canonical three-dimensional resource vectors the
arena computes the exact same float64 operations in an order equivalent (by
commutativity) to the dict path, so placements are bit-identical to
``NodeSelector`` — the golden-equivalence suite pins this.
"""

from __future__ import annotations

from typing import Dict, List, Mapping, Optional, Sequence, Tuple

import numpy as np

from ..cluster import (
    Cluster,
    D_INTER_NODE,
    D_INTER_PROCESS,
    D_INTER_RACK,
)
from ..node_selection import DEFAULT_SOFT_WEIGHTS, PEER_CREDIT
from ..resources import BANDWIDTH, ResourceVector
from ..topology import Topology

#: Same strict-improvement threshold as NodeSelector's sequential scan.
SELECT_EPS = 1e-12


def swap_network_delta(net, na, nb, pa, pb, m_ab=0, mask_a=None, mask_b=None, xp=np):
    """O(degree) network-cost delta for swapping the nodes of two tasks.

    The one incremental-delta implementation shared by the sequential
    ``SwapAnnealer`` (scalars: ``na``/``nb`` node indices, ``pa``/``pb`` the
    neighbours' node indices as ``(deg,)`` rows) and the batched search
    engine (``(B,)`` node indices, ``(B, max_deg)`` padded neighbour rows
    with ``mask_*`` flagging real entries, ``xp=jax.numpy`` inside jit).

    ``m_ab`` counts direct a-b edges: those terms cancel exactly in the true
    cost (``net`` is symmetric) but are double-counted by the two neighbour
    sums, so their spurious contribution is subtracted.
    """
    na_r = xp.asarray(na)[..., None]
    nb_r = xp.asarray(nb)[..., None]
    da = net[nb_r, pa] - net[na_r, pa]
    db = net[na_r, pb] - net[nb_r, pb]
    if mask_a is not None:
        da = xp.where(mask_a, da, 0.0)
    if mask_b is not None:
        db = xp.where(mask_b, db, 0.0)
    corr = net[na, na] + net[nb, nb] - 2.0 * net[na, nb]
    return da.sum(axis=-1) + db.sum(axis=-1) - m_ab * corr


def swap_overload_delta(cap_a, cap_b, used_a, used_b, dem_i, dem_j, xp=np):
    """Hard-dimension overload delta for the same swap, O(dims).

    Works on scalars (the annealer's single memory dimension) or on
    ``(B, Dh)`` per-chain rows (the batched search), summing the per-dim
    relu terms over the trailing axis.
    """
    ua2 = used_a - dem_i + dem_j
    ub2 = used_b - dem_j + dem_i
    d = (
        xp.maximum(0.0, ua2 - cap_a)
        - xp.maximum(0.0, used_a - cap_a)
        + xp.maximum(0.0, ub2 - cap_b)
        - xp.maximum(0.0, used_b - cap_b)
    )
    d = xp.asarray(d)
    return d.sum(axis=-1) if d.ndim else d


class PlacementArena:
    """Dense-array view of a cluster (plus optional topology demand dims)."""

    def __init__(
        self,
        cluster: Cluster,
        topology: Optional[Topology] = None,
        weights: Optional[Mapping[str, float]] = None,
    ):
        self.cluster = cluster
        # Node index <-> id, in sorted-id order (the dict path's iteration
        # order, so argmin tie-breaks agree).
        self.node_ids: List[str] = sorted(cluster.nodes)
        self.index: Dict[str, int] = {nid: i for i, nid in enumerate(self.node_ids)}
        n = len(self.node_ids)

        # Dimension columns: union of cluster availability dims and (when
        # given) topology demand dims, in sorted order.
        dims = set()
        for node in cluster.nodes.values():
            dims |= set(node.available.values)
        if topology is not None:
            for comp in topology.components.values():
                dims |= set(comp.resource_demand.values)
        self.dims: List[str] = sorted(dims)
        self.dim_col: Dict[str, int] = {d: j for j, d in enumerate(self.dims)}
        self._soft_cols = np.array(
            [j for j, d in enumerate(self.dims) if d != BANDWIDTH], dtype=np.intp
        )
        d = len(self.dims)

        self.avail = np.zeros((n, d), dtype=np.float64)
        self.capacity = np.zeros((n, d), dtype=np.float64)
        self.alive = np.zeros(n, dtype=bool)
        rack_ids = sorted(cluster.racks)
        rack_code = {rid: k for k, rid in enumerate(rack_ids)}
        self.rack_ids: List[str] = rack_ids
        self._rack_of = np.zeros(n, dtype=np.intp)
        for i, nid in enumerate(self.node_ids):
            node = cluster.nodes[nid]
            for dim, v in node.available.values.items():
                self.avail[i, self.dim_col[dim]] = v
            for dim, v in node.capacity.values.items():
                self.capacity[i, self.dim_col[dim]] = v
            self.alive[i] = node.alive
            self._rack_of[i] = rack_code[node.rack_id]

        # N×N network-distance matrix from the rack topology (Alg 4 netDist).
        same_rack = self._rack_of[:, None] == self._rack_of[None, :]
        self.net = np.where(same_rack, D_INTER_NODE, D_INTER_RACK)
        np.fill_diagonal(self.net, D_INTER_PROCESS)

        # Per-dim distance weights (NodeSelector/weighted_distance merge).
        merged = dict(DEFAULT_SOFT_WEIGHTS)
        if weights:
            merged.update(weights)
        self.weight_row = np.array(
            [merged.get(dim, 1.0) for dim in self.dims], dtype=np.float64
        )
        self._w_soft = self.weight_row[self._soft_cols]
        self._w_bw = merged.get(BANDWIDTH, 1.0)

    @property
    def rack_of(self) -> np.ndarray:
        """(N,) rack index per node (into ``rack_ids``) — the rack topology
        the batched search's link-flow proxy reduces over."""
        return self._rack_of

    # -- demand compilation ----------------------------------------------------
    def compile_demand(self, rv: ResourceVector) -> Tuple[np.ndarray, np.ndarray]:
        """(row over arena dims, hard-column index array) for one demand."""
        row = np.zeros(len(self.dims), dtype=np.float64)
        for dim, v in rv.values.items():
            row[self.dim_col[dim]] = v
        hard = np.array(sorted(self.dim_col[dim] for dim in rv.hard), dtype=np.intp)
        return row, hard

    # -- availability ledger ---------------------------------------------------
    def snapshot(self) -> np.ndarray:
        """Cheap copy of the availability ledger (replaces deepcopy)."""
        return self.avail.copy()

    def rollback(self, snap: np.ndarray) -> None:
        self.avail[...] = snap

    def assign(self, node_idx: int, demand_row: np.ndarray) -> None:
        self.avail[node_idx] -= demand_row

    def unassign(self, node_idx: int, demand_row: np.ndarray) -> None:
        self.avail[node_idx] += demand_row

    # -- Alg 4, vectorized -----------------------------------------------------
    def feasible_mask(self, demand_row: np.ndarray, hard_cols: np.ndarray) -> np.ndarray:
        """alive ∧ availability covers every hard dim (property 2, §4.1)."""
        if hard_cols.size == 0:
            return self.alive.copy()
        ok = (self.avail[:, hard_cols] >= demand_row[hard_cols]).all(axis=1)
        return self.alive & ok

    def distances(self, demand_row: np.ndarray, ref_idx: int) -> np.ndarray:
        """Alg 4 DISTANCE from every node, as one vectorized row.

        sqrt(Σ_soft w_d (demand_d − avail_d)² + w_bw netDist(ref, ·)²) —
        same float64 ops as ``weighted_distance`` per node.
        """
        diff = demand_row[self._soft_cols] - self.avail[:, self._soft_cols]
        acc = (self._w_soft * diff**2).sum(axis=1)
        acc += self._w_bw * self.net[ref_idx] ** 2
        return np.sqrt(acc)

    def select(
        self,
        demand_row: np.ndarray,
        hard_cols: np.ndarray,
        ref_idx: int,
        credit_mask: Optional[np.ndarray] = None,
        credit: Optional[float] = None,
    ) -> Optional[int]:
        """Argmin-distance feasible node index; None if none is feasible.

        Reproduces NodeSelector's sequential ``d < best − 1e-12`` scan: the
        winner is the first index attaining the minimum, except in the
        sub-epsilon band where the exact sequential scan is replayed.
        """
        feasible = self.feasible_mask(demand_row, hard_cols)
        if not feasible.any():
            return None
        d = self.distances(demand_row, ref_idx)
        if credit_mask is not None:
            d = np.where(credit_mask, d * (PEER_CREDIT if credit is None else credit), d)
        d = np.where(feasible, d, np.inf)
        m = d.min()
        near = d <= m + SELECT_EPS
        if (d[near] == m).all():
            # Clean case (ties are exact): sequential scan picks the first
            # index attaining the minimum.
            return int(np.argmin(d))
        # Sub-epsilon gaps: replay the dict path's scan exactly.
        best, best_d = None, np.inf
        for i in range(d.shape[0]):
            if d[i] < best_d - SELECT_EPS:
                best, best_d = i, d[i]
        return best

    # -- Alg 4 lines 6-9: Ref Node ---------------------------------------------
    def establish_ref_node(self) -> int:
        """Rack with most (capacity-normalized) resources, then node within it."""
        cap = self.capacity.sum(axis=0)
        safe_cap = np.where(cap > 0, cap, 1.0)
        live_avail = np.where(self.alive[:, None], self.avail, 0.0)
        n_racks = len(self.rack_ids)
        rack_tot = np.zeros((n_racks, len(self.dims)), dtype=np.float64)
        np.add.at(rack_tot, self._rack_of, live_avail)
        rack_scores = np.where(cap > 0, rack_tot / safe_cap, 0.0).sum(axis=1)
        best_rack = int(np.argmax(rack_scores))  # first max in sorted-rack order
        members = self._rack_of == best_rack
        node_scores = np.where(cap > 0, self.avail / safe_cap, 0.0).sum(axis=1)
        node_scores = np.where(members & self.alive, node_scores, -np.inf)
        if not np.isfinite(node_scores).any():
            raise RuntimeError(f"no live nodes in rack {self.rack_ids[best_rack]}")
        return int(np.argmax(node_scores))  # first max in sorted-id order

    # -- evaluation ------------------------------------------------------------
    def network_cost(
        self, placement: np.ndarray, edges: np.ndarray
    ) -> float:
        """Σ netDist over task-edge endpoint node indices (vectorized
        counterpart of ``Assignment.network_cost``; exact — all hop weights
        are multiples of 0.5)."""
        if edges.size == 0:
            return 0.0
        return float(self.net[placement[edges[:, 0]], placement[edges[:, 1]]].sum())
