"""Storm topology model: components (spouts/bolts), tasks, the DAG (paper §2).

A ``Component`` is a processing operator with a parallelism hint; each of its
``parallelism`` instances is a ``Task`` — the unit the scheduler places.  A
``Topology`` is the DAG of components.  Components carry per-instance resource
demands set via the Storm-style user API (paper §5.2:
``setMemoryLoad`` / ``setCPULoad``).
"""

from __future__ import annotations

import dataclasses
import itertools
from typing import Callable, Dict, Iterable, List, Optional, Sequence, Tuple

from .resources import BANDWIDTH, CPU, MEMORY, ResourceVector, demand


@dataclasses.dataclass(frozen=True)
class Task:
    """One instance of a component (paper: 'Tasks')."""

    component_id: str
    index: int
    topology_id: str = ""

    @property
    def id(self) -> str:  # noqa: A003
        prefix = f"{self.topology_id}/" if self.topology_id else ""
        return f"{prefix}{self.component_id}[{self.index}]"

    def __repr__(self) -> str:
        return f"Task({self.id})"


class Component:
    """A spout or bolt with a parallelism hint and per-instance demand."""

    def __init__(
        self,
        cid: str,
        *,
        is_spout: bool = False,
        parallelism: int = 1,
        fn: Optional[Callable] = None,
        emit_ratio: float = 1.0,
        tuple_bytes: float = 100.0,
        cpu_cost_per_tuple: Optional[float] = None,
        max_rate_per_task: Optional[float] = None,
    ):
        if parallelism < 1:
            raise ValueError(f"parallelism must be >= 1, got {parallelism}")
        self.id = cid
        self.is_spout = is_spout
        self.parallelism = parallelism
        self.fn = fn  # optional jitted/callable payload for the real executor
        # Performance-model attributes (simulator):
        self.emit_ratio = emit_ratio  # tuples emitted per tuple consumed
        self.tuple_bytes = tuple_bytes  # bytes per emitted tuple
        # Intrinsic per-task rate ceiling (tuples/s): a source's fetch/emit
        # loop or an I/O-bound sink cannot exceed this regardless of CPU.
        self.max_rate_per_task = max_rate_per_task
        # CPU-seconds of work per tuple, in core fractions; defaults to
        # cpu_load/100 points interpreted against a nominal per-tuple budget.
        self.cpu_cost_per_tuple = cpu_cost_per_tuple
        # User-API resource demands (paper §5.2); defaults mirror Storm's
        # (Storm defaults: 128 MB on-heap, 10 CPU points).
        self.memory_load: float = 128.0
        self.cpu_load: float = 10.0
        self.bandwidth_load: float = 0.0

    # -- Storm user API (paper §5.2) -----------------------------------------
    def set_memory_load(self, amount_mb: float) -> "Component":
        self.memory_load = float(amount_mb)
        return self

    def set_cpu_load(self, points: float) -> "Component":
        self.cpu_load = float(points)
        return self

    def set_bandwidth_load(self, amount: float) -> "Component":
        self.bandwidth_load = float(amount)
        return self

    @property
    def resource_demand(self) -> ResourceVector:
        """Per-task demand vector A_τ."""
        return demand(self.memory_load, self.cpu_load, self.bandwidth_load)

    def tasks(self, topology_id: str = "") -> List[Task]:
        return [Task(self.id, i, topology_id) for i in range(self.parallelism)]

    def __repr__(self) -> str:
        kind = "Spout" if self.is_spout else "Bolt"
        return f"{kind}({self.id} x{self.parallelism})"


class Topology:
    """A DAG of components with directed stream edges (paper Fig 1)."""

    def __init__(self, tid: str):
        self.id = tid
        self.components: Dict[str, Component] = {}
        self.edges: List[Tuple[str, str]] = []  # (src_component, dst_component)
        # (src, dst) -> "shuffle" | "local_or_shuffle" (Storm stream groupings)
        self.groupings: Dict[Tuple[str, str], str] = {}
        self.max_spout_pending: int = 1000  # Storm topology.max.spout.pending
        # Acked (anchored tuples, reliable) vs unanchored at-most-once mode.
        # Acked topologies are throttled by the max-spout-pending credit loop;
        # unanchored ones push as fast as sources allow and shed load at
        # saturated tasks (typical for high-volume analytics pipelines).
        self.acked: bool = True

    # -- construction ---------------------------------------------------------
    def add_component(self, comp: Component) -> Component:
        if comp.id in self.components:
            raise ValueError(f"duplicate component id {comp.id!r}")
        self.components[comp.id] = comp
        return comp

    def add_edge(self, src: str, dst: str, grouping: str = "shuffle") -> None:
        for cid in (src, dst):
            if cid not in self.components:
                raise KeyError(f"unknown component {cid!r}")
        if grouping not in ("shuffle", "local_or_shuffle"):
            raise ValueError(f"unknown grouping {grouping!r}")
        if (src, dst) in self.edges:
            return
        if src == dst:
            raise ValueError("self-loops are not valid stream groupings")
        self.edges.append((src, dst))
        self.groupings[(src, dst)] = grouping

    # -- views ----------------------------------------------------------------
    @property
    def spouts(self) -> List[Component]:
        return [c for c in self.components.values() if c.is_spout]

    @property
    def bolts(self) -> List[Component]:
        return [c for c in self.components.values() if not c.is_spout]

    def neighbors(self, cid: str) -> List[str]:
        """Downstream then upstream neighbours (BFS treats the DAG as a graph,
        so that e.g. a diamond's join bolt pulls its other parent close)."""
        down = [d for s, d in self.edges if s == cid]
        up = [s for s, d in self.edges if d == cid]
        return down + [u for u in up if u not in down]

    def downstream(self, cid: str) -> List[str]:
        return [d for s, d in self.edges if s == cid]

    def upstream(self, cid: str) -> List[str]:
        return [s for s, d in self.edges if d == cid]

    def sinks(self) -> List[Component]:
        """Components with no outgoing edges (throughput is measured here)."""
        srcs = {s for s, _ in self.edges}
        return [c for c in self.components.values() if c.id not in srcs]

    def all_tasks(self) -> List[Task]:
        out: List[Task] = []
        for comp in self.components.values():
            out.extend(comp.tasks(self.id))
        return out

    def task_count(self) -> int:
        return sum(c.parallelism for c in self.components.values())

    def component_of(self, task: Task) -> Component:
        return self.components[task.component_id]

    def demand_of(self, task: Task) -> ResourceVector:
        return self.components[task.component_id].resource_demand

    def task_edges(self) -> List[Tuple[Task, Task]]:
        """All-to-all task pairs along each component edge (shuffle grouping)."""
        out: List[Tuple[Task, Task]] = []
        for src, dst in self.edges:
            for ts in self.components[src].tasks(self.id):
                for td in self.components[dst].tasks(self.id):
                    out.append((ts, td))
        return out

    def total_demand(self) -> ResourceVector:
        acc = demand()
        for comp in self.components.values():
            acc = acc + comp.resource_demand.scale(comp.parallelism)
        return acc

    def validate(self) -> None:
        if not self.spouts:
            raise ValueError(f"topology {self.id!r} has no spout")
        # Reachability: every bolt reachable from some spout.
        seen = set(c.id for c in self.spouts)
        frontier = sorted(seen)
        while frontier:
            nxt = []
            for cid in frontier:
                for d in self.downstream(cid):
                    if d not in seen:
                        seen.add(d)
                        nxt.append(d)
            frontier = nxt
        unreachable = set(self.components) - seen
        if unreachable:
            raise ValueError(f"components unreachable from spouts: {sorted(unreachable)}")

    def __repr__(self) -> str:
        return (
            f"Topology({self.id}: {len(self.components)} components, "
            f"{self.task_count()} tasks, {len(self.edges)} edges)"
        )
