"""Resource vectors with hard/soft constraint classes (paper §3, §4).

A demand or availability is a point in R^n (n = 3 in the paper: memory,
CPU, bandwidth).  Memory is a *hard* constraint — it must never be
violated; CPU and bandwidth are *soft* — they may be overloaded, and each
soft dimension carries a user weight used by the distance function
(Alg 4).  The representation generalizes to any number of named
dimensions so the TPU placement layer can reuse it (HBM hard; FLOP/s and
ICI/DCN bandwidth soft).
"""

from __future__ import annotations

import dataclasses
import math
from typing import Dict, Iterable, Mapping

# Canonical paper dimensions.
MEMORY = "memory_mb"
CPU = "cpu_points"
BANDWIDTH = "bandwidth"

DEFAULT_HARD = frozenset({MEMORY})
DEFAULT_WEIGHTS: Mapping[str, float] = {MEMORY: 1.0, CPU: 1.0, BANDWIDTH: 1.0}


@dataclasses.dataclass(frozen=True)
class ResourceVector:
    """An immutable point in resource space.

    ``values`` maps dimension name -> amount.  ``hard`` names the subset of
    dimensions that are hard constraints (H ⊆ A; S = A \\ H, per §4).
    """

    values: Mapping[str, float]
    hard: frozenset = DEFAULT_HARD

    def __post_init__(self):
        object.__setattr__(self, "values", dict(self.values))
        missing = self.hard - set(self.values)
        if missing:
            raise ValueError(f"hard dims {sorted(missing)} not in vector dims")

    # -- set views (paper §4: A = S ∪ H) ------------------------------------
    @property
    def dims(self) -> frozenset:
        return frozenset(self.values)

    @property
    def soft_dims(self) -> frozenset:
        return self.dims - self.hard

    def __getitem__(self, dim: str) -> float:
        return self.values.get(dim, 0.0)

    # -- arithmetic ----------------------------------------------------------
    def _merge(self, other: "ResourceVector", op) -> "ResourceVector":
        # Sorted so the result dict's key order (and any downstream
        # serialization/iteration) is independent of PYTHONHASHSEED.
        dims = sorted(set(self.values) | set(other.values))
        return ResourceVector(
            {d: op(self[d], other[d]) for d in dims}, self.hard | other.hard
        )

    def __add__(self, other: "ResourceVector") -> "ResourceVector":
        return self._merge(other, lambda a, b: a + b)

    def __sub__(self, other: "ResourceVector") -> "ResourceVector":
        return self._merge(other, lambda a, b: a - b)

    def scale(self, k: float) -> "ResourceVector":
        return ResourceVector({d: v * k for d, v in self.values.items()}, self.hard)

    # -- constraint checks ---------------------------------------------------
    def satisfies_hard(self, demand: "ResourceVector") -> bool:
        """Alg 4's feasibility filter: availability must cover every hard dim.

        The paper writes ``H_θ > H_τ``; equality-or-better is accepted here
        (a node with exactly enough memory is feasible).
        """
        return all(self[d] >= demand[d] for d in demand.hard)

    def satisfies_all(self, demand: "ResourceVector") -> bool:
        return all(self[d] >= demand[d] for d in demand.dims)

    def overload(self, demand: "ResourceVector") -> Dict[str, float]:
        """Per-dim amount by which ``demand`` exceeds availability (soft viol.)."""
        out = {}
        for d in sorted(demand.dims):
            excess = demand[d] - self[d]
            if excess > 0:
                out[d] = excess
        return out

    def total(self, dims: Iterable[str] | None = None) -> float:
        dims = sorted(self.dims) if dims is None else dims
        return sum(self[d] for d in dims)

    def is_nonnegative(self) -> bool:
        return all(v >= -1e-9 for v in self.values.values())


def weighted_distance(
    demand: ResourceVector,
    avail: ResourceVector,
    *,
    weights: Mapping[str, float] | None = None,
    network_distance: float = 0.0,
) -> float:
    """Alg 4 DISTANCE: weighted Euclidean distance in resource space.

    ``distance = sqrt(w_m (m_τ−m_θ)² + w_c (c_τ−c_θ)² + w_b netDist(ref,θ)²)``

    The bandwidth dimension of a *node* is defined by the paper as the network
    distance from the Ref Node (§4.2), passed in as ``network_distance``;
    any explicit bandwidth demand/availability dims are ignored in favour of
    it, exactly as Alg 4 line 13 does.
    """
    w = dict(DEFAULT_WEIGHTS)
    if weights:
        w.update(weights)
    acc = 0.0
    # Sorted accumulation order: float addition is not associative, so the
    # hash-seeded set order would make the low bits run-dependent (and
    # disagree with the arena path, which reduces over sorted dims).
    for d in sorted((demand.dims | avail.dims) - {BANDWIDTH}):
        acc += w.get(d, 1.0) * (demand[d] - avail[d]) ** 2
    acc += w.get(BANDWIDTH, 1.0) * network_distance**2
    return math.sqrt(acc)


def demand(memory_mb: float = 0.0, cpu: float = 0.0, bw: float = 0.0) -> ResourceVector:
    """Convenience constructor for the paper's 3-D task demand A_τ."""
    return ResourceVector({MEMORY: memory_mb, CPU: cpu, BANDWIDTH: bw})
