"""Pure-functional batched placement objective.

Given a ``BatchArena`` and a batch of candidate placements as an int array
``(B, T)`` of node indices, return per-candidate

* ``net``        — network cost: inter-node edge traffic × rack distance
  (the quadratic QM3DKP term R-Storm's greedy minimizes implicitly), plus
  — on arenas carrying ``move_base``/``move_cost`` (reconfiguration
  searches) — the per-task migration penalty for every task placed away
  from its pre-rebalance node;
* ``violation``  — total hard-capacity overshoot across nodes and hard
  columns (0.0 ⇔ the candidate respects every hard constraint);
* ``dead``       — count of tasks placed on dead nodes.

One vmapped/jit-compiled reduction on the jax backend (float64 via the
scoped x64 context), and the same math as a chunked numpy reduction when
jax is absent.  Both paths are exact for the repo's resource values (net
distances are 0.5-multiples; demands are dyadic), so outputs are golden-
equal across backends — the search subsystem's determinism rests on this.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Optional

import numpy as np

# Penalty weight folding hard-capacity overshoot into one scalar cost — the
# same constant the sequential annealer uses (re-exported for the search),
# so accept thresholds mean the same thing in both engines.
from ..engine.annealing import OVERLOAD_PENALTY
from .backend import chunk_ranges, jax_modules, resolve_backend, x64
from .batch import BatchArena


@dataclasses.dataclass(frozen=True)
class BatchEval:
    """Per-candidate objective terms, always numpy float64/int64 on exit."""

    net: np.ndarray  # (B,) float64
    violation: np.ndarray  # (B,) float64
    dead: np.ndarray  # (B,) int64
    # (B,) float64 throughput proxy (tuples/s), populated only when a
    # ThroughputModel was passed to ``evaluate_batch`` — the quantity the
    # "throughput" search objective maximizes.
    throughput: Optional[np.ndarray] = None

    @property
    def feasible(self) -> np.ndarray:
        """(B,) bool: no hard-capacity overshoot and no dead-node hits."""
        return (self.violation <= 0.0) & (self.dead == 0)

    def penalized(self) -> np.ndarray:
        """(B,) combined scalar cost (net + penalty × violation)."""
        return self.net + OVERLOAD_PENALTY * self.violation


def _evaluate_numpy(ba: BatchArena, P: np.ndarray, chunk: int) -> BatchEval:
    B = P.shape[0]
    net = np.zeros(B, dtype=np.float64)
    viol = np.zeros(B, dtype=np.float64)
    dead = np.zeros(B, dtype=np.int64)
    e0, e1 = ba.edges[:, 0], ba.edges[:, 1]
    mb, mc = ba.move_base, ba.move_cost
    for lo, hi in chunk_ranges(B, chunk):
        p = P[lo:hi]
        if e0.size:
            net[lo:hi] = ba.net[p[:, e0], p[:, e1]].sum(axis=-1)
        if mc is not None:
            # Same edge-sum + move-sum decomposition as the jax/pallas
            # paths; dyadic costs make the sum order-independent.
            net[lo:hi] = net[lo:hi] + np.where(p != mb, mc, 0.0).sum(axis=-1)
        used = ba.used(p)
        viol[lo:hi] = np.maximum(used - ba.avail, 0.0).sum(axis=(1, 2))
        dead[lo:hi] = (~ba.alive[p]).sum(axis=-1)
    return BatchEval(net=net, violation=viol, dead=dead)


@functools.lru_cache(maxsize=None)
def _jax_eval_fn(n_nodes: int):
    """jit-compiled vmapped evaluator (cached per node count; array shapes
    re-specialize via jit's own shape cache)."""
    jax, jnp = jax_modules()

    @jax.jit
    def evaluate(net, avail, hard_demand, alive, edges, move_base, move_cost, P):
        def one(p):
            # An empty edge set gathers to an empty row; its sum is 0.0.
            # The move term adds +0.0 on zero-cost arenas (bitwise inert).
            netc = net[p[edges[:, 0]], p[edges[:, 1]]].sum() + jnp.where(
                p != move_base, move_cost, 0.0
            ).sum()
            used = jax.ops.segment_sum(hard_demand, p, num_segments=n_nodes)
            violc = jnp.maximum(used - avail, 0.0).sum()
            deadc = (~alive[p]).sum()
            return netc, violc, deadc

        return jax.vmap(one)(P)

    return evaluate


def _evaluate_jax(ba: BatchArena, P: np.ndarray, chunk: int) -> BatchEval:
    B = P.shape[0]
    net = np.zeros(B, dtype=np.float64)
    viol = np.zeros(B, dtype=np.float64)
    dead = np.zeros(B, dtype=np.int64)
    fn = _jax_eval_fn(ba.n_nodes)
    mb, mc = ba.move_arrays()
    with x64():
        # Chunked like the numpy path: the (chunk, E) gather is the working
        # set, so a huge batch never materializes one (B, E) intermediate.
        # At most two compiled shapes per batch size (full chunk + tail).
        for lo, hi in chunk_ranges(B, chunk):
            n, v, d = fn(
                ba.net, ba.avail, ba.hard_demand, ba.alive, ba.edges,
                mb, mc, P[lo:hi],
            )
            net[lo:hi] = np.asarray(n, dtype=np.float64)
            viol[lo:hi] = np.asarray(v, dtype=np.float64)
            dead[lo:hi] = np.asarray(d, dtype=np.int64)
    return BatchEval(net=net, violation=viol, dead=dead)


def _evaluate_pallas(
    ba: BatchArena, P: np.ndarray, chunk: int, throughput_model
) -> BatchEval:
    """One fused kernel launch per chunk: netcost + capacity + dead (+
    throughput when a model is given) in a single pass over the block —
    instead of the two separate reductions the jax/numpy paths run."""
    from .kernels import fused_score  # jax-only import, deferred

    B = P.shape[0]
    net = np.zeros(B, dtype=np.float64)
    viol = np.zeros(B, dtype=np.float64)
    dead = np.zeros(B, dtype=np.int64)
    tp = np.zeros(B, dtype=np.float64) if throughput_model is not None else None
    for lo, hi in chunk_ranges(B, chunk):
        n, v, d, t = fused_score(ba, P[lo:hi], tm=throughput_model)
        net[lo:hi] = n
        viol[lo:hi] = v
        dead[lo:hi] = d
        if tp is not None:
            tp[lo:hi] = t
    return BatchEval(net=net, violation=viol, dead=dead, throughput=tp)


def evaluate_batch(
    ba: BatchArena,
    placements: np.ndarray,
    backend: str = "auto",
    chunk: int = 256,
    throughput_model=None,
) -> BatchEval:
    """Score a batch of candidate placements ``(B, T)`` (or one ``(T,)`` row).

    ``chunk`` bounds the per-call working set (the (chunk, E) edge gather)
    on *both* backends; results are independent of the chunking.  Passing a
    ``ThroughputModel`` (``search.throughput.compile_throughput``) also
    populates ``BatchEval.throughput`` with the per-candidate proxy.
    """
    P = np.ascontiguousarray(np.atleast_2d(placements))
    if P.shape[1] != ba.n_tasks:
        raise ValueError(
            f"placement batch has {P.shape[1]} tasks, arena has {ba.n_tasks}"
        )
    if chunk < 1:
        raise ValueError(f"chunk must be >= 1, got {chunk}")
    resolved = resolve_backend(backend)
    if resolved == "pallas":
        # The fused kernel computes every term (throughput included) in one
        # pass per chunk — no second throughput_batch sweep needed.
        return _evaluate_pallas(ba, P, chunk, throughput_model)
    if resolved == "jax":
        out = _evaluate_jax(ba, P, chunk)
    else:
        out = _evaluate_numpy(ba, P, chunk)
    if throughput_model is not None:
        from .throughput import throughput_batch

        out = dataclasses.replace(
            out,
            throughput=throughput_batch(
                ba, throughput_model, P, backend=backend, chunk=chunk
            ),
        )
    return out
