"""Pure-functional batched placement objective.

Given a ``BatchArena`` and a batch of candidate placements as an int array
``(B, T)`` of node indices, return per-candidate

* ``net``        — network cost: inter-node edge traffic × rack distance
  (the quadratic QM3DKP term R-Storm's greedy minimizes implicitly);
* ``violation``  — total hard-capacity overshoot across nodes and hard
  columns (0.0 ⇔ the candidate respects every hard constraint);
* ``dead``       — count of tasks placed on dead nodes.

One vmapped/jit-compiled reduction on the jax backend (float64 via the
scoped x64 context), and the same math as a chunked numpy reduction when
jax is absent.  Both paths are exact for the repo's resource values (net
distances are 0.5-multiples; demands are dyadic), so outputs are golden-
equal across backends — the search subsystem's determinism rests on this.
"""

from __future__ import annotations

import dataclasses
import functools

import numpy as np

# Penalty weight folding hard-capacity overshoot into one scalar cost — the
# same constant the sequential annealer uses (re-exported for the search),
# so accept thresholds mean the same thing in both engines.
from ..engine.annealing import OVERLOAD_PENALTY
from .backend import jax_modules, resolve_backend, x64
from .batch import BatchArena


@dataclasses.dataclass(frozen=True)
class BatchEval:
    """Per-candidate objective terms, always numpy float64/int64 on exit."""

    net: np.ndarray  # (B,) float64
    violation: np.ndarray  # (B,) float64
    dead: np.ndarray  # (B,) int64

    @property
    def feasible(self) -> np.ndarray:
        """(B,) bool: no hard-capacity overshoot and no dead-node hits."""
        return (self.violation <= 0.0) & (self.dead == 0)

    def penalized(self) -> np.ndarray:
        """(B,) combined scalar cost (net + penalty × violation)."""
        return self.net + OVERLOAD_PENALTY * self.violation


def _evaluate_numpy(ba: BatchArena, P: np.ndarray, chunk: int) -> BatchEval:
    B = P.shape[0]
    net = np.zeros(B, dtype=np.float64)
    viol = np.zeros(B, dtype=np.float64)
    dead = np.zeros(B, dtype=np.int64)
    e0, e1 = ba.edges[:, 0], ba.edges[:, 1]
    for lo in range(0, B, chunk):
        p = P[lo : lo + chunk]
        if e0.size:
            net[lo : lo + chunk] = ba.net[p[:, e0], p[:, e1]].sum(axis=-1)
        used = ba.used(p)
        viol[lo : lo + chunk] = np.maximum(used - ba.avail, 0.0).sum(axis=(1, 2))
        dead[lo : lo + chunk] = (~ba.alive[p]).sum(axis=-1)
    return BatchEval(net=net, violation=viol, dead=dead)


@functools.lru_cache(maxsize=None)
def _jax_eval_fn(n_nodes: int):
    """jit-compiled vmapped evaluator (cached per node count; array shapes
    re-specialize via jit's own shape cache)."""
    jax, jnp = jax_modules()

    @jax.jit
    def evaluate(net, avail, hard_demand, alive, edges, P):
        def one(p):
            # An empty edge set gathers to an empty row; its sum is 0.0.
            netc = net[p[edges[:, 0]], p[edges[:, 1]]].sum()
            used = jax.ops.segment_sum(hard_demand, p, num_segments=n_nodes)
            violc = jnp.maximum(used - avail, 0.0).sum()
            deadc = (~alive[p]).sum()
            return netc, violc, deadc

        return jax.vmap(one)(P)

    return evaluate


def _evaluate_jax(ba: BatchArena, P: np.ndarray) -> BatchEval:
    with x64():
        net, viol, dead = _jax_eval_fn(ba.n_nodes)(
            ba.net, ba.avail, ba.hard_demand, ba.alive, ba.edges, P
        )
    return BatchEval(
        net=np.asarray(net, dtype=np.float64),
        violation=np.asarray(viol, dtype=np.float64),
        dead=np.asarray(dead, dtype=np.int64),
    )


def evaluate_batch(
    ba: BatchArena,
    placements: np.ndarray,
    backend: str = "auto",
    chunk: int = 256,
) -> BatchEval:
    """Score a batch of candidate placements ``(B, T)`` (or one ``(T,)`` row).

    ``chunk`` bounds the numpy path's working set (the (chunk, E) gather);
    the jax path evaluates the whole batch in one vmapped call.
    """
    P = np.ascontiguousarray(np.atleast_2d(placements))
    if P.shape[1] != ba.n_tasks:
        raise ValueError(
            f"placement batch has {P.shape[1]} tasks, arena has {ba.n_tasks}"
        )
    if resolve_backend(backend) == "jax":
        return _evaluate_jax(ba, P)
    return _evaluate_numpy(ba, P, chunk)
