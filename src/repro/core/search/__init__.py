# Batched placement-search subsystem: lifts the PlacementArena's dense
# arrays into a BatchArena and evaluates thousands of candidate placements
# in parallel (jax-vmapped when available, numpy fallback otherwise; the
# "pallas" backend scores every objective term in one fused kernel — see
# .kernels — with all three backends golden-equal).  Two objectives:
# network cost (QM3DKP) and the simulator-derived throughput proxy (what
# the paper's §6 actually measures).
from .backend import HAS_JAX, resolve_backend
from .batch import BatchArena
from .objective import evaluate_batch
from .throughput import ThroughputModel, compile_throughput, throughput_batch
from .anneal import BatchAnnealer, OBJECTIVES
from .portfolio import SearchScheduler

__all__ = [
    "BatchAnnealer",
    "BatchArena",
    "HAS_JAX",
    "OBJECTIVES",
    "SearchScheduler",
    "ThroughputModel",
    "compile_throughput",
    "evaluate_batch",
    "resolve_backend",
    "throughput_batch",
]
