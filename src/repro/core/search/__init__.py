# Batched placement-search subsystem: lifts the PlacementArena's dense
# arrays into a BatchArena and evaluates thousands of candidate placements
# in parallel (jax-vmapped when available, numpy fallback otherwise).
from .backend import HAS_JAX, resolve_backend
from .batch import BatchArena
from .objective import evaluate_batch
from .anneal import BatchAnnealer
from .portfolio import SearchScheduler

__all__ = [
    "BatchAnnealer",
    "BatchArena",
    "HAS_JAX",
    "SearchScheduler",
    "evaluate_batch",
    "resolve_backend",
]
