"""``rstorm-search`` — the batched placement-search scheduler.

Wraps the whole subsystem as a registered scheduler: seed candidate chains
(greedy R-Storm, greedy under randomized task orders, random placements,
or every registered scheduler's output — the portfolio), anneal all chains
in one batched run, then return the best feasible candidate under the
requested ``objective``:

* ``netcost`` (default) — lowest network cost, guaranteed never above the
  greedy seed's;
* ``throughput`` — highest throughput proxy (:mod:`.throughput` — the
  binding bound the paper's §6 measurements are about), netcost as the
  tie-break, and the never-worse guarantee measured where it matters: the
  final candidate assignment (stranded-task recovery included) is
  simulated (``stream.simulator``) against the greedy seed; greedy wins
  any regression in *simulated sink throughput*, while a candidate that is
  strictly better under the proxy keeps a simulated tie.

Unplaced tasks: the search permutes the tasks greedy could place (swaps
preserve the per-node multiset, so hard feasibility of the seed is
preserved too); after the winner is chosen, greedy's ``unassigned`` leftovers
get one more placement pass against the winner's residual budget — an
annealed candidate can consolidate demand and free the capacity greedy
fragmented, so tasks greedy stranded may now fit.
"""

from __future__ import annotations

from typing import Dict, List, Mapping, Optional

import numpy as np

from ...obs import get_hub
from ...obs import clock as obs_clock
from ..assignment import Assignment
from ..cluster import Cluster
from ..engine import ArenaSelector, PlacementArena
from ..registry import KwargField, REGISTRY, register_scheduler
from ..schedulers import RStormScheduler, Scheduler
from ..topology import Topology
from ..traversal import task_selection
from .anneal import BatchAnnealer, OBJECTIVES, swap_proposals
from .backend import BACKENDS, resolve_backend
from .batch import BatchArena
from .objective import evaluate_batch
from .throughput import compile_throughput

INIT_MODES = ("greedy", "random", "all-registered")

#: Time-budget tiers for ``budget_s``: ``(ceiling_s, n_chains, step_scale)``.
#: A budget resolves to the first tier whose ceiling covers it and ``steps``
#: is ``step_scale × n_tasks`` clamped to [BUDGET_MIN_STEPS, BUDGET_MAX_STEPS].
#: The table is a calibrated static cost model — the decision path never
#: reads a clock, so a given (budget tier, topology size) always produces
#: the *same* search on any machine: the budget is honored statistically,
#: the determinism exactly (the contract a control loop needs).
BUDGET_TIERS = (
    (0.1, 8, 4),
    (0.5, 16, 12),
    (2.0, 32, 40),
    (10.0, 64, 120),
)
#: Plan for budgets above the last tier ceiling.
BUDGET_FLOOR_PLAN = (128, 400)
BUDGET_MIN_STEPS = 64
BUDGET_MAX_STEPS = 20_000


def budget_plan(budget_s: float, n_tasks: int) -> "tuple[int, int]":
    """Deterministic ``(n_chains, steps)`` for a latency budget.

    Pure in (budget tier, topology size): no wall-clock read anywhere in
    the decision path (hot-loop lint contract), so budgeted searches replay
    bit-identically.
    """
    if budget_s <= 0:
        raise ValueError(f"budget_s must be > 0, got {budget_s!r}")
    for ceiling, chains, scale in BUDGET_TIERS:
        if budget_s <= ceiling:
            break
    else:
        chains, scale = BUDGET_FLOOR_PLAN
    steps = min(BUDGET_MAX_STEPS, max(BUDGET_MIN_STEPS, scale * max(n_tasks, 1)))
    return chains, steps

#: Randomized-task-order greedy seeds are sequential (one Alg-4 descent
#: each), so only this many chains get one; the rest start from seeded
#: random perturbations of the plain greedy placement.
MAX_ORDERED_SEEDS = 8

#: Swap-perturbation depth for the non-ordered chains.
PERTURB_SWAPS = 16


def _greedy_with_order(
    scheduler: RStormScheduler, arena: PlacementArena, topology: Topology, order
) -> Optional[Dict[str, str]]:
    """One Alg-4 greedy descent over ``order`` (the scheduler's own arena
    placement loop, just reordered); task-id → node-id.

    Runs on the arena's current ledger and rolls it back before returning.
    Returns None when a task greedy could otherwise place fails under this
    order (the seed would cover a different task set than the batch).
    """
    snap = arena.snapshot()
    a = Assignment(topology_id=topology.id)
    scheduler._place_on_arena(arena, topology, a, order=order)
    arena.rollback(snap)
    return dict(a.placements) if not a.unassigned else None


def _perturb(base: np.ndarray, rows: np.ndarray, n_swaps: int, seed: int) -> None:
    """Apply ``n_swaps`` seeded random transpositions to each row of
    ``base[rows]`` in place (cheap chain diversification)."""
    if rows.size == 0 or base.shape[1] < 2:
        return
    ii, jj = swap_proposals(base.shape[1], n_swaps, rows.size, seed)
    for s in range(n_swaps):
        i, j = ii[s], jj[s]
        tmp = base[rows, i].copy()
        base[rows, i] = base[rows, j]
        base[rows, j] = tmp


@register_scheduler(
    "rstorm-search",
    kwargs_schema={
        "n_chains": KwargField(
            types=(int,), default=32, minimum=1, doc="parallel search chains (B)"
        ),
        "steps": KwargField(
            types=(int,),
            default=2000,
            minimum=1,
            doc="swap proposals per chain (depth moves the needle more than "
            "breadth on large topologies; breadth buys diversity)",
        ),
        "seed": KwargField(types=(int,), default=0, minimum=0, doc="PRNG seed"),
        "init": KwargField(
            types=(str,),
            default="greedy",
            choices=INIT_MODES,
            doc="chain seeding: greedy R-Storm (+ randomized task orders), "
            "uniform-random placements, or every registered scheduler",
        ),
        "weights": KwargField(
            types=(dict, type(None)),
            default=None,
            doc="soft-dimension distance weights for the greedy seed (Alg 4)",
        ),
        "objective": KwargField(
            types=(str,),
            default="netcost",
            choices=OBJECTIVES,
            doc="what the search optimizes: network cost (QM3DKP quadratic "
            "term), or the simulator-derived throughput proxy with netcost "
            "as tie-break and a simulated never-worse-than-greedy guarantee",
        ),
        "backend": KwargField(
            types=(str,),
            default="auto",
            choices=BACKENDS,
            doc="batch evaluator backend: auto picks jax when importable, "
            "numpy otherwise; 'pallas' scores candidates with the fused "
            "kernel (outputs are golden-equal across all three)",
        ),
        "multi_swap": KwargField(
            types=(int,),
            default=8,
            minimum=1,
            doc="swap proposals fused per lax.scan element on the jax/pallas "
            "annealing path (k× fewer scan steps, bit-identical chains; "
            "no-op on numpy)",
        ),
        "budget_s": KwargField(
            types=(int, float, type(None)),
            default=None,
            doc="latency budget (seconds): overrides n_chains/steps with the "
            "deterministic tier plan (budget_plan) sized from the topology — "
            "no wall-clock in the decision path, so a budgeted search "
            "replays bit-identically",
        ),
    },
)
class SearchScheduler(Scheduler):
    """Multi-start batched annealing over the greedy seed's task set."""

    def __init__(
        self,
        n_chains: int = 32,
        steps: int = 2000,
        seed: int = 0,
        init: str = "greedy",
        weights: Optional[Mapping[str, float]] = None,
        objective: str = "netcost",
        backend: str = "auto",
        multi_swap: int = 8,
        budget_s: Optional[float] = None,
    ):
        if init not in INIT_MODES:
            raise ValueError(f"unknown init {init!r}; choose from {INIT_MODES}")
        if objective not in OBJECTIVES:
            raise ValueError(
                f"unknown objective {objective!r}; choose from {OBJECTIVES}"
            )
        if multi_swap < 1:
            raise ValueError(f"multi_swap must be >= 1, got {multi_swap}")
        if budget_s is not None and budget_s <= 0:
            raise ValueError(f"budget_s must be > 0, got {budget_s!r}")
        self.n_chains = n_chains
        self.steps = steps
        self.seed = seed
        self.init = init
        self.weights = weights
        self.objective = objective
        self.backend = resolve_backend(backend)
        self.multi_swap = multi_swap
        self.budget_s = budget_s

    def plan(self, n_tasks: int) -> "tuple[int, int]":
        """``(n_chains, steps)`` for this run: the explicit kwargs, or —
        under a ``budget_s`` latency contract — the deterministic tier
        plan sized from the topology."""
        if self.budget_s is None:
            return self.n_chains, self.steps
        return budget_plan(self.budget_s, n_tasks)

    def schedule(
        self, topology: Topology, cluster: Cluster, *, commit: bool = True
    ) -> Assignment:
        # schedule_time_s is reporting metadata sampled once per schedule()
        # call via the observability plane's justified wall-clock shim;
        # placements and objective values never depend on it.
        t0 = obs_clock.perf_counter()
        hub = get_hub()
        span = hub.span(
            "search.schedule", topology=topology.id, objective=self.objective
        )
        with span:
            out = self._schedule_phases(topology, cluster, span)
        return self._finish(topology, cluster, out, commit, t0)

    def _schedule_phases(self, topology: Topology, cluster: Cluster, span) -> Assignment:
        hub = get_hub()
        topology.validate()
        # Greedy R-Storm seed on a fresh arena; avail0 (the pre-placement
        # ledger) is the capacity budget candidates are scored against.
        with hub.span("search.seed"):
            arena = PlacementArena(cluster, topology, self.weights)
            avail0 = arena.snapshot()
            seed_assignment = Assignment(topology_id=topology.id)
            greedy_scheduler = RStormScheduler(self.weights)
            greedy_scheduler._place_on_arena(arena, topology, seed_assignment)
            placements = dict(seed_assignment.placements)
        out = Assignment(
            topology_id=topology.id,
            placements=placements,
            unassigned=list(seed_assignment.unassigned),
        )
        recovered = False
        if len(placements) >= 2:
            with hub.span("search.compile") as sp:
                ba = BatchArena.from_arena(
                    arena, topology, placements, avail0=avail0
                )
                greedy_row = ba.encode(placements)
                tm = (
                    compile_throughput(ba, topology, cluster)
                    if self.objective == "throughput"
                    else None
                )
                n_chains, steps = self.plan(ba.n_tasks)
                sp.set(n_tasks=ba.n_tasks, n_nodes=ba.n_nodes)
                # Ordered re-seeds descend from the pre-placement budget,
                # not from the ledger the greedy seed just consumed.
                arena.rollback(avail0)
                P0 = self._build_inits(
                    ba, arena, topology, cluster, greedy_row, greedy_scheduler,
                    n_chains,
                )
            with hub.span("search.anneal") as sp:
                sp.set(
                    n_chains=int(P0.shape[0]),
                    steps=steps,
                    proposals=int(P0.shape[0]) * steps,
                    backend=self.backend,
                    multi_swap=self.multi_swap,
                )
                P = BatchAnnealer(ba, backend=self.backend).run(
                    P0, steps, self.seed, objective=self.objective, tm=tm,
                    multi_swap=self.multi_swap,
                )
            with hub.span("search.evaluate"):
                result = evaluate_batch(
                    ba, P, backend=self.backend, throughput_model=tm
                )
                greedy_eval = evaluate_batch(
                    ba, greedy_row, backend=self.backend, throughput_model=tm
                )
            if self.objective == "throughput":
                candidate = self._pick_throughput_candidate(
                    ba, P, result, greedy_eval
                )
                if candidate is not None:
                    # Recovery first, guarantee second: the stranded-task
                    # pass mutates the assignment, so the simulated
                    # never-worse check must see the *final* candidate.
                    trial = Assignment(
                        topology_id=topology.id,
                        placements=candidate,
                        unassigned=list(out.unassigned),
                    )
                    if trial.unassigned:
                        self._place_unassigned(arena, avail0, topology, trial)
                    if self._simulated_no_worse(topology, cluster, trial, out):
                        out = trial
                        recovered = True
            else:
                cand = np.where(result.feasible, result.net, np.inf)
                best = int(np.argmin(cand))  # ties → lowest chain index
                if np.isfinite(cand[best]) and cand[best] < greedy_eval.net[0]:
                    out.placements = ba.decode(P[best])
        if out.unassigned and not recovered:
            # The chosen candidate may have consolidated demand greedy
            # fragmented — re-attempt the stranded tasks against its
            # residual budget.
            self._place_unassigned(arena, avail0, topology, out)
        span.set(placed=len(out.placements), unassigned=len(out.unassigned))
        return out

    def _pick_throughput_candidate(
        self, ba, P, result, greedy_eval
    ) -> Optional[Dict[str, str]]:
        """Best feasible chain by (proxy throughput ↓, netcost ↑, chain
        index ↑); None unless strictly better than the greedy seed under
        the proxy (netcost as the tie-break)."""
        tp = np.where(result.feasible, result.throughput, -np.inf)
        best_tp = tp.max()
        if not np.isfinite(best_tp):
            return None
        tie = tp == best_tp
        net = np.where(tie, result.net, np.inf)
        best = int(np.argmin(net))  # ties → lowest chain index
        g_tp, g_net = float(greedy_eval.throughput[0]), float(greedy_eval.net[0])
        if (tp[best], -net[best]) <= (g_tp, -g_net):
            return None  # greedy seed already at least as good per proxy
        return ba.decode(P[best])

    def _simulated_no_worse(self, topology, cluster, trial, base) -> bool:
        """The guarantee measured in what §6 measures: the trial's final
        assignment must not simulate below the greedy seed's sink
        throughput (a proxy-strictly-better trial keeps a simulated tie)."""
        from ...stream.simulator import Simulator  # lazy: stream imports core

        sim = Simulator(cluster)
        sim_trial = sim.run(
            topology, Assignment(topology.id, placements=dict(trial.placements))
        ).sink_throughput
        sim_base = sim.run(
            topology, Assignment(topology.id, placements=dict(base.placements))
        ).sink_throughput
        return sim_trial >= sim_base

    def _place_unassigned(
        self,
        arena: PlacementArena,
        avail0: np.ndarray,
        topology: Topology,
        out: Assignment,
    ) -> None:
        """One more Alg-4 pass for the tasks greedy stranded, against the
        chosen candidate's residual budget (annealed candidates can free
        capacity the greedy descent fragmented)."""
        arena.rollback(avail0)
        component_of = {t.id: t.component_id for t in topology.all_tasks()}
        rows: Dict[str, tuple] = {}
        for tid, nid in out.placements.items():
            cid = component_of[tid]
            if cid not in rows:
                rows[cid] = arena.compile_demand(
                    topology.components[cid].resource_demand
                )
            arena.assign(arena.index[nid], rows[cid][0])
        selector = ArenaSelector(arena)
        missing = set(out.unassigned)
        still: List[str] = []
        for task in task_selection(topology):
            if task.id not in missing:
                continue
            cid = task.component_id
            if cid not in rows:
                rows[cid] = arena.compile_demand(
                    topology.components[cid].resource_demand
                )
            row, hard = rows[cid]
            i = selector.select(row, hard)
            if i is None:
                still.append(task.id)
                continue
            arena.assign(i, row)
            out.placements[task.id] = arena.node_ids[i]
        out.unassigned = still

    # -- chain seeding ---------------------------------------------------------
    def _build_inits(
        self,
        ba: BatchArena,
        arena: PlacementArena,
        topology: Topology,
        cluster: Cluster,
        greedy_row: np.ndarray,
        greedy_scheduler: RStormScheduler,
        n_chains: Optional[int] = None,
    ) -> np.ndarray:
        B = self.n_chains if n_chains is None else n_chains
        T = ba.n_tasks
        rng = np.random.Generator(np.random.Philox([self.seed, 0xC0FFEE]))
        P0 = np.tile(greedy_row, (B, 1))
        if self.init == "random":
            alive_idx = np.flatnonzero(ba.alive)
            if alive_idx.size:
                P0[1:] = alive_idx[rng.integers(0, alive_idx.size, size=(B - 1, T))]
            # Chain 0 stays the greedy seed so the never-worse guarantee is
            # decided within the batch, not just by the final comparison.
            return P0
        seeds: List[np.ndarray] = [greedy_row]
        if self.init == "greedy":
            order = task_selection(topology)
            for k in range(min(B - 1, MAX_ORDERED_SEEDS)):
                shuffled = list(order)
                rng.shuffle(shuffled)
                sol = _greedy_with_order(greedy_scheduler, arena, topology, shuffled)
                if sol is not None and set(sol) == set(ba.tids):
                    seeds.append(ba.encode(sol))
        else:  # all-registered portfolio
            for name in sorted(REGISTRY):
                if name == "rstorm-search":
                    continue  # never recurse into ourselves
                try:
                    a = REGISTRY[name].cls().schedule(topology, cluster, commit=False)
                except Exception:
                    continue
                if set(a.placements) == set(ba.tids):
                    seeds.append(ba.encode(a.placements))
        for c in range(B):
            P0[c] = seeds[c % len(seeds)]
        # Chains beyond the distinct seeds explore from perturbed copies.
        _perturb(
            P0,
            np.arange(len(seeds), B),
            PERTURB_SWAPS,
            self.seed ^ 0x5EED,
        )
        return P0
