"""BatchArena — the PlacementArena compiled for batched candidate search.

Where the arena answers "which node next for *this* task" (one greedy
descent), the BatchArena holds everything needed to score *complete*
placements wholesale: a candidate batch is an int array ``(B, T)`` of node
indices, and feasibility + network cost for all B candidates is one
vectorized reduction (:mod:`repro.core.search.objective`).

Compiled once per search from an arena:

* ``net``          — the arena's N×N rack net-distance matrix (shared, not
  copied);
* ``avail``        — N×Dh availability on the hard columns *before* this
  topology's tasks are placed (the capacity budget a candidate must fit);
* ``hard_demand``  — T×Dh per-task demand on those columns (the
  hard-constraint column mask applied at compile time);
* ``alive``        — N bool mask (dead-node hits make a candidate
  infeasible);
* ``edges``        — E×2 task-index pairs over the placed tasks (inter-node
  edge traffic × distance is the objective's cost term);
* ``adj``/``adj_mask`` — T×max_deg padded adjacency for O(degree)
  batched swap deltas (same delta implementation as ``SwapAnnealer``).

Task order is ``sorted(placements)`` — the same canonical order the
sequential annealer uses, so seeds and results translate losslessly between
the two engines.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional

import numpy as np

from ..engine.arena import PlacementArena
from ..topology import Topology


@dataclasses.dataclass
class BatchArena:
    """Dense batch-evaluation view over one (topology, cluster) pair."""

    node_ids: List[str]
    tids: List[str]
    hard_dims: List[str]
    net: np.ndarray  # (N, N) float64
    avail: np.ndarray  # (N, Dh) float64, pre-placement hard-column budget
    hard_demand: np.ndarray  # (T, Dh) float64
    alive: np.ndarray  # (N,) bool
    edges: np.ndarray  # (E, 2) intp task-index pairs
    adj: np.ndarray  # (T, max_deg) intp, -1 padded
    adj_mask: np.ndarray  # (T, max_deg) bool
    # Rack topology (throughput-proxy link flows): rack index per node.
    rack_of: Optional[np.ndarray] = None  # (N,) intp
    n_racks: int = 0
    # Migration soft-cost (reconfiguration searches): a per-task penalty
    # added to ``net`` for every task placed away from its pre-rebalance
    # node, so the search trades netcost/throughput gains against live-
    # cluster disruption.  None ⇔ no move term (from-scratch scheduling):
    # the numpy evaluator skips the term and the jax/pallas paths receive
    # zero arrays, whose +0.0 contribution is bitwise inert on the
    # non-negative net sums — scores stay golden-equal to pre-move arenas.
    # Costs must be dyadic-grid multiples (the engine quantizes them) so
    # the summed term is exact in any accumulation order.
    move_base: Optional[np.ndarray] = None  # (T,) intp pre-move node index
    move_cost: Optional[np.ndarray] = None  # (T,) float64 per-task penalty

    @property
    def n_nodes(self) -> int:
        return len(self.node_ids)

    @property
    def n_tasks(self) -> int:
        return len(self.tids)

    @classmethod
    def from_arena(
        cls,
        arena: PlacementArena,
        topology: Topology,
        placements: Dict[str, str],
        avail0: Optional[np.ndarray] = None,
    ) -> "BatchArena":
        """Compile the batch view for the tasks in ``placements``.

        ``avail0`` is the arena availability snapshot taken *before* those
        tasks were assigned (``arena.snapshot()``); defaults to the arena's
        current ledger for callers compiling against an untouched arena.
        """
        tids = sorted(placements)
        tindex = {tid: i for i, tid in enumerate(tids)}
        avail_all = arena.avail if avail0 is None else avail0

        # Hard columns: dims any placed task declares hard.  Soft columns
        # never constrain feasibility (they may legally go negative), so
        # they are dropped at compile time.
        demands = {t.id: topology.demand_of(t) for t in topology.all_tasks()}
        hard_dims = sorted(
            {dim for tid in tids for dim in demands[tid].hard}
        )
        hard_cols = np.array([arena.dim_col[d] for d in hard_dims], dtype=np.intp)
        hard_demand = np.zeros((len(tids), len(hard_dims)), dtype=np.float64)
        for tid in tids:
            rv = demands[tid]
            for j, dim in enumerate(hard_dims):
                if dim in rv.hard:
                    hard_demand[tindex[tid], j] = rv[dim]
        avail = (
            avail_all[:, hard_cols].astype(np.float64, copy=True)
            if hard_cols.size
            else np.zeros((len(arena.node_ids), 0), dtype=np.float64)
        )

        # Directed task edges over placed tasks + padded adjacency.
        adj_lists: List[List[int]] = [[] for _ in tids]
        edge_pairs: List[List[int]] = []
        for src, dst in topology.task_edges():
            a, b = tindex.get(src.id), tindex.get(dst.id)
            if a is None or b is None:
                continue
            edge_pairs.append([a, b])
            adj_lists[a].append(b)
            adj_lists[b].append(a)
        edges = (
            np.array(edge_pairs, dtype=np.intp)
            if edge_pairs
            else np.zeros((0, 2), dtype=np.intp)
        )
        max_deg = max((len(x) for x in adj_lists), default=0)
        adj = np.full((len(tids), max(max_deg, 1)), -1, dtype=np.intp)
        for i, nbrs in enumerate(adj_lists):
            adj[i, : len(nbrs)] = nbrs
        adj_mask = adj >= 0

        return cls(
            node_ids=list(arena.node_ids),
            tids=tids,
            hard_dims=hard_dims,
            net=arena.net,
            avail=avail,
            hard_demand=hard_demand,
            alive=arena.alive.copy(),
            edges=edges,
            adj=adj,
            adj_mask=adj_mask,
            rack_of=arena.rack_of.copy(),
            n_racks=len(arena.rack_ids),
        )

    def move_arrays(self) -> "tuple[np.ndarray, np.ndarray]":
        """``(move_base, move_cost)`` with zero-cost defaults — the dense
        form the jax/pallas paths always consume (cost 0.0 ⇔ the move term
        adds +0.0, which is bitwise inert on the non-negative net sums)."""
        if self.move_cost is None:
            return (
                np.zeros(self.n_tasks, dtype=np.intp),
                np.zeros(self.n_tasks, dtype=np.float64),
            )
        return self.move_base, self.move_cost

    # -- placement codecs ------------------------------------------------------
    def encode(self, placements: Dict[str, str]) -> np.ndarray:
        """task→node-id dict (over exactly ``self.tids``) → (T,) index row."""
        index = {nid: i for i, nid in enumerate(self.node_ids)}
        return np.array([index[placements[tid]] for tid in self.tids], dtype=np.intp)

    def decode(self, row: np.ndarray) -> Dict[str, str]:
        """(T,) node-index row → task→node-id dict."""
        return {tid: self.node_ids[int(row[i])] for i, tid in enumerate(self.tids)}

    def used(self, placements: np.ndarray) -> np.ndarray:
        """Per-node hard-column usage for a batch ``(B, T)`` → ``(B, N, Dh)``."""
        p = np.atleast_2d(placements)
        out = np.zeros((p.shape[0], self.n_nodes, len(self.hard_dims)))
        for b in range(p.shape[0]):
            np.add.at(out[b], p[b], self.hard_demand)
        return out
