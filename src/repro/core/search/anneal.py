"""Batched multi-start annealing over candidate placements.

B independent chains run pairwise-swap local search *simultaneously*: each
step proposes one swap per chain, scores it with the same O(degree)
incremental delta the sequential ``SwapAnnealer`` uses
(:func:`repro.core.engine.arena.swap_network_delta`), and accepts it under a
threshold-accepting schedule (Dueck & Scheuer's deterministic cousin of
simulated annealing): a swap is accepted iff

    Δ(net + penalty × hard-violation)  ≤  threshold(step)

with the threshold annealing linearly to 0, where the loop becomes pure
hill-climbing.  Threshold accepting was chosen over Metropolis acceptance
deliberately — no ``exp``/``log`` in the hot loop means the accept decision
is a comparison of *exact* float64 quantities, so the jax scan and the
numpy fallback produce bit-identical chains.

All randomness (swap proposals) is pregenerated with numpy's Philox
generator from one seed and fed to both backends as data, so a fixed seed
gives a deterministic result regardless of backend or chain count ordering.

Because violations are penalized at ``OVERLOAD_PENALTY`` (≫ any threshold),
chains seeded with feasible placements stay feasible at every step, while
infeasible seeds (random init) are driven toward feasibility first.
"""

from __future__ import annotations

import functools
from typing import Tuple

import numpy as np

from ..engine.arena import swap_network_delta, swap_overload_delta
from .backend import jax_modules, resolve_backend, x64
from .batch import BatchArena
from .objective import OVERLOAD_PENALTY

#: Initial accept threshold, in net-distance hops: early steps may accept
#: swaps that worsen the placement by up to this much, escaping the greedy
#: seed's local minimum; anneals linearly to 0.
DEFAULT_T0 = 2.0


def swap_proposals(
    n_tasks: int, steps: int, n_chains: int, seed: int
) -> Tuple[np.ndarray, np.ndarray]:
    """Pregenerated (i, j) task-index proposals, shape (steps, B) each.

    ``j = (i + offset) % T`` with offset ≥ 1 guarantees i ≠ j.  Philox is
    counter-based, so the stream is stable across numpy versions/platforms.
    """
    rng = np.random.Generator(np.random.Philox(seed))
    ii = rng.integers(0, n_tasks, size=(steps, n_chains), dtype=np.int64)
    off = rng.integers(1, max(n_tasks, 2), size=(steps, n_chains), dtype=np.int64)
    return ii, (ii + off) % n_tasks


class BatchAnnealer:
    """Run B swap-search chains in lockstep on one BatchArena."""

    def __init__(self, ba: BatchArena, backend: str = "auto"):
        self.ba = ba
        self.backend = resolve_backend(backend)

    def run(
        self, P0: np.ndarray, steps: int, seed: int, t0: float = DEFAULT_T0
    ) -> np.ndarray:
        """Anneal every chain of ``P0`` (B, T) for ``steps`` proposals each;
        returns the final (B, T) batch (numpy, regardless of backend)."""
        P0 = np.ascontiguousarray(np.atleast_2d(P0))
        n_chains, n_tasks = P0.shape
        if n_tasks != self.ba.n_tasks:
            raise ValueError(
                f"init batch has {n_tasks} tasks, arena has {self.ba.n_tasks}"
            )
        if n_tasks < 2 or (self.ba.edges.size == 0 and self.ba.avail.size == 0):
            return P0.copy()  # nothing a swap could improve
        ii, jj = swap_proposals(n_tasks, steps, n_chains, seed)
        thresh = np.linspace(float(t0), 0.0, steps)
        used0 = self.ba.used(P0)
        if self.backend == "jax":
            return self._run_jax(P0, used0, ii, jj, thresh)
        return self._run_numpy(P0, used0, ii, jj, thresh)

    # -- numpy fallback --------------------------------------------------------
    def _run_numpy(self, P0, used0, ii, jj, thresh) -> np.ndarray:
        ba = self.ba
        P = P0.astype(np.intp, copy=True)
        used = used0.copy()
        bidx = np.arange(P.shape[0])
        for s in range(ii.shape[0]):
            i, j = ii[s], jj[s]
            na, nb = P[bidx, i], P[bidx, j]
            ai, mi = ba.adj[i], ba.adj_mask[i]
            aj, mj = ba.adj[j], ba.adj_mask[j]
            pa = P[bidx[:, None], np.where(mi, ai, 0)]
            pb = P[bidx[:, None], np.where(mj, aj, 0)]
            m_ab = ((ai == j[:, None]) & mi).sum(axis=-1)
            delta = swap_network_delta(ba.net, na, nb, pa, pb, m_ab, mi, mj)
            di, dj = ba.hard_demand[i], ba.hard_demand[j]
            delta = delta + OVERLOAD_PENALTY * swap_overload_delta(
                ba.avail[na], ba.avail[nb], used[bidx, na], used[bidx, nb], di, dj
            )
            accept = (na != nb) & (delta <= thresh[s])
            P[bidx, i] = np.where(accept, nb, na)
            P[bidx, j] = np.where(accept, na, nb)
            du = np.where(accept[:, None], dj - di, 0.0)
            np.add.at(used, (bidx, na), du)
            np.add.at(used, (bidx, nb), -du)
        return P

    # -- jax scan --------------------------------------------------------------
    def _run_jax(self, P0, used0, ii, jj, thresh) -> np.ndarray:
        with x64():
            P = _jax_anneal_fn()(
                self.ba.net,
                self.ba.avail,
                self.ba.hard_demand,
                self.ba.adj,
                self.ba.adj_mask,
                P0.astype(np.int32),
                used0,
                ii.astype(np.int32),
                jj.astype(np.int32),
                thresh,
            )
        return np.asarray(P).astype(np.intp)


@functools.lru_cache(maxsize=None)
def _jax_anneal_fn():
    """jit-compiled lax.scan over the pregenerated proposal rows — the same
    per-step math as ``BatchAnnealer._run_numpy``, with scatter updates.
    One cached callable serves every arena/batch size (jit re-specializes
    on array shapes)."""
    jax, jnp = jax_modules()

    @jax.jit
    def anneal(net, avail, hard_demand, adj, adj_mask, P0, used0, ii, jj, thresh):
        bidx = jnp.arange(P0.shape[0])

        def step(carry, xs):
            P, used = carry
            i, j, th = xs
            na, nb = P[bidx, i], P[bidx, j]
            ai, mi = adj[i], adj_mask[i]
            aj, mj = adj[j], adj_mask[j]
            pa = P[bidx[:, None], jnp.where(mi, ai, 0)]
            pb = P[bidx[:, None], jnp.where(mj, aj, 0)]
            m_ab = ((ai == j[:, None]) & mi).sum(axis=-1)
            delta = swap_network_delta(net, na, nb, pa, pb, m_ab, mi, mj, xp=jnp)
            di, dj = hard_demand[i], hard_demand[j]
            delta = delta + OVERLOAD_PENALTY * swap_overload_delta(
                avail[na], avail[nb], used[bidx, na], used[bidx, nb], di, dj, xp=jnp
            )
            accept = (na != nb) & (delta <= th)
            P = P.at[bidx, i].set(jnp.where(accept, nb, na))
            P = P.at[bidx, j].set(jnp.where(accept, na, nb))
            du = jnp.where(accept[:, None], dj - di, 0.0)
            used = used.at[bidx, na].add(du).at[bidx, nb].add(-du)
            return (P, used), None

        (P, _), _ = jax.lax.scan(step, (P0, used0), (ii, jj, thresh))
        return P

    return anneal
