"""Batched multi-start annealing over candidate placements.

B independent chains run pairwise-swap local search *simultaneously*: each
step proposes one swap per chain, scores it with the same O(degree)
incremental delta the sequential ``SwapAnnealer`` uses
(:func:`repro.core.engine.arena.swap_network_delta`), and accepts it under a
threshold-accepting schedule (Dueck & Scheuer's deterministic cousin of
simulated annealing): a swap is accepted iff

    Δ(net + penalty × hard-violation)  ≤  threshold(step)

with the threshold annealing linearly to 0, where the loop becomes pure
hill-climbing.  Threshold accepting was chosen over Metropolis acceptance
deliberately — no ``exp``/``log`` in the hot loop means the accept decision
is a comparison of *exact* float64 quantities, so the jax scan and the
numpy fallback produce bit-identical chains.

All randomness (swap proposals) is pregenerated with numpy's Philox
generator from one seed and fed to both backends as data, so a fixed seed
gives a deterministic result regardless of backend or chain count ordering.

Because violations are penalized at ``OVERLOAD_PENALTY`` (≫ any threshold),
chains seeded with feasible placements stay feasible at every step, while
infeasible seeds (random init) are driven toward feasibility first.
"""

from __future__ import annotations

import functools
from typing import Optional, Tuple

import numpy as np

from ...obs import get_hub
from ..engine.arena import swap_network_delta, swap_overload_delta
from .backend import jax_modules, resolve_backend, x64
from .batch import BatchArena
from .objective import OVERLOAD_PENALTY, evaluate_batch
from .throughput import (
    ThroughputModel,
    ack_lambda,
    aggregates_numpy,
    hard_lambda,
    proxy_from_state,
    swap_state_terms,
)

#: Registry-visible objective modes for the batched annealer / search.
OBJECTIVES = ("netcost", "throughput")

#: Initial accept threshold, in net-distance hops: early steps may accept
#: swaps that worsen the placement by up to this much, escaping the greedy
#: seed's local minimum; anneals linearly to 0.
DEFAULT_T0 = 2.0

#: Max best-so-far curve points per annealer run when a MetricsHub is
#: active.  Curve marks land on multiples of the jax path's fused block
#: size, so an instrumented run replays the *exact* uninstrumented chain:
#: the jitted steps return their full carry, and chains split across call
#: boundaries never diverge (see ``_jax_anneal_tp_fn``).
CURVE_POINTS = 8


def _curve_marks(steps: int, k: int, n_points: int = CURVE_POINTS) -> list:
    """Ascending proposal counts (multiples of ``k``; the final step always
    included) at which the best-so-far objective curve is sampled."""
    k = max(1, min(k, steps))
    blocks = steps // k
    marks = sorted({(blocks * p // n_points) * k for p in range(1, n_points + 1)} - {0})
    if steps not in marks:
        marks.append(steps)
    return marks


def _mark_segments(lo: int, hi: int, marks):
    """Split the proposal range [lo, hi) at any interior curve marks."""
    if not marks:
        yield lo, hi
        return
    prev = lo
    for m in marks:
        if lo < m < hi:
            yield prev, m
            prev = m
    yield prev, hi


class _AnnealObs:
    """Hub-enabled annealer instrumentation (``repro.obs``): a best-so-far
    objective curve on the proposal-count axis plus per-chain acceptance.
    Pure read-side — it evaluates placements the chains already produced,
    so recording never perturbs a chain."""

    def __init__(self, hub, ba: BatchArena, objective: str, tm) -> None:
        self.hub = hub
        self.ba = ba
        self.objective = objective
        self.tm = tm
        self.best: Optional[float] = None
        self.series = hub.series("search.best_objective", objective=objective)

    def point(self, n_swaps: int, P, state=None, tp=None) -> None:
        if self.objective == "throughput":
            if tp is None:
                # Carried jax aggregates are exact (grid-quantized), so the
                # host-side proxy equals the in-scan carried value.
                vals = [np.asarray(s) for s in state]
                tp = proxy_from_state(*vals, self.tm)
            cur = float(np.max(tp))
            self.best = cur if self.best is None else max(self.best, cur)
        else:
            ev = evaluate_batch(
                self.ba, np.asarray(P).astype(np.intp), backend="numpy"
            )
            cur = float(np.min(ev.penalized()))
            self.best = cur if self.best is None else min(self.best, cur)
        self.series.append(n_swaps, self.best)

    def finish(self, acc: np.ndarray, steps: int) -> None:
        acc = np.asarray(acc, dtype=np.int64)
        n_chains = acc.shape[0]
        total = int(acc.sum(dtype=np.int64))
        self.hub.counter("search.proposals").inc(steps * n_chains)
        self.hub.counter("search.accepted").inc(total)
        self.hub.gauge("search.accept_rate", objective=self.objective).set(
            total / max(steps * n_chains, 1)
        )
        rates = self.hub.series(
            "search.chain_accept_rate", objective=self.objective
        )
        for b in range(n_chains):
            rates.append(b, int(acc[b]) / max(steps, 1))


def move_delta(move_cost, move_base, i, j, na, nb, xp=np):
    """Δ(migration term) for swapping tasks ``i``/``j`` between nodes
    ``na``/``nb``: each task's penalty toggles on whether its new node
    matches its pre-move node.  With all-zero costs the result is ±0.0,
    which is bitwise inert on the accept comparisons — zero-cost arenas
    walk chains identical to arenas without the term."""
    ci, cj = move_cost[i], move_cost[j]
    bi, bj = move_base[i], move_base[j]
    return ci * (
        (nb != bi).astype(xp.float64) - (na != bi).astype(xp.float64)
    ) + cj * ((na != bj).astype(xp.float64) - (nb != bj).astype(xp.float64))


def swap_proposals(
    n_tasks: int, steps: int, n_chains: int, seed: int
) -> Tuple[np.ndarray, np.ndarray]:
    """Pregenerated (i, j) task-index proposals, shape (steps, B) each.

    ``j = (i + offset) % T`` with offset ≥ 1 guarantees i ≠ j.  Philox is
    counter-based, so the stream is stable across numpy versions/platforms.
    """
    rng = np.random.Generator(np.random.Philox(seed))
    ii = rng.integers(0, n_tasks, size=(steps, n_chains), dtype=np.int64)
    off = rng.integers(1, max(n_tasks, 2), size=(steps, n_chains), dtype=np.int64)
    return ii, (ii + off) % n_tasks


class BatchAnnealer:
    """Run B swap-search chains in lockstep on one BatchArena."""

    def __init__(self, ba: BatchArena, backend: str = "auto"):
        self.ba = ba
        self.backend = resolve_backend(backend)

    def run(
        self,
        P0: np.ndarray,
        steps: int,
        seed: int,
        t0: float = DEFAULT_T0,
        objective: str = "netcost",
        tm: Optional[ThroughputModel] = None,
        multi_swap: int = 1,
    ) -> np.ndarray:
        """Anneal every chain of ``P0`` (B, T) for ``steps`` proposals each;
        returns the final (B, T) batch (numpy, regardless of backend).

        ``objective="netcost"`` (default) accepts on Δ(net + penalty ×
        violation) ≤ threshold.  ``objective="throughput"`` (requires a
        compiled ``ThroughputModel``) *maximizes* the throughput proxy with
        netcost as the annealed tie-break: a swap is accepted iff it reduces
        hard violation, or — violation unchanged — raises the proxy, or —
        proxy unchanged (the min-bound plateaus often) — passes the netcost
        threshold test.  All comparisons are of exact float64 quantities
        (grid-quantized state), so both backends walk identical chains.

        ``multi_swap=k`` fuses k pregenerated proposals into each
        ``lax.scan`` element on the jax path: the same per-swap math is
        applied sequentially inside one scan step (threshold-accept per
        swap, within the block), so the chain — and the final placements —
        are *bit-identical* to ``multi_swap=1`` while the scan runs k×
        fewer steps (k× less per-step launch/carry overhead).  The numpy
        fallback has no launch overhead and already walks the identical
        chain, so ``multi_swap`` is a no-op there by construction.
        """
        if objective not in OBJECTIVES:
            raise ValueError(
                f"unknown objective {objective!r}; choose from {OBJECTIVES}"
            )
        if objective == "throughput" and tm is None:
            raise ValueError("objective='throughput' requires a ThroughputModel")
        if multi_swap < 1:
            raise ValueError(f"multi_swap must be >= 1, got {multi_swap}")
        P0 = np.ascontiguousarray(np.atleast_2d(P0))
        n_chains, n_tasks = P0.shape
        if n_tasks != self.ba.n_tasks:
            raise ValueError(
                f"init batch has {n_tasks} tasks, arena has {self.ba.n_tasks}"
            )
        if n_tasks < 2 or (self.ba.edges.size == 0 and self.ba.avail.size == 0):
            return P0.copy()  # nothing a swap could improve
        ii, jj = swap_proposals(n_tasks, steps, n_chains, seed)
        thresh = np.linspace(float(t0), 0.0, steps)
        used0 = self.ba.used(P0)
        # Ambient observability: a live MetricsHub gets acceptance counts
        # and a best-so-far curve; with NULL_HUB (the default) ``rec`` is
        # None and every recording site is skipped.
        hub = get_hub()
        rec = _AnnealObs(hub, self.ba, objective, tm) if hub.enabled else None
        # "pallas" selects the fused evaluator in evaluate_batch/
        # throughput_batch; the annealer's hot loop is the fused multi-swap
        # scan either way, so it shares the jax path (bit-identical chains).
        use_jax = self.backend in ("jax", "pallas")
        if objective == "throughput":
            if use_jax:
                return self._run_jax_tp(
                    P0, used0, ii, jj, thresh, tm, multi_swap, rec
                )
            return self._run_numpy_tp(P0, used0, ii, jj, thresh, tm, rec)
        if use_jax:
            return self._run_jax(P0, used0, ii, jj, thresh, multi_swap, rec)
        return self._run_numpy(P0, used0, ii, jj, thresh, rec)

    # -- numpy fallback --------------------------------------------------------
    def _run_numpy(self, P0, used0, ii, jj, thresh, rec=None) -> np.ndarray:
        ba = self.ba
        P = P0.astype(np.intp, copy=True)
        used = used0.copy()
        bidx = np.arange(P.shape[0])
        acc = np.zeros(P.shape[0], dtype=np.int64)
        marks = _curve_marks(ii.shape[0], 1) if rec is not None else []
        nm = 0
        mb, mc = ba.move_base, ba.move_cost
        for s in range(ii.shape[0]):
            i, j = ii[s], jj[s]
            na, nb = P[bidx, i], P[bidx, j]
            ai, mi = ba.adj[i], ba.adj_mask[i]
            aj, mj = ba.adj[j], ba.adj_mask[j]
            pa = P[bidx[:, None], np.where(mi, ai, 0)]
            pb = P[bidx[:, None], np.where(mj, aj, 0)]
            m_ab = ((ai == j[:, None]) & mi).sum(axis=-1)
            delta = swap_network_delta(ba.net, na, nb, pa, pb, m_ab, mi, mj)
            di, dj = ba.hard_demand[i], ba.hard_demand[j]
            delta = delta + OVERLOAD_PENALTY * swap_overload_delta(
                ba.avail[na], ba.avail[nb], used[bidx, na], used[bidx, nb], di, dj
            )
            if mc is not None:
                delta = delta + move_delta(mc, mb, i, j, na, nb)
            accept = (na != nb) & (delta <= thresh[s])
            P[bidx, i] = np.where(accept, nb, na)
            P[bidx, j] = np.where(accept, na, nb)
            du = np.where(accept[:, None], dj - di, 0.0)
            np.add.at(used, (bidx, na), du)
            np.add.at(used, (bidx, nb), -du)
            acc += accept
            if rec is not None and nm < len(marks) and s + 1 == marks[nm]:
                rec.point(s + 1, P)
                nm += 1
        if rec is not None:
            rec.finish(acc, ii.shape[0])
        return P

    # -- numpy fallback, throughput objective ----------------------------------
    def _run_numpy_tp(self, P0, used0, ii, jj, thresh, tm, rec=None) -> np.ndarray:
        ba = self.ba
        P = P0.astype(np.intp, copy=True)
        used = used0.copy()
        B = P.shape[0]
        bidx = np.arange(B)
        acc = np.zeros(B, dtype=np.int64)
        marks = _curve_marks(ii.shape[0], 1) if rec is not None else []
        nm = 0
        mb, mc = ba.move_base, ba.move_cost
        cpu_load, mem_used, egress, ingress, rack_up, ack_num = aggregates_numpy(
            ba, tm, P
        )
        nic_cap, rack_cap = tm.nic_cap, tm.rack_cap
        tp = proxy_from_state(
            cpu_load, mem_used, egress, ingress, rack_up, ack_num, tm
        )
        for s in range(ii.shape[0]):
            i, j = ii[s], jj[s]
            na, nb = P[bidx, i], P[bidx, j]
            ai, mi = ba.adj[i], ba.adj_mask[i]
            aj, mj = ba.adj[j], ba.adj_mask[j]
            pa = P[bidx[:, None], np.where(mi, ai, 0)]
            pb = P[bidx[:, None], np.where(mj, aj, 0)]
            m_ab = ((ai == j[:, None]) & mi).sum(axis=-1)
            dnet = swap_network_delta(ba.net, na, nb, pa, pb, m_ab, mi, mj)
            if mc is not None:
                dnet = dnet + move_delta(mc, mb, i, j, na, nb)
            di, dj = ba.hard_demand[i], ba.hard_demand[j]
            dov = swap_overload_delta(
                ba.avail[na], ba.avail[nb], used[bidx, na], used[bidx, nb], di, dj
            )
            # Candidate throughput state (functional copies; committed only
            # where accepted).
            dc = tm.task_cpu[j] - tm.task_cpu[i]
            dm = tm.task_mem[j] - tm.task_mem[i]
            cl, mu = cpu_load.copy(), mem_used.copy()
            cl[bidx, na] += dc
            cl[bidx, nb] -= dc
            mu[bidx, na] += dm
            mu[bidx, nb] -= dm
            eg, ing, rk, an = (
                egress.copy(), ingress.copy(), rack_up.copy(), ack_num.copy(),
            )
            (ei, ev, ii2, iv, ri, rv, ci, cv) = swap_state_terms(
                P, bidx, i, j, na, nb,
                ba.adj, tm.adj_bytes, tm.adj_src, tm.adj_comp, tm.adj_lat,
                tm.rack_of,
            )
            np.add.at(eg, (bidx[:, None], ei), ev)
            np.add.at(ing, (bidx[:, None], ii2), iv)
            np.add.at(rk, (bidx[:, None], ri), rv)
            np.add.at(an, (bidx[:, None], ci), cv)
            lam = hard_lambda(
                cl, mu, eg, ing, rk,
                tm.cpu_cap, tm.mem_cap, nic_cap, rack_cap,
                tm.thrash_factor, tm.source_bound,
            )
            tp_new = np.minimum(
                lam, ack_lambda(an, tm.den_flow, tm.ack)
            ) * tm.sink_rate
            # Compare tp_new/tp directly — forming tp_new - tp would invite
            # XLA to contract the final multiply and the subtract into one
            # FMA on the jax path, yielding sub-ulp nonzero "differences"
            # where the plateau is exact (backend golden equality hinges on
            # both paths asking the same question of the same bits).
            accept = (na != nb) & (
                (dov < 0.0)
                | (
                    (dov == 0.0)
                    & ((tp_new > tp) | ((tp_new == tp) & (dnet <= thresh[s])))
                )
            )
            P[bidx, i] = np.where(accept, nb, na)
            P[bidx, j] = np.where(accept, na, nb)
            du = np.where(accept[:, None], dj - di, 0.0)
            np.add.at(used, (bidx, na), du)
            np.add.at(used, (bidx, nb), -du)
            w = accept[:, None]
            cpu_load = np.where(w, cl, cpu_load)
            mem_used = np.where(w, mu, mem_used)
            egress = np.where(w, eg, egress)
            ingress = np.where(w, ing, ingress)
            rack_up = np.where(w, rk, rack_up)
            ack_num = np.where(w, an, ack_num)
            tp = np.where(accept, tp_new, tp)
            acc += accept
            if rec is not None and nm < len(marks) and s + 1 == marks[nm]:
                rec.point(s + 1, P, tp=tp)
                nm += 1
        if rec is not None:
            rec.finish(acc, ii.shape[0])
        return P

    # -- jax scan, throughput objective ----------------------------------------
    def _run_jax_tp(self, P0, used0, ii, jj, thresh, tm, k, rec=None) -> np.ndarray:
        ba = self.ba
        state = aggregates_numpy(ba, tm, P0.astype(np.intp))
        mb, mc = ba.move_arrays()
        model_args = (
            ba.net, ba.avail, ba.hard_demand, ba.adj, ba.adj_mask,
            mb.astype(np.int32), mc,
            tm.task_cpu, tm.task_mem, tm.cpu_cap, tm.mem_cap,
            tm.nic_cap, tm.rack_cap, tm.adj_bytes, tm.adj_src,
            tm.adj_comp, tm.adj_lat, tm.rack_of, tm.den_flow,
            np.float64(tm.thrash_factor), np.float64(tm.source_bound),
            np.float64(tm.sink_rate),
        )
        P, used = P0.astype(np.int32), used0
        acc = np.zeros(P0.shape[0], dtype=np.int32)
        steps = ii.shape[0]
        marks = _curve_marks(steps, min(k, steps)) if rec is not None else None
        with x64():
            for lo, hi, kk in _swap_blocks(steps, k):
                # Curve marks only split the scan at full-carry boundaries,
                # which is bit-identical to the unsplit scan by contract.
                for mlo, mhi in _mark_segments(lo, hi, marks):
                    P, used, state, acc = _jax_anneal_tp_fn(tm.ack, kk)(
                        *model_args, P, used, state, acc,
                        _rows(ii, mlo, mhi, kk), _rows(jj, mlo, mhi, kk),
                        thresh[mlo:mhi].reshape(-1, kk),
                    )
                    if rec is not None:
                        rec.point(mhi, np.asarray(P), state=state)
        if rec is not None:
            rec.finish(np.asarray(acc), steps)
        return np.asarray(P).astype(np.intp)

    # -- jax scan --------------------------------------------------------------
    def _run_jax(self, P0, used0, ii, jj, thresh, k, rec=None) -> np.ndarray:
        ba = self.ba
        P, used = P0.astype(np.int32), used0
        acc = np.zeros(P0.shape[0], dtype=np.int32)
        steps = ii.shape[0]
        mb, mc = ba.move_arrays()
        marks = _curve_marks(steps, min(k, steps)) if rec is not None else None
        with x64():
            for lo, hi, kk in _swap_blocks(steps, k):
                for mlo, mhi in _mark_segments(lo, hi, marks):
                    P, used, acc = _jax_anneal_fn(kk)(
                        ba.net, ba.avail, ba.hard_demand, ba.adj, ba.adj_mask,
                        mb.astype(np.int32), mc, P, used, acc,
                        _rows(ii, mlo, mhi, kk), _rows(jj, mlo, mhi, kk),
                        thresh[mlo:mhi].reshape(-1, kk),
                    )
                    if rec is not None:
                        rec.point(mhi, np.asarray(P))
        if rec is not None:
            rec.finish(np.asarray(acc), steps)
        return np.asarray(P).astype(np.intp)


def _swap_blocks(steps: int, k: int):
    """Split ``steps`` proposals into a main run of k-fused scan elements
    plus a k=1 tail for the remainder — (lo, hi, k_eff) segments.  Only two
    compiled variants per k ever exist (k and 1), and a k > steps simply
    degrades to the tail."""
    k = max(1, min(k, steps))
    main = (steps // k) * k
    if main:
        yield 0, main, k
    if steps > main:
        yield main, steps, 1


def _rows(a: np.ndarray, lo: int, hi: int, k: int) -> np.ndarray:
    """(steps, B) int proposal rows → (outer, k, B) int32 scan elements."""
    return a[lo:hi].astype(np.int32).reshape(-1, k, a.shape[1])


@functools.lru_cache(maxsize=None)
def _jax_anneal_fn(k: int):
    """jit-compiled lax.scan over k-fused proposal blocks — the same
    per-swap math as ``BatchAnnealer._run_numpy``, with scatter updates.
    Each scan element carries k proposals, applied sequentially (unrolled
    at trace time), so the chain is bit-identical to k=1 while the scan —
    and its per-step dispatch/carry overhead — shrinks k×.  Returns the
    full carry so a tail call can chain.  One cached callable per k serves
    every arena/batch size (jit re-specializes on array shapes)."""
    jax, jnp = jax_modules()

    @jax.jit
    def anneal(
        net, avail, hard_demand, adj, adj_mask, move_base, move_cost,
        P0, used0, acc0, ii, jj, thresh,
    ):
        bidx = jnp.arange(P0.shape[0])

        def swap(P, used, acc, i, j, th):
            na, nb = P[bidx, i], P[bidx, j]
            ai, mi = adj[i], adj_mask[i]
            aj, mj = adj[j], adj_mask[j]
            pa = P[bidx[:, None], jnp.where(mi, ai, 0)]
            pb = P[bidx[:, None], jnp.where(mj, aj, 0)]
            m_ab = ((ai == j[:, None]) & mi).sum(axis=-1)
            delta = swap_network_delta(net, na, nb, pa, pb, m_ab, mi, mj, xp=jnp)
            di, dj = hard_demand[i], hard_demand[j]
            delta = delta + OVERLOAD_PENALTY * swap_overload_delta(
                avail[na], avail[nb], used[bidx, na], used[bidx, nb], di, dj, xp=jnp
            )
            # ±0.0 with zero costs — accept comparisons are unchanged.
            delta = delta + move_delta(move_cost, move_base, i, j, na, nb, xp=jnp)
            accept = (na != nb) & (delta <= th)
            P = P.at[bidx, i].set(jnp.where(accept, nb, na))
            P = P.at[bidx, j].set(jnp.where(accept, na, nb))
            du = jnp.where(accept[:, None], dj - di, 0.0)
            used = used.at[bidx, na].add(du).at[bidx, nb].add(-du)
            # Pure integer side-channel for the per-chain acceptance-rate
            # telemetry — no float path reads it, so chains are unchanged.
            return P, used, acc + accept.astype(jnp.int32)

        def step(carry, xs):
            P, used, acc = carry
            i, j, th = xs  # (k, B), (k, B), (k,)
            for r in range(k):
                P, used, acc = swap(P, used, acc, i[r], j[r], th[r])
            return (P, used, acc), None

        (P, used, acc), _ = jax.lax.scan(step, (P0, used0, acc0), (ii, jj, thresh))
        return P, used, acc

    return anneal


@functools.lru_cache(maxsize=None)
def _jax_anneal_tp_fn(ack, k: int):
    """jit-compiled lax.scan for the throughput objective — the same
    per-swap math as ``BatchAnnealer._run_numpy_tp`` (one cached callable
    per topology structure and fusion factor: the AckPlan and k are the
    static keys; every model array is a traced argument so no constants
    are baked in).  Like :func:`_jax_anneal_fn`, each scan element applies
    k proposals sequentially and the full aggregate state is returned so
    a tail call can chain: the proxy recomputed from the carried exact
    (grid-quantized) aggregates at a chain boundary is bit-identical to
    the carried value, so chains split across calls never diverge."""
    jax, jnp = jax_modules()

    @jax.jit
    def anneal(
        net, avail, hard_demand, adj, adj_mask, move_base, move_cost,
        task_cpu, task_mem, cpu_cap, mem_cap, nic_cap, rack_cap,
        adj_bytes, adj_src, adj_comp, adj_lat, rack_of, den_flow,
        thrash_factor, source_bound, sink_rate,
        P0, used0, state0, acc0, ii, jj, thresh,
    ):
        bidx = jnp.arange(P0.shape[0])
        cpu0, mem0, eg0, in0, rk0, an0 = state0
        tp0 = jnp.minimum(
            hard_lambda(
                cpu0, mem0, eg0, in0, rk0,
                cpu_cap, mem_cap, nic_cap, rack_cap,
                thrash_factor, source_bound, xp=jnp,
            ),
            ack_lambda(an0, den_flow, ack, xp=jnp),
        ) * sink_rate

        def swap(carry, i, j, th):
            (
                P, used, cpu_load, mem_used, egress, ingress,
                rack_up, ack_num, tp, acc,
            ) = carry
            na, nb = P[bidx, i], P[bidx, j]
            ai, mi = adj[i], adj_mask[i]
            aj, mj = adj[j], adj_mask[j]
            pa = P[bidx[:, None], jnp.where(mi, ai, 0)]
            pb = P[bidx[:, None], jnp.where(mj, aj, 0)]
            m_ab = ((ai == j[:, None]) & mi).sum(axis=-1)
            dnet = swap_network_delta(net, na, nb, pa, pb, m_ab, mi, mj, xp=jnp)
            # ±0.0 with zero costs — the tie-break compare is unchanged.
            dnet = dnet + move_delta(move_cost, move_base, i, j, na, nb, xp=jnp)
            di, dj = hard_demand[i], hard_demand[j]
            dov = swap_overload_delta(
                avail[na], avail[nb], used[bidx, na], used[bidx, nb], di, dj, xp=jnp
            )
            dc = task_cpu[j] - task_cpu[i]
            dm = task_mem[j] - task_mem[i]
            cl = cpu_load.at[bidx, na].add(dc).at[bidx, nb].add(-dc)
            mu = mem_used.at[bidx, na].add(dm).at[bidx, nb].add(-dm)
            (ei, ev, ij2, iv, ri, rv, ci, cv) = swap_state_terms(
                P, bidx, i, j, na, nb,
                adj, adj_bytes, adj_src, adj_comp, adj_lat, rack_of, xp=jnp,
            )
            col = bidx[:, None]
            eg = egress.at[col, ei].add(ev)
            ing = ingress.at[col, ij2].add(iv)
            rk = rack_up.at[col, ri].add(rv)
            an = ack_num.at[col, ci].add(cv)
            lam = hard_lambda(
                cl, mu, eg, ing, rk,
                cpu_cap, mem_cap, nic_cap, rack_cap,
                thrash_factor, source_bound, xp=jnp,
            )
            tp_new = jnp.minimum(lam, ack_lambda(an, den_flow, ack, xp=jnp)) * sink_rate
            # Direct comparisons, not tp_new - tp: a subtract after the
            # multiply is FMA-contractible under XLA (see the numpy twin).
            accept = (na != nb) & (
                (dov < 0.0)
                | ((dov == 0.0) & ((tp_new > tp) | ((tp_new == tp) & (dnet <= th))))
            )
            P = P.at[bidx, i].set(jnp.where(accept, nb, na))
            P = P.at[bidx, j].set(jnp.where(accept, na, nb))
            du = jnp.where(accept[:, None], dj - di, 0.0)
            used = used.at[bidx, na].add(du).at[bidx, nb].add(-du)
            w = accept[:, None]
            return (
                P,
                used,
                jnp.where(w, cl, cpu_load),
                jnp.where(w, mu, mem_used),
                jnp.where(w, eg, egress),
                jnp.where(w, ing, ingress),
                jnp.where(w, rk, rack_up),
                jnp.where(w, an, ack_num),
                jnp.where(accept, tp_new, tp),
                # Integer acceptance side-channel (telemetry only).
                acc + accept.astype(jnp.int32),
            )

        def step(carry, xs):
            i, j, th = xs  # (k, B), (k, B), (k,)
            for r in range(k):
                carry = swap(carry, i[r], j[r], th[r])
            return carry, None

        carry0 = (P0, used0, cpu0, mem0, eg0, in0, rk0, an0, tp0, acc0)
        carry, _ = jax.lax.scan(step, carry0, (ii, jj, thresh))
        return carry[0], carry[1], carry[2:8], carry[9]

    return anneal
