"""Batched throughput proxy — search what the paper measures (§6).

The paper's headline claims are about *sink throughput*, but network cost is
only a proxy that diverges exactly in the CPU-bound and shedding regimes
(§6.3.2, §6.5).  This module distills the simulator's binding analysis
(:mod:`repro.stream.simulator`) into a per-candidate bound that is one
vmapped/jit jax reduction over a ``(B, T)`` placement batch:

    proxy(p) = min(source, cpu(p), bandwidth(p), ack(p)) × lossless sink rate

* **source** — the placement-independent λ ceiling from intrinsic per-task
  rates (``max_rate_per_task``);
* **cpu(p)** — segment-sum the per-task CPU cost rows onto nodes, divide
  into per-node *effective* capacity (memory over-subscription thrashes a
  node to ``thrash_factor`` of its CPU, the §6.5 collapse mechanism);
* **bandwidth(p)** — edge-gather per-link flow: per-NIC egress/ingress and
  per-rack uplink bytes per unit λ against link capacity;
* **ack(p)** — first-order credit loop for acked topologies:
  ``pending / L₀(p)`` with L₀ the *zero-load* critical-path latency
  (flow-weighted hop latencies by placement class + per-component service
  at free capacity + the constant acker round trip).  The queueing-aware
  refinement (utilization-inflated serialization, M/M/1 sojourn at the
  operating point) is a recorded ROADMAP follow-up.

The per-task rates are the simulator's *lossless* component rates under a
uniform shuffle split (placement-independent by construction — what makes
the whole bound a gather/segment-sum instead of a fixed-point solve).  The
evaluator models Storm's ``local_or_shuffle`` locality routing for the
bandwidth/ack terms: a src task with a colocated dst routes everything
locally (no NIC bytes, intra-node latency), computed per candidate via one
extra segment-sum of colocation counts.  The annealer's O(degree)
incremental hot loop keeps the uniform-split approximation (locality flips
have non-local state effects); the scheduler's final candidate selection
and the never-worse-than-greedy check use this faithful evaluator.

Exactness contract (the same golden-equality bar as ``evaluate_batch``):
every per-task rate/flow is quantized to a dyadic grid at compile time
(``GRID`` for resource rows, the finer ``ACK_GRID`` for latency×flow
summands), so all segment-sums are exact integer arithmetic in float64 —
the sum order (numpy ``add.at`` vs XLA scatter/segment_sum) cannot change a
bit, and the numpy fallback is bit-identical to the jax path.  The scalar
simulator reuses :func:`capacity_bound` for its own per-node bounds, so the
proxy and the simulator share one source of truth for "binding bound"
semantics.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import List, Optional, Tuple

import numpy as np

from .backend import chunk_ranges, jax_modules, resolve_backend, x64
from .batch import BatchArena

_EPS = 1e-12

#: Dyadic quantization grid for per-task rates/flows: values become exact
#: multiples of 2^-26, so segment-sums (of realistically bounded magnitude)
#: are exact in float64 regardless of accumulation order — the structural
#: guarantee behind numpy/jax bit-equality of the proxy.
GRID = 2.0 ** -26

#: Finer grid for latency×flow summands (magnitudes ~1e-8..1e-2); sums stay
#: exact while below 2^53 × ACK_GRID ≈ 32 seconds of aggregate latency.
ACK_GRID = 2.0 ** -48


def quantize(x: np.ndarray, grid: float = GRID) -> np.ndarray:
    """Round to a dyadic grid (float64, exact representation)."""
    return np.round(np.asarray(x, dtype=np.float64) / grid) * grid


def capacity_bound(use, cap, xp=np):
    """λ ceiling from ``use × λ ≤ cap`` per entry, reduced over the trailing
    axis: ``min over entries with use > eps of max(cap, 0) / use`` (``inf``
    when nothing binds).

    The one array-form "binding bound" both the scalar simulator
    (``Simulator._cpu_bound`` / ``_bandwidth_bound``) and the batched proxy
    compute — extracted so the two cannot drift.
    """
    use = xp.asarray(use)
    binds = use > _EPS
    ratio = xp.where(binds, xp.maximum(cap, 0.0) / xp.where(binds, use, 1.0), xp.inf)
    return xp.min(ratio, axis=-1, initial=xp.inf)


@dataclasses.dataclass(frozen=True)
class AckPlan:
    """Static (hashable) description of the zero-load ack-loop bound.

    ``dp`` drives the unrolled critical-path recursion: for each component
    (reverse topological order) the tuple of ``(comp_edge_index, downstream
    component index)`` pairs; ``svc`` is the per-component zero-load service
    delay; ``spouts`` the component indices the path maximum starts from.
    Hashable so the jit-compiled evaluator caches per topology structure.
    """

    acked: bool
    pending: float
    ack_overhead_s: float
    svc: Tuple[float, ...]
    spouts: Tuple[int, ...]
    dp: Tuple[Tuple[int, Tuple[Tuple[int, int], ...]], ...]
    n_comp_edges: int


def ack_lambda(num, den, plan: AckPlan, xp=np):
    """λ ceiling from the credit loop: pending / L₀, where the hop latency
    of component edge *k* is ``num[..., k] / den[k]`` (flow-weighted mean
    over its task pairs) and L₀ is the critical spout→sink path.

    ``num`` has trailing axis ``max(n_comp_edges, 1)`` (leading axes
    broadcast); returns that leading shape.  ``inf`` (a scalar — the
    minimum with it is an identity) for unanchored topologies.
    """
    if not plan.acked:
        return np.inf
    hop = xp.where(den > 0.0, num / xp.where(den > 0.0, den, 1.0), 0.0)
    zeros = hop[..., 0] * 0.0
    path = {}
    for ci, downs in plan.dp:
        best = zeros
        for ce, d in downs:
            best = xp.maximum(best, hop[..., ce] + plan.svc[d] + path[d])
        path[ci] = best
    L = zeros
    for sp in plan.spouts:
        L = xp.maximum(L, plan.svc[sp] + path[sp])
    return plan.pending / (L + plan.ack_overhead_s)


@dataclasses.dataclass(frozen=True)
class ThroughputModel:
    """Per-(topology, cluster) arrays the proxy reduces over.

    All per-task quantities are grid-quantized; all arrays are aligned with
    the owning ``BatchArena`` (``tids`` task order, ``node_ids`` node order,
    ``edges`` edge order, ``adj`` adjacency slots).
    """

    task_cpu: np.ndarray   # (T,) CPU points per unit λ (rate × cost)
    task_mem: np.ndarray   # (T,) memory MB (static)
    cpu_cap: np.ndarray    # (N,) CPU points
    mem_cap: np.ndarray    # (N,) memory MB
    rack_of: np.ndarray    # (N,) intp rack index
    n_racks: int
    edge_bytes: np.ndarray  # (E,) bytes/s per unit λ, aligned with ba.edges
    edge_comp: np.ndarray   # (E,) intp component-edge index per task edge
    edge_lat: np.ndarray    # (3, E) latency×flow summands per placement class
    den_flow: np.ndarray    # (n_comp_edges,) flow sums (hop-mean denominators)
    # Storm locality routing (local_or_shuffle): a src task with ≥1
    # colocated dst task routes *everything* locally — its pairs carry no
    # NIC bytes and intra-node latency.  ``pair_key`` maps each task edge
    # to its (src task, comp edge) combo; ``local_num`` is the combo's
    # quantized out-rate × intra-node latency (its ack contribution while
    # locally routed; zero for shuffle combos).
    edge_local: np.ndarray  # (E,) bool — src component edge is local_or_shuffle
    pair_key: np.ndarray    # (E,) intp combo index
    combo_ce: np.ndarray    # (K,) intp comp-edge per combo
    local_num: np.ndarray   # (K,) float64
    n_combos: int
    adj_bytes: np.ndarray   # (T, max_deg) per-slot edge bytes, aligned with ba.adj
    adj_src: np.ndarray     # (T, max_deg) True where the row task is the edge src
    adj_comp: np.ndarray    # (T, max_deg) intp component-edge index per slot
    adj_lat: np.ndarray     # (3, T, max_deg) latency×flow summands per slot
    ack: AckPlan
    nic_bw: float
    rack_bw: float
    thrash_factor: float
    source_bound: float    # scalar λ ceiling (inf when no component is rate-limited)
    sink_rate: float       # lossless per-unit-λ sink processing rate

    @property
    def nic_cap(self) -> np.ndarray:
        return np.full(self.cpu_cap.shape[0], self.nic_bw, dtype=np.float64)

    @property
    def rack_cap(self) -> np.ndarray:
        return np.full(max(self.n_racks, 1), self.rack_bw, dtype=np.float64)


def lossless_task_profile(topology):
    """(per-task rate, per-task-edge flow) under the lossless uniform split.

    Returns ``(rates, flows)`` where ``rates[tid]`` is the per-unit-λ
    processed rate of one task (spouts: emitted) and ``flows[(src_cid,
    dst_cid)]`` is the per-unit-λ tuple flow on one (src task, dst task)
    pair of that component edge.  Placement-independent: shuffle semantics
    split each task's output uniformly over all downstream tasks.
    """
    from ...stream.simulator import _component_rates  # stream imports core; lazy here

    rate_in, rate_out = _component_rates(topology)
    rates = {}
    for cid, comp in topology.components.items():
        r = rate_out[cid] if comp.is_spout else rate_in[cid]
        per_task = r / comp.parallelism
        for t in comp.tasks(topology.id):
            rates[t.id] = per_task
    flows = {}
    for src, dst in topology.edges:
        cs, cd = topology.components[src], topology.components[dst]
        flows[(src, dst)] = rate_out[src] / (cs.parallelism * cd.parallelism)
    return rates, flows


def _ack_plan(topology, cluster, ce_of, ack_overhead_s) -> AckPlan:
    """Compile the static critical-path recursion for the ack bound."""
    from ...stream.simulator import _cpu_cost, _topo_order

    order = _topo_order(topology)
    cindex = {cid: k for k, cid in enumerate(order)}
    live_caps = [n.spec.cpu_capacity for n in cluster.live_nodes()]
    one_core = min(min(live_caps) if live_caps else 100.0, 100.0)
    svc = []
    for cid in order:
        comp = topology.components[cid]
        cost = _cpu_cost(comp)
        mu = one_core / cost if cost > _EPS else np.inf
        if comp.max_rate_per_task is not None:
            mu = min(mu, comp.max_rate_per_task)
        svc.append(1.0 / mu if np.isfinite(mu) and mu > _EPS else 0.0)
    dp = tuple(
        (
            cindex[cid],
            tuple(
                (ce_of[(cid, d)], cindex[d]) for d in topology.downstream(cid)
            ),
        )
        for cid in reversed(order)
    )
    pending = sum(
        topology.max_spout_pending * c.parallelism for c in topology.spouts
    )
    return AckPlan(
        acked=bool(topology.acked),
        pending=float(pending),
        ack_overhead_s=float(ack_overhead_s),
        svc=tuple(svc),
        spouts=tuple(cindex[c.id] for c in topology.spouts),
        dp=dp,
        n_comp_edges=len(ce_of),
    )


def compile_throughput(
    ba: BatchArena,
    topology,
    cluster,
    network=None,
    thrash_factor: Optional[float] = None,
) -> ThroughputModel:
    """Compile the proxy arrays for one ``BatchArena``.

    ``network`` defaults to the paper's Emulab model; ``thrash_factor`` and
    the ack overhead to the simulator's constants (so proxy and simulator
    agree on the §6.5 collapse mechanism and the credit loop).
    """
    from ...stream.simulator import ACK_OVERHEAD_S, THRASH_FACTOR, _cpu_cost
    from ...stream.network import EMULAB_NETWORK

    if network is None:
        network = EMULAB_NETWORK
    if thrash_factor is None:
        thrash_factor = THRASH_FACTOR
    if ba.rack_of is None:
        raise ValueError("BatchArena was compiled without rack information")

    rates, flows = lossless_task_profile(topology)
    comps = topology.components
    tindex = {tid: i for i, tid in enumerate(ba.tids)}

    task_cpu = np.zeros(ba.n_tasks, dtype=np.float64)
    task_mem = np.zeros(ba.n_tasks, dtype=np.float64)
    for t in topology.all_tasks():
        i = tindex.get(t.id)
        if i is None:
            continue
        comp = comps[t.component_id]
        # Same units as _TopologyLoad._build: points per unit λ.
        task_cpu[i] = rates[t.id] * _cpu_cost(comp)
        task_mem[i] = comp.memory_load

    ce_of = {edge: k for k, edge in enumerate(topology.edges)}

    # Per-task-edge arrays, replaying BatchArena.from_arena's edge loop so
    # rows align with ba.edges and slots with ba.adj.  The three edge_lat
    # rows are the quantized latency×flow summands for the placement
    # classes (same node / same rack / inter-rack); crossing classes carry
    # the zero-load serialization delay.
    E = ba.edges.shape[0]
    edge_bytes = np.zeros(E, dtype=np.float64)
    edge_comp = np.zeros(E, dtype=np.intp)
    edge_lat = np.zeros((3, E), dtype=np.float64)
    edge_local = np.zeros(E, dtype=bool)
    pair_key = np.zeros(E, dtype=np.intp)
    combo_index: dict = {}
    combo_ce_list: List[int] = []
    local_num_list: List[float] = []
    adj_bytes = np.zeros(ba.adj.shape, dtype=np.float64)
    adj_src = np.zeros(ba.adj.shape, dtype=bool)
    adj_comp = np.zeros(ba.adj.shape, dtype=np.intp)
    adj_lat = np.zeros((3,) + ba.adj.shape, dtype=np.float64)
    slot = [0] * ba.n_tasks
    e = 0
    for src, dst in topology.task_edges():
        a, b = tindex.get(src.id), tindex.get(dst.id)
        if a is None or b is None:
            continue
        cs = comps[src.component_id]
        cedge = (src.component_id, dst.component_id)
        flow = flows[cedge]
        byt = float(quantize(flow * cs.tuple_bytes))
        ser = cs.tuple_bytes / network.nic_bw
        lat3 = quantize(
            np.array(
                [
                    network.lat_inter_process * flow,
                    (network.lat_inter_node + ser) * flow,
                    (network.lat_inter_rack + ser) * flow,
                ]
            ),
            ACK_GRID,
        )
        ce = ce_of[cedge]
        is_local = topology.groupings.get(cedge, "shuffle") == "local_or_shuffle"
        combo = (a, ce)
        if combo not in combo_index:
            combo_index[combo] = len(combo_ce_list)
            combo_ce_list.append(ce)
            # Per-src-task ack contribution while locally routed: the whole
            # out rate traverses intra-node hops (only local combos use it).
            n_dst = comps[dst.component_id].parallelism
            local_num_list.append(
                float(
                    quantize(flow * n_dst * network.lat_inter_process, ACK_GRID)
                )
                if is_local
                else 0.0
            )
        assert ba.adj[a, slot[a]] == b and ba.adj[b, slot[b]] == a
        edge_bytes[e] = byt
        edge_comp[e] = ce
        edge_lat[:, e] = lat3
        edge_local[e] = is_local
        pair_key[e] = combo_index[combo]
        for r, is_src in ((a, True), (b, False)):
            adj_bytes[r, slot[r]] = byt
            adj_src[r, slot[r]] = is_src
            adj_comp[r, slot[r]] = ce
            adj_lat[:, r, slot[r]] = lat3
            slot[r] += 1
        e += 1
    combo_ce = (
        np.array(combo_ce_list, dtype=np.intp)
        if combo_ce_list
        else np.zeros(1, dtype=np.intp)
    )
    local_num = (
        np.array(local_num_list, dtype=np.float64)
        if local_num_list
        else np.zeros(1, dtype=np.float64)
    )

    den_flow = np.zeros(max(len(ce_of), 1), dtype=np.float64)
    q_flows = {edge: float(quantize(f, ACK_GRID)) for edge, f in flows.items()}
    for src, dst in topology.task_edges():
        if src.id in tindex and dst.id in tindex:
            den_flow[ce_of[(src.component_id, dst.component_id)]] += q_flows[
                (src.component_id, dst.component_id)
            ]

    source = np.inf
    for comp in comps.values():
        if comp.max_rate_per_task is None:
            continue
        r = rates[comp.tasks(topology.id)[0].id]  # equal across the component
        if r > _EPS:
            source = min(source, comp.max_rate_per_task / r)
    sink_rate = sum(
        rates[t.id] for s in topology.sinks() for t in s.tasks(topology.id)
    )

    cpu_cap = np.array(
        [cluster.nodes[nid].spec.cpu_capacity for nid in ba.node_ids], dtype=np.float64
    )
    mem_cap = np.array(
        [cluster.nodes[nid].spec.memory_capacity_mb for nid in ba.node_ids],
        dtype=np.float64,
    )
    return ThroughputModel(
        task_cpu=quantize(task_cpu),
        task_mem=quantize(task_mem),
        cpu_cap=cpu_cap,
        mem_cap=mem_cap,
        rack_of=ba.rack_of.astype(np.intp),
        n_racks=int(ba.n_racks),
        edge_bytes=edge_bytes,
        edge_comp=edge_comp,
        edge_lat=edge_lat,
        den_flow=den_flow,
        edge_local=edge_local,
        pair_key=pair_key,
        combo_ce=combo_ce,
        local_num=local_num,
        n_combos=max(len(combo_ce_list), 1),
        adj_bytes=adj_bytes,
        adj_src=adj_src,
        adj_comp=adj_comp,
        adj_lat=adj_lat,
        ack=_ack_plan(topology, cluster, ce_of, ACK_OVERHEAD_S),
        nic_bw=float(network.nic_bw),
        rack_bw=float(network.rack_uplink_bw),
        thrash_factor=float(thrash_factor),
        source_bound=float(source),
        sink_rate=float(sink_rate),
    )


def hard_lambda(
    cpu_load, mem_used, egress, ingress, rack_up,
    cpu_cap, mem_cap, nic_cap, rack_cap,
    thrash_factor, source_bound, xp=np,
):
    """min(source, cpu, bandwidth) from per-node/per-rack aggregates
    (trailing axis = nodes/racks; leading axes broadcast — ``(B, N)``
    batches or ``(N,)`` singles).  Shared by the batched evaluator and the
    annealer's hot loop."""
    eff_cap = xp.where(mem_used > mem_cap + 1e-9, cpu_cap * thrash_factor, cpu_cap)
    b = capacity_bound(cpu_load, eff_cap, xp=xp)
    b = xp.minimum(b, capacity_bound(egress, nic_cap, xp=xp))
    b = xp.minimum(b, capacity_bound(ingress, nic_cap, xp=xp))
    b = xp.minimum(b, capacity_bound(rack_up, rack_cap, xp=xp))
    return xp.minimum(b, source_bound)


def edge_lat_class(src_n, dst_n, rack_of, edge_lat, xp=np):
    """Select the latency×flow summand per task edge from its placement
    class (gather rows of the precompiled (3, ...) quantized table)."""
    same_node = src_n == dst_n
    same_rack = rack_of[src_n] == rack_of[dst_n]
    return xp.where(
        same_node, edge_lat[0], xp.where(same_rack, edge_lat[1], edge_lat[2])
    )


def aggregates_numpy(ba: BatchArena, tm: ThroughputModel, P: np.ndarray):
    """(cpu_load, mem_used, egress, ingress, rack_up, ack_num) for a
    ``(B, T)`` batch — the carried state of the throughput objective."""
    B = P.shape[0]
    N, R = ba.n_nodes, max(tm.n_racks, 1)
    CE = max(tm.ack.n_comp_edges, 1)
    bidx = np.arange(B)[:, None]
    cpu_load = np.zeros((B, N))
    mem_used = np.zeros((B, N))
    np.add.at(cpu_load, (bidx, P), tm.task_cpu[None, :])
    np.add.at(mem_used, (bidx, P), tm.task_mem[None, :])
    egress = np.zeros((B, N))
    ingress = np.zeros((B, N))
    rack_up = np.zeros((B, R))
    ack_num = np.zeros((B, CE))
    if ba.edges.shape[0]:
        src_n = P[:, ba.edges[:, 0]]
        dst_n = P[:, ba.edges[:, 1]]
        cross = src_n != dst_n
        w = np.where(cross, tm.edge_bytes[None, :], 0.0)
        np.add.at(egress, (bidx, src_n), w)
        np.add.at(ingress, (bidx, dst_n), w)
        rs, rd = tm.rack_of[src_n], tm.rack_of[dst_n]
        wr = np.where(rs != rd, tm.edge_bytes[None, :], 0.0)
        np.add.at(rack_up, (bidx, rs), wr)
        lat = edge_lat_class(src_n, dst_n, tm.rack_of, tm.edge_lat[:, None, :])
        np.add.at(ack_num, (bidx, np.broadcast_to(tm.edge_comp, src_n.shape)), lat)
    return cpu_load, mem_used, egress, ingress, rack_up, ack_num


def proxy_from_state(
    cpu_load, mem_used, egress, ingress, rack_up, ack_num, tm: ThroughputModel, xp=np
):
    """The full proxy from carried aggregates (leading axes broadcast)."""
    lam = hard_lambda(
        cpu_load, mem_used, egress, ingress, rack_up,
        tm.cpu_cap, tm.mem_cap, tm.nic_cap, tm.rack_cap,
        tm.thrash_factor, tm.source_bound, xp=xp,
    )
    lam = xp.minimum(lam, ack_lambda(ack_num, tm.den_flow, tm.ack, xp=xp))
    return lam * tm.sink_rate


def swap_state_terms(
    P, bidx, i, j, na, nb, adj, adj_bytes, adj_src, adj_comp, adj_lat, rack_of,
    xp=np,
):
    """Scatter terms updating the carried throughput state for swapping the
    nodes of task rows ``i`` (na→nb) and ``j`` (nb→na), per chain.

    Returns ``(eg_idx, eg_val, in_idx, in_val, rk_idx, rk_val, ce_idx,
    ce_val)``, each ``(B, 4·max_deg)``: old contributions of the incident
    edges negated, new contributions positive.  Mutual i–j edges appear in
    both adjacency rows and are halved (0.5× a grid value is exact), so
    their total stays right; padded slots carry zero weights throughout.
    """
    col = bidx[:, None]
    parts = []
    for r, pos_old, pos_new, other, other_new in (
        (i, na, nb, j, na),
        (j, nb, na, i, nb),
    ):
        nbr = adj[r]
        w = adj_bytes[r]
        is_src = adj_src[r]
        ce = adj_comp[r]
        l0, l1, l2 = adj_lat[0][r], adj_lat[1][r], adj_lat[2][r]
        mutual = nbr == other[:, None]
        half = xp.where(mutual, 0.5, 1.0)
        nbr_old = P[col, xp.where(nbr >= 0, nbr, 0)]
        nbr_new = xp.where(mutual, other_new[:, None], nbr_old)
        for pos_r, nbr_pos, sign in (
            (pos_old, nbr_old, -1.0),
            (pos_new, nbr_new, 1.0),
        ):
            src = xp.where(is_src, pos_r[:, None], nbr_pos)
            dst = xp.where(is_src, nbr_pos, pos_r[:, None])
            same_node = src == dst
            v = sign * half * xp.where(same_node, 0.0, w)
            rs, rd = rack_of[src], rack_of[dst]
            same_rack = rs == rd
            vr = sign * half * xp.where(same_rack, 0.0, w)
            vl = sign * half * xp.where(
                same_node, l0, xp.where(same_rack, l1, l2)
            )
            parts.append((src, v, dst, v, rs, vr, ce, vl))
    return tuple(
        xp.concatenate([p[k] for p in parts], axis=1) for k in range(8)
    )


def _locality_chunk_numpy(ba: BatchArena, tm: ThroughputModel, P: np.ndarray):
    """Locality-aware proxy for one numpy chunk — the faithful evaluator
    (the annealer's carried state keeps the uniform-split approximation;
    see the module docstring)."""
    B = P.shape[0]
    N, R = ba.n_nodes, max(tm.n_racks, 1)
    CE, K = max(tm.ack.n_comp_edges, 1), tm.n_combos
    bidx = np.arange(B)[:, None]
    cpu_load = np.zeros((B, N))
    mem_used = np.zeros((B, N))
    np.add.at(cpu_load, (bidx, P), tm.task_cpu[None, :])
    np.add.at(mem_used, (bidx, P), tm.task_mem[None, :])
    egress = np.zeros((B, N))
    ingress = np.zeros((B, N))
    rack_up = np.zeros((B, R))
    ack_num = np.zeros((B, CE))
    if ba.edges.shape[0]:
        src_n = P[:, ba.edges[:, 0]]
        dst_n = P[:, ba.edges[:, 1]]
        colo = src_n == dst_n
        L = np.zeros((B, K))
        np.add.at(
            L,
            (bidx, np.broadcast_to(tm.pair_key, src_n.shape)),
            colo.astype(np.float64),
        )
        L_pair = L[:, tm.pair_key]  # (B, E) gather of each pair's combo count
        routed_local = tm.edge_local[None, :] & (L_pair > 0.0)
        w = np.where(~colo & ~routed_local, tm.edge_bytes[None, :], 0.0)
        np.add.at(egress, (bidx, src_n), w)
        np.add.at(ingress, (bidx, dst_n), w)
        rs, rd = tm.rack_of[src_n], tm.rack_of[dst_n]
        wr = np.where((rs != rd) & ~routed_local, tm.edge_bytes[None, :], 0.0)
        np.add.at(rack_up, (bidx, rs), wr)
        lat = np.where(
            routed_local,
            0.0,
            edge_lat_class(src_n, dst_n, tm.rack_of, tm.edge_lat[:, None, :]),
        )
        np.add.at(ack_num, (bidx, np.broadcast_to(tm.edge_comp, src_n.shape)), lat)
        ln = np.where(L > 0.0, tm.local_num[None, :], 0.0)
        np.add.at(ack_num, (bidx, np.broadcast_to(tm.combo_ce, ln.shape)), ln)
    return proxy_from_state(
        cpu_load, mem_used, egress, ingress, rack_up, ack_num, tm
    )


def _throughput_numpy(ba: BatchArena, tm: ThroughputModel, P: np.ndarray, chunk: int):
    B = P.shape[0]
    out = np.zeros(B, dtype=np.float64)
    for lo, hi in chunk_ranges(B, chunk):
        out[lo:hi] = _locality_chunk_numpy(ba, tm, P[lo:hi])
    return out


def _throughput_pallas(ba: BatchArena, tm: ThroughputModel, P: np.ndarray, chunk: int):
    """Proxy via the fused scoring kernel (netcost/capacity/dead ride along
    in the same pass — the point of the fusion; callers that want all four
    should go through ``evaluate_batch(backend="pallas")`` directly)."""
    from .kernels import fused_score  # jax-only import, deferred

    B = P.shape[0]
    out = np.zeros(B, dtype=np.float64)
    for lo, hi in chunk_ranges(B, chunk):
        out[lo:hi] = fused_score(ba, P[lo:hi], tm=tm)[3]
    return out


@functools.lru_cache(maxsize=None)
def _jax_tp_fn(n_nodes: int, n_racks: int, n_combos: int, ack: AckPlan):
    """jit-compiled vmapped proxy (cached per node/rack/combo count and
    topology structure; array shapes re-specialize via jit's own cache)."""
    jax, jnp = jax_modules()
    n_racks = max(n_racks, 1)
    n_ce = max(ack.n_comp_edges, 1)

    @jax.jit
    def evaluate(
        P, task_cpu, task_mem, cpu_cap, mem_cap, nic_cap, rack_cap,
        edges, edge_bytes, edge_comp, edge_lat, den_flow, rack_of,
        edge_local, pair_key, combo_ce, local_num,
        thrash_factor, source_bound, sink_rate,
    ):
        def one(p):
            cpu_load = jax.ops.segment_sum(task_cpu, p, num_segments=n_nodes)
            mem_used = jax.ops.segment_sum(task_mem, p, num_segments=n_nodes)
            src_n, dst_n = p[edges[:, 0]], p[edges[:, 1]]
            colo = src_n == dst_n
            L = jax.ops.segment_sum(
                colo.astype(jnp.float64), pair_key, num_segments=n_combos
            )
            routed_local = edge_local & (L[pair_key] > 0.0)
            w = jnp.where(~colo & ~routed_local, edge_bytes, 0.0)
            egress = jax.ops.segment_sum(w, src_n, num_segments=n_nodes)
            ingress = jax.ops.segment_sum(w, dst_n, num_segments=n_nodes)
            rs, rd = rack_of[src_n], rack_of[dst_n]
            wr = jnp.where((rs != rd) & ~routed_local, edge_bytes, 0.0)
            rack_up = jax.ops.segment_sum(wr, rs, num_segments=n_racks)
            lat = jnp.where(
                routed_local,
                0.0,
                edge_lat_class(src_n, dst_n, rack_of, edge_lat, xp=jnp),
            )
            ack_num = jax.ops.segment_sum(lat, edge_comp, num_segments=n_ce)
            ln = jnp.where(L > 0.0, local_num, 0.0)
            ack_num = ack_num + jax.ops.segment_sum(
                ln, combo_ce, num_segments=n_ce
            )
            lam = hard_lambda(
                cpu_load, mem_used, egress, ingress, rack_up,
                cpu_cap, mem_cap, nic_cap, rack_cap,
                thrash_factor, source_bound, xp=jnp,
            )
            lam = jnp.minimum(lam, ack_lambda(ack_num, den_flow, ack, xp=jnp))
            return lam * sink_rate

        return jax.vmap(one)(P)

    return evaluate


def _throughput_jax(ba: BatchArena, tm: ThroughputModel, P: np.ndarray, chunk: int):
    fn = _jax_tp_fn(ba.n_nodes, tm.n_racks, tm.n_combos, tm.ack)
    out = np.zeros(P.shape[0], dtype=np.float64)
    with x64():
        # Honor chunking on the jax path too: one (chunk, E) gather at a
        # time instead of a monolithic (B, E) one (same contract as
        # ``evaluate_batch``; at most two compiled shapes per batch size).
        for lo, hi in chunk_ranges(P.shape[0], chunk):
            out[lo:hi] = np.asarray(
                fn(
                    P[lo:hi], tm.task_cpu, tm.task_mem,
                    tm.cpu_cap, tm.mem_cap, tm.nic_cap, tm.rack_cap,
                    ba.edges, tm.edge_bytes, tm.edge_comp, tm.edge_lat,
                    tm.den_flow, tm.rack_of,
                    tm.edge_local, tm.pair_key, tm.combo_ce, tm.local_num,
                    tm.thrash_factor, tm.source_bound, tm.sink_rate,
                ),
                dtype=np.float64,
            )
    return out


def throughput_batch(
    ba: BatchArena,
    tm: ThroughputModel,
    placements: np.ndarray,
    backend: str = "auto",
    chunk: int = 256,
) -> np.ndarray:
    """(B,) throughput proxy (tuples/s) for a ``(B, T)`` candidate batch
    (or one ``(T,)`` row).  Backends are bit-identical (grid quantization
    makes every reduction exact)."""
    P = np.ascontiguousarray(np.atleast_2d(placements))
    if P.shape[1] != ba.n_tasks:
        raise ValueError(
            f"placement batch has {P.shape[1]} tasks, arena has {ba.n_tasks}"
        )
    resolved = resolve_backend(backend)
    if resolved == "pallas":
        return _throughput_pallas(ba, tm, P, chunk)
    if resolved == "jax":
        return _throughput_jax(ba, tm, P, chunk)
    return _throughput_numpy(ba, tm, P, chunk)
