"""Backend gate for the batched search subsystem.

The objective and the annealing loop are written once against the shared
numpy-style array API and dispatched to either ``jax.numpy`` (vmapped /
jit-compiled, float64 via the scoped ``enable_x64`` context so results match
the numpy path bit-for-bit) or plain ``numpy``.  The container may not ship
jax at all — everything here degrades to the numpy path with identical
outputs, which the golden-equality tests pin.
"""

from __future__ import annotations

import contextlib
import importlib.util
from typing import Iterator, Tuple

#: Availability is probed without importing: jax's ~1 s import cost must not
#: tax every ``import repro.core`` (the search registers eagerly there); the
#: actual module import is deferred to the first jax-backend call.
HAS_JAX = importlib.util.find_spec("jax") is not None

#: ``pallas`` is the fused single-pass scoring kernel
#: (:mod:`repro.core.search.kernels`) — jax-only, bit-identical to the
#: ``jax``/``numpy`` oracle paths by the same dyadic-grid exactness argument.
BACKENDS = ("auto", "jax", "numpy", "pallas")


def resolve_backend(name: str = "auto") -> str:
    """Map a requested backend to a concrete one, validating availability."""
    if name not in BACKENDS:
        raise ValueError(f"unknown backend {name!r}; choose from {BACKENDS}")
    if name == "auto":
        return "jax" if HAS_JAX else "numpy"
    if name in ("jax", "pallas") and not HAS_JAX:
        raise RuntimeError(
            f"backend={name!r} requested but jax is not importable; "
            "install jax or use backend='numpy'/'auto'"
        )
    return name


def chunk_ranges(n: int, chunk: int) -> Iterator[Tuple[int, int]]:
    """Yield ``(lo, hi)`` slice bounds covering ``range(n)`` in ``chunk``
    steps — the one chunking loop every evaluator backend shares, so the
    "results independent of chunking" contract has a single implementation
    (numpy, jax-vmap, and pallas paths all iterate these exact bounds)."""
    if chunk < 1:
        raise ValueError(f"chunk must be >= 1, got {chunk}")
    for lo in range(0, n, chunk):
        yield lo, min(lo + chunk, n)


def jax_modules():
    """(jax, jax.numpy), imported lazily — only call after
    ``resolve_backend`` said 'jax'."""
    import jax
    import jax.numpy as jnp

    return jax, jnp


@contextlib.contextmanager
def x64() -> Iterator[None]:
    """Scoped float64 for jax traces (global-config safe: the repo's Pallas
    kernels run float32 and must not see a process-wide x64 flip)."""
    if not HAS_JAX:  # numpy path — nothing to scope
        yield
    else:
        from jax.experimental import enable_x64

        with enable_x64():
            yield
