# Pallas kernel layer for the batched placement search: one fused kernel
# scoring a (B, T) candidate block for netcost, hard-capacity violation,
# dead-node hits, and the throughput proxy in a single pass (the
# backend="pallas" option of evaluate_batch/throughput_batch).  The
# numpy and jax-vmap paths remain the bit-exact golden oracles; the
# dyadic-grid quantization that makes their reductions exact makes this
# kernel's float64 accumulation exact too, so all three backends are
# golden-equal.
from .fused_score import DEFAULT_BLOCK_B, default_interpret, fused_score

__all__ = ["DEFAULT_BLOCK_B", "default_interpret", "fused_score"]
