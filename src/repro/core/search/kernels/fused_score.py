"""Fused candidate-scoring Pallas kernel (``backend="pallas"``).

One ``pl.pallas_call`` scores a ``(B, T)`` candidate block for all four
objective terms in a single pass over the block:

* **net** — edge-gather netcost (``net[p[src], p[dst]]`` summed per row);
* **violation** — per-node/per-dim hard-capacity segment-sum overshoot;
* **dead** — dead-node hit count;
* **throughput** — the locality-aware proxy ``min(source, cpu, bandwidth,
  ack) × sink_rate`` (optional: only when a ``ThroughputModel`` is given).

The grid tiles the batch dimension only (``block_b`` candidates per
program; the batch is padded to a block multiple and the padded rows are
sliced off by the wrapper — the masking idiom from the Pallas guide, done
at the host boundary so no partial block ever reaches the kernel).  Each
program reads its own placement block plus the shared arena/model arrays
and writes its own output rows — no cross-program accumulation, so grid
execution order cannot affect a bit.

Exactness contract: every accumulated quantity is a dyadic-grid multiple
(``throughput.GRID`` / ``ACK_GRID``; net distances are 0.5-multiples), so
float64 segment-sums are exact regardless of accumulation order, and the
elementwise tail (divisions, min/max, the ack recursion) is identical
correctly-rounded IEEE arithmetic on identical bits.  The kernel is
therefore bit-identical to the numpy and jax-vmap oracles — pinned by
``tests/test_search_kernels.py`` over the §6 topology suite.

Deployment note: the kernel body uses jnp gather/scatter (``x.at[].add``,
advanced-index gathers), which interpret mode (and any XLA backend)
executes exactly; a Mosaic-TPU lowering would replace them with the
one-hot/matmul formulation — a recorded ROADMAP follow-up.  Committed
call sites must not hard-code ``interpret=True`` (the ``pallas-interpret``
lint rule): the default is computed from the runtime platform by
:func:`default_interpret`.
"""

from __future__ import annotations

import functools
from typing import Optional, Tuple

import numpy as np

from ..backend import jax_modules, x64
from ..batch import BatchArena
from ..throughput import ThroughputModel, ack_lambda, edge_lat_class, hard_lambda

#: Candidates per grid program.  The per-program working set is the
#: (block_b, E) edge gather — small enough for VMEM on every §6 topology
#: while keeping ≥10k-candidate batches to ~1e3 programs.
DEFAULT_BLOCK_B = 8


def default_interpret() -> bool:
    """Interpret unless running on a real TPU — committed call sites plumb
    this instead of hard-coding ``interpret=True`` (lint: pallas-interpret).
    Interpret mode executes the kernel through XLA with float64 intact,
    which is exactly what the golden-equality contract needs on CPU."""
    jax, _ = jax_modules()
    return jax.default_backend() != "tpu"


def _fused_kernel(
    # inputs (refs): candidate block + shared arena arrays
    P_ref, net_ref, avail_ref, demand_ref, deadw_ref, edges_ref, evalid_ref,
    mb_ref, mc_ref,
    *refs,
    blk_b: int,
    n_nodes: int,
    n_racks: int,
    n_ce: int,
    n_combos: int,
    ack,
    thrash_factor: float,
    source_bound: float,
    sink_rate: float,
    with_tp: bool,
):
    """Score one (blk_b, T) placement block; write (blk_b,) output rows.

    ``refs`` is the variadic tail: with ``with_tp`` the 11 ThroughputModel
    input refs precede the output refs (net, viol, dead[, tp]).
    """
    jax, jnp = jax_modules()

    if with_tp:
        (
            task_cpu_ref, task_mem_ref, cpu_cap_ref, mem_cap_ref,
            nic_cap_ref, rack_cap_ref, edge_bytes_ref, edge_comp_ref,
            edge_lat_ref, den_flow_ref, rack_of_ref, edge_local_ref,
            pair_key_ref, combo_ce_ref, local_num_ref,
            net_o, viol_o, dead_o, tp_o,
        ) = refs
    else:
        net_o, viol_o, dead_o = refs

    P = P_ref[...]  # (blk_b, T) int32 node indices
    # 2D iota (TPU requires ≥2D); broadcasts against every (blk_b, X) index.
    bidx = jax.lax.broadcasted_iota(jnp.int32, (blk_b, 1), 0)

    # -- hard capacity + dead count (the evaluate_batch terms) -------------
    demand = demand_ref[...]          # (T, Dh)
    avail = avail_ref[...]            # (N, Dh)
    used = jnp.zeros(
        (blk_b, n_nodes, demand.shape[1]), dtype=jnp.float64
    ).at[bidx, P].add(demand[None, :, :])
    viol_o[...] = jnp.maximum(used - avail[None, :, :], 0.0).sum(axis=(1, 2))
    dead_o[...] = deadw_ref[...][P].sum(axis=-1)

    # -- edge-gather netcost ----------------------------------------------
    edges = edges_ref[...]            # (E, 2) int32 (E padded to ≥1)
    src_t, dst_t = edges[:, 0], edges[:, 1]
    src_n = P[:, src_t]               # (blk_b, E)
    dst_n = P[:, dst_t]
    evalid = evalid_ref[...]          # (E,) 1.0 real edge / 0.0 padding
    # Migration soft cost: per-task penalty when placed off its pre-move
    # node (zero arrays on non-reconfig arenas → +0.0, bitwise inert).
    net_o[...] = (net_ref[...][src_n, dst_n] * evalid[None, :]).sum(
        axis=-1
    ) + jnp.where(P != mb_ref[...][None, :], mc_ref[...][None, :], 0.0).sum(
        axis=-1
    )

    if not with_tp:
        return

    # -- throughput proxy (the _jax_tp_fn math, batched over the block) ----
    task_cpu = task_cpu_ref[...]
    task_mem = task_mem_ref[...]
    cpu_load = jnp.zeros((blk_b, n_nodes), dtype=jnp.float64).at[bidx, P].add(
        task_cpu[None, :]
    )
    mem_used = jnp.zeros((blk_b, n_nodes), dtype=jnp.float64).at[bidx, P].add(
        task_mem[None, :]
    )
    edge_bytes = edge_bytes_ref[...]
    edge_comp = edge_comp_ref[...]
    rack_of = rack_of_ref[...]
    pair_key = pair_key_ref[...]
    colo = src_n == dst_n
    L = jnp.zeros((blk_b, n_combos), dtype=jnp.float64).at[
        bidx, pair_key[None, :]
    ].add(colo.astype(jnp.float64))
    routed_local = edge_local_ref[...][None, :] & (L[bidx, pair_key[None, :]] > 0.0)
    w = jnp.where(~colo & ~routed_local, edge_bytes[None, :], 0.0)
    egress = jnp.zeros((blk_b, n_nodes), dtype=jnp.float64).at[bidx, src_n].add(w)
    ingress = jnp.zeros((blk_b, n_nodes), dtype=jnp.float64).at[bidx, dst_n].add(w)
    rs, rd = rack_of[src_n], rack_of[dst_n]
    wr = jnp.where((rs != rd) & ~routed_local, edge_bytes[None, :], 0.0)
    rack_up = jnp.zeros((blk_b, n_racks), dtype=jnp.float64).at[bidx, rs].add(wr)
    lat = jnp.where(
        routed_local,
        0.0,
        edge_lat_class(src_n, dst_n, rack_of, edge_lat_ref[...][:, None, :], xp=jnp),
    )
    ack_num = jnp.zeros((blk_b, n_ce), dtype=jnp.float64).at[
        bidx, edge_comp[None, :]
    ].add(lat)
    ln = jnp.where(L > 0.0, local_num_ref[...][None, :], 0.0)
    ack_num = ack_num.at[bidx, combo_ce_ref[...][None, :]].add(ln)
    lam = hard_lambda(
        cpu_load, mem_used, egress, ingress, rack_up,
        cpu_cap_ref[...], mem_cap_ref[...], nic_cap_ref[...], rack_cap_ref[...],
        thrash_factor, source_bound, xp=jnp,
    )
    lam = jnp.minimum(lam, ack_lambda(ack_num, den_flow_ref[...], ack, xp=jnp))
    tp_o[...] = lam * sink_rate


@functools.lru_cache(maxsize=None)
def _fused_fn(
    n_nodes: int,
    n_racks: int,
    n_ce: int,
    n_combos: int,
    ack,
    thrash_factor: float,
    source_bound: float,
    sink_rate: float,
    block_b: int,
    with_tp: bool,
    interpret: bool,
):
    """jit-compiled fused scorer (one cached callable per arena/model
    structure; array shapes re-specialize via jit's own shape cache)."""
    jax, jnp = jax_modules()
    from jax.experimental import pallas as pl

    kernel = functools.partial(
        _fused_kernel,
        blk_b=block_b,
        n_nodes=n_nodes,
        n_racks=n_racks,
        n_ce=n_ce,
        n_combos=n_combos,
        ack=ack,
        thrash_factor=thrash_factor,
        source_bound=source_bound,
        sink_rate=sink_rate,
        with_tp=with_tp,
    )

    def _full(a):
        """BlockSpec for an un-tiled shared array (every program sees it)."""
        nd = a.ndim
        return pl.BlockSpec(a.shape, lambda i: (0,) * nd)

    @jax.jit
    def run(P, net, avail, demand, deadw, edges, evalid, mb, mc, *tp_arrays):
        Bp, T = P.shape
        inputs = (P, net, avail, demand, deadw, edges, evalid, mb, mc) + tp_arrays
        n_out = 4 if with_tp else 3
        out = pl.pallas_call(
            kernel,
            grid=(pl.cdiv(Bp, block_b),),  # Bp pre-padded to a block multiple
            in_specs=[pl.BlockSpec((block_b, T), lambda i: (i, 0))]
            + [_full(a) for a in inputs[1:]],
            out_specs=[pl.BlockSpec((block_b,), lambda i: (i,))] * n_out,
            out_shape=[jax.ShapeDtypeStruct((Bp,), jnp.float64)] * n_out,
            interpret=interpret,
        )(*inputs)
        return out

    return run


def _padded_inputs(ba: BatchArena, tm: Optional[ThroughputModel]):
    """Numpy input arrays with the empty-edge / empty-hard-dim cases padded
    to width ≥1 (a (0,0) dummy edge with zero weights/latency scores 0 in
    every term, and zero-width demand columns violate nothing)."""
    N = ba.n_nodes
    Dh = ba.avail.shape[1]
    if Dh:
        avail, demand = ba.avail, ba.hard_demand
    else:
        avail = np.zeros((N, 1), dtype=np.float64)
        demand = np.zeros((ba.n_tasks, 1), dtype=np.float64)
    deadw = (~ba.alive).astype(np.float64)
    E = ba.edges.shape[0]
    if E:
        edges = ba.edges.astype(np.int32)
        evalid = np.ones(E, dtype=np.float64)
    else:
        edges = np.zeros((1, 2), dtype=np.int32)
        evalid = np.zeros(1, dtype=np.float64)
    mb, mc = ba.move_arrays()
    base = (ba.net, avail, demand, deadw, edges, evalid, mb.astype(np.int32), mc)
    if tm is None:
        return base, ()
    if E:
        eb, ec, el3 = tm.edge_bytes, tm.edge_comp, tm.edge_lat
        elc, pk = tm.edge_local, tm.pair_key
    else:
        eb = np.zeros(1, dtype=np.float64)
        ec = np.zeros(1, dtype=np.int32)
        el3 = np.zeros((3, 1), dtype=np.float64)
        elc = np.zeros(1, dtype=bool)
        pk = np.zeros(1, dtype=np.int32)
    tp_arrays = (
        tm.task_cpu, tm.task_mem, tm.cpu_cap, tm.mem_cap,
        tm.nic_cap, tm.rack_cap, eb, ec.astype(np.int32), el3,
        tm.den_flow, tm.rack_of.astype(np.int32), elc,
        pk.astype(np.int32), tm.combo_ce.astype(np.int32), tm.local_num,
    )
    return base, tp_arrays


def fused_score(
    ba: BatchArena,
    placements: np.ndarray,
    tm: Optional[ThroughputModel] = None,
    block_b: int = DEFAULT_BLOCK_B,
    interpret: Optional[bool] = None,
) -> Tuple[np.ndarray, np.ndarray, np.ndarray, Optional[np.ndarray]]:
    """Score a ``(B, T)`` batch in **one** fused ``pallas_call``.

    Returns ``(net, violation, dead, throughput)`` — numpy float64/int64,
    bit-identical to the ``evaluate_batch``/``throughput_batch`` oracles;
    ``throughput`` is None unless ``tm`` is given.  ``interpret=None``
    resolves via :func:`default_interpret` (interpret off-TPU).
    """
    P = np.ascontiguousarray(np.atleast_2d(placements))
    B, T = P.shape
    if T != ba.n_tasks:
        raise ValueError(
            f"placement batch has {T} tasks, arena has {ba.n_tasks}"
        )
    if block_b < 1:
        raise ValueError(f"block_b must be >= 1, got {block_b}")
    interp = default_interpret() if interpret is None else bool(interpret)
    # Pad the batch to a block multiple with node-0 rows; the padded rows
    # score garbage that never leaves this function.
    n_blocks = -(-B // block_b)
    Bp = n_blocks * block_b
    P32 = np.zeros((Bp, T), dtype=np.int32)
    P32[:B] = P
    base, tp_arrays = _padded_inputs(ba, tm)
    fn = _fused_fn(
        ba.n_nodes,
        max(tm.n_racks, 1) if tm is not None else 1,
        max(tm.ack.n_comp_edges, 1) if tm is not None else 1,
        tm.n_combos if tm is not None else 1,
        tm.ack if tm is not None else None,
        tm.thrash_factor if tm is not None else 0.0,
        tm.source_bound if tm is not None else np.inf,
        tm.sink_rate if tm is not None else 0.0,
        block_b,
        tm is not None,
        interp,
    )
    with x64():
        out = fn(P32, *base, *tp_arrays)
    net = np.asarray(out[0], dtype=np.float64)[:B]
    viol = np.asarray(out[1], dtype=np.float64)[:B]
    dead = np.asarray(out[2], dtype=np.float64)[:B].astype(np.int64)
    tp = (
        np.asarray(out[3], dtype=np.float64)[:B] if tm is not None else None
    )
    return net, viol, dead, tp
