"""Algorithm 4 — node selection by weighted Euclidean distance in resource
space, anchored on the Ref Node."""

from __future__ import annotations

import math
from typing import AbstractSet, Dict, Mapping, Optional, Tuple

from .cluster import Cluster, Node
from .resources import BANDWIDTH, CPU, MEMORY, ResourceVector, weighted_distance

#: Colocate-with-upstream distance discount (DESIGN.md §6.1b) — the default
#: ``credit`` for both selection twins (NodeSelector and ArenaSelector).
PEER_CREDIT = 0.75

DEFAULT_SOFT_WEIGHTS: Mapping[str, float] = {
    # Normalizing weights: memory is in MB (thousands), CPU in points
    # (hundreds) — the paper allows weights "so that values can be normalized
    # for comparison".  These bring each term to O(1) for the Emulab node
    # (2048 MB, 100 points) and make one rack hop cost about as much as a
    # fully-loaded node, which reproduces the paper's pack-then-spill order.
    MEMORY: (1.0 / 2048.0) ** 2,
    CPU: (1.0 / 50.0) ** 2,
    BANDWIDTH: 1.0,
}


class NodeSelector:
    """Stateful node selection: holds the Ref Node across calls (Alg 4's
    ``global refNode``)."""

    def __init__(
        self,
        cluster: Cluster,
        weights: Optional[Mapping[str, float]] = None,
    ):
        self.cluster = cluster
        self.weights = dict(DEFAULT_SOFT_WEIGHTS)
        if weights:
            self.weights.update(weights)
        self.ref_node: Optional[str] = None

    # -- Alg 4 lines 6-9 -------------------------------------------------------
    def _establish_ref_node(self) -> str:
        rack = self.cluster.rack_with_most_resources()
        node = self.cluster.node_with_most_resources(rack)
        self.ref_node = node.id
        return node.id

    def distance(self, task_demand: ResourceVector, node: Node) -> float:
        """Alg 4 DISTANCE procedure."""
        ref = self.ref_node if self.ref_node is not None else node.id
        net = self.cluster.network_distance(ref, node.id)
        return weighted_distance(
            task_demand, node.available, weights=self.weights, network_distance=net
        )

    def select(
        self,
        task_demand: ResourceVector,
        credit_nodes: Optional[AbstractSet[str]] = None,
        credit: float = PEER_CREDIT,
    ) -> Optional[Node]:
        """Pick argmin-distance feasible node; None if no node satisfies the
        hard constraints (scheduler reports the task unassigned — R-Storm
        never violates hard constraints, property 2 in §4.1).

        ``credit_nodes`` (first-class peer-credit option, DESIGN.md §6.1b):
        candidates in the set get their distance multiplied by ``credit``, so
        among near-equidistant nodes the one already hosting an upstream peer
        wins — the quadratic-term colocation credit R-Storm+ uses.
        """
        if self.ref_node is None or not self.cluster.nodes[self.ref_node].alive:
            self._establish_ref_node()
        best: Optional[Node] = None
        best_d = math.inf
        # Deterministic iteration order for reproducible schedules.
        for nid in sorted(self.cluster.nodes):
            node = self.cluster.nodes[nid]
            if not node.alive or not node.can_fit_hard(task_demand):
                continue
            d = self.distance(task_demand, node)
            if credit_nodes and nid in credit_nodes:
                d *= credit
            if d < best_d - 1e-12:
                best, best_d = node, d
        return best
