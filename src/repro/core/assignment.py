"""Schedule assignments: the output of any scheduler."""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Mapping, Optional, Tuple

from .cluster import Cluster
from .topology import Task, Topology


@dataclasses.dataclass
class Assignment:
    """task.id -> node_id mapping plus bookkeeping for evaluation."""

    topology_id: str
    placements: Dict[str, str] = dataclasses.field(default_factory=dict)
    # Tasks the scheduler could not place without violating a hard constraint.
    unassigned: List[str] = dataclasses.field(default_factory=list)
    scheduler_name: str = ""
    schedule_time_s: float = 0.0

    def node_of(self, task: Task) -> Optional[str]:
        return self.placements.get(task.id)

    def tasks_on(self, node_id: str) -> List[str]:
        return [t for t, n in self.placements.items() if n == node_id]

    def nodes_used(self) -> List[str]:
        return sorted(set(self.placements.values()))

    def is_complete(self, topology: Topology) -> bool:
        want = {t.id for t in topology.all_tasks()}
        return want == set(self.placements) and not self.unassigned

    def merge(self, other: "Assignment") -> "Assignment":
        merged = Assignment(
            topology_id=f"{self.topology_id}+{other.topology_id}",
            placements={**self.placements, **other.placements},
            unassigned=self.unassigned + other.unassigned,
            scheduler_name=self.scheduler_name,
            schedule_time_s=self.schedule_time_s + other.schedule_time_s,
        )
        return merged

    # -- evaluation helpers ----------------------------------------------------
    def network_cost(
        self, topology: Topology, cluster: Cluster, live_only: bool = False
    ) -> float:
        """Sum of netDist over all communicating task pairs (lower is better).

        This is the quadratic term of QM3DKP that R-Storm's greedy heuristic
        minimizes implicitly.  With ``live_only``, pairs touching a dead node
        are excluded — the cost of the traffic actually flowing, matching the
        simulator's placement-aware rates mid-failure.
        """
        cost = 0.0
        for src, dst in topology.task_edges():
            a, b = self.placements.get(src.id), self.placements.get(dst.id)
            if a is None or b is None:
                continue
            if live_only and not (cluster.nodes[a].alive and cluster.nodes[b].alive):
                continue
            cost += cluster.network_distance(a, b)
        return cost

    def hard_violations(self, topology: Topology, cluster: Cluster) -> List[str]:
        """Node ids whose hard (memory) budget the placement exceeds."""
        by_node: Dict[str, float] = {}
        demands = {t.id: topology.demand_of(t) for t in topology.all_tasks()}
        out = []
        for tid, nid in self.placements.items():
            if tid in demands:
                by_node[nid] = by_node.get(nid, 0.0) + demands[tid]["memory_mb"]
        for nid, used in by_node.items():
            if used > cluster.nodes[nid].spec.memory_capacity_mb + 1e-9:
                out.append(nid)
        return sorted(out)

    def apply(self, topology: Topology, cluster: Cluster) -> None:
        """Commit placements onto cluster state (atomic apply, paper §4.1:
        'actual assignment ... is done in an atomic fashion after the schedule
        mapping ... has been determined')."""
        tasks = {t.id: t for t in topology.all_tasks()}
        for tid, nid in self.placements.items():
            if tid in tasks:
                cluster.nodes[nid].assign(tasks[tid], topology.demand_of(tasks[tid]))
