"""Pluggable scheduler registry (control-plane API redesign).

Schedulers self-register with ``@register_scheduler(name, kwargs_schema=...)``.
The per-scheduler kwargs schema lets the API layer validate a declarative
``SchedulerSpec(name="rstorm_annealed", kwargs={"iters": 800})`` *before*
instantiation, with actionable error messages — so third-party schedulers
become data, not code changes.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Mapping, Optional, Tuple


@dataclasses.dataclass(frozen=True)
class KwargField:
    """Schema for one scheduler-constructor kwarg.

    ``types`` is the tuple of accepted Python types; ``choices`` restricts to
    an enumerated set; ``minimum`` lower-bounds numeric values.
    """

    types: Tuple[type, ...]
    default: Any = None
    choices: Optional[Tuple] = None
    minimum: Optional[float] = None
    doc: str = ""

    def check(self, path: str, value: Any) -> Optional[str]:
        """Return an error message for ``value``, or None if it conforms."""
        names = "|".join(t.__name__ for t in self.types)
        # bool is an int subclass; only accept it where explicitly allowed.
        if isinstance(value, bool) and bool not in self.types:
            return f"{path}: expected {names}, got bool ({value!r})"
        if not isinstance(value, self.types):
            return f"{path}: expected {names}, got {type(value).__name__} ({value!r})"
        if self.choices is not None and value not in self.choices:
            return f"{path}: must be one of {sorted(self.choices)}, got {value!r}"
        if (
            self.minimum is not None
            and isinstance(value, (int, float))
            and value < self.minimum
        ):
            return f"{path}: must be >= {self.minimum}, got {value!r}"
        return None


@dataclasses.dataclass(frozen=True)
class SchedulerEntry:
    name: str
    cls: type
    kwargs_schema: Mapping[str, KwargField]


#: name -> full registry entry (class + kwargs schema).
REGISTRY: Dict[str, SchedulerEntry] = {}

#: name -> scheduler class.  Kept in sync with REGISTRY as the backwards-
#: compatible view older call sites (``SCHEDULERS[name](**kw)``) rely on.
SCHEDULERS: Dict[str, type] = {}


def register_scheduler(
    name: Optional[str] = None,
    kwargs_schema: Optional[Mapping[str, KwargField]] = None,
):
    """Class decorator registering a Scheduler under ``name``.

    Usage::

        @register_scheduler("rstorm", kwargs_schema={
            "weights": KwargField(types=(dict, type(None)), default=None),
        })
        class RStormScheduler(Scheduler): ...
    """

    def deco(cls: type) -> type:
        # Only a name set on the class itself counts — an inherited one (the
        # Scheduler base's "base", or a registered parent's name) must not
        # leak into an unnamed subclass registration.
        reg_name = name or cls.__dict__.get("name") or cls.__name__
        if reg_name in REGISTRY:
            raise ValueError(f"scheduler {reg_name!r} already registered")
        REGISTRY[reg_name] = SchedulerEntry(reg_name, cls, dict(kwargs_schema or {}))
        SCHEDULERS[reg_name] = cls
        cls.name = reg_name
        return cls

    return deco


def scheduler_names() -> List[str]:
    return sorted(REGISTRY)


def validate_scheduler_kwargs(
    name: str, kwargs: Mapping[str, Any], path: str = "scheduler"
) -> List[str]:
    """Validate (name, kwargs) against the registry; return error strings."""
    if name not in REGISTRY:
        return [
            f"{path}.name: unknown scheduler {name!r}; registered: {scheduler_names()}"
        ]
    schema = REGISTRY[name].kwargs_schema
    errors: List[str] = []
    for key in sorted(kwargs):
        if key not in schema:
            errors.append(
                f"{path}.kwargs.{key}: unknown kwarg for scheduler {name!r}; "
                f"allowed: {sorted(schema)}"
            )
            continue
        err = schema[key].check(f"{path}.kwargs.{key}", kwargs[key])
        if err:
            errors.append(err)
    return errors


def get_scheduler(name: str, **kwargs):
    """Instantiate a registered scheduler, validating kwargs upfront.

    Raises KeyError for an unknown name (historical contract) and TypeError
    for kwargs that fail the scheduler's schema.
    """
    if name not in REGISTRY:
        raise KeyError(f"unknown scheduler {name!r}; have {scheduler_names()}")
    errors = validate_scheduler_kwargs(name, kwargs)
    if errors:
        raise TypeError(
            f"bad kwargs for scheduler {name!r}: " + "; ".join(errors)
        )
    return REGISTRY[name].cls(**kwargs)
