"""Multi-topology scheduling (paper §6.5) and the GlobalState module (§5.1).

GlobalState holds where every task of every topology is placed plus the
cluster's remaining availability — Nimbus is stateless, so this is an
explicit, reconstructible value.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Tuple

from .assignment import Assignment
from .cluster import Cluster
from .schedulers import Scheduler
from .topology import Topology


@dataclasses.dataclass
class GlobalState:
    cluster: Cluster
    topologies: Dict[str, Topology] = dataclasses.field(default_factory=dict)
    assignments: Dict[str, Assignment] = dataclasses.field(default_factory=dict)

    def submit(self, topology: Topology, scheduler: Scheduler) -> Assignment:
        """Schedule a new topology on the *remaining* cluster resources.

        Because schedulers commit onto the live cluster, successive topologies
        see availability already decremented by earlier ones — this is exactly
        the §6.5 experiment (PageLoad then Processing on a 24-node cluster).
        """
        if topology.id in self.topologies:
            raise ValueError(f"topology {topology.id!r} already submitted")
        assignment = scheduler.schedule(topology, self.cluster, commit=False)
        return self.commit(topology, assignment)

    def commit(self, topology: Topology, assignment: Assignment) -> Assignment:
        """Atomically apply a planned assignment and record it.

        The split from :meth:`submit` lets callers (the Nimbus facade) inspect
        a dry-run plan and reject it *before* any cluster mutation.
        """
        if topology.id in self.topologies:
            raise ValueError(f"topology {topology.id!r} already submitted")
        assignment.apply(topology, self.cluster)
        self.topologies[topology.id] = topology
        self.assignments[topology.id] = assignment
        return assignment

    def kill(self, topology_id: str) -> Assignment:
        """Remove a topology and return its resources to the cluster."""
        topology = self.topologies.pop(topology_id)
        assignment = self.assignments.pop(topology_id)
        tasks = {t.id: t for t in topology.all_tasks()}
        for tid, nid in assignment.placements.items():
            node = self.cluster.nodes[nid]
            task = tasks.get(tid)
            if task is not None and task in node.assigned_tasks:
                node.unassign(task, topology.demand_of(task))
        return assignment

    def fail_node(self, node_id: str) -> List[Tuple[str, str]]:
        """Mark a node dead and return the (topology_id, task_id) pairs it
        was hosting — the rescheduler's input.  Placements are left pointing
        at the dead node until a rebalance re-places them, mirroring Storm
        (the assignment in ZooKeeper outlives the worker)."""
        if node_id not in self.cluster.nodes:
            raise KeyError(f"unknown node {node_id!r}")
        if not self.cluster.nodes[node_id].alive:
            # Rejecting the double-fail keeps orphan reports countable: a
            # second call would re-report the same still-unrebalanced pairs.
            raise ValueError(f"node {node_id!r} already failed")
        self.cluster.fail_node(node_id)
        return [
            (topo_id, tid)
            for topo_id in sorted(self.assignments)
            for tid, nid in self.assignments[topo_id].placements.items()
            if nid == node_id
        ]

    def add_nodes(self, node_specs) -> List[str]:
        """Elastic scale-up: join fresh nodes to the cluster (atomically —
        a duplicate id rejects the whole batch).  Returns the new node ids."""
        from .cluster import Node

        specs = list(node_specs)
        seen = set(self.cluster.nodes)
        for spec in specs:
            if spec.node_id in seen:
                raise ValueError(f"node {spec.node_id!r} already exists")
            seen.add(spec.node_id)
        for spec in specs:
            self.cluster.nodes[spec.node_id] = Node(spec)
            self.cluster.racks.setdefault(spec.rack_id, []).append(spec.node_id)
        return [spec.node_id for spec in specs]

    def orphaned_tasks(self) -> List[Tuple[str, str]]:
        """(topology_id, task_id) pairs whose node has died — rescheduler input.

        Pairs, not bare task ids: task ids are only unique *within* a topology
        (two topologies both have e.g. ``spout[0]`` when built without a
        topology-id prefix), so bare ids would collide across topologies.
        """
        out: List[Tuple[str, str]] = []
        for topo_id, assignment in self.assignments.items():
            for tid, nid in assignment.placements.items():
                if not self.cluster.nodes[nid].alive:
                    out.append((topo_id, tid))
        return out
