"""Steady-state throughput simulator (the quantitative reproduction vehicle).

The paper (§6.3) classifies topology performance as bounded by either network
resources or computation time.  The simulator models one scheduled topology
(or several sharing the cluster, §6.5) with the mechanisms Storm actually
exhibits:

* **Source ceiling** — a spout task's fetch/emit loop has an intrinsic max
  rate; adding machines never raises it (§6.3.2: "a topology's throughput
  will reach a ceiling at which adding more machines will not improve
  performance").
* **CPU** — work-conserving processor sharing per node: the aggregate
  Σ rate×cost on a node cannot exceed its (effective) CPU points; the strict
  per-node bound is what an over-utilized machine imposes on every component
  with a task there (the paper's Star bottleneck).
* **Bandwidth** — per-NIC egress/ingress and per-rack uplink flows scale
  linearly with λ and cannot exceed link capacity.
* **Ack credit loop** (acked topologies) — Storm's max-spout-pending keeps
  ``pending`` tuples in flight, so λ = pending / L(λ), where L is the
  flow-weighted critical-path latency: placement-dependent hop latencies
  (intra-process < inter-process < inter-node < inter-rack, §4) + queueing-
  aware service delays + a constant acker round-trip.  This is what makes the
  paper's network-bound experiments placement-sensitive.
* **Load shedding** (unanchored topologies, ``topology.acked=False``) —
  saturated tasks drop their excess share; sink throughput is the saturating
  flow through the DAG.  Memory over-subscription (only the round-robin
  baseline produces it — R-Storm treats memory as a hard constraint) thrashes
  the node (effective CPU × ``thrash_factor``), so a topology whose tasks
  concentrate on thrashed nodes collapses (§6.5 Processing near-halt) while
  one with few tasks there merely degrades (PageLoad).

All rates are tuples/second; a topology's reported throughput is the sum of
tuple rates processed at its sink components (paper: "the average throughput
of all output bolts").
"""

from __future__ import annotations

import dataclasses
import math
from typing import Dict, List, Mapping, Optional, Sequence, Tuple

import numpy as np

from ..core.assignment import Assignment
from ..core.cluster import Cluster
# One source of truth for "binding bound" semantics: the scalar simulator
# reduces its per-node usage/capacity dicts through the same array-form
# helper the batched throughput proxy vmaps over (core imports no stream
# module at import time, so this direction is cycle-free).
from ..core.search.throughput import capacity_bound
from ..core.topology import Component, Topology
from .network import EMULAB_NETWORK, NetworkModel

THRASH_FACTOR = 0.002  # effective CPU fraction for memory-thrashed nodes
NOMINAL_RATE = 1000.0  # tuples/s/task against which cpu_load is declared
ACK_OVERHEAD_S = 5e-3  # constant acker round-trip (spout→acker→spout)
TUPLE_TIMEOUT_S = 30.0  # Storm's topology.message.timeout.secs default
RHO_CAP = 0.999
_EPS = 1e-12


@dataclasses.dataclass
class SimResult:
    topology_id: str
    spout_rate: float                  # λ*, tuples/s per spout component
    sink_throughput: float             # Σ sink processed rates, tuples/s
    binding: str                       # "cpu" | "bandwidth" | "ack" | "source"
    latency_s: float                   # critical-path latency at λ*
    machines_used: int
    avg_cpu_utilization: float         # over machines hosting ≥1 task
    node_cpu_utilization: Dict[str, float]
    thrashed_nodes: List[str]
    bounds: Dict[str, float]           # each mechanism's standalone λ

    def throughput_per_10s(self) -> float:
        """Paper's y-axis unit (tuples/10sec)."""
        return self.sink_throughput * 10.0


def _cpu_cost(comp: Component) -> float:
    """CPU point-seconds per tuple processed by one task of ``comp``."""
    if comp.cpu_cost_per_tuple is not None:
        return comp.cpu_cost_per_tuple
    return comp.cpu_load / NOMINAL_RATE


def _topo_order(topology: Topology) -> List[str]:
    order: List[str] = []
    indeg = {cid: len(topology.upstream(cid)) for cid in topology.components}
    frontier = sorted(cid for cid, d in indeg.items() if d == 0)
    while frontier:
        cid = frontier.pop(0)
        order.append(cid)
        for dst in topology.downstream(cid):
            indeg[dst] -= 1
            if indeg[dst] == 0:
                frontier.append(dst)
    if len(order) != len(topology.components):
        raise ValueError(f"topology {topology.id!r} has a cycle; simulator requires a DAG")
    return order


def _component_rates(topology: Topology) -> Tuple[Dict[str, float], Dict[str, float]]:
    """Per-unit-λ input/output rates per component (lossless propagation).

    Storm semantics: every subscriber receives the full stream of its source,
    so rate_in(c) = Σ_upstream rate_out(u);  rate_out = rate_in × emit_ratio.
    """
    rate_in: Dict[str, float] = {}
    rate_out: Dict[str, float] = {}
    for cid in _topo_order(topology):
        comp = topology.components[cid]
        if comp.is_spout:
            rate_in[cid] = 0.0
            rate_out[cid] = 1.0  # unit λ per spout component
        else:
            rin = sum(rate_out[u] for u in topology.upstream(cid))
            rate_in[cid] = rin
            rate_out[cid] = rin * comp.emit_ratio
    return rate_in, rate_out


class _TopologyLoad:
    """Per-unit-λ resource usage of one scheduled topology.

    Flows are tracked per *task*: shuffle grouping splits a task's output
    uniformly over all downstream tasks; local_or_shuffle routes it only to
    colocated downstream tasks when any exist (Storm's locality grouping —
    what makes R-Storm's colocation eliminate NIC traffic entirely on an
    edge).  Per-task input rates therefore differ within a component.
    """

    def __init__(self, topology: Topology, assignment: Assignment, cluster: Cluster):
        self.topology = topology
        self.assignment = assignment
        # Effective placements: a task whose node has died contributes no
        # load and no flow (mid-scenario, between a node failure and the
        # rebalance, its tuples simply aren't being processed).
        self.placements: Dict[str, str] = {
            tid: nid
            for tid, nid in assignment.placements.items()
            if cluster.nodes[nid].alive
        }
        self.rate_in, self.rate_out = _component_rates(topology)
        self.cpu: Dict[str, float] = {}       # node -> cpu points per unit λ
        self.egress: Dict[str, float] = {}    # node -> NIC bytes/s per unit λ
        self.ingress: Dict[str, float] = {}
        self.rack_up: Dict[str, float] = {}   # rack -> uplink bytes/s per unit λ
        self.memory: Dict[str, float] = {}    # node -> MB (static)
        # task.id -> per-unit-λ processed rate (spouts: emitted rate)
        self.task_rate: Dict[str, float] = {}
        # task.id -> [(dst_task_id, fraction_of_out)] routing table
        self.routes: Dict[str, List[Tuple[str, float]]] = {}
        # component edge -> list of (src_node, dst_node, flow_per_λ)
        self.edge_flows: Dict[Tuple[str, str], List[Tuple[str, str, float]]] = {}
        self._build(cluster)

    def _processed_per_task(self, cid: str) -> float:
        """Component-average per-task rate (used for source ceilings)."""
        comp = self.topology.components[cid]
        r = self.rate_out[cid] if comp.is_spout else self.rate_in[cid]
        return r / comp.parallelism

    def _build(self, cluster: Cluster) -> None:
        topo, asg = self.topology, self.assignment

        # Routing tables per edge (placement-dependent for local_or_shuffle).
        per_edge_routes: Dict[Tuple[str, str], Dict[str, List[str]]] = {}
        for src, dst in topo.edges:
            grouping = topo.groupings.get((src, dst), "shuffle")
            dst_tasks = [
                t for t in topo.components[dst].tasks(topo.id)
                if self.placements.get(t.id) is not None
            ]
            table: Dict[str, List[str]] = {}
            for ts in topo.components[src].tasks(topo.id):
                a = self.placements.get(ts.id)
                if a is None:
                    continue
                if grouping == "local_or_shuffle":
                    local = [t for t in dst_tasks if self.placements[t.id] == a]
                    table[ts.id] = [t.id for t in (local or dst_tasks)]
                else:
                    table[ts.id] = [t.id for t in dst_tasks]
            per_edge_routes[(src, dst)] = table

        # Per-task rate propagation in topological order.
        task_in: Dict[str, float] = {}
        for cid in _topo_order(topo):
            comp = topo.components[cid]
            for t in comp.tasks(topo.id):
                if self.placements.get(t.id) is None:
                    continue
                if comp.is_spout:
                    rate = 1.0 / comp.parallelism  # unit λ split across tasks
                else:
                    rate = task_in.get(t.id, 0.0)
                self.task_rate[t.id] = rate
                out = rate * comp.emit_ratio if not comp.is_spout else rate
                for dst in topo.downstream(cid):
                    targets = per_edge_routes[(cid, dst)].get(t.id, [])
                    if not targets:
                        continue
                    share = out / len(targets)
                    self.routes.setdefault(t.id, []).extend(
                        (tid, share) for tid in targets
                    )
                    for tid in targets:
                        task_in[tid] = task_in.get(tid, 0.0) + share

        # Node resource usage + edge flows.
        for task in topo.all_tasks():
            nid = self.placements.get(task.id)
            if nid is None:
                continue
            comp = topo.component_of(task)
            rate = self.task_rate.get(task.id, 0.0)
            self.cpu[nid] = self.cpu.get(nid, 0.0) + rate * _cpu_cost(comp)
            self.memory[nid] = self.memory.get(nid, 0.0) + comp.memory_load
        for (src, dst), table in per_edge_routes.items():
            csrc = topo.components[src]
            flows = []
            for ts_id, targets in table.items():
                a = self.placements[ts_id]
                comp = topo.components[src]
                out = self.task_rate.get(ts_id, 0.0) * (
                    1.0 if comp.is_spout else comp.emit_ratio
                )
                if not targets:
                    continue
                share = out / len(targets)
                for td_id in targets:
                    b = self.placements[td_id]
                    flows.append((a, b, share))
                    if a != b:
                        byt = share * csrc.tuple_bytes
                        self.egress[a] = self.egress.get(a, 0.0) + byt
                        self.ingress[b] = self.ingress.get(b, 0.0) + byt
                        ra, rb = cluster.nodes[a].rack_id, cluster.nodes[b].rack_id
                        if ra != rb:
                            self.rack_up[ra] = self.rack_up.get(ra, 0.0) + byt
            self.edge_flows[(src, dst)] = flows

    def nodes_used(self) -> List[str]:
        return sorted(set(self.placements.values()))

    def pending(self) -> float:
        return sum(
            self.topology.max_spout_pending * c.parallelism
            for c in self.topology.spouts
        )

    def source_bound(self) -> float:
        """λ ceiling from intrinsic per-task source rates."""
        b = math.inf
        for comp in self.topology.components.values():
            if comp.max_rate_per_task is None:
                continue
            for t in comp.tasks(self.topology.id):
                per_unit = self.task_rate.get(t.id, 0.0)
                if per_unit > _EPS:
                    b = min(b, comp.max_rate_per_task / per_unit)
        return b


class Simulator:
    def __init__(
        self,
        cluster: Cluster,
        network: NetworkModel = EMULAB_NETWORK,
        thrash_factor: float = THRASH_FACTOR,
        ack_overhead_s: float = ACK_OVERHEAD_S,
        tuple_timeout_s: float = TUPLE_TIMEOUT_S,
    ):
        self.cluster = cluster
        self.network = network
        self.thrash_factor = thrash_factor
        self.ack_overhead_s = ack_overhead_s
        # The steady-state fixed point never drives latency anywhere near the
        # timeout (λ = pending/L with L in milliseconds), so the solver only
        # *carries* the knob; the DES executor is where timeouts fire and
        # replays happen.  Keeping it here means both referees read one
        # config (RunSettings.tuple_timeout_s) instead of private defaults.
        self.tuple_timeout_s = tuple_timeout_s

    # -- public API -------------------------------------------------------------
    def run(self, topology: Topology, assignment: Assignment) -> SimResult:
        return self.run_many([(topology, assignment)])[topology.id]

    def run_many(
        self,
        scheduled: Sequence[Tuple[Topology, Assignment]],
        warm_start: Optional[Mapping[str, float]] = None,
    ) -> Dict[str, SimResult]:
        """Joint simulation of topologies sharing the cluster (paper §6.5).

        Gauss–Seidel: each round, re-solve each topology's λ against capacity
        minus every *other* topology's current usage, until convergence.

        ``warm_start`` maps topology_id -> a prior spout rate λ used as the
        solver's entry point — the incremental re-entry a scenario replay
        uses after each timeline event, where the new steady state is usually
        near the previous interval's.  The fixed point reached is the same;
        only the path to it shortens.
        """
        loads = [_TopologyLoad(t, a, self.cluster) for t, a in scheduled]
        thrashed = self._thrashed_nodes(loads)
        warm = warm_start or {}
        lam = [max(float(warm.get(load.topology.id, 0.0)), 0.0) for load in loads]
        for _ in range(40):
            delta = 0.0
            for i, load in enumerate(loads):
                other = [(loads[j], lam[j]) for j in range(len(loads)) if j != i]
                new = self._solve_single(
                    load, other, thrashed, init=lam[i] if lam[i] > 0.0 else None
                )
                delta = max(delta, abs(new - lam[i]))
                lam[i] = new
            if delta < 1e-6 * max(1.0, max(lam)):
                break
        out: Dict[str, SimResult] = {}
        for i, load in enumerate(loads):
            other = [(loads[j], lam[j]) for j in range(len(loads)) if j != i]
            out[load.topology.id] = self._result(load, lam[i], other, thrashed)
        return out

    # -- shared capacity helpers ---------------------------------------------------
    def _thrashed_nodes(self, loads: Sequence[_TopologyLoad]) -> List[str]:
        mem: Dict[str, float] = {}
        for load in loads:
            for nid, mb in load.memory.items():
                mem[nid] = mem.get(nid, 0.0) + mb
        return sorted(
            nid
            for nid, mb in mem.items()
            if mb > self.cluster.nodes[nid].spec.memory_capacity_mb + 1e-9
        )

    def _eff_cpu_capacity(self, nid: str, thrashed: Sequence[str]) -> float:
        cap = self.cluster.nodes[nid].spec.cpu_capacity
        return cap * self.thrash_factor if nid in thrashed else cap

    def _residual_cpu(
        self,
        nid: str,
        load: _TopologyLoad,
        lam: float,
        other: Sequence[Tuple[_TopologyLoad, float]],
        thrashed: Sequence[str],
    ) -> float:
        cap = self._eff_cpu_capacity(nid, thrashed)
        cap -= load.cpu.get(nid, 0.0) * lam
        cap -= sum(o.cpu.get(nid, 0.0) * lo for o, lo in other)
        return cap

    def _cpu_bound(
        self,
        load: _TopologyLoad,
        other: Sequence[Tuple[_TopologyLoad, float]],
        thrashed: Sequence[str],
    ) -> float:
        """Strict work-conserving bound: Σ rate×cost per node ≤ capacity."""
        nids = sorted(load.cpu)
        use = np.array([load.cpu[n] for n in nids], dtype=np.float64)
        cap = np.array(
            [
                self._eff_cpu_capacity(n, thrashed)
                - sum(o.cpu.get(n, 0.0) * lo for o, lo in other)
                for n in nids
            ],
            dtype=np.float64,
        )
        return float(capacity_bound(use, cap))

    def _bandwidth_bound(
        self,
        load: _TopologyLoad,
        other: Sequence[Tuple[_TopologyLoad, float]],
    ) -> float:
        b = math.inf
        for direction, link_bw in (
            ("egress", self.network.nic_bw),
            ("ingress", self.network.nic_bw),
            ("rack_up", self.network.rack_uplink_bw),
        ):
            mine: Dict[str, float] = getattr(load, direction)
            ids = sorted(mine)
            use = np.array([mine[i] for i in ids], dtype=np.float64)
            cap = np.array(
                [
                    link_bw
                    - sum(getattr(o, direction).get(i, 0.0) * lo for o, lo in other)
                    for i in ids
                ],
                dtype=np.float64,
            )
            b = min(b, float(capacity_bound(use, cap)))
        return b

    # -- latency / ack loop -----------------------------------------------------------
    def _task_mu(
        self,
        load: _TopologyLoad,
        comp: Component,
        nid: str,
        lam: float,
        other: Sequence[Tuple[_TopologyLoad, float]],
        thrashed: Sequence[str],
        task_id: str = "",
    ) -> float:
        """Max service rate of one task: residual node CPU (work-conserving —
        everything the colocated tasks at the current operating point leave
        over, plus its own share) ÷ per-tuple cost, capped by the intrinsic
        per-task ceiling and one core."""
        cost = _cpu_cost(comp)
        own = load.task_rate.get(task_id, 0.0) * lam * cost if task_id else 0.0
        residual = self._residual_cpu(nid, load, lam, other, thrashed) + own
        one_core = min(self.cluster.nodes[nid].spec.cpu_capacity, 100.0)
        points = max(min(residual, one_core), 0.0)
        mu = points / cost if cost > _EPS else math.inf
        if comp.max_rate_per_task is not None:
            mu = min(mu, comp.max_rate_per_task)
        return mu

    def _latency(
        self,
        load: _TopologyLoad,
        lam: float,
        other: Sequence[Tuple[_TopologyLoad, float]],
        thrashed: Sequence[str],
    ) -> float:
        """Flow-weighted critical-path latency at spout rate ``lam``."""
        topo, net = load.topology, self.network

        def egress_util(nid: str) -> float:
            use = load.egress.get(nid, 0.0) * lam
            use += sum(o.egress.get(nid, 0.0) * lo for o, lo in other)
            return min(use / net.nic_bw, 0.999)

        # Expected per-hop latency for each component edge.
        hop: Dict[Tuple[str, str], float] = {}
        for edge, flows in load.edge_flows.items():
            src_comp = topo.components[edge[0]]
            total, acc = 0.0, 0.0
            for a, b, f in flows:
                base = net.latency(self.cluster, a, b)
                if a != b:
                    ser = src_comp.tuple_bytes / net.nic_bw
                    base += ser / max(1e-3, 1.0 - egress_util(a))
                total += f
                acc += f * base
            hop[edge] = acc / total if total > _EPS else 0.0

        # Per-component service delay: flow-weighted mean over tasks of the
        # M/M/1 sojourn (a saturated task dominates through its huge delay).
        service: Dict[str, float] = {}
        for cid, comp in topo.components.items():
            if _cpu_cost(comp) <= _EPS and comp.max_rate_per_task is None:
                service[cid] = 0.0
                continue
            acc, weight = 0.0, 0.0
            for t in comp.tasks(topo.id):
                nid = load.placements.get(t.id)
                if nid is None:
                    continue
                rate = load.task_rate.get(t.id, 0.0) * lam
                mu = self._task_mu(load, comp, nid, lam, other, thrashed, t.id)
                rho = min(rate / max(mu, _EPS), RHO_CAP)
                w = max(load.task_rate.get(t.id, 0.0), _EPS)
                acc += w * (1.0 / max(mu, _EPS)) / (1.0 - rho)
                weight += w
            service[cid] = acc / weight if weight > 0 else 0.0

        # Critical path: longest (latency) source→sink path over the DAG.
        memo: Dict[str, float] = {}

        def path_from(cid: str) -> float:
            if cid in memo:
                return memo[cid]
            best = 0.0
            for d in topo.downstream(cid):
                best = max(best, hop[(cid, d)] + service.get(d, 0.0) + path_from(d))
            memo[cid] = best
            return best

        lat = 0.0
        for sp in topo.spouts:
            lat = max(lat, service.get(sp.id, 0.0) + path_from(sp.id))
        return lat + self.ack_overhead_s

    # -- load-shedding (unanchored) propagation ---------------------------------------
    def _shedding_sink_rate(
        self,
        load: _TopologyLoad,
        lam: float,
        other: Sequence[Tuple[_TopologyLoad, float]],
        thrashed: Sequence[str],
    ) -> float:
        """Saturating flow: each task processes min(arrivals, μ); excess is
        shed.  Per-task propagation along the placement-dependent routes."""
        topo = load.topology
        comp_of_task = {
            t.id: cid for cid, c in topo.components.items() for t in c.tasks(topo.id)
        }
        task_in: Dict[str, float] = {}
        comp_done: Dict[str, float] = {}
        for cid in _topo_order(topo):
            comp = topo.components[cid]
            done_c = 0.0
            for t in comp.tasks(topo.id):
                nid = load.placements.get(t.id)
                if nid is None:
                    continue
                if comp.is_spout:
                    arrive = lam / comp.parallelism
                else:
                    arrive = task_in.get(t.id, 0.0)
                mu = self._task_mu(load, comp, nid, lam, other, thrashed, t.id)
                done = min(arrive, mu)
                done_c += done
                out = done * (1.0 if comp.is_spout else comp.emit_ratio)
                routes = load.routes.get(t.id, [])
                # Distribute proportionally to the lossless routing shares;
                # a task's routes may span several downstream components.
                per_dst: Dict[str, float] = {}
                for tid, s in routes:
                    per_dst[tid] = per_dst.get(tid, 0.0) + s
                denom = load.task_rate.get(t.id, 0.0) * (
                    1.0 if comp.is_spout else comp.emit_ratio
                )
                if denom > _EPS:
                    for tid, s in per_dst.items():
                        task_in[tid] = task_in.get(tid, 0.0) + out * (s / denom)
                elif routes:
                    # Zero-lossless-rate source (a vanishing upstream emit
                    # ratio drives task_rate below _EPS while the shed flow
                    # is still nonzero): the lossless shares carry no
                    # information, so split by raw route multiplicity
                    # instead of silently dropping the downstream flow.
                    # Broadcast semantics as in the normal branch: every
                    # downstream *component* receives the full stream, so
                    # multiplicities normalize per destination component.
                    counts: Dict[str, int] = {}
                    comp_routes: Dict[str, int] = {}
                    for tid, _ in routes:
                        counts[tid] = counts.get(tid, 0) + 1
                        dc = comp_of_task[tid]
                        comp_routes[dc] = comp_routes.get(dc, 0) + 1
                    for tid, k in counts.items():
                        task_in[tid] = task_in.get(tid, 0.0) + out * (
                            k / comp_routes[comp_of_task[tid]]
                        )
            comp_done[cid] = done_c
        return sum(comp_done[s.id] for s in topo.sinks())

    # -- solvers -------------------------------------------------------------------
    def _solve_single(
        self,
        load: _TopologyLoad,
        other: Sequence[Tuple[_TopologyLoad, float]],
        thrashed: Sequence[str],
        init: Optional[float] = None,
    ) -> float:
        source = load.source_bound()
        bw = self._bandwidth_bound(load, other)
        if not load.topology.acked:
            # Unanchored: spouts push at their ceiling, bandwidth permitting.
            lam = min(source, bw)
            if not math.isfinite(lam):
                lam = self._cpu_bound(load, other, thrashed)
            return max(lam, 0.0)
        cpu = self._cpu_bound(load, other, thrashed)
        hard = min(source, bw, cpu)
        pending = load.pending()
        if init is not None and math.isfinite(init) and init > _EPS:
            # Warm re-entry: start the ack-loop iteration at the caller's
            # prior fixed point (capped by the current hard bounds).
            lam = min(init, hard) if math.isfinite(hard) else init
            lam = max(lam, _EPS)
        else:
            lam = 1.0 if not math.isfinite(hard) else max(hard * 0.25, _EPS)
        for _ in range(80):
            lat = self._latency(load, lam, other, thrashed)
            ack = pending / lat if lat > _EPS else math.inf
            target = min(hard, ack)
            if not math.isfinite(target):
                target = lam * 2.0
            new = 0.5 * (lam + target)
            if abs(new - lam) < 1e-9 * max(1.0, lam):
                lam = new
                break
            lam = new
        return max(lam, 0.0)

    def _result(
        self,
        load: _TopologyLoad,
        lam: float,
        other: Sequence[Tuple[_TopologyLoad, float]],
        thrashed: Sequence[str],
    ) -> SimResult:
        topo = load.topology
        bounds = {
            "source": load.source_bound(),
            "bandwidth": self._bandwidth_bound(load, other),
            "cpu": self._cpu_bound(load, other, thrashed),
        }
        lat = self._latency(load, lam, other, thrashed)
        bounds["ack"] = (
            load.pending() / lat if (topo.acked and lat > _EPS) else math.inf
        )
        finite = {k: v for k, v in bounds.items() if math.isfinite(v)}
        binding = min(finite, key=lambda k: finite[k]) if finite else "source"
        # Placement-aware sink rate: per-unit-λ processed rates of the sink
        # *tasks* actually placed on live nodes (task_rate only ever contains
        # those), so a partially-orphaned topology reports the flow its
        # surviving tasks carry — and zero once nothing is placed.
        lossless = (
            sum(
                load.task_rate.get(t.id, 0.0)
                for s in topo.sinks()
                for t in s.tasks(topo.id)
            )
            * lam
        )
        if topo.acked:
            sink_tp = lossless
        else:
            sink_tp = self._shedding_sink_rate(load, lam, other, thrashed)
            # Attribution: if shedding lost >10% of the lossless flow, CPU
            # (or thrash) was the binding mechanism.
            if sink_tp < 0.9 * lossless:
                binding = "cpu"
        # CPU utilization across machines hosting ≥1 task of *this* topology
        # (paper Fig 10 averages over the machines the scheduler used).
        node_util: Dict[str, float] = {}
        for nid in load.nodes_used():
            use = load.cpu.get(nid, 0.0) * lam
            use += sum(o.cpu.get(nid, 0.0) * lo for o, lo in other)
            node_util[nid] = min(
                use / self.cluster.nodes[nid].spec.cpu_capacity, 1.0
            )
        avg_util = sum(node_util.values()) / len(node_util) if node_util else 0.0
        return SimResult(
            topology_id=topo.id,
            spout_rate=lam,
            sink_throughput=sink_tp,
            binding=binding,
            latency_s=lat,
            machines_used=len(load.nodes_used()),
            avg_cpu_utilization=avg_util,
            node_cpu_utilization=node_util,
            thrashed_nodes=list(thrashed),
            bounds=bounds,
        )


def simulate(
    topology: Topology,
    assignment: Assignment,
    cluster: Cluster,
    network: NetworkModel = EMULAB_NETWORK,
) -> SimResult:
    return Simulator(cluster, network).run(topology, assignment)


def simulate_payload(payload):
    """Payload-driven entry point: dry-run the payload through the Nimbus
    facade and simulate the resulting placement.

    Returns the SchedulingPlan with ``plan.sim`` populated; nothing is
    committed (plan-only), so this is safe to call repeatedly.
    """
    import dataclasses as _dc

    from ..api import Nimbus  # local import: api imports this module

    if not payload.settings.simulate:
        payload = _dc.replace(
            payload, settings=_dc.replace(payload.settings, simulate=True)
        )
    return Nimbus().plan(payload)
