"""Network performance model for the simulator (paper §6.1 Emulab setup).

Distance classes follow the paper's insight ladder (§4): intra-process <
inter-process < inter-node < inter-rack.  Latencies are one-way seconds;
bandwidths are bytes/second.
"""

from __future__ import annotations

import dataclasses

from ..core.cluster import Cluster


@dataclasses.dataclass(frozen=True)
class NetworkModel:
    lat_intra_process: float = 5e-6
    lat_inter_process: float = 25e-6  # same node, cross-process (loopback)
    lat_inter_node: float = 250e-6    # same rack, through ToR switch
    lat_inter_rack: float = 2e-3      # half of the paper's 4 ms RTT
    nic_bw: float = 12.5e6            # 100 Mbps, bytes/s (per direction)
    rack_uplink_bw: float = 125e6     # 1 Gbps ToR uplink, bytes/s

    def latency(self, cluster: Cluster, node_a: str, node_b: str) -> float:
        if node_a == node_b:
            return self.lat_inter_process
        a, b = cluster.nodes[node_a], cluster.nodes[node_b]
        if a.rack_id == b.rack_id:
            return self.lat_inter_node
        return self.lat_inter_rack


# The paper's evaluation network.
EMULAB_NETWORK = NetworkModel()
