"""Storm-style TopologyBuilder user API (paper §5.2).

Mirrors the Java API surface::

    builder = TopologyBuilder("word_count")
    s1 = builder.set_spout("word", parallelism=10)
    s1.set_memory_load(1024.0)
    s1.set_cpu_load(50.0)
    b1 = builder.set_bolt("count", parallelism=4, inputs=["word"])
    topo = builder.create_topology()
"""

from __future__ import annotations

from typing import Callable, Iterable, Optional, Sequence

from ..core.topology import Component, Topology


class TopologyBuilder:
    def __init__(self, topology_id: str):
        self._topology = Topology(topology_id)

    def set_spout(
        self,
        cid: str,
        fn: Optional[Callable] = None,
        parallelism: int = 1,
        *,
        emit_ratio: float = 1.0,
        tuple_bytes: float = 100.0,
        cpu_cost_per_tuple: Optional[float] = None,
        max_rate_per_task: Optional[float] = None,
    ) -> Component:
        comp = Component(
            cid,
            is_spout=True,
            parallelism=parallelism,
            fn=fn,
            emit_ratio=emit_ratio,
            tuple_bytes=tuple_bytes,
            cpu_cost_per_tuple=cpu_cost_per_tuple,
            max_rate_per_task=max_rate_per_task,
        )
        return self._topology.add_component(comp)

    def set_bolt(
        self,
        cid: str,
        fn: Optional[Callable] = None,
        parallelism: int = 1,
        *,
        inputs: Sequence[str] = (),
        emit_ratio: float = 1.0,
        tuple_bytes: float = 100.0,
        cpu_cost_per_tuple: Optional[float] = None,
        max_rate_per_task: Optional[float] = None,
        grouping: str = "shuffle",
    ) -> Component:
        comp = Component(
            cid,
            is_spout=False,
            parallelism=parallelism,
            fn=fn,
            emit_ratio=emit_ratio,
            tuple_bytes=tuple_bytes,
            cpu_cost_per_tuple=cpu_cost_per_tuple,
            max_rate_per_task=max_rate_per_task,
        )
        self._topology.add_component(comp)
        for src in inputs:
            self._topology.add_edge(src, cid, grouping=grouping)
        return comp

    def set_max_spout_pending(self, pending: int) -> "TopologyBuilder":
        self._topology.max_spout_pending = int(pending)
        return self

    def create_topology(self) -> Topology:
        self._topology.validate()
        return self._topology
