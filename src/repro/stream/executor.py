"""A real (threaded) topology executor: tasks run their component's payload
``fn`` (typically a jitted JAX op) over queues, with placement-dependent
emulated link latency.  End-to-end proof that a scheduled topology runs; the
quantitative comparisons live in the simulator (this container has one core).

Also the feeding point for the StatisticServer → StragglerMitigator loop.
"""

from __future__ import annotations

import queue
import threading
import time
from typing import Any, Callable, Dict, List, Optional, Tuple

from ..core.assignment import Assignment
from ..core.cluster import Cluster
from ..core.topology import Task, Topology
from .metrics import StatisticServer
from .network import EMULAB_NETWORK, NetworkModel

_STOP = object()


class LocalExecutor:
    """Runs every task of a scheduled topology in its own thread.

    Emulated network latency: a tuple sent between tasks placed on different
    nodes carries a not-before timestamp ``now + latency(node_a, node_b)``;
    the receiving task waits it out.  (Scaled by ``latency_scale`` so tests
    stay fast.)
    """

    def __init__(
        self,
        topology: Topology,
        assignment: Assignment,
        cluster: Cluster,
        network: NetworkModel = EMULAB_NETWORK,
        latency_scale: float = 1.0,
        queue_capacity: int = 1024,
    ):
        self.topology = topology
        self.assignment = assignment
        self.cluster = cluster
        self.network = network
        self.latency_scale = latency_scale
        self.stats = StatisticServer()
        self._queues: Dict[str, "queue.Queue"] = {
            t.id: queue.Queue(maxsize=queue_capacity) for t in topology.all_tasks()
        }
        self._threads: List[threading.Thread] = []
        self._stop = threading.Event()
        # task id -> list of downstream task ids (shuffle grouping).
        self._routes: Dict[str, List[str]] = {}
        for src, dst in topology.edges:
            dst_ids = [t.id for t in topology.components[dst].tasks(topology.id)]
            for ts in topology.components[src].tasks(topology.id):
                self._routes.setdefault(ts.id, []).extend(dst_ids)

    # -- wiring ---------------------------------------------------------------
    def _latency_between(self, task_a: str, task_b: str) -> float:
        na = self.assignment.placements.get(task_a)
        nb = self.assignment.placements.get(task_b)
        if na is None or nb is None:
            return 0.0
        return self.network.latency(self.cluster, na, nb) * self.latency_scale

    def _emit(self, src_task: str, value: Any, rr_state: Dict[str, int]) -> None:
        routes = self._routes.get(src_task, [])
        if not routes:
            return
        # Shuffle grouping ≈ round-robin across downstream tasks.
        i = rr_state.get(src_task, 0)
        dst = routes[i % len(routes)]
        rr_state[src_task] = i + 1
        not_before = time.perf_counter() + self._latency_between(src_task, dst)
        try:
            self._queues[dst].put((not_before, value), timeout=1.0)
        except queue.Full:
            pass  # drop (at-most-once path; acked mode is simulated analytically)

    def _spout_loop(self, task: Task, max_tuples: Optional[int]) -> None:
        comp = self.topology.component_of(task)
        fn: Callable = comp.fn or (lambda i: i)
        rr: Dict[str, int] = {}
        n = 0
        while not self._stop.is_set():
            if max_tuples is not None and n >= max_tuples:
                break
            t0 = time.perf_counter()
            value = fn(n)
            self.stats.record_tuple(task.id, time.perf_counter() - t0)
            self._emit(task.id, value, rr)
            n += 1
        # Flush sentinels downstream so bolts can finish.
        for dst in set(self._routes.get(task.id, [])):
            try:
                self._queues[dst].put((0.0, _STOP), timeout=1.0)
            except queue.Full:
                pass

    def _bolt_loop(self, task: Task) -> None:
        comp = self.topology.component_of(task)
        fn: Callable = comp.fn or (lambda x: x)
        q = self._queues[task.id]
        rr: Dict[str, int] = {}
        upstream_tasks = sum(
            self.topology.components[u].parallelism
            for u in self.topology.upstream(task.component_id)
        )
        stops_seen = 0
        while not self._stop.is_set():
            try:
                not_before, value = q.get(timeout=0.25)
            except queue.Empty:
                continue
            if value is _STOP:
                stops_seen += 1
                if stops_seen >= max(1, upstream_tasks):
                    break
                continue
            wait = not_before - time.perf_counter()
            if wait > 0:
                time.sleep(wait)
            t0 = time.perf_counter()
            out = fn(value)
            self.stats.record_tuple(task.id, time.perf_counter() - t0)
            if out is not None:
                self._emit(task.id, out, rr)
        for dst in set(self._routes.get(task.id, [])):
            try:
                self._queues[dst].put((0.0, _STOP), timeout=1.0)
            except queue.Full:
                pass

    # -- public API -------------------------------------------------------------
    def run(self, max_tuples_per_spout: int = 100, timeout_s: float = 60.0) -> StatisticServer:
        """Run to completion (each spout emits ``max_tuples_per_spout``)."""
        for task in self.topology.all_tasks():
            comp = self.topology.component_of(task)
            if comp.is_spout:
                th = threading.Thread(
                    target=self._spout_loop, args=(task, max_tuples_per_spout), daemon=True
                )
            else:
                th = threading.Thread(target=self._bolt_loop, args=(task,), daemon=True)
            self._threads.append(th)
        for th in self._threads:
            th.start()
        deadline = time.perf_counter() + timeout_s
        for th in self._threads:
            remain = deadline - time.perf_counter()
            th.join(timeout=max(0.0, remain))
        self._stop.set()
        return self.stats

    def stop(self) -> None:
        self._stop.set()
