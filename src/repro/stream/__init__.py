# Stream-processing substrate: Storm-like topology builder API, the network
# model, the steady-state throughput simulator (quantitative reproduction
# vehicle on a CPU-only container), and a real threaded executor.
#
# Declarative entry points (SchedulingPayload -> plan -> simulate) live in
# ``repro.api``; ``simulate_payload`` is the bridge from a pure-dict payload
# to a simulated placement.
from .api import TopologyBuilder
from .network import NetworkModel, EMULAB_NETWORK
from .simulator import SimResult, Simulator, simulate, simulate_payload
from .metrics import StatisticServer
from . import topologies
from . import des
from .des import DesConfig, DesExecutor, DesReport, run_des

__all__ = [
    "TopologyBuilder",
    "NetworkModel",
    "EMULAB_NETWORK",
    "Simulator",
    "SimResult",
    "simulate",
    "simulate_payload",
    "StatisticServer",
    "topologies",
    "des",
    "DesConfig",
    "DesExecutor",
    "DesReport",
    "run_des",
]
