# Stream-processing substrate: Storm-like topology builder API, the network
# model, the steady-state throughput simulator (quantitative reproduction
# vehicle on a CPU-only container), and a real threaded executor.
from .api import TopologyBuilder
from .network import NetworkModel, EMULAB_NETWORK
from .simulator import SimResult, Simulator, simulate
from .metrics import StatisticServer
from . import topologies

__all__ = [
    "TopologyBuilder",
    "NetworkModel",
    "EMULAB_NETWORK",
    "Simulator",
    "SimResult",
    "simulate",
    "StatisticServer",
    "topologies",
]
