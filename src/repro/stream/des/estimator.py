"""Windowed rate estimation over fixed time buckets (sfctss-style).

The DES reports *measured* sink throughput: completions counted into fixed
``bucket_s`` buckets, then averaged over the buckets fully inside the
measurement window.  The per-bucket rate series is also surfaced on the
report (``sink_rate_trace``) so transient behaviour — a bursty arrival
phase, a backpressure collapse — is visible, not just the window mean.
"""

from __future__ import annotations

import math
from typing import List


class WindowedRateEstimator:
    """Count events into fixed-width buckets; report windowed mean rates."""

    def __init__(self, duration_s: float, bucket_s: float):
        if bucket_s <= 0.0 or duration_s <= 0.0:
            raise ValueError("duration_s and bucket_s must be > 0")
        self.bucket_s = bucket_s
        self.n = max(1, int(math.ceil(duration_s / bucket_s)))
        self.counts = [0] * self.n

    def add(self, t: float) -> None:
        i = int(t / self.bucket_s)
        if i >= self.n:
            i = self.n - 1
        elif i < 0:
            i = 0
        self.counts[i] += 1

    def rate_in(self, t0: float, t1: float) -> float:
        """Mean event rate over the buckets fully contained in [t0, t1]."""
        i0 = int(math.ceil(t0 / self.bucket_s - 1e-9))
        i1 = min(int(math.floor(t1 / self.bucket_s + 1e-9)), self.n)
        if i1 <= i0:
            return 0.0
        total = 0
        for i in range(i0, i1):
            total += self.counts[i]
        return total / ((i1 - i0) * self.bucket_s)

    def rates(self) -> List[float]:
        """Per-bucket rate series (the trace the report carries)."""
        return [c / self.bucket_s for c in self.counts]
