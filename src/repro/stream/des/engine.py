"""Discrete-event, tuple-level executor — the packet-level second referee.

The steady-state solver (``stream.simulator``) computes a fixed point of a
fluid model; this module *executes* a committed placement tuple by tuple on
a binary-heap event queue and reports what it measured.  Mechanisms:

* **Event queue** — ``heapq`` of ``(time, seq, kind, payload)``; the
  monotonically increasing ``seq`` breaks time ties deterministically, so a
  fixed seed reproduces a bit-identical event trace.
* **Nodes as CPU servers** — each node is a single FIFO server delivering
  its effective CPU points/s (thrashed nodes: ``capacity × thrash_factor``,
  the same static memory rule the solver applies); a tuple of a component
  with cost ``c`` point-seconds occupies the node for ``c / points`` — the
  aggregate throughput bound Σ rate×cost ≤ capacity is therefore *exactly*
  the solver's per-node CPU bound.  Colocated tasks share the server
  round-robin (work-conserving processor sharing).
* **Network links** — a remote hop is a pipeline of FIFO byte-servers:
  egress NIC → (rack uplink when crossing racks) → propagation latency from
  the placement's rack distance (``NetworkModel.latency``) → ingress NIC.
  Local hops pay only the intra/inter-process latency.
* **Bounded queues + backpressure** — every task has a bounded input queue.
  Acked topologies use credit-based flow control: a producer reserves a
  destination slot at dispatch and freezes (its node serves other tasks)
  until a slot frees.  Unanchored topologies shed at a full queue — the
  packet-level analogue of the solver's load-shedding propagation.
* **Ack credit loop + timeout replay** — each acked spout task holds a
  sliding window of pending tuple trees; a tree completes when every copy
  along the DAG is processed (Storm's acker XOR, modelled as an outstanding
  counter), the ack returns after ``ack_overhead_s``, and a tree that is
  still open after ``tuple_timeout_s`` fails and is replayed.  Arrival
  randomness comes from one seeded Philox stream per spout task.

Spout-window convention: the solver treats all pending across every spout
component as one pool against a single λ (``pending()/L``).  The DES
mirrors that referee convention — a spout task of component ``c`` gets a
window of ``max_spout_pending × Σ parallelism / parallelism(c)`` so the two
models agree by construction on multi-spout topologies.
"""

from __future__ import annotations

import heapq
import math
from collections import deque
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from ...core.assignment import Assignment
from ...core.cluster import Cluster
from ...core.topology import Topology
from ...obs import QUEUE_DEPTH_BUCKETS, Histogram, get_hub
from ..network import EMULAB_NETWORK, NetworkModel
from ..simulator import (
    ACK_OVERHEAD_S,
    THRASH_FACTOR,
    TUPLE_TIMEOUT_S,
    _cpu_cost,
    _topo_order,
)
from .config import DesConfig
from .estimator import WindowedRateEstimator
from .report import DesReport

# Event kinds (heap payload discriminators; ints compare fast and stable).
_GEN = 0       # spout pump wake-up (rate-driven arrivals)
_NODE = 1      # node finished servicing a tuple
_LINK = 2      # link finished serializing a payload
_ADV = 3       # propagation-latency stage done, advance the route
_ACK = 4       # completed tuple tree's ack reaches its spout
_TIMEOUT = 5   # pending tuple tree expired
_SAMPLE = 6    # periodic queue-depth sample

_KIND_NAMES = ("gen", "node", "link", "adv", "ack", "timeout", "sample")


class _Root:
    """One spout tuple tree (Storm's 'root' tuple + everything anchored)."""

    __slots__ = ("spout", "t_emit", "outstanding", "state")

    def __init__(self, spout: "_Task", t_emit: float):
        self.spout = spout
        self.t_emit = t_emit
        self.outstanding = 1  # the root job itself
        self.state = 0        # 0 open, 1 acked, 2 failed (timed out)


class _Edge:
    """One outgoing component edge of one task (its routing targets)."""

    __slots__ = ("dst", "nbytes", "rr")

    def __init__(self, dst: List["_Task"], nbytes: float):
        self.dst = dst
        self.nbytes = nbytes
        self.rr = 0


class _Task:
    __slots__ = (
        "tid", "topo_i", "is_spout", "is_sink", "acked", "svc", "node",
        "queue", "qcap", "qsize", "waiters", "blocked_out", "in_ring",
        "carry", "emit_ratio", "edges",
        "sp_window", "sp_pending", "sp_next", "sp_rate", "sp_rng",
        "sp_gen_scheduled", "sp_pumping",
    )

    def __init__(self, tid: str, topo_i: int, node: "_Node", qcap: int):
        self.tid = tid
        self.topo_i = topo_i
        self.is_spout = False
        self.is_sink = False
        self.acked = False
        self.svc = 0.0
        self.node = node
        self.queue: deque = deque()
        self.qcap = qcap
        self.qsize = 0          # queued + slots reserved by in-flight tuples
        self.waiters: deque = deque()
        self.blocked_out = 0
        self.in_ring = False
        self.carry = 0.0
        self.emit_ratio = 1.0
        self.edges: List[_Edge] = []
        self.sp_window = 0.0
        self.sp_pending = 0
        self.sp_next = 0.0
        self.sp_rate: Optional[float] = None
        self.sp_rng: Optional[np.random.Generator] = None
        self.sp_gen_scheduled = False
        self.sp_pumping = False


class _Node:
    __slots__ = ("nid", "speed", "busy", "ring", "busy_time")

    def __init__(self, nid: str, speed: float):
        self.nid = nid
        self.speed = speed
        self.busy = False
        self.ring: deque = deque()
        self.busy_time = 0.0


class _Link:
    __slots__ = ("name", "rate", "busy", "fifo")

    def __init__(self, name: str, rate: float):
        self.name = name
        self.rate = rate
        self.busy = False
        self.fifo: deque = deque()


class DesExecutor:
    """Run committed placements under a stochastic tuple stream."""

    def __init__(
        self,
        cluster: Cluster,
        network: NetworkModel = EMULAB_NETWORK,
        config: Optional[DesConfig] = None,
        *,
        thrash_factor: float = THRASH_FACTOR,
        ack_overhead_s: float = ACK_OVERHEAD_S,
        tuple_timeout_s: float = TUPLE_TIMEOUT_S,
        hub=None,
    ):
        self.cluster = cluster
        self.network = network
        self.config = config or DesConfig()
        self.thrash_factor = thrash_factor
        self.ack_overhead_s = ack_overhead_s
        self.tuple_timeout_s = tuple_timeout_s
        # Explicit MetricsHub; None defers to the ambient hub at run time
        # (NULL_HUB unless an activation — e.g. RunSettings.obs — is open).
        self.hub = hub

    # -- public API -----------------------------------------------------------
    def run(self, topology: Topology, assignment: Assignment) -> DesReport:
        return self.run_many([(topology, assignment)])[topology.id]

    def run_many(
        self, scheduled: Sequence[Tuple[Topology, Assignment]]
    ) -> Dict[str, DesReport]:
        self._compile(scheduled)
        self._loop()
        return self._reports()

    # -- compilation ----------------------------------------------------------
    def _compile(self, scheduled) -> None:
        cfg = self.config
        self._scheduled = list(scheduled)
        n = len(self._scheduled)
        self._heap: List[tuple] = []
        self._seq = 0
        self.trace: List[Tuple[float, str, str]] = []

        # Static memory over-subscription → thrashed nodes (the solver rule).
        mem: Dict[str, float] = {}
        placements: List[Dict[str, str]] = []
        for topo, asg in self._scheduled:
            pl = {
                tid: nid
                for tid, nid in asg.placements.items()
                if self.cluster.nodes[nid].alive
            }
            placements.append(pl)
            for task in topo.all_tasks():
                nid = pl.get(task.id)
                if nid is not None:
                    comp = topo.component_of(task)
                    mem[nid] = mem.get(nid, 0.0) + comp.memory_load
        self.thrashed = sorted(
            nid
            for nid, mb in mem.items()
            if mb > self.cluster.nodes[nid].spec.memory_capacity_mb + 1e-9
        )
        thr = frozenset(self.thrashed)

        self._nodes: Dict[str, _Node] = {}
        for nid in sorted(self.cluster.nodes):
            node = self.cluster.nodes[nid]
            if not node.alive:
                continue
            cap = node.spec.cpu_capacity
            eff = cap * self.thrash_factor if nid in thr else cap
            self._nodes[nid] = _Node(nid, max(eff, 1e-9))
        self._egress = {
            nid: _Link(f"eg:{nid}", self.network.nic_bw)
            for nid in sorted(self._nodes)
        }
        self._ingress = {
            nid: _Link(f"in:{nid}", self.network.nic_bw)
            for nid in sorted(self._nodes)
        }
        racks = sorted(
            {self.cluster.nodes[nid].rack_id for nid in self._nodes}
        )
        self._rack_up = {
            rid: _Link(f"up:{rid}", self.network.rack_uplink_bw)
            for rid in racks
        }
        self._routes: Dict[Tuple[str, str], tuple] = {}
        # One service-time stream for the whole run (draws happen in event
        # order, which the heap makes deterministic); None in the D/D/1
        # limit so the hot path can branch once.
        self._svc_rng = (
            np.random.Generator(np.random.Philox([cfg.seed, 0x5E21CE]))
            if cfg.service == "exponential"
            else None
        )

        # Per-topology task states, in deterministic (topo order × task
        # index) order; dead/unplaced tasks carry no flow, as in the solver.
        self._tasks: List[_Task] = []
        self._topo_tasks: List[List[_Task]] = [[] for _ in range(n)]
        self._spouts: List[_Task] = []
        self._drop_mode: List[bool] = []
        self._n_spout_comps: List[int] = []
        lookup: Dict[str, _Task] = {}
        gidx = 0
        for ti, (topo, _) in enumerate(self._scheduled):
            pl = placements[ti]
            if cfg.backpressure == "auto":
                self._drop_mode.append(not topo.acked)
            else:
                self._drop_mode.append(cfg.backpressure == "drop")
            order = _topo_order(topo)
            spout_par = 0
            n_spout_comps = 0
            for cid in order:
                comp = topo.components[cid]
                if comp.is_spout:
                    spout_par += comp.parallelism
                    n_spout_comps += 1
            self._n_spout_comps.append(n_spout_comps)
            # Joint pending pool spread per spout component (see module doc).
            pool = float(topo.max_spout_pending) * spout_par
            for cid in order:
                comp = topo.components[cid]
                cost = _cpu_cost(comp)
                sink = not topo.downstream(cid)
                for task in comp.tasks(topo.id):
                    nid = pl.get(task.id)
                    if nid is None:
                        continue
                    nd = self._nodes[nid]
                    st = _Task(task.id, ti, nd, cfg.queue_capacity)
                    st.is_spout = comp.is_spout
                    st.is_sink = sink
                    st.acked = topo.acked
                    st.emit_ratio = comp.emit_ratio
                    st.svc = cost / nd.speed
                    if not comp.is_spout and comp.max_rate_per_task is not None:
                        # Intrinsic per-task ceiling on a bolt: the service
                        # time cannot beat 1/max_rate no matter the node.
                        st.svc = max(st.svc, 1.0 / comp.max_rate_per_task)
                    if comp.is_spout:
                        st.sp_window = pool / comp.parallelism
                        st.sp_rate = comp.max_rate_per_task
                        if st.sp_rate is None and not topo.acked:
                            st.sp_rate = cfg.open_loop_rate
                        st.sp_rng = np.random.Generator(
                            np.random.Philox([cfg.seed, ti, gidx])
                        )
                        self._spouts.append(st)
                    lookup[task.id] = st
                    self._tasks.append(st)
                    self._topo_tasks[ti].append(st)
                    gidx += 1
            # Routing targets per source task (local_or_shuffle mirrors the
            # solver: colocated destinations when any exist, else all).
            for cid in order:
                comp = topo.components[cid]
                for task in comp.tasks(topo.id):
                    st = lookup.get(task.id)
                    if st is None:
                        continue
                    for dst_cid in topo.downstream(cid):
                        grouping = topo.groupings.get((cid, dst_cid), "shuffle")
                        dsts = [
                            lookup[t.id]
                            for t in topo.components[dst_cid].tasks(topo.id)
                            if t.id in lookup
                        ]
                        if not dsts:
                            continue
                        if grouping == "local_or_shuffle":
                            local = [d for d in dsts if d.node is st.node]
                            dsts = local or dsts
                        st.edges.append(_Edge(dsts, comp.tuple_bytes))

        # Per-topology counters & traces.
        self._emitted = [0] * n
        self._emitted_meas = [0] * n
        self._acked = [0] * n
        self._failed = [0] * n
        self._replayed = [0] * n
        self._open_roots = [0] * n
        self._created = [0] * n
        self._processed = [0] * n
        self._dropped = [0] * n
        # Latency and queue-depth samples live in obs histograms whether or
        # not a hub is active: DesReport percentiles and exported telemetry
        # come from the same objects — one percentile code path (pinned
        # equal by test), and ``observe`` is a bare append on the hot path.
        self._hist_lat = [Histogram() for _ in range(n)]
        self._sink_est = [
            WindowedRateEstimator(cfg.duration_s, cfg.bucket_s)
            for _ in range(n)
        ]
        self._hist_qd = [Histogram(QUEUE_DEPTH_BUCKETS) for _ in range(n)]
        self._qd_max = [0] * n
        self.events_processed = 0
        self._t_end = cfg.duration_s
        # Align the measurement window start to a bucket boundary so the
        # windowed estimator and the exact counters cover the same span.
        warm = cfg.duration_s * cfg.warmup_frac
        self._warm = math.ceil(warm / cfg.bucket_s - 1e-9) * cfg.bucket_s
        # Observability wiring: the enabled flag is a plain bool consulted
        # once per sample tick and at report time — never per event — so a
        # disabled (or absent) hub costs nothing in the event loop.
        hub = self.hub if self.hub is not None else get_hub()
        self._hub = hub
        self._obs = hub.enabled
        if self._obs:
            for ti, (topo, _) in enumerate(self._scheduled):
                hub.attach("des.latency_s", self._hist_lat[ti], topology=topo.id)
                hub.attach("des.queue_depth", self._hist_qd[ti], topology=topo.id)

    # -- event loop -----------------------------------------------------------
    def _push(self, t: float, kind: int, payload) -> None:
        heapq.heappush(self._heap, (t, self._seq, kind, payload))
        self._seq += 1

    def _loop(self) -> None:
        cfg = self.config
        for st in self._spouts:
            self._pump(st, 0.0)
        if cfg.bucket_s <= self._t_end:
            self._push(cfg.bucket_s, _SAMPLE, None)
        heap = self._heap
        while heap and heap[0][0] <= self._t_end:
            t, _, kind, payload = heapq.heappop(heap)
            self.events_processed += 1
            if kind == _NODE:
                self._on_node_done(t, payload)
            elif kind == _LINK:
                self._on_link_done(t, payload)
            elif kind == _ADV:
                self._advance(t, *payload)
            elif kind == _ACK:
                self._on_ack(t, payload)
            elif kind == _GEN:
                payload.sp_gen_scheduled = False
                self._pump(payload, t)
            elif kind == _TIMEOUT:
                self._on_timeout(t, payload)
            else:
                self._on_sample(t)
            if cfg.trace_events:
                self.trace.append((t, _KIND_NAMES[kind], self._label(kind, payload)))

    @staticmethod
    def _label(kind: int, payload) -> str:
        if kind == _NODE:
            return payload[1].tid
        if kind == _LINK:
            return payload[0].name
        if kind == _ADV:
            return payload[3].tid
        if kind in (_ACK, _TIMEOUT):
            return payload.spout.tid
        if kind == _GEN:
            return payload.tid
        return ""

    # -- spout generation -----------------------------------------------------
    def _pump(self, st: _Task, t: float) -> None:
        if st.sp_pumping:
            return  # a _generate side effect re-entered (queue drained)
        st.sp_pumping = True
        try:
            while True:
                if st.sp_rate is not None and st.sp_next > t + 1e-15:
                    if st.sp_next <= self._t_end and not st.sp_gen_scheduled:
                        st.sp_gen_scheduled = True
                        self._push(st.sp_next, _GEN, st)
                    return
                if st.acked and st.sp_pending >= st.sp_window:
                    return  # resumed by the next ack/timeout
                if st.qsize >= st.qcap:
                    return  # resumed when the spout's own queue drains
                if st.sp_rate is not None:
                    # The rate is a ceiling, not a schedule: no burst of
                    # catch-up emissions after a blocked stretch.  Advance
                    # *before* generating — the enqueue's side effects can
                    # consult the pump state.
                    st.sp_next = self._next_emit(st, max(st.sp_next, t))
                self._generate(st, t)
        finally:
            st.sp_pumping = False

    def _next_emit(self, st: _Task, base: float) -> float:
        cfg = self.config
        rate = st.sp_rate
        if cfg.arrival == "uniform":
            return base + 1.0 / rate
        if cfg.arrival == "poisson":
            return base + float(st.sp_rng.exponential(1.0 / rate))
        # bursty: on/off with unchanged mean rate.
        period = cfg.burst_period_s
        on_len = period / cfg.burst_factor
        nxt = base + 1.0 / (rate * cfg.burst_factor)
        if nxt % period > on_len:
            nxt = (math.floor(nxt / period) + 1.0) * period
        return nxt

    def _generate(self, st: _Task, t: float) -> None:
        ti = st.topo_i
        root = _Root(st, t)
        self._emitted[ti] += 1
        if t >= self._warm:
            self._emitted_meas[ti] += 1
        self._created[ti] += 1
        if st.acked:
            st.sp_pending += 1
            self._open_roots[ti] += 1
            to = self.tuple_timeout_s
            if to is not None and t + to <= self._t_end:
                self._push(t + to, _TIMEOUT, root)
        st.qsize += 1
        st.queue.append(root)
        self._make_eligible(st)
        self._node_kick(st.node, t)

    # -- node scheduling ------------------------------------------------------
    def _make_eligible(self, st: _Task) -> None:
        if not st.in_ring and st.blocked_out == 0 and st.queue:
            st.node.ring.append(st)
            st.in_ring = True

    def _node_kick(self, nd: _Node, t: float) -> None:
        if nd.busy:
            return
        ring = nd.ring
        while ring:
            st = ring.popleft()
            st.in_ring = False
            if st.blocked_out or not st.queue:
                continue  # frozen or drained since enqueued; drop lazily
            root = st.queue.popleft()
            self._dequeued(st, t)
            if st.queue and st.blocked_out == 0:
                ring.append(st)
                st.in_ring = True
            svc = st.svc
            if self._svc_rng is not None and svc > 0.0:
                svc = float(self._svc_rng.exponential(svc))
            nd.busy = True
            nd.busy_time += min(svc, max(self._t_end - t, 0.0))
            self._push(t + svc, _NODE, (nd, st, root))
            return

    def _dequeued(self, st: _Task, t: float) -> None:
        """A slot freed in ``st``'s input queue: grant the oldest credit
        waiter, and wake a window/queue-blocked spout pump."""
        st.qsize -= 1
        if st.waiters:
            src, root, nbytes, route = st.waiters.popleft()
            st.qsize += 1
            self._advance(t, route, 0, root, st, nbytes)
            src.blocked_out -= 1
            if src.blocked_out == 0:
                self._make_eligible(src)
                self._node_kick(src.node, t)
        if st.is_spout:
            self._pump(st, t)

    def _on_node_done(self, t: float, payload) -> None:
        nd, st, root = payload
        nd.busy = False
        ti = st.topo_i
        self._processed[ti] += 1
        if st.is_spout:
            n_emit = 1
        else:
            st.carry += st.emit_ratio
            n_emit = int(st.carry)
            st.carry -= n_emit
        children = 0
        if st.edges:
            for _ in range(n_emit):
                for edge in st.edges:
                    self._dispatch(st, edge, root, t)
                    children += 1
        if st.is_sink:
            self._sink_est[ti].add(t)
            if not st.acked and t >= self._warm:
                self._hist_lat[ti].observe(t - root.t_emit)
        if st.acked:
            root.outstanding += children - 1
            if root.outstanding == 0 and root.state == 0:
                root.state = 1
                self._push(t + self.ack_overhead_s, _ACK, root)
        self._node_kick(nd, t)

    # -- tuple transport ------------------------------------------------------
    def _route(self, a: str, b: str) -> tuple:
        r = self._routes.get((a, b))
        if r is None:
            if a == b:
                r = ((1, self.network.lat_inter_process),)
            else:
                na, nb = self.cluster.nodes[a], self.cluster.nodes[b]
                stages = [(0, self._egress[a])]
                if na.rack_id != nb.rack_id:
                    stages.append((0, self._rack_up[na.rack_id]))
                stages.append((1, self.network.latency(self.cluster, a, b)))
                stages.append((0, self._ingress[b]))
                r = tuple(stages)
            self._routes[(a, b)] = r
        return r

    def _dispatch(self, st: _Task, edge: _Edge, root: _Root, t: float) -> None:
        dsts = edge.dst
        if len(dsts) == 1:
            dst = dsts[0]
        else:
            dst = dsts[edge.rr % len(dsts)]
            edge.rr += 1
        self._created[st.topo_i] += 1
        route = self._route(st.node.nid, dst.node.nid)
        if self._drop_mode[st.topo_i]:
            self._advance(t, route, 0, root, dst, edge.nbytes)
            return
        if dst.qsize >= dst.qcap:
            dst.waiters.append((st, root, edge.nbytes, route))
            st.blocked_out += 1
            return
        dst.qsize += 1
        self._advance(t, route, 0, root, dst, edge.nbytes)

    def _advance(self, t, route, i, root, dst: _Task, nbytes) -> None:
        if i >= len(route):
            self._enqueue(t, root, dst)
            return
        is_lat, v = route[i]
        if is_lat:
            self._push(t + v, _ADV, (route, i + 1, root, dst, nbytes))
        else:
            self._link_push(v, (route, i + 1, root, dst, nbytes), t)

    def _link_push(self, link: _Link, payload, t: float) -> None:
        link.fifo.append(payload)
        if not link.busy:
            self._link_start(link, t)

    def _link_start(self, link: _Link, t: float) -> None:
        payload = link.fifo.popleft()
        ser = payload[4] / link.rate
        if self._svc_rng is not None and ser > 0.0:
            ser = float(self._svc_rng.exponential(ser))
        link.busy = True
        self._push(t + ser, _LINK, (link, payload))

    def _on_link_done(self, t: float, payload) -> None:
        link, inner = payload
        link.busy = False
        if link.fifo:
            self._link_start(link, t)
        self._advance(t, *inner)

    def _enqueue(self, t: float, root: _Root, dst: _Task) -> None:
        if self._drop_mode[dst.topo_i]:
            if dst.qsize >= dst.qcap:
                self._dropped[dst.topo_i] += 1
                return
            dst.qsize += 1
        dst.queue.append(root)
        self._make_eligible(dst)
        self._node_kick(dst.node, t)

    # -- ack loop -------------------------------------------------------------
    def _on_ack(self, t: float, root: _Root) -> None:
        st = root.spout
        ti = st.topo_i
        self._acked[ti] += 1
        self._open_roots[ti] -= 1
        st.sp_pending -= 1
        if t >= self._warm:
            self._hist_lat[ti].observe(t - root.t_emit)
        self._pump(st, t)

    def _on_timeout(self, t: float, root: _Root) -> None:
        if root.state != 0:
            return  # acked (or ack in flight) before the timer fired
        root.state = 2
        st = root.spout
        ti = st.topo_i
        self._failed[ti] += 1
        self._replayed[ti] += 1
        self._open_roots[ti] -= 1
        st.sp_pending -= 1
        # The freed window slot re-enters the spout loop: the replacement
        # emission *is* the replay (Storm re-emits failed roots through the
        # same nextTuple path, subject to the same rate ceiling).
        self._pump(st, t)

    # -- sampling & reports ---------------------------------------------------
    def _on_sample(self, t: float) -> None:
        for ti, tasks in enumerate(self._topo_tasks):
            total = 0
            mx = self._qd_max[ti]
            for st in tasks:
                q = len(st.queue)
                total += q
                if q > mx:
                    mx = q
            self._hist_qd[ti].observe(total)
            self._qd_max[ti] = mx
        if self._obs:
            self._sample_obs(t)
        nxt = t + self.config.bucket_s
        if nxt <= self._t_end:
            self._push(nxt, _SAMPLE, None)

    def _sample_obs(self, t: float) -> None:
        """Hub-enabled per-sample time series, all on sim-time: per-task
        queue depth, cumulative drop/replay/ack counters, and running
        per-node utilization (busy so far / sim-time so far)."""
        hub = self._hub
        for ti, (topo, _) in enumerate(self._scheduled):
            tid = topo.id
            for st in self._topo_tasks[ti]:
                hub.series(
                    "des.task_queue_depth", topology=tid, task=st.tid
                ).append(t, len(st.queue))
            hub.series("des.dropped", topology=tid).append(t, self._dropped[ti])
            hub.series("des.replayed", topology=tid).append(t, self._replayed[ti])
            hub.series("des.acked", topology=tid).append(t, self._acked[ti])
        for nid in sorted(self._nodes):
            nd = self._nodes[nid]
            hub.series("des.node_utilization", node=nid).append(
                t, min(nd.busy_time / t, 1.0) if t > 0.0 else 0.0
            )

    def _walk_in_flight(self) -> List[int]:
        """Independent tuple census at drain (the conservation referee):
        queued + credit-blocked + in link FIFOs + in service / in propagation
        (the latter live only as pending heap events)."""
        n = len(self._scheduled)
        walked = [0] * n
        for st in self._tasks:
            walked[st.topo_i] += len(st.queue) + len(st.waiters)
        for group in (self._egress, self._ingress, self._rack_up):
            for key in sorted(group):
                for payload in group[key].fifo:
                    walked[payload[3].topo_i] += 1
        for _, _, kind, payload in self._heap:
            if kind == _NODE:
                walked[payload[1].topo_i] += 1
            elif kind == _LINK:
                walked[payload[1][3].topo_i] += 1
            elif kind == _ADV:
                walked[payload[3].topo_i] += 1
        return walked

    def _reports(self) -> Dict[str, DesReport]:
        cfg = self.config
        meas = max(self._t_end - self._warm, 1e-12)
        walked = self._walk_in_flight()
        out: Dict[str, DesReport] = {}
        for ti, (topo, _) in enumerate(self._scheduled):
            hist = self._hist_lat[ti]
            p50, p95, p99 = hist.percentiles()
            mean_lat = hist.mean()
            qd50, qd95, qd99 = self._hist_qd[ti].percentiles()
            used = sorted({st.node.nid for st in self._topo_tasks[ti]})
            node_util = {
                nid: min(self._nodes[nid].busy_time / self._t_end, 1.0)
                for nid in used
            }
            avg_util = (
                math.fsum(node_util.values()) / len(node_util)
                if node_util
                else 0.0
            )
            n_sp = max(self._n_spout_comps[ti], 1)
            out[topo.id] = DesReport(
                topology_id=topo.id,
                spout_rate=self._emitted_meas[ti] / (meas * n_sp),
                sink_throughput=self._sink_est[ti].rate_in(
                    self._warm, self._t_end
                ),
                binding="measured",
                latency_s=mean_lat,
                p50_latency_s=p50,
                p95_latency_s=p95,
                p99_latency_s=p99,
                machines_used=len(used),
                avg_cpu_utilization=avg_util,
                node_cpu_utilization=node_util,
                thrashed_nodes=list(self.thrashed),
                emitted=self._emitted[ti],
                acked=self._acked[ti],
                failed=self._failed[ti],
                replayed=self._replayed[ti],
                roots_in_flight=self._open_roots[ti],
                tuples_created=self._created[ti],
                tuples_processed=self._processed[ti],
                tuples_dropped=self._dropped[ti],
                tuples_in_flight=walked[ti],
                queue_depth_max=self._qd_max[ti],
                queue_depth_trace=list(self._hist_qd[ti].values),
                sink_rate_trace=self._sink_est[ti].rates(),
                sim_time_s=self._t_end,
                warmup_s=self._warm,
                events_processed=self.events_processed,
                p50_queue_depth=qd50,
                p95_queue_depth=qd95,
                p99_queue_depth=qd99,
            )
            if self._obs:
                self._publish_report_obs(topo.id, out[topo.id])
        return out

    def _publish_report_obs(self, tid: str, rep: DesReport) -> None:
        """End-of-run totals into the hub (counters/gauges + sink-rate
        series on bucket sim-time), complementing the attached histograms."""
        hub = self._hub
        hub.counter("des.emitted", topology=tid).inc(rep.emitted)
        hub.counter("des.acked", topology=tid).inc(rep.acked)
        hub.counter("des.failed", topology=tid).inc(rep.failed)
        hub.counter("des.replayed", topology=tid).inc(rep.replayed)
        hub.counter("des.dropped", topology=tid).inc(rep.tuples_dropped)
        hub.gauge("des.sink_throughput", topology=tid).set(rep.sink_throughput)
        hub.gauge("des.spout_rate", topology=tid).set(rep.spout_rate)
        hub.gauge("des.events_processed").set(self.events_processed)
        for nid in sorted(rep.node_cpu_utilization):
            hub.gauge("des.node_utilization", node=nid).set(
                rep.node_cpu_utilization[nid]
            )
        sr = hub.series("des.sink_rate", topology=tid)
        for i, rate in enumerate(rep.sink_rate_trace):
            sr.append(i * self.config.bucket_s, rate)


def run_des(
    topology: Topology,
    assignment: Assignment,
    cluster: Cluster,
    network: NetworkModel = EMULAB_NETWORK,
    config: Optional[DesConfig] = None,
    **knobs,
) -> DesReport:
    """One-shot convenience mirroring ``stream.simulate``."""
    return DesExecutor(cluster, network, config, **knobs).run(
        topology, assignment
    )
