"""Discrete-event tuple-level executor — the packet-level second referee.

See ``engine`` for the event model, ``config`` for the knobs, ``report``
for what a run measures.
"""

from .config import ARRIVALS, BACKPRESSURE, SERVICE, DesConfig
from .engine import DesExecutor, run_des
from .estimator import WindowedRateEstimator
from .report import DesReport

__all__ = [
    "ARRIVALS",
    "BACKPRESSURE",
    "SERVICE",
    "DesConfig",
    "DesExecutor",
    "DesReport",
    "WindowedRateEstimator",
    "run_des",
]
