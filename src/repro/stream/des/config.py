"""Run configuration for the discrete-event tuple-level executor.

``DesConfig`` is the engine-side knob bundle (pure data, no imports from the
control plane); the serialized/validated counterpart is
``repro.api.specs.DesSettings``, which converts into this via
``DesSettings.to_config()``.
"""

from __future__ import annotations

import dataclasses

#: Arrival processes a spout stream can follow.  "uniform" is a deterministic
#: metronome (matches the solver's fluid assumption most closely), "poisson"
#: draws exponential gaps from the per-spout Philox stream, "bursty" is an
#: on/off process with the *same mean rate* — during 1/burst_factor of every
#: period the spout emits at burst_factor × rate, then goes silent.  Bursty
#: is the scenario class the steady-state solver cannot represent: identical
#: mean load, transient queue growth.
ARRIVALS = ("uniform", "poisson", "bursty")

#: Backpressure semantics for bounded input queues.  "credit": a producer
#: reserves a destination slot before dispatching and freezes when none is
#: available (Storm 1.x+ credit-style flow control — what acked topologies
#: get).  "drop": tuples that arrive at a full queue are shed (unanchored
#: topologies — mirrors the solver's load-shedding propagation).  "auto"
#: picks per topology: credit when ``topology.acked``, drop otherwise.
BACKPRESSURE = ("auto", "credit", "drop")

#: Per-tuple service-time model.  "exponential" draws each node/link service
#: from an exponential with the declared mean (``cpu_cost_per_tuple`` and
#: the byte serialization time are *means*; the fixed-point solver's M/M/1
#: sojourns and ``ser/(1-util)`` hop inflation assume exactly this), so the
#: cross-validation compares the solver against its own traffic assumptions.
#: "deterministic" uses the means verbatim — the D/D/1 limit, useful for
#: exact closed-form agreement on single chains.
SERVICE = ("exponential", "deterministic")


@dataclasses.dataclass(frozen=True)
class DesConfig:
    """Knobs of one DES run (everything else comes from the placement)."""

    #: Simulated wall-clock horizon, seconds.
    duration_s: float = 0.5
    #: Leading fraction of the horizon excluded from every measurement
    #: (throughput, latency percentiles) while queues and the ack window
    #: fill to steady state.
    warmup_frac: float = 0.3
    #: Bounded input-queue capacity per task, tuples.
    queue_capacity: int = 128
    #: Philox root seed; each spout task derives its own independent stream
    #: from (seed, topology index, task index).
    seed: int = 0
    #: Arrival process, one of ``ARRIVALS``.
    arrival: str = "uniform"
    #: Bursty arrivals: rate multiplier during the on-phase (duty cycle is
    #: 1/burst_factor so the mean rate is unchanged).
    burst_factor: float = 8.0
    #: Bursty arrivals: on/off period, seconds.
    burst_period_s: float = 0.25
    #: Windowed rate-estimator bucket width, seconds (also the queue-depth
    #: sampling interval).
    bucket_s: float = 0.05
    #: Emission rate (tuples/s per spout task) for unanchored spouts with no
    #: intrinsic ``max_rate_per_task`` — an open-loop source has to push at
    #: *some* finite rate for a packet-level run to terminate.
    open_loop_rate: float = 5000.0
    #: Queue overflow semantics, one of ``BACKPRESSURE``.
    backpressure: str = "auto"
    #: Service-time model, one of ``SERVICE``.
    service: str = "exponential"
    #: Record every processed event as a (time, kind, label) triple —
    #: the bit-identical-trace determinism contract is asserted on this.
    trace_events: bool = False

    def __post_init__(self):
        if self.duration_s <= 0.0:
            raise ValueError(f"duration_s must be > 0, got {self.duration_s!r}")
        if not 0.0 <= self.warmup_frac < 1.0:
            raise ValueError(
                f"warmup_frac must be in [0, 1), got {self.warmup_frac!r}"
            )
        if self.queue_capacity < 1:
            raise ValueError(
                f"queue_capacity must be >= 1, got {self.queue_capacity!r}"
            )
        if self.arrival not in ARRIVALS:
            raise ValueError(
                f"arrival must be one of {ARRIVALS}, got {self.arrival!r}"
            )
        if self.backpressure not in BACKPRESSURE:
            raise ValueError(
                f"backpressure must be one of {BACKPRESSURE}, "
                f"got {self.backpressure!r}"
            )
        if self.service not in SERVICE:
            raise ValueError(
                f"service must be one of {SERVICE}, got {self.service!r}"
            )
        if self.burst_factor < 1.0:
            raise ValueError(
                f"burst_factor must be >= 1, got {self.burst_factor!r}"
            )
        for name in ("burst_period_s", "bucket_s", "open_loop_rate"):
            if getattr(self, name) <= 0.0:
                raise ValueError(
                    f"{name} must be > 0, got {getattr(self, name)!r}"
                )
