"""Measured per-topology results of one DES run.

``DesReport`` is attribute-compatible with the solver's ``SimResult`` where
the two overlap (``sink_throughput``, ``spout_rate``, ``latency_s``,
``machines_used``, ``avg_cpu_utilization``, ``node_cpu_utilization``,
``thrashed_nodes``, ``binding``) so ``ScenarioRunner`` and
``SchedulingPlan`` consume either engine's output through one code path —
and it adds what only a packet-level run can measure: latency percentiles,
queue-depth traces, and the tuple-conservation ledger.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional


@dataclasses.dataclass
class DesReport:
    topology_id: str
    #: Measured emission rate, tuples/s per spout component (the solver's
    #: λ* unit, so the cross-validation suite compares like with like).
    spout_rate: float
    #: Windowed-estimator sink rate over the measurement window, tuples/s.
    sink_throughput: float
    #: Always "measured" — a DES run observes, it does not attribute a
    #: single binding mechanism the way the fixed-point solver does.
    binding: str
    #: Mean end-to-end tuple latency (emit → full ack for acked topologies,
    #: emit → sink processing for unanchored ones), seconds.
    latency_s: float
    p50_latency_s: Optional[float]
    p95_latency_s: Optional[float]
    p99_latency_s: Optional[float]
    machines_used: int
    avg_cpu_utilization: float
    node_cpu_utilization: Dict[str, float]
    thrashed_nodes: List[str]
    # -- root (spout-tuple) ledger: emitted == acked + failed + in-flight --
    emitted: int
    acked: int
    failed: int          # ack-timeout expirations (each triggers a replay)
    replayed: int
    roots_in_flight: int
    # -- tuple ledger (every copy along the DAG) ---------------------------
    tuples_created: int
    tuples_processed: int
    tuples_dropped: int
    tuples_in_flight: int  # independently walked at drain, not derived
    # -- traces ------------------------------------------------------------
    queue_depth_max: int
    queue_depth_trace: List[int]     # Σ queued tuples, sampled per bucket
    sink_rate_trace: List[float]     # per-bucket sink rates
    sim_time_s: float
    warmup_s: float
    events_processed: int
    # -- queue-depth distribution (per-bucket Σ-queued samples) ------------
    # Extracted from the same ``repro.obs`` Histogram the telemetry export
    # renders, so report and JSONL percentiles share one code path.
    p50_queue_depth: Optional[float] = None
    p95_queue_depth: Optional[float] = None
    p99_queue_depth: Optional[float] = None

    def throughput_per_10s(self) -> float:
        """Paper's y-axis unit (tuples/10sec)."""
        return self.sink_throughput * 10.0

    def to_dict(self) -> Dict[str, object]:
        return dataclasses.asdict(self)
