"""The paper's evaluation topologies.

Micro-benchmarks (Fig 7): Linear, Diamond, Star — each in a *network-bound*
variant ("very little processing at each component", §6.3.1) and a
*computation-time-bound* variant ("significant amount of arbitrary
processing", §6.3.2).

Production topologies (Fig 11, "Modeled After Typical Industry Topologies"):
Yahoo PageLoad and Processing — event-level advertising pipelines for
near-real-time analytical reporting (§6.4); unanchored (at-most-once) as is
typical for high-volume analytics, so they push at source speed and shed load
at saturated tasks.

Resource demands follow the paper's user API (setMemoryLoad / setCPULoad);
per-tuple costs, tuple sizes and source ceilings parameterize the simulator.
"""

from __future__ import annotations

from ..core.topology import Topology
from .api import TopologyBuilder

# -- micro-benchmarks (Fig 7) --------------------------------------------------

# Network-bound settings (§6.3.1: "very little processing at each component"):
# negligible per-tuple CPU, mid-size tuples, and a finite ack window so the
# placement-dependent credit-loop latency is what limits throughput.
_NET = dict(cpu_cost_per_tuple=2e-4, tuple_bytes=128.0)
_NET_PENDING = 64

# CPU-bound settings (§6.3.2): sources have an intrinsic per-task emit ceiling
# (the reason adding machines stops helping) and bolts do real work per tuple.
_CPU_PENDING = 4096
_CPU_SOURCE_RATE = 500.0  # tuples/s per spout task


def linear(network_bound: bool = True, parallelism: int = 4) -> Topology:
    """Fig 7a: spout -> b1 -> b2 -> b3."""
    kind = "net" if network_bound else "cpu"
    b = TopologyBuilder(f"linear_{kind}")
    b.set_max_spout_pending(_NET_PENDING if network_bound else _CPU_PENDING)
    if network_bound:
        b.set_spout("spout", parallelism=parallelism, **_NET).set_memory_load(
            512.0
        ).set_cpu_load(10.0)
        prev = "spout"
        for i in range(1, 4):
            cid = f"bolt{i}"
            comp = b.set_bolt(cid, parallelism=parallelism, inputs=[prev], **_NET)
            comp.set_memory_load(512.0).set_cpu_load(10.0)
            prev = cid
    else:
        b.set_spout(
            "spout",
            parallelism=parallelism,
            cpu_cost_per_tuple=0.01,
            tuple_bytes=64.0,
            max_rate_per_task=_CPU_SOURCE_RATE,
        ).set_memory_load(640.0).set_cpu_load(10.0)
        prev = "spout"
        for i in range(1, 4):
            cid = f"bolt{i}"
            comp = b.set_bolt(
                cid,
                parallelism=parallelism,
                inputs=[prev],
                cpu_cost_per_tuple=0.04,
                tuple_bytes=64.0,
            )
            comp.set_memory_load(640.0).set_cpu_load(25.0)
            prev = cid
    return b.create_topology()


def diamond(network_bound: bool = True, parallelism: int = 4) -> Topology:
    """Fig 7b: spout fans out to mid1..mid3, which join into one sink bolt."""
    kind = "net" if network_bound else "cpu"
    b = TopologyBuilder(f"diamond_{kind}")
    b.set_max_spout_pending(_NET_PENDING if network_bound else _CPU_PENDING)
    if network_bound:
        b.set_spout("spout", parallelism=parallelism, **_NET).set_memory_load(
            400.0
        ).set_cpu_load(10.0)
        mids = []
        for i in range(1, 4):
            cid = f"mid{i}"
            b.set_bolt(cid, parallelism=parallelism, inputs=["spout"], **_NET).set_memory_load(
                400.0
            ).set_cpu_load(10.0)
            mids.append(cid)
        b.set_bolt("sink", parallelism=parallelism, inputs=mids, **_NET).set_memory_load(
            400.0
        ).set_cpu_load(10.0)
    else:
        b.set_spout(
            "spout",
            parallelism=parallelism,
            cpu_cost_per_tuple=0.01,
            tuple_bytes=64.0,
            max_rate_per_task=_CPU_SOURCE_RATE,
        ).set_memory_load(600.0).set_cpu_load(10.0)
        mids = []
        for i in range(1, 4):
            cid = f"mid{i}"
            b.set_bolt(
                cid,
                parallelism=parallelism,
                inputs=["spout"],
                cpu_cost_per_tuple=0.03,
                tuple_bytes=64.0,
            ).set_memory_load(600.0).set_cpu_load(18.0)
            mids.append(cid)
        b.set_bolt(
            "sink",
            parallelism=parallelism,
            inputs=mids,
            cpu_cost_per_tuple=0.012,
            tuple_bytes=64.0,
        ).set_memory_load(600.0).set_cpu_load(22.0)
    return b.create_topology()


def star(network_bound: bool = True, parallelism: int = 4) -> Topology:
    """Fig 7c: two spouts feed a central bolt which fans out to two sinks.

    The centre is deliberately heavy — §6.3.2 observes default Storm
    over-utilizes one machine here ("creates a bottleneck that throttles the
    overall throughput of the Star topology").
    """
    kind = "net" if network_bound else "cpu"
    b = TopologyBuilder(f"star_{kind}")
    b.set_max_spout_pending(_NET_PENDING if network_bound else _CPU_PENDING)
    if network_bound:
        net = dict(_NET, tuple_bytes=64.0)  # fan-in/out doubles flows; keep NICs off the floor
        for i in (1, 2):
            b.set_spout(f"spout{i}", parallelism=parallelism, **net).set_memory_load(
                384.0
            ).set_cpu_load(10.0)
        b.set_bolt(
            "centre", parallelism=parallelism, inputs=["spout1", "spout2"], **net
        ).set_memory_load(512.0).set_cpu_load(15.0)
        for i in (1, 2):
            b.set_bolt(
                f"out{i}", parallelism=parallelism, inputs=["centre"], **net
            ).set_memory_load(384.0).set_cpu_load(10.0)
    else:
        # More tasks than machines: default Storm inevitably stacks two heavy
        # centre tasks on one node — the paper's bottleneck machine (§6.3.2).
        parallelism = max(parallelism, 6)
        for i in (1, 2):
            b.set_spout(
                f"spout{i}",
                parallelism=parallelism,
                cpu_cost_per_tuple=0.01,
                tuple_bytes=64.0,
                max_rate_per_task=_CPU_SOURCE_RATE,
            ).set_memory_load(400.0).set_cpu_load(6.0)
        # Heavy centre: each task needs most of a core at the source rate.
        b.set_bolt(
            "centre",
            parallelism=parallelism,
            inputs=["spout1", "spout2"],
            cpu_cost_per_tuple=0.085,
            tuple_bytes=64.0,
        ).set_memory_load(500.0).set_cpu_load(85.0)
        for i in (1, 2):
            b.set_bolt(
                f"out{i}",
                parallelism=parallelism,
                inputs=["centre"],
                cpu_cost_per_tuple=0.005,
                tuple_bytes=64.0,
            ).set_memory_load(400.0).set_cpu_load(6.0)
    return b.create_topology()


# -- Yahoo production topologies (Fig 11) ---------------------------------------


def pageload(parallelism: int = 3) -> Topology:
    """Fig 11a — PageLoad: event-level page-load records from the ad platform,
    deserialized, filtered, geo/session-enriched, aggregated, persisted.
    Unanchored analytics pipeline: big tuples make it placement/bandwidth
    sensitive."""
    b = TopologyBuilder("pageload")
    b.set_max_spout_pending(10)
    t = b.set_spout(
        "kafka_spout",
        parallelism=parallelism,
        cpu_cost_per_tuple=0.004,
        tuple_bytes=6000.0,
        max_rate_per_task=1600.0,
    )
    t.set_memory_load(400.0).set_cpu_load(20.0)
    chain = [
        # (id, emit_ratio, cpu_cost, tuple_bytes, mem, cpu_load)
        ("deserialize", 1.0, 0.010, 5500.0, 400.0, 25.0),
        ("filter", 0.7, 0.006, 5500.0, 300.0, 15.0),
        ("geo_enrich", 1.0, 0.015, 6500.0, 500.0, 30.0),
        ("session_join", 1.0, 0.020, 6500.0, 500.0, 35.0),
        ("aggregate", 0.4, 0.012, 2500.0, 400.0, 25.0),
        ("persist", 1.0, 0.008, 2500.0, 300.0, 15.0),
    ]
    prev = "kafka_spout"
    for cid, ratio, cost, nbytes, mem, load in chain:
        comp = b.set_bolt(
            cid,
            parallelism=parallelism,
            inputs=[prev],
            emit_ratio=ratio,
            cpu_cost_per_tuple=cost,
            tuple_bytes=nbytes,
            grouping="local_or_shuffle",
        )
        comp.set_memory_load(mem).set_cpu_load(load)
        prev = cid
    return b.create_topology()  # acked: near-real-time reporting pipeline


def processing(parallelism: int = 2) -> Topology:
    """Fig 11b — Processing: heavier event-processing pipeline (rules engine +
    dedupe over large in-memory state + rollup), memory-hungry by design —
    two of its tasks on one 2 GB node over-subscribe memory."""
    b = TopologyBuilder("processing")
    b.set_spout(
        "event_spout",
        parallelism=parallelism,
        cpu_cost_per_tuple=0.005,
        tuple_bytes=10000.0,
        max_rate_per_task=1800.0,
    ).set_memory_load(800.0).set_cpu_load(20.0)
    b.set_bolt(
        "parse",
        parallelism=parallelism,
        inputs=["event_spout"],
        cpu_cost_per_tuple=0.012,
        tuple_bytes=4000.0,
        grouping="local_or_shuffle",
    ).set_memory_load(1050.0).set_cpu_load(30.0)
    b.set_bolt(
        "rules_engine",
        parallelism=parallelism,
        inputs=["parse"],
        cpu_cost_per_tuple=0.030,
        tuple_bytes=3800.0,
        grouping="local_or_shuffle",
    ).set_memory_load(1300.0).set_cpu_load(45.0)
    b.set_bolt(
        "dedupe",
        parallelism=parallelism,
        inputs=["rules_engine"],
        cpu_cost_per_tuple=0.015,
        tuple_bytes=3800.0,
        emit_ratio=0.8,
        grouping="local_or_shuffle",
    ).set_memory_load(1300.0).set_cpu_load(35.0)
    b.set_bolt(
        "rollup",
        parallelism=parallelism,
        inputs=["dedupe"],
        cpu_cost_per_tuple=0.012,
        tuple_bytes=1500.0,
        emit_ratio=0.5,
        grouping="local_or_shuffle",
    ).set_memory_load(1050.0).set_cpu_load(25.0)
    b.set_bolt(
        "db_writer",
        parallelism=parallelism,
        inputs=["rollup"],
        cpu_cost_per_tuple=0.008,
        tuple_bytes=1500.0,
        grouping="local_or_shuffle",
    ).set_memory_load(800.0).set_cpu_load(15.0)
    topo = b.create_topology()
    topo.acked = False
    return topo


ALL_MICRO = {
    "linear": linear,
    "diamond": diamond,
    "star": star,
}

ALL_YAHOO = {
    "pageload": pageload,
    "processing": processing,
}

ALL = {**ALL_MICRO, **ALL_YAHOO}


def make(name: str, **kwargs) -> Topology:
    """Build a named evaluation topology (scenario-table style)."""
    if name not in ALL:
        raise KeyError(f"unknown topology {name!r}; have {sorted(ALL)}")
    return ALL[name](**kwargs)


def spec(name: str, **kwargs):
    """The declarative (TopologySpec) form of a named evaluation topology —
    the bridge from this module's builder-made catalog to payload-as-data."""
    from ..api.specs import TopologySpec  # local import: api imports core only

    return TopologySpec.from_topology(make(name, **kwargs))
