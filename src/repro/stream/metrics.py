"""StatisticServer (paper §5.1): throughput on a task, component, and topology
level, plus EWMA service times feeding the straggler mitigator.

Consolidated onto the ``repro.obs`` registry: tuple counts and service-time
EWMAs live in a private always-on ``MetricsHub`` (counter ``stream.tuples``
and gauge ``stream.service_ewma_s``, both labeled by task), so the threaded
executor's live statistics and the deterministic telemetry plane share one
metric vocabulary and export path.  Wall-clock throughput windows go through
``obs.clock`` — the tree's single sanctioned wall-clock shim — because a
threaded executor measures real elapsed time by design.
"""

from __future__ import annotations

import collections
import threading
from typing import Dict, Optional

from ..obs import MetricsHub
from ..obs import clock as obs_clock


class StatisticServer:
    def __init__(self, ewma_alpha: float = 0.2):
        self._lock = threading.Lock()
        self._alpha = ewma_alpha
        #: Always-on private hub; ``hub.records()``/``hub.export()`` expose
        #: the live counters in the same JSONL form the rest of the tree emits.
        self.hub = MetricsHub()
        self._t0 = obs_clock.perf_counter()

    # -- recording ---------------------------------------------------------------
    def record_tuple(self, task_id: str, service_time_s: Optional[float] = None) -> None:
        with self._lock:
            self.hub.counter("stream.tuples", task=task_id).inc()
            if service_time_s is not None:
                ewma = self.hub.gauge("stream.service_ewma_s", task=task_id)
                prev = ewma.value
                if prev is None:
                    ewma.set(service_time_s)
                else:
                    ewma.set(self._alpha * service_time_s + (1 - self._alpha) * prev)

    # -- queries -------------------------------------------------------------------
    def task_counts(self) -> Dict[str, int]:
        with self._lock:
            return {
                labels["task"]: metric.value
                for labels, metric in self.hub.find("counter", "stream.tuples")
            }

    def component_counts(self) -> Dict[str, int]:
        out: Dict[str, int] = collections.defaultdict(int)
        for tid, n in self.task_counts().items():
            out[tid.split("[")[0]] += n
        return dict(out)

    def topology_count(self, topology_id: str) -> int:
        prefix = f"{topology_id}/"
        return sum(n for t, n in self.task_counts().items() if t.startswith(prefix))

    def service_times(self) -> Dict[str, float]:
        with self._lock:
            return {
                labels["task"]: metric.value
                for labels, metric in self.hub.find("gauge", "stream.service_ewma_s")
                if metric.value is not None
            }

    def throughput(self, task_prefix: str = "") -> float:
        """Tuples/s since start for tasks matching the prefix."""
        dt = max(obs_clock.perf_counter() - self._t0, 1e-9)
        return (
            sum(n for t, n in self.task_counts().items() if t.startswith(task_prefix))
            / dt
        )

    def reset(self) -> None:
        with self._lock:
            self.hub = MetricsHub()
            self._t0 = obs_clock.perf_counter()
