"""StatisticServer (paper §5.1): throughput on a task, component, and topology
level, plus EWMA service times feeding the straggler mitigator."""

from __future__ import annotations

import collections
import threading
import time
from typing import Dict, Optional


class StatisticServer:
    def __init__(self, ewma_alpha: float = 0.2):
        self._lock = threading.Lock()
        self._counts: Dict[str, int] = collections.defaultdict(int)
        self._service_ewma: Dict[str, float] = {}
        self._alpha = ewma_alpha
        self._t0 = time.perf_counter()

    # -- recording ---------------------------------------------------------------
    def record_tuple(self, task_id: str, service_time_s: Optional[float] = None) -> None:
        with self._lock:
            self._counts[task_id] += 1
            if service_time_s is not None:
                prev = self._service_ewma.get(task_id)
                if prev is None:
                    self._service_ewma[task_id] = service_time_s
                else:
                    self._service_ewma[task_id] = (
                        self._alpha * service_time_s + (1 - self._alpha) * prev
                    )

    # -- queries -------------------------------------------------------------------
    def task_counts(self) -> Dict[str, int]:
        with self._lock:
            return dict(self._counts)

    def component_counts(self) -> Dict[str, int]:
        out: Dict[str, int] = collections.defaultdict(int)
        for tid, n in self.task_counts().items():
            out[tid.split("[")[0]] += n
        return dict(out)

    def topology_count(self, topology_id: str) -> int:
        prefix = f"{topology_id}/"
        return sum(n for t, n in self.task_counts().items() if t.startswith(prefix))

    def service_times(self) -> Dict[str, float]:
        with self._lock:
            return dict(self._service_ewma)

    def throughput(self, task_prefix: str = "") -> float:
        """Tuples/s since start for tasks matching the prefix."""
        dt = max(time.perf_counter() - self._t0, 1e-9)
        return (
            sum(n for t, n in self.task_counts().items() if t.startswith(task_prefix))
            / dt
        )

    def reset(self) -> None:
        with self._lock:
            self._counts.clear()
            self._service_ewma.clear()
            self._t0 = time.perf_counter()
