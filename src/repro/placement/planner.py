"""Resource-aware placement planner (DESIGN.md §2.2).

The paper's algorithm, translated to mesh placement:
  * hard constraint  = per-device HBM (Alg 4's `H_θ > H_τ` filter): a plan
    that does not fit is never emitted; the planner escalates sharding
    (TP → TP+ZeRO) until the hard constraint holds or raises;
  * soft constraints = compute balance and collective traffic: encoded in
    the preference order of sharding rules (keep heavy collectives on the
    near axes, push only DP/ZeRO traffic across the far 'pod' axis);
  * quadratic/colocation term = expert placement: experts that exchange the
    most traffic with their tokens are packed pod-locally by literally
    running the paper's scheduler (``plan_expert_placement``).
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Optional, Tuple

import jax
import numpy as np
from jax.sharding import PartitionSpec as P

from ..configs.base import ModelConfig, ShapeCell
from ..core import Cluster, Component, NodeSpec, RStormScheduler, Topology
from . import sharding_rules as rules

if True:  # typing-only import kept lazy to avoid models<->placement cycle
    from typing import TYPE_CHECKING
    if TYPE_CHECKING:  # pragma: no cover
        from ..models.lm import Model
from .hardware import ChipSpec, V5E
from .memory_model import (
    MemoryEstimate,
    estimate_decode,
    estimate_prefill,
    estimate_train,
)
from .sharding_rules import MeshShape


@dataclasses.dataclass
class Plan:
    arch: str
    shape: str
    mesh: MeshShape
    fsdp: bool
    param_specs: Any                      # pytree of PartitionSpec
    batch_specs: Optional[Any]            # train/prefill inputs
    cache_specs: Optional[Any]            # decode cache
    activation_rules: Dict[str, P]
    memory: MemoryEstimate
    notes: List[str]
    n_micro: int = 1                      # gradient-accumulation microbatches


class InfeasiblePlanError(RuntimeError):
    """No sharding satisfies the HBM hard constraint (paper: a task whose
    hard constraints no node can satisfy stays unassigned — here we refuse
    the launch instead of OOMing at runtime)."""


class ResourceAwarePlanner:
    def __init__(self, chip: ChipSpec = V5E):
        self.chip = chip

    # -- parameter sharding -----------------------------------------------------------
    def _param_specs(self, model: "Model", mesh: MeshShape, fsdp: bool):
        cfg = model.cfg
        axes_tree = model.param_axes()
        shapes_tree = jax.eval_shape(lambda: model.init_params(jax.random.PRNGKey(0)))

        def walk(axes_node, shape_node):
            if isinstance(axes_node, dict):
                return {k: walk(axes_node[k], shape_node[k]) for k in axes_node}
            return rules.param_partition_spec(
                cfg, axes_node, tuple(shape_node.shape), mesh, fsdp
            )

        return walk(axes_tree, shapes_tree), shapes_tree

    def _activation_rules(self, cfg: ModelConfig, mesh: MeshShape) -> Dict[str, P]:
        da = mesh.data_axes
        if rules.dp_only() and mesh.n_devices <= 256:
            da = da + ("model",)
        batch = da if len(da) > 1 else (da[0] if da else None)
        model_ok = cfg.vocab % mesh.size("model") == 0 and not rules.dp_only()
        out = {
            "residual": P(batch, None, None),
            "logits": P(batch, None, "model" if model_ok else None),
        }
        if cfg.n_experts:
            # MoE dispatch buffer (E, C, D): experts over 'model' when
            # divisible, else capacity over the data axes (token-parallel).
            if cfg.n_experts % mesh.size("model") == 0:
                out["moe_buffer"] = P("model", None, None)
                out["moe_buffer_grouped"] = P(batch, "model", None, None)
            else:
                out["moe_buffer"] = P(None, batch, None)
                out["moe_buffer_grouped"] = P(batch, None, None, None)
            # (§Perf MoE iter 2, REFUTED: resharding ye to fully-token-
            # sharded rows made GSPMD replicate upstream tensors — no
            # moe_ye_rows rule is installed, the constraint is a no-op.)
            import os as _os
            if _os.environ.get("REPRO_OPT_MOE_NOEP", "0") == "1":
                # §Perf MoE iter 3: keep the dispatch buffer token-sharded
                # only; the expert GEMM then gathers the (small) expert
                # weights over the model axis instead of the (huge) buffer.
                out["moe_buffer_grouped"] = P(batch, None, None, None)
            if _os.environ.get("REPRO_OPT_MOE_LOCAL", "0") == "1":
                # §Perf MoE iter 4: staged shardings around scatter/gather.
                out["moe_buffer_local"] = P(batch, None, None, None)
                out["moe_ye_local"] = P(batch, None, None)
        return out

    # -- public API -------------------------------------------------------------------
    def plan(self, model: "Model", shape: ShapeCell, mesh: MeshShape) -> Plan:
        cfg = model.cfg
        notes: List[str] = []
        if shape.kind == "prefill":
            return self._plan_prefill(model, shape, mesh)
        if shape.kind == "train":
            # Escalation ladder (hard-constraint-driven, Alg 4 style):
            # TP → TP+ZeRO → TP+ZeRO+grad-accum microbatching.
            ladder = [(False, 1)] + [(True, m) for m in (1, 2, 4, 8, 16)]
            est = None
            for fsdp, n_micro in ladder:
                if n_micro > shape.global_batch:
                    break
                specs, shapes = self._param_specs(model, mesh, fsdp)
                est = estimate_train(
                    cfg, shape, shapes, specs, mesh, self.chip, n_micro=n_micro
                )
                if est.fits:
                    if fsdp:
                        notes.append("escalated to TP+ZeRO (params+opt over data axes)")
                    if n_micro > 1:
                        notes.append(f"gradient accumulation x{n_micro}")
                    return Plan(
                        cfg.arch, shape.name, mesh, fsdp, specs,
                        self._batch_specs(cfg, shape, mesh), None,
                        self._activation_rules(cfg, mesh), est, notes,
                        n_micro=n_micro,
                    )
            raise InfeasiblePlanError(
                f"{cfg.arch}/{shape.name}: {est.total/2**30:.1f} GiB/device > "
                f"{est.hbm_usable/2**30:.1f} GiB even with TP+ZeRO+accum"
            )
        # decode
        specs, shapes = self._param_specs(model, mesh, False)
        cache_shapes = jax.eval_shape(
            lambda: model.init_cache(shape.global_batch, shape.seq_len)
        )

        def leaf_spec(path, leaf):
            name = str(path[-1].key) if hasattr(path[-1], "key") else ""
            grouped = any(
                getattr(p, "key", "") == "groups" for p in path
            )
            return rules.cache_partition_spec(
                cfg, name, tuple(leaf.shape), mesh, grouped
            )

        cache_specs = jax.tree_util.tree_map_with_path(leaf_spec, cache_shapes)
        est = estimate_decode(
            cfg, shape, shapes, specs, cache_shapes, cache_specs, mesh, self.chip
        )
        if not est.fits:
            # escalate: ZeRO-style param sharding also in decode
            specs, shapes = self._param_specs(model, mesh, True)
            est = estimate_decode(
                cfg, shape, shapes, specs, cache_shapes, cache_specs, mesh, self.chip
            )
            notes.append("decode params sharded over data axes (weight-gathered)")
            if not est.fits:
                raise InfeasiblePlanError(
                    f"{cfg.arch}/{shape.name}: decode needs {est.total/2**30:.1f} GiB/device"
                )
        return Plan(
            cfg.arch, shape.name, mesh, False, specs, None, cache_specs,
            self._activation_rules(cfg, mesh), est, notes,
        )

    def _plan_prefill(self, model: "Model", shape: ShapeCell, mesh: MeshShape) -> Plan:
        cfg = model.cfg
        notes: List[str] = ["serving weights bf16"]
        cache_shapes = jax.eval_shape(
            lambda: model.init_cache(shape.global_batch, shape.seq_len)
        )

        def leaf_spec(path, leaf):
            name = str(path[-1].key) if hasattr(path[-1], "key") else ""
            grouped = any(getattr(p, "key", "") == "groups" for p in path)
            return rules.cache_partition_spec(cfg, name, tuple(leaf.shape), mesh, grouped)

        cache_specs = jax.tree_util.tree_map_with_path(leaf_spec, cache_shapes)
        est = None
        for fsdp in (False, True):
            specs, shapes = self._param_specs(model, mesh, fsdp)
            est = estimate_prefill(
                cfg, shape, shapes, specs, cache_shapes, cache_specs, mesh, self.chip
            )
            if est.fits:
                if fsdp:
                    notes.append("prefill weights sharded over data axes too")
                return Plan(
                    cfg.arch, shape.name, mesh, fsdp, specs,
                    self._batch_specs(cfg, shape, mesh), cache_specs,
                    self._activation_rules(cfg, mesh), est, notes,
                )
        raise InfeasiblePlanError(
            f"{cfg.arch}/{shape.name}: prefill needs {est.total/2**30:.1f} GiB/device"
        )

    def _batch_specs(self, cfg: ModelConfig, shape: ShapeCell, mesh: MeshShape):
        B = shape.global_batch
        specs = {
            "tokens": rules.batch_spec(mesh, 2, batch_size=B),
            "labels": rules.batch_spec(mesh, 2, batch_size=B),
        }
        if cfg.vision_prefix:
            specs["patches"] = rules.batch_spec(mesh, 3, batch_size=B)
        if cfg.enc_dec:
            specs["frames"] = rules.batch_spec(mesh, 3, batch_size=B)
        if shape.kind == "prefill":
            specs.pop("labels")
        return specs


# =====================================================================================
# Expert placement — direct reuse of the paper's scheduler (QM3DKP heuristic)
# =====================================================================================
def plan_expert_placement(
    cfg: ModelConfig,
    mesh: MeshShape,
    expert_load: Optional[np.ndarray] = None,
    expert_bytes_mb: Optional[float] = None,
) -> Dict[str, Any]:
    """Place experts onto (pod × model-slice) device groups with R-Storm.

    Topology: router → expert_i → combiner; cluster: one node per
    (pod, model-slice) with HBM capacity; pods are racks (inter-pod DCN is
    the far hop).  Hot experts (``expert_load``, tokens/expert histogram)
    carry proportional CPU demand, so the paper's soft-constraint machinery
    balances them across pods while the hard memory constraint prevents
    oversubscribing any device group.

    Returns {"assignment": expert->group, "per_group": counts,
    "max_load_share": float, "topology", "cluster"}.
    """
    E = cfg.n_experts
    if E == 0:
        raise ValueError(f"{cfg.arch} has no experts")
    n_pods = mesh.size("pod") if "pod" in mesh.axes else 1
    n_groups = mesh.size("model")
    if expert_load is None:
        expert_load = np.ones((E,), np.float64)
    load = expert_load / expert_load.sum()

    if expert_bytes_mb is None:
        expert_bytes_mb = 3 * cfg.d_model * cfg.d_ff * 4 / 1e6  # fp32 swiglu expert

    t = Topology("expert-placement")
    t.add_component(Component("router", is_spout=True, parallelism=1)).set_memory_load(
        1.0
    ).set_cpu_load(1.0)
    for e in range(E):
        c = Component(f"expert{e}", parallelism=1)
        c.set_memory_load(expert_bytes_mb)
        c.set_cpu_load(100.0 * float(load[e]) * n_pods * n_groups)
        t.add_component(c)
        t.add_edge("router", f"expert{e}")
    t.add_component(Component("combine", parallelism=1)).set_memory_load(1.0).set_cpu_load(1.0)
    for e in range(E):
        t.add_edge(f"expert{e}", "combine")

    # One "node" per (pod, model-slice); capacity = HBM share for experts.
    hbm_mb = V5E.hbm_usable / 1e6 * 0.5  # half of HBM budget for expert weights
    specs = [
        NodeSpec(
            node_id=f"p{p}g{g}",
            rack_id=f"pod{p}",
            cpu_capacity=100.0,
            memory_capacity_mb=hbm_mb,
        )
        for p in range(n_pods)
        for g in range(n_groups)
    ]
    cluster = Cluster(specs)
    assignment = RStormScheduler().schedule(t, cluster, commit=True)
    expert_to_group = {}
    per_group: Dict[str, int] = {}
    group_load: Dict[str, float] = {}
    for e in range(E):
        nid = assignment.placements.get(f"expert-placement/expert{e}[0]")
        expert_to_group[e] = nid
        if nid is not None:
            per_group[nid] = per_group.get(nid, 0) + 1
            group_load[nid] = group_load.get(nid, 0.0) + float(load[e])
    return {
        "assignment": expert_to_group,
        "per_group": per_group,
        "max_load_share": max(group_load.values()) if group_load else 0.0,
        "unassigned": list(assignment.unassigned),
        "topology": t,
        "cluster": cluster,
    }


def round_robin_expert_placement(cfg: ModelConfig, mesh: MeshShape, expert_load=None):
    """Naive baseline: expert e -> group e % n_groups (what a non-resource-
    aware EP sharding does)."""
    E = cfg.n_experts
    n_pods = mesh.size("pod") if "pod" in mesh.axes else 1
    n_groups = mesh.size("model")
    if expert_load is None:
        expert_load = np.ones((E,), np.float64)
    load = expert_load / expert_load.sum()
    groups = [f"p{i % n_pods}g{(i // n_pods) % n_groups}" for i in range(E)]
    group_load: Dict[str, float] = {}
    for e, g in enumerate(groups):
        group_load[g] = group_load.get(g, 0.0) + float(load[e])
    return {
        "assignment": {e: groups[e] for e in range(E)},
        "max_load_share": max(group_load.values()),
    }
