from .constraints import activation_rules, maybe_constrain
from .hardware import V5E, ChipSpec
from .memory_model import MemoryEstimate, estimate_decode, estimate_prefill, estimate_train
from .planner import (
    InfeasiblePlanError,
    Plan,
    ResourceAwarePlanner,
    plan_expert_placement,
    round_robin_expert_placement,
)
from .sharding_rules import MeshShape

__all__ = [
    "activation_rules",
    "maybe_constrain",
    "V5E",
    "ChipSpec",
    "MemoryEstimate",
    "estimate_train",
    "estimate_prefill",
    "estimate_decode",
    "InfeasiblePlanError",
    "Plan",
    "ResourceAwarePlanner",
    "plan_expert_placement",
    "round_robin_expert_placement",
    "MeshShape",
]
