"""Target-hardware constants (TPU v5e, per the assignment)."""

from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class ChipSpec:
    name: str = "tpu-v5e"
    peak_flops_bf16: float = 197e12        # FLOP/s per chip
    hbm_bytes: float = 16 * 1024**3        # 16 GiB
    hbm_bw: float = 819e9                  # bytes/s
    ici_bw_per_link: float = 50e9          # bytes/s per link
    ici_links: int = 4
    # Usable fraction of HBM after runtime/framework reservations.
    hbm_usable_fraction: float = 0.90

    @property
    def hbm_usable(self) -> float:
        return self.hbm_bytes * self.hbm_usable_fraction


V5E = ChipSpec()
