"""Per-device HBM accounting — the planner's *hard constraint* (paper: memory
must never be over-subscribed; an OOM on a TPU is as catastrophic as the
paper's swap-thrash).  The authoritative check is the dry-run compile's
``memory_analysis()``; this analytic model drives the planner's escalation
(TP → TP+ZeRO) before compiling."""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, Tuple

import jax
import numpy as np
from jax.sharding import PartitionSpec

from ..configs.base import ModelConfig, ShapeCell
from .hardware import ChipSpec, V5E
from .sharding_rules import MeshShape


def _shards_of(spec: PartitionSpec, mesh: MeshShape) -> int:
    n = 1
    for entry in spec:
        if entry is None:
            continue
        axes = entry if isinstance(entry, tuple) else (entry,)
        for a in axes:
            n *= mesh.size(a)
    return n


def bytes_per_device(shapes_tree, specs_tree, mesh: MeshShape) -> float:
    """Σ leaf bytes / shards, for a pytree of ShapeDtypeStructs + specs."""
    leaves, _ = jax.tree_util.tree_flatten(shapes_tree)
    spec_leaves, _ = jax.tree_util.tree_flatten(
        specs_tree, is_leaf=lambda x: isinstance(x, PartitionSpec)
    )
    assert len(leaves) == len(spec_leaves), (len(leaves), len(spec_leaves))
    total = 0.0
    for leaf, spec in zip(leaves, spec_leaves):
        size = float(np.prod(leaf.shape)) * leaf.dtype.itemsize
        total += size / _shards_of(spec, mesh)
    return total


@dataclasses.dataclass
class MemoryEstimate:
    params: float
    opt_state: float
    grads: float
    activations: float
    cache: float
    total: float
    hbm_usable: float

    @property
    def fits(self) -> bool:
        return self.total <= self.hbm_usable

    def as_dict(self) -> Dict[str, float]:
        return dataclasses.asdict(self)


CE_CHUNK = 512        # models.lm.Model.CE_CHUNK
SDPA_BLOCK_Q = 512    # models.attention.SDPA_BLOCK_Q


def estimate_train(
    cfg: ModelConfig,
    shape: ShapeCell,
    param_shapes,
    param_specs,
    mesh: MeshShape,
    chip: ChipSpec = V5E,
    n_micro: int = 1,
) -> MemoryEstimate:
    p_bytes = bytes_per_device(param_shapes, param_specs, mesh)
    opt_bytes = 2.0 * p_bytes              # adam m, v (same sharding)
    grad_bytes = p_bytes                   # accumulator (param sharding)
    dp = 1
    for a in mesh.data_axes:
        dp *= mesh.size(a)
    B, S = shape.global_batch, shape.seq_len
    Bm = max(B // n_micro, 1)
    D, V, H = cfg.d_model, cfg.vocab, cfg.n_heads
    L = cfg.n_layers
    bdev = max(Bm / dp, 1.0)               # per-device microbatch rows
    # Per-layer checkpointed residual carries (bf16) under full remat.
    act = 2.0 * L * bdev * S * D * 2
    # Chunked-CE logits transient: (Bm, CE_CHUNK, V) fp32 ×2 (value+grad).
    act += bdev * CE_CHUNK * (V / max(mesh.size("model"), 1)) * 4 * 2
    # Blocked-attention score transient: (Bm, H, BLOCK_Q, S) fp32.
    act += bdev * H * SDPA_BLOCK_Q * min(S, 64 * 1024) * 4
    if cfg.n_experts:
        T = Bm * S
        C = max(8, int(T * cfg.top_k * cfg.capacity_factor / cfg.n_experts))
        if cfg.n_experts % mesh.size("model") == 0:
            moe_shards = mesh.size("model") * dp
        else:
            moe_shards = dp
        act += 2.0 * cfg.n_experts * C * D * 2 / moe_shards
    total = p_bytes + opt_bytes + grad_bytes + act
    return MemoryEstimate(p_bytes, opt_bytes, grad_bytes, act, 0.0, total, chip.hbm_usable)


def estimate_prefill(
    cfg: ModelConfig,
    shape: ShapeCell,
    param_shapes,
    param_specs,
    cache_shapes,
    cache_specs,
    mesh: MeshShape,
    chip: ChipSpec = V5E,
) -> MemoryEstimate:
    """Prefill is inference: bf16 weights, no grads/opt, no checkpointed
    carries — the dominant terms are the emitted KV cache and the blocked-
    attention transient."""
    p_bytes = 0.5 * bytes_per_device(param_shapes, param_specs, mesh)
    c_bytes = bytes_per_device(cache_shapes, cache_specs, mesh)
    dp = 1
    for a in mesh.data_axes:
        dp *= mesh.size(a)
    B, S = shape.global_batch, shape.seq_len
    bdev = max(B / dp, 1.0)
    D, H = cfg.d_model, cfg.n_heads
    act = 6.0 * bdev * S * D * 2                                   # residual streams
    act += bdev * H * SDPA_BLOCK_Q * min(S, 64 * 1024) * 4          # attn scores block
    if cfg.n_experts:
        T = bdev * S
        C = max(8, int(T * cfg.top_k * cfg.capacity_factor / cfg.n_experts))
        act += 2.0 * cfg.n_experts * C * D * 2
    total = p_bytes + c_bytes + act
    return MemoryEstimate(p_bytes, 0.0, 0.0, act, c_bytes, total, chip.hbm_usable)


def estimate_decode(
    cfg: ModelConfig,
    shape: ShapeCell,
    param_shapes,
    param_specs,
    cache_shapes,
    cache_specs,
    mesh: MeshShape,
    chip: ChipSpec = V5E,
) -> MemoryEstimate:
    # Serving weights are bf16 (checkpoint loaded at half the fp32 size).
    p_bytes = 0.5 * bytes_per_device(param_shapes, param_specs, mesh)
    c_bytes = bytes_per_device(cache_shapes, cache_specs, mesh)
    act = 1e9  # decode transient allowance
    total = p_bytes + c_bytes + act
    return MemoryEstimate(p_bytes, 0.0, 0.0, act, c_bytes, total, chip.hbm_usable)
