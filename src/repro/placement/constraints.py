"""Named activation-sharding constraints (MaxText-style logical rules).

The planner installs a rule table; model code marks key intermediates with
``maybe_constrain(name, x)``.  Outside a planned context the call is a no-op,
so smoke tests and single-device runs are unaffected.
"""

from __future__ import annotations

import contextlib
import threading
from typing import Dict, Optional

import jax
from jax.sharding import PartitionSpec

_STATE = threading.local()


def current_rules() -> Optional[Dict[str, PartitionSpec]]:
    return getattr(_STATE, "rules", None)


@contextlib.contextmanager
def activation_rules(rules: Dict[str, PartitionSpec]):
    prev = current_rules()
    _STATE.rules = dict(rules)
    try:
        yield
    finally:
        _STATE.rules = prev


def maybe_constrain(name: str, x: jax.Array) -> jax.Array:
    rules = current_rules()
    if not rules or name not in rules:
        return x
    spec = rules[name]
    if len(spec) != x.ndim:
        # Rank mismatch (e.g. smoke config): skip rather than fail.
        return x
    try:
        return jax.lax.with_sharding_constraint(x, spec)
    except Exception:
        return x
