"""Logical-axis → mesh-axis sharding rules with divisibility-aware fallbacks.

The planner (DESIGN.md §2.2) treats HBM as the hard constraint and picks, per
tensor, the closest feasible sharding in preference order — the same
best-feasible-fit selection as Alg 4's node selection, specialized to the
structured 'cluster' of mesh axes.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Mapping, Optional, Sequence, Tuple

import jax
from jax.sharding import PartitionSpec as P

from ..configs.base import ModelConfig

# Tensor-parallel preference order over logical axes: the first divisible
# logical dim in this list gets the "model" axis.
TP_PREFERENCE = ("vocab", "experts", "ffn", "q_heads", "kv_heads", "ffn_in", "embed")


def dp_only() -> bool:
    """Beyond-paper optimization (EXPERIMENTS.md §Perf iter 2): for small
    dense models the 16-way model axis mostly replicates per-token work; in
    DP-only mode the model axis becomes extra data parallelism (batch over
    all 256/512 devices, params ZeRO-sharded over both axes)."""
    import os

    return os.environ.get("REPRO_OPT_DP_ONLY", "0") == "1"


def _tp_preference() -> Tuple[str, ...]:
    """Beyond-paper optimization (EXPERIMENTS.md §Perf): sharding a weight on
    its *input* ('embed') dim makes every matmul produce partial sums — an
    activation-sized all-reduce per projection.  REPRO_OPT_NO_EMBED_TP=1
    drops that fallback (weights replicate or ZeRO-shard instead), which is
    what non-16-divisible-head archs (smollm, whisper) want."""
    import os

    if os.environ.get("REPRO_OPT_NO_EMBED_TP", "0") == "1":
        return tuple(a for a in TP_PREFERENCE if a != "embed")
    return TP_PREFERENCE

# Logical axes whose divisibility must be checked semantically (head count,
# expert count) rather than on the fused dim size.
_SEMANTIC_COUNT = {"q_heads": "n_heads", "kv_heads": "n_kv_heads", "experts": "n_experts"}


@dataclasses.dataclass
class MeshShape:
    """Named mesh axes and sizes, e.g. {"pod":2, "data":16, "model":16}."""

    axes: Mapping[str, int]

    @property
    def data_axes(self) -> Tuple[str, ...]:
        return tuple(a for a in ("pod", "data") if a in self.axes)

    def size(self, name: str) -> int:
        return self.axes.get(name, 1)

    @property
    def n_devices(self) -> int:
        n = 1
        for v in self.axes.values():
            n *= v
        return n


def _divisible(cfg: ModelConfig, logical: str, dim_size: int, shards: int) -> bool:
    if shards <= 1:
        return True
    if dim_size % shards != 0:
        return False
    sem = _SEMANTIC_COUNT.get(logical)
    if sem is not None and getattr(cfg, sem) % shards != 0:
        return False
    return True


def choose_tp_axis(
    cfg: ModelConfig,
    axes: Sequence[Optional[str]],
    shape: Tuple[int, ...],
    mesh: MeshShape,
) -> Optional[int]:
    """Index of the tensor dim that takes the 'model' axis, or None."""
    model = mesh.size("model")
    if model <= 1:
        return None
    pref = _tp_preference()
    ranked = []
    for i, name in enumerate(axes):
        if name in pref and _divisible(cfg, name, shape[i], model):
            ranked.append((pref.index(name), i))
    if not ranked:
        return None
    return min(ranked)[1]


def choose_fsdp_axis(
    cfg: ModelConfig,
    axes: Sequence[Optional[str]],
    shape: Tuple[int, ...],
    mesh: MeshShape,
    taken: Optional[int],
    fsdp_axes: Tuple[str, ...],
) -> Optional[int]:
    """Dim for ZeRO-style sharding over the data(+pod) axes, if any fits."""
    shards = 1
    for a in fsdp_axes:
        shards *= mesh.size(a)
    if shards <= 1:
        return None
    best = None
    for i, name in enumerate(axes):
        if i == taken or name is None or name == "layers":
            continue
        if _divisible(cfg, name, shape[i], shards):
            if best is None or shape[i] > shape[best]:
                best = i
    return best


def param_partition_spec(
    cfg: ModelConfig,
    axes: Sequence[Optional[str]],
    shape: Tuple[int, ...],
    mesh: MeshShape,
    fsdp: bool,
) -> P:
    tp = None if dp_only() else choose_tp_axis(cfg, axes, shape, mesh)
    entries: list = [None] * len(axes)
    if tp is not None:
        entries[tp] = "model"
    if fsdp or dp_only():
        fa = mesh.data_axes + ("model",) if dp_only() else mesh.data_axes
        fs = choose_fsdp_axis(cfg, axes, shape, mesh, tp, fa)
        if fs is not None:
            entries[fs] = fa if len(fa) > 1 else fa[0]
    return P(*entries)


def batch_spec(
    mesh: MeshShape, ndim: int, batch_dim: int = 0, batch_size: int | None = None
) -> P:
    entries: list = [None] * ndim
    da = mesh.data_axes
    if dp_only():
        # Extend batch sharding onto the model axis only when divisible
        # (e.g. global_batch 256 over a 2x16x16 mesh keeps pod+data DP and
        # uses the model axis for ZeRO only).
        ext = da + ("model",)
        shards = 1
        for a in ext:
            shards *= mesh.size(a)
        if batch_size is None or (batch_size % max(shards, 1) == 0):
            da = ext
    entries[batch_dim] = da if len(da) > 1 else (da[0] if da else None)
    return P(*entries)


def cache_partition_spec(
    cfg: ModelConfig,
    name: str,
    leaf_shape: Tuple[int, ...],
    mesh: MeshShape,
    grouped: bool,
) -> P:
    """KV / recurrent-state sharding by leaf name.

    KV leaves ('k'/'v', shape (..., S, Kv, hd)): batch over the data axes,
    kv heads over 'model' when divisible, else the *sequence* dim over
    'model' (KV sequence-parallel decode — attention then reduces partial
    scores across the model axis).  Recurrent-state leaves shard batch and,
    when divisible, the head dim."""
    ndim = len(leaf_shape)
    entries: list = [None] * ndim
    b = 1 if grouped else 0
    da = mesh.data_axes
    dp = 1
    for a in da:
        dp *= mesh.size(a)
    if ndim > b and leaf_shape[b] % max(dp, 1) == 0 and dp > 1:
        entries[b] = da if len(da) > 1 else da[0]
    model = mesh.size("model")
    if model <= 1:
        return P(*entries)
    if name in ("k", "v") and ndim >= b + 3:
        s_dim, kv_dim = ndim - 3, ndim - 2
        if cfg.n_kv_heads % model == 0:
            entries[kv_dim] = "model"
        elif leaf_shape[s_dim] % model == 0:
            entries[s_dim] = "model"
    elif name in ("C", "n", "m") and ndim >= b + 2:
        if leaf_shape[b + 1] == cfg.n_heads and cfg.n_heads % model == 0:
            entries[b + 1] = "model"
    return P(*entries)
