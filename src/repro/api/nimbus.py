"""The Nimbus control-plane facade (paper §5: a stateless Nimbus turns a
declarative topology + cluster description into a placement).

``Nimbus`` wraps ``GlobalState`` behind the cluster-lifecycle verbs:

* ``plan(payload)``   — dry-run: schedule against a scratch copy, commit
  nothing (the cluster and the global state are untouched);
* ``submit(payload)`` — plan, then atomically commit (paper §4.1);
* ``kill(topology_id)`` — remove a topology, returning its resources;
* ``fail_node(node_id)`` — mark a worker dead, reporting its orphans;
* ``add_nodes(specs)``  — elastic scale-up, re-placing unassigned tasks;
* ``rebalance()``     — re-place orphaned/unassigned tasks (paper §3);
* ``change_load(topology_id, component_id, factor)`` — mid-run load shift;
* ``migrate_stragglers(service_times)`` — DESIGN.md §5 mitigation;
* ``apply(event)``    — dispatch one typed scenario event (the event-sourced
  timeline entry point used by ``repro.api.scenario.ScenarioRunner``).

Both plan and submit return a ``SchedulingPlan`` report: placements,
unassigned tasks, per-node utilization, network cost and schedule time.

Rebalancing verbs route through ``core.reconfig.ReconfigEngine``:
``Nimbus(..., reconfig="greedy")`` (the default) replays the historical
greedy orphan patch-up bit-identically; ``reconfig="search"`` adds a
migration-aware annealing pass that only commits simulated-never-worse
placements (``reconfig_kwargs`` are validated against
``core.reconfig.RECONFIG_SCHEMAS``).
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Mapping, Optional, Sequence, Tuple, Union

from ..core.assignment import Assignment
from ..core.cluster import Cluster
from ..core.multitopology import GlobalState
from ..core.reconfig import ReconfigEngine, validate_reconfig
from ..core.registry import get_scheduler
from ..core.rescheduler import RebalanceResult, StragglerMitigator
from ..core.resources import BANDWIDTH, CPU, MEMORY
from ..core.topology import Topology
from ..obs import MetricsHub, get_hub
from .errors import (
    PayloadValidationError,
    ScenarioReplayError,
    UnschedulablePayloadError,
)
from .specs import ClusterSpec, SchedulingPayload


@dataclasses.dataclass
class SimSummary:
    """The serialized projection of a ``stream.simulator.SimResult`` — what
    ``SchedulingPlan.to_dict`` keeps of a simulation, and what
    ``SchedulingPlan.from_dict`` reconstructs (the full SimResult carries
    live per-node detail that is not part of the plan contract)."""

    sink_throughput: float
    binding: str
    latency_s: float
    machines_used: int
    avg_cpu_utilization: float
    # Latency percentiles — the DES executor measures them per tuple; the
    # steady-state solver has only a mean, so these stay None there (and are
    # omitted from the dict form to keep solver plans byte-stable).
    p50_latency_s: Optional[float] = None
    p95_latency_s: Optional[float] = None
    p99_latency_s: Optional[float] = None


@dataclasses.dataclass
class SchedulingPlan:
    """What the control plane decided for one payload."""

    topology_id: str
    scheduler_name: str
    committed: bool
    placements: Dict[str, str]
    unassigned: List[str]
    network_cost: float
    schedule_time_s: float
    #: node -> {memory_mb, cpu_points, bandwidth} fraction of that node's
    #: capacity consumed by *this* topology.
    node_utilization: Dict[str, Dict[str, float]]
    sim: Optional[Any] = None  # stream.simulator.SimResult when requested
    # Live objects for downstream tooling (not part of the dict form).
    assignment: Optional[Assignment] = dataclasses.field(
        default=None, repr=False, compare=False
    )
    topology: Optional[Topology] = dataclasses.field(
        default=None, repr=False, compare=False
    )

    @property
    def machines_used(self) -> int:
        return len(set(self.placements.values()))

    def is_complete(self) -> bool:
        return not self.unassigned

    def to_dict(self) -> Dict[str, Any]:
        out = {
            "topology_id": self.topology_id,
            "scheduler_name": self.scheduler_name,
            "committed": self.committed,
            "placements": dict(self.placements),
            "unassigned": list(self.unassigned),
            "network_cost": self.network_cost,
            "schedule_time_s": self.schedule_time_s,
            "node_utilization": {
                nid: dict(dims) for nid, dims in self.node_utilization.items()
            },
            "machines_used": self.machines_used,
        }
        if self.sim is not None:
            sim = {
                "sink_throughput": self.sim.sink_throughput,
                "binding": self.sim.binding,
                "latency_s": self.sim.latency_s,
                "machines_used": self.sim.machines_used,
                "avg_cpu_utilization": self.sim.avg_cpu_utilization,
            }
            for key in ("p50_latency_s", "p95_latency_s", "p99_latency_s"):
                v = getattr(self.sim, key, None)
                if v is not None:
                    sim[key] = v
            out["sim"] = sim
        return out

    @classmethod
    def from_dict(cls, d: Mapping[str, Any]) -> "SchedulingPlan":
        """Rebuild a plan from its ``to_dict`` form (lossless round-trip:
        ``from_dict(p.to_dict()).to_dict() == p.to_dict()``).

        The live ``assignment``/``topology`` objects are not part of the dict
        contract and come back as None; an attached sim is reconstructed as a
        ``SimSummary``.  ``machines_used`` is derived from placements, so the
        stored value is ignored.
        """
        d = dict(d)
        sim = d.get("sim")
        return cls(
            topology_id=d["topology_id"],
            scheduler_name=d["scheduler_name"],
            committed=d["committed"],
            placements=dict(d["placements"]),
            unassigned=list(d["unassigned"]),
            network_cost=d["network_cost"],
            schedule_time_s=d["schedule_time_s"],
            node_utilization={
                nid: dict(dims) for nid, dims in d["node_utilization"].items()
            },
            sim=SimSummary(**sim) if sim is not None else None,
        )

    @classmethod
    def from_assignment(
        cls,
        assignment: Assignment,
        topology: Topology,
        cluster: Cluster,
        committed: bool,
        sim: Optional[Any] = None,
    ) -> "SchedulingPlan":
        used: Dict[str, Dict[str, float]] = {}
        demands = {t.id: topology.demand_of(t) for t in topology.all_tasks()}
        for tid, nid in assignment.placements.items():
            acc = used.setdefault(nid, {MEMORY: 0.0, CPU: 0.0, BANDWIDTH: 0.0})
            d = demands[tid]
            for dim in acc:
                acc[dim] += d[dim]
        utilization = {}
        for nid, dims in used.items():
            cap = cluster.nodes[nid].capacity
            utilization[nid] = {
                dim: (use / cap[dim] if cap[dim] > 0 else 0.0)
                for dim, use in dims.items()
            }
        return cls(
            topology_id=topology.id,
            scheduler_name=assignment.scheduler_name,
            committed=committed,
            placements=dict(assignment.placements),
            unassigned=list(assignment.unassigned),
            network_cost=assignment.network_cost(topology, cluster),
            schedule_time_s=assignment.schedule_time_s,
            node_utilization=utilization,
            sim=sim,
            assignment=assignment,
            topology=topology,
        )


class Nimbus:
    """Unified submit/plan/kill/rebalance facade over ``GlobalState``.

    The cluster is established either at construction (a ``ClusterSpec`` or
    a live ``Cluster``) or lazily from the first *submitted* payload —
    ``plan`` on an empty Nimbus stays fully stateless.  Once a cluster is
    live, payloads whose ``ClusterSpec`` does not describe it are rejected —
    the payload is self-contained, so silent mismatch would mean the caller
    is scheduling against an environment other than the one they declared.
    """

    def __init__(
        self,
        cluster: Union[Cluster, ClusterSpec, None] = None,
        hub: Optional[MetricsHub] = None,
        reconfig: str = "greedy",
        reconfig_kwargs: Optional[Mapping[str, Any]] = None,
    ):
        #: Explicit telemetry hub.  When None, each plan/submit consults the
        #: payload's ``settings.obs`` (fresh hub per call when enabled) and
        #: otherwise inherits whatever hub is ambient via ``obs.get_hub``.
        self.hub = hub
        #: How rebalance/scale-up re-place tasks: ``"greedy"`` is the
        #: orphan patch-up (bit-identical to the historical Rescheduler),
        #: ``"search"`` runs the greedy pass and then a migration-aware
        #: anneal over (migration set × placement), committing only
        #: simulated-never-worse candidates.
        errors = validate_reconfig(reconfig, reconfig_kwargs)
        if errors:
            raise PayloadValidationError(errors)
        self._reconfig_mode = reconfig
        self._reconfig_kwargs = (
            dict(reconfig_kwargs) if reconfig_kwargs is not None else None
        )
        self._cluster_spec: Optional[ClusterSpec] = None
        #: Soft-constraint weights used by rebalance/migration (Alg 4's user
        #: weights); updated by ``set_weights`` / a ``WeightsChangeEvent``.
        self._weights: Optional[Dict[str, float]] = None
        if isinstance(cluster, ClusterSpec):
            errors = cluster.validate("cluster")
            if errors:
                raise PayloadValidationError(errors)
            self._cluster_spec = cluster
            cluster = cluster.to_cluster()
        elif cluster is not None:
            # Record the spec of a caller-supplied live cluster so payload
            # mismatch checking works on this construction path too.
            self._cluster_spec = ClusterSpec.from_cluster(cluster)
        self.state: Optional[GlobalState] = (
            GlobalState(cluster) if cluster is not None else None
        )

    # -- introspection -----------------------------------------------------------
    @property
    def cluster(self) -> Optional[Cluster]:
        return self.state.cluster if self.state is not None else None

    @property
    def topologies(self) -> List[str]:
        return sorted(self.state.topologies) if self.state is not None else []

    # -- internals ---------------------------------------------------------------
    def _prepare(self, payload: SchedulingPayload, *, persist: bool):
        """Validate everything and materialize objects — no mutation on error.

        ``persist`` controls whether an empty Nimbus adopts the payload's
        cluster as its live one (submit) or materializes a throwaway copy
        (plan, which must stay side-effect free)."""
        payload.validate()
        topology = payload.topology.to_topology()
        scheduler = get_scheduler(payload.scheduler.name, **payload.scheduler.kwargs)
        if self.state is None:
            cluster = payload.cluster.to_cluster()
            if persist:
                self._cluster_spec = payload.cluster
                self.state = GlobalState(cluster)
        else:
            # Fast path: identical spec.  Slow path: semantically equivalent
            # (e.g. a preset vs the explicit node list it expands to).
            if payload.cluster != self._cluster_spec and not payload.cluster.describes(
                self.state.cluster
            ):
                raise PayloadValidationError(
                    [
                        "cluster: payload cluster spec does not match the cluster "
                        f"this Nimbus is managing ({len(self.state.cluster.nodes)} "
                        "nodes); submit to a fresh Nimbus or reuse the original spec"
                    ]
                )
            cluster = self.state.cluster
        return topology, scheduler, cluster

    def _hub_for(self, settings) -> MetricsHub:
        """The telemetry hub one plan/submit runs under.

        Resolution order: an explicit ``Nimbus(hub=...)`` wins; else the
        payload's ``settings.obs`` (fresh hub per call, so two identical
        submissions export byte-identical JSONL); else the ambient hub."""
        if self.hub is not None:
            return self.hub
        obs = getattr(settings, "obs", None)
        if obs is not None and obs.enabled:
            return MetricsHub()
        return get_hub()

    def _export_obs(self, hub: MetricsHub, settings) -> None:
        obs = getattr(settings, "obs", None)
        if obs is not None and hub.enabled and obs.export_path:
            hub.export(obs.export_path, include_wall=obs.include_wall)

    def _simulate(
        self,
        topology: Topology,
        assignment: Assignment,
        cluster: Cluster,
        settings=None,
    ):
        engine = getattr(settings, "sim_engine", "solver") if settings else "solver"
        with get_hub().span("nimbus.simulate", topology=topology.id, engine=engine):
            return self._engine(cluster, settings).run(topology, assignment)

    def _engine(self, cluster: Cluster, settings=None):
        """The referee a payload's settings ask for — the steady-state
        fixed-point solver by default, the discrete-event tuple-level
        executor when ``settings.sim_engine == "des"``.  Both read the same
        mechanism knobs so one RunSettings pins one physical model."""
        from ..stream.simulator import Simulator  # local: stream imports api

        if settings is None:
            return Simulator(cluster)
        knobs = dict(
            thrash_factor=settings.thrash_factor,
            ack_overhead_s=settings.ack_overhead_s,
            tuple_timeout_s=settings.tuple_timeout_s,
        )
        if settings.sim_engine == "des":
            from ..stream.des import DesExecutor

            config = settings.des.to_config() if settings.des is not None else None
            return DesExecutor(cluster, config=config, **knobs)
        return Simulator(cluster, **knobs)

    # -- verbs -------------------------------------------------------------------
    def plan(self, payload: SchedulingPayload) -> SchedulingPlan:
        """Dry-run scheduling: neither the cluster nor GlobalState changes
        (an empty Nimbus stays empty — nothing is pinned by planning)."""
        hub = self._hub_for(payload.settings)
        with hub.activate(), hub.span(
            "nimbus.plan",
            topology=payload.topology.id,
            scheduler=payload.scheduler.name,
        ) as span:
            topology, scheduler, cluster = self._prepare(payload, persist=False)
            with hub.span("nimbus.schedule", scheduler=payload.scheduler.name):
                assignment = scheduler.schedule(topology, cluster, commit=False)
            sim = (
                self._simulate(topology, assignment, cluster, payload.settings)
                if payload.settings.simulate
                else None
            )
            plan = SchedulingPlan.from_assignment(
                assignment, topology, cluster, committed=False, sim=sim
            )
            span.set(placed=len(plan.placements), unassigned=len(plan.unassigned))
        self._export_obs(hub, payload.settings)
        return plan

    def submit(self, payload: SchedulingPayload) -> SchedulingPlan:
        """Plan, then atomically commit onto the live cluster.

        A payload that fails validation, collides with a submitted topology
        id, or (with ``allow_partial=False``) cannot be fully placed is
        rejected before any cluster mutation.
        """
        hub = self._hub_for(payload.settings)
        with hub.activate(), hub.span(
            "nimbus.submit",
            topology=payload.topology.id,
            scheduler=payload.scheduler.name,
        ) as span:
            plan = self._submit_locked(payload, hub, span)
        self._export_obs(hub, payload.settings)
        return plan

    def _submit_locked(self, payload, hub, span) -> SchedulingPlan:
        was_empty = self.state is None
        topology, scheduler, cluster = self._prepare(payload, persist=True)
        try:
            if topology.id in self.state.topologies:
                raise PayloadValidationError(
                    [
                        f"topology.id: {topology.id!r} is already submitted; "
                        "kill it first or choose a different id"
                    ]
                )
            with hub.span("nimbus.schedule", scheduler=payload.scheduler.name):
                assignment = scheduler.schedule(topology, cluster, commit=False)
            if assignment.unassigned and not payload.settings.allow_partial:
                raise UnschedulablePayloadError(topology.id, assignment.unassigned)
        except BaseException:
            if was_empty:
                # A rejected submit must leave an empty Nimbus empty — don't
                # let it silently adopt the rejected payload's cluster.
                self.state = None
                self._cluster_spec = None
            raise
        self.state.commit(topology, assignment)
        sim = (
            self._simulate(topology, assignment, cluster, payload.settings)
            if payload.settings.simulate
            else None
        )
        plan = SchedulingPlan.from_assignment(
            assignment, topology, cluster, committed=True, sim=sim
        )
        span.set(placed=len(plan.placements), unassigned=len(plan.unassigned))
        return plan

    def kill(self, topology_id: str) -> Assignment:
        """Remove a submitted topology, returning its resources to the cluster."""
        if self.state is None or topology_id not in self.state.topologies:
            raise KeyError(
                f"unknown topology {topology_id!r}; submitted: {self.topologies}"
            )
        return self.state.kill(topology_id)

    def fail_node(self, node_id: str) -> List[Tuple[str, str]]:
        """Mark a worker node dead (paper §3 failure injection).

        Returns the orphaned (topology_id, task_id) pairs; call
        ``rebalance()`` to re-place them on the survivors."""
        if self.state is None or node_id not in self.state.cluster.nodes:
            raise KeyError(
                f"unknown node {node_id!r}; have "
                f"{sorted(self.state.cluster.nodes) if self.state else []}"
            )
        return self._reconfig().fail_node(node_id)

    def add_nodes(self, node_specs: Sequence[Any], weights=None) -> RebalanceResult:
        """Elastic scale-up: join fresh nodes, then re-place any unassigned
        tasks.  Accepts core ``NodeSpec``s or API ``NodeEntry``s."""
        if self.state is None:
            raise ScenarioReplayError(
                "add_nodes needs a live cluster; construct Nimbus(cluster) "
                "or submit a payload first"
            )
        specs = [
            n.to_node_spec() if hasattr(n, "to_node_spec") else n
            for n in node_specs
        ]
        result = self._reconfig(weights).handle_scale_up(specs)
        # The live node set changed; keep the recorded spec in sync so later
        # payload-vs-cluster mismatch checks compare against reality.
        self._cluster_spec = ClusterSpec.from_cluster(self.state.cluster)
        return result

    def rebalance(self, weights=None) -> RebalanceResult:
        """Re-place orphaned (dead-node) and unassigned tasks.

        Returns a ``RebalanceResult`` with disjoint per-topology ``moved``
        and ``unplaced`` task-id lists."""
        if self.state is None:
            return RebalanceResult()
        hub = self.hub if self.hub is not None else get_hub()
        with hub.activate(), hub.span(
            "nimbus.rebalance", mode=self._reconfig_mode
        ) as span:
            result = self._reconfig(weights).rebalance()
            span.set(
                moved=result.moved_count(), unplaced=result.unplaced_count()
            )
        return result

    def _reconfig(self, weights=None) -> ReconfigEngine:
        """The reconfiguration engine for one lifecycle verb (stateless
        between calls — it reads the live GlobalState each time)."""
        return ReconfigEngine(
            self.state,
            weights if weights is not None else self._weights,
            mode=self._reconfig_mode,
            kwargs=self._reconfig_kwargs,
        )

    def change_load(
        self, topology_id: str, component_id: str, factor: float
    ) -> Dict[str, Any]:
        """Mid-run load shift: multiply ``component_id``'s per-tuple CPU
        cost by ``factor`` (> 1 makes each tuple ``factor``× more expensive
        to process, shrinking the component's service rate).

        Only the *behavioural* cost changes — the declared ``cpu_load``
        demand the node ledger was charged with stays put, so committed
        placements and capacity bookkeeping are untouched.  Simulations run
        after this call see the new cost; a rebalance (reactive or scripted)
        is how the placement catches up.
        """
        from ..stream.simulator import _cpu_cost  # local: stream imports api

        if self.state is None or topology_id not in self.state.topologies:
            raise KeyError(
                f"unknown topology {topology_id!r}; submitted: {self.topologies}"
            )
        topology = self.state.topologies[topology_id]
        comp = topology.components.get(component_id)
        if comp is None:
            raise KeyError(
                f"unknown component {component_id!r} in topology "
                f"{topology_id!r}; have {sorted(topology.components)}"
            )
        if not isinstance(factor, (int, float)) or factor <= 0:
            raise ValueError(f"factor must be > 0, got {factor!r}")
        comp.cpu_cost_per_tuple = _cpu_cost(comp) * float(factor)
        return {
            "topology_id": topology_id,
            "component_id": component_id,
            "factor": float(factor),
            "cpu_cost_per_tuple": comp.cpu_cost_per_tuple,
        }

    def migrate_stragglers(
        self,
        service_times: Mapping[str, float],
        factor: float = 3.0,
        weights=None,
    ) -> Tuple[List[str], Dict[str, str]]:
        """Detect tasks slower than ``factor`` × their component median and
        move them to the closest feasible other node (DESIGN.md §5).

        Returns ``(straggler_task_ids, {task_id: new_node_id})``."""
        if self.state is None:
            return [], {}
        mitigator = StragglerMitigator(
            self.state, factor, weights if weights is not None else self._weights
        )
        found = mitigator.find_stragglers(dict(service_times))
        return found, mitigator.migrate(found)

    def set_weights(self, weights: Optional[Mapping[str, float]]) -> None:
        """Change the soft-constraint weights future rebalances/migrations
        use (a live-tuning knob; committed placements are untouched)."""
        self._weights = dict(weights) if weights is not None else None

    def simulate_all(
        self,
        warm_start: Optional[Mapping[str, float]] = None,
        *,
        engine: Optional[str] = None,
        des=None,
        settings=None,
    ) -> Dict[str, Any]:
        """Joint simulation of every committed topology (§6.5).

        The default referee is the steady-state fixed-point solver;
        ``engine="des"`` runs the discrete-event tuple-level executor instead
        and returns ``DesReport`` objects (measured sink throughput, latency
        percentiles, queue traces).  ``des`` optionally carries a
        ``specs.DesSettings``/``stream.des.DesConfig`` for that run;
        ``settings`` a full ``RunSettings`` (engine/des arguments win when
        both are given).

        ``warm_start`` maps topology_id -> previous spout rate λ, letting a
        scenario replay re-enter the solver near the old fixed point instead
        of from scratch after each timeline event (solver engine only — the
        DES always runs its full packet-level horizon)."""
        if self.state is None or not self.state.topologies:
            return {}
        pairs = [
            (self.state.topologies[tid], self.state.assignments[tid])
            for tid in sorted(self.state.topologies)
        ]
        if engine is None and settings is not None:
            engine = settings.sim_engine
        if des is None and settings is not None:
            des = settings.des
        if engine not in (None, "solver", "des"):
            raise ValueError(
                f"engine must be 'solver' or 'des', got {engine!r}"
            )
        if engine == "des":
            from ..stream.des import DesConfig, DesExecutor

            config = des.to_config() if hasattr(des, "to_config") else des
            if config is not None and not isinstance(config, DesConfig):
                raise TypeError(
                    "des must be a DesSettings or stream.des.DesConfig, "
                    f"got {des!r}"
                )
            knobs = (
                dict(
                    thrash_factor=settings.thrash_factor,
                    ack_overhead_s=settings.ack_overhead_s,
                    tuple_timeout_s=settings.tuple_timeout_s,
                )
                if settings is not None
                else {}
            )
            executor = DesExecutor(self.state.cluster, config=config, **knobs)
            hub = self.hub if self.hub is not None else get_hub()
            with hub.activate(), hub.span(
                "nimbus.simulate", engine="des", topologies=len(pairs)
            ):
                return executor.run_many(pairs)
        from ..stream.simulator import Simulator

        solver = (
            Simulator(
                self.state.cluster,
                thrash_factor=settings.thrash_factor,
                ack_overhead_s=settings.ack_overhead_s,
                tuple_timeout_s=settings.tuple_timeout_s,
            )
            if settings is not None
            else Simulator(self.state.cluster)
        )
        hub = self.hub if self.hub is not None else get_hub()
        with hub.activate(), hub.span(
            "nimbus.simulate", engine="solver", topologies=len(pairs)
        ):
            return solver.run_many(pairs, warm_start=warm_start)

    # -- event-sourced dispatch (the scenario timeline entry point) ----------------
    def apply(self, event: Any) -> Dict[str, Any]:
        """Apply one typed scenario event and return its JSON-able outcome.

        This is the single dispatcher ``ScenarioRunner`` replays a timeline
        through; each event kind maps onto exactly one lifecycle verb, so
        anything a scenario can do is also a first-class API call.
        """
        kind = getattr(event, "kind", None)
        handler = self._APPLY.get(kind) if isinstance(kind, str) else None
        if handler is None:
            raise ScenarioReplayError(
                f"unknown scenario event {event!r}; known kinds: "
                f"{sorted(self._APPLY)}"
            )
        if self.state is None:
            raise ScenarioReplayError(
                "Nimbus.apply needs a live cluster; construct Nimbus(cluster) "
                "before replaying a timeline"
            )
        return handler(self, event)

    def _apply_submit(self, event) -> Dict[str, Any]:
        payload = SchedulingPayload(
            topology=event.topology,
            cluster=self._cluster_spec,
            scheduler=event.scheduler,
            settings=event.settings,
        )
        plan = self.submit(payload)
        # Event outcomes are replay-comparable: the same timeline must yield
        # bit-identical outcomes, so wall-clock timing is scrubbed at the
        # source (use ``submit`` directly when you need schedule_time_s).
        return {"plan": dict(plan.to_dict(), schedule_time_s=0.0)}

    def _apply_kill(self, event) -> Dict[str, Any]:
        assignment = self.kill(event.topology_id)
        return {
            "topology_id": event.topology_id,
            "released_tasks": len(assignment.placements),
        }

    def _apply_node_fail(self, event) -> Dict[str, Any]:
        orphans = self.fail_node(event.node_id)
        return {
            "node_id": event.node_id,
            "orphaned": [[topo_id, tid] for topo_id, tid in orphans],
        }

    def _apply_node_join(self, event) -> Dict[str, Any]:
        result = self.add_nodes(list(event.nodes))
        return {"nodes": [n.node_id for n in event.nodes], **result.to_dict()}

    def _apply_rebalance(self, event) -> Dict[str, Any]:
        return self.rebalance().to_dict()

    def _apply_straggler_report(self, event) -> Dict[str, Any]:
        found, moves = self.migrate_stragglers(
            dict(event.service_times), event.factor
        )
        return {"stragglers": list(found), "moves": dict(moves)}

    def _apply_weights_change(self, event) -> Dict[str, Any]:
        self.set_weights(dict(event.weights))
        return {"weights": dict(event.weights)}

    def _apply_load_change(self, event) -> Dict[str, Any]:
        return self.change_load(
            event.topology_id, event.component_id, event.factor
        )

    #: event kind -> handler; kinds match ``repro.api.scenario.EVENT_TYPES``.
    _APPLY = {
        "submit": _apply_submit,
        "kill": _apply_kill,
        "node_fail": _apply_node_fail,
        "node_join": _apply_node_join,
        "rebalance": _apply_rebalance,
        "straggler_report": _apply_straggler_report,
        "weights_change": _apply_weights_change,
        "load_change": _apply_load_change,
    }
