"""The Nimbus control-plane facade (paper §5: a stateless Nimbus turns a
declarative topology + cluster description into a placement).

``Nimbus`` wraps ``GlobalState`` behind four verbs:

* ``plan(payload)``   — dry-run: schedule against a scratch copy, commit
  nothing (the cluster and the global state are untouched);
* ``submit(payload)`` — plan, then atomically commit (paper §4.1);
* ``kill(topology_id)`` — remove a topology, returning its resources;
* ``rebalance()``     — re-place orphaned/unassigned tasks after failures
  or elastic scale-up.

Both plan and submit return a ``SchedulingPlan`` report: placements,
unassigned tasks, per-node utilization, network cost and schedule time.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Optional, Union

from ..core.assignment import Assignment
from ..core.cluster import Cluster
from ..core.multitopology import GlobalState
from ..core.registry import get_scheduler
from ..core.rescheduler import Rescheduler
from ..core.resources import BANDWIDTH, CPU, MEMORY
from ..core.topology import Topology
from .errors import PayloadValidationError, UnschedulablePayloadError
from .specs import ClusterSpec, SchedulingPayload


@dataclasses.dataclass
class SchedulingPlan:
    """What the control plane decided for one payload."""

    topology_id: str
    scheduler_name: str
    committed: bool
    placements: Dict[str, str]
    unassigned: List[str]
    network_cost: float
    schedule_time_s: float
    #: node -> {memory_mb, cpu_points, bandwidth} fraction of that node's
    #: capacity consumed by *this* topology.
    node_utilization: Dict[str, Dict[str, float]]
    sim: Optional[Any] = None  # stream.simulator.SimResult when requested
    # Live objects for downstream tooling (not part of the dict form).
    assignment: Optional[Assignment] = dataclasses.field(
        default=None, repr=False, compare=False
    )
    topology: Optional[Topology] = dataclasses.field(
        default=None, repr=False, compare=False
    )

    @property
    def machines_used(self) -> int:
        return len(set(self.placements.values()))

    def is_complete(self) -> bool:
        return not self.unassigned

    def to_dict(self) -> Dict[str, Any]:
        out = {
            "topology_id": self.topology_id,
            "scheduler_name": self.scheduler_name,
            "committed": self.committed,
            "placements": dict(self.placements),
            "unassigned": list(self.unassigned),
            "network_cost": self.network_cost,
            "schedule_time_s": self.schedule_time_s,
            "node_utilization": {
                nid: dict(dims) for nid, dims in self.node_utilization.items()
            },
            "machines_used": self.machines_used,
        }
        if self.sim is not None:
            out["sim"] = {
                "sink_throughput": self.sim.sink_throughput,
                "binding": self.sim.binding,
                "latency_s": self.sim.latency_s,
                "machines_used": self.sim.machines_used,
                "avg_cpu_utilization": self.sim.avg_cpu_utilization,
            }
        return out

    @classmethod
    def from_assignment(
        cls,
        assignment: Assignment,
        topology: Topology,
        cluster: Cluster,
        committed: bool,
        sim: Optional[Any] = None,
    ) -> "SchedulingPlan":
        used: Dict[str, Dict[str, float]] = {}
        demands = {t.id: topology.demand_of(t) for t in topology.all_tasks()}
        for tid, nid in assignment.placements.items():
            acc = used.setdefault(nid, {MEMORY: 0.0, CPU: 0.0, BANDWIDTH: 0.0})
            d = demands[tid]
            for dim in acc:
                acc[dim] += d[dim]
        utilization = {}
        for nid, dims in used.items():
            cap = cluster.nodes[nid].capacity
            utilization[nid] = {
                dim: (use / cap[dim] if cap[dim] > 0 else 0.0)
                for dim, use in dims.items()
            }
        return cls(
            topology_id=topology.id,
            scheduler_name=assignment.scheduler_name,
            committed=committed,
            placements=dict(assignment.placements),
            unassigned=list(assignment.unassigned),
            network_cost=assignment.network_cost(topology, cluster),
            schedule_time_s=assignment.schedule_time_s,
            node_utilization=utilization,
            sim=sim,
            assignment=assignment,
            topology=topology,
        )


class Nimbus:
    """Unified submit/plan/kill/rebalance facade over ``GlobalState``.

    The cluster is established either at construction (a ``ClusterSpec`` or
    a live ``Cluster``) or lazily from the first *submitted* payload —
    ``plan`` on an empty Nimbus stays fully stateless.  Once a cluster is
    live, payloads whose ``ClusterSpec`` does not describe it are rejected —
    the payload is self-contained, so silent mismatch would mean the caller
    is scheduling against an environment other than the one they declared.
    """

    def __init__(self, cluster: Union[Cluster, ClusterSpec, None] = None):
        self._cluster_spec: Optional[ClusterSpec] = None
        if isinstance(cluster, ClusterSpec):
            errors = cluster.validate("cluster")
            if errors:
                raise PayloadValidationError(errors)
            self._cluster_spec = cluster
            cluster = cluster.to_cluster()
        elif cluster is not None:
            # Record the spec of a caller-supplied live cluster so payload
            # mismatch checking works on this construction path too.
            self._cluster_spec = ClusterSpec.from_cluster(cluster)
        self.state: Optional[GlobalState] = (
            GlobalState(cluster) if cluster is not None else None
        )

    # -- introspection -----------------------------------------------------------
    @property
    def cluster(self) -> Optional[Cluster]:
        return self.state.cluster if self.state is not None else None

    @property
    def topologies(self) -> List[str]:
        return sorted(self.state.topologies) if self.state is not None else []

    # -- internals ---------------------------------------------------------------
    def _prepare(self, payload: SchedulingPayload, *, persist: bool):
        """Validate everything and materialize objects — no mutation on error.

        ``persist`` controls whether an empty Nimbus adopts the payload's
        cluster as its live one (submit) or materializes a throwaway copy
        (plan, which must stay side-effect free)."""
        payload.validate()
        topology = payload.topology.to_topology()
        scheduler = get_scheduler(payload.scheduler.name, **payload.scheduler.kwargs)
        if self.state is None:
            cluster = payload.cluster.to_cluster()
            if persist:
                self._cluster_spec = payload.cluster
                self.state = GlobalState(cluster)
        else:
            # Fast path: identical spec.  Slow path: semantically equivalent
            # (e.g. a preset vs the explicit node list it expands to).
            if payload.cluster != self._cluster_spec and not payload.cluster.describes(
                self.state.cluster
            ):
                raise PayloadValidationError(
                    [
                        "cluster: payload cluster spec does not match the cluster "
                        f"this Nimbus is managing ({len(self.state.cluster.nodes)} "
                        "nodes); submit to a fresh Nimbus or reuse the original spec"
                    ]
                )
            cluster = self.state.cluster
        return topology, scheduler, cluster

    def _simulate(self, topology: Topology, assignment: Assignment, cluster: Cluster):
        from ..stream.simulator import Simulator  # local: stream imports api

        return Simulator(cluster).run(topology, assignment)

    # -- verbs -------------------------------------------------------------------
    def plan(self, payload: SchedulingPayload) -> SchedulingPlan:
        """Dry-run scheduling: neither the cluster nor GlobalState changes
        (an empty Nimbus stays empty — nothing is pinned by planning)."""
        topology, scheduler, cluster = self._prepare(payload, persist=False)
        assignment = scheduler.schedule(topology, cluster, commit=False)
        sim = (
            self._simulate(topology, assignment, cluster)
            if payload.settings.simulate
            else None
        )
        return SchedulingPlan.from_assignment(
            assignment, topology, cluster, committed=False, sim=sim
        )

    def submit(self, payload: SchedulingPayload) -> SchedulingPlan:
        """Plan, then atomically commit onto the live cluster.

        A payload that fails validation, collides with a submitted topology
        id, or (with ``allow_partial=False``) cannot be fully placed is
        rejected before any cluster mutation.
        """
        was_empty = self.state is None
        topology, scheduler, cluster = self._prepare(payload, persist=True)
        try:
            if topology.id in self.state.topologies:
                raise PayloadValidationError(
                    [
                        f"topology.id: {topology.id!r} is already submitted; "
                        "kill it first or choose a different id"
                    ]
                )
            assignment = scheduler.schedule(topology, cluster, commit=False)
            if assignment.unassigned and not payload.settings.allow_partial:
                raise UnschedulablePayloadError(topology.id, assignment.unassigned)
        except BaseException:
            if was_empty:
                # A rejected submit must leave an empty Nimbus empty — don't
                # let it silently adopt the rejected payload's cluster.
                self.state = None
                self._cluster_spec = None
            raise
        self.state.commit(topology, assignment)
        sim = (
            self._simulate(topology, assignment, cluster)
            if payload.settings.simulate
            else None
        )
        return SchedulingPlan.from_assignment(
            assignment, topology, cluster, committed=True, sim=sim
        )

    def kill(self, topology_id: str) -> Assignment:
        """Remove a submitted topology, returning its resources to the cluster."""
        if self.state is None or topology_id not in self.state.topologies:
            raise KeyError(
                f"unknown topology {topology_id!r}; submitted: {self.topologies}"
            )
        return self.state.kill(topology_id)

    def rebalance(self, weights=None) -> Dict[str, List[str]]:
        """Re-place orphaned (dead-node) and unassigned tasks.

        Returns per-topology lists of task ids that were moved."""
        if self.state is None:
            return {}
        return Rescheduler(self.state, weights).rebalance()

    def simulate_all(self) -> Dict[str, Any]:
        """Joint steady-state simulation of every committed topology (§6.5)."""
        from ..stream.simulator import Simulator

        if self.state is None or not self.state.topologies:
            return {}
        pairs = [
            (self.state.topologies[tid], self.state.assignments[tid])
            for tid in sorted(self.state.topologies)
        ]
        return Simulator(self.state.cluster).run_many(pairs)
