"""Event-sourced scenario API: a cluster's lifecycle as a replayable timeline.

The paper's headline claims are *dynamic* — schedules must be reproduced
"quickly" after failures (§3) and R-Storm's edge widens when topologies share
a churning cluster (§6.5) — so whole dynamic scenarios become data here, the
way a single scheduling request became a ``SchedulingPayload``:

* a ``ScenarioSpec`` is a validated, JSON-round-trippable ordered timeline of
  typed events (submit / kill / node_fail / node_join / rebalance /
  straggler_report / weights_change / load_change) over a declarative
  ``ClusterSpec``;
* a ``ScenarioRunner`` replays the timeline through the single
  ``Nimbus.apply(event)`` dispatcher, re-simulating joint steady state after
  every step (warm-started from the previous interval's rates);
* the result is a ``ScenarioTrace``: one entry per timeline step with the
  event, its outcome (embedded ``SchedulingPlan`` dicts round-trip via
  ``SchedulingPlan.from_dict``), per-topology throughput/binding/network
  cost, and cluster occupancy — deterministic, so the same timeline JSON
  always yields the identical trace dict.

Validation mirrors the payload layer: every problem is reported (not just the
first) with a path-tagged message, including a static walk of the timeline
(kill of a never-submitted topology, failing an unknown node, joining a
duplicate node id, ...) before any replay starts.
"""

from __future__ import annotations

import dataclasses
import json
from typing import Any, ClassVar, Dict, List, Mapping, Optional, Tuple

from ..core.resources import BANDWIDTH, CPU, MEMORY
from ..obs import MetricsHub, get_hub
from .errors import PayloadValidationError, ScenarioReplayError
from .nimbus import Nimbus
from .specs import (
    ClusterSpec,
    NodeEntry,
    RunSettings,
    SchedulerSpec,
    TopologySpec,
    _check_keys,
    _get,
    _require_mapping,
)

_WEIGHT_DIMS = (MEMORY, CPU, BANDWIDTH)


# -- typed timeline events -------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class SubmitEvent:
    """Submit one topology against the scenario's live cluster (the cluster
    spec lives on the ``ScenarioSpec`` — events carry only the delta)."""

    kind: ClassVar[str] = "submit"
    topology: TopologySpec
    scheduler: SchedulerSpec
    settings: RunSettings = dataclasses.field(default_factory=RunSettings)

    _FIELDS = ("kind", "topology", "scheduler", "settings")

    def validate(self, path: str) -> List[str]:
        errors = self.topology.validate(f"{path}.topology")
        errors += self.scheduler.validate(f"{path}.scheduler")
        errors += self.settings.validate(f"{path}.settings")
        return errors

    def to_dict(self) -> Dict[str, Any]:
        return {
            "kind": self.kind,
            "topology": self.topology.to_dict(),
            "scheduler": self.scheduler.to_dict(),
            "settings": self.settings.to_dict(),
        }

    @classmethod
    def from_dict(cls, d: Mapping, path: str, errors: List[str]) -> "SubmitEvent":
        _check_keys(d, path, cls._FIELDS, errors)
        for key in ("topology", "scheduler"):
            if key not in d:
                errors.append(f"{path}.{key}: required key missing")
        return cls(
            topology=TopologySpec.from_dict(
                d.get("topology", {}), f"{path}.topology", errors
            ),
            scheduler=SchedulerSpec.from_dict(
                d.get("scheduler", {}), f"{path}.scheduler", errors
            ),
            settings=RunSettings.from_dict(
                d.get("settings", {}), f"{path}.settings", errors
            ),
        )


@dataclasses.dataclass(frozen=True)
class KillEvent:
    """Remove a submitted topology; its resources return to the cluster."""

    kind: ClassVar[str] = "kill"
    topology_id: str

    _FIELDS = ("kind", "topology_id")

    def validate(self, path: str) -> List[str]:
        if not isinstance(self.topology_id, str) or not self.topology_id:
            return [
                f"{path}.topology_id: must be a non-empty string, "
                f"got {self.topology_id!r}"
            ]
        return []

    def to_dict(self) -> Dict[str, Any]:
        return {"kind": self.kind, "topology_id": self.topology_id}

    @classmethod
    def from_dict(cls, d: Mapping, path: str, errors: List[str]) -> "KillEvent":
        _check_keys(d, path, cls._FIELDS, errors)
        return cls(topology_id=_get(d, "topology_id", (str,), path, errors, default=""))


@dataclasses.dataclass(frozen=True)
class NodeFailEvent:
    """A worker node dies; its tasks become orphans until a rebalance."""

    kind: ClassVar[str] = "node_fail"
    node_id: str

    _FIELDS = ("kind", "node_id")

    def validate(self, path: str) -> List[str]:
        if not isinstance(self.node_id, str) or not self.node_id:
            return [f"{path}.node_id: must be a non-empty string, got {self.node_id!r}"]
        return []

    def to_dict(self) -> Dict[str, Any]:
        return {"kind": self.kind, "node_id": self.node_id}

    @classmethod
    def from_dict(cls, d: Mapping, path: str, errors: List[str]) -> "NodeFailEvent":
        _check_keys(d, path, cls._FIELDS, errors)
        return cls(node_id=_get(d, "node_id", (str,), path, errors, default=""))


@dataclasses.dataclass(frozen=True)
class NodeJoinEvent:
    """Elastic scale-up: fresh nodes join; unassigned tasks are re-placed."""

    kind: ClassVar[str] = "node_join"
    nodes: Tuple[NodeEntry, ...]

    _FIELDS = ("kind", "nodes")

    def validate(self, path: str) -> List[str]:
        if not self.nodes:
            return [f"{path}.nodes: at least one node required"]
        errors: List[str] = []
        for i, node in enumerate(self.nodes):
            errors.extend(node.validate(f"{path}.nodes[{i}]"))
        return errors

    def to_dict(self) -> Dict[str, Any]:
        return {"kind": self.kind, "nodes": [n.to_dict() for n in self.nodes]}

    @classmethod
    def from_dict(cls, d: Mapping, path: str, errors: List[str]) -> "NodeJoinEvent":
        _check_keys(d, path, cls._FIELDS, errors)
        raw = _get(d, "nodes", (list, tuple), path, errors, default=())
        return cls(
            nodes=tuple(
                NodeEntry.from_dict(n, f"{path}.nodes[{i}]", errors)
                for i, n in enumerate(raw or ())
            )
        )


@dataclasses.dataclass(frozen=True)
class RebalanceEvent:
    """Re-place orphaned and unassigned tasks on the current cluster."""

    kind: ClassVar[str] = "rebalance"

    _FIELDS = ("kind",)

    def validate(self, path: str) -> List[str]:
        return []

    def to_dict(self) -> Dict[str, Any]:
        return {"kind": self.kind}

    @classmethod
    def from_dict(cls, d: Mapping, path: str, errors: List[str]) -> "RebalanceEvent":
        _check_keys(d, path, cls._FIELDS, errors)
        return cls()


@dataclasses.dataclass(frozen=True)
class StragglerReportEvent:
    """Observed per-task service times (the StatisticServer feed as data);
    tasks slower than ``factor`` × their component median are migrated."""

    kind: ClassVar[str] = "straggler_report"
    service_times: Mapping[str, float]
    factor: float = 3.0

    _FIELDS = ("kind", "service_times", "factor")

    def validate(self, path: str) -> List[str]:
        errors: List[str] = []
        if not isinstance(self.service_times, Mapping) or not self.service_times:
            errors.append(
                f"{path}.service_times: must be a non-empty mapping of "
                f"task id -> seconds/tuple, got {self.service_times!r}"
            )
        else:
            for tid, s in self.service_times.items():
                if not isinstance(tid, str) or not tid:
                    errors.append(
                        f"{path}.service_times: keys must be task-id strings, "
                        f"got {tid!r}"
                    )
                elif (
                    isinstance(s, bool)
                    or not isinstance(s, (int, float))
                    or s < 0
                ):
                    errors.append(
                        f"{path}.service_times[{tid!r}]: must be a number >= 0, "
                        f"got {s!r}"
                    )
        if (
            isinstance(self.factor, bool)
            or not isinstance(self.factor, (int, float))
            or self.factor <= 0
        ):
            errors.append(
                f"{path}.factor: must be a number > 0, got {self.factor!r}"
            )
        return errors

    def to_dict(self) -> Dict[str, Any]:
        return {
            "kind": self.kind,
            "service_times": dict(self.service_times),
            "factor": self.factor,
        }

    @classmethod
    def from_dict(
        cls, d: Mapping, path: str, errors: List[str]
    ) -> "StragglerReportEvent":
        _check_keys(d, path, cls._FIELDS, errors)
        times = _get(d, "service_times", (dict,), path, errors, default={})
        return cls(
            service_times=dict(times or {}),
            factor=_get(d, "factor", (float,), path, errors, default=3.0),
        )


@dataclasses.dataclass(frozen=True)
class WeightsChangeEvent:
    """Re-tune the soft-constraint weights used by later rebalances and
    straggler migrations (Alg 4's user weights as a live knob)."""

    kind: ClassVar[str] = "weights_change"
    weights: Mapping[str, float]

    _FIELDS = ("kind", "weights")

    def validate(self, path: str) -> List[str]:
        errors: List[str] = []
        if not isinstance(self.weights, Mapping) or not self.weights:
            return [
                f"{path}.weights: must be a non-empty mapping of resource "
                f"dimension -> weight, got {self.weights!r}"
            ]
        for dim, w in self.weights.items():
            if dim not in _WEIGHT_DIMS:
                errors.append(
                    f"{path}.weights: unknown dimension {dim!r}; "
                    f"allowed: {list(_WEIGHT_DIMS)}"
                )
            elif isinstance(w, bool) or not isinstance(w, (int, float)) or w < 0:
                errors.append(
                    f"{path}.weights[{dim!r}]: must be a number >= 0, got {w!r}"
                )
        return errors

    def to_dict(self) -> Dict[str, Any]:
        return {"kind": self.kind, "weights": dict(self.weights)}

    @classmethod
    def from_dict(
        cls, d: Mapping, path: str, errors: List[str]
    ) -> "WeightsChangeEvent":
        _check_keys(d, path, cls._FIELDS, errors)
        weights = _get(d, "weights", (dict,), path, errors, default={})
        return cls(weights=dict(weights or {}))


@dataclasses.dataclass(frozen=True)
class LoadChangeEvent:
    """Mid-run load shift: multiply one component's per-tuple CPU cost by
    ``factor`` (> 1 = each tuple gets more expensive, shrinking that
    component's service rate).  The declared placement demand is untouched
    — this models the *workload* drifting under a fixed schedule, the
    situation a reactive rebalance exists to repair."""

    kind: ClassVar[str] = "load_change"
    topology_id: str
    component_id: str
    factor: float

    _FIELDS = ("kind", "topology_id", "component_id", "factor")

    def validate(self, path: str) -> List[str]:
        errors: List[str] = []
        for key in ("topology_id", "component_id"):
            v = getattr(self, key)
            if not isinstance(v, str) or not v:
                errors.append(
                    f"{path}.{key}: must be a non-empty string, got {v!r}"
                )
        if (
            isinstance(self.factor, bool)
            or not isinstance(self.factor, (int, float))
            or self.factor <= 0
        ):
            errors.append(
                f"{path}.factor: must be a number > 0, got {self.factor!r}"
            )
        return errors

    def to_dict(self) -> Dict[str, Any]:
        return {
            "kind": self.kind,
            "topology_id": self.topology_id,
            "component_id": self.component_id,
            "factor": self.factor,
        }

    @classmethod
    def from_dict(
        cls, d: Mapping, path: str, errors: List[str]
    ) -> "LoadChangeEvent":
        _check_keys(d, path, cls._FIELDS, errors)
        return cls(
            topology_id=_get(d, "topology_id", (str,), path, errors, default=""),
            component_id=_get(d, "component_id", (str,), path, errors, default=""),
            factor=_get(d, "factor", (float,), path, errors, default=1.0),
        )


#: kind -> event class; the same kinds ``Nimbus.apply`` dispatches on.
EVENT_TYPES = {
    cls.kind: cls
    for cls in (
        SubmitEvent,
        KillEvent,
        NodeFailEvent,
        NodeJoinEvent,
        RebalanceEvent,
        StragglerReportEvent,
        WeightsChangeEvent,
        LoadChangeEvent,
    )
}


def event_from_dict(d: Any, path: str, errors: List[str]):
    """Parse one timeline entry, dispatching on its ``kind`` tag.

    Collects problems into ``errors`` (returning None) rather than raising,
    so one malformed entry doesn't swallow the rest of the report."""
    if not isinstance(d, Mapping):
        errors.append(f"{path}: expected a mapping, got {type(d).__name__}")
        return None
    kind = d.get("kind")
    cls = EVENT_TYPES.get(kind) if isinstance(kind, str) else None
    if cls is None:
        errors.append(
            f"{path}.kind: unknown event kind {kind!r}; "
            f"allowed: {sorted(EVENT_TYPES)}"
        )
        return None
    return cls.from_dict(d, path, errors)


# -- the scenario spec -----------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class ScenarioSpec:
    """A whole dynamic scenario as one validated, self-contained value:
    the environment (``ClusterSpec``) plus an ordered event timeline."""

    cluster: ClusterSpec
    timeline: Tuple[Any, ...] = ()
    name: str = "scenario"

    _FIELDS = ("cluster", "timeline", "name")

    def validate(self) -> "ScenarioSpec":
        """Raise PayloadValidationError listing *all* problems, or return self.

        Beyond per-event checks this statically walks the timeline, tracking
        which topologies are live and which nodes exist/are dead, so that a
        kill of a never-submitted topology or a failure of an unknown node is
        rejected before any replay starts.
        """
        errors: List[str] = []
        if not isinstance(self.name, str) or not self.name:
            errors.append(f"name: must be a non-empty string, got {self.name!r}")
        cluster_errors = self.cluster.validate("cluster")
        errors.extend(cluster_errors)
        # Node-existence checks need a materialized node set; only a broken
        # *cluster* spec disables them (unrelated errors must not).
        known_nodes: set = set()
        if not cluster_errors:
            known_nodes = set(self.cluster.to_cluster().nodes)
        dead_nodes: set = set()
        live_topologies: set = set()
        #: live topology id -> its component ids (for load-change checks).
        live_components: Dict[str, set] = {}
        for i, event in enumerate(self.timeline):
            path = f"timeline[{i}]"
            if not hasattr(event, "kind") or event.kind not in EVENT_TYPES:
                errors.append(
                    f"{path}: not a scenario event: {event!r}; "
                    f"allowed kinds: {sorted(EVENT_TYPES)}"
                )
                continue
            errors.extend(event.validate(path))
            if isinstance(event, SubmitEvent):
                if event.topology.id in live_topologies:
                    errors.append(
                        f"{path}.topology.id: {event.topology.id!r} is already "
                        "submitted at this point in the timeline; kill it "
                        "first or choose a different id"
                    )
                live_topologies.add(event.topology.id)
                live_components[event.topology.id] = {
                    c.id for c in event.topology.components
                }
            elif isinstance(event, KillEvent):
                if event.topology_id not in live_topologies:
                    errors.append(
                        f"{path}.topology_id: {event.topology_id!r} is not "
                        "submitted at this point in the timeline "
                        f"(live: {sorted(live_topologies)})"
                    )
                live_topologies.discard(event.topology_id)
                live_components.pop(event.topology_id, None)
            elif isinstance(event, LoadChangeEvent):
                if event.topology_id not in live_topologies:
                    errors.append(
                        f"{path}.topology_id: {event.topology_id!r} is not "
                        "submitted at this point in the timeline "
                        f"(live: {sorted(live_topologies)})"
                    )
                elif (
                    event.topology_id in live_components
                    and event.component_id
                    not in live_components[event.topology_id]
                ):
                    errors.append(
                        f"{path}.component_id: unknown component "
                        f"{event.component_id!r} in topology "
                        f"{event.topology_id!r} (have "
                        f"{sorted(live_components[event.topology_id])})"
                    )
            elif isinstance(event, NodeFailEvent) and known_nodes:
                if event.node_id not in known_nodes:
                    errors.append(
                        f"{path}.node_id: unknown node {event.node_id!r} at "
                        "this point in the timeline"
                    )
                elif event.node_id in dead_nodes:
                    errors.append(
                        f"{path}.node_id: node {event.node_id!r} already "
                        "failed earlier in the timeline"
                    )
                dead_nodes.add(event.node_id)
            elif isinstance(event, NodeJoinEvent) and known_nodes:
                for j, node in enumerate(event.nodes):
                    if node.node_id in known_nodes:
                        errors.append(
                            f"{path}.nodes[{j}].node_id: node "
                            f"{node.node_id!r} already exists at this point "
                            "in the timeline"
                        )
                    known_nodes.add(node.node_id)
        if errors:
            raise PayloadValidationError(errors)
        return self

    # -- lossless dict/JSON round-trip ---------------------------------------------
    def to_dict(self) -> Dict[str, Any]:
        return {
            "name": self.name,
            "cluster": self.cluster.to_dict(),
            "timeline": [event.to_dict() for event in self.timeline],
        }

    @classmethod
    def from_dict(cls, d: Any) -> "ScenarioSpec":
        """Parse + fully validate a pure-dict scenario; raises
        PayloadValidationError with every problem found."""
        d = _require_mapping(d, "scenario")
        errors: List[str] = []
        _check_keys(d, "scenario", cls._FIELDS, errors)
        raw_timeline = _get(d, "timeline", (list, tuple), "scenario", errors, default=())
        timeline = tuple(
            event
            for i, raw in enumerate(raw_timeline or ())
            if (event := event_from_dict(raw, f"timeline[{i}]", errors)) is not None
        )
        if "cluster" not in d:
            # No cluster to parse against, but the timeline errors collected
            # above still ship in the same report.
            errors.append("scenario.cluster: required key missing")
            raise PayloadValidationError(errors)
        spec = cls(
            cluster=ClusterSpec.from_dict(d["cluster"], "cluster", errors),
            timeline=timeline,
            name=_get(d, "name", (str,), "scenario", errors, default="scenario"),
        )
        if errors:
            # Best-effort semantic pass so the caller sees structural and
            # semantic problems in one shot (payload-layer convention).
            try:
                spec.validate()
            except PayloadValidationError as semantic:
                errors.extend(e for e in semantic.errors if e not in errors)
            raise PayloadValidationError(errors)
        return spec.validate()

    def to_json(self, indent: Optional[int] = None) -> str:
        return json.dumps(self.to_dict(), indent=indent, sort_keys=True)

    @classmethod
    def from_json(cls, s: str) -> "ScenarioSpec":
        return cls.from_dict(json.loads(s))


# -- the trace -------------------------------------------------------------------


@dataclasses.dataclass
class ScenarioTraceEntry:
    """Steady state after one timeline event was applied."""

    step: int
    event: Dict[str, Any]             # the event's to_dict form
    outcome: Dict[str, Any]           # what Nimbus.apply returned
    #: topology_id -> {sink_throughput, spout_rate, binding, latency_s,
    #:                 machines_used, thrashed_nodes}
    topologies: Dict[str, Dict[str, Any]]
    network_cost: Dict[str, float]    # topology_id -> netDist sum
    unplaced: Dict[str, List[str]]    # topology_id -> currently unassigned
    machines_used: int                # live nodes hosting >= 1 task
    alive_nodes: int

    def to_dict(self) -> Dict[str, Any]:
        return {
            "step": self.step,
            "event": self.event,
            "outcome": self.outcome,
            "topologies": self.topologies,
            "network_cost": dict(self.network_cost),
            "unplaced": {t: list(v) for t, v in self.unplaced.items()},
            "machines_used": self.machines_used,
            "alive_nodes": self.alive_nodes,
        }


@dataclasses.dataclass
class ScenarioTrace:
    """The replay's full record: one entry per timeline step.

    Deterministic — replaying the same ``ScenarioSpec`` (or its JSON) yields
    the identical ``to_dict()`` — so traces are goldens, diffable across
    schedulers and commits.  Wall-clock scheduling times inside embedded
    plans are scrubbed to 0.0 to keep that property.
    """

    scenario: str
    entries: List[ScenarioTraceEntry] = dataclasses.field(default_factory=list)

    def to_dict(self) -> Dict[str, Any]:
        return {
            "scenario": self.scenario,
            "entries": [e.to_dict() for e in self.entries],
        }

    def to_json(self, indent: Optional[int] = None) -> str:
        return json.dumps(self.to_dict(), indent=indent, sort_keys=True)

    def throughput(self, topology_id: str) -> List[Optional[float]]:
        """Per-interval sink throughput of one topology (None before submit
        / after kill) — the paper's y-axis over scenario time."""
        return [
            e.topologies.get(topology_id, {}).get("sink_throughput")
            for e in self.entries
        ]

    def final(self) -> Optional[ScenarioTraceEntry]:
        return self.entries[-1] if self.entries else None

    def final_throughput(self) -> Dict[str, float]:
        last = self.final()
        if last is None:
            return {}
        return {
            tid: metrics["sink_throughput"]
            for tid, metrics in last.topologies.items()
        }


# -- the runner ------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class ReactiveRebalanceMarker:
    """Synthetic trace marker for a policy-triggered rebalance.

    Not a timeline event — it cannot be authored into a ``ScenarioSpec``
    (it's absent from ``EVENT_TYPES``); it appears in traces only when a
    ``ReconfigPolicy`` fires, recording which timeline step's observations
    triggered it."""

    trigger_step: int
    kind: ClassVar[str] = "reactive_rebalance"

    def to_dict(self) -> Dict[str, Any]:
        return {"kind": self.kind, "trigger_step": self.trigger_step}


class ScenarioRunner:
    """Replay a ``ScenarioSpec`` through one ``Nimbus``, re-simulating joint
    steady state after every event.

    ``warm_start`` (default on) feeds each interval's solved spout rates into
    the next interval's solver — the incremental re-entry that makes long
    churn timelines cheap; turn it off to re-solve each interval cold.

    ``engine`` selects the per-interval referee: the steady-state fixed-point
    solver (default) or the discrete-event tuple-level executor
    (``engine="des"``, optionally with a ``DesSettings``/``DesConfig`` in
    ``des``).  DES intervals additionally carry latency percentiles in the
    trace; warm starts don't apply (every interval is a full packet run).

    ``hub`` opts into deterministic telemetry: each replay step becomes a
    ``scenario.step`` span, and per-interval cluster state is published as
    step-keyed series (``scenario.sink_throughput``, ``scenario.network_cost``,
    ``scenario.machines_used``, ``scenario.alive_nodes``) alongside whatever
    the scheduler/referee record under the same hub.  The trace itself is
    unchanged — telemetry rides next to it, never inside it.

    ``reconfig``/``reconfig_kwargs`` select how the replayed Nimbus
    re-places tasks on rebalance/join (``"greedy"`` default — existing
    traces replay bit-identically; ``"search"`` anneals migration ×
    placement).  ``policy`` opts into DRS-style reactive reconfiguration: a
    ``core.reconfig.ReconfigPolicy`` observed against the hub after every
    step; when it fires, the runner rebalances, re-simulates, and appends a
    ``reactive_rebalance`` entry to the trace.  The policy reads the DES
    executor's utilization/queue series, so it needs ``engine="des"`` and
    an enabled hub to ever trigger.
    """

    def __init__(
        self,
        spec: ScenarioSpec,
        warm_start: bool = True,
        engine: str = "solver",
        des=None,
        hub: Optional[MetricsHub] = None,
        reconfig: str = "greedy",
        reconfig_kwargs: Optional[Mapping[str, Any]] = None,
        policy=None,
    ):
        from ..core.reconfig import validate_reconfig

        if engine not in ("solver", "des"):
            raise ValueError(f"engine must be 'solver' or 'des', got {engine!r}")
        errors = validate_reconfig(reconfig, reconfig_kwargs)
        if errors:
            raise PayloadValidationError(errors)
        self.spec = spec.validate()
        self.warm_start = warm_start
        self.engine = engine
        self.des = des
        self.hub = hub
        self.reconfig = reconfig
        self.reconfig_kwargs = (
            dict(reconfig_kwargs) if reconfig_kwargs is not None else None
        )
        self.policy = policy

    def run(self) -> ScenarioTrace:
        hub = self.hub if self.hub is not None else get_hub()
        with hub.activate():
            return self._run(hub)

    def _run(self, hub: MetricsHub) -> ScenarioTrace:
        nimbus = Nimbus(
            self.spec.cluster,
            reconfig=self.reconfig,
            reconfig_kwargs=self.reconfig_kwargs,
        )
        trace = ScenarioTrace(scenario=self.spec.name)
        rates: Dict[str, float] = {}
        for step, event in enumerate(self.spec.timeline):
            with hub.span("scenario.step", step=step, kind=event.kind):
                try:
                    outcome = nimbus.apply(event)
                except Exception as e:
                    # Static validation can't catch everything (e.g. a submit
                    # that turns out unschedulable); name the failing step.
                    raise ScenarioReplayError(
                        f"applying {event.kind!r}: {type(e).__name__}: {e}",
                        step=step,
                    ) from e
                sims = nimbus.simulate_all(
                    warm_start=rates if self.warm_start else None,
                    engine=self.engine,
                    des=self.des,
                )
            rates = {tid: r.spout_rate for tid, r in sims.items()}
            entry = self._entry(step, event, outcome, nimbus, sims)
            trace.entries.append(entry)
            if hub.enabled:
                self._record_obs(hub, entry)
            if self.policy is not None and self.policy.observe(hub):
                # Reactive reconfiguration: the observed interval looked
                # imbalanced for long enough — rebalance now, re-simulate,
                # and record the extra interval.  The marker shares the
                # triggering step number so trace consumers can line the
                # pair up against the timeline.
                marker = ReactiveRebalanceMarker(trigger_step=step)
                with hub.span(
                    "scenario.reactive_rebalance", step=step
                ):
                    outcome = nimbus.rebalance().to_dict()
                    sims = nimbus.simulate_all(
                        warm_start=rates if self.warm_start else None,
                        engine=self.engine,
                        des=self.des,
                    )
                rates = {tid: r.spout_rate for tid, r in sims.items()}
                entry = self._entry(step, marker, outcome, nimbus, sims)
                trace.entries.append(entry)
                if hub.enabled:
                    self._record_obs(hub, entry)
        return trace

    def _record_obs(self, hub: MetricsHub, entry: "ScenarioTraceEntry") -> None:
        """Publish one interval's cluster state as step-keyed series."""
        name = self.spec.name
        hub.series("scenario.machines_used", scenario=name).append(
            entry.step, entry.machines_used
        )
        hub.series("scenario.alive_nodes", scenario=name).append(
            entry.step, entry.alive_nodes
        )
        for tid in sorted(entry.topologies):
            hub.series(
                "scenario.sink_throughput", scenario=name, topology=tid
            ).append(entry.step, float(entry.topologies[tid]["sink_throughput"]))
        for tid in sorted(entry.network_cost):
            hub.series(
                "scenario.network_cost", scenario=name, topology=tid
            ).append(entry.step, float(entry.network_cost[tid]))

    def _entry(self, step, event, outcome, nimbus: Nimbus, sims) -> ScenarioTraceEntry:
        state, cluster = nimbus.state, nimbus.cluster
        topo_metrics: Dict[str, Dict[str, Any]] = {}
        net_cost: Dict[str, float] = {}
        unplaced: Dict[str, List[str]] = {}
        used_nodes: set = set()
        for tid in sorted(state.topologies):
            topology = state.topologies[tid]
            assignment = state.assignments[tid]
            res = sims.get(tid)
            if res is not None:
                metrics = {
                    "sink_throughput": res.sink_throughput,
                    "spout_rate": res.spout_rate,
                    "binding": res.binding,
                    "latency_s": res.latency_s,
                    "machines_used": res.machines_used,
                    "thrashed_nodes": list(res.thrashed_nodes),
                }
                # DES reports carry measured latency percentiles; solver
                # results don't, and solver traces stay byte-identical.
                for key in ("p50_latency_s", "p95_latency_s", "p99_latency_s"):
                    v = getattr(res, key, None)
                    if v is not None:
                        metrics[key] = v
                topo_metrics[tid] = metrics
            net_cost[tid] = assignment.network_cost(topology, cluster, live_only=True)
            if assignment.unassigned:
                unplaced[tid] = sorted(assignment.unassigned)
            used_nodes.update(
                nid
                for nid in assignment.placements.values()
                if cluster.nodes[nid].alive
            )
        return ScenarioTraceEntry(
            step=step,
            event=event.to_dict(),
            outcome=outcome,
            topologies=topo_metrics,
            network_cost=net_cost,
            unplaced=unplaced,
            machines_used=len(used_nodes),
            alive_nodes=len(cluster.live_nodes()),
        )


def run_scenario(
    spec: ScenarioSpec,
    warm_start: bool = True,
    engine: str = "solver",
    des=None,
    hub: Optional[MetricsHub] = None,
    reconfig: str = "greedy",
    reconfig_kwargs: Optional[Mapping[str, Any]] = None,
    policy=None,
) -> ScenarioTrace:
    """One-shot convenience: validate + replay a scenario."""
    return ScenarioRunner(
        spec,
        warm_start=warm_start,
        engine=engine,
        des=des,
        hub=hub,
        reconfig=reconfig,
        reconfig_kwargs=reconfig_kwargs,
        policy=policy,
    ).run()
