"""Errors raised by the control-plane API."""

from __future__ import annotations

from typing import List, Optional, Sequence


class PayloadValidationError(ValueError):
    """A SchedulingPayload failed upfront validation.

    Carries every problem found (not just the first) as path-tagged,
    actionable messages, e.g. ``topology.edges[2].dst: unknown component
    'bollt3' (components: ['bolt3', 'spout'])``.
    """

    def __init__(self, errors: Sequence[str]):
        self.errors: List[str] = list(errors)
        super().__init__(
            "invalid SchedulingPayload:\n  - " + "\n  - ".join(self.errors)
        )


class ScenarioReplayError(RuntimeError):
    """A scenario event could not be applied to the live cluster state.

    Raised by ``Nimbus.apply`` for events that are structurally valid but
    impossible in the current state (unknown event kind, no cluster
    established, an event referencing state the timeline never created).
    ``ScenarioSpec.validate`` catches the statically-detectable cases before
    any replay starts; this error covers the dynamic remainder.
    """

    def __init__(self, message: str, step: Optional[int] = None):
        self.step = step
        prefix = f"timeline[{step}]: " if step is not None else ""
        super().__init__(prefix + message)


class UnschedulablePayloadError(RuntimeError):
    """A valid payload could not be fully placed and the payload's
    ``RunSettings.allow_partial`` is False.  Raised by ``Nimbus.submit``
    *before* any cluster mutation — the plan is discarded whole."""

    def __init__(self, topology_id: str, unassigned: Sequence[str]):
        self.topology_id = topology_id
        self.unassigned = list(unassigned)
        super().__init__(
            f"topology {topology_id!r}: {len(self.unassigned)} task(s) could not "
            f"be placed ({self.unassigned[:5]}{'...' if len(self.unassigned) > 5 else ''}); "
            "payload has allow_partial=False, nothing was committed"
        )
