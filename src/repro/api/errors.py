"""Errors raised by the control-plane API."""

from __future__ import annotations

from typing import List, Sequence


class PayloadValidationError(ValueError):
    """A SchedulingPayload failed upfront validation.

    Carries every problem found (not just the first) as path-tagged,
    actionable messages, e.g. ``topology.edges[2].dst: unknown component
    'bollt3' (components: ['bolt3', 'spout'])``.
    """

    def __init__(self, errors: Sequence[str]):
        self.errors: List[str] = list(errors)
        super().__init__(
            "invalid SchedulingPayload:\n  - " + "\n  - ".join(self.errors)
        )


class UnschedulablePayloadError(RuntimeError):
    """A valid payload could not be fully placed and the payload's
    ``RunSettings.allow_partial`` is False.  Raised by ``Nimbus.submit``
    *before* any cluster mutation — the plan is discarded whole."""

    def __init__(self, topology_id: str, unassigned: Sequence[str]):
        self.topology_id = topology_id
        self.unassigned = list(unassigned)
        super().__init__(
            f"topology {topology_id!r}: {len(self.unassigned)} task(s) could not "
            f"be placed ({self.unassigned[:5]}{'...' if len(self.unassigned) > 5 else ''}); "
            "payload has allow_partial=False, nothing was committed"
        )
