"""Declarative control-plane contract: one validated SchedulingPayload.

Modeled on AsyncFlow's ``SimulationPayload`` design: a single self-contained
input object joining the workload (``TopologySpec``), the environment
(``ClusterSpec``), the policy (``SchedulerSpec``) and ``RunSettings`` — with
strict upfront validation and a lossless dict/JSON round-trip, so whole
scheduling scenarios become data, not hand-wired Python.

Every validation problem is reported (not just the first) with a path-tagged,
actionable message, and a malformed payload is always rejected before any
cluster state is touched.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Mapping, Optional, Tuple

from ..core.cluster import Cluster, NodeSpec, emulab_cluster, emulab_cluster_24
from ..core.registry import validate_scheduler_kwargs
from ..core.topology import Component, Topology
from .errors import PayloadValidationError

_GROUPINGS = ("shuffle", "local_or_shuffle")

#: Named cluster presets (the paper's Emulab environments, §6.1 / §6.5).
CLUSTER_PRESETS = {
    "emulab_12": emulab_cluster,
    "emulab_24": emulab_cluster_24,
}


# -- parsing helpers -----------------------------------------------------------

_MISSING = object()


def _check_keys(
    d: Mapping, path: str, allowed: Tuple[str, ...], errors: List[str]
) -> None:
    unknown = sorted(set(d) - set(allowed))
    if unknown:
        errors.append(f"{path}: unknown key(s) {unknown}; allowed: {sorted(allowed)}")


def _get(
    d: Mapping,
    key: str,
    types: Tuple[type, ...],
    path: str,
    errors: List[str],
    default: Any = _MISSING,
    allow_none: bool = False,
):
    """Fetch + type-check one key; coerce int->float where float is expected."""
    if key not in d:
        if default is _MISSING:
            errors.append(f"{path}.{key}: required key missing")
            return None
        return default
    value = d[key]
    if value is None and allow_none:
        return None
    if isinstance(value, bool) and bool not in types:
        errors.append(f"{path}.{key}: expected {_names(types)}, got bool ({value!r})")
        return default if default is not _MISSING else None
    if isinstance(value, int) and float in types and int not in types:
        value = float(value)
    if not isinstance(value, types):
        errors.append(
            f"{path}.{key}: expected {_names(types)}, got "
            f"{type(value).__name__} ({value!r})"
        )
        return default if default is not _MISSING else None
    return value


def _names(types: Tuple[type, ...]) -> str:
    return "|".join(t.__name__ for t in types)


def _require_mapping(obj: Any, path: str) -> Dict:
    if not isinstance(obj, Mapping):
        raise PayloadValidationError(
            [f"{path}: expected a mapping, got {type(obj).__name__}"]
        )
    return dict(obj)


# -- component / edge / topology ------------------------------------------------


@dataclasses.dataclass(frozen=True)
class ComponentSpec:
    """One spout/bolt: parallelism + per-instance resource loads (paper §5.2)."""

    id: str
    is_spout: bool = False
    parallelism: int = 1
    memory_load_mb: float = 128.0
    cpu_load: float = 10.0
    bandwidth_load: float = 0.0
    emit_ratio: float = 1.0
    tuple_bytes: float = 100.0
    cpu_cost_per_tuple: Optional[float] = None
    max_rate_per_task: Optional[float] = None

    _FIELDS = (
        "id",
        "is_spout",
        "parallelism",
        "memory_load_mb",
        "cpu_load",
        "bandwidth_load",
        "emit_ratio",
        "tuple_bytes",
        "cpu_cost_per_tuple",
        "max_rate_per_task",
    )

    def validate(self, path: str) -> List[str]:
        errors: List[str] = []
        if not isinstance(self.id, str) or not self.id:
            errors.append(f"{path}.id: must be a non-empty string, got {self.id!r}")
        if not isinstance(self.parallelism, int) or self.parallelism < 1:
            errors.append(
                f"{path}.parallelism: must be an int >= 1, got {self.parallelism!r}"
            )
        for name in ("memory_load_mb", "cpu_load", "bandwidth_load", "emit_ratio", "tuple_bytes"):
            v = getattr(self, name)
            if not isinstance(v, (int, float)) or v < 0:
                errors.append(f"{path}.{name}: must be a number >= 0, got {v!r}")
        for name in ("cpu_cost_per_tuple", "max_rate_per_task"):
            v = getattr(self, name)
            if v is not None and (not isinstance(v, (int, float)) or v <= 0):
                errors.append(f"{path}.{name}: must be null or a number > 0, got {v!r}")
        return errors

    def to_dict(self) -> Dict[str, Any]:
        return {f: getattr(self, f) for f in self._FIELDS}

    @classmethod
    def from_dict(cls, d: Any, path: str, errors: List[str]) -> "ComponentSpec":
        d = dict(_require_mapping(d, path))
        _check_keys(d, path, cls._FIELDS, errors)
        return cls(
            id=_get(d, "id", (str,), path, errors, default=""),
            is_spout=_get(d, "is_spout", (bool,), path, errors, default=False),
            parallelism=_get(d, "parallelism", (int,), path, errors, default=1),
            memory_load_mb=_get(d, "memory_load_mb", (float,), path, errors, default=128.0),
            cpu_load=_get(d, "cpu_load", (float,), path, errors, default=10.0),
            bandwidth_load=_get(d, "bandwidth_load", (float,), path, errors, default=0.0),
            emit_ratio=_get(d, "emit_ratio", (float,), path, errors, default=1.0),
            tuple_bytes=_get(d, "tuple_bytes", (float,), path, errors, default=100.0),
            cpu_cost_per_tuple=_get(
                d, "cpu_cost_per_tuple", (float,), path, errors, default=None, allow_none=True
            ),
            max_rate_per_task=_get(
                d, "max_rate_per_task", (float,), path, errors, default=None, allow_none=True
            ),
        )

    def to_component(self) -> Component:
        comp = Component(
            self.id,
            is_spout=self.is_spout,
            parallelism=self.parallelism,
            emit_ratio=self.emit_ratio,
            tuple_bytes=self.tuple_bytes,
            cpu_cost_per_tuple=self.cpu_cost_per_tuple,
            max_rate_per_task=self.max_rate_per_task,
        )
        comp.set_memory_load(self.memory_load_mb)
        comp.set_cpu_load(self.cpu_load)
        comp.set_bandwidth_load(self.bandwidth_load)
        return comp

    @classmethod
    def from_component(cls, comp: Component) -> "ComponentSpec":
        return cls(
            id=comp.id,
            is_spout=comp.is_spout,
            parallelism=comp.parallelism,
            memory_load_mb=comp.memory_load,
            cpu_load=comp.cpu_load,
            bandwidth_load=comp.bandwidth_load,
            emit_ratio=comp.emit_ratio,
            tuple_bytes=comp.tuple_bytes,
            cpu_cost_per_tuple=comp.cpu_cost_per_tuple,
            max_rate_per_task=comp.max_rate_per_task,
        )


@dataclasses.dataclass(frozen=True)
class EdgeSpec:
    """A directed stream edge with its Storm grouping."""

    src: str
    dst: str
    grouping: str = "shuffle"

    _FIELDS = ("src", "dst", "grouping")

    def to_dict(self) -> Dict[str, Any]:
        return {"src": self.src, "dst": self.dst, "grouping": self.grouping}

    @classmethod
    def from_dict(cls, d: Any, path: str, errors: List[str]) -> "EdgeSpec":
        d = dict(_require_mapping(d, path))
        _check_keys(d, path, cls._FIELDS, errors)
        return cls(
            src=_get(d, "src", (str,), path, errors, default=""),
            dst=_get(d, "dst", (str,), path, errors, default=""),
            grouping=_get(d, "grouping", (str,), path, errors, default="shuffle"),
        )


@dataclasses.dataclass(frozen=True)
class TopologySpec:
    """The declarative form of a Storm topology DAG."""

    id: str
    components: Tuple[ComponentSpec, ...]
    edges: Tuple[EdgeSpec, ...] = ()
    max_spout_pending: int = 1000
    acked: bool = True

    _FIELDS = ("id", "components", "edges", "max_spout_pending", "acked")

    def validate(self, path: str = "topology") -> List[str]:
        errors: List[str] = []
        if not isinstance(self.id, str) or not self.id:
            errors.append(f"{path}.id: must be a non-empty string, got {self.id!r}")
        if not self.components:
            errors.append(f"{path}.components: at least one component required")
        seen: set = set()
        for i, comp in enumerate(self.components):
            errors.extend(comp.validate(f"{path}.components[{i}]"))
            if comp.id in seen:
                errors.append(
                    f"{path}.components[{i}].id: duplicate component id {comp.id!r}"
                )
            seen.add(comp.id)
        known = sorted(seen)
        if self.components and not any(c.is_spout for c in self.components):
            errors.append(f"{path}.components: topology has no spout")
        if not isinstance(self.max_spout_pending, int) or self.max_spout_pending < 1:
            errors.append(
                f"{path}.max_spout_pending: must be an int >= 1, "
                f"got {self.max_spout_pending!r}"
            )
        seen_edges: set = set()
        adj: Dict[str, List[str]] = {cid: [] for cid in known}
        for i, e in enumerate(self.edges):
            epath = f"{path}.edges[{i}]"
            for end in ("src", "dst"):
                cid = getattr(e, end)
                if cid not in seen:
                    errors.append(
                        f"{epath}.{end}: unknown component {cid!r} (components: {known})"
                    )
            if e.src == e.dst:
                errors.append(f"{epath}: self-loop {e.src!r} -> {e.dst!r} is not a valid stream")
            if e.grouping not in _GROUPINGS:
                errors.append(
                    f"{epath}.grouping: unknown grouping {e.grouping!r}; "
                    f"allowed: {list(_GROUPINGS)}"
                )
            if (e.src, e.dst) in seen_edges:
                errors.append(f"{epath}: duplicate edge {e.src!r} -> {e.dst!r}")
            seen_edges.add((e.src, e.dst))
            if e.src in adj and e.dst in adj and e.src != e.dst:
                adj[e.src].append(e.dst)
        if not errors:
            errors.extend(self._validate_graph(path, adj))
        return errors

    def _validate_graph(self, path: str, adj: Dict[str, List[str]]) -> List[str]:
        """Cycle + reachability checks (the simulator requires a DAG and the
        scheduler's BFS traversal requires spout-reachability)."""
        errors: List[str] = []
        indeg = {cid: 0 for cid in adj}
        for srcs in adj.values():
            for dst in srcs:
                indeg[dst] += 1
        frontier = sorted(cid for cid, d in indeg.items() if d == 0)
        order: List[str] = []
        while frontier:
            cid = frontier.pop(0)
            order.append(cid)
            for dst in adj[cid]:
                indeg[dst] -= 1
                if indeg[dst] == 0:
                    frontier.append(dst)
        if len(order) != len(adj):
            cyclic = sorted(set(adj) - set(order))
            errors.append(
                f"{path}.edges: cycle detected involving components {cyclic}; "
                "topologies must be DAGs"
            )
            return errors
        reached = {c.id for c in self.components if c.is_spout}
        frontier = sorted(reached)
        while frontier:
            nxt = []
            for cid in frontier:
                for dst in adj.get(cid, []):
                    if dst not in reached:
                        reached.add(dst)
                        nxt.append(dst)
            frontier = nxt
        unreachable = sorted(set(adj) - reached)
        if unreachable:
            errors.append(
                f"{path}: components unreachable from any spout: {unreachable}; "
                "the topology graph is disconnected"
            )
        return errors

    def to_dict(self) -> Dict[str, Any]:
        return {
            "id": self.id,
            "components": [c.to_dict() for c in self.components],
            "edges": [e.to_dict() for e in self.edges],
            "max_spout_pending": self.max_spout_pending,
            "acked": self.acked,
        }

    @classmethod
    def from_dict(cls, d: Any, path: str, errors: List[str]) -> "TopologySpec":
        d = dict(_require_mapping(d, path))
        _check_keys(d, path, cls._FIELDS, errors)
        raw_components = _get(d, "components", (list, tuple), path, errors, default=())
        raw_edges = _get(d, "edges", (list, tuple), path, errors, default=())
        components = tuple(
            ComponentSpec.from_dict(c, f"{path}.components[{i}]", errors)
            for i, c in enumerate(raw_components or ())
        )
        edges = tuple(
            EdgeSpec.from_dict(e, f"{path}.edges[{i}]", errors)
            for i, e in enumerate(raw_edges or ())
        )
        return cls(
            id=_get(d, "id", (str,), path, errors, default=""),
            components=components,
            edges=edges,
            max_spout_pending=_get(d, "max_spout_pending", (int,), path, errors, default=1000),
            acked=_get(d, "acked", (bool,), path, errors, default=True),
        )

    def to_topology(self) -> Topology:
        topo = Topology(self.id)
        for comp in self.components:
            topo.add_component(comp.to_component())
        for e in self.edges:
            topo.add_edge(e.src, e.dst, grouping=e.grouping)
        topo.max_spout_pending = self.max_spout_pending
        topo.acked = self.acked
        return topo

    @classmethod
    def from_topology(cls, topology: Topology) -> "TopologySpec":
        """Lossless capture of a builder-made Topology as data."""
        return cls(
            id=topology.id,
            components=tuple(
                ComponentSpec.from_component(c) for c in topology.components.values()
            ),
            edges=tuple(
                EdgeSpec(src, dst, topology.groupings.get((src, dst), "shuffle"))
                for src, dst in topology.edges
            ),
            max_spout_pending=topology.max_spout_pending,
            acked=topology.acked,
        )


# -- cluster ------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class NodeEntry:
    """One worker node in an explicit ClusterSpec."""

    node_id: str
    rack_id: str
    cpu_capacity: float = 100.0
    memory_capacity_mb: float = 2048.0
    bandwidth_capacity: float = 100.0
    num_worker_slots: int = 4

    _FIELDS = (
        "node_id",
        "rack_id",
        "cpu_capacity",
        "memory_capacity_mb",
        "bandwidth_capacity",
        "num_worker_slots",
    )

    def validate(self, path: str) -> List[str]:
        errors: List[str] = []
        for name in ("node_id", "rack_id"):
            v = getattr(self, name)
            if not isinstance(v, str) or not v:
                errors.append(f"{path}.{name}: must be a non-empty string, got {v!r}")
        for name in ("cpu_capacity", "memory_capacity_mb", "bandwidth_capacity"):
            v = getattr(self, name)
            if not isinstance(v, (int, float)) or v <= 0:
                errors.append(f"{path}.{name}: must be a number > 0, got {v!r}")
        if not isinstance(self.num_worker_slots, int) or self.num_worker_slots < 1:
            errors.append(
                f"{path}.num_worker_slots: must be an int >= 1, "
                f"got {self.num_worker_slots!r}"
            )
        return errors

    def to_dict(self) -> Dict[str, Any]:
        return {f: getattr(self, f) for f in self._FIELDS}

    @classmethod
    def from_dict(cls, d: Any, path: str, errors: List[str]) -> "NodeEntry":
        d = dict(_require_mapping(d, path))
        _check_keys(d, path, cls._FIELDS, errors)
        return cls(
            node_id=_get(d, "node_id", (str,), path, errors, default=""),
            rack_id=_get(d, "rack_id", (str,), path, errors, default=""),
            cpu_capacity=_get(d, "cpu_capacity", (float,), path, errors, default=100.0),
            memory_capacity_mb=_get(
                d, "memory_capacity_mb", (float,), path, errors, default=2048.0
            ),
            bandwidth_capacity=_get(
                d, "bandwidth_capacity", (float,), path, errors, default=100.0
            ),
            num_worker_slots=_get(d, "num_worker_slots", (int,), path, errors, default=4),
        )

    def to_node_spec(self) -> NodeSpec:
        return NodeSpec(
            node_id=self.node_id,
            rack_id=self.rack_id,
            cpu_capacity=self.cpu_capacity,
            memory_capacity_mb=self.memory_capacity_mb,
            bandwidth_capacity=self.bandwidth_capacity,
            num_worker_slots=self.num_worker_slots,
        )


@dataclasses.dataclass(frozen=True)
class ClusterSpec:
    """Declarative cluster description, in exactly one of three forms:

    * ``preset`` — a named environment (``emulab_12``, ``emulab_24``);
    * homogeneous — ``racks`` x ``nodes_per_rack`` identical nodes;
    * explicit — a full ``nodes`` list.
    """

    preset: Optional[str] = None
    nodes: Tuple[NodeEntry, ...] = ()
    racks: Optional[int] = None
    nodes_per_rack: Optional[int] = None
    cpu: float = 100.0
    memory_mb: float = 2048.0
    bandwidth: float = 100.0
    slots: int = 4

    _HOMOGENEOUS_FIELDS = ("racks", "nodes_per_rack", "cpu", "memory_mb", "bandwidth", "slots")

    def mode(self) -> str:
        modes = []
        if self.preset is not None:
            modes.append("preset")
        if self.nodes:
            modes.append("explicit")
        if self.racks is not None or self.nodes_per_rack is not None:
            modes.append("homogeneous")
        if len(modes) != 1:
            return "ambiguous" if modes else "empty"
        return modes[0]

    def validate(self, path: str = "cluster") -> List[str]:
        errors: List[str] = []
        mode = self.mode()
        if mode == "empty":
            return [
                f"{path}: must set exactly one of 'preset', 'nodes', or "
                "'racks'+'nodes_per_rack'"
            ]
        if mode == "ambiguous":
            return [
                f"{path}: 'preset', 'nodes' and 'racks'/'nodes_per_rack' are "
                "mutually exclusive; set exactly one form"
            ]
        if mode == "preset":
            if self.preset not in CLUSTER_PRESETS:
                errors.append(
                    f"{path}.preset: unknown preset {self.preset!r}; "
                    f"available: {sorted(CLUSTER_PRESETS)}"
                )
        elif mode == "explicit":
            seen: set = set()
            for i, node in enumerate(self.nodes):
                errors.extend(node.validate(f"{path}.nodes[{i}]"))
                if node.node_id in seen:
                    errors.append(
                        f"{path}.nodes[{i}].node_id: duplicate node id {node.node_id!r}"
                    )
                seen.add(node.node_id)
        else:  # homogeneous
            for name in ("racks", "nodes_per_rack"):
                v = getattr(self, name)
                if not isinstance(v, int) or v < 1:
                    errors.append(f"{path}.{name}: must be an int >= 1, got {v!r}")
            for name in ("cpu", "memory_mb", "bandwidth"):
                v = getattr(self, name)
                if not isinstance(v, (int, float)) or v <= 0:
                    errors.append(f"{path}.{name}: must be a number > 0, got {v!r}")
            if not isinstance(self.slots, int) or self.slots < 1:
                errors.append(f"{path}.slots: must be an int >= 1, got {self.slots!r}")
        return errors

    def to_dict(self) -> Dict[str, Any]:
        mode = self.mode()
        if mode == "preset":
            return {"preset": self.preset}
        if mode == "explicit":
            return {"nodes": [n.to_dict() for n in self.nodes]}
        return {
            "racks": self.racks,
            "nodes_per_rack": self.nodes_per_rack,
            "cpu": self.cpu,
            "memory_mb": self.memory_mb,
            "bandwidth": self.bandwidth,
            "slots": self.slots,
        }

    @classmethod
    def from_dict(cls, d: Any, path: str, errors: List[str]) -> "ClusterSpec":
        d = dict(_require_mapping(d, path))
        if "preset" in d:
            _check_keys(d, path, ("preset",), errors)
            return cls(preset=_get(d, "preset", (str,), path, errors, default=""))
        if "nodes" in d:
            _check_keys(d, path, ("nodes",), errors)
            raw = _get(d, "nodes", (list, tuple), path, errors, default=())
            return cls(
                nodes=tuple(
                    NodeEntry.from_dict(n, f"{path}.nodes[{i}]", errors)
                    for i, n in enumerate(raw or ())
                )
            )
        _check_keys(d, path, cls._HOMOGENEOUS_FIELDS, errors)
        if not d:
            errors.append(
                f"{path}: must set exactly one of 'preset', 'nodes', or "
                "'racks'+'nodes_per_rack'"
            )
            return cls()
        return cls(
            racks=_get(d, "racks", (int,), path, errors, default=None, allow_none=True),
            nodes_per_rack=_get(
                d, "nodes_per_rack", (int,), path, errors, default=None, allow_none=True
            ),
            cpu=_get(d, "cpu", (float,), path, errors, default=100.0),
            memory_mb=_get(d, "memory_mb", (float,), path, errors, default=2048.0),
            bandwidth=_get(d, "bandwidth", (float,), path, errors, default=100.0),
            slots=_get(d, "slots", (int,), path, errors, default=4),
        )

    def to_cluster(self) -> Cluster:
        mode = self.mode()
        if mode == "preset":
            return CLUSTER_PRESETS[self.preset]()
        if mode == "explicit":
            return Cluster([n.to_node_spec() for n in self.nodes])
        return Cluster.homogeneous(
            racks=self.racks,
            nodes_per_rack=self.nodes_per_rack,
            cpu=self.cpu,
            memory_mb=self.memory_mb,
            bandwidth=self.bandwidth,
            slots=self.slots,
        )

    def describes(self, cluster: Cluster) -> bool:
        """True if this spec materializes to exactly ``cluster``'s node set —
        the semantic equivalence check (a preset and the explicit node list it
        expands to describe the same cluster)."""
        want = {n.spec.node_id: n.spec for n in self.to_cluster().nodes.values()}
        have = {nid: n.spec for nid, n in cluster.nodes.items()}
        return want == have

    @classmethod
    def from_cluster(cls, cluster: Cluster) -> "ClusterSpec":
        """Capture a live Cluster as an explicit node list."""
        return cls(
            nodes=tuple(
                NodeEntry(
                    node_id=n.spec.node_id,
                    rack_id=n.spec.rack_id,
                    cpu_capacity=n.spec.cpu_capacity,
                    memory_capacity_mb=n.spec.memory_capacity_mb,
                    bandwidth_capacity=n.spec.bandwidth_capacity,
                    num_worker_slots=n.spec.num_worker_slots,
                )
                for n in cluster.nodes.values()
            )
        )


# -- scheduler / settings / payload ---------------------------------------------


@dataclasses.dataclass(frozen=True)
class SchedulerSpec:
    """A scheduler by registry name + constructor kwargs (validated against
    the scheduler's registered kwargs schema before instantiation)."""

    name: str
    kwargs: Mapping[str, Any] = dataclasses.field(default_factory=dict)

    _FIELDS = ("name", "kwargs")

    def validate(self, path: str = "scheduler") -> List[str]:
        if not isinstance(self.name, str) or not self.name:
            return [f"{path}.name: must be a non-empty string, got {self.name!r}"]
        if not isinstance(self.kwargs, Mapping):
            return [
                f"{path}.kwargs: expected a mapping, got {type(self.kwargs).__name__}"
            ]
        return validate_scheduler_kwargs(self.name, self.kwargs, path=path)

    def to_dict(self) -> Dict[str, Any]:
        return {"name": self.name, "kwargs": dict(self.kwargs)}

    @classmethod
    def from_dict(cls, d: Any, path: str, errors: List[str]) -> "SchedulerSpec":
        d = dict(_require_mapping(d, path))
        _check_keys(d, path, cls._FIELDS, errors)
        kwargs = _get(d, "kwargs", (dict,), path, errors, default={})
        return cls(
            name=_get(d, "name", (str,), path, errors, default=""),
            kwargs=dict(kwargs or {}),
        )


@dataclasses.dataclass(frozen=True)
class DesSettings:
    """Serialized knobs of the discrete-event executor (``stream.des``).

    Mirrors ``stream.des.DesConfig`` field for field (``to_config`` converts)
    so a payload/scenario can pin a DES run — duration, arrival process,
    queue bounds, seed — as data.
    """

    duration_s: float = 0.5
    warmup_frac: float = 0.3
    queue_capacity: int = 128
    seed: int = 0
    arrival: str = "uniform"
    burst_factor: float = 8.0
    burst_period_s: float = 0.25
    bucket_s: float = 0.05
    open_loop_rate: float = 5000.0
    backpressure: str = "auto"
    service: str = "exponential"

    _FIELDS = (
        "duration_s", "warmup_frac", "queue_capacity", "seed", "arrival",
        "burst_factor", "burst_period_s", "bucket_s", "open_loop_rate",
        "backpressure", "service",
    )
    _ARRIVALS = ("uniform", "poisson", "bursty")
    _BACKPRESSURE = ("auto", "credit", "drop")
    _SERVICE = ("exponential", "deterministic")

    def validate(self, path: str = "settings.des") -> List[str]:
        errors: List[str] = []
        for name in ("duration_s", "burst_period_s", "bucket_s", "open_loop_rate"):
            v = getattr(self, name)
            if not isinstance(v, (int, float)) or isinstance(v, bool) or v <= 0:
                errors.append(f"{path}.{name}: must be a positive number, got {v!r}")
        if not isinstance(self.warmup_frac, (int, float)) or isinstance(
            self.warmup_frac, bool
        ) or not 0.0 <= self.warmup_frac < 1.0:
            errors.append(
                f"{path}.warmup_frac: must be in [0, 1), got {self.warmup_frac!r}"
            )
        if not isinstance(self.queue_capacity, int) or isinstance(
            self.queue_capacity, bool
        ) or self.queue_capacity < 1:
            errors.append(
                f"{path}.queue_capacity: must be an int >= 1, "
                f"got {self.queue_capacity!r}"
            )
        if not isinstance(self.seed, int) or isinstance(self.seed, bool) or (
            self.seed < 0
        ):
            errors.append(f"{path}.seed: must be an int >= 0, got {self.seed!r}")
        if not isinstance(self.burst_factor, (int, float)) or isinstance(
            self.burst_factor, bool
        ) or self.burst_factor < 1.0:
            errors.append(
                f"{path}.burst_factor: must be >= 1, got {self.burst_factor!r}"
            )
        for name, allowed in (
            ("arrival", self._ARRIVALS),
            ("backpressure", self._BACKPRESSURE),
            ("service", self._SERVICE),
        ):
            v = getattr(self, name)
            if v not in allowed:
                errors.append(
                    f"{path}.{name}: must be one of {list(allowed)}, got {v!r}"
                )
        return errors

    def to_config(self):
        """The engine-side ``stream.des.DesConfig`` this spec pins."""
        from ..stream.des import DesConfig  # local: stream imports api lazily

        return DesConfig(
            duration_s=float(self.duration_s),
            warmup_frac=float(self.warmup_frac),
            queue_capacity=self.queue_capacity,
            seed=self.seed,
            arrival=self.arrival,
            burst_factor=float(self.burst_factor),
            burst_period_s=float(self.burst_period_s),
            bucket_s=float(self.bucket_s),
            open_loop_rate=float(self.open_loop_rate),
            backpressure=self.backpressure,
            service=self.service,
        )

    def to_dict(self) -> Dict[str, Any]:
        return {f: getattr(self, f) for f in self._FIELDS}

    @classmethod
    def from_dict(cls, d: Any, path: str, errors: List[str]) -> "DesSettings":
        d = dict(_require_mapping(d, path))
        _check_keys(d, path, cls._FIELDS, errors)
        return cls(
            duration_s=_get(d, "duration_s", (float,), path, errors, default=0.5),
            warmup_frac=_get(d, "warmup_frac", (float,), path, errors, default=0.3),
            queue_capacity=_get(
                d, "queue_capacity", (int,), path, errors, default=128
            ),
            seed=_get(d, "seed", (int,), path, errors, default=0),
            arrival=_get(d, "arrival", (str,), path, errors, default="uniform"),
            burst_factor=_get(
                d, "burst_factor", (float,), path, errors, default=8.0
            ),
            burst_period_s=_get(
                d, "burst_period_s", (float,), path, errors, default=0.25
            ),
            bucket_s=_get(d, "bucket_s", (float,), path, errors, default=0.05),
            open_loop_rate=_get(
                d, "open_loop_rate", (float,), path, errors, default=5000.0
            ),
            backpressure=_get(
                d, "backpressure", (str,), path, errors, default="auto"
            ),
            service=_get(d, "service", (str,), path, errors, default="exponential"),
        )


@dataclasses.dataclass(frozen=True)
class ObsSettings:
    """Declarative opt-in to the ``repro.obs`` telemetry plane.

    ``enabled`` — activate a ``MetricsHub`` for the submission so the
    scheduler, the chosen referee and the control plane publish metrics
    and spans into it.
    ``export_path`` — write the hub's deterministic JSONL there after the
    run (consumed by ``python -m repro.obs.report``).
    ``include_wall`` — also export wall-clock span durations; off by
    default because wall times break byte-identical goldens.
    """

    enabled: bool = True
    export_path: Optional[str] = None
    include_wall: bool = False

    _FIELDS = ("enabled", "export_path", "include_wall")

    def validate(self, path: str = "settings.obs") -> List[str]:
        errors: List[str] = []
        for name in ("enabled", "include_wall"):
            v = getattr(self, name)
            if not isinstance(v, bool):
                errors.append(f"{path}.{name}: must be a bool, got {v!r}")
        if self.export_path is not None and (
            not isinstance(self.export_path, str) or not self.export_path
        ):
            errors.append(
                f"{path}.export_path: must be null or a non-empty string, "
                f"got {self.export_path!r}"
            )
        return errors

    def to_dict(self) -> Dict[str, Any]:
        out: Dict[str, Any] = {"enabled": self.enabled}
        if self.export_path is not None:
            out["export_path"] = self.export_path
        if self.include_wall:
            out["include_wall"] = self.include_wall
        return out

    @classmethod
    def from_dict(cls, d: Any, path: str, errors: List[str]) -> "ObsSettings":
        d = dict(_require_mapping(d, path))
        _check_keys(d, path, cls._FIELDS, errors)
        return cls(
            enabled=_get(d, "enabled", (bool,), path, errors, default=True),
            export_path=_get(
                d, "export_path", (str,), path, errors, default=None, allow_none=True
            ),
            include_wall=_get(
                d, "include_wall", (bool,), path, errors, default=False
            ),
        )


@dataclasses.dataclass(frozen=True)
class RunSettings:
    """Per-submission knobs.

    ``allow_partial`` — accept plans with unassigned tasks (False makes
    ``Nimbus.submit`` reject an incomplete plan before any mutation).
    ``simulate`` — attach a simulation result to the plan.
    ``sim_engine`` — which referee ``simulate`` uses: the steady-state
    fixed-point solver ("solver") or the discrete-event tuple-level
    executor ("des").
    ``ack_overhead_s`` / ``thrash_factor`` / ``tuple_timeout_s`` — the
    mechanism constants both referees read (defaults mirror
    ``stream.simulator``'s module constants; a test pins the sync), so a
    payload can pin Storm's acker round-trip, the memory-thrash penalty and
    the message timeout as data instead of relying on hard-coded defaults.
    ``des`` — optional ``DesSettings`` pinning the DES run itself.
    ``obs`` — optional ``ObsSettings`` turning on deterministic telemetry
    (metrics + spans, optional JSONL export) for the submission.

    Serialization is sparse: only non-default knobs are emitted, so
    payloads written before a knob existed round-trip byte-identically.
    """

    allow_partial: bool = True
    simulate: bool = False
    sim_engine: str = "solver"
    ack_overhead_s: float = 5e-3   # stream.simulator.ACK_OVERHEAD_S
    thrash_factor: float = 0.002   # stream.simulator.THRASH_FACTOR
    tuple_timeout_s: float = 30.0  # stream.simulator.TUPLE_TIMEOUT_S
    des: Optional[DesSettings] = None
    obs: Optional[ObsSettings] = None

    _FIELDS = (
        "allow_partial", "simulate", "sim_engine", "ack_overhead_s",
        "thrash_factor", "tuple_timeout_s", "des", "obs",
    )
    _ENGINES = ("solver", "des")

    def validate(self, path: str = "settings") -> List[str]:
        errors: List[str] = []
        for name in ("allow_partial", "simulate"):
            v = getattr(self, name)
            if not isinstance(v, bool):
                errors.append(f"{path}.{name}: must be a bool, got {v!r}")
        if self.sim_engine not in self._ENGINES:
            errors.append(
                f"{path}.sim_engine: must be one of {list(self._ENGINES)}, "
                f"got {self.sim_engine!r}"
            )
        for name in ("ack_overhead_s", "thrash_factor", "tuple_timeout_s"):
            v = getattr(self, name)
            if not isinstance(v, (int, float)) or isinstance(v, bool) or v <= 0:
                errors.append(f"{path}.{name}: must be a positive number, got {v!r}")
        if self.des is not None:
            if isinstance(self.des, DesSettings):
                errors.extend(self.des.validate(f"{path}.des"))
            else:
                errors.append(
                    f"{path}.des: expected DesSettings or null, got {self.des!r}"
                )
        if self.obs is not None:
            if isinstance(self.obs, ObsSettings):
                errors.extend(self.obs.validate(f"{path}.obs"))
            else:
                errors.append(
                    f"{path}.obs: expected ObsSettings or null, got {self.obs!r}"
                )
        return errors

    def to_dict(self) -> Dict[str, Any]:
        out: Dict[str, Any] = {
            "allow_partial": self.allow_partial,
            "simulate": self.simulate,
        }
        if self.sim_engine != "solver":
            out["sim_engine"] = self.sim_engine
        if self.ack_overhead_s != 5e-3:
            out["ack_overhead_s"] = self.ack_overhead_s
        if self.thrash_factor != 0.002:
            out["thrash_factor"] = self.thrash_factor
        if self.tuple_timeout_s != 30.0:
            out["tuple_timeout_s"] = self.tuple_timeout_s
        if self.des is not None:
            out["des"] = self.des.to_dict()
        if self.obs is not None:
            out["obs"] = self.obs.to_dict()
        return out

    @classmethod
    def from_dict(cls, d: Any, path: str, errors: List[str]) -> "RunSettings":
        d = dict(_require_mapping(d, path))
        _check_keys(d, path, cls._FIELDS, errors)
        des = d.get("des")
        obs = d.get("obs")
        return cls(
            allow_partial=_get(d, "allow_partial", (bool,), path, errors, default=True),
            simulate=_get(d, "simulate", (bool,), path, errors, default=False),
            sim_engine=_get(d, "sim_engine", (str,), path, errors, default="solver"),
            ack_overhead_s=_get(
                d, "ack_overhead_s", (float,), path, errors, default=5e-3
            ),
            thrash_factor=_get(
                d, "thrash_factor", (float,), path, errors, default=0.002
            ),
            tuple_timeout_s=_get(
                d, "tuple_timeout_s", (float,), path, errors, default=30.0
            ),
            des=(
                DesSettings.from_dict(des, f"{path}.des", errors)
                if des is not None
                else None
            ),
            obs=(
                ObsSettings.from_dict(obs, f"{path}.obs", errors)
                if obs is not None
                else None
            ),
        )


@dataclasses.dataclass(frozen=True)
class SchedulingPayload:
    """The full, self-contained input of one scheduling request."""

    topology: TopologySpec
    cluster: ClusterSpec
    scheduler: SchedulerSpec
    settings: RunSettings = dataclasses.field(default_factory=RunSettings)

    _FIELDS = ("topology", "cluster", "scheduler", "settings")

    def validate(self) -> "SchedulingPayload":
        """Raise PayloadValidationError listing *all* problems, or return self."""
        errors: List[str] = []
        errors.extend(self.topology.validate("topology"))
        errors.extend(self.cluster.validate("cluster"))
        errors.extend(self.scheduler.validate("scheduler"))
        errors.extend(self.settings.validate("settings"))
        if errors:
            raise PayloadValidationError(errors)
        return self

    def to_dict(self) -> Dict[str, Any]:
        return {
            "topology": self.topology.to_dict(),
            "cluster": self.cluster.to_dict(),
            "scheduler": self.scheduler.to_dict(),
            "settings": self.settings.to_dict(),
        }

    @classmethod
    def from_dict(cls, d: Any) -> "SchedulingPayload":
        """Parse + fully validate a pure-dict payload.

        Raises PayloadValidationError (with every problem found) on any
        structural or semantic error; the returned payload is guaranteed
        valid and round-trips losslessly through ``to_dict``.
        """
        d = _require_mapping(d, "payload")
        errors: List[str] = []
        _check_keys(d, "payload", cls._FIELDS, errors)
        for key in ("topology", "cluster", "scheduler"):
            if key not in d:
                errors.append(f"payload.{key}: required key missing")
        if errors and any("required key missing" in e for e in errors):
            raise PayloadValidationError(errors)
        payload = cls(
            topology=TopologySpec.from_dict(d["topology"], "topology", errors),
            cluster=ClusterSpec.from_dict(d["cluster"], "cluster", errors),
            scheduler=SchedulerSpec.from_dict(d["scheduler"], "scheduler", errors),
            settings=RunSettings.from_dict(
                d.get("settings", {}), "settings", errors
            ),
        )
        if errors:
            # Best-effort semantic pass over the partially-parsed payload so
            # the caller sees e.g. a cycle *and* the bad kwarg in one shot.
            try:
                payload.validate()
            except PayloadValidationError as semantic:
                errors.extend(e for e in semantic.errors if e not in errors)
            raise PayloadValidationError(errors)
        return payload.validate()
