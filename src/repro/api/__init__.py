# Public control-plane surface: one validated SchedulingPayload contract,
# the pluggable scheduler registry, the Nimbus lifecycle facade
# (submit/plan/kill/fail_node/add_nodes/rebalance/migrate_stragglers/apply),
# and the event-sourced scenario timeline (ScenarioSpec -> ScenarioRunner ->
# ScenarioTrace).  This is the API new schedulers, clusters, workloads and
# whole dynamic scenarios plug into as data rather than code.
from ..core.registry import (
    KwargField,
    REGISTRY,
    SchedulerEntry,
    get_scheduler,
    register_scheduler,
    scheduler_names,
    validate_scheduler_kwargs,
)
from ..core.rescheduler import RebalanceResult
from .errors import (
    PayloadValidationError,
    ScenarioReplayError,
    UnschedulablePayloadError,
)
from .nimbus import Nimbus, SchedulingPlan, SimSummary
from .scenario import (
    EVENT_TYPES,
    KillEvent,
    NodeFailEvent,
    NodeJoinEvent,
    RebalanceEvent,
    ScenarioRunner,
    ScenarioSpec,
    ScenarioTrace,
    ScenarioTraceEntry,
    StragglerReportEvent,
    SubmitEvent,
    WeightsChangeEvent,
    run_scenario,
)
from .specs import (
    CLUSTER_PRESETS,
    ClusterSpec,
    ComponentSpec,
    DesSettings,
    EdgeSpec,
    NodeEntry,
    RunSettings,
    SchedulerSpec,
    SchedulingPayload,
    TopologySpec,
)

__all__ = [
    "CLUSTER_PRESETS",
    "ClusterSpec",
    "ComponentSpec",
    "DesSettings",
    "EVENT_TYPES",
    "EdgeSpec",
    "KillEvent",
    "KwargField",
    "Nimbus",
    "NodeEntry",
    "NodeFailEvent",
    "NodeJoinEvent",
    "PayloadValidationError",
    "REGISTRY",
    "RebalanceEvent",
    "RebalanceResult",
    "RunSettings",
    "ScenarioReplayError",
    "ScenarioRunner",
    "ScenarioSpec",
    "ScenarioTrace",
    "ScenarioTraceEntry",
    "SchedulerEntry",
    "SchedulerSpec",
    "SchedulingPayload",
    "SchedulingPlan",
    "SimSummary",
    "StragglerReportEvent",
    "SubmitEvent",
    "TopologySpec",
    "UnschedulablePayloadError",
    "WeightsChangeEvent",
    "get_scheduler",
    "register_scheduler",
    "run_scenario",
    "scheduler_names",
    "validate_scheduler_kwargs",
]
