# Public control-plane surface: one validated SchedulingPayload contract,
# the pluggable scheduler registry, and the Nimbus submit/plan/kill/rebalance
# facade.  This is the API new schedulers, clusters and workloads plug into
# as data rather than code.
from ..core.registry import (
    KwargField,
    REGISTRY,
    SchedulerEntry,
    get_scheduler,
    register_scheduler,
    scheduler_names,
    validate_scheduler_kwargs,
)
from .errors import PayloadValidationError, UnschedulablePayloadError
from .nimbus import Nimbus, SchedulingPlan
from .specs import (
    CLUSTER_PRESETS,
    ClusterSpec,
    ComponentSpec,
    EdgeSpec,
    NodeEntry,
    RunSettings,
    SchedulerSpec,
    SchedulingPayload,
    TopologySpec,
)

__all__ = [
    "CLUSTER_PRESETS",
    "ClusterSpec",
    "ComponentSpec",
    "EdgeSpec",
    "KwargField",
    "Nimbus",
    "NodeEntry",
    "PayloadValidationError",
    "REGISTRY",
    "RunSettings",
    "SchedulerEntry",
    "SchedulerSpec",
    "SchedulingPayload",
    "SchedulingPlan",
    "TopologySpec",
    "UnschedulablePayloadError",
    "get_scheduler",
    "register_scheduler",
    "scheduler_names",
    "validate_scheduler_kwargs",
]
