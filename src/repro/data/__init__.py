from .pipeline import ByteTokenizer, LMDataset, Prefetcher, synthetic_corpus

__all__ = ["ByteTokenizer", "LMDataset", "Prefetcher", "synthetic_corpus"]
