"""Data pipeline: byte-level tokenizer, deterministic synthetic corpus or
file-backed text, host-sharded batching with background prefetch."""

from __future__ import annotations

import queue
import threading
from typing import Dict, Iterator, Optional, Sequence

import numpy as np


class ByteTokenizer:
    """Byte-level tokenizer with a small reserved-special prefix."""

    PAD, BOS, EOS = 0, 1, 2
    OFFSET = 3

    @property
    def vocab_size(self) -> int:
        return 256 + self.OFFSET

    def encode(self, text: str) -> np.ndarray:
        return np.frombuffer(text.encode("utf-8"), np.uint8).astype(np.int32) + self.OFFSET

    def decode(self, ids: Sequence[int]) -> str:
        arr = np.asarray([i - self.OFFSET for i in ids if i >= self.OFFSET], np.uint8)
        return arr.tobytes().decode("utf-8", errors="replace")


def synthetic_corpus(seed: int = 0, n_docs: int = 256) -> Iterator[str]:
    """Deterministic pseudo-text: Zipf-ish word soup with structure so a
    small LM's loss visibly drops within a few hundred steps."""
    rng = np.random.default_rng(seed)
    words = [f"w{i}" for i in range(200)]
    probs = 1.0 / np.arange(1, len(words) + 1)
    probs /= probs.sum()
    for _ in range(n_docs):
        n = int(rng.integers(64, 256))
        idx = rng.choice(len(words), size=n, p=probs)
        # inject bigram structure: every 'w0' is followed by 'w1'
        toks = []
        for i in idx:
            toks.append(words[i])
            if i == 0:
                toks.append(words[1])
        yield " ".join(toks)


class LMDataset:
    """Packs a token stream into (tokens, labels) windows; deterministically
    shards across data-parallel hosts (shard `host_id` of `num_hosts`)."""

    def __init__(
        self,
        seq_len: int,
        batch_size: int,
        vocab_size: int,
        seed: int = 0,
        corpus: Optional[Iterator[str]] = None,
        host_id: int = 0,
        num_hosts: int = 1,
    ):
        tok = ByteTokenizer()
        ids = []
        for doc in corpus if corpus is not None else synthetic_corpus(seed):
            ids.append(tok.encode(doc) % vocab_size)
            ids.append(np.array([tok.EOS], np.int32))
        stream = np.concatenate(ids)
        n_win = len(stream) // (seq_len + 1)
        stream = stream[: n_win * (seq_len + 1)].reshape(n_win, seq_len + 1)
        self.windows = stream[host_id::num_hosts]
        self.batch_size = batch_size
        self.seq_len = seq_len
        self.rng = np.random.default_rng(seed + host_id)

    def __iter__(self) -> Iterator[Dict[str, np.ndarray]]:
        while True:
            idx = self.rng.integers(0, len(self.windows), self.batch_size)
            w = self.windows[idx]
            yield {"tokens": w[:, :-1].astype(np.int32), "labels": w[:, 1:].astype(np.int32)}


class Prefetcher:
    """Background-thread prefetch (depth-bounded) over any batch iterator."""

    def __init__(self, it: Iterator, depth: int = 2):
        self._it = iter(it)
        self._q: "queue.Queue" = queue.Queue(maxsize=depth)
        self._done = object()
        self._thread = threading.Thread(target=self._fill, daemon=True)
        self._thread.start()

    def _fill(self):
        try:
            for item in self._it:
                self._q.put(item)
        finally:
            self._q.put(self._done)

    def __iter__(self):
        return self

    def __next__(self):
        item = self._q.get()
        if item is self._done:
            raise StopIteration
        return item
