"""Sharded checkpointing with manifest + async writer (fault tolerance,
DESIGN.md §5).

Layout: ``<dir>/step_<N>/manifest.json`` + one ``.npy`` per leaf (keyed by a
stable flattened path).  Restore is elastic: it only needs the manifest, so a
restarted job with a different mesh re-shards on load (plans are pure
functions of (topology, cluster) — same property the paper relies on for
Nimbus statelessness)."""

from __future__ import annotations

import json
import os
import queue
import re
import shutil
import threading
import time
from typing import Any, Dict, List, Optional, Tuple

import jax
import numpy as np


def _flatten_with_paths(tree) -> List[Tuple[str, Any]]:
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    out = []
    for path, leaf in flat:
        key = "/".join(
            str(p.key) if hasattr(p, "key") else str(p.idx) for p in path
        )
        out.append((key, leaf))
    return out


def save_checkpoint(directory: str, step: int, state) -> str:
    """Synchronous save.  Returns the checkpoint path."""
    path = os.path.join(directory, f"step_{step:08d}")
    tmp = path + ".tmp"
    os.makedirs(tmp, exist_ok=True)
    manifest = {"step": step, "leaves": {}, "time": time.time()}
    for key, leaf in _flatten_with_paths(state):
        arr = np.asarray(leaf)
        fname = re.sub(r"[^A-Za-z0-9_.-]", "_", key) + ".npy"
        np.save(os.path.join(tmp, fname), arr)
        manifest["leaves"][key] = {
            "file": fname,
            "shape": list(arr.shape),
            "dtype": str(arr.dtype),
        }
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
    if os.path.exists(path):
        shutil.rmtree(path)
    os.replace(tmp, path)  # atomic publish
    return path


def latest_step(directory: str) -> Optional[int]:
    if not os.path.isdir(directory):
        return None
    steps = []
    for name in os.listdir(directory):
        m = re.fullmatch(r"step_(\d+)", name)
        if m and os.path.exists(os.path.join(directory, name, "manifest.json")):
            steps.append(int(m.group(1)))
    return max(steps) if steps else None


def restore_checkpoint(directory: str, like, step: Optional[int] = None):
    """Restore into the structure of ``like`` (a pytree of arrays or
    ShapeDtypeStructs).  Returns (state, step)."""
    if step is None:
        step = latest_step(directory)
        if step is None:
            raise FileNotFoundError(f"no checkpoints under {directory}")
    path = os.path.join(directory, f"step_{step:08d}")
    with open(os.path.join(path, "manifest.json")) as f:
        manifest = json.load(f)
    leaves = {}
    for key, meta in manifest["leaves"].items():
        leaves[key] = np.load(os.path.join(path, meta["file"]))
    flat_like = _flatten_with_paths(like)
    restored = []
    for key, leaf in flat_like:
        if key not in leaves:
            raise KeyError(f"checkpoint missing leaf {key!r}")
        arr = leaves[key]
        want = tuple(leaf.shape)
        if tuple(arr.shape) != want:
            raise ValueError(f"leaf {key!r}: checkpoint {arr.shape} != wanted {want}")
        restored.append(jax.numpy.asarray(arr, dtype=leaf.dtype))
    treedef = jax.tree_util.tree_structure(like)
    return jax.tree_util.tree_unflatten(treedef, restored), step


class AsyncCheckpointer:
    """Background writer: training never blocks on I/O.  ``save`` snapshots
    to host memory synchronously (cheap) and enqueues the disk write."""

    def __init__(self, directory: str, keep: int = 3):
        self.directory = directory
        self.keep = keep
        self._q: "queue.Queue" = queue.Queue()
        self._done: Dict[int, str] = {}
        self._err: Optional[BaseException] = None
        self._thread = threading.Thread(target=self._worker, daemon=True)
        self._thread.start()

    def _worker(self):
        while True:
            item = self._q.get()
            if item is None:
                return
            step, host_state = item
            try:
                path = save_checkpoint(self.directory, step, host_state)
                self._done[step] = path
                self._gc()
            except BaseException as e:  # noqa: BLE001
                self._err = e

    def _gc(self):
        steps = sorted(self._done)
        while len(steps) > self.keep:
            s = steps.pop(0)
            path = self._done.pop(s)
            shutil.rmtree(path, ignore_errors=True)

    def save(self, step: int, state) -> None:
        if self._err:
            raise self._err
        host_state = jax.tree_util.tree_map(lambda x: np.asarray(x), state)
        self._q.put((step, host_state))

    def wait(self, timeout: float = 60.0) -> None:
        deadline = time.time() + timeout
        while not self._q.empty():
            if time.time() > deadline:
                raise TimeoutError("checkpoint queue did not drain")
            time.sleep(0.01)
        if self._err:
            raise self._err

    def close(self) -> None:
        self.wait()
        self._q.put(None)
        self._thread.join(timeout=10)
