"""Training step construction: value_and_grad + gradient accumulation +
AdamW, built per (model, plan)."""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from ..models.lm import Model
from .compression import compress_grads_with_feedback, init_error_feedback
from .optimizer import AdamWConfig, adamw_update, init_opt_state


@dataclasses.dataclass(frozen=True)
class TrainOptions:
    opt: AdamWConfig = AdamWConfig()
    n_micro: int = 1
    compress_grads: bool = False


def init_train_state(model: Model, key, opts: TrainOptions) -> Dict[str, Any]:
    params = model.init_params(key)
    state = {"params": params, "opt": init_opt_state(params)}
    if opts.compress_grads:
        state["err"] = init_error_feedback(params)
    return state


def _bf16_grad_reduce() -> bool:
    """Beyond-paper optimization (EXPERIMENTS.md §Perf iter 3): cast grads to
    bf16 before the data-parallel reduction (halves all-reduce bytes; Adam
    statistics stay fp32).  Off by default."""
    import os

    return os.environ.get("REPRO_OPT_BF16_GRADS", "0") == "1"


def make_train_step(model: Model, opts: TrainOptions = TrainOptions()) -> Callable:
    """Returns train_step(state, batch) -> (state, metrics).

    Gradient accumulation: the global batch is split into ``n_micro``
    microbatches scanned sequentially; grads are averaged in fp32.  With a
    sharded batch this is exactly the memory/throughput trade the planner's
    hard-constraint escalation selects (DESIGN.md §2.2).
    """

    def loss_for(params, batch):
        loss, metrics = model.loss_fn(params, batch)
        return loss, metrics

    grad_fn = jax.value_and_grad(loss_for, has_aux=True)

    def train_step(state, batch):
        params = state["params"]
        if opts.n_micro <= 1:
            (loss, metrics), grads = grad_fn(params, batch)
            if _bf16_grad_reduce():
                grads = jax.tree_util.tree_map(
                    lambda g: g.astype(jnp.bfloat16), grads
                )
        else:
            n = opts.n_micro

            def split(x):
                return x.reshape((n, x.shape[0] // n) + x.shape[1:])

            micro = jax.tree_util.tree_map(split, batch)

            def body(acc, mb):
                (l, m), g = grad_fn(params, mb)
                acc_g, acc_l = acc
                acc_g = jax.tree_util.tree_map(
                    lambda a, b: a + b.astype(jnp.float32) / n, acc_g, g
                )
                return (acc_g, acc_l + l / n), None

            zero_g = jax.tree_util.tree_map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params
            )
            (grads, loss), _ = jax.lax.scan(body, (zero_g, jnp.zeros((), jnp.float32)), micro)
            metrics = {"ce": loss, "aux": jnp.zeros((), jnp.float32)}
        new_state = dict(state)
        if opts.compress_grads:
            grads, new_err = compress_grads_with_feedback(grads, state["err"])
            new_state["err"] = new_err
        params_new, opt_new, opt_metrics = adamw_update(
            opts.opt, params, grads, state["opt"]
        )
        new_state["params"] = params_new
        new_state["opt"] = opt_new
        metrics = dict(metrics)
        metrics.update(opt_metrics)
        metrics["loss"] = loss
        return new_state, metrics

    return train_step
