from .optimizer import AdamWConfig, adamw_update, init_opt_state, lr_schedule
from .train_loop import TrainOptions, init_train_state, make_train_step
from .checkpoint import (
    AsyncCheckpointer,
    latest_step,
    restore_checkpoint,
    save_checkpoint,
)
from .compression import (
    compress_grads_with_feedback,
    dequantize_int8,
    init_error_feedback,
    quantize_int8,
)

__all__ = [
    "AdamWConfig", "adamw_update", "init_opt_state", "lr_schedule",
    "TrainOptions", "init_train_state", "make_train_step",
    "AsyncCheckpointer", "latest_step", "restore_checkpoint", "save_checkpoint",
    "compress_grads_with_feedback", "dequantize_int8", "init_error_feedback", "quantize_int8",
]
