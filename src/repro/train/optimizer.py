"""Hand-rolled AdamW with global-norm clipping and schedules (no optax)."""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, NamedTuple, Tuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10000
    min_lr_ratio: float = 0.1


def lr_schedule(cfg: AdamWConfig, step: jax.Array) -> jax.Array:
    """Linear warmup + cosine decay to min_lr_ratio."""
    step = step.astype(jnp.float32)
    warm = cfg.lr * step / jnp.maximum(cfg.warmup_steps, 1)
    prog = jnp.clip(
        (step - cfg.warmup_steps) / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1),
        0.0,
        1.0,
    )
    cos = cfg.min_lr_ratio + (1 - cfg.min_lr_ratio) * 0.5 * (1 + jnp.cos(jnp.pi * prog))
    return jnp.where(step < cfg.warmup_steps, warm, cfg.lr * cos)


def global_norm(tree) -> jax.Array:
    leaves = jax.tree_util.tree_leaves(tree)
    return jnp.sqrt(
        sum(jnp.sum(jnp.square(x.astype(jnp.float32))) for x in leaves)
    )


def clip_by_global_norm(tree, max_norm: float):
    norm = global_norm(tree)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-9))
    return jax.tree_util.tree_map(lambda g: (g.astype(jnp.float32) * scale).astype(g.dtype), tree), norm


def init_opt_state(params) -> Dict[str, Any]:
    zeros = lambda p: jnp.zeros_like(p, dtype=jnp.float32)  # noqa: E731
    return {
        "m": jax.tree_util.tree_map(zeros, params),
        "v": jax.tree_util.tree_map(zeros, params),
        "step": jnp.zeros((), jnp.int32),
    }


def adamw_update(
    cfg: AdamWConfig, params, grads, opt_state
) -> Tuple[Any, Dict[str, Any], Dict[str, jax.Array]]:
    grads, gnorm = clip_by_global_norm(grads, cfg.grad_clip)
    step = opt_state["step"] + 1
    lr = lr_schedule(cfg, step)
    b1, b2 = cfg.b1, cfg.b2
    bc1 = 1 - b1 ** step.astype(jnp.float32)
    bc2 = 1 - b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        g32 = g.astype(jnp.float32)
        m_new = b1 * m + (1 - b1) * g32
        v_new = b2 * v + (1 - b2) * jnp.square(g32)
        mhat = m_new / bc1
        vhat = v_new / bc2
        delta = mhat / (jnp.sqrt(vhat) + cfg.eps) + cfg.weight_decay * p.astype(jnp.float32)
        p_new = p.astype(jnp.float32) - lr * delta
        return p_new.astype(p.dtype), m_new, v_new

    flat_p, treedef = jax.tree_util.tree_flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_m = treedef.flatten_up_to(opt_state["m"])
    flat_v = treedef.flatten_up_to(opt_state["v"])
    out = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    params_new = jax.tree_util.tree_unflatten(treedef, [o[0] for o in out])
    m_new = jax.tree_util.tree_unflatten(treedef, [o[1] for o in out])
    v_new = jax.tree_util.tree_unflatten(treedef, [o[2] for o in out])
    opt_new = {"m": m_new, "v": v_new, "step": step}
    return params_new, opt_new, {"lr": lr, "grad_norm": gnorm}
