"""Gradient compression for cross-pod data parallelism (DESIGN.md §5).

int8 quantization with per-tensor scale and error feedback (the residual is
carried to the next step, so compression error does not bias convergence —
1-bit Adam / PowerSGD lineage).  On a multi-pod mesh the compressed gradient
is what crosses the slow DCN 'pod' axis; XLA reduces int8 traffic 4x over
fp32 (an explicit distributed-optimization trick for 1000+ node scale).
"""

from __future__ import annotations

from typing import Any, Tuple

import jax
import jax.numpy as jnp


def quantize_int8(x: jax.Array) -> Tuple[jax.Array, jax.Array]:
    scale = jnp.max(jnp.abs(x.astype(jnp.float32))) / 127.0 + 1e-12
    q = jnp.clip(jnp.round(x.astype(jnp.float32) / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_int8(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * scale


def init_error_feedback(params) -> Any:
    return jax.tree_util.tree_map(
        lambda p: jnp.zeros_like(p, dtype=jnp.float32), params
    )


def compress_grads_with_feedback(grads, error) -> Tuple[Any, Any]:
    """Returns (decompressed grads as seen by every replica, new error)."""

    def one(g, e):
        corrected = g.astype(jnp.float32) + e
        q, scale = quantize_int8(corrected)
        deq = dequantize_int8(q, scale)
        return deq.astype(g.dtype), corrected - deq

    flat_g, treedef = jax.tree_util.tree_flatten(grads)
    flat_e = treedef.flatten_up_to(error)
    out = [one(g, e) for g, e in zip(flat_g, flat_e)]
    new_g = jax.tree_util.tree_unflatten(treedef, [o[0] for o in out])
    new_e = jax.tree_util.tree_unflatten(treedef, [o[1] for o in out])
    return new_g, new_e
