"""Event-sourced scenario API tests: ScenarioSpec validation + lossless JSON
round-trip, the Nimbus.apply lifecycle dispatcher, and the golden replay
guarantee (same timeline JSON -> bit-identical ScenarioTrace dicts) across
every registered scheduler."""

import pytest

from repro.api import (
    ClusterSpec,
    KillEvent,
    Nimbus,
    NodeEntry,
    NodeFailEvent,
    NodeJoinEvent,
    PayloadValidationError,
    RebalanceEvent,
    ScenarioReplayError,
    ScenarioRunner,
    ScenarioSpec,
    SchedulerSpec,
    SchedulingPayload,
    SchedulingPlan,
    StragglerReportEvent,
    SubmitEvent,
    WeightsChangeEvent,
    run_scenario,
    scheduler_names,
)
from repro.stream import topologies

#: registry name -> fast kwargs (the golden sweep covers every scheduler).
ALL_SCHEDULERS = {
    "round_robin": {"seed": 1},
    "rstorm": {},
    "rstorm-search": {"n_chains": 8, "steps": 60},
    "rstorm_plus": {},
    "rstorm_annealed": {"iters": 200},
}


def acceptance_scenario(sched="rstorm", kwargs=None) -> ScenarioSpec:
    """The acceptance timeline: submit two topologies -> fail a node ->
    scale up -> rebalance."""
    return ScenarioSpec(
        name=f"acceptance_{sched}",
        cluster=ClusterSpec(preset="emulab_24"),
        timeline=(
            SubmitEvent(
                topology=topologies.spec("pageload"),
                scheduler=SchedulerSpec(sched, dict(kwargs or {})),
            ),
            SubmitEvent(
                topology=topologies.spec("processing"),
                scheduler=SchedulerSpec(sched, dict(kwargs or {})),
            ),
            NodeFailEvent(node_id="r0n0"),
            NodeJoinEvent(
                nodes=(
                    NodeEntry("fresh0", "rack_fresh"),
                    NodeEntry("fresh1", "rack_fresh"),
                )
            ),
            RebalanceEvent(),
        ),
    )


def test_registry_matches_golden_sweep():
    assert sorted(ALL_SCHEDULERS) == scheduler_names()


# -- spec validation + round trip -------------------------------------------------
def test_scenario_spec_json_round_trip():
    spec = acceptance_scenario()
    replayed = ScenarioSpec.from_json(spec.to_json(indent=2))
    assert replayed.to_dict() == spec.to_dict()
    assert replayed == spec  # frozen dataclasses: structural equality


def test_scenario_validation_reports_every_problem():
    spec = ScenarioSpec(
        cluster=ClusterSpec(preset="emulab_12"),
        timeline=(
            SubmitEvent(
                topology=topologies.spec("pageload"),
                scheduler=SchedulerSpec("rstorm"),
            ),
            SubmitEvent(  # duplicate live topology id
                topology=topologies.spec("pageload"),
                scheduler=SchedulerSpec("rstormx"),  # and unknown scheduler
            ),
            KillEvent(topology_id="nope"),        # never submitted
            NodeFailEvent(node_id="r9n9"),        # unknown node
            NodeJoinEvent(nodes=(NodeEntry("r0n0", "rack0"),)),  # exists
            WeightsChangeEvent(weights={"watts": 1.0}),  # unknown dimension
        ),
    )
    with pytest.raises(PayloadValidationError) as ei:
        spec.validate()
    errors = "\n".join(ei.value.errors)
    assert "already submitted" in errors
    assert "unknown scheduler" in errors
    assert "'nope' is not submitted" in errors
    assert "unknown node 'r9n9'" in errors
    assert "'r0n0' already exists" in errors
    assert "unknown dimension 'watts'" in errors


def test_scenario_from_dict_rejects_unknown_kind_and_double_fail():
    d = acceptance_scenario().to_dict()
    d["timeline"].append({"kind": "meteor_strike"})
    d["timeline"].append({"kind": "node_fail", "node_id": "r0n0"})  # again
    with pytest.raises(PayloadValidationError) as ei:
        ScenarioSpec.from_dict(d)
    errors = "\n".join(ei.value.errors)
    assert "unknown event kind 'meteor_strike'" in errors
    assert "already failed" in errors


def test_scenario_validation_unrelated_error_keeps_node_checks():
    """A bad scenario name must not disable the node-existence walk."""
    spec = ScenarioSpec(
        name="",
        cluster=ClusterSpec(preset="emulab_12"),
        timeline=(NodeFailEvent(node_id="bogus"),),
    )
    with pytest.raises(PayloadValidationError) as ei:
        spec.validate()
    errors = "\n".join(ei.value.errors)
    assert "name: must be a non-empty string" in errors
    assert "unknown node 'bogus'" in errors


def test_scenario_from_dict_missing_cluster_still_reports_timeline():
    with pytest.raises(PayloadValidationError) as ei:
        ScenarioSpec.from_dict({"timeline": [{"kind": "meteor_strike"}]})
    errors = "\n".join(ei.value.errors)
    assert "scenario.cluster: required key missing" in errors
    assert "unknown event kind 'meteor_strike'" in errors


def test_scenario_from_dict_aggregates_across_malformed_entries():
    """One non-mapping timeline entry must not swallow the other problems."""
    with pytest.raises(PayloadValidationError) as ei:
        ScenarioSpec.from_dict(
            {
                "cluster": {"preset": "bogus"},
                "timeline": [42, {"kind": "meteor_strike"}],
            }
        )
    errors = "\n".join(ei.value.errors)
    assert "timeline[0]: expected a mapping" in errors
    assert "unknown event kind 'meteor_strike'" in errors
    assert "unknown preset 'bogus'" in errors


# -- the apply dispatcher ---------------------------------------------------------
def test_apply_failure_then_rebalance_path():
    nimbus = Nimbus(ClusterSpec(preset="emulab_12"))
    out = nimbus.apply(
        SubmitEvent(
            topology=topologies.spec("pageload"), scheduler=SchedulerSpec("rstorm")
        )
    )
    plan = SchedulingPlan.from_dict(out["plan"])
    assert plan.committed and plan.to_dict() == out["plan"]
    victim = sorted(set(plan.placements.values()))[0]
    out = nimbus.apply(NodeFailEvent(node_id=victim))
    assert out["orphaned"] and all(t == "pageload" for t, _ in out["orphaned"])
    orphan_ids = sorted(tid for _, tid in out["orphaned"])
    # Double-failing the same node must be rejected, not re-report orphans.
    with pytest.raises(ValueError, match="already failed"):
        nimbus.fail_node(victim)
    out = nimbus.apply(RebalanceEvent())
    assert sorted(out["moved"]["pageload"]) == orphan_ids
    assert out["unplaced"] == {}
    assert nimbus.state.orphaned_tasks() == []
    placements = nimbus.state.assignments["pageload"].placements
    assert victim not in set(placements.values())


def test_apply_scale_up_lands_unplaced_tasks():
    nimbus = Nimbus(ClusterSpec(racks=1, nodes_per_rack=3))
    out = nimbus.apply(
        SubmitEvent(
            topology=topologies.spec("pageload"), scheduler=SchedulerSpec("rstorm")
        )
    )
    unassigned = out["plan"]["unassigned"]
    assert unassigned, "3 x 2GB nodes cannot hold pageload"
    out = nimbus.apply(
        NodeJoinEvent(
            nodes=tuple(NodeEntry(f"fresh{i}", "rack_fresh") for i in range(4))
        )
    )
    assert sorted(out["moved"]["pageload"]) == sorted(unassigned)
    assert out["unplaced"] == {}
    assert nimbus.state.assignments["pageload"].is_complete(
        nimbus.state.topologies["pageload"]
    )
    # The joined nodes are part of the live cluster spec now: a follow-up
    # submit against the *current* cluster is accepted.
    assert "fresh0" in nimbus.cluster.nodes


def test_apply_straggler_and_weights_events():
    nimbus = Nimbus(ClusterSpec(preset="emulab_12"))
    out = nimbus.apply(
        SubmitEvent(
            topology=topologies.spec("pageload"), scheduler=SchedulerSpec("rstorm")
        )
    )
    placements = dict(nimbus.state.assignments["pageload"].placements)
    times = {tid: 0.002 for tid in placements}
    slow = sorted(placements)[0]
    times[slow] = 1.0
    nimbus.apply(WeightsChangeEvent(weights={"cpu_points": 0.001}))
    assert nimbus._weights == {"cpu_points": 0.001}
    out = nimbus.apply(StragglerReportEvent(service_times=times))
    assert out["stragglers"] == [slow]
    assert out["moves"][slow] != placements[slow]


def test_replay_failure_names_the_timeline_step():
    """Dynamically-failing events (static validation can't see them) must
    surface with their step index."""
    from repro.api import RunSettings

    spec = ScenarioSpec(
        cluster=ClusterSpec(racks=1, nodes_per_rack=2),
        timeline=(
            SubmitEvent(  # 2 x 2GB nodes cannot hold pageload whole
                topology=topologies.spec("pageload"),
                scheduler=SchedulerSpec("rstorm"),
                settings=RunSettings(allow_partial=False),
            ),
        ),
    )
    with pytest.raises(ScenarioReplayError, match=r"timeline\[0\].*submit"):
        run_scenario(spec)


def test_apply_rejects_unknown_event_and_empty_nimbus():
    class Weird:
        kind = "meteor_strike"

    with pytest.raises(ScenarioReplayError, match="unknown scenario event"):
        Nimbus(ClusterSpec(preset="emulab_12")).apply(Weird())
    with pytest.raises(ScenarioReplayError, match="needs a live cluster"):
        Nimbus().apply(RebalanceEvent())


# -- golden replay ----------------------------------------------------------------
@pytest.mark.parametrize("sched", sorted(ALL_SCHEDULERS))
def test_golden_replay_is_deterministic(sched):
    """Acceptance: the same timeline JSON replays to bit-identical traces,
    for every registered scheduler."""
    raw = acceptance_scenario(sched, ALL_SCHEDULERS[sched]).to_json()
    t1 = ScenarioRunner(ScenarioSpec.from_json(raw)).run()
    t2 = run_scenario(ScenarioSpec.from_json(raw))
    assert t1.to_dict() == t2.to_dict()
    assert t1.to_json() == t2.to_json()
    # The trace records every step and both topologies' steady state.
    assert [e.event["kind"] for e in t1.entries] == [
        "submit", "submit", "node_fail", "node_join", "rebalance",
    ]
    final = t1.final()
    assert set(final.topologies) == {"pageload", "processing"}
    assert final.unplaced == {}
    assert final.alive_nodes == 25  # 24 - 1 failed + 2 joined
    # Embedded plans round-trip losslessly through SchedulingPlan.from_dict.
    for entry in t1.entries[:2]:
        plan_d = entry.outcome["plan"]
        assert SchedulingPlan.from_dict(plan_d).to_dict() == plan_d
    # The throughput series is one point per timeline step.
    assert len(t1.throughput("pageload")) == len(t1.entries)


def test_warm_start_replay_matches_cold_replay_shape():
    """Warm-started re-entry changes the solver's path, not the story: both
    reach a steady state with the same bindings and placements."""
    spec = acceptance_scenario()
    warm = ScenarioRunner(spec, warm_start=True).run()
    cold = ScenarioRunner(spec, warm_start=False).run()
    for ew, ec in zip(warm.entries, cold.entries):
        assert ew.outcome == ec.outcome
        assert set(ew.topologies) == set(ec.topologies)
        for tid in ew.topologies:
            tw, tc = ew.topologies[tid], ec.topologies[tid]
            assert tw["machines_used"] == tc["machines_used"]
            assert tw["sink_throughput"] == pytest.approx(
                tc["sink_throughput"], rel=1e-3
            )


# -- plan round trip --------------------------------------------------------------
def test_scheduling_plan_round_trips_with_sim():
    payload = SchedulingPayload.from_dict(
        {
            "topology": topologies.spec("pageload").to_dict(),
            "cluster": {"preset": "emulab_12"},
            "scheduler": {"name": "rstorm", "kwargs": {}},
            "settings": {"allow_partial": True, "simulate": True},
        }
    )
    plan = Nimbus().plan(payload)
    d = plan.to_dict()
    rebuilt = SchedulingPlan.from_dict(d)
    assert rebuilt.to_dict() == d
    assert rebuilt.sim.sink_throughput == plan.sim.sink_throughput
    assert rebuilt.machines_used == plan.machines_used
    assert rebuilt.assignment is None and rebuilt.topology is None
