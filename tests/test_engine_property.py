"""Hypothesis property tests for the placement engine (skipped when the
``hypothesis`` dependency is absent — the container does not bake it in).

The load-bearing invariant: the availability ledger's snapshot/rollback
always restores availability *exactly* (bit-for-bit), for any interleaving
of assigns/unassigns — this is what lets "plan on a scratch copy" become a
cheap array snapshot instead of ``copy.deepcopy(cluster)``.
"""

import pytest

pytest.importorskip("hypothesis")
import numpy as np  # noqa: E402
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core import Cluster, PlacementArena, demand, get_scheduler  # noqa: E402

from test_schedulers import linear_topology  # noqa: E402


@settings(max_examples=50, deadline=None)
@given(
    racks=st.integers(1, 4),
    npr=st.integers(1, 6),
    ops=st.lists(
        st.tuples(
            st.integers(0, 23),  # node slot (mod node count)
            st.floats(0.0, 4096.0, allow_nan=False),
            st.floats(0.0, 200.0, allow_nan=False),
            st.booleans(),  # assign vs unassign
        ),
        max_size=40,
    ),
)
def test_property_ledger_rollback_restores_availability_exactly(racks, npr, ops):
    arena = PlacementArena(Cluster.homogeneous(racks=racks, nodes_per_rack=npr))
    before = arena.avail.copy()
    snap = arena.snapshot()
    n = len(arena.node_ids)
    for slot, mem, cpu, is_assign in ops:
        row, _ = arena.compile_demand(demand(mem, cpu, 1.0))
        if is_assign:
            arena.assign(slot % n, row)
        else:
            arena.unassign(slot % n, row)
    arena.rollback(snap)
    # Bit-exact equality, not approx: rollback is a restore, not a recompute.
    assert np.array_equal(arena.avail, before)


@settings(max_examples=25, deadline=None)
@given(
    n_bolts=st.integers(1, 5),
    par=st.integers(1, 6),
    mem=st.floats(16.0, 1024.0, allow_nan=False),
    cpu=st.floats(1.0, 120.0, allow_nan=False),
    racks=st.integers(1, 4),
    npr=st.integers(1, 8),
)
def test_property_arena_matches_legacy_rstorm(n_bolts, par, mem, cpu, racks, npr):
    t = linear_topology(n_bolts=n_bolts, parallelism=par, mem=mem, cpu=cpu)
    cl = Cluster.homogeneous(racks=racks, nodes_per_rack=npr)
    a = get_scheduler("rstorm", engine="arena").schedule(t, cl, commit=False)
    cl.reset()
    b = get_scheduler("rstorm", engine="legacy").schedule(t, cl, commit=False)
    assert a.placements == b.placements
    assert sorted(a.unassigned) == sorted(b.unassigned)


@settings(max_examples=15, deadline=None)
@given(seed=st.integers(0, 100), iters=st.integers(1, 200))
def test_property_arena_matches_legacy_annealer(seed, iters):
    t = linear_topology(n_bolts=3, parallelism=4)
    cl = Cluster.homogeneous(racks=2, nodes_per_rack=6)
    a = get_scheduler("rstorm_annealed", engine="arena", seed=seed, iters=iters).schedule(
        t, cl, commit=False
    )
    cl.reset()
    b = get_scheduler("rstorm_annealed", engine="legacy", seed=seed, iters=iters).schedule(
        t, cl, commit=False
    )
    assert a.placements == b.placements
