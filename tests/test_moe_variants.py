"""MoE dispatch-variant tests: the grouped (shard-local) dispatch used by the
optimized path must agree with the global-sort baseline when capacity is
generous, and degrade gracefully (token dropping) when it is not."""

import dataclasses

import pytest

pytest.importorskip("jax")  # optional-jax CI leg: MoE models are jax-only
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro import configs
from repro.models.common import init_from_spec
from repro.models.moe import (
    moe_capacity,
    moe_forward_global,
    moe_forward_grouped,
    moe_spec,
)

KEY = jax.random.PRNGKey(0)


def _setup(capacity_factor=8.0, B=4, S=32, arch="olmoe-1b-7b"):
    cfg = dataclasses.replace(configs.get_smoke(arch), capacity_factor=capacity_factor)
    p = init_from_spec(moe_spec(cfg), KEY)
    x = jax.random.normal(jax.random.PRNGKey(1), (B, S, cfg.d_model), jnp.float32)
    return cfg, p, x


def test_grouped_matches_global_dropless():
    cfg, p, x = _setup(capacity_factor=8.0)
    a, aux_a = moe_forward_global(cfg, p, x)
    b, aux_b = moe_forward_grouped(cfg, p, x)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-4)
    assert abs(float(aux_a - aux_b)) < 1e-5


@settings(max_examples=10, deadline=None)
@given(
    b=st.sampled_from([2, 4]),
    s=st.sampled_from([16, 32]),
    cf=st.floats(0.5, 4.0),
)
def test_grouped_output_finite_and_bounded(b, s, cf):
    cfg, p, x = _setup(capacity_factor=cf, B=b, S=s)
    out, aux = moe_forward_grouped(cfg, p, x)
    assert out.shape == x.shape
    assert bool(jnp.isfinite(out).all()) and bool(jnp.isfinite(aux))
    # With tokens dropped, outputs are a gated convex-ish combination of
    # expert outputs — magnitudes stay bounded.
    assert float(jnp.max(jnp.abs(out))) < 1e3


def test_capacity_is_lane_aligned():
    cfg, _, _ = _setup()
    for t in (64, 1000, 4096):
        c = moe_capacity(cfg, t)
        assert c % 8 == 0 and c >= 8


def test_grouped_drops_when_capacity_tight():
    """At capacity_factor << 1, some tokens must be dropped (outputs for
    dropped tokens are zero-contribution), and nothing NaNs."""
    cfg, p, x = _setup(capacity_factor=0.25)
    out, _ = moe_forward_grouped(cfg, p, x)
    out_full, _ = moe_forward_grouped(
        dataclasses.replace(cfg, capacity_factor=8.0), p, x
    )
    # dropped-token path differs from the dropless one
    assert float(jnp.max(jnp.abs(out - out_full))) > 1e-6
    assert bool(jnp.isfinite(out).all())
