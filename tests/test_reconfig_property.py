"""Hypothesis property tests for the reconfiguration plane (skipped when the
``hypothesis`` dependency is absent — the container does not bake it in).

Invariants, for every reconfig mode across seed × failure sweeps:

* a task is never both moved and unplaced by one rebalance;
* no hard capacity constraint is violated and every placement is on a live
  node after any fail / scale-up / rebalance trajectory;
* search-mode rebalance never loses simulated sink throughput versus the
  greedy patch-up on the same failover (the engine's never-worse guard).
"""

import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core import (  # noqa: E402
    GlobalState,
    NodeSpec,
    RStormScheduler,
    emulab_cluster,
)
from repro.core.reconfig import ReconfigEngine  # noqa: E402
from repro.stream import Simulator, topologies  # noqa: E402

FAST_SEARCH = {"n_chains": 8, "steps": 120}


def _submit(name):
    cl = emulab_cluster()
    gs = GlobalState(cl)
    t = topologies.make(name)
    a = gs.submit(t, RStormScheduler())
    return cl, gs, t, a


def _fail_one(cl, a, engine, victim_idx):
    """Fail the victim_idx-th (mod) live used node and rebalance; returns the
    RebalanceResult, or None when no used node is left alive."""
    used = [n for n in sorted(set(a.placements.values())) if cl.nodes[n].alive]
    if not used:
        return None
    engine.fail_node(used[victim_idx % len(used)])
    return engine.rebalance()


def _check_invariants(cl, t, a, result):
    if result is not None:
        moved = {tid for v in result.moved.values() for tid in v}
        unplaced = {tid for v in result.unplaced.values() for tid in v}
        assert not (moved & unplaced)
    assert a.hard_violations(t, cl) == []
    for _, nid in a.placements.items():
        assert cl.nodes[nid].alive


@settings(max_examples=10, deadline=None)
@given(
    name=st.sampled_from(sorted(topologies.ALL)),
    victim_idx=st.integers(0, 4),
    seed=st.integers(0, 3),
)
def test_search_failover_never_loses_throughput(name, victim_idx, seed):
    """Single-failover sweep: identical victim under both modes; search's
    simulated sink throughput is never below greedy's."""
    tps = {}
    for mode, kwargs in (
        ("greedy", None),
        ("search", dict(FAST_SEARCH, seed=seed)),
    ):
        cl, gs, t, a = _submit(name)
        engine = ReconfigEngine(gs, mode=mode, kwargs=kwargs)
        result = _fail_one(cl, a, engine, victim_idx)
        _check_invariants(cl, t, a, result)
        tps[mode] = Simulator(cl).run(t, a).sink_throughput
    assert tps["search"] >= tps["greedy"]


@settings(max_examples=10, deadline=None)
@given(
    name=st.sampled_from(sorted(topologies.ALL_MICRO)),
    mode=st.sampled_from(["greedy", "search"]),
    victim_idx=st.integers(0, 4),
    n_failures=st.integers(1, 2),
    seed=st.integers(0, 3),
)
def test_reconfig_trajectory_invariants(name, mode, victim_idx, n_failures, seed):
    """Longer trajectories (fail* -> scale-up -> rebalance) keep every
    structural invariant in both modes."""
    cl, gs, t, a = _submit(name)
    kwargs = dict(FAST_SEARCH, seed=seed) if mode == "search" else None
    engine = ReconfigEngine(gs, mode=mode, kwargs=kwargs)
    for _ in range(n_failures):
        result = _fail_one(cl, a, engine, victim_idx)
        _check_invariants(cl, t, a, result)
    result = engine.handle_scale_up(
        [NodeSpec("fresh0", "rack_fresh", 100.0, 4096.0)]
    )
    _check_invariants(cl, t, a, result)
