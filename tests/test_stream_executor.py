"""Real threaded executor test: a scheduled topology actually runs jitted
JAX ops end-to-end with emulated link latency."""

import pytest

pytest.importorskip("jax")  # optional-jax CI leg: the real executor is jax-only
import jax
import jax.numpy as jnp

from repro.core import RStormScheduler, emulab_cluster
from repro.stream import TopologyBuilder
from repro.stream.executor import LocalExecutor


def test_executor_runs_jax_topology():
    @jax.jit
    def spout_fn(i):
        return jnp.full((4,), i, jnp.float32)

    @jax.jit
    def double(x):
        return x * 2.0

    @jax.jit
    def square(x):
        return x * x

    b = TopologyBuilder("exec_demo")
    b.set_spout("src", fn=lambda i: spout_fn(i), parallelism=1)
    b.set_bolt("double", fn=double, parallelism=2, inputs=["src"])
    b.set_bolt("square", fn=square, parallelism=1, inputs=["double"])
    topo = b.create_topology()
    for comp in topo.components.values():
        comp.set_memory_load(128.0).set_cpu_load(10.0)

    cluster = emulab_cluster()
    assignment = RStormScheduler().schedule(topo, cluster, commit=False)
    ex = LocalExecutor(topo, assignment, cluster, latency_scale=0.1)
    stats = ex.run(max_tuples_per_spout=20, timeout_s=30.0)
    counts = stats.component_counts()
    assert counts.get("exec_demo/src") == 20
    assert counts.get("exec_demo/double", 0) == 20
    assert counts.get("exec_demo/square", 0) == 20
    # StatisticServer feeds service-time EWMAs (straggler input)
    assert stats.service_times()
