"""Simulator + multi-topology + rescheduler behaviour tests (paper §6)."""

import pytest

from repro.core import (
    GlobalState,
    Rescheduler,
    RoundRobinScheduler,
    RStormScheduler,
    StragglerMitigator,
    emulab_cluster,
    emulab_cluster_24,
)
from repro.stream import Simulator, topologies


def _run(topo, sched, cl):
    cl.reset()
    a = sched.schedule(topo, cl, commit=False)
    cl.reset()
    return a, Simulator(cl).run(topo, a)


# -- Fig 8 / 9 / 12 bands -----------------------------------------------------
@pytest.mark.parametrize("name,lo,hi", [("linear", 25, 80), ("diamond", 20, 60), ("star", 25, 70)])
def test_network_bound_gain_bands(name, lo, hi):
    cl = emulab_cluster()
    t = topologies.ALL_MICRO[name](network_bound=True)
    _, rr = _run(t, RoundRobinScheduler(seed=1), cl)
    _, rs = _run(t, RStormScheduler(), cl)
    gain = (rs.sink_throughput / rr.sink_throughput - 1) * 100
    assert lo <= gain <= hi, f"{name}: gain {gain:.1f}% outside [{lo},{hi}]"


@pytest.mark.parametrize("name", ["linear", "diamond"])
def test_cpu_bound_same_throughput_fewer_machines(name):
    cl = emulab_cluster()
    t = topologies.ALL_MICRO[name](network_bound=False)
    _, rr = _run(t, RoundRobinScheduler(seed=1), cl)
    _, rs = _run(t, RStormScheduler(), cl)
    assert rs.sink_throughput == pytest.approx(rr.sink_throughput, rel=0.05)
    assert rs.machines_used <= rr.machines_used * 0.67
    assert rs.avg_cpu_utilization > rr.avg_cpu_utilization * 1.4


def test_star_cpu_default_bottleneck():
    """§6.3.2: node-major default stacks heavy centre tasks -> bottleneck."""
    cl = emulab_cluster()
    t = topologies.star(network_bound=False)
    _, rr = _run(t, RoundRobinScheduler(seed=1, slot_mode="node_major"), cl)
    _, rs = _run(t, RStormScheduler(), cl)
    assert rs.sink_throughput > rr.sink_throughput * 2.0
    assert rs.avg_cpu_utilization > rr.avg_cpu_utilization * 2.5


@pytest.mark.parametrize("name,lo", [("pageload", 30), ("processing", 25)])
def test_yahoo_gains(name, lo):
    cl = emulab_cluster()
    t = topologies.ALL_YAHOO[name]()
    _, rr = _run(t, RoundRobinScheduler(seed=1), cl)
    _, rs = _run(t, RStormScheduler(), cl)
    gain = (rs.sink_throughput / rr.sink_throughput - 1) * 100
    assert gain >= lo


# -- Fig 13 multi-topology -------------------------------------------------------
def test_multi_topology_rstorm_keeps_both_healthy():
    cl = emulab_cluster_24()
    gs = GlobalState(cl)
    pl, pr = topologies.pageload(), topologies.processing()
    a1 = gs.submit(pl, RStormScheduler())
    a2 = gs.submit(pr, RStormScheduler())
    assert not a1.unassigned and not a2.unassigned
    res = Simulator(cl).run_many([(pl, a1), (pr, a2)])
    assert res["pageload"].thrashed_nodes == []
    assert res["processing"].sink_throughput > 1000
    assert res["pageload"].sink_throughput > 500


def test_multi_topology_default_collapses_processing():
    cl = emulab_cluster_24()
    gs = GlobalState(cl)
    pl, pr = topologies.pageload(), topologies.processing()
    a1 = gs.submit(pl, RoundRobinScheduler(seed=10, slot_mode="node_major"))
    a2 = gs.submit(pr, RoundRobinScheduler(seed=2, slot_mode="node_major"))
    res = Simulator(cl).run_many([(pl, a1), (pr, a2)])
    assert res["processing"].thrashed_nodes  # memory over-subscription
    assert res["processing"].sink_throughput < 100  # "grinded to a near halt"
    assert res["pageload"].sink_throughput > 300  # degraded but alive


def test_kill_returns_resources():
    cl = emulab_cluster_24()
    gs = GlobalState(cl)
    pl = topologies.pageload()
    gs.submit(pl, RStormScheduler())
    before = cl.total_available()["memory_mb"]
    gs.kill("pageload")
    after = cl.total_available()["memory_mb"]
    assert after > before
    assert after == pytest.approx(cl.total_capacity()["memory_mb"])


# -- fault tolerance ---------------------------------------------------------------
def test_rescheduler_moves_orphans_and_stays_feasible():
    cl = emulab_cluster()
    gs = GlobalState(cl)
    t = topologies.linear(network_bound=True)
    a = gs.submit(t, RStormScheduler())
    victim = a.nodes_used()[0]
    moved = Rescheduler(gs).handle_node_failure(victim)
    assert moved, "tasks should have been migrated"
    # All placements now on live nodes, hard constraints hold.
    for tid, nid in a.placements.items():
        assert cl.nodes[nid].alive
    assert a.hard_violations(t, cl) == []


def test_rescheduler_scale_up_places_unassigned():
    from repro.core import NodeSpec

    cl = emulab_cluster()
    gs = GlobalState(cl)
    t = topologies.linear(network_bound=True)
    a = gs.submit(t, RStormScheduler())
    # Kill enough nodes that some tasks cannot be placed.
    resch = Rescheduler(gs)
    for nid in list(a.nodes_used()):
        resch.handle_node_failure(nid)
    for nid in [n for n in cl.nodes if cl.nodes[n].alive][:4]:
        resch.handle_node_failure(nid)
    # Now scale up with fresh nodes; unassigned tasks must land.
    resch.handle_scale_up(
        [NodeSpec(f"new{i}", "rack_new", 100.0, 2048.0) for i in range(8)]
    )
    assert a.is_complete(t)


def test_straggler_mitigator_moves_slow_task():
    cl = emulab_cluster()
    gs = GlobalState(cl)
    t = topologies.linear(network_bound=True)
    a = gs.submit(t, RStormScheduler())
    tid = next(iter(a.placements))
    times = {x.id: 0.001 for x in t.all_tasks()}
    times[tid] = 0.5  # 500x the median
    mit = StragglerMitigator(gs)
    stragglers = mit.find_stragglers(times)
    assert tid in stragglers
    old_node = a.placements[tid]
    moves = mit.migrate([tid])
    assert moves.get(tid) is not None and moves[tid] != old_node
