"""Simulator + multi-topology + rescheduler behaviour tests (paper §6)."""

import pytest

from repro.core import (
    Component,
    GlobalState,
    Rescheduler,
    RoundRobinScheduler,
    RStormScheduler,
    StragglerMitigator,
    Topology,
    emulab_cluster,
    emulab_cluster_24,
)
from repro.stream import Simulator, topologies


def _run(topo, sched, cl):
    cl.reset()
    a = sched.schedule(topo, cl, commit=False)
    cl.reset()
    return a, Simulator(cl).run(topo, a)


# -- Fig 8 / 9 / 12 bands -----------------------------------------------------
@pytest.mark.parametrize("name,lo,hi", [("linear", 25, 80), ("diamond", 20, 60), ("star", 25, 70)])
def test_network_bound_gain_bands(name, lo, hi):
    cl = emulab_cluster()
    t = topologies.ALL_MICRO[name](network_bound=True)
    _, rr = _run(t, RoundRobinScheduler(seed=1), cl)
    _, rs = _run(t, RStormScheduler(), cl)
    gain = (rs.sink_throughput / rr.sink_throughput - 1) * 100
    assert lo <= gain <= hi, f"{name}: gain {gain:.1f}% outside [{lo},{hi}]"


@pytest.mark.parametrize("name", ["linear", "diamond"])
def test_cpu_bound_same_throughput_fewer_machines(name):
    cl = emulab_cluster()
    t = topologies.ALL_MICRO[name](network_bound=False)
    _, rr = _run(t, RoundRobinScheduler(seed=1), cl)
    _, rs = _run(t, RStormScheduler(), cl)
    assert rs.sink_throughput == pytest.approx(rr.sink_throughput, rel=0.05)
    assert rs.machines_used <= rr.machines_used * 0.67
    assert rs.avg_cpu_utilization > rr.avg_cpu_utilization * 1.4


def test_star_cpu_default_bottleneck():
    """§6.3.2: node-major default stacks heavy centre tasks -> bottleneck."""
    cl = emulab_cluster()
    t = topologies.star(network_bound=False)
    _, rr = _run(t, RoundRobinScheduler(seed=1, slot_mode="node_major"), cl)
    _, rs = _run(t, RStormScheduler(), cl)
    assert rs.sink_throughput > rr.sink_throughput * 2.0
    assert rs.avg_cpu_utilization > rr.avg_cpu_utilization * 2.5


@pytest.mark.parametrize("name,lo", [("pageload", 30), ("processing", 25)])
def test_yahoo_gains(name, lo):
    cl = emulab_cluster()
    t = topologies.ALL_YAHOO[name]()
    _, rr = _run(t, RoundRobinScheduler(seed=1), cl)
    _, rs = _run(t, RStormScheduler(), cl)
    gain = (rs.sink_throughput / rr.sink_throughput - 1) * 100
    assert gain >= lo


# -- Fig 13 multi-topology -------------------------------------------------------
def test_multi_topology_rstorm_keeps_both_healthy():
    cl = emulab_cluster_24()
    gs = GlobalState(cl)
    pl, pr = topologies.pageload(), topologies.processing()
    a1 = gs.submit(pl, RStormScheduler())
    a2 = gs.submit(pr, RStormScheduler())
    assert not a1.unassigned and not a2.unassigned
    res = Simulator(cl).run_many([(pl, a1), (pr, a2)])
    assert res["pageload"].thrashed_nodes == []
    assert res["processing"].sink_throughput > 1000
    assert res["pageload"].sink_throughput > 500


def test_multi_topology_default_collapses_processing():
    cl = emulab_cluster_24()
    gs = GlobalState(cl)
    pl, pr = topologies.pageload(), topologies.processing()
    a1 = gs.submit(pl, RoundRobinScheduler(seed=10, slot_mode="node_major"))
    a2 = gs.submit(pr, RoundRobinScheduler(seed=2, slot_mode="node_major"))
    res = Simulator(cl).run_many([(pl, a1), (pr, a2)])
    assert res["processing"].thrashed_nodes  # memory over-subscription
    assert res["processing"].sink_throughput < 100  # "grinded to a near halt"
    assert res["pageload"].sink_throughput > 300  # degraded but alive


def test_kill_returns_resources():
    cl = emulab_cluster_24()
    gs = GlobalState(cl)
    pl = topologies.pageload()
    gs.submit(pl, RStormScheduler())
    before = cl.total_available()["memory_mb"]
    gs.kill("pageload")
    after = cl.total_available()["memory_mb"]
    assert after > before
    assert after == pytest.approx(cl.total_capacity()["memory_mb"])


# -- shedding propagation (zero-lossless-rate regression) --------------------------
def test_shedding_zero_lossless_rate_edge_not_dropped():
    """Regression: a source task whose lossless rate vanishes (a tiny
    upstream emit ratio) used to have its shed flow silently dropped —
    every downstream component, however much it re-amplifies, reported 0.
    The fix splits by raw route multiplicity instead."""
    def build(emit: float) -> Topology:
        t = Topology("tinyemit")
        spout = Component(
            "s", is_spout=True, parallelism=1, max_rate_per_task=100.0
        )
        spout.set_memory_load(64.0).set_cpu_load(5.0)
        damp = Component(
            "damp", parallelism=1, emit_ratio=emit, cpu_cost_per_tuple=1e-4
        )
        damp.set_memory_load(64.0).set_cpu_load(5.0)
        # Two downstream amplifier components: broadcast semantics — each
        # must receive damp's FULL output stream, also in the fallback.
        for name in ("amp_a", "amp_b"):
            amp = Component(
                name, parallelism=2, emit_ratio=1.0 / emit, cpu_cost_per_tuple=1e-4
            )
            amp.set_memory_load(64.0).set_cpu_load(5.0)
            t.add_component(amp)
        sink = Component("sink", parallelism=1, cpu_cost_per_tuple=1e-4)
        sink.set_memory_load(64.0).set_cpu_load(5.0)
        t.add_component(spout)
        t.add_component(damp)
        t.add_component(sink)
        t.add_edge("s", "damp")
        t.add_edge("damp", "amp_a")
        t.add_edge("damp", "amp_b")
        t.add_edge("amp_a", "sink")
        t.add_edge("amp_b", "sink")
        t.acked = False  # unanchored: sink rate comes from shedding propagation
        return t

    def sink_tp(emit: float) -> float:
        t = build(emit)
        cl = emulab_cluster()
        a = RStormScheduler().schedule(t, cl, commit=False)
        cl.reset()
        res = Simulator(cl).run(t, a)
        assert res.spout_rate == pytest.approx(100.0)
        return res.sink_throughput

    # Lossless sink rate is λ × 2 branches; the dropped-flow bug reported
    # ~0 in the fallback branch, and the first fix halved it (split across
    # all routes instead of per destination component).
    assert sink_tp(1e-13) == pytest.approx(200.0, rel=1e-3)
    # The fallback must agree with the normal branch on the same topology
    # shape (emit ratio above the _EPS threshold).
    assert sink_tp(1e-3) == pytest.approx(200.0, rel=1e-3)


# -- warm-start fidelity -----------------------------------------------------------
@pytest.mark.parametrize(
    "maker",
    [topologies.pageload, lambda: topologies.linear(network_bound=True)],
)
def test_warm_start_at_fixed_point_reproduces_cold_lambda(maker):
    """run_many(warm_start=λ*) entered at an existing fixed point must land
    on the cold-start λ* (the path shortens; the destination must not)."""
    t = maker()
    cl = emulab_cluster()
    a = RStormScheduler().schedule(t, cl, commit=False)
    cl.reset()
    sim = Simulator(cl)
    cold = sim.run(t, a)
    warm = sim.run_many([(t, a)], warm_start={t.id: cold.spout_rate})[t.id]
    assert warm.spout_rate == pytest.approx(cold.spout_rate, rel=1e-6)
    assert warm.sink_throughput == pytest.approx(cold.sink_throughput, rel=1e-6)
    assert warm.binding == cold.binding


def test_warm_start_multi_topology_fixed_point():
    cl = emulab_cluster_24()
    gs = GlobalState(cl)
    pl, pr = topologies.pageload(), topologies.processing()
    a1 = gs.submit(pl, RStormScheduler())
    a2 = gs.submit(pr, RStormScheduler())
    sim = Simulator(cl)
    cold = sim.run_many([(pl, a1), (pr, a2)])
    warm = sim.run_many(
        [(pl, a1), (pr, a2)],
        warm_start={tid: r.spout_rate for tid, r in cold.items()},
    )
    for tid in cold:
        assert warm[tid].spout_rate == pytest.approx(
            cold[tid].spout_rate, rel=1e-6
        )


# -- fault tolerance ---------------------------------------------------------------
def test_rescheduler_moves_orphans_and_stays_feasible():
    cl = emulab_cluster()
    gs = GlobalState(cl)
    t = topologies.linear(network_bound=True)
    a = gs.submit(t, RStormScheduler())
    victim = a.nodes_used()[0]
    moved = Rescheduler(gs).handle_node_failure(victim)
    assert moved, "tasks should have been migrated"
    # All placements now on live nodes, hard constraints hold.
    for tid, nid in a.placements.items():
        assert cl.nodes[nid].alive
    assert a.hard_violations(t, cl) == []


def test_rescheduler_scale_up_places_unassigned():
    from repro.core import NodeSpec

    cl = emulab_cluster()
    gs = GlobalState(cl)
    t = topologies.linear(network_bound=True)
    a = gs.submit(t, RStormScheduler())
    # Kill enough nodes that some tasks cannot be placed.
    resch = Rescheduler(gs)
    for nid in list(a.nodes_used()):
        resch.handle_node_failure(nid)
    for nid in [n for n in cl.nodes if cl.nodes[n].alive][:4]:
        resch.handle_node_failure(nid)
    # Now scale up with fresh nodes; unassigned tasks must land.
    resch.handle_scale_up(
        [NodeSpec(f"new{i}", "rack_new", 100.0, 2048.0) for i in range(8)]
    )
    assert a.is_complete(t)


def test_straggler_mitigator_moves_slow_task():
    cl = emulab_cluster()
    gs = GlobalState(cl)
    t = topologies.linear(network_bound=True)
    a = gs.submit(t, RStormScheduler())
    tid = next(iter(a.placements))
    times = {x.id: 0.001 for x in t.all_tasks()}
    times[tid] = 0.5  # 500x the median
    mit = StragglerMitigator(gs)
    stragglers = mit.find_stragglers(times)
    assert tid in stragglers
    old_node = a.placements[tid]
    moves = mit.migrate([tid])
    assert moves.get(tid) is not None and moves[tid] != old_node
