"""Placement planner tests: sharding rules, memory model, hard-constraint
escalation, expert placement via the paper's scheduler."""

import pytest

pytest.importorskip("jax")  # optional-jax CI leg: the mesh planner is jax-only
import jax
import numpy as np
from jax.sharding import PartitionSpec as P

from repro import configs
from repro.configs.base import SHAPES, shape_by_name
from repro.models import build, cell_skip_reason
from repro.placement import (
    InfeasiblePlanError,
    MeshShape,
    ResourceAwarePlanner,
    plan_expert_placement,
    round_robin_expert_placement,
)
from repro.placement.sharding_rules import (
    cache_partition_spec,
    choose_tp_axis,
    param_partition_spec,
)

MESH_SP = MeshShape({"data": 16, "model": 16})
MESH_MP = MeshShape({"pod": 2, "data": 16, "model": 16})


def test_tp_divisibility_fallbacks():
    cfg = configs.get("smollm-360m")  # 15 heads, kv=5 — not 16-divisible
    # q_heads dim must NOT take the model axis; embed (960) does.
    spec = param_partition_spec(cfg, ("embed", "q_heads"), (960, 960), MESH_SP, False)
    assert spec == P("model", None)
    cfg2 = configs.get("deepseek-7b")  # 32 heads
    spec2 = param_partition_spec(cfg2, ("embed", "q_heads"), (4096, 4096), MESH_SP, False)
    assert spec2 == P(None, "model")


def test_moe_expert_sharding_prefers_experts_axis():
    cfg = configs.get("olmoe-1b-7b")  # 64 experts % 16 == 0
    spec = param_partition_spec(
        cfg, ("experts", "embed", "ffn"), (64, 2048, 1024), MESH_SP, False
    )
    assert spec == P("model", None, None)
    cfg2 = configs.get("mixtral-8x7b")  # 8 experts, not divisible -> ffn
    spec2 = param_partition_spec(
        cfg2, ("experts", "embed", "ffn"), (8, 4096, 14336), MESH_SP, False
    )
    assert spec2 == P(None, None, "model")


def test_fsdp_adds_data_axis():
    cfg = configs.get("deepseek-7b")
    spec = param_partition_spec(cfg, ("embed", "ffn"), (4096, 11008), MESH_SP, True)
    assert "model" in jax.tree_util.tree_leaves(spec) or spec[1] == "model"
    assert spec[0] == ("data",) or spec[0] == "data"


def test_kv_cache_sequence_parallel_fallback():
    cfg = configs.get("qwen3-0.6b")  # kv=8 not divisible by 16
    spec = cache_partition_spec(cfg, "k", (28, 128, 32768, 8, 128), MESH_SP, True)
    # batch over data; sequence (not kv heads) over model
    assert spec[1] == "data" and spec[2] == "model" and spec[3] is None
    cfg2 = configs.get("deepseek-7b")  # kv=32 divisible
    spec2 = cache_partition_spec(cfg2, "k", (30, 128, 32768, 32, 128), MESH_SP, True)
    assert spec2[3] == "model" and spec2[2] is None


@pytest.mark.parametrize("mesh", [MESH_SP, MESH_MP], ids=["single_pod", "multi_pod"])
def test_all_runnable_cells_plan_feasibly(mesh):
    planner = ResourceAwarePlanner()
    for arch in configs.ARCHS:
        m = build(arch)
        for shp in SHAPES:
            if cell_skip_reason(m.cfg, shp):
                continue
            plan = planner.plan(m, shp, mesh)
            assert plan.memory.fits, f"{arch}/{shp.name} does not fit"


def test_escalation_marks_big_models():
    planner = ResourceAwarePlanner()
    plan = planner.plan(build("mixtral-8x7b"), shape_by_name("train_4k"), MESH_SP)
    assert plan.fsdp and plan.n_micro > 1
    plan_small = planner.plan(build("xlstm-350m"), shape_by_name("train_4k"), MESH_SP)
    assert not plan_small.fsdp and plan_small.n_micro == 1


def test_infeasible_raises():
    from repro.placement import ChipSpec

    tiny = ChipSpec(hbm_bytes=1 * 1024**3)  # 1 GiB chips
    planner = ResourceAwarePlanner(chip=tiny)
    with pytest.raises(InfeasiblePlanError):
        planner.plan(build("mixtral-8x7b"), shape_by_name("train_4k"), MESH_SP)


def test_expert_placement_hard_constraint_and_balance():
    cfg = configs.get("olmoe-1b-7b")
    rng = np.random.default_rng(1)
    load = rng.zipf(1.4, cfg.n_experts).astype(float)
    rs = plan_expert_placement(cfg, MESH_MP, load)
    assert not rs["unassigned"]
    # every expert placed; per-group HBM budget respected by construction
    assert len(rs["assignment"]) == cfg.n_experts
    rr = round_robin_expert_placement(cfg, MESH_MP, load)
    assert rs["max_load_share"] <= rr["max_load_share"] * 1.05


def test_long500k_skips_are_exactly_the_full_attention_archs():
    skips = []
    for arch in configs.ARCHS:
        cfg = configs.get(arch)
        if cell_skip_reason(cfg, shape_by_name("long_500k")):
            skips.append(arch)
    assert sorted(skips) == sorted(
        [
            "olmoe-1b-7b",
            "phi-3-vision-4.2b",
            "deepseek-7b",
            "smollm-360m",
            "internlm2-1.8b",
            "qwen3-0.6b",
            "whisper-large-v3",
        ]
    )
