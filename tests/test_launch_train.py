"""Integration test for the distributed launcher: planner-driven sharded
training on forced host devices, with checkpoint-resume (fault tolerance)."""

import os
import subprocess
import sys

import pytest

pytest.importorskip("jax")  # the subprocess under test imports jax


def _env():
    # Hermetic except for the platform pin: without JAX_PLATFORMS the
    # subprocess's jax import can hang probing for accelerator backends
    # on hosts that set it for exactly that reason.
    env = {"PYTHONPATH": "src", "PATH": "/usr/bin:/bin"}
    if "JAX_PLATFORMS" in os.environ:
        env["JAX_PLATFORMS"] = os.environ["JAX_PLATFORMS"]
    return env


def _run(extra, ckpt):
    return subprocess.run(
        [
            sys.executable, "-m", "repro.launch.train",
            "--arch", "smollm-360m", "--smoke", "--devices", "4",
            "--batch", "8", "--seq-len", "32", "--ckpt-dir", ckpt,
        ]
        + extra,
        capture_output=True,
        text=True,
        timeout=420,
        env=_env(),
        cwd=".",
    )


@pytest.mark.slow
def test_launcher_trains_and_resumes(tmp_path):
    ckpt = str(tmp_path / "ck")
    p1 = _run(["--steps", "20"], ckpt)
    assert p1.returncode == 0, p1.stderr[-2000:]
    assert "step   20" in p1.stdout
    # Restart from the step-20 checkpoint and continue to 30.
    p2 = _run(["--steps", "30", "--resume"], ckpt)
    assert p2.returncode == 0, p2.stderr[-2000:]
    assert "resumed from step 20" in p2.stdout
    assert "step   30" in p2.stdout
