"""Hypothesis property tests for the scheduling core (skipped when the
``hypothesis`` dependency is absent — the container does not bake it in)."""

import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core import (  # noqa: E402
    Cluster,
    RoundRobinScheduler,
    RStormScheduler,
    emulab_cluster,
)

from test_schedulers import linear_topology  # noqa: E402


@settings(max_examples=30, deadline=None)
@given(
    n_bolts=st.integers(1, 6),
    par=st.integers(1, 6),
    mem=st.floats(16.0, 1024.0),
    cpu=st.floats(1.0, 120.0),
    racks=st.integers(1, 4),
    npr=st.integers(1, 8),
)
def test_property_hard_constraints_never_violated(n_bolts, par, mem, cpu, racks, npr):
    t = linear_topology(n_bolts=n_bolts, parallelism=par, mem=mem, cpu=cpu)
    cl = Cluster.homogeneous(racks=racks, nodes_per_rack=npr)
    a = RStormScheduler().schedule(t, cl, commit=False)
    # Invariant 1: placements ∪ unassigned is a partition of all tasks.
    all_ids = {tk.id for tk in t.all_tasks()}
    assert set(a.placements) | set(a.unassigned) == all_ids
    assert not (set(a.placements) & set(a.unassigned))
    # Invariant 2: no node over its hard memory budget.
    assert a.hard_violations(t, cl) == []
    # Invariant 3: if memory fits anywhere, at least one task is placed.
    if mem <= 2048.0:
        assert a.placements


@settings(max_examples=20, deadline=None)
@given(par=st.integers(1, 5), seed=st.integers(0, 10))
def test_property_rstorm_netcost_beats_or_ties_roundrobin(par, seed):
    t = linear_topology(n_bolts=3, parallelism=par)
    cl = emulab_cluster()
    rr = RoundRobinScheduler(seed=seed).schedule(t, cl, commit=False)
    cl.reset()
    rs = RStormScheduler().schedule(t, cl, commit=False)
    assert rs.network_cost(t, cl) <= rr.network_cost(t, cl) + 1e-9


@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 50))
def test_property_schedulers_are_deterministic(seed):
    t = linear_topology()
    cl = emulab_cluster()
    a1 = RStormScheduler().schedule(t, cl, commit=False)
    cl.reset()
    a2 = RStormScheduler().schedule(t, cl, commit=False)
    assert a1.placements == a2.placements
    cl.reset()
    b1 = RoundRobinScheduler(seed=seed).schedule(t, cl, commit=False)
    cl.reset()
    b2 = RoundRobinScheduler(seed=seed).schedule(t, cl, commit=False)
    assert b1.placements == b2.placements
