"""Golden-equivalence suite: the array-backed engine must reproduce the
legacy dict path's *exact* placements, unassigned sets and network cost for
every registered scheduler across the benchmark topologies (chain, star,
Yahoo, multi-topology), plus arena unit tests (ledger, net matrix, select).
"""

import numpy as np
import pytest

from repro.core import (
    Assignment,
    Cluster,
    Component,
    GlobalState,
    NodeSpec,
    PlacementArena,
    REGISTRY,
    Topology,
    demand,
    emulab_cluster,
    emulab_cluster_24,
    get_scheduler,
    scheduler_names,
)
from repro.stream import topologies as T

#: The arena-vs-legacy equivalence contract only applies to schedulers that
#: expose both engines; pure-search schedulers (rstorm-search) have no
#: legacy dict path and are covered by tests/test_search.py instead.
DUAL_ENGINE = [
    n for n in scheduler_names() if "engine" in REGISTRY[n].kwargs_schema
]


def chain_topology(components=6, parallelism=5, mem=128.0, cpu=10.0):
    t = Topology(f"chain{components}x{parallelism}")
    prev = None
    for i in range(components):
        c = Component(f"c{i}", is_spout=(i == 0), parallelism=parallelism)
        c.set_memory_load(mem).set_cpu_load(cpu)
        t.add_component(c)
        if prev:
            t.add_edge(prev, c.id)
        prev = c.id
    return t


def hetero_cluster():
    """Mixed capacities/racks — exercises non-tied ref-node selection."""
    specs = []
    for r, (cpu, mem) in enumerate([(100.0, 2048.0), (200.0, 4096.0), (50.0, 1024.0)]):
        for n in range(4):
            specs.append(
                NodeSpec(
                    node_id=f"r{r}n{n}",
                    rack_id=f"rack{r}",
                    cpu_capacity=cpu,
                    memory_capacity_mb=mem,
                )
            )
    return Cluster(specs)


CASES = [
    ("chain", lambda: chain_topology(), emulab_cluster),
    ("chain_big", lambda: chain_topology(10, 10), lambda: Cluster.homogeneous(racks=4, nodes_per_rack=8, memory_mb=8192.0, cpu=400.0)),
    ("linear_net", lambda: T.linear(True), emulab_cluster),
    ("linear_cpu", lambda: T.linear(False), emulab_cluster),
    ("diamond_net", lambda: T.diamond(True), emulab_cluster),
    ("star_net", lambda: T.star(True), emulab_cluster),
    ("star_cpu", lambda: T.star(False), emulab_cluster),
    ("pageload", T.pageload, emulab_cluster_24),
    ("processing", T.processing, emulab_cluster_24),
    ("hetero", lambda: chain_topology(4, 6, mem=700.0, cpu=40.0), hetero_cluster),
    ("infeasible", lambda: chain_topology(3, 3, mem=8192.0), emulab_cluster),
]

#: Non-default kwargs per scheduler (kept small so the suite stays fast).
SCHED_KWARGS = {"rstorm_annealed": {"iters": 250}, "round_robin": {"seed": 3}}


def both_engines(name, topology, cluster):
    kwargs = SCHED_KWARGS.get(name, {})
    a = get_scheduler(name, engine="arena", **kwargs).schedule(
        topology, cluster, commit=False
    )
    cluster.reset()
    b = get_scheduler(name, engine="legacy", **kwargs).schedule(
        topology, cluster, commit=False
    )
    return a, b


@pytest.mark.parametrize("case", [c[0] for c in CASES])
@pytest.mark.parametrize("name", DUAL_ENGINE)
def test_arena_reproduces_legacy_placements(case, name):
    _, topo_factory, cluster_factory = next(c for c in CASES if c[0] == case)
    topology = topo_factory()
    cluster = cluster_factory()
    a, b = both_engines(name, topology, cluster)
    assert a.placements == b.placements
    assert sorted(a.unassigned) == sorted(b.unassigned)
    assert a.network_cost(topology, cluster) == b.network_cost(topology, cluster)


@pytest.mark.parametrize("name", DUAL_ENGINE)
def test_arena_reproduces_legacy_after_node_failure(name):
    """Dead nodes flow through the alive mask and ref-node re-establishment."""
    results = []
    for engine in ("arena", "legacy"):
        cluster = emulab_cluster()
        get_scheduler("rstorm", engine=engine).schedule(
            chain_topology(3, 4, mem=256.0), cluster, commit=True
        )
        cluster.fail_node("r0n0")
        a = get_scheduler(name, engine=engine, **SCHED_KWARGS.get(name, {})).schedule(
            T.linear(True), cluster, commit=False
        )
        results.append((dict(a.placements), sorted(a.unassigned)))
        assert "r0n0" not in a.placements.values()
    assert results[0] == results[1]


def test_multi_topology_submission_identical_end_state():
    """§6.5: sequential submits see already-decremented availability."""
    def run(engine):
        state = GlobalState(emulab_cluster_24())
        sched = get_scheduler("rstorm", engine=engine)
        a1 = state.submit(T.pageload(), sched)
        a2 = state.submit(T.processing(), sched)
        avail = {nid: dict(n.available.values) for nid, n in state.cluster.nodes.items()}
        return dict(a1.placements), dict(a2.placements), avail

    assert run("arena") == run("legacy")


# -- arena unit tests ----------------------------------------------------------
def test_net_matrix_matches_cluster_network_distance():
    cluster = emulab_cluster()
    arena = PlacementArena(cluster)
    for i, a in enumerate(arena.node_ids):
        for j, b in enumerate(arena.node_ids):
            assert arena.net[i, j] == cluster.network_distance(a, b)


def test_ledger_snapshot_rollback_restores_exactly():
    arena = PlacementArena(emulab_cluster())
    row, _ = arena.compile_demand(demand(512.0, 30.0, 1.0))
    snap = arena.snapshot()
    before = arena.avail.copy()
    for i in (0, 3, 3, 7):
        arena.assign(i, row)
    assert not np.array_equal(arena.avail, before)
    arena.rollback(snap)
    assert np.array_equal(arena.avail, before)
    # snapshot is a copy, not a view — later assigns must not corrupt it.
    arena.assign(1, row)
    assert np.array_equal(snap, before)


def test_select_returns_none_when_infeasible():
    arena = PlacementArena(emulab_cluster())
    row, hard = arena.compile_demand(demand(99999.0, 1.0))
    assert arena.select(row, hard, ref_idx=0) is None


def test_select_skips_dead_nodes():
    cluster = emulab_cluster()
    arena = PlacementArena(cluster)
    row, hard = arena.compile_demand(demand(128.0, 10.0))
    ref = arena.establish_ref_node()
    first = arena.select(row, hard, ref)
    arena.alive[first] = False
    second = arena.select(row, hard, ref)
    assert second is not None and second != first


def test_arena_network_cost_matches_assignment():
    topology = chain_topology(4, 3)
    cluster = emulab_cluster()
    a = get_scheduler("rstorm").schedule(topology, cluster, commit=False)
    arena = PlacementArena(cluster, topology)
    tids = sorted(a.placements)
    tindex = {tid: i for i, tid in enumerate(tids)}
    placement = np.array([arena.index[a.placements[t]] for t in tids])
    edges = np.array(
        [
            [tindex[s.id], tindex[d.id]]
            for s, d in topology.task_edges()
            if s.id in tindex and d.id in tindex
        ]
    )
    assert arena.network_cost(placement, edges) == a.network_cost(topology, cluster)


def test_engine_kwarg_validated_by_registry():
    with pytest.raises(TypeError, match="engine"):
        get_scheduler("rstorm", engine="turbo")
    with pytest.raises(ValueError, match="unknown engine"):
        from repro.core import RStormScheduler

        RStormScheduler(engine="turbo")
