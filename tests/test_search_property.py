"""Hypothesis property tests for the batched search subsystem (skipped when
the ``hypothesis`` dependency is absent — the container does not bake it in).

Resource values are drawn from dyadic grids so sums are exact in float64;
that is the domain where the subsystem guarantees jax/numpy golden equality
and where the never-worse/no-violation properties are exact, not approximate.
"""

import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

import numpy as np  # noqa: E402

from repro.core import (  # noqa: E402
    Cluster,
    Component,
    Topology,
    get_scheduler,
)
from repro.core.search import HAS_JAX  # noqa: E402

#: Dyadic memory/cpu grids (exact float sums at any count that fits a node).
MEMS = st.sampled_from([32.0, 64.0, 128.0, 256.0, 512.0])
CPUS = st.sampled_from([5.0, 10.0, 20.0, 40.0])


def linear_topology(n_bolts, parallelism, mem, cpu):
    t = Topology(f"lin{n_bolts}x{parallelism}")
    prev = None
    for i in range(n_bolts + 1):
        c = Component(f"c{i}", is_spout=(i == 0), parallelism=parallelism)
        c.set_memory_load(mem).set_cpu_load(cpu)
        t.add_component(c)
        if prev:
            t.add_edge(prev, c.id)
        prev = c.id
    return t


@settings(max_examples=15, deadline=None)
@given(
    n_bolts=st.integers(1, 4),
    par=st.integers(1, 5),
    mem=MEMS,
    cpu=CPUS,
    racks=st.integers(1, 3),
    npr=st.integers(2, 6),
    seed=st.integers(0, 100),
)
def test_property_search_never_worse_and_never_violates(
    n_bolts, par, mem, cpu, racks, npr, seed
):
    t = linear_topology(n_bolts, par, mem, cpu)
    cl = Cluster.homogeneous(racks=racks, nodes_per_rack=npr)
    greedy = get_scheduler("rstorm").schedule(t, cl, commit=False)
    cl.reset()
    s = get_scheduler(
        "rstorm-search", n_chains=6, steps=80, seed=seed
    ).schedule(t, cl, commit=False)
    # At least greedy's task coverage (the recovery pass may place tasks
    # greedy stranded, never the reverse); never a hard-constraint
    # violation; and on the same task set, never a higher network cost.
    assert set(greedy.placements) <= set(s.placements)
    assert set(s.unassigned) <= set(greedy.unassigned)
    assert s.hard_violations(t, cl) == []
    if set(s.placements) == set(greedy.placements):
        assert s.network_cost(t, cl) <= greedy.network_cost(t, cl)


@settings(max_examples=10, deadline=None)
@given(par=st.integers(1, 5), seed=st.integers(0, 50))
def test_property_search_deterministic_across_runs_and_backends(par, seed):
    t = linear_topology(3, par, 128.0, 10.0)
    cl = Cluster.homogeneous(racks=2, nodes_per_rack=4)
    kw = dict(n_chains=6, steps=60, seed=seed)
    a = get_scheduler("rstorm-search", backend="numpy", **kw).schedule(
        t, cl, commit=False
    )
    cl.reset()
    b = get_scheduler("rstorm-search", backend="numpy", **kw).schedule(
        t, cl, commit=False
    )
    assert a.placements == b.placements
    if HAS_JAX:
        cl.reset()
        c = get_scheduler("rstorm-search", backend="jax", **kw).schedule(
            t, cl, commit=False
        )
        assert a.placements == c.placements
