"""Per-kernel validation: Pallas (interpret=True) vs pure-jnp oracle, with
hypothesis shape/dtype sweeps (assignment deliverable c)."""

import pytest

pytest.importorskip("jax")  # optional-jax CI leg: kernels are jax-only
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.kernels.decode_attn import decode_attention, decode_attention_ref
from repro.kernels.flash import attention_ref, flash_attention, flash_attention_op
from repro.kernels.mlstm import mlstm_chunk, mlstm_chunk_op, mlstm_ref
from repro.kernels.moe_gemm import grouped_gemm, grouped_gemm_ref
from repro.kernels.rglru import rglru_scan, rglru_scan_ref

KEY = jax.random.PRNGKey(0)


def tol_for(dt):
    return 3e-2 if dt == jnp.bfloat16 else 1e-4


def assert_close(got, ref, dt):
    got = np.asarray(got, np.float32)
    ref = np.asarray(ref, np.float32)
    denom = max(np.max(np.abs(ref)), 1e-6)
    assert np.max(np.abs(got - ref)) / denom <= tol_for(dt), (
        f"relerr {np.max(np.abs(got - ref)) / denom:.2e}"
    )


# -- flash attention ---------------------------------------------------------------
@settings(max_examples=8, deadline=None)
@given(
    b=st.sampled_from([1, 2]),
    kv=st.sampled_from([1, 2, 4]),
    g=st.sampled_from([1, 2]),
    s=st.sampled_from([128, 256]),
    hd=st.sampled_from([32, 64]),
    causal=st.booleans(),
    dt=st.sampled_from([jnp.float32, jnp.bfloat16]),
)
def test_flash_attention_sweep(b, kv, g, s, hd, causal, dt):
    h = kv * g
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (b, h, s, hd), dt)
    k = jax.random.normal(ks[1], (b, kv, s, hd), dt)
    v = jax.random.normal(ks[2], (b, kv, s, hd), dt)
    got = flash_attention(q, k, v, causal=causal, block_q=128, block_k=128, interpret=True)
    ref = attention_ref(q, k, v, causal=causal)
    assert_close(got, ref, dt)


@pytest.mark.parametrize("window", [64, 128, 256])
def test_flash_attention_sliding_window(window):
    b, h, kv, s, hd = 1, 4, 2, 512, 64
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (b, h, s, hd))
    k = jax.random.normal(ks[1], (b, kv, s, hd))
    v = jax.random.normal(ks[2], (b, kv, s, hd))
    got = flash_attention(q, k, v, causal=True, window=window, interpret=True)
    ref = attention_ref(q, k, v, causal=True, window=window)
    assert_close(got, ref, jnp.float32)


def test_flash_op_model_layout():
    b, s, h, kv, hd = 2, 256, 4, 2, 64
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (b, s, h, hd))
    k = jax.random.normal(ks[1], (b, s, kv, hd))
    v = jax.random.normal(ks[2], (b, s, kv, hd))
    got = flash_attention_op(q, k, v, interpret=True)
    ref = attention_ref(
        q.transpose(0, 2, 1, 3), k.transpose(0, 2, 1, 3), v.transpose(0, 2, 1, 3)
    ).transpose(0, 2, 1, 3)
    assert_close(got, ref, jnp.float32)


# -- decode attention -----------------------------------------------------------------
@settings(max_examples=8, deadline=None)
@given(
    b=st.sampled_from([1, 2]),
    kv=st.sampled_from([1, 2]),
    g=st.sampled_from([1, 4]),
    s=st.sampled_from([512, 1024]),
    frac=st.floats(0.01, 1.0),
    dt=st.sampled_from([jnp.float32, jnp.bfloat16]),
)
def test_decode_attention_sweep(b, kv, g, s, frac, dt):
    h, hd = kv * g, 64
    length = max(int(s * frac), 1)
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (b, h, hd), dt)
    k = jax.random.normal(ks[1], (b, kv, s, hd), dt)
    v = jax.random.normal(ks[2], (b, kv, s, hd), dt)
    got = decode_attention(q, k, v, length, block_k=256, interpret=True)
    ref = decode_attention_ref(q, k, v, length)
    assert_close(got, ref, dt)


# -- rglru ------------------------------------------------------------------------------
@settings(max_examples=8, deadline=None)
@given(
    b=st.sampled_from([1, 3]),
    s=st.sampled_from([128, 256, 512]),
    d=st.sampled_from([64, 128]),
    blk=st.sampled_from([64, 128, 256]),
)
def test_rglru_sweep(b, s, d, blk):
    if s % blk != 0:
        blk = s
    ks = jax.random.split(KEY, 3)
    a = jax.nn.sigmoid(jax.random.normal(ks[0], (b, s, d)))
    x = jax.random.normal(ks[1], (b, s, d))
    h0 = jax.random.normal(ks[2], (b, d))
    got = rglru_scan(a, x, h0, block_t=blk, interpret=True)
    ref = rglru_scan_ref(a, x, h0)
    assert_close(got, ref, jnp.float32)


def test_rglru_matches_model_block():
    """Kernel vs the model's associative-scan implementation."""
    from repro.models.recurrent import rglru_forward, rglru_spec
    from repro.models.common import init_from_spec
    from repro import configs

    cfg = configs.get_smoke("recurrentgemma-9b")
    p = init_from_spec(rglru_spec(cfg), KEY)
    x = jax.random.normal(KEY, (2, 32, cfg.d_model), jnp.float32)
    out_model, state = rglru_forward(cfg, p, x)
    # Re-derive a,gated as the model does and push through the kernel.
    from repro.models.recurrent import _causal_conv4, _rglru_gates

    xb, _ = _causal_conv4(p, x @ p["w_in_x"])
    a, gated = _rglru_gates(p, xb, x)
    h = rglru_scan(a, gated, jnp.zeros((2, cfg.d_model)), block_t=32, interpret=True)
    gate = jax.nn.gelu((x @ p["w_in_gate"]).astype(jnp.float32))
    out_kernel = (h * gate) @ p["w_out"]
    assert_close(out_kernel, out_model, jnp.float32)


# -- mlstm -------------------------------------------------------------------------------
@settings(max_examples=6, deadline=None)
@given(
    b=st.sampled_from([1, 2]),
    h=st.sampled_from([1, 2]),
    s=st.sampled_from([128, 256]),
    hd=st.sampled_from([32, 64]),
    chunk=st.sampled_from([64, 128]),
)
def test_mlstm_sweep(b, h, s, hd, chunk):
    ks = jax.random.split(KEY, 5)
    q = jax.random.normal(ks[0], (b, h, s, hd))
    k = jax.random.normal(ks[1], (b, h, s, hd)) / np.sqrt(hd)
    v = jax.random.normal(ks[2], (b, h, s, hd))
    li = jax.random.normal(ks[3], (b, h, s))
    lf = jax.nn.log_sigmoid(jax.random.normal(ks[4], (b, h, s)) + 2.0)
    got = mlstm_chunk(q, k, v, li, lf, chunk=chunk, interpret=True)
    ref = mlstm_ref(q, k, v, li, lf)
    assert_close(got, ref, jnp.float32)


def test_mlstm_matches_model_forward():
    """Kernel vs the model's chunkwise jnp implementation."""
    from repro.models.recurrent import _mlstm_qkv_gates, mlstm_spec
    from repro.models.common import init_from_spec
    from repro import configs

    cfg = configs.get_smoke("xlstm-350m")
    p = init_from_spec(mlstm_spec(cfg), KEY)
    x = jax.random.normal(KEY, (2, 64, cfg.d_model), jnp.float32)
    q, k, v, li, lf = _mlstm_qkv_gates(cfg, p, x)
    got = mlstm_chunk(q, k, v, li, lf, chunk=32, interpret=True)
    ref = mlstm_ref(q, k, v, li, lf)
    assert_close(got, ref, jnp.float32)


# -- grouped gemm ---------------------------------------------------------------------------
@settings(max_examples=8, deadline=None)
@given(
    e=st.sampled_from([1, 4, 8]),
    c=st.sampled_from([128, 256]),
    d=st.sampled_from([128, 256]),
    f=st.sampled_from([128, 384]),
    dt=st.sampled_from([jnp.float32, jnp.bfloat16]),
)
def test_grouped_gemm_sweep(e, c, d, f, dt):
    ks = jax.random.split(KEY, 2)
    x = jax.random.normal(ks[0], (e, c, d), dt)
    w = jax.random.normal(ks[1], (e, d, f), dt) * 0.05
    got = grouped_gemm(x, w, interpret=True)
    ref = grouped_gemm_ref(x, w)
    assert_close(got, ref, dt)
