"""Property-based DES invariants (hypothesis).

Mirrors ``test_stream_des``'s hand-picked invariant checks across the whole
config space: any (seed, arrival process, queue bound, ack mode) must
conserve tuples and reproduce bit-identically.
"""

import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core import RStormScheduler, emulab_cluster  # noqa: E402
from repro.stream import DesConfig, DesExecutor, topologies  # noqa: E402


@settings(max_examples=12, deadline=None)
@given(
    seed=st.integers(min_value=0, max_value=2**31),
    arrival=st.sampled_from(["uniform", "poisson", "bursty"]),
    qcap=st.integers(min_value=2, max_value=64),
    acked=st.booleans(),
)
def test_property_conservation_and_determinism(seed, arrival, qcap, acked):
    topo = topologies.linear(False, parallelism=2)
    topo.acked = acked
    cl = emulab_cluster()
    a = RStormScheduler().schedule(topo, cl, commit=False)
    cl.reset()
    cfg = DesConfig(
        duration_s=0.12, seed=seed, arrival=arrival, queue_capacity=qcap
    )
    rep = DesExecutor(cl, config=cfg).run(topo, a)
    assert rep.tuples_created == (
        rep.tuples_processed + rep.tuples_dropped + rep.tuples_in_flight
    )
    if rep.acked or rep.failed or rep.roots_in_flight:
        assert rep.emitted == rep.acked + rep.failed + rep.roots_in_flight
    rep2 = DesExecutor(cl, config=cfg).run(topo, a)
    assert rep.to_dict() == rep2.to_dict()
