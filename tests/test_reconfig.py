"""Reconfiguration-plane tests: mode validation, budgeted planning, the
greedy-mode bit-identity guarantee, the search-mode simulated-never-worse
acceptance sweep (§6 micro + Yahoo failover), the LoadChangeEvent lifecycle,
and the DRS-style reactive policy demo (hotspot -> trigger -> p99 drop)."""

import pytest

from repro.api import (
    ClusterSpec,
    DesSettings,
    LoadChangeEvent,
    Nimbus,
    NodeEntry,
    NodeFailEvent,
    NodeJoinEvent,
    PayloadValidationError,
    RebalanceEvent,
    ReconfigPolicy,
    ScenarioRunner,
    ScenarioSpec,
    SchedulerSpec,
    SubmitEvent,
    run_scenario,
    validate_reconfig,
)
from repro.core import (
    GlobalState,
    Rescheduler,
    RStormScheduler,
    emulab_cluster,
)
from repro.core.reconfig import DEFAULT_MOVE_COST, RECONFIG_SCHEMAS, ReconfigEngine
from repro.core.search.portfolio import (
    BUDGET_MAX_STEPS,
    BUDGET_MIN_STEPS,
    budget_plan,
)
from repro.obs import MetricsHub
from repro.stream import Simulator, topologies

SEARCH_KW = {"seed": 0, "n_chains": 8, "steps": 300}


# -- validation -------------------------------------------------------------------
def test_validate_reconfig_unknown_mode():
    errors = validate_reconfig("nope")
    assert errors and "unknown mode" in errors[0]


def test_validate_reconfig_greedy_rejects_kwargs():
    assert validate_reconfig("greedy") == []
    assert validate_reconfig("greedy", {"steps": 10})


def test_validate_reconfig_search_kwargs():
    assert validate_reconfig("search") == []
    assert validate_reconfig("search", dict(SEARCH_KW)) == []
    assert validate_reconfig("search", {"move_cost": -1.0})
    assert validate_reconfig("search", {"objective": "fastest"})
    assert validate_reconfig("search", {"unknown_knob": 1})
    assert validate_reconfig("search", {"budget_s": 0}) and not validate_reconfig(
        "search", {"budget_s": 0.5}
    )


def test_reconfig_schemas_expose_move_cost_default():
    assert RECONFIG_SCHEMAS["search"]["move_cost"].default == DEFAULT_MOVE_COST
    assert RECONFIG_SCHEMAS["greedy"] == {}


def test_nimbus_rejects_bad_reconfig():
    with pytest.raises(PayloadValidationError):
        Nimbus(reconfig="annealed")
    with pytest.raises(PayloadValidationError):
        Nimbus(reconfig="search", reconfig_kwargs={"move_cost": -2})


# -- budgeted planning ------------------------------------------------------------
def test_budget_plan_rejects_nonpositive():
    with pytest.raises(ValueError):
        budget_plan(0.0, 10)
    with pytest.raises(ValueError):
        budget_plan(-1.0, 10)


def test_budget_plan_deterministic_and_monotone():
    chains1, steps1 = budget_plan(0.1, 24)
    assert (chains1, steps1) == budget_plan(0.1, 24)
    prev_effort = 0
    for budget in (0.05, 0.3, 1.0, 5.0, 60.0):
        chains, steps = budget_plan(budget, 24)
        effort = chains * steps
        assert effort >= prev_effort  # more budget never plans less work
        prev_effort = effort


def test_budget_plan_step_clamps():
    _, lo = budget_plan(0.1, 1)
    assert lo >= BUDGET_MIN_STEPS
    _, hi = budget_plan(100.0, 10_000)
    assert hi <= BUDGET_MAX_STEPS


# -- greedy-mode bit identity -----------------------------------------------------
def _failover_state(name="linear"):
    cl = emulab_cluster()
    gs = GlobalState(cl)
    t = topologies.make(name)
    a = gs.submit(t, RStormScheduler())
    return cl, gs, t, a


def test_greedy_engine_matches_rescheduler_exactly():
    """mode="greedy" must replay the historical Rescheduler bit-identically:
    same placements, same moved/unplaced report, on twin states."""
    cl_a, gs_a, _, asg_a = _failover_state()
    cl_b, gs_b, _, asg_b = _failover_state()
    victim = asg_a.nodes_used()[0]
    assert victim == asg_b.nodes_used()[0]

    gs_a.fail_node(victim)
    legacy = Rescheduler(gs_a).rebalance()

    engine = ReconfigEngine(gs_b, mode="greedy")
    engine.fail_node(victim)
    routed = engine.rebalance()

    assert routed.to_dict() == legacy.to_dict()
    assert dict(asg_b.placements) == dict(asg_a.placements)
    assert list(asg_b.unassigned) == list(asg_a.unassigned)


def _acceptance_spec():
    return ScenarioSpec(
        name="acceptance",
        cluster=ClusterSpec(preset="emulab_24"),
        timeline=(
            SubmitEvent(
                topology=topologies.spec("pageload"),
                scheduler=SchedulerSpec("rstorm", {}),
            ),
            NodeFailEvent(node_id="r0n0"),
            NodeJoinEvent(nodes=(NodeEntry("fresh0", "rack_fresh"),)),
            RebalanceEvent(),
        ),
    )


def test_greedy_scenario_trace_identical_to_default():
    """Explicit reconfig="greedy" and the default runner produce
    byte-identical traces — existing goldens are safe."""
    spec = _acceptance_spec()
    default = run_scenario(spec).to_dict()
    greedy = run_scenario(spec, reconfig="greedy").to_dict()
    assert greedy == default


# -- search-mode acceptance: never worse on every failover scenario ---------------
@pytest.mark.parametrize("name", sorted(topologies.ALL))
def test_search_failover_never_worse_than_greedy(name):
    """§6 acceptance: on each micro + Yahoo topology, fail a used node and
    rebalance; search-mode simulated sink throughput >= greedy's."""
    results = {}
    for mode, kwargs in (("greedy", None), ("search", dict(SEARCH_KW))):
        cl, gs, t, a = _failover_state(name)
        victim = a.nodes_used()[0]
        engine = ReconfigEngine(gs, mode=mode, kwargs=kwargs)
        engine.fail_node(victim)
        result = engine.rebalance()
        assert a.hard_violations(t, cl) == []
        for tid, nid in a.placements.items():
            assert cl.nodes[nid].alive
        moved = set(result.moved.get(t.id, ()))
        unplaced = set(result.unplaced.get(t.id, ()))
        assert not (moved & unplaced)
        results[mode] = Simulator(cl).run(t, a).sink_throughput
    assert results["search"] >= results["greedy"]


def test_search_rebalance_reports_moved_count():
    cl, gs, t, a = _failover_state()
    engine = ReconfigEngine(gs, mode="search", kwargs=dict(SEARCH_KW))
    engine.fail_node(a.nodes_used()[0])
    result = engine.rebalance()
    assert result.moved_count() > 0
    assert result.moved_count() == sum(len(v) for v in result.moved.values())


def test_budgeted_search_failover():
    """budget_s replaces explicit chains/steps and still lands a feasible,
    never-worse placement."""
    cl, gs, t, a = _failover_state()
    engine = ReconfigEngine(gs, mode="search", kwargs={"seed": 0, "budget_s": 0.1})
    engine.fail_node(a.nodes_used()[0])
    engine.rebalance()
    assert a.hard_violations(t, cl) == []
    assert not a.unassigned


# -- LoadChangeEvent --------------------------------------------------------------
def test_load_change_round_trips_and_validates():
    e = LoadChangeEvent(topology_id="t", component_id="c", factor=2.5)
    spec = ScenarioSpec(
        name="lc",
        cluster=ClusterSpec(preset="emulab_12"),
        timeline=(
            SubmitEvent(
                topology=topologies.spec("linear"),
                scheduler=SchedulerSpec("rstorm", {}),
            ),
            LoadChangeEvent(
                topology_id="linear_net", component_id="bolt1", factor=2.0
            ),
        ),
    )
    assert ScenarioSpec.from_json(spec.to_json()) == spec
    assert e.validate("x") == []
    assert LoadChangeEvent("t", "c", 0.0).validate("x")
    assert LoadChangeEvent("", "c", 1.0).validate("x")


def test_load_change_static_walk_rejects_bad_targets():
    submit = SubmitEvent(
        topology=topologies.spec("linear"),
        scheduler=SchedulerSpec("rstorm", {}),
    )
    # Not-yet-submitted topology.
    with pytest.raises(PayloadValidationError) as exc:
        ScenarioSpec(
            cluster=ClusterSpec(preset="emulab_12"),
            timeline=(
                LoadChangeEvent(
                    topology_id="linear_net", component_id="bolt1", factor=2.0
                ),
                submit,
            ),
        ).validate()
    assert any("not submitted" in e for e in exc.value.errors)
    # Unknown component on a live topology.
    with pytest.raises(PayloadValidationError) as exc:
        ScenarioSpec(
            cluster=ClusterSpec(preset="emulab_12"),
            timeline=(
                submit,
                LoadChangeEvent(
                    topology_id="linear_net", component_id="nope", factor=2.0
                ),
            ),
        ).validate()
    assert any("unknown component" in e for e in exc.value.errors)


def test_load_change_shifts_simulated_throughput():
    """A hotspot factor > 1 lowers steady-state throughput (the schedule is
    stale); a search rebalance claws some of it back, greedy cannot."""
    spec = ScenarioSpec(
        name="lc",
        cluster=ClusterSpec(preset="emulab_24"),
        timeline=(
            SubmitEvent(
                topology=topologies.spec("pageload"),
                scheduler=SchedulerSpec("rstorm", {}),
            ),
            LoadChangeEvent(
                topology_id="pageload", component_id="geo_enrich", factor=3.0
            ),
            RebalanceEvent(),
        ),
    )
    greedy = run_scenario(spec)
    tp = greedy.throughput("pageload")
    assert tp[1] < tp[0]  # the hotspot costs throughput
    # Nothing orphaned -> greedy rebalance is a no-op (modulo warm-start
    # fixed-point re-entry noise).
    assert tp[2] == pytest.approx(tp[1], rel=1e-9)
    search = run_scenario(spec, reconfig="search", reconfig_kwargs=dict(SEARCH_KW))
    assert search.throughput("pageload")[2] >= tp[2]


def test_change_load_rejects_unknown_targets():
    nimbus = Nimbus(ClusterSpec(preset="emulab_12"))
    with pytest.raises(KeyError):
        nimbus.change_load("ghost", "c", 2.0)


# -- reactive policy --------------------------------------------------------------
def _hub_with_utils(values, t=1.0):
    hub = MetricsHub()
    for i, v in enumerate(values):
        hub.series("des.node_utilization", node=f"n{i}").append(t, v)
    return hub


def test_policy_requires_enabled_hub():
    class Disabled:
        enabled = False

    assert ReconfigPolicy().observe(Disabled()) is False


def test_policy_triggers_on_sustained_imbalance():
    policy = ReconfigPolicy(util_imbalance=0.3, sustain=2, cooldown=1)
    hot = _hub_with_utils([1.0, 0.1, 0.1, 0.1])
    cold = _hub_with_utils([0.5, 0.4, 0.5, 0.4])
    assert policy.observe(cold) is False
    assert policy.observe(hot) is False  # 1st hot interval: not sustained yet
    assert policy.observe(hot) is True  # 2nd: trigger
    assert policy.triggers == 1
    assert policy.observe(hot) is False  # cooldown interval
    assert policy.observe(hot) is False  # counting again from zero
    assert policy.observe(hot) is True
    assert policy.triggers == 2


def test_policy_queue_depth_signal():
    policy = ReconfigPolicy(util_imbalance=10.0, queue_depth=50.0, sustain=1)
    hub = _hub_with_utils([0.5, 0.5])
    hub.series("des.task_queue_depth", topology="t", task="t/a[0]").append(1.0, 80)
    assert policy.observe(hub) is True
    assert policy.last_imbalance == pytest.approx(0.0)


def test_policy_rejects_bad_thresholds():
    with pytest.raises(ValueError):
        ReconfigPolicy(util_imbalance=-0.1)
    with pytest.raises(ValueError):
        ReconfigPolicy(sustain=0)
    with pytest.raises(ValueError):
        ReconfigPolicy(cooldown=-1)
    with pytest.raises(ValueError):
        ReconfigPolicy(queue_depth=-5)


def test_reactive_hotspot_demo_reduces_p99():
    """End-to-end DRS demo: a LoadChangeEvent hotspot raises measured p99;
    the policy fires exactly once (only after the hotspot, not on the
    healthy placement) and the triggered budgeted search rebalance brings
    p99 back down."""
    spec = ScenarioSpec(
        name="hotspot",
        cluster=ClusterSpec(preset="emulab_24"),
        timeline=(
            SubmitEvent(
                topology=topologies.spec("pageload"),
                scheduler=SchedulerSpec("rstorm", {}),
            ),
            LoadChangeEvent(
                topology_id="pageload", component_id="geo_enrich", factor=8.0
            ),
        ),
    )
    policy = ReconfigPolicy(util_imbalance=0.7, sustain=1, cooldown=2)
    trace = ScenarioRunner(
        spec,
        engine="des",
        des=DesSettings(duration_s=0.5, seed=0),
        hub=MetricsHub(),
        reconfig="search",
        reconfig_kwargs={"seed": 0, "n_chains": 16, "steps": 600, "move_cost": 0.25},
        policy=policy,
    ).run()
    kinds = [e.event["kind"] for e in trace.entries]
    assert kinds == ["submit", "load_change", "reactive_rebalance"]
    assert policy.triggers == 1
    p99 = [e.topologies["pageload"]["p99_latency_s"] for e in trace.entries]
    assert p99[1] > p99[0]  # the hotspot hurt
    assert p99[2] < p99[1]  # the reactive rebalance helped
    reactive = trace.entries[2]
    assert reactive.event["trigger_step"] == 1
    assert sum(len(v) for v in reactive.outcome["moved"].values()) > 0
