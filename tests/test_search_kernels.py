"""Golden-oracle tests for the fused Pallas candidate-scoring kernel.

The contract under test: ``fused_score`` (interpret mode) == jax-vmap ==
numpy **bit-identical** on every objective term — netcost, hard-capacity
violation, dead-node count, and the throughput proxy — across the §6
topology suite.  The dyadic-grid quantization of every throughput input
makes all float64 segment-sums exact regardless of accumulation order,
which is what lets three differently-ordered reductions agree to the bit
(see ``repro.core.search.kernels``).

Also pinned here: the host-side padding boundary (batches that are not a
block multiple, single-row batches, block sizes larger than the batch),
all-dead candidates, the ≥10k-candidates-in-one-call capacity the fused
path exists for, and the multi-swap annealer's bit-identity to the k=1
chain on both objectives.

Shape edge cases run twice: once as deterministic parametrized sweeps
(always on), and once property-style under hypothesis when it is
installed (the container may not ship it — those simply skip).
"""

from __future__ import annotations

import numpy as np
import pytest

jax = pytest.importorskip("jax")

from repro.core.search import BatchAnnealer, evaluate_batch
from repro.core.search.kernels import DEFAULT_BLOCK_B, fused_score
from repro.core.search.throughput import compile_throughput, throughput_batch
from repro.stream import topologies as T

from tests.test_search import compile_case, emulab_cluster, random_batch

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st

    HAS_HYPOTHESIS = True
except ImportError:  # container may not ship hypothesis — satellite tests skip
    HAS_HYPOTHESIS = False

# The §6 suite (same topology set the benchmarks sweep).
SUITE = [
    ("linear_net", lambda: T.linear(True)),
    ("diamond_net", lambda: T.diamond(True)),
    ("star_net", lambda: T.star(True)),
    ("linear_cpu", lambda: T.linear(False)),
    ("diamond_cpu", lambda: T.diamond(False)),
    ("star_cpu", lambda: T.star(False)),
    ("pageload", T.pageload),
    ("processing", T.processing),
]


def kernel_case(maker, with_tm=True, cluster_factory=emulab_cluster):
    topology, cluster, arena, assignment, ba = compile_case(
        maker, cluster_factory
    )
    tm = compile_throughput(ba, topology, cluster) if with_tm else None
    return ba, tm


def assert_bit_identical(ba, tm, P, block_b=DEFAULT_BLOCK_B):
    """The three-backend golden-equality contract on one batch."""
    net_np = evaluate_batch(ba, P, backend="numpy", throughput_model=tm)
    net_jx = evaluate_batch(ba, P, backend="jax", throughput_model=tm)
    kn, kv, kd, kt = fused_score(
        ba, P, tm=tm, block_b=block_b, interpret=True
    )
    for oracle in (net_np, net_jx):
        assert np.array_equal(oracle.net, kn)
        assert np.array_equal(oracle.violation, kv)
        assert np.array_equal(oracle.dead, kd)
        if tm is not None:
            assert np.array_equal(oracle.throughput, kt)
    if tm is None:
        assert kt is None
    return kn, kv, kd, kt


# --------------------------------------------------------------------------
# three-backend golden equality across the §6 suite
# --------------------------------------------------------------------------


@pytest.mark.parametrize("name,maker", SUITE, ids=[n for n, _ in SUITE])
def test_fused_kernel_bit_identical_on_suite(name, maker):
    ba, tm = kernel_case(maker)
    # B=13 is deliberately not a multiple of the block: the padded tail
    # rows must not leak into (or corrupt) the first 13 outputs.
    P = random_batch(ba, 13, seed=11)
    assert_bit_identical(ba, tm, P)


@pytest.mark.parametrize("name,maker", SUITE, ids=[n for n, _ in SUITE])
def test_evaluate_batch_pallas_backend_on_suite(name, maker):
    ba, tm = kernel_case(maker)
    P = random_batch(ba, 13, seed=17)
    a = evaluate_batch(ba, P, backend="numpy", throughput_model=tm)
    b = evaluate_batch(ba, P, backend="pallas", throughput_model=tm)
    assert np.array_equal(a.net, b.net)
    assert np.array_equal(a.violation, b.violation)
    assert np.array_equal(a.dead, b.dead)
    assert np.array_equal(a.throughput, b.throughput)
    assert np.array_equal(a.feasible, b.feasible)
    tp = throughput_batch(ba, tm, P, backend="pallas")
    assert np.array_equal(a.throughput, tp)


def test_pallas_backend_chunking_is_invisible():
    ba, tm = kernel_case(T.pageload)
    P = random_batch(ba, 29, seed=3)
    whole = evaluate_batch(ba, P, backend="pallas", throughput_model=tm)
    chunked = evaluate_batch(
        ba, P, backend="pallas", throughput_model=tm, chunk=7
    )
    assert np.array_equal(whole.net, chunked.net)
    assert np.array_equal(whole.throughput, chunked.throughput)


# --------------------------------------------------------------------------
# padding / batch-shape edge cases (deterministic sweeps, always on)
# --------------------------------------------------------------------------


@pytest.mark.parametrize("B", [1, 5, 8, 13, 16])
@pytest.mark.parametrize("block_b", [1, 3, 8, 16])
def test_padding_boundary_shapes(B, block_b):
    ba, tm = kernel_case(lambda: T.linear(True))
    P = random_batch(ba, B, seed=B * 31 + block_b)
    assert_bit_identical(ba, tm, P, block_b=block_b)


def test_star_max_degree_padding():
    # The star hub has the topology's maximum degree — the densest edge
    # gather rows — and parallelism=4 keeps T=n*4 off the block multiple.
    ba, tm = kernel_case(lambda: T.star(True))
    P = random_batch(ba, 9, seed=23)
    assert_bit_identical(ba, tm, P)


def test_all_dead_candidates():
    def crippled():
        c = emulab_cluster()
        for nid in sorted(c.nodes)[:4]:
            c.fail_node(nid)
        return c

    ba, _tm = kernel_case(
        lambda: T.linear(True), with_tm=False, cluster_factory=crippled
    )
    dead_nodes = np.flatnonzero(~ba.alive)
    assert dead_nodes.size > 0
    rng = np.random.Generator(np.random.Philox(5))
    P = dead_nodes[rng.integers(0, dead_nodes.size, size=(13, ba.n_tasks))]
    _, _, kd, _ = assert_bit_identical(ba, None, P)
    assert (kd == ba.n_tasks).all()  # every task on a dead node


def test_netcost_only_mode_matches_oracles():
    ba, _ = kernel_case(T.processing, with_tm=False)
    P = random_batch(ba, 13, seed=7)
    assert_bit_identical(ba, None, P)


# --------------------------------------------------------------------------
# capacity: ≥10k concurrent candidates in ONE fused call
# --------------------------------------------------------------------------


def test_ten_thousand_candidates_single_call():
    ba, tm = kernel_case(lambda: T.linear(True))
    B = 10_240
    P = random_batch(ba, B, seed=42)
    kn, kv, kd, kt = fused_score(ba, P, tm=tm, interpret=True)
    assert kn.shape == kv.shape == kd.shape == kt.shape == (B,)
    oracle = evaluate_batch(
        ba, P, backend="numpy", chunk=B, throughput_model=tm
    )
    assert np.array_equal(oracle.net, kn)
    assert np.array_equal(oracle.violation, kv)
    assert np.array_equal(oracle.dead, kd)
    assert np.array_equal(oracle.throughput, kt)


# --------------------------------------------------------------------------
# multi-swap annealing: k-fused chains are bit-identical to k=1
# --------------------------------------------------------------------------


@pytest.mark.parametrize("k", [1, 4, 8])
def test_multi_swap_netcost_bit_identical(k):
    ba, _ = kernel_case(lambda: T.diamond(True), with_tm=False)
    P0 = random_batch(ba, 12, seed=2)
    # steps=30 is not a multiple of 4 or 8 — the k=1 tail chain runs too.
    ref = BatchAnnealer(ba, backend="numpy").run(P0, 30, seed=9)
    out = BatchAnnealer(ba, backend="jax").run(P0, 30, seed=9, multi_swap=k)
    assert np.array_equal(ref, out)


@pytest.mark.parametrize("k", [1, 4])
def test_multi_swap_throughput_bit_identical(k):
    ba, tm = kernel_case(lambda: T.linear(True))
    P0 = random_batch(ba, 8, seed=2)
    ref = BatchAnnealer(ba, backend="numpy").run(
        P0, 30, seed=9, objective="throughput", tm=tm
    )
    out = BatchAnnealer(ba, backend="jax").run(
        P0, 30, seed=9, objective="throughput", tm=tm, multi_swap=k
    )
    assert np.array_equal(ref, out)


def test_multi_swap_pallas_backend_and_validation():
    ba, _ = kernel_case(lambda: T.linear(True), with_tm=False)
    P0 = random_batch(ba, 8, seed=4)
    ref = BatchAnnealer(ba, backend="numpy").run(P0, 20, seed=1)
    out = BatchAnnealer(ba, backend="pallas").run(P0, 20, seed=1, multi_swap=8)
    assert np.array_equal(ref, out)
    with pytest.raises(ValueError, match="multi_swap"):
        BatchAnnealer(ba, backend="numpy").run(P0, 20, seed=1, multi_swap=0)


# --------------------------------------------------------------------------
# property-style shape fuzzing (runs only where hypothesis is installed)
# --------------------------------------------------------------------------

if HAS_HYPOTHESIS:

    @settings(max_examples=20, deadline=None)
    @given(
        B=st.integers(min_value=1, max_value=40),
        block_b=st.integers(min_value=1, max_value=32),
        seed=st.integers(min_value=0, max_value=2**31 - 1),
    )
    def test_hypothesis_padding_never_leaks(B, block_b, seed):
        ba, tm = kernel_case(lambda: T.linear(True))
        P = random_batch(ba, B, seed=seed)
        assert_bit_identical(ba, tm, P, block_b=block_b)

else:

    @pytest.mark.skip(reason="hypothesis not installed")
    def test_hypothesis_padding_never_leaks():
        pass
