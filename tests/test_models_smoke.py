"""Per-architecture smoke tests: reduced config of the same family, one
forward/train step on CPU, asserting output shapes and no NaNs (assignment
deliverable f)."""

import pytest

pytest.importorskip("jax")  # optional-jax CI leg: models are jax-only
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.models import build, extend_cache

KEY = jax.random.PRNGKey(0)


def make_batch(m, B=2, S=8, with_labels=True):
    batch = {"tokens": jax.random.randint(KEY, (B, S), 0, m.cfg.vocab)}
    if with_labels:
        batch["labels"] = jax.random.randint(KEY, (B, S), 0, m.cfg.vocab)
    if m.cfg.vision_prefix:
        batch["patches"] = jax.random.normal(
            KEY, (B, m.cfg.vision_prefix, m.cfg.d_model), jnp.bfloat16
        )
    if m.cfg.enc_dec:
        batch["frames"] = jax.random.normal(
            KEY, (B, m.cfg.enc_seq, m.cfg.d_model), jnp.bfloat16
        )
    return batch


@pytest.mark.parametrize("arch", configs.ARCHS)
def test_forward_shapes_and_finiteness(arch):
    m = build(arch, smoke=True)
    params = m.init_params(KEY)
    B, S = 2, 8
    batch = make_batch(m, B, S)
    logits, aux, _ = jax.jit(lambda p, b: m.forward(p, b))(params, batch)
    assert logits.shape == (B, S, m.cfg.vocab)
    assert bool(jnp.isfinite(logits.astype(jnp.float32)).all())
    assert bool(jnp.isfinite(aux))


@pytest.mark.parametrize("arch", configs.ARCHS)
def test_train_step_grads_finite(arch):
    m = build(arch, smoke=True)
    params = m.init_params(KEY)
    batch = make_batch(m)

    @jax.jit
    def step(p, b):
        (loss, metrics), grads = jax.value_and_grad(m.loss_fn, has_aux=True)(p, b)
        return loss, grads

    loss, grads = step(params, batch)
    assert bool(jnp.isfinite(loss))
    flat = jax.tree_util.tree_leaves(grads)
    assert flat, "no grads"
    for g in flat:
        assert bool(jnp.isfinite(g.astype(jnp.float32)).all())
    # at least some signal reaches the embedding table
    gmax = max(float(jnp.max(jnp.abs(g.astype(jnp.float32)))) for g in flat)
    assert gmax > 0


@pytest.mark.parametrize("arch", configs.ARCHS)
def test_prefill_then_decode_matches_forward(arch):
    m = build(arch, smoke=True)
    params = m.init_params(KEY)
    B, S = 2, 8
    prefix = m.cfg.vision_prefix
    batch = make_batch(m, B, S, with_labels=False)
    tok_next = jax.random.randint(jax.random.PRNGKey(1), (B, 1), 0, m.cfg.vocab)
    full = dict(batch)
    full["tokens"] = jnp.concatenate([batch["tokens"], tok_next], axis=1)
    logits_full, _, _ = m.forward(params, full)
    last, cache = m.prefill(params, batch)
    assert last.shape == (B, 1, m.cfg.vocab)
    cache = extend_cache(m, cache, prefix + S + 4)
    logits_dec, new_cache = m.decode_step(params, cache, tok_next, jnp.int32(prefix + S))
    ref = np.asarray(logits_full[:, -1], np.float32)
    got = np.asarray(logits_dec[:, 0], np.float32)
    err = np.max(np.abs(ref - got)) / (np.max(np.abs(ref)) + 1e-9)
    tol = 0.08 if m.cfg.n_experts else 1e-3  # MoE: capacity-drop divergence
    assert err <= tol, f"{arch} decode/forward mismatch {err:.4f}"
    # cache structure preserved
    assert jax.tree_util.tree_structure(cache) == jax.tree_util.tree_structure(new_cache)


@pytest.mark.parametrize("arch", ["xlstm-350m", "recurrentgemma-9b", "mixtral-8x7b"])
def test_subquadratic_state_is_constant_size(arch):
    """long_500k-capable archs: decode state must not grow with seq_len."""
    m = build(arch, smoke=True)
    c_small = jax.eval_shape(lambda: m.init_cache(1, 64))
    c_big = jax.eval_shape(lambda: m.init_cache(1, 4096))

    def nbytes(tree):
        return sum(
            int(np.prod(l.shape)) * l.dtype.itemsize
            for l in jax.tree_util.tree_leaves(tree)
        )

    if arch == "xlstm-350m":
        assert nbytes(c_small) == nbytes(c_big)
    else:
        # windowed KV only: growth capped at the window size
        assert nbytes(c_big) <= nbytes(c_small) * (m.cfg.window / 64 + 1)


def test_multi_token_decode_loop():
    m = build("smollm-360m", smoke=True)
    params = m.init_params(KEY)
    B, S = 1, 4
    batch = {"tokens": jax.random.randint(KEY, (B, S), 0, m.cfg.vocab)}
    last, cache = m.prefill(params, batch)
    cache = extend_cache(m, cache, S + 8)
    step = jax.jit(m.decode_step)
    tok = jnp.argmax(last[:, -1], -1)[:, None].astype(jnp.int32)
    for i in range(4):
        logits, cache = step(params, cache, tok, jnp.int32(S + i))
        assert bool(jnp.isfinite(logits.astype(jnp.float32)).all())
        tok = jnp.argmax(logits[:, -1], -1)[:, None].astype(jnp.int32)
