"""DES executor tests (the packet-level second referee).

Four layers: cross-validation against the steady-state solver (every §6
micro + Yahoo topology), conservation/determinism invariants (hypothesis),
behaviours only a packet-level model has (bursty queue growth, timeout
replay, backpressure), and the control-plane wiring (Nimbus engine
dispatch, plan round-trip, scenario traces, settings sync).
"""

import pytest

from repro.api import (
    ClusterSpec,
    DesSettings,
    Nimbus,
    RunSettings,
    ScenarioSpec,
    SchedulerSpec,
    SchedulingPayload,
    SchedulingPlan,
    ScenarioRunner,
    SubmitEvent,
)
from repro.core import RStormScheduler, emulab_cluster
from repro.stream import DesConfig, DesExecutor, Simulator, topologies
from repro.stream.des import run_des
from repro.stream.simulator import ACK_OVERHEAD_S, THRASH_FACTOR, TUPLE_TIMEOUT_S


def _place(topo, cl=None):
    cl = cl if cl is not None else emulab_cluster()
    cl.reset()
    a = RStormScheduler().schedule(topo, cl, commit=False)
    cl.reset()
    return cl, a


# -- cross-validation: DES vs fixed-point solver ---------------------------------
# Per-case horizons: network-bound micros generate ~1M events/s of simulated
# time, so they get shorter horizons; cpu-bound and Yahoo runs are cheap.
AGREEMENT_CASES = [
    ("linear_net", lambda: topologies.linear(True), 0.3),
    ("linear_cpu", lambda: topologies.linear(False), 0.5),
    ("diamond_net", lambda: topologies.diamond(True), 0.3),
    ("diamond_cpu", lambda: topologies.diamond(False), 0.5),
    ("star_net", lambda: topologies.star(True), 0.2),
    ("star_cpu", lambda: topologies.star(False), 0.5),
    ("processing", lambda: topologies.processing(), 1.0),
]


@pytest.mark.parametrize(
    "name,maker,duration", AGREEMENT_CASES, ids=[c[0] for c in AGREEMENT_CASES]
)
def test_des_agrees_with_solver(name, maker, duration):
    """Acceptance pin: measured DES throughput within 10% of the solver's
    fixed point on every §6 micro + the Processing pipeline, closed loop."""
    topo = maker()
    cl, a = _place(topo)
    sol = Simulator(cl).run(topo, a)
    rep = DesExecutor(cl, config=DesConfig(duration_s=duration)).run(topo, a)
    assert rep.sink_throughput == pytest.approx(sol.sink_throughput, rel=0.10), (
        f"{name}: DES {rep.sink_throughput:.1f} vs solver "
        f"{sol.sink_throughput:.1f} "
        f"({(rep.sink_throughput / sol.sink_throughput - 1) * 100:+.1f}%)"
    )


def test_des_pageload_sustains_solver_rate_at_steady_load():
    """Acceptance pin for PageLoad, the one closed-loop divergence case.

    The solver's M/M/1 sojourns assume Poisson congestion; PageLoad's
    closed-loop window pacing is *less* bursty than that, so the DES
    closed loop clears ~12% more than λ*.  The referee question is the
    converse: is the solver's λ* actually sustainable at the packet
    level?  Drive the DES open loop at exactly λ* with Poisson arrivals
    and check the sink keeps up within 10%.
    """
    topo = topologies.pageload()
    cl, a = _place(topo)
    sol = Simulator(cl).run(topo, a)
    spout = topo.components["kafka_spout"]
    # Re-pin the source: effectively unbounded window, emission capped at
    # the solver's fixed point (split across spout tasks).
    topo.max_spout_pending = 10**6
    spout.max_rate_per_task = sol.spout_rate / spout.parallelism
    rep = DesExecutor(
        cl, config=DesConfig(duration_s=1.0, arrival="poisson")
    ).run(topo, a)
    assert rep.sink_throughput == pytest.approx(sol.sink_throughput, rel=0.10)
    assert rep.spout_rate == pytest.approx(sol.spout_rate, rel=0.10)


def test_des_report_shape_and_percentiles():
    topo = topologies.pageload()
    cl, a = _place(topo)
    rep = run_des(topo, a, cl, config=DesConfig(duration_s=0.3))
    assert rep.topology_id == "pageload"
    assert rep.binding == "measured"
    assert 0.0 < rep.p50_latency_s <= rep.p95_latency_s <= rep.p99_latency_s
    assert rep.p50_latency_s <= rep.latency_s <= rep.p99_latency_s * 1.5
    assert rep.machines_used >= 1
    assert 0.0 < rep.avg_cpu_utilization <= 1.0
    assert rep.queue_depth_trace and rep.sink_rate_trace
    assert rep.events_processed > 1000
    d = rep.to_dict()
    assert d["sink_throughput"] == rep.sink_throughput
    assert d["p99_latency_s"] == rep.p99_latency_s


# -- conservation + determinism invariants ----------------------------------------
def _assert_conserved(rep):
    # Tuple ledger: every copy created along the DAG is either processed,
    # shed, or independently *found* somewhere in flight at drain.
    assert rep.tuples_created == (
        rep.tuples_processed + rep.tuples_dropped + rep.tuples_in_flight
    )
    # Root ledger (acked topologies): every emitted tree is acked, failed,
    # or still open.  Unanchored topologies keep no root ledger.
    if rep.acked or rep.failed or rep.roots_in_flight:
        assert rep.emitted == rep.acked + rep.failed + rep.roots_in_flight


def test_tuple_conservation_all_topologies():
    for name, maker, duration in AGREEMENT_CASES:
        topo = maker()
        cl, a = _place(topo)
        rep = DesExecutor(
            cl, config=DesConfig(duration_s=min(duration, 0.3))
        ).run(topo, a)
        _assert_conserved(rep)


def test_fixed_seed_bit_identical_trace():
    """Acceptance pin: same seed -> bit-identical event trace and report."""
    topo = topologies.pageload()
    cl, a = _place(topo)
    cfg = DesConfig(duration_s=0.2, arrival="poisson", trace_events=True)
    ex1 = DesExecutor(cl, config=cfg)
    rep1 = ex1.run(topo, a)
    ex2 = DesExecutor(cl, config=cfg)
    rep2 = ex2.run(topo, a)
    assert ex1.trace == ex2.trace
    assert rep1.to_dict() == rep2.to_dict()
    # ... and a different seed produces a genuinely different stream.
    ex3 = DesExecutor(cl, config=DesConfig(
        duration_s=0.2, arrival="poisson", trace_events=True, seed=7))
    ex3.run(topo, a)
    assert ex3.trace != ex1.trace


def test_deterministic_single_chain_matches_solver_closely():
    """D/D/1 limit: deterministic service + metronome arrivals on a single
    cpu-bound chain leaves nothing stochastic — DES and solver should agree
    much tighter than the stochastic 10% band."""
    topo = topologies.linear(False, parallelism=2)
    cl, a = _place(topo)
    sol = Simulator(cl).run(topo, a)
    rep = DesExecutor(
        cl, config=DesConfig(duration_s=0.5, service="deterministic")
    ).run(topo, a)
    assert rep.sink_throughput == pytest.approx(sol.sink_throughput, rel=0.05)


# -- packet-level behaviours the solver cannot represent -------------------------
def test_bursty_arrivals_grow_queues_at_same_mean_rate():
    """Same mean load, on/off arrivals: the fluid fixed point is identical,
    but the packet-level run shows transient queue growth — the scenario
    class that motivates a second referee."""
    topo = topologies.processing()  # unanchored: no window to absorb bursts
    cl, a = _place(topo)
    uni = DesExecutor(
        cl, config=DesConfig(duration_s=0.5, arrival="uniform")
    ).run(topo, a)
    bur = DesExecutor(
        cl,
        config=DesConfig(
            duration_s=0.5, arrival="bursty", burst_factor=8.0,
            burst_period_s=0.1, queue_capacity=4096,
        ),
    ).run(topo, a)
    assert bur.queue_depth_max >= uni.queue_depth_max * 2
    # Both runs carry the same mean load, so the mean throughputs stay in
    # the same band even while the transient queue picture diverges.
    assert bur.sink_throughput == pytest.approx(uni.sink_throughput, rel=0.25)


def test_timeout_replay_fires_and_conserves():
    """A timeout below the pipeline latency makes trees fail and replay;
    the root ledger still balances and the run still terminates."""
    topo = topologies.pageload()
    cl, a = _place(topo)
    rep = DesExecutor(
        cl, config=DesConfig(duration_s=0.3), tuple_timeout_s=0.004
    ).run(topo, a)
    assert rep.failed > 0
    assert rep.replayed == rep.failed
    _assert_conserved(rep)
    # Acks still complete for trees that beat the clock — or every tree
    # failed; either way the ledger closed above.


def test_backpressure_credit_vs_drop():
    """Credit mode never sheds; drop mode on the same overloaded topology
    sheds instead of blocking."""
    topo = topologies.processing()
    cl, a = _place(topo)
    credit = DesExecutor(
        cl,
        config=DesConfig(
            duration_s=0.3, backpressure="credit", queue_capacity=8
        ),
    ).run(topo, a)
    drop = DesExecutor(
        cl,
        config=DesConfig(duration_s=0.3, backpressure="drop", queue_capacity=8),
    ).run(topo, a)
    assert credit.tuples_dropped == 0
    _assert_conserved(credit)
    _assert_conserved(drop)


# -- control-plane wiring ---------------------------------------------------------
def _payload(**settings) -> SchedulingPayload:
    return SchedulingPayload(
        topology=topologies.spec("pageload"),
        cluster=ClusterSpec(preset="emulab_12"),
        scheduler=SchedulerSpec("rstorm", {}),
        settings=RunSettings(**settings),
    )


def test_nimbus_plan_with_des_engine_round_trips():
    plan = Nimbus().plan(
        _payload(
            simulate=True,
            sim_engine="des",
            des=DesSettings(duration_s=0.2),
        )
    )
    assert plan.sim is not None and plan.sim.binding == "measured"
    d = plan.to_dict()
    for key in ("p50_latency_s", "p95_latency_s", "p99_latency_s"):
        assert d["sim"][key] > 0.0
    rt = SchedulingPlan.from_dict(d)
    assert rt.to_dict() == d
    assert rt.sim.p99_latency_s == plan.sim.p99_latency_s


def test_solver_plan_dict_has_no_percentile_keys():
    """Solver plans must stay byte-stable: no percentile keys appear."""
    d = Nimbus().plan(_payload(simulate=True)).to_dict()
    assert sorted(d["sim"]) == [
        "avg_cpu_utilization", "binding", "latency_s", "machines_used",
        "sink_throughput",
    ]
    rt = SchedulingPlan.from_dict(d)
    assert rt.sim.p50_latency_s is None
    assert rt.to_dict() == d


def test_simulate_all_engine_dispatch():
    nim = Nimbus()
    nim.submit(_payload())
    sol = nim.simulate_all()
    des = nim.simulate_all(engine="des", des=DesSettings(duration_s=0.2))
    assert set(sol) == set(des) == {"pageload"}
    assert des["pageload"].binding == "measured"
    assert des["pageload"].p95_latency_s > 0.0
    with pytest.raises(ValueError):
        nim.simulate_all(engine="nope")
    # A full RunSettings drives the same dispatch.
    via_settings = nim.simulate_all(
        settings=RunSettings(
            sim_engine="des", des=DesSettings(duration_s=0.2)
        )
    )
    assert via_settings["pageload"].to_dict() == des["pageload"].to_dict()


def test_scenario_runner_des_engine_traces_percentiles():
    spec = ScenarioSpec(
        name="des_interval",
        cluster=ClusterSpec(preset="emulab_12"),
        timeline=(
            SubmitEvent(
                topology=topologies.spec("pageload"),
                scheduler=SchedulerSpec("rstorm", {}),
            ),
        ),
    )
    trace = ScenarioRunner(
        spec, engine="des", des=DesSettings(duration_s=0.2)
    ).run()
    metrics = trace.entries[-1].topologies["pageload"]
    assert metrics["binding"] == "measured"
    assert metrics["p50_latency_s"] > 0.0
    assert metrics["p99_latency_s"] >= metrics["p95_latency_s"]
    # Solver traces keep their golden shape (no percentile keys).
    sol_trace = ScenarioRunner(spec).run()
    assert "p50_latency_s" not in sol_trace.entries[-1].topologies["pageload"]
    with pytest.raises(ValueError):
        ScenarioRunner(spec, engine="nope")


# -- one config for both referees -------------------------------------------------
def test_run_settings_defaults_mirror_simulator_constants():
    """RunSettings carries literal defaults (no import cycle with stream);
    this is the sync pin that keeps them honest."""
    rs = RunSettings()
    assert rs.ack_overhead_s == ACK_OVERHEAD_S
    assert rs.thrash_factor == THRASH_FACTOR
    assert rs.tuple_timeout_s == TUPLE_TIMEOUT_S


def test_des_settings_mirror_des_config_defaults():
    ds, cfg = DesSettings(), DesConfig()
    for field in DesSettings._FIELDS:
        assert getattr(ds, field) == getattr(cfg, field), field
    assert ds.to_config() == cfg


def test_run_settings_sparse_round_trip():
    assert RunSettings().to_dict() == {"allow_partial": True, "simulate": False}
    rs = RunSettings(
        simulate=True,
        sim_engine="des",
        tuple_timeout_s=5.0,
        des=DesSettings(duration_s=0.25, arrival="bursty"),
    )
    d = rs.to_dict()
    assert d["sim_engine"] == "des" and d["tuple_timeout_s"] == 5.0
    assert "ack_overhead_s" not in d and "thrash_factor" not in d
    errors = []
    rt = RunSettings.from_dict(d, "settings", errors)
    assert not errors and rt == rs
    assert rt.validate() == []


def test_run_settings_validation_rejects_bad_knobs():
    errs = RunSettings(sim_engine="magic").validate()
    assert any("sim_engine" in e for e in errs)
    errs = RunSettings(des=DesSettings(arrival="storm")).validate()
    assert any("settings.des.arrival" in e for e in errs)
    errs = RunSettings(tuple_timeout_s=0.0).validate()
    assert any("tuple_timeout_s" in e for e in errs)
    with pytest.raises(ValueError):
        DesConfig(arrival="storm")


def test_shared_knobs_reach_both_engines():
    """One RunSettings, two referees: the mechanism knobs land in whichever
    engine the payload picks."""
    topo = topologies.pageload()
    cl, a = _place(topo)
    nim = Nimbus()
    plan = nim.plan(
        _payload(simulate=True, sim_engine="des", ack_overhead_s=0.05,
                 des=DesSettings(duration_s=0.2))
    )
    base = nim.plan(
        _payload(simulate=True, sim_engine="des", des=DesSettings(duration_s=0.2))
    )
    # A 10x acker round-trip shows up directly in closed-loop latency.
    assert plan.sim.latency_s > base.sim.latency_s * 2
