"""Nimbus facade tests: plan is side-effect free, submit commits atomically,
kill/rebalance manage state, and the payload path reproduces the old direct
``scheduler.schedule()`` placements exactly."""

import pytest

from repro.api import (
    ClusterSpec,
    ComponentSpec,
    Nimbus,
    PayloadValidationError,
    RunSettings,
    SchedulerSpec,
    SchedulingPayload,
    TopologySpec,
    UnschedulablePayloadError,
    get_scheduler,
)
from repro.api.specs import CLUSTER_PRESETS
from repro.stream import Simulator, topologies


def payload(
    topo_name="pageload",
    scheduler="rstorm",
    kwargs=None,
    preset="emulab_12",
    **settings,
) -> SchedulingPayload:
    return SchedulingPayload(
        topology=topologies.spec(topo_name),
        cluster=ClusterSpec(preset=preset),
        scheduler=SchedulerSpec(scheduler, dict(kwargs or {})),
        settings=RunSettings(**settings),
    )


def cluster_is_pristine(cluster) -> bool:
    return (
        cluster.total_available().values == cluster.total_capacity().values
        and all(not n.assigned_tasks for n in cluster.nodes.values())
    )


# -- plan vs submit ----------------------------------------------------------------
def test_plan_is_side_effect_free():
    nimbus = Nimbus()
    p = payload()
    plan1 = nimbus.plan(p)
    assert not plan1.committed
    # Planning on an empty Nimbus pins nothing: no state, no cluster.
    assert nimbus.topologies == [] and nimbus.cluster is None
    plan2 = nimbus.plan(p)
    assert plan1.placements == plan2.placements
    # Planning against a declared cluster leaves it pristine.
    declared = Nimbus(ClusterSpec(preset="emulab_12"))
    declared.plan(p)
    assert cluster_is_pristine(declared.cluster)
    # A dry-run does not block a later submit against a different cluster.
    fresh = Nimbus()
    fresh.plan(payload(preset="emulab_12"))
    assert fresh.submit(payload(preset="emulab_24")).committed


def test_nimbus_from_live_cluster_checks_payload_spec():
    from repro.core import emulab_cluster_24

    nimbus = Nimbus(emulab_cluster_24())
    with pytest.raises(PayloadValidationError, match="does not match"):
        nimbus.submit(payload(preset="emulab_12"))
    # An equivalent spec (preset expanding to the same node set) is accepted.
    assert nimbus.submit(payload(preset="emulab_24")).committed


def test_submit_commits_and_kill_returns_resources():
    nimbus = Nimbus()
    plan = nimbus.submit(payload())
    assert plan.committed and nimbus.topologies == ["pageload"]
    assert not cluster_is_pristine(nimbus.cluster)
    used = sum(len(n.assigned_tasks) for n in nimbus.cluster.nodes.values())
    assert used == len(plan.placements)
    nimbus.kill("pageload")
    assert nimbus.topologies == []
    assert cluster_is_pristine(nimbus.cluster)
    with pytest.raises(KeyError, match="unknown topology"):
        nimbus.kill("pageload")


def test_duplicate_submit_rejected_without_mutation():
    nimbus = Nimbus()
    nimbus.submit(payload())
    before = nimbus.cluster.total_available().values
    with pytest.raises(PayloadValidationError, match="already submitted"):
        nimbus.submit(payload())
    assert nimbus.cluster.total_available().values == before


def test_malformed_payload_rejected_before_any_mutation():
    nimbus = Nimbus()
    nimbus.submit(payload())  # establish a live cluster
    bad = SchedulingPayload(
        topology=TopologySpec(
            id="bad",
            components=(ComponentSpec(id="s", is_spout=True, memory_load_mb=-1.0),),
        ),
        cluster=ClusterSpec(preset="emulab_12"),
        scheduler=SchedulerSpec("rstormx"),
    )
    before = nimbus.cluster.total_available().values
    with pytest.raises(PayloadValidationError) as ei:
        nimbus.submit(bad)
    assert any("memory_load_mb" in e for e in ei.value.errors)
    assert any("unknown scheduler" in e for e in ei.value.errors)
    assert nimbus.cluster.total_available().values == before
    assert nimbus.topologies == ["pageload"]


def test_allow_partial_false_rejects_infeasible_plan_whole():
    # 4 GB per task fits nowhere on the 2 GB-node Emulab cluster.
    huge = SchedulingPayload(
        topology=TopologySpec(
            id="huge",
            components=(
                ComponentSpec(id="s", is_spout=True, parallelism=3, memory_load_mb=4096.0),
            ),
        ),
        cluster=ClusterSpec(preset="emulab_12"),
        scheduler=SchedulerSpec("rstorm"),
        settings=RunSettings(allow_partial=False),
    )
    nimbus = Nimbus()
    with pytest.raises(UnschedulablePayloadError, match="nothing was committed"):
        nimbus.submit(huge)
    assert nimbus.topologies == []
    # A rejected submit leaves an empty Nimbus truly empty: it must not have
    # adopted the rejected payload's cluster...
    assert nimbus.cluster is None
    # ...so a later submit against a *different* cluster is still accepted.
    plan = nimbus.submit(payload(preset="emulab_24"))
    assert plan.committed and nimbus.topologies == ["pageload"]


def test_mismatched_cluster_spec_rejected():
    nimbus = Nimbus(ClusterSpec(preset="emulab_12"))
    with pytest.raises(PayloadValidationError, match="does not match"):
        nimbus.submit(payload(preset="emulab_24"))


# -- equivalence with the old hand-wired path ----------------------------------------
@pytest.mark.parametrize(
    "sched_name,kwargs",
    [
        ("rstorm", {}),
        ("round_robin", {"seed": 1}),
        ("rstorm_annealed", {"iters": 300}),
    ],
)
@pytest.mark.parametrize("preset", ["emulab_12", "emulab_24"])
@pytest.mark.parametrize("topo_name", ["pageload", "processing"])
def test_payload_path_matches_direct_scheduler_path(sched_name, kwargs, preset, topo_name):
    """Acceptance: Nimbus.submit places exactly as scheduler.schedule() did."""
    plan = Nimbus().submit(payload(topo_name, sched_name, kwargs, preset))
    cluster = CLUSTER_PRESETS[preset]()
    direct = get_scheduler(sched_name, **kwargs).schedule(
        getattr(topologies, topo_name)(), cluster, commit=False
    )
    assert plan.placements == direct.placements
    assert plan.unassigned == direct.unassigned


# -- plan report -----------------------------------------------------------------
def test_plan_reports_utilization_netcost_and_sim():
    plan = Nimbus().plan(payload(scheduler="rstorm", simulate=True))
    assert plan.scheduler_name == "rstorm"
    assert plan.schedule_time_s > 0
    assert plan.machines_used == len(set(plan.placements.values()))
    assert set(plan.node_utilization) == set(plan.placements.values())
    for dims in plan.node_utilization.values():
        assert 0.0 < dims["memory_mb"] <= 1.0  # memory is a hard constraint
    # network_cost matches the assignment's own accounting.
    cluster = CLUSTER_PRESETS["emulab_12"]()
    assert plan.network_cost == pytest.approx(
        plan.assignment.network_cost(plan.topology, cluster)
    )
    # The attached sim equals a direct Simulator run of the same placement.
    direct = Simulator(cluster).run(plan.topology, plan.assignment)
    assert plan.sim.sink_throughput == pytest.approx(direct.sink_throughput)
    d = plan.to_dict()
    assert d["sim"]["binding"] == plan.sim.binding
    assert d["machines_used"] == plan.machines_used


# -- rebalance / multi-topology state --------------------------------------------
def test_rebalance_replaces_orphans_after_node_failure():
    nimbus = Nimbus()
    plan = nimbus.submit(payload())
    victim = sorted(set(plan.placements.values()))[0]
    nimbus.cluster.fail_node(victim)
    orphans = nimbus.state.orphaned_tasks()
    assert orphans and all(topo == "pageload" for topo, _ in orphans)
    result = nimbus.rebalance()
    assert sorted(result.moved["pageload"]) == sorted(tid for _, tid in orphans)
    assert result.unplaced == {}  # survivors have room: nothing left behind
    assignment = nimbus.state.assignments["pageload"]
    assert victim not in set(assignment.placements.values())
    assert nimbus.state.orphaned_tasks() == []


def test_rebalance_separates_moved_from_unplaced():
    """A task that ends up unassigned must be in unplaced, not moved."""
    nimbus = Nimbus()
    plan = nimbus.submit(payload())
    # Kill every node except two: the survivors cannot hold all ~21 tasks.
    orphaned = 0
    for nid in sorted(nimbus.cluster.nodes)[:-2]:
        orphaned += len(nimbus.fail_node(nid))
    result = nimbus.rebalance()
    assert result.unplaced, "2 x 2GB nodes cannot hold pageload"
    assert result.moved_count() + result.unplaced_count() == orphaned
    assert not set(result.moved.get("pageload", ())) & set(
        result.unplaced.get("pageload", ())
    )
    assignment = nimbus.state.assignments["pageload"]
    assert sorted(assignment.unassigned) == sorted(result.unplaced["pageload"])
    # Scale-up through the lifecycle verb lands the leftovers.
    from repro.core import NodeSpec

    scale = nimbus.add_nodes(
        [NodeSpec(f"fresh{i}", "rack_fresh", 100.0, 2048.0) for i in range(6)]
    )
    assert sorted(scale.moved["pageload"]) == sorted(result.unplaced["pageload"])
    assert scale.unplaced == {} and not assignment.unassigned


def test_orphaned_tasks_are_topology_qualified_pairs():
    """Two topologies with colliding bare task ids must stay distinguishable."""
    from repro.core import Component, GlobalState, RStormScheduler, Topology, emulab_cluster_24

    def mk(tid):
        t = Topology(tid)
        c = Component("spout", is_spout=True, parallelism=2)
        c.set_memory_load(256.0)
        t.add_component(c)
        return t

    gs = GlobalState(emulab_cluster_24())
    a1 = gs.submit(mk("t1"), RStormScheduler())
    a2 = gs.submit(mk("t2"), RStormScheduler())
    for assignment in (a1, a2):
        for nid in set(assignment.placements.values()):
            if gs.cluster.nodes[nid].alive:
                gs.cluster.fail_node(nid)
    pairs = gs.orphaned_tasks()
    assert len(pairs) == len(set(pairs))  # no collisions: pairs are unique
    assert {topo for topo, _ in pairs} == {"t1", "t2"}
    # Each pair resolves inside its own topology's assignment.
    for topo_id, tid in pairs:
        assert tid in gs.assignments[topo_id].placements
