"""Training substrate tests: optimizer, train loop (+accumulation), data
pipeline, checkpointing (sync + async), gradient compression, serving."""

import os
import tempfile

import pytest

pytest.importorskip("jax")  # optional-jax CI leg: training is jax-only
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.data import ByteTokenizer, LMDataset, Prefetcher
from repro.models import build
from repro.serve import Request, ServingEngine
from repro.train import (
    AdamWConfig,
    AsyncCheckpointer,
    TrainOptions,
    adamw_update,
    compress_grads_with_feedback,
    init_error_feedback,
    init_opt_state,
    init_train_state,
    latest_step,
    lr_schedule,
    make_train_step,
    restore_checkpoint,
    save_checkpoint,
)

KEY = jax.random.PRNGKey(0)


# -- optimizer ------------------------------------------------------------------
def test_lr_schedule_shape():
    cfg = AdamWConfig(lr=1e-3, warmup_steps=10, total_steps=100)
    lrs = [float(lr_schedule(cfg, jnp.int32(s))) for s in (0, 5, 10, 50, 100)]
    assert lrs[0] == 0.0
    assert lrs[1] == pytest.approx(5e-4)
    assert lrs[2] == pytest.approx(1e-3)
    assert lrs[3] < 1e-3
    assert lrs[4] == pytest.approx(1e-4, rel=0.01)


def test_adamw_reduces_quadratic():
    params = {"w": jnp.array([5.0, -3.0])}
    opt = init_opt_state(params)
    cfg = AdamWConfig(lr=0.1, weight_decay=0.0, warmup_steps=0, total_steps=1000)
    for _ in range(200):
        grads = {"w": 2 * params["w"]}
        params, opt, _ = adamw_update(cfg, params, grads, opt)
    assert float(jnp.max(jnp.abs(params["w"]))) < 0.1


def test_grad_accumulation_matches_single_batch():
    m = build("smollm-360m", smoke=True)
    state1 = init_train_state(m, KEY, TrainOptions())
    state2 = jax.tree_util.tree_map(lambda x: x, state1)
    batch = {
        "tokens": jax.random.randint(KEY, (4, 16), 0, m.cfg.vocab),
        "labels": jax.random.randint(KEY, (4, 16), 0, m.cfg.vocab),
    }
    s1, m1 = jax.jit(make_train_step(m, TrainOptions()))(state1, batch)
    s2, m2 = jax.jit(make_train_step(m, TrainOptions(n_micro=2)))(state2, batch)
    # Averaged-microbatch loss equals full-batch loss for a mean CE.
    assert float(m1["loss"]) == pytest.approx(float(m2["loss"]), rel=2e-2)
    # Params move in a near-identical direction.
    l1 = jax.tree_util.tree_leaves(s1["params"])
    l2 = jax.tree_util.tree_leaves(s2["params"])
    diffs = [float(jnp.max(jnp.abs(a - b))) for a, b in zip(l1, l2)]
    assert max(diffs) < 5e-2


def test_training_reduces_loss():
    m = build("qwen3-0.6b", smoke=True)
    opts = TrainOptions(opt=AdamWConfig(lr=1e-3, warmup_steps=5, total_steps=200))
    state = init_train_state(m, KEY, opts)
    step = jax.jit(make_train_step(m, opts))
    ds = iter(LMDataset(seq_len=16, batch_size=8, vocab_size=m.cfg.vocab))
    losses = []
    for i in range(30):
        b = next(ds)
        state, metrics = step(state, {k: jnp.asarray(v) for k, v in b.items()})
        losses.append(float(metrics["loss"]))
    assert np.mean(losses[-5:]) < np.mean(losses[:5]) - 0.1


# -- gradient compression -----------------------------------------------------------
def test_compression_error_feedback_reduces_bias():
    g = {"w": jnp.asarray(np.linspace(-1, 1, 1024), jnp.float32)}
    err = init_error_feedback(g)
    acc = jnp.zeros((1024,))
    for _ in range(50):
        dec, err = compress_grads_with_feedback(g, err)
        acc = acc + dec["w"]
    # Mean decompressed gradient converges to the true gradient.
    assert float(jnp.max(jnp.abs(acc / 50 - g["w"]))) < 1e-2


# -- data -----------------------------------------------------------------------------
def test_tokenizer_roundtrip():
    tok = ByteTokenizer()
    s = "hello R-Storm 123"
    assert tok.decode(tok.encode(s).tolist()) == s


def test_dataset_host_sharding_disjoint():
    a = LMDataset(seq_len=32, batch_size=2, vocab_size=256, host_id=0, num_hosts=2)
    b = LMDataset(seq_len=32, batch_size=2, vocab_size=256, host_id=1, num_hosts=2)
    assert len(a.windows) + len(b.windows) > 0
    overlap = {w.tobytes() for w in a.windows} & {w.tobytes() for w in b.windows}
    assert not overlap


def test_prefetcher_preserves_order():
    it = Prefetcher(iter(range(10)), depth=3)
    assert list(it) == list(range(10))


# -- checkpointing -----------------------------------------------------------------------
def test_checkpoint_latest_and_gc():
    with tempfile.TemporaryDirectory() as d:
        state = {"a": jnp.arange(4), "nested": {"b": jnp.ones((2, 2))}}
        ckpt = AsyncCheckpointer(d, keep=2)
        for s in (1, 2, 3):
            ckpt.save(s, state)
        ckpt.close()
        assert latest_step(d) == 3
        # keep=2: step_1 garbage-collected
        assert not os.path.exists(os.path.join(d, "step_00000001"))
        like = jax.tree_util.tree_map(
            lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), state
        )
        restored, step = restore_checkpoint(d, like)
        assert step == 3
        assert jnp.array_equal(restored["a"], state["a"])


def test_checkpoint_restart_resumes_training():
    """Fault-tolerance path: kill training, restore, continue — state equal."""
    m = build("smollm-360m", smoke=True)
    opts = TrainOptions()
    state = init_train_state(m, KEY, opts)
    step = jax.jit(make_train_step(m, opts))
    ds = iter(LMDataset(seq_len=16, batch_size=4, vocab_size=m.cfg.vocab))
    batches = [next(ds) for _ in range(6)]
    to_dev = lambda b: {k: jnp.asarray(v) for k, v in b.items()}  # noqa: E731
    with tempfile.TemporaryDirectory() as d:
        for b in batches[:3]:
            state, _ = step(state, to_dev(b))
        save_checkpoint(d, 3, state)
        cont = state
        for b in batches[3:]:
            cont, _ = step(cont, to_dev(b))
        like = jax.tree_util.tree_map(
            lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), state
        )
        restored, _ = restore_checkpoint(d, like)
        for b in batches[3:]:
            restored, _ = step(restored, to_dev(b))
        for a, c in zip(
            jax.tree_util.tree_leaves(cont), jax.tree_util.tree_leaves(restored)
        ):
            np.testing.assert_allclose(
                np.asarray(a, np.float32), np.asarray(c, np.float32), atol=1e-6
            )


# -- serving ---------------------------------------------------------------------------------
def test_serving_engine_completes_requests():
    m = build("smollm-360m", smoke=True)
    params = m.init_params(KEY)
    eng = ServingEngine(m, params, batch_slots=2, max_seq=32)
    reqs = [
        Request(rid=i, prompt=np.array([3 + i, 4, 5], np.int32), max_new_tokens=4)
        for i in range(3)
    ]
    done = eng.run(reqs, max_steps=64)
    assert all(r.done for r in done)
    assert all(len(r.output) == 4 for r in done)
