"""Tier-1 tests for the deterministic observability plane (:mod:`repro.obs`).

Covers the four contracts the plane ships with:

* registry semantics — typed create-or-get metrics, exact percentiles,
  deterministic export ordering, JSON-safe records;
* the zero-cost disabled path — a disabled hub hands out shared inert
  singletons and retains **zero** state, even through a full DES run;
* one-code-path percentiles — ``DesReport`` and the JSONL export read the
  same ``Histogram`` objects, so their p50/p95/p99 are equal by identity;
* determinism goldens — a fixed-seed payload exports byte-identical JSONL
  across fresh control-plane runs (§6 micro topology and Yahoo PageLoad),
  and instrumentation never changes placements, reports, or traces.
"""

from __future__ import annotations

import json
import math

import pytest

from repro.api import (
    ClusterSpec,
    DesSettings,
    Nimbus,
    ObsSettings,
    RebalanceEvent,
    RunSettings,
    ScenarioRunner,
    ScenarioSpec,
    SchedulerSpec,
    SchedulingPayload,
    SubmitEvent,
    TopologySpec,
    get_scheduler,
)
from repro.core.cluster import Cluster, emulab_cluster
from repro.obs import (
    DEFAULT_BUCKETS,
    NULL_HUB,
    NULL_METRIC,
    NULL_SPAN,
    Histogram,
    MetricsHub,
    get_hub,
)
from repro.obs.report import main as report_main
from repro.stream import topologies as T
from repro.stream.des import DesConfig, DesExecutor


def _has_jax() -> bool:
    try:
        import jax  # noqa: F401

        return True
    except Exception:
        return False


# --------------------------------------------------------------------------
# registry semantics
# --------------------------------------------------------------------------


def test_registry_create_or_get_and_typed_records():
    hub = MetricsHub()
    c = hub.counter("x.count", topology="t")
    c.inc()
    c.inc(2)
    assert hub.counter("x.count", topology="t") is c  # create-or-get
    assert hub.counter("x.count", topology="u") is not c  # labels key
    g = hub.gauge("x.rate")
    assert g.value is None
    g.set(3.5)
    s = hub.series("x.curve")
    s.append(0, 1.0)
    s.append(1, 2.0)
    recs = {(r["kind"], r["name"], json.dumps(r["labels"], sort_keys=True)): r
            for r in hub.records()}
    assert recs[("counter", "x.count", '{"topology": "t"}')]["value"] == 3
    assert recs[("gauge", "x.rate", "{}")]["value"] == 3.5
    assert recs[("series", "x.curve", "{}")]["points"] == [[0, 1.0], [1, 2.0]]


def test_histogram_exact_percentiles_and_buckets():
    h = Histogram(DEFAULT_BUCKETS)
    for v in range(1, 101):
        h.observe(float(v))
    p50, p95, p99 = h.percentiles()
    # Exact (interpolated) percentiles over retained values — not bucket
    # midpoints: that is the registry's "exact p50/p95/p99" contract.
    assert p50 == 50.5 and p95 == 95.05 and p99 == 99.01
    assert h.mean() == pytest.approx(50.5)
    rec = h.record()
    assert rec["count"] == 100
    assert rec["p99"] == 99.01
    assert sum(rec["bucket_counts"]) == 100
    empty = Histogram()
    assert empty.percentiles() == (None, None, None)
    assert empty.mean() == 0.0


def test_export_is_sorted_json_safe_and_stable():
    def build():
        hub = MetricsHub()
        hub.counter("b.second").inc(1)
        hub.counter("a.first", node="n2").inc(2)
        hub.counter("a.first", node="n1").inc(3)
        hub.series("c.mixed", step=3).append(0, 1.0)
        hub.series("c.mixed", step="x").append(0, 2.0)  # mixed label types
        with hub.span("outer", phase="p") as sp:
            sp.set(items=2)
            with hub.span("inner"):
                pass
        return hub

    a, b = build().to_jsonl(), build().to_jsonl()
    assert a == b  # deterministic across fresh hubs
    lines = [json.loads(line) for line in a.strip().split("\n")]
    # Export order stringifies label values so mixed int/str labels still
    # sort totally — mirror that here.
    metric_idents = [
        (r["kind"], r["name"], tuple(sorted((k, str(v)) for k, v in r["labels"].items())))
        for r in lines
        if r["kind"] != "span"
    ]
    assert metric_idents == sorted(metric_idents)  # sorted export
    spans = [r for r in lines if r["kind"] == "span"]
    assert [s["name"] for s in spans] == ["outer", "inner"]
    assert spans[0]["parent"] is None and spans[1]["parent"] == spans[0]["seq"]
    assert spans[0]["meta"] == {"items": 2}
    assert all("wall_s" not in s for s in spans)  # excluded by default


def test_include_wall_adds_span_durations_only_on_request():
    hub = MetricsHub()
    with hub.span("timed"):
        pass
    rec = hub.records(include_wall=True)[-1]
    assert "wall_s" in rec and rec["wall_s"] >= 0.0


# --------------------------------------------------------------------------
# the disabled path: shared singletons, zero retained state
# --------------------------------------------------------------------------


def test_disabled_hub_hands_out_inert_singletons_and_keeps_no_state():
    hub = MetricsHub(enabled=False)
    assert hub.counter("x", a=1) is NULL_METRIC
    assert hub.gauge("y") is NULL_METRIC
    assert hub.series("z") is NULL_METRIC
    assert hub.histogram("h") is NULL_METRIC
    assert hub.span("s") is NULL_SPAN
    NULL_METRIC.inc()
    NULL_METRIC.set(1.0)
    NULL_METRIC.append(0, 1.0)
    NULL_METRIC.observe(2.0)
    with hub.span("s") as sp:
        sp.set(k=1)
    hub.attach("h2", Histogram())
    assert hub._metrics == {} and hub._spans == [] and hub._seq == 0
    assert hub.to_jsonl() == ""


def test_null_hub_retains_zero_state_through_a_des_run():
    cluster = emulab_cluster()
    topo = T.linear()
    assignment = get_scheduler("rstorm").schedule(topo, cluster, commit=False)
    # No activation: the DES resolves NULL_HUB ambiently and must leave it
    # untouched — that is the "disabled path is free" contract.
    DesExecutor(cluster, config=DesConfig(duration_s=0.1, seed=1)).run(
        topo, assignment
    )
    assert get_hub() is NULL_HUB
    assert NULL_HUB._metrics == {} and NULL_HUB._spans == [] and NULL_HUB._seq == 0


# --------------------------------------------------------------------------
# DES: one code path for report and telemetry percentiles
# --------------------------------------------------------------------------


def _des_run(hub=None, seed=7):
    cluster = emulab_cluster()
    topo = T.linear()
    assignment = get_scheduler("rstorm").schedule(topo, cluster, commit=False)
    ex = DesExecutor(cluster, config=DesConfig(duration_s=0.2, seed=seed))
    if hub is None:
        return ex.run(topo, assignment)
    with hub.activate():
        return ex.run(topo, assignment)


def test_des_report_and_export_share_percentiles():
    hub = MetricsHub()
    rep = _des_run(hub)
    recs = [json.loads(line) for line in hub.to_jsonl().strip().split("\n")]
    lat = [r for r in recs if r["kind"] == "histogram" and r["name"] == "des.latency_s"]
    qd = [r for r in recs if r["kind"] == "histogram" and r["name"] == "des.queue_depth"]
    assert len(lat) == 1 and len(qd) == 1
    # DesReport percentiles and exported percentiles are the same Histogram,
    # so equality is exact — no tolerance.
    assert lat[0]["p50"] == rep.p50_latency_s
    assert lat[0]["p95"] == rep.p95_latency_s
    assert lat[0]["p99"] == rep.p99_latency_s
    assert qd[0]["p50"] == rep.p50_queue_depth
    assert qd[0]["p99"] == rep.p99_queue_depth
    assert qd[0]["count"] == len(rep.queue_depth_trace)
    # The time-series plane rides along: per-task queue depth, cumulative
    # ledgers, per-node utilization.
    names = {r["name"] for r in recs}
    assert {"des.task_queue_depth", "des.dropped", "des.node_utilization",
            "des.sink_rate", "des.emitted", "des.acked"} <= names


def test_des_instrumentation_is_invisible_to_the_report():
    bare = _des_run()
    instrumented = _des_run(MetricsHub())
    assert instrumented.to_dict() == bare.to_dict()


def test_des_queue_depth_percentiles_match_trace():
    import numpy as np

    hub = MetricsHub()
    rep = _des_run(hub)
    if rep.queue_depth_trace:
        want = float(
            np.percentile(
                np.asarray(rep.queue_depth_trace, dtype=np.float64), 95.0
            )
        )
        assert rep.p95_queue_depth == want


# --------------------------------------------------------------------------
# determinism goldens: fixed seed -> byte-identical JSONL
# --------------------------------------------------------------------------


def _payload(topo_spec, export_path):
    return SchedulingPayload(
        topology=topo_spec,
        cluster=ClusterSpec(preset="emulab_12"),
        scheduler=SchedulerSpec(name="rstorm"),
        settings=RunSettings(
            simulate=True,
            sim_engine="des",
            des=DesSettings(duration_s=0.15, seed=11),
            obs=ObsSettings(enabled=True, export_path=str(export_path)),
        ),
    )


@pytest.mark.parametrize(
    "make_topo", [T.linear, T.pageload], ids=["micro_linear", "yahoo_pageload"]
)
def test_golden_byte_identical_jsonl_across_runs(make_topo, tmp_path):
    spec = TopologySpec.from_topology(make_topo())
    paths = [tmp_path / "run1.jsonl", tmp_path / "run2.jsonl"]
    plans = [Nimbus().plan(_payload(spec, p)) for p in paths]
    assert plans[0].placements == plans[1].placements
    a, b = paths[0].read_bytes(), paths[1].read_bytes()
    assert a and a == b, "fixed seed must export byte-identical telemetry"
    # Every line is minified sorted-key JSON (the byte-stability substrate).
    for line in a.decode().strip().split("\n"):
        rec = json.loads(line)
        assert line == json.dumps(rec, sort_keys=True, separators=(",", ":"))


def test_scenario_trace_unchanged_and_series_recorded():
    spec = ScenarioSpec(
        cluster=ClusterSpec(preset="emulab_12"),
        timeline=(
            SubmitEvent(
                topology=TopologySpec.from_topology(T.linear()),
                scheduler=SchedulerSpec(name="rstorm"),
            ),
            RebalanceEvent(),
        ),
        name="obs-scn",
    )
    hub = MetricsHub()
    with_hub = ScenarioRunner(spec, hub=hub).run()
    without = ScenarioRunner(spec).run()
    assert with_hub.to_dict() == without.to_dict()
    names = {r["name"] for r in hub.records()}
    assert {"scenario.step", "scenario.sink_throughput", "scenario.network_cost",
            "scenario.machines_used", "scenario.alive_nodes",
            "nimbus.submit", "nimbus.rebalance", "nimbus.simulate"} <= names
    # Per-interval series are keyed by timeline step, not time.
    (labels, series), = [
        (l, m) for l, m in hub.find("series", "scenario.machines_used")
    ]
    assert labels == {"scenario": "obs-scn"}
    assert [p[0] for p in series.points] == [0, 1]


# --------------------------------------------------------------------------
# search: instrumentation never perturbs placements
# --------------------------------------------------------------------------


@pytest.mark.parametrize(
    "backend",
    ["numpy"] + (["jax"] if _has_jax() else []),
)
def test_search_placements_invariant_under_hub(backend):
    topo = T.linear()

    def run(hub=None):
        cluster = Cluster.homogeneous(
            racks=2, nodes_per_rack=4, cpu=400.0, memory_mb=4096.0
        )
        sched = get_scheduler(
            "rstorm-search", seed=5, n_chains=4, steps=40, multi_swap=4,
            backend=backend,
        )
        if hub is None:
            return sched.schedule(topo, cluster, commit=False)
        with hub.activate():
            return sched.schedule(topo, cluster, commit=False)

    bare = run()
    hub = MetricsHub()
    observed = run(hub)
    assert observed.placements == bare.placements
    names = {r["name"] for r in hub.records()}
    assert {"search.best_objective", "search.chain_accept_rate",
            "search.accept_rate", "search.proposals", "search.accepted",
            "search.schedule", "search.anneal"} <= names
    # Acceptance rates are probabilities; the curve is monotone non-increasing
    # for the netcost objective (best-so-far).
    (_, gauge), = hub.find("gauge", "search.accept_rate")
    assert 0.0 <= gauge.value <= 1.0
    (_, curve), = hub.find("series", "search.best_objective")
    values = [p[1] for p in curve.points]
    assert values == sorted(values, reverse=True) or all(
        not math.isnan(v) for v in values
    )
    # Telemetry itself is deterministic.
    hub2 = MetricsHub()
    run(hub2)
    assert hub2.to_jsonl() == hub.to_jsonl()


# --------------------------------------------------------------------------
# settings plumbing
# --------------------------------------------------------------------------


def test_obs_settings_sparse_roundtrip():
    assert "obs" not in RunSettings().to_dict()
    rs = RunSettings(obs=ObsSettings(enabled=True, export_path="/tmp/x.jsonl"))
    d = rs.to_dict()
    assert d["obs"] == {"enabled": True, "export_path": "/tmp/x.jsonl"}
    rt = RunSettings.from_dict(json.loads(json.dumps(d)), "settings", [])
    assert rt.obs == rs.obs
    # include_wall only serializes when set (sparse).
    assert "include_wall" not in ObsSettings().to_dict()
    assert ObsSettings(include_wall=True).to_dict()["include_wall"] is True


def test_obs_settings_validation_reports_bad_fields():
    errors = ObsSettings(enabled=True, export_path="").validate("settings.obs")
    assert any("export_path" in e for e in errors)
    errors = RunSettings.from_dict(
        {"obs": {"enabled": "yes"}}, "settings", errs := []
    ) and errs
    assert any("enabled" in e for e in errs)


# --------------------------------------------------------------------------
# report CLI
# --------------------------------------------------------------------------


def _export_sample(path, seed=7):
    hub = MetricsHub()
    _des_run(hub, seed=seed)
    hub.export(str(path))
    return path


def test_report_cli_summarize_and_self_diff(tmp_path, capsys):
    p = _export_sample(tmp_path / "run.jsonl")
    assert report_main(["summarize", str(p), "--top", "3"]) == 0
    out = capsys.readouterr().out
    assert "des.latency_s" in out and "histograms" in out
    assert "top-3 hot nodes" in out
    assert report_main(["diff", str(p), str(p)]) == 0
    assert "identical telemetry" in capsys.readouterr().out


def test_report_cli_diff_flags_changed_run(tmp_path, capsys):
    a = _export_sample(tmp_path / "a.jsonl", seed=7)
    b = _export_sample(tmp_path / "b.jsonl", seed=8)
    rc = report_main(["diff", str(a), str(b)])
    out = capsys.readouterr().out
    assert rc == 1 and "~" in out
