"""Unit + property tests for the R-Storm scheduling core (Alg 1-4)."""

import math

import pytest

from repro.core import (
    AnnealedScheduler,
    Assignment,
    Cluster,
    Component,
    NodeSpec,
    RoundRobinScheduler,
    RStormPlusScheduler,
    RStormScheduler,
    Topology,
    bfs_topology_traversal,
    demand,
    emulab_cluster,
    task_selection,
    weighted_distance,
)


def linear_topology(n_bolts=3, parallelism=4, mem=512.0, cpu=30.0):
    t = Topology("lin")
    prev = None
    for i in range(n_bolts + 1):
        c = Component(f"c{i}", is_spout=(i == 0), parallelism=parallelism)
        c.set_memory_load(mem).set_cpu_load(cpu)
        t.add_component(c)
        if prev:
            t.add_edge(prev, c.id)
        prev = c.id
    return t


# -- resources ----------------------------------------------------------------
def test_resource_vector_arithmetic():
    a = demand(100.0, 10.0, 1.0)
    b = demand(50.0, 5.0, 0.5)
    assert (a - b)["memory_mb"] == 50.0
    assert (a + b)["cpu_points"] == 15.0
    assert a.satisfies_hard(b)
    assert not b.satisfies_hard(a)
    assert a.hard == frozenset({"memory_mb"})


def test_weighted_distance_matches_alg4():
    d = demand(100.0, 50.0)
    avail = demand(200.0, 70.0)
    got = weighted_distance(d, avail, weights={"memory_mb": 1.0, "cpu_points": 1.0, "bandwidth": 1.0}, network_distance=2.0)
    assert got == pytest.approx(math.sqrt(100.0**2 + 20.0**2 + 4.0))


# -- traversal (Alg 2, 3) -------------------------------------------------------
def test_bfs_starts_at_spout_and_orders_adjacent():
    t = linear_topology()
    order = bfs_topology_traversal(t)
    assert order == ["c0", "c1", "c2", "c3"]


def test_bfs_diamond_visits_all_once():
    t = Topology("d")
    for cid, sp in [("s", True), ("a", False), ("b", False), ("j", False)]:
        t.add_component(Component(cid, is_spout=sp))
    t.add_edge("s", "a")
    t.add_edge("s", "b")
    t.add_edge("a", "j")
    t.add_edge("b", "j")
    order = bfs_topology_traversal(t)
    assert sorted(order) == ["a", "b", "j", "s"]
    assert order[0] == "s"


def test_task_selection_interleaves_components():
    t = linear_topology(n_bolts=1, parallelism=2)
    ordering = [tk.component_id for tk in task_selection(t)]
    assert ordering == ["c0", "c1", "c0", "c1"]


def test_task_selection_covers_all_tasks():
    t = linear_topology(n_bolts=3, parallelism=5)
    tasks = task_selection(t)
    assert len(tasks) == t.task_count()
    assert len({tk.id for tk in tasks}) == len(tasks)


# -- schedulers -----------------------------------------------------------------
def test_rstorm_places_all_and_respects_memory():
    t = linear_topology()
    cl = emulab_cluster()
    a = RStormScheduler().schedule(t, cl, commit=False)
    assert a.is_complete(t)
    assert a.hard_violations(t, cl) == []


def test_rstorm_uses_fewer_machines_lower_netcost_than_default():
    t = linear_topology()
    cl = emulab_cluster()
    rr = RoundRobinScheduler(seed=3).schedule(t, cl, commit=False)
    cl.reset()
    rs = RStormScheduler().schedule(t, cl, commit=False)
    assert len(rs.nodes_used()) < len(rr.nodes_used())
    assert rs.network_cost(t, cl) < rr.network_cost(t, cl)


def test_rstorm_reports_unassigned_when_infeasible():
    t = linear_topology(mem=4096.0)  # no node has 4 GB
    cl = emulab_cluster()
    a = RStormScheduler().schedule(t, cl, commit=False)
    assert len(a.unassigned) == t.task_count()
    assert a.hard_violations(t, cl) == []


def test_commit_updates_cluster_state():
    t = linear_topology()
    cl = emulab_cluster()
    RStormScheduler().schedule(t, cl, commit=True)
    used = sum(len(n.assigned_tasks) for n in cl.nodes.values())
    assert used == t.task_count()
    total_before = cl.total_capacity()["memory_mb"]
    avail = cl.total_available()["memory_mb"]
    assert avail == pytest.approx(total_before - 512.0 * t.task_count())


def test_round_robin_modes_cover_all_tasks():
    t = linear_topology()
    for mode in ("port_major", "node_major"):
        cl = emulab_cluster()
        a = RoundRobinScheduler(seed=0, slot_mode=mode).schedule(t, cl, commit=False)
        assert a.is_complete(t)


def test_annealed_never_worse_than_seed():
    t = linear_topology(n_bolts=4, parallelism=3)
    cl = emulab_cluster()
    seed = RStormScheduler().schedule(t, cl, commit=False)
    cl.reset()
    ann = AnnealedScheduler(iters=300).schedule(t, cl, commit=False)
    assert ann.network_cost(t, cl) <= seed.network_cost(t, cl) + 1e-9


# -- registry -----------------------------------------------------------------
def test_registry_knows_all_builtin_schedulers():
    from repro.core import get_scheduler, scheduler_names

    assert scheduler_names() == [
        "round_robin",
        "rstorm",
        "rstorm-search",
        "rstorm_annealed",
        "rstorm_plus",
    ]
    assert isinstance(get_scheduler("rstorm"), RStormScheduler)
    assert get_scheduler("rstorm_annealed", iters=7).iters == 7


def test_registry_rejects_unknown_name_and_bad_kwargs():
    from repro.core import get_scheduler, validate_scheduler_kwargs

    with pytest.raises(KeyError, match="unknown scheduler"):
        get_scheduler("nope")
    with pytest.raises(TypeError, match="iters"):
        get_scheduler("rstorm_annealed", iters="many")
    with pytest.raises(TypeError, match="unknown kwarg"):
        get_scheduler("rstorm", turbo=True)
    errs = validate_scheduler_kwargs("round_robin", {"slot_mode": "diagonal"})
    assert errs and "port_major" in errs[0]


def test_register_scheduler_decorator_adds_third_party_scheduler():
    from repro.core import REGISTRY, SCHEDULERS, Scheduler, get_scheduler
    from repro.core.registry import register_scheduler

    @register_scheduler("test_noop")
    class NoopScheduler(Scheduler):
        def schedule(self, topology, cluster, *, commit=True):
            from repro.core import Assignment

            return Assignment(topology_id=topology.id)

    try:
        assert isinstance(get_scheduler("test_noop"), NoopScheduler)
        assert SCHEDULERS["test_noop"] is NoopScheduler
        with pytest.raises(ValueError, match="already registered"):
            register_scheduler("test_noop")(NoopScheduler)
    finally:
        del REGISTRY["test_noop"]
        del SCHEDULERS["test_noop"]


def test_register_scheduler_unnamed_subclass_does_not_inherit_parent_name():
    from repro.core import REGISTRY, SCHEDULERS
    from repro.core.registry import register_scheduler

    # RStormScheduler is registered as "rstorm"; an unnamed subclass must fall
    # back to its class name, not collide with (or shadow) the parent's.
    @register_scheduler()
    class MyVariant(RStormScheduler):
        pass

    try:
        assert MyVariant.name == "MyVariant"
        assert SCHEDULERS["rstorm"] is RStormScheduler
        assert SCHEDULERS["MyVariant"] is MyVariant
    finally:
        del REGISTRY["MyVariant"]
        del SCHEDULERS["MyVariant"]
