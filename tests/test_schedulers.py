"""Unit + property tests for the R-Storm scheduling core (Alg 1-4)."""

import math

import pytest
from hypothesis import given, settings, strategies as st

from repro.core import (
    AnnealedScheduler,
    Assignment,
    Cluster,
    Component,
    NodeSpec,
    RoundRobinScheduler,
    RStormPlusScheduler,
    RStormScheduler,
    Topology,
    bfs_topology_traversal,
    demand,
    emulab_cluster,
    task_selection,
    weighted_distance,
)


def linear_topology(n_bolts=3, parallelism=4, mem=512.0, cpu=30.0):
    t = Topology("lin")
    prev = None
    for i in range(n_bolts + 1):
        c = Component(f"c{i}", is_spout=(i == 0), parallelism=parallelism)
        c.set_memory_load(mem).set_cpu_load(cpu)
        t.add_component(c)
        if prev:
            t.add_edge(prev, c.id)
        prev = c.id
    return t


# -- resources ----------------------------------------------------------------
def test_resource_vector_arithmetic():
    a = demand(100.0, 10.0, 1.0)
    b = demand(50.0, 5.0, 0.5)
    assert (a - b)["memory_mb"] == 50.0
    assert (a + b)["cpu_points"] == 15.0
    assert a.satisfies_hard(b)
    assert not b.satisfies_hard(a)
    assert a.hard == frozenset({"memory_mb"})


def test_weighted_distance_matches_alg4():
    d = demand(100.0, 50.0)
    avail = demand(200.0, 70.0)
    got = weighted_distance(d, avail, weights={"memory_mb": 1.0, "cpu_points": 1.0, "bandwidth": 1.0}, network_distance=2.0)
    assert got == pytest.approx(math.sqrt(100.0**2 + 20.0**2 + 4.0))


# -- traversal (Alg 2, 3) -------------------------------------------------------
def test_bfs_starts_at_spout_and_orders_adjacent():
    t = linear_topology()
    order = bfs_topology_traversal(t)
    assert order == ["c0", "c1", "c2", "c3"]


def test_bfs_diamond_visits_all_once():
    t = Topology("d")
    for cid, sp in [("s", True), ("a", False), ("b", False), ("j", False)]:
        t.add_component(Component(cid, is_spout=sp))
    t.add_edge("s", "a")
    t.add_edge("s", "b")
    t.add_edge("a", "j")
    t.add_edge("b", "j")
    order = bfs_topology_traversal(t)
    assert sorted(order) == ["a", "b", "j", "s"]
    assert order[0] == "s"


def test_task_selection_interleaves_components():
    t = linear_topology(n_bolts=1, parallelism=2)
    ordering = [tk.component_id for tk in task_selection(t)]
    assert ordering == ["c0", "c1", "c0", "c1"]


def test_task_selection_covers_all_tasks():
    t = linear_topology(n_bolts=3, parallelism=5)
    tasks = task_selection(t)
    assert len(tasks) == t.task_count()
    assert len({tk.id for tk in tasks}) == len(tasks)


# -- schedulers -----------------------------------------------------------------
def test_rstorm_places_all_and_respects_memory():
    t = linear_topology()
    cl = emulab_cluster()
    a = RStormScheduler().schedule(t, cl, commit=False)
    assert a.is_complete(t)
    assert a.hard_violations(t, cl) == []


def test_rstorm_uses_fewer_machines_lower_netcost_than_default():
    t = linear_topology()
    cl = emulab_cluster()
    rr = RoundRobinScheduler(seed=3).schedule(t, cl, commit=False)
    cl.reset()
    rs = RStormScheduler().schedule(t, cl, commit=False)
    assert len(rs.nodes_used()) < len(rr.nodes_used())
    assert rs.network_cost(t, cl) < rr.network_cost(t, cl)


def test_rstorm_reports_unassigned_when_infeasible():
    t = linear_topology(mem=4096.0)  # no node has 4 GB
    cl = emulab_cluster()
    a = RStormScheduler().schedule(t, cl, commit=False)
    assert len(a.unassigned) == t.task_count()
    assert a.hard_violations(t, cl) == []


def test_commit_updates_cluster_state():
    t = linear_topology()
    cl = emulab_cluster()
    RStormScheduler().schedule(t, cl, commit=True)
    used = sum(len(n.assigned_tasks) for n in cl.nodes.values())
    assert used == t.task_count()
    total_before = cl.total_capacity()["memory_mb"]
    avail = cl.total_available()["memory_mb"]
    assert avail == pytest.approx(total_before - 512.0 * t.task_count())


def test_round_robin_modes_cover_all_tasks():
    t = linear_topology()
    for mode in ("port_major", "node_major"):
        cl = emulab_cluster()
        a = RoundRobinScheduler(seed=0, slot_mode=mode).schedule(t, cl, commit=False)
        assert a.is_complete(t)


def test_annealed_never_worse_than_seed():
    t = linear_topology(n_bolts=4, parallelism=3)
    cl = emulab_cluster()
    seed = RStormScheduler().schedule(t, cl, commit=False)
    cl.reset()
    ann = AnnealedScheduler(iters=300).schedule(t, cl, commit=False)
    assert ann.network_cost(t, cl) <= seed.network_cost(t, cl) + 1e-9


# -- hypothesis property tests ----------------------------------------------------
@settings(max_examples=30, deadline=None)
@given(
    n_bolts=st.integers(1, 6),
    par=st.integers(1, 6),
    mem=st.floats(16.0, 1024.0),
    cpu=st.floats(1.0, 120.0),
    racks=st.integers(1, 4),
    npr=st.integers(1, 8),
)
def test_property_hard_constraints_never_violated(n_bolts, par, mem, cpu, racks, npr):
    t = linear_topology(n_bolts=n_bolts, parallelism=par, mem=mem, cpu=cpu)
    cl = Cluster.homogeneous(racks=racks, nodes_per_rack=npr)
    a = RStormScheduler().schedule(t, cl, commit=False)
    # Invariant 1: placements ∪ unassigned is a partition of all tasks.
    all_ids = {tk.id for tk in t.all_tasks()}
    assert set(a.placements) | set(a.unassigned) == all_ids
    assert not (set(a.placements) & set(a.unassigned))
    # Invariant 2: no node over its hard memory budget.
    assert a.hard_violations(t, cl) == []
    # Invariant 3: if memory fits anywhere, at least one task is placed.
    if mem <= 2048.0:
        assert a.placements


@settings(max_examples=20, deadline=None)
@given(par=st.integers(1, 5), seed=st.integers(0, 10))
def test_property_rstorm_netcost_beats_or_ties_roundrobin(par, seed):
    t = linear_topology(n_bolts=3, parallelism=par)
    cl = emulab_cluster()
    rr = RoundRobinScheduler(seed=seed).schedule(t, cl, commit=False)
    cl.reset()
    rs = RStormScheduler().schedule(t, cl, commit=False)
    assert rs.network_cost(t, cl) <= rr.network_cost(t, cl) + 1e-9


@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 50))
def test_property_schedulers_are_deterministic(seed):
    t = linear_topology()
    cl = emulab_cluster()
    a1 = RStormScheduler().schedule(t, cl, commit=False)
    cl.reset()
    a2 = RStormScheduler().schedule(t, cl, commit=False)
    assert a1.placements == a2.placements
    cl.reset()
    b1 = RoundRobinScheduler(seed=seed).schedule(t, cl, commit=False)
    cl.reset()
    b2 = RoundRobinScheduler(seed=seed).schedule(t, cl, commit=False)
    assert b1.placements == b2.placements
