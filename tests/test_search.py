"""Batched placement-search subsystem (repro.core.search).

Covers: BatchArena compilation, the batched objective against the exact
dict-path evaluators, the shared swap-delta against full recomputation
(the regression the extraction from SwapAnnealer is pinned by), the
rstorm-search scheduler's never-worse-than-greedy guarantee, determinism,
jax/numpy golden equality, and the control-plane integration
(registry kwargs, Nimbus plan/submit/rebalance, ScenarioRunner replay).
"""

import numpy as np
import pytest

from repro.core import (
    Assignment,
    BatchArena,
    Cluster,
    Component,
    NodeSpec,
    PlacementArena,
    SearchScheduler,
    Topology,
    emulab_cluster,
    evaluate_batch,
    get_scheduler,
    validate_scheduler_kwargs,
)
from repro.core.engine import swap_network_delta, swap_overload_delta
from repro.core.search import BatchAnnealer, HAS_JAX
from repro.core.search.anneal import swap_proposals
from repro.core.search.throughput import compile_throughput, throughput_batch
from repro.stream import Simulator, topologies as T

BACKENDS = ["numpy"] + (["jax"] if HAS_JAX else [])


def chain_topology(components=5, parallelism=4, mem=128.0, cpu=10.0):
    t = Topology(f"chain{components}x{parallelism}")
    prev = None
    for i in range(components):
        c = Component(f"c{i}", is_spout=(i == 0), parallelism=parallelism)
        c.set_memory_load(mem).set_cpu_load(cpu)
        t.add_component(c)
        if prev:
            t.add_edge(prev, c.id)
        prev = c.id
    return t


def compile_case(topo_factory=chain_topology, cluster_factory=emulab_cluster):
    topology, cluster = topo_factory(), cluster_factory()
    arena = PlacementArena(cluster, topology)
    avail0 = arena.snapshot()
    assignment = Assignment(topology_id=topology.id)
    get_scheduler("rstorm")._place_on_arena(arena, topology, assignment)
    ba = BatchArena.from_arena(
        arena, topology, dict(assignment.placements), avail0=avail0
    )
    return topology, cluster, arena, assignment, ba


def random_batch(ba, n, seed=0, alive_only=True):
    rng = np.random.Generator(np.random.Philox(seed))
    pool = np.flatnonzero(ba.alive) if alive_only else np.arange(ba.n_nodes)
    return pool[rng.integers(0, pool.size, size=(n, ba.n_tasks))]


# -- BatchArena compilation -------------------------------------------------------
def test_batch_arena_shapes_and_order():
    topology, cluster, arena, assignment, ba = compile_case()
    assert ba.tids == sorted(assignment.placements)
    assert ba.n_tasks == len(assignment.placements)
    assert ba.n_nodes == len(cluster.nodes)
    assert ba.hard_dims == ["memory_mb"]
    assert ba.net is arena.net  # shared, not copied
    assert ba.hard_demand.shape == (ba.n_tasks, 1)
    assert ba.adj.shape[0] == ba.n_tasks
    assert (ba.adj[ba.adj_mask] >= 0).all()
    # Every directed component edge appears as task pairs over placed tasks.
    assert ba.edges.shape[0] == sum(
        topology.components[s].parallelism * topology.components[d].parallelism
        for s, d in topology.edges
    )


def test_encode_decode_round_trip():
    *_, assignment, ba = compile_case()
    row = ba.encode(dict(assignment.placements))
    assert ba.decode(row) == dict(assignment.placements)


# -- objective vs exact dict-path evaluation --------------------------------------
@pytest.mark.parametrize("backend", BACKENDS)
def test_objective_matches_assignment_network_cost(backend):
    topology, cluster, arena, assignment, ba = compile_case(
        lambda: T.pageload(), lambda: emulab_cluster()
    )
    P = random_batch(ba, 16, seed=7)
    result = evaluate_batch(ba, P, backend=backend)
    for b in range(P.shape[0]):
        a = Assignment(topology.id, placements=ba.decode(P[b]))
        assert result.net[b] == a.network_cost(topology, cluster)
        # On a fresh cluster, availability == capacity, so zero violation
        # must coincide with the dict-path hard_violations check.
        assert (result.violation[b] == 0.0) == (
            a.hard_violations(topology, cluster) == []
        )
    assert (result.dead == 0).all()


@pytest.mark.parametrize("backend", BACKENDS)
def test_objective_flags_dead_nodes(backend):
    topology, cluster, arena, assignment, ba = compile_case()
    cluster.fail_node(ba.node_ids[0])
    arena2 = PlacementArena(cluster, topology)
    ba2 = BatchArena.from_arena(
        arena2, topology, dict(assignment.placements), avail0=arena2.snapshot()
    )
    P = np.zeros((1, ba2.n_tasks), dtype=np.intp)  # everything on the dead node
    result = evaluate_batch(ba2, P, backend=backend)
    assert result.dead[0] == ba2.n_tasks
    assert not result.feasible[0]


def test_greedy_seed_is_feasible_with_zero_violation():
    topology, cluster, arena, assignment, ba = compile_case()
    result = evaluate_batch(ba, ba.encode(dict(assignment.placements)))
    assert result.violation[0] == 0.0
    assert result.feasible[0]


# -- shared swap delta vs full recompute (regression for the extraction) ----------
def test_swap_delta_matches_full_recompute():
    topology, cluster, arena, assignment, ba = compile_case(
        lambda: T.diamond(True), lambda: emulab_cluster()
    )
    rng = np.random.Generator(np.random.Philox(3))
    P = random_batch(ba, 1, seed=11)[0]
    base = evaluate_batch(ba, P)
    used = ba.used(P)[0]
    for _ in range(50):
        i = int(rng.integers(0, ba.n_tasks))
        j = int((i + rng.integers(1, ba.n_tasks)) % ba.n_tasks)
        na, nb = int(P[i]), int(P[j])
        pa = P[np.where(ba.adj_mask[i], ba.adj[i], 0)]
        pb = P[np.where(ba.adj_mask[j], ba.adj[j], 0)]
        m_ab = int(((ba.adj[i] == j) & ba.adj_mask[i]).sum())
        dnet = swap_network_delta(
            ba.net, na, nb, pa, pb, m_ab, ba.adj_mask[i], ba.adj_mask[j]
        )
        dov = swap_overload_delta(
            ba.avail[na], ba.avail[nb], used[na], used[nb],
            ba.hard_demand[i], ba.hard_demand[j],
        )
        Q = P.copy()
        Q[i], Q[j] = P[j], P[i]
        full = evaluate_batch(ba, Q)
        assert dnet == full.net[0] - base.net[0]
        assert dov == pytest.approx(full.violation[0] - base.violation[0])


def test_sequential_annealer_tracked_cost_matches_recompute():
    """The SwapAnnealer, now running on the shared delta, must still land on
    a placement whose tracked cost equals the from-scratch evaluation."""
    import random
    from repro.core import SwapAnnealer

    topology, cluster, arena, assignment, ba = compile_case()
    ann = SwapAnnealer(arena, topology, dict(assignment.placements))
    placements = ann.run(300, random.Random(5))
    a = Assignment(topology.id, placements=placements)
    assert ann.cost() == a.network_cost(topology, cluster)


# -- batched annealer -------------------------------------------------------------
def test_swap_proposals_never_propose_identity():
    ii, jj = swap_proposals(17, 200, 8, seed=4)
    assert (ii != jj).all()
    ii2, jj2 = swap_proposals(17, 200, 8, seed=4)
    assert (ii == ii2).all() and (jj == jj2).all()


@pytest.mark.parametrize("backend", BACKENDS)
def test_annealer_chains_stay_feasible_from_greedy(backend):
    topology, cluster, arena, assignment, ba = compile_case()
    P0 = np.tile(ba.encode(dict(assignment.placements)), (8, 1))
    P = BatchAnnealer(ba, backend=backend).run(P0, steps=150, seed=2)
    result = evaluate_batch(ba, P, backend=backend)
    assert (result.violation == 0.0).all()
    assert (result.dead == 0).all()


@pytest.mark.skipif(not HAS_JAX, reason="jax not installed")
def test_annealer_backends_golden_equal():
    topology, cluster, arena, assignment, ba = compile_case(
        lambda: T.pageload(), lambda: emulab_cluster()
    )
    P0 = random_batch(ba, 16, seed=9)
    a = BatchAnnealer(ba, backend="numpy").run(P0, steps=200, seed=13)
    b = BatchAnnealer(ba, backend="jax").run(P0, steps=200, seed=13)
    assert (a == b).all()
    ra = evaluate_batch(ba, a, backend="numpy")
    rb = evaluate_batch(ba, b, backend="jax")
    assert (ra.net == rb.net).all()
    assert (ra.violation == rb.violation).all()


# -- the registered scheduler -----------------------------------------------------
@pytest.mark.parametrize("backend", BACKENDS)
@pytest.mark.parametrize("init", ["greedy", "random", "all-registered"])
def test_search_never_worse_than_greedy(init, backend):
    topology, cluster = T.pageload(), emulab_cluster()
    greedy = get_scheduler("rstorm").schedule(topology, cluster, commit=False)
    greedy_net = greedy.network_cost(topology, cluster)
    cluster.reset()
    s = get_scheduler(
        "rstorm-search", n_chains=12, steps=120, seed=1, init=init, backend=backend
    ).schedule(topology, cluster, commit=False)
    assert s.network_cost(topology, cluster) <= greedy_net
    assert s.hard_violations(topology, cluster) == []
    assert sorted(s.unassigned) == sorted(greedy.unassigned)
    assert set(s.placements) == set(greedy.placements)


def test_search_improves_on_flagship_overhead_case():
    """Acceptance: strictly lower network cost than greedy on the
    1000-task / 256-node case (small budget keeps the test fast)."""
    topo = chain_topology(25, 40)
    cluster = Cluster.homogeneous(
        racks=8, nodes_per_rack=32, memory_mb=65536.0, cpu=6400.0
    )
    greedy = get_scheduler("rstorm").schedule(topo, cluster, commit=False)
    cluster.reset()
    s = get_scheduler("rstorm-search", n_chains=16, steps=150, seed=0).schedule(
        topo, cluster, commit=False
    )
    assert s.network_cost(topo, cluster) < greedy.network_cost(topo, cluster)
    assert s.hard_violations(topo, cluster) == []


@pytest.mark.parametrize("backend", BACKENDS)
def test_search_deterministic(backend):
    topology, cluster = T.diamond(True), emulab_cluster()
    kw = dict(n_chains=10, steps=100, seed=42, backend=backend)
    a = get_scheduler("rstorm-search", **kw).schedule(topology, cluster, commit=False)
    cluster.reset()
    b = get_scheduler("rstorm-search", **kw).schedule(topology, cluster, commit=False)
    assert a.placements == b.placements


@pytest.mark.skipif(not HAS_JAX, reason="jax not installed")
def test_search_backends_agree_end_to_end():
    topology, cluster = T.pageload(), emulab_cluster()
    kw = dict(n_chains=12, steps=150, seed=3)
    a = get_scheduler("rstorm-search", backend="numpy", **kw).schedule(
        topology, cluster, commit=False
    )
    cluster.reset()
    b = get_scheduler("rstorm-search", backend="jax", **kw).schedule(
        topology, cluster, commit=False
    )
    assert a.placements == b.placements


def test_search_degrades_to_greedy_on_trivial_topology():
    t = Topology("solo")
    t.add_component(Component("s", is_spout=True, parallelism=1))
    cluster = emulab_cluster()
    s = get_scheduler("rstorm-search", n_chains=4, steps=10).schedule(
        t, cluster, commit=False
    )
    cluster.reset()
    g = get_scheduler("rstorm").schedule(t, cluster, commit=False)
    assert s.placements == g.placements


# -- control-plane integration ----------------------------------------------------
def test_kwargs_schema_validation():
    assert validate_scheduler_kwargs("rstorm-search", {"n_chains": 8}) == []
    errs = validate_scheduler_kwargs(
        "rstorm-search", {"init": "genetic", "steps": 0, "bogus": 1}
    )
    assert len(errs) == 3
    with pytest.raises(TypeError):
        get_scheduler("rstorm-search", init="genetic")
    if HAS_JAX:
        assert SearchScheduler(backend="jax").backend == "jax"
    else:
        # Explicit jax on a jax-less box must fail loudly, not fall back.
        with pytest.raises(RuntimeError):
            SearchScheduler(backend="jax")
    assert SearchScheduler(backend="auto").backend == (
        "jax" if HAS_JAX else "numpy"
    )


def test_nimbus_plan_submit_rebalance_with_search():
    from repro.api import (
        ClusterSpec,
        Nimbus,
        RunSettings,
        SchedulerSpec,
        SchedulingPayload,
        TopologySpec,
    )

    payload = SchedulingPayload(
        topology=TopologySpec.from_topology(T.pageload()),
        cluster=ClusterSpec(preset="emulab_12"),
        scheduler=SchedulerSpec("rstorm-search", {"n_chains": 8, "steps": 80}),
        settings=RunSettings(simulate=False),
    )
    nim = Nimbus()
    plan = nim.plan(payload)
    assert plan.scheduler_name == "rstorm-search"
    assert not plan.committed and nim.cluster is None
    plan2 = nim.submit(payload)
    assert plan2.committed
    assert plan2.placements == plan.placements  # stateless plan == submit
    # Greedy rstorm on the same payload must not beat the search plan.
    greedy_nim = Nimbus()
    gplan = greedy_nim.plan(
        SchedulingPayload(
            topology=payload.topology,
            cluster=payload.cluster,
            scheduler=SchedulerSpec("rstorm"),
            settings=RunSettings(simulate=False),
        )
    )
    assert plan.network_cost <= gplan.network_cost
    # Lifecycle verbs keep working on a search-scheduled state.
    orphans = nim.fail_node(sorted(nim.cluster.nodes)[0])
    result = nim.rebalance()
    assert {tid for _, tid in orphans} == set(
        result.moved.get(plan.topology_id, [])
    ) | set(result.unplaced.get(plan.topology_id, []))


# -- throughput proxy (the §6 objective) --------------------------------------------
def tp_case(maker=T.pageload):
    topology, cluster, arena, assignment, ba = compile_case(
        maker, lambda: emulab_cluster()
    )
    tm = compile_throughput(ba, topology, cluster)
    return topology, cluster, assignment, ba, tm


@pytest.mark.parametrize("maker", [T.pageload, T.processing, lambda: T.linear(True)])
def test_throughput_proxy_deterministic(maker):
    topology, cluster, assignment, ba, tm = tp_case(maker)
    P = random_batch(ba, 12, seed=5)
    a = throughput_batch(ba, tm, P, backend="numpy")
    tm2 = compile_throughput(ba, topology, cluster)
    b = throughput_batch(ba, tm2, P, backend="numpy")
    assert (a == b).all()
    assert np.isfinite(a).all() and (a >= 0.0).all()


@pytest.mark.skipif(not HAS_JAX, reason="jax not installed")
@pytest.mark.parametrize(
    "maker",
    [T.pageload, T.processing, lambda: T.linear(True), lambda: T.star(False)],
)
def test_throughput_proxy_backends_bit_identical(maker):
    """Same golden-equality bar as evaluate_batch: the grid-quantized
    reductions make numpy and jax agree to the last bit."""
    topology, cluster, assignment, ba, tm = tp_case(maker)
    P = random_batch(ba, 16, seed=7)
    P[0] = ba.encode(dict(assignment.placements))
    a = throughput_batch(ba, tm, P, backend="numpy")
    b = throughput_batch(ba, tm, P, backend="jax")
    assert (a == b).all()


def test_throughput_proxy_matches_simulator_in_cpu_bound_regime():
    """Where the paper's §6.3.2 analysis is exact (uniform shuffle, CPU
    binding), the proxy *is* the simulator's answer for the greedy seed."""
    for maker in (lambda: T.linear(False), lambda: T.star(False)):
        topology, cluster, assignment, ba, tm = tp_case(maker)
        proxy = float(
            throughput_batch(ba, tm, ba.encode(dict(assignment.placements)))[0]
        )
        sim = Simulator(cluster).run(topology, assignment).sink_throughput
        assert proxy == pytest.approx(sim, rel=1e-6)


def test_evaluate_batch_populates_throughput_field():
    topology, cluster, assignment, ba, tm = tp_case()
    P = random_batch(ba, 6, seed=3)
    plain = evaluate_batch(ba, P, backend="numpy")
    assert plain.throughput is None
    full = evaluate_batch(ba, P, backend="numpy", throughput_model=tm)
    assert full.throughput is not None
    assert (full.throughput == throughput_batch(ba, tm, P, backend="numpy")).all()
    assert (full.net == plain.net).all()


@pytest.mark.parametrize("backend", BACKENDS)
def test_evaluate_batch_chunked_equals_unchunked(backend):
    """Regression: ``chunk`` used to be ignored on the jax path — a huge
    batch built one monolithic (B, E) gather.  Chunked results must be
    bit-identical to unchunked on both backends."""
    topology, cluster, assignment, ba, tm = tp_case()
    P = random_batch(ba, 11, seed=9)
    whole = evaluate_batch(ba, P, backend=backend, chunk=1024, throughput_model=tm)
    parts = evaluate_batch(ba, P, backend=backend, chunk=3, throughput_model=tm)
    assert (whole.net == parts.net).all()
    assert (whole.violation == parts.violation).all()
    assert (whole.dead == parts.dead).all()
    assert (whole.throughput == parts.throughput).all()
    with pytest.raises(ValueError):
        evaluate_batch(ba, P, backend=backend, chunk=0)


@pytest.mark.parametrize("backend", BACKENDS)
def test_annealer_throughput_mode_feasible_and_never_below_seed_proxy(backend):
    # Shuffle-grouped topology: the annealer's uniform-split carried state
    # and the locality-aware evaluator coincide, so the hill-climb
    # guarantee (proxy never drops below the seed's) is exact.
    topology, cluster, assignment, ba, tm = tp_case(lambda: T.linear(True))
    greedy_row = ba.encode(dict(assignment.placements))
    P0 = np.tile(greedy_row, (6, 1))
    P = BatchAnnealer(ba, backend=backend).run(
        P0, steps=150, seed=4, objective="throughput", tm=tm
    )
    result = evaluate_batch(ba, P, backend=backend, throughput_model=tm)
    assert (result.violation == 0.0).all()
    seed_tp = throughput_batch(ba, tm, greedy_row, backend=backend)[0]
    assert (result.throughput >= seed_tp).all()


@pytest.mark.parametrize("backend", BACKENDS)
def test_annealer_throughput_mode_stays_feasible_on_local_groupings(backend):
    topology, cluster, assignment, ba, tm = tp_case()  # pageload: local_or_shuffle
    P0 = np.tile(ba.encode(dict(assignment.placements)), (6, 1))
    P = BatchAnnealer(ba, backend=backend).run(
        P0, steps=150, seed=4, objective="throughput", tm=tm
    )
    result = evaluate_batch(ba, P, backend=backend, throughput_model=tm)
    assert (result.violation == 0.0).all()
    assert (result.dead == 0).all()


def test_annealer_throughput_mode_requires_model():
    *_, ba, tm = tp_case()
    with pytest.raises(ValueError):
        BatchAnnealer(ba).run(np.zeros((1, ba.n_tasks), dtype=np.intp), 10, 0,
                              objective="throughput")
    with pytest.raises(ValueError):
        BatchAnnealer(ba).run(np.zeros((1, ba.n_tasks), dtype=np.intp), 10, 0,
                              objective="latency")


@pytest.mark.skipif(not HAS_JAX, reason="jax not installed")
@pytest.mark.parametrize(
    "maker", [T.pageload, T.processing, lambda: T.diamond(True)]
)
def test_annealer_throughput_mode_backends_golden_equal(maker):
    topology, cluster, assignment, ba, tm = tp_case(maker)
    P0 = random_batch(ba, 10, seed=11)
    P0[0] = ba.encode(dict(assignment.placements))
    a = BatchAnnealer(ba, backend="numpy").run(
        P0, steps=250, seed=13, objective="throughput", tm=tm
    )
    b = BatchAnnealer(ba, backend="jax").run(
        P0, steps=250, seed=13, objective="throughput", tm=tm
    )
    assert (a == b).all()


@pytest.mark.parametrize(
    "maker",
    [
        lambda: T.linear(True),
        lambda: T.linear(False),
        lambda: T.star(False),
        T.pageload,
        T.processing,
    ],
)
def test_search_throughput_objective_never_worse_in_simulated_sink_tp(maker):
    """The acceptance guarantee, measured where §6 measures: simulated sink
    throughput of the chosen placement vs the greedy R-Storm seed."""
    topology, cluster = maker(), emulab_cluster()
    greedy = get_scheduler("rstorm").schedule(topology, cluster, commit=False)
    cluster.reset()
    s = get_scheduler(
        "rstorm-search", n_chains=8, steps=150, seed=0, objective="throughput"
    ).schedule(topology, cluster, commit=False)
    cluster.reset()
    sim = Simulator(cluster)
    tp_s = sim.run(topology, s).sink_throughput
    tp_g = sim.run(topology, greedy).sink_throughput
    assert tp_s >= tp_g
    assert s.hard_violations(topology, cluster) == []


def test_search_throughput_objective_deterministic():
    topology, cluster = T.pageload(), emulab_cluster()
    kw = dict(n_chains=8, steps=120, seed=7, objective="throughput")
    a = get_scheduler("rstorm-search", **kw).schedule(topology, cluster, commit=False)
    cluster.reset()
    b = get_scheduler("rstorm-search", **kw).schedule(topology, cluster, commit=False)
    assert a.placements == b.placements


def test_search_objective_kwarg_registry_validation():
    assert validate_scheduler_kwargs(
        "rstorm-search", {"objective": "throughput"}
    ) == []
    errs = validate_scheduler_kwargs("rstorm-search", {"objective": "latency"})
    assert len(errs) == 1
    with pytest.raises(TypeError):
        get_scheduler("rstorm-search", objective="latency")


# -- unassigned recovery (bugfix regression) ----------------------------------------
def recovery_case():
    """Near-full two-node cluster where greedy's spread (CPU distance term)
    strands the big sink task, but a consolidated rearrangement frees the
    memory it needs."""
    t = Topology("recov")
    prev = None
    for k in range(3):
        comp = Component(f"c{k}", is_spout=(k == 0), parallelism=1)
        comp.set_memory_load(500.0).set_cpu_load(60.0)
        t.add_component(comp)
        if prev:
            t.add_edge(prev, comp.id)
        prev = comp.id
    x = Component("x", parallelism=1)
    x.set_memory_load(1100.0).set_cpu_load(10.0)
    t.add_component(x)
    t.add_edge(prev, "x")
    cl = Cluster(
        [NodeSpec(f"n{i}", "rack0", 100.0, 1500.0) for i in range(2)]
    )
    return t, cl


def test_search_recovers_task_greedy_stranded():
    """Regression: the search used to carry greedy's ``unassigned`` list
    through unchanged even when the annealed winner freed the capacity."""
    t, cl = recovery_case()
    greedy = get_scheduler("rstorm").schedule(t, cl, commit=False)
    assert greedy.unassigned == ["recov/x[0]"]  # the setup's premise
    cl.reset()
    s = get_scheduler(
        "rstorm-search", n_chains=12, steps=400, seed=0, init="random"
    ).schedule(t, cl, commit=False)
    assert s.is_complete(t)
    assert s.hard_violations(t, cl) == []


def test_search_recovery_is_deterministic_and_respects_budget():
    t, cl = recovery_case()
    kw = dict(n_chains=12, steps=400, seed=0, init="random")
    a = get_scheduler("rstorm-search", **kw).schedule(t, cl, commit=False)
    cl.reset()
    b = get_scheduler("rstorm-search", **kw).schedule(t, cl, commit=False)
    assert a.placements == b.placements
    assert a.unassigned == b.unassigned


def test_scenario_replay_with_search_is_deterministic():
    from repro.api import (
        ClusterSpec,
        NodeFailEvent,
        RebalanceEvent,
        ScenarioRunner,
        ScenarioSpec,
        SchedulerSpec,
        SubmitEvent,
    )

    spec = ScenarioSpec(
        name="search_failover",
        cluster=ClusterSpec(preset="emulab_12"),
        timeline=(
            SubmitEvent(
                topology=T.spec("pageload"),
                scheduler=SchedulerSpec(
                    "rstorm-search", {"n_chains": 8, "steps": 60, "seed": 5}
                ),
            ),
            NodeFailEvent(node_id="r0n0"),
            RebalanceEvent(),
        ),
    )
    t1 = ScenarioRunner(spec).run()
    t2 = ScenarioRunner(spec).run()
    assert t1.to_dict() == t2.to_dict()
