"""Tier-1 tests for repro-lint (:mod:`repro.analysis`).

Three layers:

* per-rule fixtures — every rule gets a positive (violation fires), a
  negative (idiomatic zone code stays clean), and a suppression case;
* a regression fixture reproducing the real ``weighted_distance``
  iter-order violation fixed in the same PR that introduced the linter;
* the tree gate — ``src``/``benchmarks``/``examples`` must lint clean, so
  any new determinism hazard fails tier-1 before it can ship.
"""

from __future__ import annotations

import subprocess
import sys
import textwrap
from pathlib import Path

import pytest

from repro.analysis.lint import lint_paths, lint_source, main
from repro.analysis.rules import RULES, Violation
from repro.analysis.zones import rules_for_path

REPO_ROOT = Path(__file__).resolve().parent.parent

# Synthetic paths that land in each zone (zone matching is purely textual).
CORE = "src/repro/core/fixture_mod.py"
HOT = "src/repro/core/search/fixture_mod.py"
HARNESS = "benchmarks/fixture_bench.py"
KERNEL = "src/repro/kernels/fixture_kernel.py"  # accelerator kernels (f32 ok)
SEARCH_KERNEL = "src/repro/core/search/kernels/fixture_kernel.py"
DES = "src/repro/stream/des/fixture_engine.py"
OBS = "src/repro/obs/fixture_obs.py"
OUTSIDE = "tools/fixture_tool.py"


def rules_hit(source: str, path: str = CORE):
    violations, _ = lint_source(textwrap.dedent(source), path)
    return {v.rule for v in violations}


def violations_of(source: str, path: str = CORE):
    violations, _ = lint_source(textwrap.dedent(source), path)
    return violations


# --------------------------------------------------------------------------
# zones
# --------------------------------------------------------------------------


def test_zone_rule_sets():
    core = set(rules_for_path(CORE))
    hot = set(rules_for_path(HOT))
    harness = set(rules_for_path(HARNESS))
    kernel = set(rules_for_path(KERNEL))
    skernel = set(rules_for_path(SEARCH_KERNEL))
    assert "iter-order" in core and "hot-loop" not in core
    assert {"hot-loop", "float32-literal", "iter-order"} <= hot
    assert "unseeded-random" in harness and "hot-loop" not in harness
    # Accelerator kernels: pallas hygiene, but no exactness dtype pinning
    # (the flash kernels are float32 by design) and no hot-loop zone.
    assert {"pallas-interpret", "pallas-accum-order", "pallas-grid-truncate"} <= kernel
    assert "pallas-accum-dtype" not in kernel
    assert "float32-literal" not in kernel
    # Search kernels: everything above PLUS the golden-oracle exactness
    # contract (float64 accumulators) and the hot-loop/search-zone rules,
    # because repro/core/search/kernels nests inside repro/core/search.
    assert {
        "pallas-interpret",
        "pallas-accum-order",
        "pallas-grid-truncate",
        "pallas-accum-dtype",
        "float32-literal",
        "hot-loop",
    } <= skernel
    assert rules_for_path(OUTSIDE) == ()
    # The DES executor is core-zone: its bit-identical-trace contract means
    # every random draw must flow from a seeded Philox root, and none of the
    # hot-loop/kernel rules apply (it's a pure-Python event loop).
    des = set(rules_for_path(DES))
    assert des == core
    assert "hot-loop" not in des and "pallas-interpret" not in des
    # The observability plane: byte-identical-JSONL contract => core
    # determinism rules, plus hot-loop so wall-clock reads stay confined
    # to the single allow-listed shim in obs/clock.py.  No jax in obs.
    obs = set(rules_for_path(OBS))
    assert {
        "unseeded-random",
        "iter-order",
        "float-sum",
        "np-reduce-dtype",
        "hot-loop",
    } == obs
    assert "jax-purity" not in obs and "float32-literal" not in obs


def test_des_zone_catches_unseeded_stream():
    # An unseeded default_rng() in the DES would silently break the
    # fixed-seed -> bit-identical-trace determinism contract.
    src = """
        import numpy as np
        def service_time(mean):
            rng = np.random.default_rng()
            return rng.exponential(mean)
    """
    assert "unseeded-random" in rules_hit(src, DES)
    seeded = """
        import numpy as np
        def service_stream(seed):
            return np.random.Generator(np.random.Philox([seed, 0x5E21CE]))
    """
    assert "unseeded-random" not in rules_hit(seeded, DES)


def test_obs_zone_catches_wall_clock_read():
    # A bare wall-clock read in the telemetry plane would leak wall time
    # into exported metrics and break the byte-identical-JSONL goldens.
    src = """
        import time
        def span_duration(t_enter):
            return time.perf_counter() - t_enter
    """
    assert "hot-loop" in rules_hit(src, OBS)
    # ...and the sanctioned shim pattern: a same-line justified allow, which
    # is exactly how obs/clock.py confines the tree's one wall-clock site.
    shim = (
        "import time\n"
        "def perf_counter():\n"
        "    return time.perf_counter()  # repro-lint: allow(hot-loop) shim\n"
    )
    kept, suppressed = lint_source(shim, OBS)
    assert kept == []
    assert [v.rule for v in suppressed] == ["hot-loop"]


def test_obs_zone_catches_float_sum_and_unseeded_random():
    src = """
        import numpy as np
        def summarize(values):
            rng = np.random.default_rng()
            return sum(values), rng
    """
    assert rules_hit(src, OBS) == {"float-sum", "unseeded-random"}


def test_outside_zone_is_never_linted():
    assert violations_of("import random\nrandom.random()\n", OUTSIDE) == []


def test_all_registered_rules_are_reachable_from_some_zone():
    reachable = (
        set(rules_for_path(CORE))
        | set(rules_for_path(HOT))
        | set(rules_for_path(HARNESS))
        | set(rules_for_path(KERNEL))
        | set(rules_for_path(SEARCH_KERNEL))
        | set(rules_for_path(OBS))
    )
    assert reachable == set(RULES)


# --------------------------------------------------------------------------
# unseeded-random
# --------------------------------------------------------------------------


def test_unseeded_random_positive():
    src = """
    import random
    import numpy as np

    def jitter(xs):
        np.random.shuffle(xs)
        k = random.choice(xs)
        rng = np.random.default_rng()
        return k, rng
    """
    vs = violations_of(src)
    assert [v.rule for v in vs] == ["unseeded-random"] * 3


def test_unseeded_random_negative():
    src = """
    import random
    import numpy as np

    def jitter(xs, seed):
        rng = np.random.Generator(np.random.Philox(seed))
        alt = np.random.default_rng(seed)
        py = random.Random(seed)
        return rng.permutation(xs), alt, py
    """
    assert rules_hit(src) == set()


def test_unseeded_random_suppressed():
    src = """
    import numpy as np

    rng = np.random.default_rng()  # repro-lint: allow(unseeded-random) demo only
    """
    kept, suppressed = lint_source(textwrap.dedent(src), CORE)
    assert kept == []
    assert [v.rule for v in suppressed] == ["unseeded-random"]


# --------------------------------------------------------------------------
# iter-order
# --------------------------------------------------------------------------


def test_iter_order_positive_for_loop_and_reductions():
    src = """
    def f(rv, members):
        acc = 0.0
        for d in rv.dims:          # set-valued attribute
            acc += rv[d]
        s = {1.0, 2.0}
        order = list(s)            # order-sensitive builtin over a set
        total = sum(x * x for x in s)
        table = {d: rv[d] for d in rv.soft_dims}
        return acc, order, total, table
    """
    vs = violations_of(src)
    assert {v.rule for v in vs} == {"iter-order"}
    assert len(vs) == 4


def test_iter_order_tracks_set_algebra_and_dict_of_sets():
    src = """
    def f(topology, hosts):
        upstream_of = {c: set(topology.upstream(c)) for c in topology.components}
        for up in upstream_of.get("b", ()):
            hosts[up] = True
        combined = upstream_of["a"] | {"x"}
        return [hosts[u] for u in combined]
    """
    vs = violations_of(src)
    assert [v.rule for v in vs] == ["iter-order"] * 2


def test_iter_order_negative_sorted_and_order_free_consumers():
    src = """
    def f(rv, demand):
        total = sum(rv[d] for d in sorted(rv.dims))
        ok = all(rv[d] >= demand[d] for d in demand.hard)
        n = len({d for d in rv.dims if rv[d] > 0})
        cols = sorted(rv[d] for d in rv.hard)
        for d in sorted(demand.dims | rv.dims):
            total += demand[d]
        return total, ok, n, cols
    """
    assert rules_hit(src) == set()


def test_iter_order_local_self_assignment_beats_zone_set_attrs():
    # PlacementArena binds self.dims to a *sorted list*; the zone-wide
    # "dims is a frozenset" fact must not apply to it.
    src = """
    class Arena:
        def __init__(self, dims):
            self.dims = sorted(dims)

        def weight_row(self, merged):
            return [merged.get(d, 1.0) for d in self.dims]
    """
    assert rules_hit(src) == set()


def test_iter_order_suppressed_by_comment_line_above():
    src = """
    def f(s):
        # repro-lint: allow(iter-order) order feeds a set, not floats
        # (multi-line justification keeps the suppression attached)
        return [x for x in s if x]

    def g():
        s = set("abc")
        return f(s)
    """
    kept, suppressed = lint_source(textwrap.dedent(src), CORE)
    assert kept == []
    assert suppressed == []  # `s` param type unknown inside f — nothing fires
    src2 = """
    s = set("abc")
    # repro-lint: allow(iter-order) demo
    # justification continues here
    order = list(s)
    """
    kept2, suppressed2 = lint_source(textwrap.dedent(src2), CORE)
    assert kept2 == []
    assert [v.rule for v in suppressed2] == ["iter-order"]


def test_wrong_rule_name_does_not_suppress():
    src = """
    s = {1, 2}
    order = list(s)  # repro-lint: allow(float-sum) wrong rule
    """
    kept, _ = lint_source(textwrap.dedent(src), CORE)
    assert [v.rule for v in kept] == ["iter-order"]


def test_wildcard_suppression():
    src = """
    s = {1, 2}
    order = list(s)  # repro-lint: allow(*) fixture
    """
    kept, suppressed = lint_source(textwrap.dedent(src), CORE)
    assert kept == [] and len(suppressed) == 1


# --------------------------------------------------------------------------
# float-sum / np-reduce-dtype / float32-literal
# --------------------------------------------------------------------------


def test_float_sum_positive_negative():
    bad = "def f(xs):\n    return sum(xs)\n"
    good = "import math\ndef f(xs):\n    return xs.sum() + math.fsum(xs)\n"
    assert rules_hit(bad) == {"float-sum"}
    assert rules_hit(good) == set()


def test_np_reduce_dtype_positive_negative():
    bad = """
    import numpy as np

    def f(a, b):
        return np.sum(a) + np.dot(a, b)
    """
    good = """
    import numpy as np

    def f(a, b):
        return np.sum(a, dtype=np.float64) + a.astype(np.float64) @ b
    """
    assert rules_hit(bad) == {"np-reduce-dtype"}
    assert rules_hit(good) == set()


def test_float32_literal_fires_only_in_hot_zone():
    src = """
    import numpy as np

    def f(n):
        return np.zeros(n, dtype=np.float32)
    """
    assert rules_hit(src, HOT) == {"float32-literal"}
    assert rules_hit(src, CORE) == set()  # core zone does not pin dtypes


def test_float32_dtype_string_in_hot_zone():
    src = """
    import jax.numpy as jnp

    def f(n):
        return jnp.zeros(n, dtype="float32")
    """
    assert rules_hit(src, HOT) == {"float32-literal"}


# --------------------------------------------------------------------------
# jax-purity / x64-scope
# --------------------------------------------------------------------------


def test_jax_purity_positive():
    src = """
    import jax
    import numpy as np

    TRACE_LOG = []
    CACHE = {}

    @jax.jit
    def step(x):
        print("tracing", x)
        y = np.asarray(x)
        TRACE_LOG.append(y)
        CACHE["last"] = y
        return x * 2
    """
    vs = violations_of(src)
    assert [v.rule for v in vs] == ["jax-purity"] * 4


def test_jax_purity_wrapped_call_form():
    src = """
    import jax

    def body(carry, x):
        print(x)
        return carry + x, x

    def run(xs):
        return jax.lax.scan(body, 0.0, xs)
    """
    assert rules_hit(src) == {"jax-purity"}


def test_jax_purity_negative():
    src = """
    import jax
    import jax.numpy as jnp

    @jax.jit
    def step(x):
        scratch = []
        scratch.append(x)          # local mutation is fine
        return jnp.sum(jnp.asarray(scratch[0]))
    """
    assert rules_hit(src) == set()


def test_x64_scope_positive_and_exemption():
    src = """
    import jax

    def force_x64():
        jax.config.update("jax_enable_x64", True)
    """
    assert rules_hit(src, CORE) == {"x64-scope"}
    # The scoped helper module itself is the one allowed owner.
    assert rules_hit(src, "src/repro/core/search/backend.py") == set()


def test_x64_scope_import_form():
    src = "from jax.experimental import enable_x64\n"
    assert rules_hit(src, CORE) == {"x64-scope"}


# --------------------------------------------------------------------------
# hot-loop
# --------------------------------------------------------------------------


def test_hot_loop_positive():
    src = """
    import copy
    import math
    import time

    def anneal_step(state, delta, temp):
        t0 = time.perf_counter()
        trial = copy.deepcopy(state)
        accept = delta < temp * math.exp(-1.0)
        return trial, accept, t0
    """
    vs = violations_of(src, HOT)
    assert [v.rule for v in vs] == ["hot-loop"] * 3


def test_hot_loop_not_active_outside_engine_search():
    # schedulers.py's legacy path may deepcopy — by zone design.
    src = "import copy\ndef f(c):\n    return copy.deepcopy(c)\n"
    assert rules_hit(src, CORE) == set()


def test_hot_loop_threshold_accepting_negative():
    src = """
    def accept(delta, threshold):
        return delta <= threshold  # exact comparison, no libm
    """
    assert rules_hit(src, HOT) == set()


# --------------------------------------------------------------------------
# pallas kernel zone: interpret / accum-order / accum-dtype / grid-truncate
# --------------------------------------------------------------------------


def test_pallas_interpret_positive_negative():
    bad = """
    import jax.experimental.pallas as pl

    def run(x):
        return pl.pallas_call(kernel, out_shape=x, interpret=True)(x)
    """
    good = """
    import jax.experimental.pallas as pl

    def run(x, interpret):
        return pl.pallas_call(kernel, out_shape=x, interpret=interpret)(x)
    """
    assert rules_hit(bad, KERNEL) == {"pallas-interpret"}
    assert rules_hit(good, KERNEL) == set()
    # Wrapper call sites are covered too — forcing interpret on a helper
    # that plumbs the flag is the same hazard.
    wrapper = "def f(ba, P):\n    return fused_score(ba, P, interpret=True)\n"
    assert rules_hit(wrapper, SEARCH_KERNEL) == {"pallas-interpret"}
    # Outside the kernel zones the rule is not active (tests pin
    # interpret=True deliberately — that is the golden-oracle harness).
    assert rules_hit(bad, CORE) == set()


def test_pallas_interpret_suppressed():
    src = """
    def run(x):
        # repro-lint: allow(pallas-interpret) CI smoke leg has no TPU
        return pl.pallas_call(kernel, out_shape=x, interpret=True)(x)
    """
    kept, suppressed = lint_source(textwrap.dedent(src), KERNEL)
    assert kept == []
    assert [v.rule for v in suppressed] == ["pallas-interpret"]


def test_pallas_accum_order_positive_negative():
    bad = """
    def kernel(x_ref, o_ref):
        i = pl.program_id(0)
        o_ref[0] += x_ref[i]
    """
    good = """
    def kernel(x_ref, o_ref):
        o_ref[...] = x_ref[...].sum()
    """
    assert rules_hit(bad, KERNEL) == {"pallas-accum-order"}
    assert rules_hit(good, KERNEL) == set()


def test_pallas_accum_order_inline_program_id_and_suppression():
    bad = "def kernel(x_ref, o_ref):\n    o_ref[pl.program_id(0)] += 1.0\n"
    assert rules_hit(bad, KERNEL) == {"pallas-accum-order"}
    ok = (
        "def kernel(x_ref, o_ref):\n"
        "    # repro-lint: allow(pallas-accum-order) grid-quantized exact adds\n"
        "    o_ref[pl.program_id(0)] += 1.0\n"
    )
    kept, suppressed = lint_source(ok, KERNEL)
    assert kept == [] and len(suppressed) == 1


def test_pallas_accum_dtype_positive_negative():
    bad = """
    import jax.numpy as jnp
    import numpy as np

    def kernel(x_ref, o_ref):
        acc = jnp.zeros((8, 4))
        out = np.zeros(8, dtype=np.float32)
        return acc, out
    """
    good = """
    import jax.numpy as jnp
    import numpy as np

    def kernel(x_ref, o_ref):
        acc = jnp.zeros((8, 4), dtype=jnp.float64)
        idx = np.zeros(8, np.int32)
        flags = np.full(8, False, dtype=np.bool_)
        return acc, idx, flags
    """
    vs = violations_of(bad, SEARCH_KERNEL)
    # missing dtype (jnp defaults to f32) + explicit f32; the f32 literal
    # also trips the hot-loop zone's float32-literal rule on this path.
    assert {v.rule for v in vs} >= {"pallas-accum-dtype"}
    assert sum(v.rule == "pallas-accum-dtype" for v in vs) == 2
    assert rules_hit(good, SEARCH_KERNEL) == set()
    # The float32 flash kernels are outside the exactness subzone.
    assert "pallas-accum-dtype" not in rules_hit(bad, KERNEL)


def test_pallas_grid_truncate_positive_negative():
    bad = """
    import jax.experimental.pallas as pl

    def run(x, B, blk):
        return pl.pallas_call(kernel, grid=(B // blk,), out_shape=x)(x)
    """
    good = """
    import jax.experimental.pallas as pl

    def run(x, B, blk):
        return pl.pallas_call(kernel, grid=(pl.cdiv(B, blk),), out_shape=x)(x)
    """
    assert rules_hit(bad, KERNEL) == {"pallas-grid-truncate"}
    assert rules_hit(good, KERNEL) == set()
    # Floor division elsewhere in a kernel file is fine — only a
    # pallas_call grid silently drops work.
    other = "def f(n, b):\n    return n // b\n"
    assert rules_hit(other, KERNEL) == set()


# --------------------------------------------------------------------------
# regression: the real weighted_distance violation fixed in this PR
# --------------------------------------------------------------------------

WEIGHTED_DISTANCE_PRE_FIX = """
import math

def weighted_distance(demand, avail, w, network_distance):
    acc = 0.0
    for d in (demand.dims | avail.dims) - {"bandwidth"}:
        acc += w.get(d, 1.0) * (demand[d] - avail[d]) ** 2
    acc += w.get("bandwidth", 1.0) * network_distance ** 2
    return math.sqrt(acc)
"""


def test_regression_weighted_distance_pre_fix_flagged():
    vs = violations_of(WEIGHTED_DISTANCE_PRE_FIX, "src/repro/core/resources.py")
    assert [v.rule for v in vs] == ["iter-order"]
    assert vs[0].line == 6  # the `for d in (... | ...) - {...}` header


def test_regression_weighted_distance_post_fix_clean():
    fixed = WEIGHTED_DISTANCE_PRE_FIX.replace(
        'for d in (demand.dims | avail.dims) - {"bandwidth"}:',
        'for d in sorted((demand.dims | avail.dims) - {"bandwidth"}):',
    )
    assert violations_of(fixed, "src/repro/core/resources.py") == []


# --------------------------------------------------------------------------
# engine mechanics: rendering, ordering, parse errors, CLI, tree gate
# --------------------------------------------------------------------------


def test_violation_render_format():
    v = Violation(path="a/b.py", line=3, col=7, rule="iter-order", message="m")
    assert v.render() == "a/b.py:3:7: iter-order: m"


def test_violations_sorted_by_position():
    src = """
    s = {1, 2}
    b = list(s)
    a = tuple(s)
    """
    vs = violations_of(src)
    assert [v.line for v in vs] == sorted(v.line for v in vs)


def test_parse_error_reported_not_raised():
    kept, _ = lint_source("def broken(:\n", CORE)
    assert [v.rule for v in kept] == ["parse-error"]


def test_cli_clean_dirty_and_missing_path(tmp_path, capsys):
    clean = tmp_path / "src" / "repro" / "core" / "ok.py"
    clean.parent.mkdir(parents=True)
    clean.write_text("x = 1\n", encoding="utf-8")
    dirty = clean.with_name("bad.py")
    dirty.write_text("s = {1, 2}\norder = list(s)\n", encoding="utf-8")

    assert main([str(clean)]) == 0
    rc = main([str(dirty)])
    out = capsys.readouterr().out
    assert rc == 1
    assert "bad.py:2:" in out and "iter-order" in out
    assert main([str(tmp_path / "nope")]) == 2


def test_cli_list_rules(capsys):
    assert main(["--list-rules"]) == 0
    listed = capsys.readouterr().out.split()
    assert set(listed) == set(RULES)


def test_tree_is_clean():
    """The acceptance gate: the real tree has zero unsuppressed violations."""
    roots = [
        str(REPO_ROOT / "src"),
        str(REPO_ROOT / "benchmarks"),
        str(REPO_ROOT / "examples"),
    ]
    violations, _suppressed, n_zone = lint_paths(roots)
    assert violations == [], "\n".join(v.render() for v in violations)
    assert n_zone > 30  # the zones really do cover the tree


def test_module_entrypoint_runs_clean():
    """`python -m repro.analysis.lint` exits 0 on the tree (no runpy warning)."""
    proc = subprocess.run(
        [sys.executable, "-m", "repro.analysis.lint", "src", "benchmarks", "examples"],
        cwd=REPO_ROOT,
        capture_output=True,
        text=True,
        env={**__import__("os").environ, "PYTHONPATH": str(REPO_ROOT / "src")},
    )
    assert proc.returncode == 0, proc.stdout + proc.stderr
    assert "RuntimeWarning" not in proc.stderr
