"""Small-mesh dry-run integration test: the same lower+compile path as the
512-device production dry-run, on a tiny forced-device mesh.

Runs in a subprocess because XLA_FLAGS must be set before jax initializes.
"""

import json
import os
import subprocess
import sys
import textwrap

import pytest

pytest.importorskip("jax")  # the subprocess under test imports jax


def _env():
    # Hermetic except for the platform pin: without JAX_PLATFORMS the
    # subprocess's jax import can hang probing for accelerator backends
    # on hosts that set it for exactly that reason.
    env = {"PYTHONPATH": "src", "PATH": "/usr/bin:/bin"}
    if "JAX_PLATFORMS" in os.environ:
        env["JAX_PLATFORMS"] = os.environ["JAX_PLATFORMS"]
    return env

SCRIPT = textwrap.dedent(
    """
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import dataclasses, json
    import jax
    import jax.numpy as jnp
    from jax.sharding import NamedSharding, PartitionSpec as P

    from repro import configs
    from repro.configs.base import ShapeCell
    from repro.models import build_from_config, input_specs
    from repro.placement import MeshShape, ResourceAwarePlanner, activation_rules
    from repro.launch.dryrun import _lower_cell, collective_bytes

    mesh = jax.make_mesh((4, 2), ("data", "model"))
    mshape = MeshShape({"data": 4, "model": 2})
    planner = ResourceAwarePlanner()
    results = {}
    for arch, shape in (
        ("qwen3-0.6b", ShapeCell("train_small", 256, 8, "train")),
        ("olmoe-1b-7b", ShapeCell("decode_small", 256, 8, "decode")),
        ("recurrentgemma-9b", ShapeCell("prefill_small", 256, 8, "prefill")),
    ):
        cfg = dataclasses.replace(
            configs.get_smoke(arch), n_layers=len(configs.get_smoke(arch).pattern)
        )
        model = build_from_config(cfg)
        plan = planner.plan(model, shape, mshape)
        specs = input_specs(cfg, shape)
        with mesh:
            with activation_rules(plan.activation_rules):
                lowered = _lower_cell(
                    model, cfg, shape, mesh, mshape, plan, specs, 1, False
                )
                compiled = lowered.compile()
        txt = compiled.as_text()
        results[arch] = {
            "collectives": sorted(collective_bytes(txt)),
            "mem": float(compiled.memory_analysis().temp_size_in_bytes),
        }
    print("RESULT " + json.dumps(results))
    """
)


@pytest.mark.slow
def test_small_mesh_dryrun_compiles_all_kinds():
    proc = subprocess.run(
        [sys.executable, "-c", SCRIPT],
        capture_output=True,
        text=True,
        timeout=420,
        env=_env(),
        cwd=".",
    )
    assert proc.returncode == 0, proc.stderr[-3000:]
    line = [l for l in proc.stdout.splitlines() if l.startswith("RESULT ")][-1]
    results = json.loads(line[len("RESULT "):])
    assert set(results) == {"qwen3-0.6b", "olmoe-1b-7b", "recurrentgemma-9b"}
    for arch, r in results.items():
        assert r["mem"] >= 0
